"""Shared benchmark utilities: datasets, timing, device models.

Hardware models used when a figure needs the paper's GPUs (this container is
CPU-only): V100 PCIe gen3 ~12 GB/s H2D/D2H; paper Fig. 12 saturated kernel
throughputs (MGARD 45, ZFP 210, Huffman 150 GB/s on V100-class).  Our own
measured CPU numbers are always reported alongside the modeled ones.
"""

from __future__ import annotations

import time
from dataclasses import dataclass

import jax
import numpy as np

V100 = {
    "h2d_bps": 12e9,
    "d2h_bps": 12e9,
    "kernel_bps": {"mgard": 45e9, "zfp": 210e9, "huffman": 150e9},
    "output_fraction": {"mgard": 0.2, "zfp": 0.5, "huffman": 0.7},
}


def nyx_like(n: int = 64, seed: int = 0) -> np.ndarray:
    """Smooth-ish cosmology-like density field (NYX stand-in)."""
    rng = np.random.default_rng(seed)
    g = np.linspace(0, 8 * np.pi, n)
    x, y, z = np.meshgrid(g, g, g, indexing="ij")
    f = (
        np.sin(x) * np.cos(y) * np.sin(z)
        + 0.5 * np.sin(2 * x + 1) * np.cos(3 * z)
        + 0.05 * rng.normal(size=x.shape)
    )
    return np.exp(f.astype(np.float32))  # positive, skewed like density


def timeit(fn, *args, repeat: int = 3, warmup: int = 1) -> float:
    for _ in range(warmup):
        jax.block_until_ready(fn(*args))
    ts = []
    for _ in range(repeat):
        t0 = time.perf_counter()
        jax.block_until_ready(fn(*args))
        ts.append(time.perf_counter() - t0)
    return min(ts)


@dataclass
class Row:
    name: str
    us_per_call: float
    derived: str

    def emit(self) -> None:
        print(f"{self.name},{self.us_per_call:.1f},{self.derived}")
