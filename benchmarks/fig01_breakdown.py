"""Fig. 1 — time breakdown of un-pipelined reduction (memory ops vs compute).

Paper claim: 34–89% of end-to-end time is memory operations (H2D/D2H/alloc)
when reducing 500 MB NYX on V100 without pipelining.  We reproduce the
breakdown with the paper's V100 device model (kernel throughputs from its
own Fig. 12, PCIe ~12 GB/s) and report our measured CPU-XLA kernel
throughput alongside.
"""

from __future__ import annotations

import jax.numpy as jnp
import numpy as np

from .common import V100, Row, nyx_like, timeit
from repro.core import api


def breakdown(method: str, nbytes: float) -> dict:
    k_bps = V100["kernel_bps"][method]
    out_frac = V100["output_fraction"][method]
    t_h2d = nbytes / V100["h2d_bps"]
    t_kernel = nbytes / k_bps
    t_d2h = nbytes * out_frac / V100["d2h_bps"]
    t_total = t_h2d + t_kernel + t_d2h
    return {
        "mem_share": (t_h2d + t_d2h) / t_total,
        "t_total": t_total,
        "t_kernel": t_kernel,
    }


def main() -> None:
    nbytes = 500e6  # paper: 500 MB NYX
    for method in ("mgard", "zfp", "huffman"):
        b = breakdown(method, nbytes)
        Row(
            f"fig01.{method}.v100_model",
            b["t_total"] * 1e6,
            f"mem_share={b['mem_share']:.1%}",
        ).emit()

    # our measured CPU-XLA compress throughput (small field; compute only —
    # the spec is prebuilt so every timed call hits the cached plan)
    data = nyx_like(48)
    x = jnp.asarray(data)
    for method, kw in (("mgard", {"error_bound": 1e-2}), ("zfp", {"rate": 16})):
        spec = api.make_spec(data, method, **kw)
        t = timeit(lambda: api.encode(spec, x), repeat=2)
        bps = data.nbytes / t
        Row(
            f"fig01.{method}.cpu_measured",
            t * 1e6,
            f"kernel_bps={bps/1e6:.1f}MB/s",
        ).emit()


if __name__ == "__main__":
    main()
