"""Figs. 10 & 13 — chunked pipeline: none / fixed(small,large) / adaptive.

Fig. 10: 4.3 GB variable through MGARD on the V100 model — sustained
throughput + overlap ratio for fixed-100MB, fixed-2GB, adaptive.
Fig. 13: end-to-end speedups (the paper reports up to 2.1×/3.5× for
fixed-vs-none on MGARD/ZFP and 1.3×/1.6× adaptive-vs-fixed).

Also runs the REAL ChunkedPipeline (CPU) on a small field as an execution
check (timings are CPU-scale; the schedule logic is identical).
"""

from __future__ import annotations

import numpy as np

from .common import V100, Row, nyx_like
from repro.core import api, chunk_model as cm, pipeline as pl


def v100_phi(method: str) -> cm.PhiModel:
    gamma = V100["kernel_bps"][method]
    c_thr = 1 << 30  # saturates near 1 GB chunks (paper Fig. 11)
    return cm.PhiModel(alpha=gamma / c_thr, beta0=gamma * 0.02, gamma=gamma,
                       c_threshold=c_thr)


def main() -> None:
    total = int(4.3e9)
    for method in ("mgard", "zfp"):
        phi = v100_phi(method)
        out_frac = V100["output_fraction"][method]
        reps = {}
        for mode, kw in (
            ("none", {}),
            ("fixed_small", {"c_fixed": 100 << 20}),
            ("fixed_large", {"c_fixed": 2 << 30}),
            ("adaptive", {"c_init": 16 << 20, "c_limit": 2 << 30}),
        ):
            sim_mode = mode.split("_")[0] if mode != "adaptive" else "adaptive"
            rep = pl.simulate_pipeline(
                total, sim_mode, phi, V100["h2d_bps"], V100["d2h_bps"],
                output_fraction=out_frac, **kw,
            )
            reps[mode] = rep
            Row(
                f"fig10.{method}.{mode}",
                rep.makespan * 1e6,
                f"sustained={rep.sustained_bps/1e9:.1f}GB/s overlap={rep.overlap_ratio:.1%} chunks={len(rep.chunk_sizes)}",
            ).emit()
        Row(
            f"fig13.{method}.fixed_vs_none",
            0.0,
            f"speedup={reps['none'].makespan/reps['fixed_small'].makespan:.2f}x",
        ).emit()
        Row(
            f"fig13.{method}.adaptive_vs_fixed_small",
            0.0,
            f"speedup={reps['fixed_small'].makespan/reps['adaptive'].makespan:.2f}x",
        ).emit()
        Row(
            f"fig13.{method}.adaptive_vs_fixed_large",
            0.0,
            f"speedup={reps['fixed_large'].makespan/reps['adaptive'].makespan:.2f}x",
        ).emit()

    # real execution check (CPU): chunked compress of a 32^3 field through
    # the streaming API (every chunk after the first hits the plan cache)
    data = nyx_like(32)
    stream = api.CompressorStream("zfp", mode="fixed", c_fixed_elems=8 * 32 * 32,
                                  rate=16)
    res = stream.compress(data)
    out = stream.decompress(res)
    err = float(np.abs(out - data).max())
    Row(
        "fig13.real_chunked_exec",
        res.wall_time * 1e6,
        f"chunks={len(res.chunks)} ratio={res.ratio():.2f}x maxerr={err:.2e}",
    ).emit()


if __name__ == "__main__":
    main()
