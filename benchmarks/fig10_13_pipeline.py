"""Figs. 10 & 13 — chunked pipeline: model rows + REAL overlap measurement.

Two halves:

  1. **Model** (Fig. 10/13): the V100 timeline simulation — sustained
     throughput + overlap ratio for none / fixed(small,large) / adaptive
     chunk schedules (the paper reports up to 2.1x/3.5x fixed-vs-none and
     1.3x/1.6x adaptive-vs-fixed).
  2. **Execution** (PR 5): the real lane-overlapped ``CompressorStream``
     on a ≥8-chunk stream.  The pipelined run (window=2) is compared
     against (a) the measured serial run (window=1, same code path) and
     (b) the *serial sum* of its own per-lane busy times — overlap
     efficiency is ``serial_sum / pipelined_wall`` (>1 means lanes really
     ran concurrently).  Both runs are asserted bit-identical.
  3. **Prediction validation** (PR 7): every stream also runs with
     ``chunk_size="auto", window="auto"`` — the calibrated cost model +
     timeline simulator picks the schedule and *predicts* its makespan;
     the predicted wall is compared against the measured wall
     (``prediction_error``, target <10%), the auto stream is re-run as an
     explicit fixed stream at the resolved (chunk, window) and asserted
     bit-identical, and a window=1 run at the same chunk size checks the
     tuner never loses to serial.

``--smoke --out BENCH_pipeline.json`` (via ``scripts/check.sh bench
pipeline``) emits the JSON consumed by CI trend tracking: per-lane
seconds, measured walls, overlap efficiency, prediction errors, and the
bit-identity bits.
"""

from __future__ import annotations

import argparse
import json
from pathlib import Path

import numpy as np

from .common import V100, Row, nyx_like
from repro.core import api, chunk_model as cm, pipeline as pl


def v100_phi(method: str) -> cm.PhiModel:
    gamma = V100["kernel_bps"][method]
    c_thr = 1 << 30  # saturates near 1 GB chunks (paper Fig. 11)
    return cm.PhiModel(alpha=gamma / c_thr, beta0=gamma * 0.02, gamma=gamma,
                       c_threshold=c_thr)


def model_rows() -> dict:
    out = {}
    total = int(4.3e9)
    for method in ("mgard", "zfp"):
        phi = v100_phi(method)
        out_frac = V100["output_fraction"][method]
        reps = {}
        for mode, kw in (
            ("none", {}),
            ("fixed_small", {"c_fixed": 100 << 20}),
            ("fixed_large", {"c_fixed": 2 << 30}),
            ("adaptive", {"c_init": 16 << 20, "c_limit": 2 << 30}),
        ):
            sim_mode = mode.split("_")[0] if mode != "adaptive" else "adaptive"
            rep = pl.simulate_pipeline(
                total, sim_mode, phi, V100["h2d_bps"], V100["d2h_bps"],
                output_fraction=out_frac, **kw,
            )
            reps[mode] = rep
            out[f"fig10.{method}.{mode}"] = {
                "makespan_s": rep.makespan,
                "sustained_gbps": rep.sustained_bps / 1e9,
                "overlap_ratio": rep.overlap_ratio,
                "chunks": len(rep.chunk_sizes),
            }
            Row(
                f"fig10.{method}.{mode}",
                rep.makespan * 1e6,
                f"sustained={rep.sustained_bps/1e9:.1f}GB/s overlap={rep.overlap_ratio:.1%} chunks={len(rep.chunk_sizes)}",
            ).emit()
        for name, num, den in (
            ("fixed_vs_none", "none", "fixed_small"),
            ("adaptive_vs_fixed_small", "fixed_small", "adaptive"),
            ("adaptive_vs_fixed_large", "fixed_large", "adaptive"),
        ):
            speed = reps[num].makespan / reps[den].makespan
            out[f"fig13.{method}.{name}"] = {"speedup": speed}
            Row(f"fig13.{method}.{name}", 0.0, f"speedup={speed:.2f}x").emit()
    return out


def measure_stream(method: str, data: np.ndarray, window: int,
                   c_fixed_elems: int, **params) -> pl.ChunkedResult:
    # frame=True: the io lane also produces each chunk's wire bytes
    # (container framing + crc32), the work a storage pipeline always pays
    stream = api.CompressorStream(
        method, mode="fixed", c_fixed_elems=c_fixed_elems,
        window=window, backend="xla", frame=True, **params)
    return stream.compress(data)


def measure_auto(method: str, data: np.ndarray, **params) -> pl.ChunkedResult:
    stream = api.CompressorStream(
        method, chunk_size="auto", window="auto", backend="xla", frame=True,
        **params)
    return stream.compress(data)


def auto_validation(method: str, params: dict, data: np.ndarray,
                    repeat: int = 3) -> dict:
    """Run the auto-tuned stream; validate prediction, identity, serial."""
    # first auto run calibrates this machine if no store exists (one-time,
    # persisted); the measured repeats below all hit the warm store and
    # cover the tuner's candidate race plus exploitation of the winner
    from repro.core import tuner

    measure_auto(method, data, **params)
    n_runs = repeat + tuner._EXPLORE_K * tuner._EXPLORE_RUNS
    res_auto = min(
        (measure_auto(method, data, **params) for _ in range(n_runs)),
        key=lambda r: r.wall_time,
    )
    tuned = res_auto.tuned or {}
    chunk_elems = tuned.get("chunk_elems", max(1, data.size // max(
        1, len(res_auto.chunks))))
    window = res_auto.window

    # bit-identity: the SAME (chunk, window) requested explicitly must
    # produce byte-identical wire output
    res_explicit = measure_stream(method, data, window, chunk_elems, **params)
    bit_identical = (
        api.CompressorStream.to_bytes(res_auto)
        == api.CompressorStream.to_bytes(res_explicit)
    )
    # never-worse-than-serial: window=1 at the tuner's own chunk size,
    # interleaved with further auto runs — millisecond walls drift with
    # machine load, interleaving keeps the drift symmetric
    auto_walls, serial_walls = [], []
    for _ in range(repeat + 6):
        auto_walls.append(measure_auto(method, data, **params).wall_time)
        serial_walls.append(
            measure_stream(method, data, 1, chunk_elems, **params).wall_time)
    auto_wall = min(res_auto.wall_time, min(auto_walls))
    serial_wall = min(serial_walls)

    # post-convergence prediction: every auto run fed its measured wall
    # back via tuner.observe, so re-planning yields the settled estimate
    # rather than the pre-feedback one embedded in res_auto
    final = tuner.plan_stream(
        data.size, data.dtype.itemsize, method=method,
        dtype=str(data.dtype), backend="xla", params=params)
    if final.source == "calibrated":
        pred, pred_serial = final.predicted_s, final.predicted_serial_s
    else:
        pred = tuned.get("predicted_s")
        pred_serial = tuned.get("predicted_serial_s")
    err = abs(pred - auto_wall) / auto_wall if pred else None
    err_serial = (abs(pred_serial - serial_wall) / serial_wall
                  if pred_serial else None)
    report = {
        "chunk_elems": int(chunk_elems),
        "window": int(window),
        "chunks": len(res_auto.chunks),
        "source": tuned.get("source", "unknown"),
        "wall_s": auto_wall,
        "predicted_s": pred,
        "prediction_error": err,
        "serial_wall_s": serial_wall,
        "predicted_serial_s": pred_serial,
        "serial_prediction_error": err_serial,
        "speedup_vs_serial": serial_wall / auto_wall,
        "bit_identical_to_explicit": bool(bit_identical),
    }
    pe = f"{err:.1%}" if err is not None else "n/a"
    Row(
        f"fig10.auto.{method}",
        auto_wall * 1e6,
        f"chunks={report['chunks']} window={window} pred_err={pe} "
        f"vs_serial={report['speedup_vs_serial']:.2f}x "
        f"bit_identical={bit_identical}",
    ).emit()
    return report


def real_overlap(method: str, params: dict, data: np.ndarray,
                 n_chunks: int, repeat: int = 3) -> dict:
    """Measure the pipelined vs serial CompressorStream on real data."""
    c_fixed = max(1, data.size // n_chunks)
    # warm up: compile every per-chunk plan so walls measure execution
    measure_stream(method, data, 2, c_fixed, **params)

    res_pipe = min(
        (measure_stream(method, data, 2, c_fixed, **params)
         for _ in range(repeat)),
        key=lambda r: r.wall_time,
    )
    res_serial = min(
        (measure_stream(method, data, 1, c_fixed, **params)
         for _ in range(repeat)),
        key=lambda r: r.wall_time,
    )

    bit_identical = (
        api.CompressorStream.to_bytes(res_pipe)
        == api.CompressorStream.to_bytes(res_serial)
    )
    lanes = res_pipe.lane_seconds()
    serial_sum = sum(lanes.values())
    report = {
        "chunks": len(res_pipe.chunks),
        "window": 2,
        "max_in_flight": res_pipe.max_in_flight,
        "raw_mb": data.nbytes / 1e6,
        "ratio": res_pipe.ratio(),
        "pipelined_wall_s": res_pipe.wall_time,
        "serial_wall_s": res_serial.wall_time,
        "lane_seconds": lanes,
        "serial_lane_sum_s": serial_sum,
        "overlap_efficiency": serial_sum / res_pipe.wall_time,
        "speedup_vs_serial_run": res_serial.wall_time / res_pipe.wall_time,
        "bit_identical": bool(bit_identical),
        "per_chunk": [
            {"nbytes": t.nbytes, "h2d_s": t.h2d, "compute_s": t.compute,
             "serialize_s": t.serialize}
            for t in res_pipe.timings
        ],
    }
    Row(
        f"fig10.real.{method}",
        res_pipe.wall_time * 1e6,
        (f"chunks={report['chunks']} overlap_eff="
         f"{report['overlap_efficiency']:.2f}x serial_sum="
         f"{serial_sum*1e3:.1f}ms wall={res_pipe.wall_time*1e3:.1f}ms "
         f"bit_identical={bit_identical}"),
    ).emit()
    return report


def main(argv=None) -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--smoke", action="store_true",
                    help="CPU-sized data (CI); same code path as full size")
    ap.add_argument("--out", type=Path, default=None,
                    help="write BENCH_pipeline.json here")
    args = ap.parse_args(argv)

    report = {"model": model_rows(), "real": {}}
    n, n_chunks = (48, 8) if args.smoke else (96, 12)
    smooth = nyx_like(n)
    # checkpoint-like incompressible state: the lossless path where wire
    # serialization is a real fraction of the chunk cost
    noise = np.random.default_rng(0).normal(size=smooth.shape).astype(np.float32)
    report["auto"] = {}
    for method, params, data in (
        ("zfp", {"rate": 16}, smooth),
        ("mgard", {"error_bound": 1e-2}, smooth),
        ("huffman-bytes", {}, noise),
    ):
        report["real"][method] = real_overlap(method, params, data, n_chunks)
        report["auto"][method] = auto_validation(method, params, data)

    ok = all(r["bit_identical"] for r in report["real"].values())
    overlapped = all(
        r["overlap_efficiency"] > 1.0 for r in report["real"].values()
    )
    auto_ok = all(
        r["bit_identical_to_explicit"] for r in report["auto"].values()
    )
    pred_errs = [r["prediction_error"] for r in report["auto"].values()
                 if r["prediction_error"] is not None]
    report["summary"] = {
        "bit_identical": ok,
        "all_streams_overlap": overlapped,
        "min_overlap_efficiency": min(
            r["overlap_efficiency"] for r in report["real"].values()
        ),
        "auto_bit_identical": auto_ok,
        "auto_never_worse_than_serial": all(
            r["wall_s"] <= r["serial_wall_s"] * 1.05
            for r in report["auto"].values()
        ),
        "max_prediction_error": max(pred_errs) if pred_errs else None,
    }
    if args.out:
        args.out.write_text(json.dumps(report, indent=1))
        print(f"wrote {args.out}")
    if not ok:
        raise SystemExit("pipelined stream is NOT bit-identical to serial")
    if not auto_ok:
        raise SystemExit("auto-tuned stream is NOT bit-identical to explicit")


if __name__ == "__main__":
    main()
