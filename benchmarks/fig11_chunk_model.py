"""Fig. 11 — Φ(C) roofline chunk-size model, fitted from real profiles.

Profiles OUR ZFP-X pipeline on CPU across chunk sizes, fits the paper's
piecewise linear→constant model, and reports fit quality — the same
procedure the paper uses to build its adaptive-pipeline estimator.
"""

from __future__ import annotations

import jax.numpy as jnp
import numpy as np

from .common import Row, nyx_like, timeit
from repro.core import chunk_model as cm
from repro.core import zfp


def main() -> None:
    data = nyx_like(64).reshape(-1)
    sizes = [4096, 16384, 65536, 262144]
    chunk_bytes, bps = [], []
    for n in sizes:
        x = jnp.asarray(data[:n])
        t = timeit(lambda x=x: zfp.compress_jit(x, 16, 1, (n,)), repeat=2)
        chunk_bytes.append(n * 4)
        bps.append(n * 4 / t)
        Row(f"fig11.profile.{n*4>>10}KB", t * 1e6, f"bps={n*4/t/1e6:.1f}MB/s").emit()
    phi = cm.fit_phi(np.array(chunk_bytes), np.array(bps))
    pred = phi(np.array(chunk_bytes))
    r2 = 1 - np.sum((pred - bps) ** 2) / max(np.sum((bps - np.mean(bps)) ** 2), 1e-12)
    Row(
        "fig11.phi_fit",
        0.0,
        f"gamma={phi.gamma/1e6:.1f}MB/s c_thr={phi.c_threshold/1024:.0f}KB r2={r2:.3f}",
    ).emit()


if __name__ == "__main__":
    main()
