"""Fig. 12 — kernel throughput per pipeline × adapter ("portability × perf").

The paper's five processors become our adapter matrix: xla-cpu (measured),
pallas_interpret (measured; Python interpretation, correctness surface), and
the TPU-v5e projection (roofline: these kernels are memory-bound, so
throughput ≈ HBM_bw / bytes-touched-per-input-byte).
"""

from __future__ import annotations

import jax.numpy as jnp
import numpy as np

from .common import Row, nyx_like, timeit
from repro.core import huffman
from repro.kernels.zfp_block import ops as zfp_ops
from repro.runtime.roofline import HBM_BW

# bytes touched per input byte (read in + write out + tables), per pipeline
_TPU_TRAFFIC_FACTOR = {"zfp": 1.6, "huffman": 2.2, "mgard": 3.5}


def main() -> None:
    data = nyx_like(32)
    blocks = data.reshape(-1, 64)[:2048]

    for adapter in ("xla", "pallas_interpret"):
        x = jnp.asarray(blocks)
        t = timeit(
            lambda: zfp_ops.compress_blocks(x, 16, 3, adapter=adapter), repeat=2
        )
        Row(
            f"fig12.zfp.{adapter}",
            t * 1e6,
            f"bps={blocks.nbytes/t/1e6:.1f}MB/s",
        ).emit()

    keys = jnp.asarray(
        np.minimum(np.abs(np.random.default_rng(0).normal(0, 30, 1 << 18)), 4095
                   ).astype(np.int32)
    )
    t = timeit(lambda: huffman.histogram(keys, 4096), repeat=2)
    Row("fig12.huffman_hist.xla", t * 1e6,
        f"bps={keys.nbytes/t/1e6:.1f}MB/s").emit()

    for method, factor in _TPU_TRAFFIC_FACTOR.items():
        proj = HBM_BW / factor
        Row(f"fig12.{method}.tpu_v5e_roofline", 0.0,
            f"projected_bps={proj/1e9:.0f}GB/s (memory-bound, factor={factor})").emit()


if __name__ == "__main__":
    main()
