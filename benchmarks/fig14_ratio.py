"""Fig. 14 — compression ratio vs pipeline setting (none / fixed / adaptive).

Paper claim: small fixed chunks cost 5–67% of MGARD's ratio (decorrelation
range is truncated); adaptive ends within 1% of un-chunked because most
bytes flow through large chunks.  ZFP is insensitive (4^d blocks ≪ chunk).
"""

from __future__ import annotations

import numpy as np
import jax.numpy as jnp

from .common import Row, nyx_like
from repro.core import api


def _ratio_chunked(data: np.ndarray, method: str, kw: dict, rows: list[int]) -> float:
    total_raw, total_comp = 0, 0
    start = 0
    for r in rows:
        chunk = data[start : start + r]
        c = api.compress(jnp.asarray(chunk), method, **kw)
        total_raw += chunk.nbytes
        total_comp += c.nbytes()
        start += r
    return total_raw / total_comp


def main() -> None:
    data = nyx_like(64)
    flat = data.reshape(64, -1)
    n = flat.shape[0]
    for method, kw in (
        ("mgard", {"error_bound": 1e-2}),
        ("zfp", {"rate": 12}),
    ):
        whole = api.compress(jnp.asarray(data), method, **kw).ratio()
        small = _ratio_chunked(flat, method, kw, [4] * (n // 4))       # tiny chunks
        # adaptive-like: one small lead-in chunk then big ones
        adaptive = _ratio_chunked(flat, method, kw, [4, 12, 48])
        Row(f"fig14.{method}.none", 0.0, f"ratio={whole:.2f}x").emit()
        Row(f"fig14.{method}.fixed_small", 0.0,
            f"ratio={small:.2f}x loss={(1-small/whole):.1%}").emit()
        Row(f"fig14.{method}.adaptive", 0.0,
            f"ratio={adaptive:.2f}x loss={(1-adaptive/whole):.1%}").emit()


if __name__ == "__main__":
    main()
