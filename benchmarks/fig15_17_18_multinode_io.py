"""Figs. 15/17/18 — measured multi-host parallel I/O: aggregation wins.

The paper's multi-node result (Figs. 15/17/18) is that *aggregated*
parallel writes — every device's leaf coalesced into one shard file per
host — beat both the file-per-rank layout (one file per leaf: metadata
storms) and a single shared file (all hosts pwrite one inode: server-side
serialization).  This benchmark **measures** that contest on this machine
instead of modeling it:

  * hosts are simulated as real subprocesses (``HPDR_HOST_ID`` /
    ``HPDR_HOST_COUNT``, the same environment contract the multi-host
    checkpoint tests use), synchronized through ``launch.mesh.fs_barrier``
    so every host's write burst starts together;
  * each (strategy × host-count) cell writes the same total volume —
    ``blobs`` segments of ``blob_bytes`` per host — and the experiment
    wall is the **max** across hosts (the straggler defines a parallel
    write).  Blobs are deliberately small (the paper's regime: one blob
    per compressed leaf, many leaves per device) — the regime where
    per-object metadata and syscall overhead dominates the file-per-rank
    layout and aggregation pays;
  * ``aggregated`` additionally validates the coordinator path: host 0
    stitches the shard directories into a global view
    (``stitch_shard_directories``) and its (untimed) cost is reported;
  * Fig. 18's restore side is measured in-process: a topology-aware
    ``ShardSetReader`` reading only locally-owned segments vs a remeshed
    reader forced cross-shard, with pread-locality stats.

Rows: ``fig15.aggregated.h<N>`` (throughput scaling across host counts),
``fig17.<strategy>.h<N>`` (strategy contest), ``fig18.restore.*``.
Artifact: ``BENCH_io.json`` (``scripts/check.sh bench io``), including
``aggregated_ge_file_per_rank`` per host count — the acceptance gate.
"""

from __future__ import annotations

import argparse
import json
import os
import subprocess
import sys
import tempfile
import time
from pathlib import Path

import numpy as np

from .common import Row

STRATEGIES = ("aggregated", "file_per_rank", "shared_file")

_REPO_ROOT = Path(__file__).resolve().parents[1]


# ---------------------------------------------------------------------------
# worker: one simulated host (runs in a subprocess)
# ---------------------------------------------------------------------------


def _worker(args: argparse.Namespace) -> None:
    from repro.launch.mesh import HostTopology, fs_barrier
    from repro.runtime.io import (
        AggregatedWriter,
        shard_file_name,
        stitch_shard_directories,
    )

    topo = HostTopology(args.host, args.hosts)
    base = Path(args.dir)
    blob = (
        np.random.default_rng(args.host)
        .integers(0, 256, size=args.blob_bytes, dtype=np.uint8)
        .tobytes()
    )
    strategies = args.strategies.split(",")
    walls: dict[str, float] = {}
    extra: dict[str, float] = {}
    for trial in range(args.trials):
        for strategy in strategies:
            d = base / f"{strategy}-{trial}"
            d.mkdir(parents=True, exist_ok=True)
            if strategy == "shared_file" and topo.host_id == 0:
                # the shared inode must exist (at full size) before anyone
                # pwrites into it
                with open(d / "shared.bin", "wb") as f:
                    f.truncate(args.hosts * args.blobs * args.blob_bytes)
            # drain the previous phase's dirty pages before the barrier:
            # otherwise kernel writeback from phase N-1 competes with phase
            # N's writes and the measurement becomes an order effect
            os.sync()
            fs_barrier(d, f"start-{strategy}-{trial}", topo)
            t0 = time.perf_counter()
            if strategy == "aggregated":
                with AggregatedWriter(
                    d / shard_file_name(topo.host_id),
                    meta={"host": topo.host_id},
                ) as w:
                    for i in range(args.blobs):
                        w.add(f"b{topo.host_id}-{i}", blob)
            elif strategy == "file_per_rank":
                # one file per leaf: B opens + B closes per host — the
                # metadata traffic aggregation exists to remove
                for i in range(args.blobs):
                    with open(d / f"leaf-{topo.host_id}-{i}.bin", "wb") as f:
                        f.write(blob)
            elif strategy == "shared_file":
                # every host pwrites its stripe of ONE shared file
                fd = os.open(str(d / "shared.bin"), os.O_WRONLY)
                try:
                    off = topo.host_id * args.blobs * args.blob_bytes
                    for i in range(args.blobs):
                        os.pwrite(fd, blob, off + i * args.blob_bytes)
                finally:
                    os.close(fd)
            else:  # pragma: no cover - guarded by the parent
                raise ValueError(f"unknown strategy {strategy!r}")
            walls[f"{strategy}/{trial}"] = time.perf_counter() - t0
            if strategy == "aggregated":
                # coordinator validation (untimed w.r.t. the write wall:
                # the done-barrier wait would charge stragglers to host 0)
                fs_barrier(d, f"done-{strategy}-{trial}", topo)
                if topo.host_id == 0:
                    s0 = time.perf_counter()
                    stitched = stitch_shard_directories(
                        d,
                        {str(h): shard_file_name(h) for h in range(args.hosts)},
                    )
                    extra[f"stitch/{trial}"] = time.perf_counter() - s0
                    assert stitched["segments"] == args.hosts * args.blobs

    result = {
        "host": topo.host_id,
        "bytes_per_host": args.blobs * args.blob_bytes,
        "walls": walls,
        "extra": extra,
    }
    out = base / f"result-{topo.host_id}.json"
    tmp = out.with_name(out.name + f".tmp{os.getpid()}")
    tmp.write_text(json.dumps(result))
    os.replace(tmp, out)


# ---------------------------------------------------------------------------
# parent: spawn one subprocess per simulated host, aggregate the walls
# ---------------------------------------------------------------------------


def _spawn_hosts(
    directory: Path, n_hosts: int, blobs: int, blob_bytes: int,
    trials: int, strategies: tuple,
) -> list[dict]:
    env = dict(os.environ)
    env["HPDR_HOST_COUNT"] = str(n_hosts)
    env["PYTHONPATH"] = (
        str(_REPO_ROOT / "src")
        + os.pathsep
        + env.get("PYTHONPATH", "")
    ).rstrip(os.pathsep)
    procs = []
    for h in range(n_hosts):
        env_h = dict(env)
        env_h["HPDR_HOST_ID"] = str(h)
        procs.append(subprocess.Popen(
            [
                sys.executable, "-m", "benchmarks.fig15_17_18_multinode_io",
                "--worker", "--dir", str(directory),
                "--host", str(h), "--hosts", str(n_hosts),
                "--blobs", str(blobs), "--blob-bytes", str(blob_bytes),
                "--trials", str(trials),
                "--strategies", ",".join(strategies),
            ],
            cwd=str(_REPO_ROOT), env=env_h,
            stdout=subprocess.PIPE, stderr=subprocess.STDOUT, text=True,
        ))
    results = []
    for h, p in enumerate(procs):
        out, _ = p.communicate(timeout=600)
        if p.returncode != 0:
            raise RuntimeError(f"host {h} worker failed:\n{out}")
        results.append(json.loads((directory / f"result-{h}.json").read_text()))
    return results


def _measure_restore(
    directory: Path, n_hosts: int, blobs: int
) -> dict:
    """Fig. 18: topology-aware (local-only) vs remeshed (cross-shard) reads."""
    from repro.runtime.io import ShardSetReader, shard_file_name

    shard_files = {str(h): shard_file_name(h) for h in range(n_hosts)}

    def read_all(local_host: int | None) -> dict:
        t0 = time.perf_counter()
        stats_sum = {"local_preads": 0, "cross_preads": 0, "shards_opened": 0}
        hosts = range(n_hosts) if local_host is None else [local_host]
        for h in hosts:
            # a same-topology host restores exactly the leaves it owns
            local = str(h) if local_host is not None else None
            with ShardSetReader(directory, shard_files, local=local) as r:
                for i in range(blobs):
                    r.read(str(h), f"b{h}-{i}")
                stats_sum["local_preads"] += r.stats["local_preads"]
                stats_sum["cross_preads"] += r.stats["cross_preads"]
                stats_sum["shards_opened"] += len(r.stats["shards_opened"])
        stats_sum["wall_s"] = time.perf_counter() - t0
        return stats_sum

    # same topology: every host opens ONE shard, zero cross preads
    local = read_all(local_host=0)
    for h in range(1, n_hosts):
        per = read_all(local_host=h)
        for k in ("local_preads", "cross_preads", "shards_opened"):
            local[k] += per[k]
        local["wall_s"] += per["wall_s"]
    # remeshed: one process reads every shard (no locality)
    remeshed = read_all(local_host=None)
    return {"local": local, "remeshed": remeshed}


def io_bench(
    out_path: str | Path = "BENCH_io.json",
    *,
    host_counts: tuple = (1, 2, 4),
    blobs: int = 4096,
    blob_bytes: int = 8 << 10,
    trials: int = 3,
) -> dict:
    report: dict = {
        "config": {
            "host_counts": list(host_counts),
            "blobs_per_host": blobs,
            "blob_bytes": blob_bytes,
            "trials": trials,
            "strategies": list(STRATEGIES),
        },
        "experiments": [],
        "aggregated_ge_file_per_rank": {},
    }
    with tempfile.TemporaryDirectory(prefix="hpdr-io-bench-") as td:
        for n in host_counts:
            gdir = Path(td) / f"h{n}"
            gdir.mkdir()
            results = _spawn_hosts(
                gdir, n, blobs, blob_bytes, trials, STRATEGIES
            )
            total_bytes = n * blobs * blob_bytes
            bps: dict[str, float] = {}
            for strategy in STRATEGIES:
                # wall per trial = straggler host; score = best trial
                wall = min(
                    max(r["walls"][f"{strategy}/{t}"] for r in results)
                    for t in range(trials)
                )
                bps[strategy] = total_bytes / wall
                exp = {
                    "hosts": n,
                    "strategy": strategy,
                    "wall_s": wall,
                    "total_bytes": total_bytes,
                    "write_bps": bps[strategy],
                    "per_host_walls": {
                        str(r["host"]): min(
                            r["walls"][f"{strategy}/{t}"]
                            for t in range(trials)
                        )
                        for r in results
                    },
                }
                if strategy == "aggregated":
                    stitch = [
                        v for r in results for k, v in r["extra"].items()
                        if k.startswith("stitch/")
                    ]
                    exp["stitch_s"] = min(stitch) if stitch else None
                report["experiments"].append(exp)
                Row(
                    f"fig17.{strategy}.h{n}", wall * 1e6,
                    f"write={bps[strategy] / 1e6:.0f}MB/s "
                    f"bytes={total_bytes >> 20}MiB",
                ).emit()
            report["aggregated_ge_file_per_rank"][str(n)] = bool(
                bps["aggregated"] >= bps["file_per_rank"]
            )
            Row(
                f"fig15.aggregated.h{n}", 0.0,
                f"agg={bps['aggregated'] / 1e6:.0f}MB/s "
                f"fpr={bps['file_per_rank'] / 1e6:.0f}MB/s "
                f"shared={bps['shared_file'] / 1e6:.0f}MB/s",
            ).emit()

            if n == max(host_counts):
                # the last aggregated trial's shards are still on disk
                shard_dir = gdir / f"aggregated-{trials - 1}"
                restore = _measure_restore(shard_dir, n, blobs)
                report["restore"] = {"hosts": n, **restore}
                for kind in ("local", "remeshed"):
                    st = restore[kind]
                    Row(
                        f"fig18.restore.{kind}", st["wall_s"] * 1e6,
                        f"local_preads={st['local_preads']} "
                        f"cross_preads={st['cross_preads']} "
                        f"shards_opened={st['shards_opened']}",
                    ).emit()

    Path(out_path).write_text(json.dumps(report, indent=1))
    return report


def main() -> None:
    io_bench("BENCH_io.json", host_counts=(1, 2), blobs=512,
             blob_bytes=8 << 10, trials=2)


if __name__ == "__main__":
    parser = argparse.ArgumentParser(description=__doc__)
    parser.add_argument("--smoke", action="store_true",
                        help="short run: small blobs, 2 trials")
    parser.add_argument("--out", default="BENCH_io.json",
                        help="JSON artifact path")
    # worker mode (internal): one simulated host
    parser.add_argument("--worker", action="store_true")
    parser.add_argument("--dir")
    parser.add_argument("--host", type=int, default=0)
    parser.add_argument("--hosts", type=int, default=1)
    parser.add_argument("--blobs", type=int, default=64)
    parser.add_argument("--blob-bytes", type=int, default=64 << 10)
    parser.add_argument("--trials", type=int, default=2)
    parser.add_argument("--strategies", default=",".join(STRATEGIES))
    args = parser.parse_args()
    if args.worker:
        _worker(args)
        sys.exit(0)
    print("name,us_per_call,derived")
    if args.smoke:
        io_bench(args.out, host_counts=(1, 2, 4), blobs=512,
                 blob_bytes=8 << 10, trials=3)
    else:
        io_bench(args.out)
