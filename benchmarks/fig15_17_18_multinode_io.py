"""Figs. 15/17/18 — multi-node aggregate throughput & parallel-I/O acceleration.

Weak-scaling model (Fig. 15): aggregate = nodes × gpus × per-GPU end-to-end
throughput × scalability(CMM vs not).  Per-GPU end-to-end throughput comes
from the Fig. 10/13 pipeline simulation; scalability factors from Fig. 16.

I/O acceleration (Figs. 17/18): write = raw/(fs_bw) vs compressed =
raw/ratio/fs_bw + raw/reduction_throughput (reduction overlaps I/O only
partially — worst-case additive, like the paper's measured configuration).
Ratios are measured from OUR pipelines on the NYX stand-in; filesystem
constants are Summit GPFS 2.5 TB/s and Frontier Lustre 9.4 TB/s.
"""

from __future__ import annotations

import jax.numpy as jnp
import numpy as np

from .common import FRONTIER, SUMMIT, V100, Row, nyx_like
from repro.core import api, chunk_model as cm, pipeline as pl
from .fig10_13_pipeline import v100_phi


def per_gpu_e2e(method: str) -> float:
    rep = pl.simulate_pipeline(
        int(4.3e9), "adaptive", v100_phi(method),
        V100["h2d_bps"], V100["d2h_bps"],
        output_fraction=V100["output_fraction"][method],
    )
    return rep.sustained_bps


def main() -> None:
    data = nyx_like(64)
    ratios = {
        "mgard": api.compress(jnp.asarray(data), "mgard", error_bound=1e-2).ratio(),
        "zfp": api.compress(jnp.asarray(data), "zfp", rate=12).ratio(),
        "lz_class": api.compress(jnp.asarray(data), "huffman-bytes").ratio(),
    }

    # Fig. 15: weak-scaling aggregate reduction throughput
    for system, nodes in (("summit", 512), ("frontier", 1024)):
        sysc = SUMMIT if system == "summit" else FRONTIER
        gpus = nodes * sysc["gpus_per_node"]
        for method in ("mgard", "zfp"):
            bps = per_gpu_e2e(method)
            for name, scal in (("hpdr", 0.96), ("baseline", 0.72)):
                agg = gpus * bps * scal
                Row(
                    f"fig15.{system}.{method}.{name}",
                    0.0,
                    f"aggregate={agg/1e12:.1f}TB/s ({gpus} GPUs)",
                ).emit()

    # Figs. 17/18: I/O acceleration
    for system in ("summit", "frontier"):
        sysc = SUMMIT if system == "summit" else FRONTIER
        nodes = 512 if system == "summit" else 1024
        gpus = nodes * sysc["gpus_per_node"]
        raw = 7.5e9 * gpus  # paper: 7.5 GB per GPU weak scaling
        t_write_raw = raw / sysc["fs_bw"]
        for method, red_scal in (("mgard", 0.96), ("zfp", 0.96)):
            ratio = ratios[method]
            red_bps = per_gpu_e2e(method) * gpus * red_scal
            t_comp = raw / red_bps
            t_write = raw / ratio / sysc["fs_bw"] + t_comp
            Row(
                f"fig17.{system}.{method}.write_accel",
                t_write * 1e6,
                f"accel={t_write_raw/t_write:.1f}x ratio={ratio:.1f}x",
            ).emit()
        # LZ-class: low ratio + overhead → no acceleration (paper's NVCOMP-LZ4)
        ratio = ratios["lz_class"]
        red_bps = 10e9 * gpus
        t_write = raw / ratio / sysc["fs_bw"] + raw / red_bps
        Row(
            f"fig17.{system}.lz_class.write_accel",
            t_write * 1e6,
            f"accel={t_write_raw/t_write:.2f}x ratio={ratio:.2f}x",
        ).emit()

    # Fig. 18: strong scaling (32 TB E3SM-like, ratio from our MGARD @1e-4)
    e3sm_ratio = 7.9  # paper-measured; our small-field proxy recorded alongside
    our_proxy = api.compress(jnp.asarray(nyx_like(48)), "mgard",
                             error_bound=1e-4).ratio()
    for nodes in (512, 1024, 2048):
        gpus = nodes * FRONTIER["gpus_per_node"]
        raw = 32e12
        t_raw = raw / FRONTIER["fs_bw"]
        red_bps = per_gpu_e2e("mgard") * gpus * 0.96
        t_hpdr = raw / e3sm_ratio / FRONTIER["fs_bw"] + raw / red_bps
        slow_bps = 5e9 * gpus  # MGARD-GPU-class reduction throughput
        t_slow = raw / e3sm_ratio / FRONTIER["fs_bw"] + raw / slow_bps
        Row(
            f"fig18.frontier.{nodes}nodes",
            0.0,
            f"hpdr_accel={t_raw/t_hpdr:.1f}x slow_reduction_accel={t_raw/t_slow:.2f}x our_proxy_ratio={our_proxy:.1f}x",
        ).emit()


if __name__ == "__main__":
    main()
