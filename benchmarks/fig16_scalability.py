"""Fig. 16 — dense multi-GPU scalability: CMM vs allocator-bound designs.

Model: per-call device work t_k parallelises perfectly across G GPUs
(independent data), but every *allocation* serialises in the shared runtime
(t_a per call, executed G times back-to-back).  CMM drops per-call alloc to
~0 after warmup (contexts persist).  Average real-to-ideal ratio across
G = 1..6 reproduces the paper's 96% (CMM) vs 46–74% (baselines).

Measured side: we time our API with a warm CMM (plan reuse) vs cold
(fresh shapes each call, forcing re-trace/alloc) on CPU.
"""

from __future__ import annotations

import time

import jax.numpy as jnp
import numpy as np

from .common import Row, nyx_like
from repro.core import api


def model_scalability(t_kernel: float, t_alloc: float, gpus: int) -> float:
    ideal = 1.0 / t_kernel * gpus
    real = gpus / (t_kernel + gpus * t_alloc)
    return real / ideal


def main() -> None:
    # paper-scale model: 500MB at 45 GB/s kernel; alloc ~1ms (cached: ~0)
    t_k = 500e6 / 45e9
    for name, t_a in (("cmm", 2e-5), ("alloc_bound", 1.2e-3)):
        ratios = [model_scalability(t_k, t_a, g) for g in range(1, 7)]
        Row(f"fig16.{name}.avg_scalability", 0.0,
            f"avg={np.mean(ratios):.1%} at6={ratios[-1]:.1%}").emit()

    # measured: warm-plan reuse (one cached ReductionPlan, CMM hits) vs
    # forced plan rebuild (fresh shape per call → CMM miss + re-trace)
    data = nyx_like(48).reshape(-1)
    x = jnp.asarray(data[:65536])
    spec = api.make_spec(data[:65536], "zfp", rate=16)
    api.encode(spec, x)  # warm: builds + caches the plan
    t0 = time.perf_counter()
    for _ in range(5):
        api.encode(spec, x)
    warm = (time.perf_counter() - t0) / 5
    t0 = time.perf_counter()
    cold_sizes = [65536 - 8 * i for i in range(1, 4)]
    for n in cold_sizes:
        api.encode(api.make_spec(data[:n], "zfp", rate=16), jnp.asarray(data[:n]))
    cold = (time.perf_counter() - t0) / len(cold_sizes)
    Row("fig16.measured_context_reuse", warm * 1e6,
        f"cold_over_warm={cold/warm:.1f}x (plan-cache hit vs rebuild)").emit()


if __name__ == "__main__":
    main()
