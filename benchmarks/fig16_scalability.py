"""Fig. 16 — dense multi-GPU scalability: CMM vs allocator-bound designs.

Model: per-call device work t_k parallelises perfectly across G GPUs
(independent data), but every *allocation* serialises in the shared runtime
(t_a per call, executed G times back-to-back).  CMM drops per-call alloc to
~0 after warmup (contexts persist).  Average real-to-ideal ratio across
G = 1..6 reproduces the paper's 96% (CMM) vs 46–74% (baselines).

Measured side: we time our API with a warm CMM (plan reuse) vs cold
(fresh shapes each call, forcing re-trace/alloc) on CPU, plus the
execution-engine section: per-backend encode throughput and sharded
pytree fan-out on the local ``data`` mesh, written to ``BENCH_engine.json``
for the perf trajectory (``scripts/check.sh bench``).
"""

from __future__ import annotations

import argparse
import json
import time
from pathlib import Path

import jax.numpy as jnp
import numpy as np

from .common import Row, nyx_like
from repro.core import api
from repro.core.adapters import available_backends
from repro.core.engine import ExecutionEngine


def model_scalability(t_kernel: float, t_alloc: float, gpus: int) -> float:
    ideal = 1.0 / t_kernel * gpus
    real = gpus / (t_kernel + gpus * t_alloc)
    return real / ideal


def engine_bench(out_path: str | Path = "BENCH_engine.json", n: int = 32) -> dict:
    """Per-backend engine throughput on a 1×CPU (or local) ``data`` mesh.

    Encodes a nyx-like field under every runnable backend through
    ``ExecutionEngine`` plan-bound specs (warm CMM), plus the sharded
    ``compress_pytree`` fan-out; emits Rows and writes the JSON artifact.
    """
    data = nyx_like(n)
    report: dict = {"field_elems": int(data.size), "backends": {}}
    with ExecutionEngine() as eng:
        report["devices"] = len(eng.devices)
        for backend in available_backends():
            if backend == "pallas":  # compiled path needs TPU/GPU silicon
                continue
            spec = eng.make_spec(data, "zfp", rate=16, backend=backend)
            eng.encode(spec, data)  # warm: plan build + compile
            reps = 3 if backend == "xla" else 1
            t0 = time.perf_counter()
            for _ in range(reps):
                eng.encode(spec, data)
            dt = (time.perf_counter() - t0) / reps
            bps = data.nbytes / dt
            report["backends"][backend] = {"encode_s": dt, "encode_bps": bps}
            Row(f"fig16.engine.{backend}", dt * 1e6,
                f"encode={bps/1e6:.1f}MB/s").emit()
        tree = {f"w{i}": data.reshape(-1)[: 1 << 16].copy() for i in range(8)}
        eng.compress_pytree(tree, select=lambda k, a: ("zfp", {"rate": 16}))
        t0 = time.perf_counter()
        _, stats = eng.compress_pytree(
            tree, select=lambda k, a: ("zfp", {"rate": 16})
        )
        dt = time.perf_counter() - t0
        report["pytree_fanout"] = {
            "leaves": stats["leaves"], "buckets": stats["buckets"],
            "sharded_leaves": stats["sharded_leaves"],
            "devices": stats["devices"], "wall_s": dt,
            "bps": stats["raw"] / dt,
        }
        Row("fig16.engine.pytree_fanout", dt * 1e6,
            f"leaves={stats['leaves']} devices={stats['devices']} "
            f"bps={stats['raw']/dt/1e6:.1f}MB/s").emit()
    Path(out_path).write_text(json.dumps(report, indent=1))
    return report


def main() -> None:
    # paper-scale model: 500MB at 45 GB/s kernel; alloc ~1ms (cached: ~0)
    t_k = 500e6 / 45e9
    for name, t_a in (("cmm", 2e-5), ("alloc_bound", 1.2e-3)):
        ratios = [model_scalability(t_k, t_a, g) for g in range(1, 7)]
        Row(f"fig16.{name}.avg_scalability", 0.0,
            f"avg={np.mean(ratios):.1%} at6={ratios[-1]:.1%}").emit()

    # measured: warm-plan reuse (one cached ReductionPlan, CMM hits) vs
    # forced plan rebuild (fresh shape per call → CMM miss + re-trace)
    data = nyx_like(48).reshape(-1)
    x = jnp.asarray(data[:65536])
    spec = api.make_spec(data[:65536], "zfp", rate=16)
    api.encode(spec, x)  # warm: builds + caches the plan
    t0 = time.perf_counter()
    for _ in range(5):
        api.encode(spec, x)
    warm = (time.perf_counter() - t0) / 5
    t0 = time.perf_counter()
    cold_sizes = [65536 - 8 * i for i in range(1, 4)]
    for n in cold_sizes:
        api.encode(api.make_spec(data[:n], "zfp", rate=16), jnp.asarray(data[:n]))
    cold = (time.perf_counter() - t0) / len(cold_sizes)
    Row("fig16.measured_context_reuse", warm * 1e6,
        f"cold_over_warm={cold/warm:.1f}x (plan-cache hit vs rebuild)").emit()
    engine_bench()


if __name__ == "__main__":
    parser = argparse.ArgumentParser(description=__doc__)
    parser.add_argument("--smoke", action="store_true",
                        help="engine-only smoke run (1×CPU mesh)")
    parser.add_argument("--out", default="BENCH_engine.json",
                        help="engine JSON artifact path")
    args = parser.parse_args()
    if args.smoke:
        print("name,us_per_call,derived")
        engine_bench(args.out, n=24)
    else:
        main()
