"""Beyond-paper features: progressive retrieval curve + compressed gradients.

Progressive retrieval is the refactoring use-case HPDR's lineage targets
(paper refs [23]–[25]); compressed cross-pod gradient reduction is HPDR's
block quantization applied to training (DESIGN.md §3).
"""

from __future__ import annotations

import jax.numpy as jnp
import numpy as np

from .common import Row, nyx_like
from repro.core import progressive
from repro.optim import grad_compress as gc


def main() -> None:
    # progressive retrieval: bytes vs error per component-prefix
    f = nyx_like(32)
    eb = 1e-3 * float(f.max() - f.min())
    stream = progressive.refactor(jnp.asarray(f), eb, tiers=3)
    curve = progressive.error_curve(stream, f)
    for c in curve:
        Row(
            f"progressive.tier{c['tier']}",
            0.0,
            f"prefix_bytes={c['bytes']} bound={c['bound']:.3e} "
            f"max_err={c['max_err']:.3e}",
        ).emit()
    Row("progressive.full_ratio", 0.0,
        f"ratio={f.nbytes/stream.nbytes():.2f}x bound_met={curve[-1]['max_err']<=eb}").emit()

    # gradient compression: traffic + error-feedback accumulation fidelity
    rng = np.random.default_rng(0)
    g = rng.normal(size=1 << 20).astype(np.float32)
    for bits in (8, 4):
        q, s = gc.quantize_blocks(jnp.asarray(g), bits=bits)
        payload = q.nbytes + s.nbytes if bits == 8 else q.nbytes // 2 + s.nbytes
        out = np.asarray(gc.dequantize_blocks(q, s, g.shape))
        rel = np.abs(out - g).max() / np.abs(g).max()
        Row(
            f"gradcomp.int{bits}",
            0.0,
            f"traffic_vs_bf16={g.nbytes/2/payload:.2f}x rel_err={rel:.2e}",
        ).emit()


if __name__ == "__main__":
    main()
