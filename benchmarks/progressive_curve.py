"""Progressive retrieval curve (PR 9) — emits BENCH_progressive.json.

Refactors a Nyx-like field into precision components, writes the aggregated
component file, and measures the acceptance surface of the progressive tier:

  * **curve**        — per error bound: bytes fetched, preads, achieved
    max-error, and the prefix-read ratio against the full container file;
  * **refine_chain** — a coarse retrieve followed by one refine to the
    finest bound: the chain must pread each component exactly once
    (``prefix_additive``), total exactly the direct-full bytes, and beat
    two independent full retrievals;
  * **bit_identity** — the chained reconstruction equals a fresh direct
    retrieve at the finest bound bit-for-bit.

Usage:  python -m benchmarks.progressive_curve --smoke --out BENCH_progressive.json
        (wired as ``scripts/check.sh bench progressive``)
"""

from __future__ import annotations

import argparse
import json
import os
import tempfile
from pathlib import Path

import numpy as np
import jax.numpy as jnp

from .common import Row, nyx_like
from repro.core import progressive


def measure(n: int, tiers: int, rel_eb: float) -> dict:
    f = nyx_like(n)
    eb = rel_eb * float(f.max() - f.min())
    stream = progressive.refactor(jnp.asarray(f), eb, tiers=tiers)
    bounds = stream.tier_bounds

    with tempfile.TemporaryDirectory() as td:
        path = Path(td) / "prog.hpdr"
        stream.write(path)
        file_bytes = os.path.getsize(path)

        curve = []
        for b in bounds:  # one fresh reader per bound: independent fetch cost
            with progressive.ProgressiveReader(path) as r:
                out = np.asarray(r.retrieve(err=b))
                row = {
                    "error_bound": b,
                    "tiers_loaded": r.tiers_loaded,
                    "bytes_fetched": r.bytes_fetched,
                    "preads": r.preads,
                    "max_err": float(np.abs(out - f).max()),
                    "prefix_read_ratio": r.bytes_fetched / file_bytes,
                }
            curve.append(row)
            Row(
                f"progressive.bound{row['tiers_loaded'] - 1}",
                0.0,
                f"bytes={row['bytes_fetched']} preads={row['preads']} "
                f"bound={b:.3e} max_err={row['max_err']:.3e} "
                f"prefix_ratio={row['prefix_read_ratio']:.3f}",
            ).emit()

        with progressive.ProgressiveReader(path) as r:
            r.retrieve(err=bounds[0])
            coarse_bytes = r.bytes_fetched
            refined = np.asarray(r.refine(err=bounds[-1]))
            chain_total = r.bytes_fetched
            chain_preads = r.preads
        with progressive.ProgressiveReader(path) as direct:
            full = np.asarray(direct.retrieve(err=bounds[-1]))
            direct_bytes = direct.bytes_fetched

    two_full = 2 * direct_bytes
    chain = {
        "coarse_bytes": coarse_bytes,
        "refine_delta_bytes": chain_total - coarse_bytes,
        "chain_total_bytes": chain_total,
        "chain_preads": chain_preads,
        "direct_full_bytes": direct_bytes,
        "two_full_retrievals_bytes": two_full,
        "prefix_additive": chain_total == direct_bytes,
        "savings_vs_two_full": 1.0 - chain_total / two_full,
        "bit_identical_to_direct": bool(np.array_equal(refined, full)),
    }
    Row(
        "progressive.refine_chain",
        0.0,
        f"chain={chain_total} direct={direct_bytes} two_full={two_full} "
        f"additive={chain['prefix_additive']} "
        f"bit_identical={chain['bit_identical_to_direct']}",
    ).emit()

    return {
        "field": {"n": n, "raw_mb": f.nbytes / 1e6},
        "tiers": tiers,
        "error_bound": eb,
        "file_bytes": file_bytes,
        "curve": curve,
        "refine_chain": chain,
    }


def main(argv=None) -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--smoke", action="store_true",
                    help="small field (CI)")
    ap.add_argument("--out", type=Path, default=None,
                    help="write BENCH_progressive.json here")
    args = ap.parse_args(argv)

    n = 32 if args.smoke else 64
    tiers = 3 if args.smoke else 4
    report = measure(n, tiers, rel_eb=1e-4)
    report["summary"] = {
        "bounds_measured": len(report["curve"]),
        "all_bounds_met": all(
            c["max_err"] <= c["error_bound"] for c in report["curve"]
        ),
        "bytes_monotone": all(
            b["bytes_fetched"] > a["bytes_fetched"]
            for a, b in zip(report["curve"], report["curve"][1:])
        ),
        "prefix_additive": report["refine_chain"]["prefix_additive"],
        "bit_identical": report["refine_chain"]["bit_identical_to_direct"],
        "coarse_prefix_ratio": report["curve"][0]["prefix_read_ratio"],
    }
    if args.out:
        args.out.write_text(json.dumps(report, indent=1))
        print(f"wrote {args.out}")


if __name__ == "__main__":
    main()
