"""Roofline report: aggregate results/dryrun/*.json into the §Roofline table."""

from __future__ import annotations

import glob
import json
from pathlib import Path

from .common import Row

RESULTS = Path(__file__).resolve().parents[1] / "results" / "dryrun"


def load(variant: str = "baseline") -> list[dict]:
    recs = []
    for f in sorted(glob.glob(str(RESULTS / f"*__{variant}.json"))):
        recs.append(json.loads(Path(f).read_text()))
    return recs


def main() -> None:
    recs = load()
    ok = [r for r in recs if r.get("status") == "ok"]
    Row("roofline.cells_ok", 0.0, f"{len(ok)}/{len(recs)}").emit()
    for r in ok:
        if r["multi_pod"]:
            continue  # roofline table is single-pod (brief)
        rf = r["roofline"]
        bound = max(rf["t_compute_s"], rf["t_memory_s"], rf["t_collective_s"])
        frac = rf["t_compute_s"] / bound if bound else 0.0
        Row(
            f"roofline.{r['arch']}.{r['shape']}",
            bound * 1e6,
            f"dom={rf['dominant']} tc={rf['t_compute_s']:.3e} tm={rf['t_memory_s']:.3e} "
            f"tl={rf['t_collective_s']:.3e} compute_frac={frac:.2f}",
        ).emit()


if __name__ == "__main__":
    main()
