"""Benchmark driver — one module per paper table/figure.

Prints ``name,us_per_call,derived`` CSV rows (brief requirement).
"""

from __future__ import annotations

import sys
import time
import traceback


def main() -> None:
    from . import (
        fig01_breakdown,
        fig10_13_pipeline,
        fig11_chunk_model,
        fig12_kernel_throughput,
        fig14_ratio,
        fig15_17_18_multinode_io,
        fig16_scalability,
        fig_progressive_gradcomp,
        roofline_report,
    )

    modules = [
        ("fig01_breakdown", fig01_breakdown),
        ("fig10_13_pipeline", fig10_13_pipeline),
        ("fig11_chunk_model", fig11_chunk_model),
        ("fig12_kernel_throughput", fig12_kernel_throughput),
        ("fig14_ratio", fig14_ratio),
        ("fig16_scalability", fig16_scalability),
        ("fig15_17_18_multinode_io", fig15_17_18_multinode_io),
        ("fig_progressive_gradcomp", fig_progressive_gradcomp),
        ("roofline_report", roofline_report),
    ]
    print("name,us_per_call,derived")
    failures = 0
    for name, mod in modules:
        t0 = time.time()
        try:
            mod.main()
            print(f"bench.{name}.wall,{(time.time()-t0)*1e6:.0f},ok")
        except Exception:  # noqa: BLE001
            failures += 1
            print(f"bench.{name}.wall,{(time.time()-t0)*1e6:.0f},FAILED")
            traceback.print_exc()
    if failures:
        sys.exit(1)


if __name__ == "__main__":
    main()
