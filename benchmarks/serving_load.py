"""Serving-layer concurrency benchmark: latency, goodput, batch fill.

Closed-loop load generation against :class:`ReductionService`: N client
threads each issue same-spec compress requests back-to-back for a fixed
wall-clock window.  Swept over ≥3 offered loads (thread counts) and over
the dispatcher ``batch_window``, reporting per-load:

  * client-side latency p50 / p99 (seconds, measured around the blocking
    ``compress`` call — admission wait + coalesce window + execution);
  * goodput (raw bytes successfully reduced per second of wall clock);
  * batch fill ratio (stacked leaves per stacked bucket) and requests per
    bucket from the service's own metrics — the coalescing win: under
    concurrent same-spec load the dispatcher merges requests from
    different clients into ONE ``shard_map`` bucket, so fill > 1.

The direct-API single-thread path is timed as the no-service baseline.
Artifact: ``BENCH_serving.json`` (``scripts/check.sh bench serving``).
"""

from __future__ import annotations

import argparse
import json
import threading
import time
from pathlib import Path

import numpy as np

from .common import Row, nyx_like
from repro.core import api
from repro.core.engine import ExecutionEngine
from repro.serving import ReductionService


def _percentile(xs: list[float], q: float) -> float:
    return float(np.percentile(np.asarray(xs), q)) if xs else 0.0


def _make_tree(n: int, seed: int) -> dict:
    field = nyx_like(n, seed=seed)
    return {"rho": field, "vx": np.roll(field, 3, axis=0)}


def _select(key, arr):
    del key, arr
    return "zfp", {"rate": 16}


def run_load(
    svc: ReductionService,
    n_threads: int,
    duration_s: float,
    trees: list[dict],
) -> dict:
    """Closed loop: each thread hammers ``svc.compress`` for ``duration_s``."""
    latencies: list[list[float]] = [[] for _ in range(n_threads)]
    raw_done = [0] * n_threads
    errors = [0] * n_threads
    start = threading.Barrier(n_threads + 1)

    def client(i: int) -> None:
        tree = trees[i % len(trees)]
        start.wait()
        stop = time.monotonic() + duration_s
        while time.monotonic() < stop:
            t0 = time.perf_counter()
            try:
                _flat, stats = svc.compress(tree, _select)
            except Exception:
                errors[i] += 1
                continue
            latencies[i].append(time.perf_counter() - t0)
            raw_done[i] += stats["raw"]

    threads = [threading.Thread(target=client, args=(i,)) for i in range(n_threads)]
    for t in threads:
        t.start()
    start.wait()
    t_wall = time.perf_counter()
    for t in threads:
        t.join()
    wall = time.perf_counter() - t_wall
    lats = [x for per in latencies for x in per]
    return {
        "threads": n_threads,
        "requests": len(lats),
        "errors": sum(errors),
        "wall_s": wall,
        "p50_s": _percentile(lats, 50),
        "p99_s": _percentile(lats, 99),
        "goodput_bps": sum(raw_done) / wall if wall > 0 else 0.0,
        "rps": len(lats) / wall if wall > 0 else 0.0,
    }


def serving_bench(
    out_path: str | Path = "BENCH_serving.json",
    *,
    n: int = 32,
    duration_s: float = 2.0,
    loads: tuple[int, ...] = (1, 2, 4, 8),
    windows: tuple[float, ...] = (0.0, 0.002, 0.01),
) -> dict:
    trees = [_make_tree(n, seed=s) for s in range(4)]
    raw_bytes = sum(a.nbytes for a in trees[0].values())
    report: dict = {
        "field_elems": int(trees[0]["rho"].size),
        "raw_bytes_per_request": int(raw_bytes),
        "duration_s": duration_s,
        "loads": [],
        "batch_window_sweep": [],
    }

    with ExecutionEngine(backend="xla") as eng:
        # no-service baseline: the direct API, one thread, same tree/spec
        api.compress_pytree(trees[0], _select, engine=eng)  # warm plan
        t0 = time.perf_counter()
        reps = 5
        for _ in range(reps):
            api.compress_pytree(trees[0], _select, engine=eng)
        direct = (time.perf_counter() - t0) / reps
        report["direct_api"] = {
            "latency_s": direct,
            "goodput_bps": raw_bytes / direct,
        }
        Row("serving.direct_api", direct * 1e6,
            f"goodput={raw_bytes / direct / 1e6:.1f}MB/s").emit()

        # offered-load sweep at the default window
        for n_threads in loads:
            with ReductionService(eng, batch_window=0.002,
                                  max_queue=4 * n_threads) as svc:
                svc.compress(trees[0], _select)  # warm
                res = run_load(svc, n_threads, duration_s, trees)
                snap = svc.stats()
            res["batch_fill_ratio"] = snap.batch_fill_ratio
            res["requests_per_bucket"] = snap.requests_per_bucket
            res["coalesced_requests"] = snap.coalesced_requests
            res["stacked_buckets"] = snap.stacked_buckets
            res["wait_s_mean"] = snap.wait_s_mean
            report["loads"].append(res)
            Row(f"serving.load.t{n_threads}", res["p50_s"] * 1e6,
                f"p99={res['p99_s'] * 1e3:.1f}ms "
                f"goodput={res['goodput_bps'] / 1e6:.1f}MB/s "
                f"fill={res['batch_fill_ratio']:.1f}").emit()

        # batch-window sweep at a fixed concurrent load: latency the
        # dispatcher *spends* lingering vs the fill it buys
        sweep_threads = max(loads)
        for window in windows:
            with ReductionService(eng, batch_window=window,
                                  max_queue=4 * sweep_threads) as svc:
                svc.compress(trees[0], _select)
                res = run_load(svc, sweep_threads, duration_s, trees)
                snap = svc.stats()
            report["batch_window_sweep"].append({
                "batch_window_s": window,
                "p50_s": res["p50_s"],
                "p99_s": res["p99_s"],
                "goodput_bps": res["goodput_bps"],
                "batch_fill_ratio": snap.batch_fill_ratio,
                "requests_per_bucket": snap.requests_per_bucket,
            })
            Row(f"serving.window.{window * 1e3:g}ms", res["p50_s"] * 1e6,
                f"fill={snap.batch_fill_ratio:.1f} "
                f"req_per_bucket={snap.requests_per_bucket:.1f}").emit()

    # the coalescing claim, checked where concurrency was offered: under
    # concurrent same-spec load buckets hold more than one request's work
    concurrent = [r for r in report["loads"] if r["threads"] > 1]
    report["coalescing_engaged"] = bool(concurrent) and any(
        r["batch_fill_ratio"] > 1.0 for r in concurrent
    )
    Path(out_path).write_text(json.dumps(report, indent=1))
    return report


def socket_bench(
    out_path: str | Path = "BENCH_serving.json",
    *,
    n: int = 24,
    duration_s: float = 2.0,
    bulk_clients: int = 4,
    batch_window: float = 0.03,
) -> dict:
    """Wire-protocol mode: real socket clients, per-priority p50/p99.

    Phase 1 measures the interactive lane (socket ``fetch_kv``) against an
    idle service; phase 2 repeats it while ``bulk_clients`` socket clients
    saturate the bulk lane with back-to-back compress requests.  The
    priority queue's whole point is the delta between the two runs:
    ``interactive_p99_bounded`` records whether loaded p99 stayed within
    2x unloaded p99 (the PR-10 acceptance bound).  Per-priority service
    histograms and per-connection byte totals land in the artifact.
    """
    from repro.serving.client import ReductionClient
    from repro.serving.server import ReductionServer

    tree = _make_tree(n, seed=0)

    def interactive_loop(address: str, duration: float) -> list[float]:
        lats: list[float] = []
        with ReductionClient(address, tenant="interactive") as cli:
            cli.fetch_kv("bench")  # warm connection + session
            stop = time.monotonic() + duration
            while time.monotonic() < stop:
                t0 = time.perf_counter()
                cli.fetch_kv("bench")
                lats.append(time.perf_counter() - t0)
        return lats

    with ExecutionEngine(backend="xla") as eng:
        # batch_window dominates BOTH phases' latency floor (closed-loop
        # interactive requests always eat one linger), so the loaded/
        # unloaded ratio isolates what the priority queue actually adds:
        # time stuck behind bulk dispatch cycles.  Small cycles
        # (max_batch_requests) keep that tail under the 2x bound.
        svc = ReductionService(
            eng, batch_window=batch_window, max_queue=8 * bulk_clients,
            max_batch_requests=2,
        )
        with svc, ReductionServer(svc) as srv:
            # KV sessions are tenant-scoped: park under the tenant the
            # interactive clients will fetch as
            svc.park_kv("bench", {"k": tree["rho"]}, tenant="interactive")
            with ReductionClient(srv.unix_address, tenant="warm") as cli:
                cli.compress(tree, method="zfp", rate=16)  # warm the plan

            unloaded = interactive_loop(srv.unix_address, duration_s)

            stop_evt = threading.Event()
            bulk_requests = [0] * bulk_clients

            def bulk_worker(i: int) -> None:
                with ReductionClient(srv.unix_address,
                                     tenant=f"bulk{i}") as cli:
                    while not stop_evt.is_set():
                        try:
                            cli.compress(tree, method="zfp", rate=16)
                            bulk_requests[i] += 1
                        except Exception:
                            pass

            threads = [threading.Thread(target=bulk_worker, args=(i,))
                       for i in range(bulk_clients)]
            for t in threads:
                t.start()
            time.sleep(0.2)  # let the bulk lane actually saturate
            loaded = interactive_loop(srv.unix_address, duration_s)
            stop_evt.set()
            for t in threads:
                t.join()
            snap = svc.stats()

    result = {
        "bulk_clients": bulk_clients,
        "batch_window_s": batch_window,
        "bulk_requests": int(sum(bulk_requests)),
        "unloaded": {
            "requests": len(unloaded),
            "p50_s": _percentile(unloaded, 50),
            "p99_s": _percentile(unloaded, 99),
        },
        "loaded": {
            "requests": len(loaded),
            "p50_s": _percentile(loaded, 50),
            "p99_s": _percentile(loaded, 99),
        },
        "service_priorities": snap.priorities,
        "connections": {
            k: snap.connections[k]
            for k in ("opened", "closed", "rx_bytes", "tx_bytes",
                      "frames_rx", "frames_tx", "protocol_errors")
        },
    }
    result["interactive_p99_bounded"] = bool(
        result["loaded"]["p99_s"] <= 2.0 * result["unloaded"]["p99_s"]
    )
    Row("serving.socket.interactive_unloaded",
        result["unloaded"]["p50_s"] * 1e6,
        f"p99={result['unloaded']['p99_s'] * 1e3:.1f}ms").emit()
    Row("serving.socket.interactive_loaded",
        result["loaded"]["p50_s"] * 1e6,
        f"p99={result['loaded']['p99_s'] * 1e3:.1f}ms "
        f"bounded={result['interactive_p99_bounded']} "
        f"bulk_reqs={result['bulk_requests']}").emit()
    for prio in ("interactive", "bulk"):
        h = snap.priorities[prio]
        Row(f"serving.socket.prio.{prio}", h["wait_p50"] * 1e6,
            f"p99={h['wait_p99'] * 1e3:.2f}ms dispatched={h['dispatched']} "
            f"forced={h['forced']}").emit()

    out_path = Path(out_path)
    report = json.loads(out_path.read_text()) if out_path.exists() else {}
    report["socket"] = result
    out_path.write_text(json.dumps(report, indent=1))
    return result


if __name__ == "__main__":
    parser = argparse.ArgumentParser(description=__doc__)
    parser.add_argument("--smoke", action="store_true",
                        help="short run: small field, 3 loads, ~10s total")
    parser.add_argument("--out", default="BENCH_serving.json",
                        help="JSON artifact path")
    args = parser.parse_args()
    print("name,us_per_call,derived")
    if args.smoke:
        serving_bench(args.out, n=24, duration_s=1.0, loads=(1, 2, 4),
                      windows=(0.0, 0.005))
        socket_bench(args.out, n=24, duration_s=1.5, bulk_clients=3)
    else:
        serving_bench(args.out)
        socket_bench(args.out)
