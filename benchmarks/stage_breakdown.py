"""Per-stage pipeline breakdown + host↔device transfer accounting.

The paper's headline architectural number is that running the whole
reduction pipeline on the device cuts memory-transfer overhead to ~2.3% of
runtime.  This benchmark makes that trackable per PR, in *both* directions:
for each stage-graph codec it drives ``api.encode_profiled`` and
``api.decode_profiled`` (warm plans, so timings are execution, not
compilation) and emits

  * wall seconds per pipeline stage, encode and decode (fused device
    segments blocked on, host barriers/prepares timed as-is);
  * exact H2D/D2H bytes per call — every transfer in the stage pipeline is
    declared, so this is an accounting, not an estimate.  The decode rows
    carry the symmetry check: decode H2D must equal the compressed
    sections plus metadata-scale decode operands (``decode_h2d_bytes`` vs
    ``stream_bytes`` + ``decode_operand_bytes``) — never a raw-array-sized
    staging transfer;
  * the transfer:input ratio and the stream size.

``scripts/check.sh bench stages`` runs the smoke size and writes
``BENCH_stages.json``.
"""

from __future__ import annotations

import argparse
import json
import time
from pathlib import Path

import jax.numpy as jnp
import numpy as np

from .common import Row, nyx_like
from repro.core import api
from repro.core.codecs import get_codec


CODEC_CASES = (
    ("mgard", {"error_bound": 1e-2}),
    ("zfp", {"rate": 16}),
    ("huffman", {}),
    ("huffman-bytes", {}),
)


def _data_for(method: str, n: int) -> np.ndarray:
    field = nyx_like(n)
    if method == "huffman":
        q = np.clip((field / field.max()) * 255.0, 0, 255)
        return q.astype(np.int32)
    return field


def stage_bench(out_path: str | Path = "BENCH_stages.json", n: int = 24) -> dict:
    report: dict = {"field_elems": int(n) ** 3, "codecs": {}}
    for method, kw in CODEC_CASES:
        data = _data_for(method, n)
        spec = api.make_spec(data, method, **kw)
        api.encode_profiled(spec, jnp.asarray(data))  # warm: plan + traces
        t0 = time.perf_counter()
        c, stage_s, transfers = api.encode_profiled(spec, jnp.asarray(data))
        wall = time.perf_counter() - t0
        entry = {
            "input_bytes": int(data.nbytes),
            "stream_bytes": int(c.nbytes()),
            "encode_s": wall,
            "stages_s": {k: round(v, 6) for k, v in stage_s.items()},
            **transfers.as_dict(),
        }
        entry["transfer_frac_of_input"] = round(
            (transfers.h2d + transfers.d2h) / max(data.nbytes, 1), 4
        )
        report["codecs"][method] = entry
        Row(
            f"stages.{method}.encode", wall * 1e6,
            f"d2h={transfers.d2h}B h2d={transfers.h2d}B "
            f"ratio={data.nbytes / max(c.nbytes(), 1):.1f}x",
        ).emit()
        for stage_name, secs in stage_s.items():
            Row(f"stages.{method}.{stage_name}", secs * 1e6, "").emit()

        # decode direction: warm the inverse pipeline, then measure — the
        # symmetry claim is that H2D is the compressed sections plus
        # metadata-scale decode operands (codebook tables, bin schedules),
        # never a raw-array-sized staging transfer
        api.decode_profiled(c)
        t0 = time.perf_counter()
        out, dec_stage_s, dec_tr = api.decode_profiled(c)
        import jax

        jax.block_until_ready(out)
        dec_wall = time.perf_counter() - t0
        codec = get_codec(method)
        plan = api.get_plan(codec.decode_spec(c))
        prepared = codec.decode_state(plan, c)
        state_bytes = (
            sum(int(a.nbytes) for a in prepared[0].values())
            if prepared is not None else 0
        )
        entry.update(
            decode_s=dec_wall,
            decode_stages_s={k: round(v, 6) for k, v in dec_stage_s.items()},
            decode_h2d_bytes=int(dec_tr.h2d),
            decode_d2h_bytes=int(dec_tr.d2h),
            decode_state_bytes=int(state_bytes),
            decode_operand_bytes=int(dec_tr.h2d - state_bytes),
            # the flag asserts the pipeline path actually ran AND counted
            # its staging: h2d at least the compressed sections (a silent
            # host-fallback regression measures 0 and must read false);
            # sections may pad up to one outlier bucket and operands are
            # metadata-scale — 64 KiB bounds both for every case here
            decode_h2d_is_stream_plus_meta=bool(
                prepared is not None
                and state_bytes > 0
                and state_bytes <= dec_tr.h2d <= c.nbytes() + 65536
                and dec_tr.h2d < max(data.nbytes, 1)
            ),
        )
        Row(
            f"stages.{method}.decode", dec_wall * 1e6,
            f"h2d={dec_tr.h2d}B stream={c.nbytes()}B",
        ).emit()
        for stage_name, secs in dec_stage_s.items():
            Row(f"stages.{method}.dec.{stage_name}", secs * 1e6, "").emit()
    Path(out_path).write_text(json.dumps(report, indent=1))
    return report


def main() -> None:
    parser = argparse.ArgumentParser(description=__doc__)
    parser.add_argument("--smoke", action="store_true",
                        help="smoke-sized run (24^3 field)")
    parser.add_argument("--out", default="BENCH_stages.json")
    parser.add_argument("--n", type=int, default=None,
                        help="field edge length (default 24 smoke / 48 full)")
    args = parser.parse_args()
    n = args.n if args.n is not None else (24 if args.smoke else 48)
    print("name,us_per_call,derived")
    stage_bench(args.out, n=n)


if __name__ == "__main__":
    main()
