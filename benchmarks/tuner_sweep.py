"""Auto-tuner validation sweep (PR 7) — emits BENCH_tuner.json.

For each codec the sweep measures every fixed (chunk-count, window)
configuration on the grid, then runs the auto-tuned stream
(``chunk_size="auto", window="auto"``) and scores it:

  * ``auto_vs_best_fixed``  — auto wall / best fixed wall (target ≤1.10:
    the tuner must land within 10% of the best fixed config);
  * ``auto_vs_worst_fixed`` — how much a bad fixed choice would cost;
  * ``auto_vs_serial``      — auto wall / measured window=1 wall at the
    tuner's OWN chunk size (target ≤1.05: the overlap decision never
    loses to the serial schedule);
  * ``prediction_error``    — |predicted makespan − measured wall| /
    measured wall (target <0.10), where the prediction is taken AFTER
    the tuner's online residual has converged (the warm-up run feeds its
    measured wall back via ``tuner.observe``).

The first auto run calibrates the machine if no persisted store exists
(one-time; subsequent runs load the JSON with zero sweeps).

Usage:  python -m benchmarks.tuner_sweep --smoke --out BENCH_tuner.json
        (wired as ``scripts/check.sh bench tuner``)
"""

from __future__ import annotations

import argparse
import json
from pathlib import Path

import numpy as np

from .common import Row, nyx_like
from repro.core import api

SMOKE_GRID = {"n_chunks": (2, 4, 8, 16), "windows": (1, 2)}
FULL_GRID = {"n_chunks": (2, 4, 8, 12, 16, 24), "windows": (1, 2, 3)}


def _fixed_wall(method: str, data: np.ndarray, window: int,
                c_fixed_elems: int, repeat: int, **params) -> float:
    def run():
        stream = api.CompressorStream(
            method, mode="fixed", c_fixed_elems=c_fixed_elems,
            window=window, backend="xla", frame=True, **params)
        return stream.compress(data)

    run()  # warm plans
    return min(run().wall_time for _ in range(repeat))


def _auto_result(method: str, data: np.ndarray, repeat: int, **params):
    from repro.core import tuner

    def run():
        stream = api.CompressorStream(
            method, chunk_size="auto", window="auto", backend="xla",
            frame=True, **params)
        return stream.compress(data)

    run()  # warm plans + calibrate on first-ever use
    # enough runs for the tuner's candidate race to explore and settle,
    # plus ``repeat`` exploitation runs of the measured winner
    n_runs = repeat + tuner._EXPLORE_K * tuner._EXPLORE_RUNS
    return min((run() for _ in range(n_runs)), key=lambda r: r.wall_time)


def sweep_codec(method: str, params: dict, data: np.ndarray,
                grid: dict, repeat: int) -> dict:
    fixed = {}
    serial_walls = []
    for k in grid["n_chunks"]:
        c = max(1, data.size // k)
        for w in grid["windows"]:
            wall = _fixed_wall(method, data, w, c, repeat, **params)
            fixed[f"chunks={k},window={w}"] = wall
            if w == 1:
                serial_walls.append(wall)
    best_key = min(fixed, key=fixed.get)
    worst_key = max(fixed, key=fixed.get)

    from repro.core import tuner

    res = _auto_result(method, data, repeat, **params)

    # the race is settled by now (enough runs above) — one more auto run
    # reports the pinned winner's config
    auto_stream = api.CompressorStream(
        method, chunk_size="auto", window="auto", backend="xla",
        frame=True, **params)
    settled = auto_stream.compress(data)
    tuned = settled.tuned or {}
    chunk_elems = int(tuned.get("chunk_elems") or max(1, data.size // 8))

    # The grid above only *finds* the best/worst fixed configs; the
    # scored ratios are measured here with auto / best-fixed / serial
    # runs interleaved — walls drift with machine load across a sweep,
    # and interleaving keeps that drift symmetric:
    #   * serial baseline at the tuner's OWN chunking scores the overlap
    #     decision, independent of the chunk-size decision;
    #   * the grid-best config scores the whole (chunk, window) choice.
    k_best, w_best = (int(s.split("=")[1])
                      for s in best_key.split(","))
    best_stream = api.CompressorStream(
        method, mode="fixed", c_fixed_elems=max(1, data.size // k_best),
        window=w_best, backend="xla", frame=True, **params)
    serial_stream = api.CompressorStream(
        method, mode="fixed", c_fixed_elems=chunk_elems, window=1,
        backend="xla", frame=True, **params)
    auto_walls, best_pair_walls, serial_pair_walls = [], [], []
    for _ in range(repeat + 6):
        auto_walls.append(auto_stream.compress(data).wall_time)
        best_pair_walls.append(best_stream.compress(data).wall_time)
        serial_pair_walls.append(serial_stream.compress(data).wall_time)
    auto_wall = min(auto_walls)
    best_fixed_wall = min(best_pair_walls)
    serial_same_chunk = min(serial_pair_walls)

    # post-convergence prediction: every auto run fed its measured wall
    # back via tuner.observe, so re-planning now yields the settled
    # (empirical) estimate for this spec
    final = tuner.plan_stream(
        data.size, data.dtype.itemsize, method=method,
        dtype=str(data.dtype), backend="xla", params=params)
    pred = final.predicted_s if final.source == "calibrated" else None
    best_auto = min(res.wall_time, settled.wall_time, auto_wall)
    err = abs(pred - best_auto) / best_auto if pred else None

    report = {
        "raw_mb": data.nbytes / 1e6,
        "fixed_walls_s": fixed,
        "best_fixed": {"config": best_key, "wall_s": best_fixed_wall,
                       "grid_wall_s": fixed[best_key]},
        "worst_fixed": {"config": worst_key, "wall_s": fixed[worst_key]},
        "serial_grid_best_s": min(serial_walls),
        "serial_same_chunk_s": serial_same_chunk,
        "auto": {
            "chunk_elems": tuned.get("chunk_elems"),
            "window": settled.window,
            "chunks": len(settled.chunks),
            "source": tuned.get("source", "unknown"),
            "wall_s": auto_wall,
            "predicted_s": pred,
        },
        "auto_vs_best_fixed": auto_wall / best_fixed_wall,
        "auto_vs_worst_fixed": auto_wall / fixed[worst_key],
        "auto_vs_serial": auto_wall / serial_same_chunk,
        "prediction_error": err,
    }
    pe = f"{err:.1%}" if err is not None else "n/a"
    Row(
        f"tuner.{method}",
        auto_wall * 1e6,
        f"auto_vs_best={report['auto_vs_best_fixed']:.2f}x "
        f"auto_vs_serial={report['auto_vs_serial']:.2f}x pred_err={pe} "
        f"window={settled.window} chunks={len(settled.chunks)}",
    ).emit()
    return report


def main(argv=None) -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--smoke", action="store_true",
                    help="small grid + CPU-sized data (CI)")
    ap.add_argument("--out", type=Path, default=None,
                    help="write BENCH_tuner.json here")
    args = ap.parse_args(argv)

    grid = SMOKE_GRID if args.smoke else FULL_GRID
    repeat = 3
    n = 48 if args.smoke else 96
    smooth = nyx_like(n)
    noise = np.random.default_rng(0).normal(size=smooth.shape).astype(np.float32)

    report = {"grid": {k: list(v) for k, v in grid.items()}, "codecs": {}}
    for method, params, data in (
        ("zfp", {"rate": 16}, smooth),
        ("mgard", {"error_bound": 1e-2}, smooth),
        ("huffman-bytes", {}, noise),
    ):
        report["codecs"][method] = sweep_codec(method, params, data, grid, repeat)

    errs = [r["prediction_error"] for r in report["codecs"].values()
            if r["prediction_error"] is not None]
    report["summary"] = {
        "auto_within_10pct_of_best": all(
            r["auto_vs_best_fixed"] <= 1.10 for r in report["codecs"].values()
        ),
        "auto_never_worse_than_serial": all(
            r["auto_vs_serial"] <= 1.05 for r in report["codecs"].values()
        ),
        "max_auto_vs_best_fixed": max(
            r["auto_vs_best_fixed"] for r in report["codecs"].values()
        ),
        "max_prediction_error": max(errs) if errs else None,
        "prediction_error_under_10pct": bool(errs) and max(errs) < 0.10,
    }
    if args.out:
        args.out.write_text(json.dumps(report, indent=1))
        print(f"wrote {args.out}")


if __name__ == "__main__":
    main()
