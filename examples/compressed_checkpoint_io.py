"""The paper's scenario as a framework feature: reduction-accelerated I/O.

Writes a model checkpoint through all three HPDR pipelines, measures ratio
and throughput, and projects the multi-node I/O acceleration with the
Frontier/Summit filesystem model (paper Figs. 15/17/18).

    PYTHONPATH=src python examples/compressed_checkpoint_io.py
"""

import tempfile
import time

import jax
import numpy as np

from repro.checkpoint import CheckpointManager, CheckpointPolicy
from repro.configs import get_config
from repro.models import build_model


def main() -> None:
    cfg = get_config("qwen1.5-4b").smoke()
    model = build_model(cfg)
    params = model.init(jax.random.PRNGKey(0))
    nbytes = sum(x.nbytes for x in jax.tree.leaves(params))
    print(f"model: {nbytes/1e6:.1f} MB of parameters\n")

    for name, policy in (
        ("lossless (huffman-bytes)", CheckpointPolicy(exact=True)),
        ("zfp rate-28 (~1e-6 rel)", CheckpointPolicy(float_method="zfp", zfp_rate=28, lossless_small=1)),
        ("zfp rate-16 (transport)", CheckpointPolicy(float_method="zfp", zfp_rate=16, lossless_small=1)),
        ("mgard eb 1e-4", CheckpointPolicy(float_method="mgard", mgard_eb=1e-4, lossless_small=1)),
    ):
        with tempfile.TemporaryDirectory() as d:
            mgr = CheckpointManager(d, policy)
            t0 = time.perf_counter()
            rep = mgr.save(0, {"params": params})
            dt = time.perf_counter() - t0
            restored, _ = mgr.restore(0, target={"params": params})
            err = max(
                float(np.abs(np.asarray(a) - np.asarray(b)).max())
                for a, b in zip(jax.tree.leaves(restored), jax.tree.leaves(params))
            )
            print(f"{name:28s} ratio={rep['ratio']:5.2f}x  "
                  f"{nbytes/dt/1e6:6.1f} MB/s (CPU)  max_abs_err={err:.2e}")

    # multi-node projection (paper's weak-scaling I/O model)
    print("\nI/O projection @ Frontier (1024 nodes × 4 GPUs, Lustre 9.4 TB/s):")
    for ratio, red_bps in (("4.0x (mgard 1e-2)", 4.0), ("2.6x (zfp r12)", 2.6)):
        r = float(ratio.split("x")[0])
        raw = 7.5e9 * 4096
        t_raw = raw / 9.4e12
        t_comp = raw / r / 9.4e12 + raw / (4096 * 11.8e9 * 0.96)
        print(f"  ratio {ratio:18s} write accel = {t_raw/t_comp:.1f}x")


if __name__ == "__main__":
    main()
