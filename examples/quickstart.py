"""Quickstart: compress/decompress a scientific field with all three pipelines.

Demonstrates the plan-based API: a ``ReductionSpec`` is built per setting,
its ``ReductionPlan`` (jitted executables + workspace) is CMM-cached, and
re-encoding with the same spec is a pure cache hit.

    PYTHONPATH=src python examples/quickstart.py
"""

import numpy as np
import jax.numpy as jnp

from repro.core import api
from repro.core.context import GLOBAL_CMM


def main() -> None:
    # synthetic smooth 3-D field (NYX-density stand-in)
    n = 64
    g = np.linspace(0, 8 * np.pi, n)
    x, y, z = np.meshgrid(g, g, g, indexing="ij")
    rng = np.random.default_rng(0)
    data = np.exp(
        np.sin(x) * np.cos(y) * np.sin(z) + 0.05 * rng.normal(size=x.shape)
    ).astype(np.float32)
    print(f"input: {data.shape} float32, {data.nbytes/1e6:.1f} MB\n")

    for method, kw, note in (
        ("mgard", {"error_bound": 1e-2}, "error-bounded lossy (rel 1e-2)"),
        ("mgard", {"error_bound": 1e-4, "dict_size": 65536}, "error-bounded lossy (rel 1e-4)"),
        ("zfp", {"rate": 8}, "fixed-rate 8 bits/value"),
        ("zfp", {"rate": 16}, "fixed-rate 16 bits/value"),
        ("huffman-bytes", {}, "lossless byte-entropy (LZ-class)"),
    ):
        spec = api.make_spec(data, method, **kw)     # hashable CMM key
        comp = api.encode(spec, jnp.asarray(data))   # plan built once, cached
        blob = comp.to_bytes()  # portable v2 stream (what the checkpointer writes)
        out = np.asarray(api.decompress(api.Compressed.from_bytes(blob)))
        err = np.abs(out - data).max()
        rel = err / (data.max() - data.min())
        print(f"{method:14s} {note:32s} ratio={comp.ratio():6.2f}x  "
              f"stream={len(blob)/1e6:6.2f}MB  max_rel_err={rel:.2e}")

    # second encode with an identical spec: a pure plan-cache hit
    hits_before = GLOBAL_CMM.hit_count
    spec = api.make_spec(data, "zfp", rate=16)
    api.encode(spec, jnp.asarray(data))
    print(f"\nre-encode with cached plan: +{GLOBAL_CMM.hit_count - hits_before} CMM hit(s)")
    print("CMM context cache:", GLOBAL_CMM.stats())


if __name__ == "__main__":
    main()
