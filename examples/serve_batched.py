"""Batched serving example: continuous-batching engine + KV-cache parking.

    PYTHONPATH=src python examples/serve_batched.py
"""

import numpy as np
import jax

from repro.configs import get_config
from repro.models import build_model
from repro.serving.engine import (
    Request,
    ServingEngine,
    compress_kv_cache,
    decompress_kv_cache,
)


def main() -> None:
    cfg = get_config("qwen2.5-3b").smoke()
    model = build_model(cfg)
    params = model.init(jax.random.PRNGKey(0))
    engine = ServingEngine(model, params, batch_size=2, max_len=64)

    rng = np.random.default_rng(0)
    requests = [
        Request(uid=i, prompt=rng.integers(0, cfg.vocab, 6).astype(np.int32),
                max_new_tokens=8)
        for i in range(5)
    ]
    stats = engine.serve(requests)
    print("serve stats:", stats)
    for r in requests[:3]:
        print(f"  req {r.uid}: prompt={list(r.prompt)} -> {r.out_tokens}")

    # park the session: ZFP-X fixed-rate compression of the KV cache
    comp, cstats = compress_kv_cache(engine.cache, rate=12)
    print(f"\nKV cache parked: {cstats['raw']/1e6:.2f}MB → "
          f"{cstats['compressed']/1e6:.2f}MB ({cstats['ratio']:.1f}x)")
    restored = decompress_kv_cache(comp, engine.cache)
    engine.cache = restored
    print("session resumed from compressed cache.")


if __name__ == "__main__":
    main()
