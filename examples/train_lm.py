"""End-to-end training driver: LM training with HPDR-compressed checkpoints.

Default preset trains a ~10M-param qwen-family model for 200 steps on CPU;
``--preset 100m`` selects a ~100M-param config (a few hundred steps on a
real accelerator; pass --steps to trim on CPU).

    PYTHONPATH=src python examples/train_lm.py --steps 200
"""

import argparse
import dataclasses

from repro.configs import get_config
from repro.launch.train import train_loop


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--preset", choices=["small", "100m"], default="small")
    ap.add_argument("--steps", type=int, default=200)
    ap.add_argument("--ckpt-dir", default="/tmp/hpdr_train_ckpt")
    ap.add_argument("--arch", default="qwen2.5-3b")
    args = ap.parse_args()

    if args.preset == "small":
        out = train_loop(
            args.arch, steps=args.steps, batch=8, seq=128, smoke=True,
            ckpt_dir=args.ckpt_dir, ckpt_every=max(args.steps // 4, 1),
            sched="wsd",
        )
    else:
        # ~100M params: d_model 512, 12 layers, vocab 32k (smoke-based resize)
        cfg = get_config(args.arch).smoke()
        cfg = dataclasses.replace(
            cfg, d_model=512, n_layers=12, n_heads=8, n_kv_heads=8,
            head_dim=64, d_ff=2048, vocab=32000,
        )
        from repro.launch import train as T

        orig = T.get_config
        T.get_config = lambda name: cfg  # inject the resized config
        try:
            out = train_loop(
                args.arch, steps=args.steps, batch=8, seq=256, smoke=False,
                ckpt_dir=args.ckpt_dir, ckpt_every=max(args.steps // 4, 1),
            )
        finally:
            T.get_config = orig
    print("\nresult:", {k: v for k, v in out.items() if k != "ckpt_report"})
    if out.get("ckpt_report"):
        r = out["ckpt_report"]
        print(f"checkpoint: {r['raw_bytes']/1e6:.1f}MB → "
              f"{r['compressed_bytes']/1e6:.1f}MB (ratio {r['ratio']:.2f}x) "
              f"in {r['save_s']:.1f}s")


if __name__ == "__main__":
    main()
