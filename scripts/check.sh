#!/usr/bin/env bash
# Tier-1 verification gate (ROADMAP.md) — run this before every PR.
# CI and humans must invoke the same command; add flags here, not in CI.
set -euo pipefail
cd "$(dirname "$0")/.."
PYTHONPATH=src${PYTHONPATH:+:$PYTHONPATH} python -m pytest -x -q "$@"
