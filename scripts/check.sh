#!/usr/bin/env bash
# Tier-1 verification gate (ROADMAP.md) — run this before every PR.
# CI and humans must invoke the same command; add flags here, not in CI.
#
#   scripts/check.sh                run the full tier-1 test suite
#   scripts/check.sh fast           the iteration tier (<1 min): the
#                                   conformance suite + core fast tests,
#                                   skipping @slow and @subprocess tests
#   scripts/check.sh bench          benchmark smoke mode: fig16 engine
#                                   throughput on a 1×CPU mesh
#                                   -> BENCH_engine.json
#   scripts/check.sh bench stages   per-stage pipeline timings (encode AND
#                                   decode) + host<->device transfer bytes
#                                   per codec (smoke-sized)
#                                   -> BENCH_stages.json
#   scripts/check.sh bench pipeline chunk-pipeline overlap: pipelined vs
#                                   serial wall clock, per-lane timings,
#                                   bit-identity check
#                                   -> BENCH_pipeline.json
#   scripts/check.sh bench serving  reduction-service concurrency: latency
#                                   p50/p99 + goodput at >=3 offered loads,
#                                   batch fill ratio vs batch window, PLUS
#                                   the socket-mode run: per-priority
#                                   p50/p99 over the wire protocol and the
#                                   interactive-under-bulk-saturation bound
#                                   -> BENCH_serving.json
#   scripts/check.sh bench tuner    auto-tuner validation: auto vs best/worst
#                                   fixed (chunk, window) configs per codec +
#                                   predicted-vs-measured makespan error
#                                   -> BENCH_tuner.json
#   scripts/check.sh bench io       multi-host parallel I/O: aggregated
#                                   shard writes vs file-per-rank vs single
#                                   shared file across 1/2/4 subprocess-
#                                   simulated hosts + restore pread locality
#                                   -> BENCH_io.json
#   scripts/check.sh bench progressive  progressive retrieval: bytes-fetched
#                                   vs error bound at 3+ bounds, refine-chain
#                                   prefix additivity + bit identity, prefix-
#                                   read ratio vs full container read
#                                   -> BENCH_progressive.json
#   scripts/check.sh docs           execute every fenced ```python block in
#                                   docs/*.md against the current API
set -euo pipefail
cd "$(dirname "$0")/.."
if [[ "${1:-}" == "docs" ]]; then
  shift
  PYTHONPATH=src${PYTHONPATH:+:$PYTHONPATH} \
    python scripts/check_docs.py "$@"
  exit 0
fi
if [[ "${1:-}" == "fast" ]]; then
  shift
  # the per-iteration gate: round-trip conformance + the quick unit tiers,
  # with multi-device subprocess tests and slow model suites excluded
  PYTHONPATH=src${PYTHONPATH:+:$PYTHONPATH} \
    python -m pytest -x -q -m "not slow and not subprocess" \
      tests/test_conformance.py tests/test_pipeline.py tests/test_bitstream.py \
      tests/test_cmm.py tests/test_abstractions.py tests/test_api_portability.py \
      tests/test_tuner.py tests/test_progressive.py \
      tests/test_progressive_conformance.py \
      tests/test_wire_protocol.py tests/test_wire_fault.py \
      "$@"
  exit 0
fi
if [[ "${1:-}" == "bench" ]]; then
  shift
  if [[ "${1:-}" == "stages" ]]; then
    shift
    PYTHONPATH=src${PYTHONPATH:+:$PYTHONPATH} \
      python -m benchmarks.stage_breakdown --smoke --out BENCH_stages.json "$@"
    exit 0
  fi
  if [[ "${1:-}" == "pipeline" ]]; then
    shift
    PYTHONPATH=src${PYTHONPATH:+:$PYTHONPATH} \
      python -m benchmarks.fig10_13_pipeline --smoke --out BENCH_pipeline.json "$@"
    exit 0
  fi
  if [[ "${1:-}" == "serving" ]]; then
    shift
    PYTHONPATH=src${PYTHONPATH:+:$PYTHONPATH} \
      python -m benchmarks.serving_load --smoke --out BENCH_serving.json "$@"
    exit 0
  fi
  if [[ "${1:-}" == "tuner" ]]; then
    shift
    PYTHONPATH=src${PYTHONPATH:+:$PYTHONPATH} \
      python -m benchmarks.tuner_sweep --smoke --out BENCH_tuner.json "$@"
    exit 0
  fi
  if [[ "${1:-}" == "io" ]]; then
    shift
    PYTHONPATH=src${PYTHONPATH:+:$PYTHONPATH} \
      python -m benchmarks.fig15_17_18_multinode_io --smoke --out BENCH_io.json "$@"
    exit 0
  fi
  if [[ "${1:-}" == "progressive" ]]; then
    shift
    PYTHONPATH=src${PYTHONPATH:+:$PYTHONPATH} \
      python -m benchmarks.progressive_curve --smoke --out BENCH_progressive.json "$@"
    exit 0
  fi
  PYTHONPATH=src${PYTHONPATH:+:$PYTHONPATH} \
    python -m benchmarks.fig16_scalability --smoke --out BENCH_engine.json "$@"
  exit 0
fi
PYTHONPATH=src${PYTHONPATH:+:$PYTHONPATH} python -m pytest -x -q "$@"
