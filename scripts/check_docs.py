"""Validate that fenced ``python`` blocks in docs/*.md run against the API.

Documentation drifts; executable documentation doesn't.  Every fenced code
block tagged exactly ```python is executed, in file order, in one shared
namespace per document (so later blocks build on earlier imports and
variables, reading top-to-bottom like a session).  Blocks tagged
```python notest are skipped — reserved for illustrative sketches
(protocol outlines, platform-specific snippets) that are not runnable on a
CPU CI container.

Usage:  PYTHONPATH=src python scripts/check_docs.py [docs/*.md ...]
Exit status is non-zero on the first failing block, with the doc name,
block index and the offending source echoed.
"""

from __future__ import annotations

import re
import sys
import traceback
from pathlib import Path

_FENCE = re.compile(
    r"^```python[ \t]*(?P<tag>[^\n`]*)\n(?P<body>.*?)^```[ \t]*$",
    re.MULTILINE | re.DOTALL,
)


def doc_blocks(text: str) -> list[tuple[bool, str]]:
    """All ```python fences as ``(runnable, source)`` in document order."""
    out = []
    for m in _FENCE.finditer(text):
        runnable = "notest" not in m.group("tag").split()
        out.append((runnable, m.group("body")))
    return out


def check_doc(path: Path) -> tuple[int, int]:
    """Run ``path``'s python blocks; returns (ran, skipped).  Raises on
    the first failing block with the source attached."""
    ns: dict = {"__name__": f"docs:{path.name}"}
    ran = skipped = 0
    for i, (runnable, src) in enumerate(doc_blocks(path.read_text())):
        if not runnable:
            skipped += 1
            continue
        try:
            exec(compile(src, f"{path}#block{i}", "exec"), ns)
        except Exception:
            print(f"FAIL {path} block {i}:\n{'-' * 60}\n{src}{'-' * 60}")
            traceback.print_exc()
            raise SystemExit(1)
        ran += 1
    return ran, skipped


def main(argv: list[str]) -> int:
    paths = [Path(p) for p in argv] or sorted(Path("docs").glob("*.md"))
    if not paths:
        print("no docs to check")
        return 1
    total_ran = 0
    for path in paths:
        ran, skipped = check_doc(path)
        total_ran += ran
        print(f"ok {path}: {ran} block(s) ran, {skipped} skipped")
    if total_ran == 0:
        print("no runnable python blocks found — docs are unchecked")
        return 1
    return 0


if __name__ == "__main__":
    raise SystemExit(main(sys.argv[1:]))
