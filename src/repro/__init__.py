"""repro — HPDR (High-Performance Portable Data Reduction) on JAX/TPU,
integrated into a multi-pod LM training/serving framework.

Subpackages: core (the paper), kernels (Pallas), models, configs, runtime,
optim, checkpoint, serving, data, launch.
"""

__version__ = "0.1.0"
