from .manager import CheckpointManager, CheckpointPolicy  # noqa: F401
