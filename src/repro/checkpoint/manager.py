"""Distributed checkpointing with HPDR compression (DESIGN.md §3.1).

The paper's at-scale result is *reduction as an I/O accelerator* (ADIOS2 +
MGARD-X on 1024 Frontier nodes).  In this framework the bulk I/O is the
checkpoint stream, so every shard is pushed through the HPDR pipeline:

  * per-tensor method selection by tensor class — float weights/moments go
    through ZFP-X fixed-rate or MGARD-X error-bounded; integer state and
    anything that must restore bit-exact goes through lossless Huffman-bytes;
  * chunked through the HDEM double-buffered executor (overlaps compress
    with device→host fetch on real hardware);
  * CMM-cached compression contexts across checkpoint rounds;
  * **engine-scheduled**: per-leaf compression fans out across the
    execution engine's ``data``-axis devices (submit/result futures), and
    ``save_async`` runs the whole save on the engine's ``io`` lane against a
    snapshot — the train loop's bubble is one device_get, not one
    filesystem round-trip;
  * **elastic restore**: arrays are resharded onto whatever mesh the restart
    runs with (`jax.device_put` with the new NamedSharding), so pod counts
    can change between runs.

  * **aggregated I/O**: every leaf's container coalesces into ONE aligned
    segment file per step (``leaves.hpdr``) written through
    :class:`repro.runtime.io.AggregatedWriter` — large positional writes on
    a dedicated flush thread, with a segment directory so restore
    ``pread``s exactly the leaves it needs (old per-leaf-file checkpoints
    still restore);
  * **multi-host sharded I/O** (paper Figs. 15/17/18): under a
    multi-controller :class:`~repro.launch.mesh.HostTopology` every host
    runs its own writer producing a local shard (``leaves-<host>.hpdr``)
    holding exactly the leaves it owns (deterministic crc32 assignment);
    hosts rendezvous on a shared-filesystem barrier and the coordinator
    (host 0) stitches the per-host segment directories into a **global
    manifest**.  Restore is topology-aware: a same-topology restore
    ``pread``s only its local shard's byte ranges
    (``restore(leaves="local")``), while a remeshed restart falls back to
    cross-shard preads — observable via ``last_restore_io``.

Layout:  <dir>/step_<N>/manifest.json + <dir>/step_<N>/leaves.hpdr
         (multi-host: <dir>/step_<N>/leaves-<host>.hpdr per host)
         (pre-aggregation checkpoints: <dir>/step_<N>/<leaf-path>.hpdr)
"""

from __future__ import annotations

import json
import time
from dataclasses import dataclass
from pathlib import Path
from typing import Any, Callable

import jax
import jax.numpy as jnp
import numpy as np

from ..core import api
from ..core import engine as engine_mod
from ..launch.mesh import HostTopology, barrier_payloads, fs_barrier
from ..runtime.executor import IO, Submission
from ..runtime.io import (
    AggregatedReader,
    AggregatedWriter,
    ShardSetReader,
    shard_file_name,
    stitch_shard_directories,
)

_SEP = "::"
_AGGREGATE_FILE = "leaves.hpdr"
_COMMIT_POLL_S = 0.005


@dataclass(frozen=True)
class CheckpointPolicy:
    # zfp | mgard | mgard-progressive | huffman-bytes (lossless);
    # mgard-progressive writes one segment per precision tier so restore
    # can pread a prefix (restore(max_error=...))
    float_method: str = "zfp"
    zfp_rate: int = 28               # bits/value — ~1e-6 rel err, 1.14× smaller
    mgard_eb: float = 1e-6
    progressive_tiers: int = 3       # precision components per leaf
    progressive_ratio: float = 8.0   # bound ratio between adjacent tiers
    lossless_small: int = 16384      # tensors below this many elems: lossless
    exact: bool = False              # force lossless everywhere
    # float leaves at/above this many bytes go through the auto-tuned
    # chunked CompressorStream (chunk_size="auto", window="auto"): the
    # calibrated machine cost model picks the chunking/overlap per leaf,
    # and the leaf's segment becomes a framed HPDS stream.  None disables.
    stream_threshold: int | None = 8 << 20
    # fsync shard/aggregate files (and their directory entries) on close;
    # default off — tests and benchmarks should not pay disk-flush latency
    fsync: bool = False
    # how long a host waits at the save barrier / for the coordinator's
    # global-manifest commit before declaring the save torn
    barrier_timeout_s: float = 120.0


def _flatten(tree: Any) -> dict[str, np.ndarray]:
    flat = {}
    for path, leaf in jax.tree_util.tree_flatten_with_path(tree)[0]:
        key = _SEP.join(
            str(getattr(e, "key", getattr(e, "idx", ""))) for e in path
        )
        flat[key] = np.asarray(leaf)
    return flat


def _method_for(arr: np.ndarray, policy: CheckpointPolicy) -> tuple[str, dict]:
    if policy.exact or arr.dtype.kind != "f" or arr.size < policy.lossless_small:
        return "huffman-bytes", {}
    if policy.float_method == "zfp":
        return "zfp", {"rate": policy.zfp_rate}
    if policy.float_method == "mgard":
        return "mgard", {"error_bound": policy.mgard_eb, "relative": True}
    if policy.float_method == "mgard-progressive":
        return "mgard-progressive", {
            "error_bound": policy.mgard_eb, "relative": True,
            "tiers": policy.progressive_tiers,
            "tier_ratio": policy.progressive_ratio,
        }
    return "huffman-bytes", {}


def _compress_leaf(
    arr: np.ndarray, policy: CheckpointPolicy
) -> bytes | tuple[str, dict, list[bytes]]:
    """One leaf's serialised form: container bytes, or — for progressive
    leaves — ``("progressive", manifest, component_blobs)`` so the writer
    can store each precision tier as its own addressable segment."""
    method, kw = _method_for(arr, policy)
    c = api.compress_leaf(arr, method, **kw)
    if c.method == "mgard-progressive":
        from ..core import progressive

        comps = [
            np.ascontiguousarray(c.arrays[progressive.component_name(t)]).tobytes()
            for t in range(len(c.meta["tier_bounds"]))
        ]
        return ("progressive", api._jsonable(c.meta), comps)
    return c.to_bytes()


def _restore_progressive(meta: dict, blobs: list[bytes]) -> np.ndarray:
    """Reconstruct a progressive leaf from a component-blob prefix."""
    from ..core import progressive

    stream = progressive.ProgressiveStream(
        manifest={
            k: meta[k]
            for k in ("shape", "padded", "L", "dict_size",
                      "tier_bounds", "component_nbytes")
        },
        components=list(blobs),
    )
    out = np.asarray(progressive.retrieve(stream))
    out = out.astype(np.dtype(meta.get("dtype", "float32")))
    stub = api.Compressed(method="mgard-progressive", meta=meta, arrays={})
    return api.restore_leaf(out, stub)


def _should_stream(arr: np.ndarray, policy: CheckpointPolicy) -> bool:
    if policy.stream_threshold is None or policy.exact:
        return False
    if policy.float_method == "mgard-progressive":
        # progressive leaves write per-tier segments, not a framed stream —
        # prefix addressability is the whole point
        return False
    return arr.dtype.kind == "f" and arr.nbytes >= policy.stream_threshold


def _stream_leaf(arr: np.ndarray, policy: CheckpointPolicy) -> tuple[bytes, dict]:
    """Compress one large leaf through the auto-tuned chunked stream.

    Runs *inline on the caller's thread* with a standalone (engine-free)
    CompressorStream: ``save_async`` executes ``save`` on the engine's
    single io worker, and a stream whose staging loop occupies an engine
    lane while waiting on that same lane's serialize futures would
    deadlock.  The standalone stream brings its own transient executor.
    """
    method, kw = _method_for(arr, policy)
    stream = api.CompressorStream(
        method, chunk_size="auto", window="auto", frame=True, **kw
    )
    res = stream.compress(arr)
    info = {"window": res.window}
    if res.tuned is not None:
        info["tuned"] = res.tuned
    return stream.to_bytes(res), info


def _decompress_leaf(raw: bytes) -> np.ndarray:
    return api.decompress_leaf(api.Compressed.from_bytes(raw))


class CheckpointManager:
    def __init__(
        self,
        directory: str | Path,
        policy: CheckpointPolicy | None = None,
        engine: engine_mod.ExecutionEngine | None = None,
        topology: HostTopology | None = None,
    ):
        self.dir = Path(directory)
        self.dir.mkdir(parents=True, exist_ok=True)
        self.policy = policy or CheckpointPolicy()
        self._engine = engine
        self._topology = topology
        self._pending: Submission | None = None
        self.last_report: dict | None = None
        #: pread-locality stats of the most recent ``restore`` (shard-set
        #: layouts record local vs cross preads; single-file layouts record
        #: everything as local) — what the topology-awareness tests assert
        self.last_restore_io: dict | None = None

    @property
    def engine(self) -> engine_mod.ExecutionEngine:
        return self._engine if self._engine is not None else engine_mod.default_engine()

    @property
    def topology(self) -> HostTopology:
        """Explicit topology, else the engine's (env / jax.distributed)."""
        return self._topology if self._topology is not None else self.engine.topology

    # ----------------------------------------------------------------- save

    def save(self, step: int, tree: Any, extra: dict | None = None) -> dict:
        topo = self.topology
        if topo.multi_host:
            return self._save_multihost(step, tree, extra, topo)
        return self._save_single(step, tree, extra)

    def _submit_leaf_compressions(self, flat: dict) -> list[tuple]:
        """Fan per-leaf compression out across the engine (compute lane).

        Large float leaves bypass the one-shot path and go through the
        auto-tuned chunked stream *inline on the save thread* (see
        ``_stream_leaf`` for why they must not occupy an engine lane);
        everything else fans out across the engine, so small leaves still
        compress while a streamed leaf is in flight.
        """
        return [
            (
                key,
                arr,
                None
                if _should_stream(arr, self.policy)
                else self.engine.submit(_compress_leaf, arr, self.policy),
            )
            for key, arr in flat.items()
        ]

    def _write_leaves(
        self, writer: AggregatedWriter, subs: list[tuple]
    ) -> tuple[dict, int, int]:
        """Drain compression futures into ``writer``; returns
        ``(leaf_entries, raw_total, comp_total)``.

        Blobs coalesce into the aggregated segment file — large aligned
        positional writes flushed on the writer's own flush thread, so leaf
        i+1's compression overlaps leaf i's disk write.
        """
        entries: dict[str, dict] = {}
        raw_total, comp_total = 0, 0
        used: set[str] = set()
        for key, arr, sub in subs:
            stream_info = None
            if sub is None:
                blob, stream_info = _stream_leaf(arr, self.policy)
            else:
                blob = sub.result()
            # sanitize separators and dedupe: distinct keys must never
            # share a segment — restore reads the key->segment mapping
            # from the manifest, so any injective name works
            base = key.replace(_SEP, "__").replace("/", "_") or "_root"
            name, i = base, 2
            while name in used:
                name = f"{base}~{i}"
                i += 1
            used.add(name)
            if isinstance(blob, tuple) and blob[0] == "progressive":
                # one addressable segment per precision tier: restore can
                # pread a component prefix (restore(max_error=...))
                _, pmeta, comps = blob
                seg_names, total = [], 0
                for t, comp in enumerate(comps):
                    seg = f"{name}~p{t:02d}"
                    writer.add(seg, comp)
                    seg_names.append(seg)
                    total += len(comp)
                entry = {
                    "segments": seg_names, "bytes": total,
                    "raw": arr.nbytes, "progressive": pmeta,
                }
                entries[key] = entry
                raw_total += arr.nbytes
                comp_total += total
                continue
            writer.add(name, blob)
            entry = {"segment": name, "bytes": len(blob), "raw": arr.nbytes}
            if stream_info is not None:
                entry["stream"] = True
                entry.update(stream_info)
            entries[key] = entry
            raw_total += arr.nbytes
            comp_total += len(blob)
        return entries, raw_total, comp_total

    def _save_single(self, step: int, tree: Any, extra: dict | None) -> dict:
        t0 = time.perf_counter()
        flat = _flatten(tree)
        step_dir = self.dir / f"step_{step:08d}"
        step_dir.mkdir(parents=True, exist_ok=True)
        manifest = {"step": step, "extra": extra or {},
                    "aggregate": _AGGREGATE_FILE, "leaves": {}}
        subs = self._submit_leaf_compressions(flat)
        with AggregatedWriter(
            step_dir / _AGGREGATE_FILE, meta={"step": step},
            fsync=self.policy.fsync, atomic=True,
        ) as writer:
            entries, raw_total, comp_total = self._write_leaves(writer, subs)
        manifest["leaves"] = entries
        io_stats = dict(writer.stats)  # after close(): counts the final flush
        manifest["raw_bytes"] = raw_total
        manifest["compressed_bytes"] = comp_total
        manifest["ratio"] = raw_total / max(comp_total, 1)
        manifest["save_s"] = time.perf_counter() - t0
        manifest["io"] = io_stats
        (step_dir / "manifest.json").write_text(json.dumps(manifest, indent=1))
        # commit marker: restore only sees completed checkpoints
        (step_dir / "COMMITTED").write_text("ok")
        self.last_report = manifest
        return manifest

    def _save_multihost(
        self, step: int, tree: Any, extra: dict | None, topo: HostTopology
    ) -> dict:
        """Per-host shard writers + coordinator-stitched global manifest.

        Every host compresses exactly the leaves it owns (deterministic
        crc32 assignment — no communication) and writes them through its
        own :class:`AggregatedWriter` into ``leaves-<host>.hpdr``
        (atomically, so a torn host write never parses).  The hosts then
        rendezvous on a shared-filesystem barrier whose marker payload
        carries each writer's I/O stats, and host 0 stitches the per-host
        segment directories into the global ``manifest.json`` before
        writing ``COMMITTED``.  Non-coordinators block on the commit
        marker, so every host returns the same manifest.
        """
        t0 = time.perf_counter()
        flat = _flatten(tree)
        step_dir = self.dir / f"step_{step:08d}"
        step_dir.mkdir(parents=True, exist_ok=True)
        owned = {k: a for k, a in flat.items() if topo.owns(k)}
        subs = self._submit_leaf_compressions(owned)
        shard = shard_file_name(topo.host_id)
        with AggregatedWriter(
            step_dir / shard,
            meta={"step": step, "host": topo.host_id, "hosts": topo.n_hosts},
            fsync=self.policy.fsync, atomic=True,
        ) as writer:
            entries, raw_total, comp_total = self._write_leaves(writer, subs)
        # rendezvous: the marker payload is each host's partial manifest —
        # leaf entries + writer stats — so stitching needs no extra files
        payload = json.dumps({
            "host": topo.host_id, "file": shard, "leaves": entries,
            "raw_bytes": raw_total, "compressed_bytes": comp_total,
            "io": dict(writer.stats), "save_s": time.perf_counter() - t0,
        })
        fs_barrier(step_dir, f"save-{step}", topo,
                   timeout=self.policy.barrier_timeout_s, payload=payload)
        if topo.host_id == 0:
            manifest = self._stitch_global_manifest(
                step, step_dir, extra, topo, t0
            )
        else:
            self._wait_for_commit(step_dir)
            manifest = json.loads((step_dir / "manifest.json").read_text())
        self.last_report = manifest
        return manifest

    def _stitch_global_manifest(
        self, step: int, step_dir: Path, extra: dict | None,
        topo: HostTopology, t0: float,
    ) -> dict:
        payloads = {
            h: json.loads(raw)
            for h, raw in barrier_payloads(step_dir, f"save-{step}", topo).items()
        }
        shard_files = {str(h): p["file"] for h, p in payloads.items()}
        # validate every shard's trailer before committing anything: a torn
        # host write must fail the global commit, not surface at restore
        stitched = stitch_shard_directories(step_dir, shard_files)
        manifest: dict = {
            "step": step, "extra": extra or {},
            "shards": shard_files,
            "topology": {"hosts": topo.n_hosts},
            "leaves": {}, "io": {},
        }
        raw_total = comp_total = 0
        for h in sorted(payloads):
            p = payloads[h]
            for key, entry in p["leaves"].items():
                manifest["leaves"][key] = {**entry, "shard": str(h)}
            raw_total += int(p["raw_bytes"])
            comp_total += int(p["compressed_bytes"])
            manifest["io"][str(h)] = p["io"]
        manifest["raw_bytes"] = raw_total
        manifest["compressed_bytes"] = comp_total
        manifest["ratio"] = raw_total / max(comp_total, 1)
        manifest["save_s"] = time.perf_counter() - t0
        manifest["stitched_segments"] = stitched["segments"]
        (step_dir / "manifest.json").write_text(json.dumps(manifest, indent=1))
        (step_dir / "COMMITTED").write_text("ok")
        return manifest

    def _wait_for_commit(self, step_dir: Path) -> None:
        deadline = time.monotonic() + self.policy.barrier_timeout_s
        marker = step_dir / "COMMITTED"
        while not marker.exists():
            if time.monotonic() > deadline:
                raise TimeoutError(
                    f"{step_dir}: coordinator never committed the global "
                    f"manifest within {self.policy.barrier_timeout_s}s"
                )
            time.sleep(_COMMIT_POLL_S)

    def save_async(self, step: int, tree: Any, extra: dict | None = None) -> Submission:
        """Snapshot to host, then compress+write on the engine's io lane.

        The returned :class:`Submission` resolves to the manifest; training
        continues immediately after the snapshot.  A previous in-flight
        save is *chained*, not waited on — the new save is submitted to the
        io lane the moment the previous one completes, so the train loop's
        bubble really is just the snapshot.  If the previous save failed,
        its exception propagates from this submission's ``result()`` (the
        chained save is skipped — a torn earlier checkpoint fails fast).
        """
        snapshot = jax.tree.map(np.asarray, tree)  # the only sync point
        prev, self._pending = self._pending, None
        if prev is None:
            self._pending = self.engine.submit(
                self.save, step, snapshot, extra, lane=IO
            )
        else:
            self._pending = self.engine.executor.submit_after(
                prev, lambda _prev_manifest: self.save(step, snapshot, extra),
                lane=IO,
            )
        return self._pending

    def wait(self) -> dict | None:
        if self._pending is not None:
            pending, self._pending = self._pending, None
            return pending.result()
        return None

    # -------------------------------------------------------------- restore

    def latest_step(self) -> int | None:
        steps = [
            int(p.name.split("_")[1])
            for p in self.dir.glob("step_*")
            if (p / "COMMITTED").exists()
        ]
        return max(steps) if steps else None

    def restore(
        self,
        step: int | None = None,
        target: Any | None = None,
        shardings: Any | None = None,
        leaves: Any | None = None,
        max_error: float | None = None,
    ) -> tuple[Any, dict]:
        """Load a checkpoint; optionally reshard onto a (new) mesh.

        ``max_error`` (absolute L∞ bound) makes the restore *progressive*:
        leaves checkpointed with ``float_method="mgard-progressive"`` read
        only the component prefix whose tier bound satisfies it — coarser
        restores pread strictly fewer bytes (``last_restore_io``).  Leaves
        stored any other way are at final precision already and are
        unaffected.

        ``target`` supplies the pytree structure; ``shardings`` (same
        structure) re-places every leaf — elastic restarts pass the new
        mesh's shardings here.  ``leaves`` (flat-mode only, ``target=None``)
        selects a subset of leaf keys: on the aggregated layouts only those
        leaves' byte ranges are ``pread`` — a partial restore never touches
        the rest of the file.  The sentinel ``leaves="local"`` selects the
        leaves this host owns under its *current* topology: when the
        checkpoint was written with the same host count, every one of them
        lives in the local shard and the restore preads only local byte
        ranges; on remeshing the owned set spans foreign shards and the
        reader falls back to cross-shard preads (``last_restore_io``).
        """
        if step is None:
            step = self.latest_step()
            if step is None:
                raise FileNotFoundError(f"no committed checkpoints in {self.dir}")
        step_dir = self.dir / f"step_{step:08d}"
        manifest = json.loads((step_dir / "manifest.json").read_text())
        if leaves is not None and target is not None:
            raise ValueError("leaves= selects a subset; incompatible with target=")
        topo = self.topology
        if isinstance(leaves, str) and leaves == "local":
            wanted: set | None = {
                k for k in manifest["leaves"] if topo.owns(k)
            }
        else:
            wanted = None if leaves is None else set(leaves)
        shard_files = manifest.get("shards")
        reader: AggregatedReader | None = None
        shard_set: ShardSetReader | None = None
        if shard_files:
            # locality only exists when the writing topology matches ours:
            # then this host's owned leaves are exactly its shard's segments
            same_topo = (
                manifest.get("topology", {}).get("hosts") == topo.n_hosts
            )
            shard_set = ShardSetReader(
                step_dir, shard_files,
                local=str(topo.host_id) if same_topo else None,
            )
        elif manifest.get("aggregate"):
            reader = AggregatedReader(step_dir / manifest["aggregate"])
        try:
            flat = {}
            for key, info in manifest["leaves"].items():
                if wanted is not None and key not in wanted:
                    continue
                if "segments" in info:  # progressive: per-tier segments
                    pmeta = info["progressive"]
                    bounds = [float(b) for b in pmeta["tier_bounds"]]
                    k = len(bounds)
                    if max_error is not None:
                        k = next(
                            (i + 1 for i, b in enumerate(bounds)
                             if b <= float(max_error)),
                            k,
                        )
                    blobs = [
                        shard_set.read(info["shard"], seg)
                        if shard_set is not None
                        else reader.read(seg)
                        for seg in info["segments"][:k]
                    ]
                    flat[key] = _restore_progressive(pmeta, blobs)
                    continue
                if shard_set is not None:
                    raw = shard_set.read(info["shard"], info["segment"])
                elif "segment" in info:
                    raw = reader.read(info["segment"])
                else:  # pre-aggregation layout: one file per leaf
                    raw = (step_dir / info["file"]).read_bytes()
                if info.get("stream"):
                    flat[key] = np.asarray(
                        api.CompressorStream.decompress(
                            api.CompressorStream.from_bytes(raw)
                        )
                    )
                else:
                    flat[key] = _decompress_leaf(raw)
        finally:
            if shard_set is not None:
                self.last_restore_io = dict(shard_set.stats)
                shard_set.close()
            elif reader is not None:
                self.last_restore_io = {
                    "local_preads": reader.preads, "cross_preads": 0,
                    "local_bytes": reader.pread_bytes, "cross_bytes": 0,
                    "shards_opened": [], "preads_by_shard": {},
                }
                reader.close()
            else:
                self.last_restore_io = {
                    "local_preads": 0, "cross_preads": 0,
                    "local_bytes": 0, "cross_bytes": 0,
                    "shards_opened": [], "preads_by_shard": {},
                }
        if target is None:
            return flat, manifest
        leaves_with_path = jax.tree_util.tree_flatten_with_path(target)
        shard_leaves = (
            jax.tree.leaves(shardings) if shardings is not None else None
        )
        out = []
        for i, (path, leaf) in enumerate(leaves_with_path[0]):
            key = _SEP.join(
                str(getattr(e, "key", getattr(e, "idx", ""))) for e in path
            )
            arr = flat[key]
            if hasattr(leaf, "dtype"):
                arr = arr.astype(leaf.dtype)
            if shard_leaves is not None:
                out.append(jax.device_put(arr, shard_leaves[i]))
            else:
                out.append(jnp.asarray(arr))
        tree = jax.tree_util.tree_unflatten(leaves_with_path[1], out)
        return tree, manifest
