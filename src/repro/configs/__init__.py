"""Architecture config registry: one module per assigned architecture."""

from __future__ import annotations

from .base import SHAPES, ModelConfig, ShapeConfig, applicable_shapes  # noqa: F401

from . import (  # noqa: E402
    deepseek_67b,
    deepseek_v3_671b,
    llama4_scout_17b_a16e,
    mamba2_370m,
    minicpm_2b,
    qwen1_5_4b,
    qwen2_5_3b,
    qwen2_vl_72b,
    recurrentgemma_9b,
    seamless_m4t_medium,
)

ARCHS: dict[str, ModelConfig] = {
    m.CONFIG.name: m.CONFIG
    for m in (
        deepseek_v3_671b,
        llama4_scout_17b_a16e,
        recurrentgemma_9b,
        mamba2_370m,
        seamless_m4t_medium,
        qwen2_5_3b,
        qwen1_5_4b,
        minicpm_2b,
        deepseek_67b,
        qwen2_vl_72b,
    )
}


def get_config(name: str) -> ModelConfig:
    if name not in ARCHS:
        raise KeyError(f"unknown arch {name!r}; known: {sorted(ARCHS)}")
    return ARCHS[name]
