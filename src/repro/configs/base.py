"""Model/shape configuration schema for the assigned architecture pool."""

from __future__ import annotations

from dataclasses import dataclass, field, replace
from typing import Sequence


@dataclass(frozen=True)
class MoEConfig:
    n_experts: int = 0
    top_k: int = 1
    n_shared: int = 0
    d_ff_expert: int = 0
    first_dense_layers: int = 0      # leading dense layers (deepseek-v3: 3)
    d_ff_dense: int = 0              # d_ff of those dense layers
    router_noise: float = 0.0
    aux_loss_coef: float = 0.001


@dataclass(frozen=True)
class MLAConfig:
    q_lora_rank: int = 1536
    kv_lora_rank: int = 512
    qk_nope_head_dim: int = 128
    qk_rope_head_dim: int = 64
    v_head_dim: int = 128


@dataclass(frozen=True)
class SSMConfig:
    d_state: int = 128
    d_conv: int = 4
    expand: int = 2
    head_dim: int = 64
    n_groups: int = 1
    chunk: int = 128                 # SSD chunk length (MXU-friendly)


@dataclass(frozen=True)
class HybridConfig:
    pattern: tuple[str, ...] = ("rec", "rec", "attn")  # RG 1 attn : 2 recurrent
    lru_width: int = 0               # 0 → d_model
    window: int = 2048               # local attention window
    conv_width: int = 4


@dataclass(frozen=True)
class ModelConfig:
    name: str
    family: str                      # dense | moe | ssm | hybrid | encdec | vlm
    n_layers: int
    d_model: int
    n_heads: int
    n_kv_heads: int
    d_ff: int
    vocab: int
    head_dim: int = 0                # 0 → d_model // n_heads
    qkv_bias: bool = False
    tie_embeddings: bool = False
    rope_theta: float = 10000.0
    norm_eps: float = 1e-6
    attn_type: str = "gqa"           # gqa | mla
    moe: MoEConfig | None = None
    mla: MLAConfig | None = None
    ssm: SSMConfig | None = None
    hybrid: HybridConfig | None = None
    # enc-dec
    n_enc_layers: int = 0
    n_dec_layers: int = 0
    # modality frontend stub: none | audio_stub | vision_stub
    frontend: str = "none"
    mrope: bool = False              # qwen2-vl M-RoPE (3 rotary sections)
    mrope_sections: tuple[int, ...] = (16, 24, 24)  # t/h/w splits of head_dim/2
    mtp: bool = False                # deepseek-v3 multi-token prediction head
    # minicpm μP-style scaling
    scale_emb: float = 1.0
    scale_depth: float = 0.0         # 0 → no residual scaling
    dim_model_base: int = 256
    # training-system knobs
    fsdp: bool = False               # additionally shard params over data axis
    remat: bool = True
    dtype: str = "bfloat16"
    param_dtype: str = "float32"
    # performance levers (§Perf hillclimb; defaults = paper-faithful baseline)
    sharding_policy: str = "tp"      # tp | fsdp_dp (pure DP + ZeRO-3 params)
    moe_group_size: int = 0          # >0: group-blocked MoE dispatch (GShard groups)
    moe_impl: str = "gshard"         # gshard (einsum dispatch) | a2a (shard_map
                                     # expert-parallel all-to-all routing)
    kv_replicate: int = 1            # decode: physically replicate KV heads to
                                     # fill the model axis (head-sharded cache)
    decode_masked_update: bool = False  # decode cache write via masked where
                                        # (shard-local on a seq-sharded cache)
                                        # instead of dynamic_update_slice
    # shape applicability
    supports_long_context: bool = False   # sub-quadratic decode state
    # HPDR integration defaults
    ckpt_compress: str = "zfp"       # checkpoint compression pipeline
    ckpt_rate: int = 16
    grad_compress_bits: int = 8      # cross-pod gradient compression

    @property
    def resolved_head_dim(self) -> int:
        return self.head_dim or self.d_model // self.n_heads

    def smoke(self) -> "ModelConfig":
        """Reduced same-family config for CPU smoke tests."""
        small = {
            "n_layers": min(self.n_layers, 4),
            "d_model": 64,
            "n_heads": 4,
            "n_kv_heads": min(self.n_kv_heads, 4) if self.n_kv_heads > 1 else 1,
            "d_ff": 128,
            "vocab": 256,
            "head_dim": 16,
            "n_enc_layers": min(self.n_enc_layers, 2),
            "n_dec_layers": min(self.n_dec_layers, 2),
            "fsdp": False,
            "dtype": "float32",
            "param_dtype": "float32",
        }
        if self.moe is not None:
            small["moe"] = replace(
                self.moe,
                n_experts=4,
                top_k=min(self.moe.top_k, 2),
                d_ff_expert=64,
                first_dense_layers=min(self.moe.first_dense_layers, 1),
                d_ff_dense=128,
            )
        if self.mla is not None:
            small["mla"] = MLAConfig(
                q_lora_rank=32, kv_lora_rank=16,
                qk_nope_head_dim=16, qk_rope_head_dim=8, v_head_dim=16,
            )
        if self.ssm is not None:
            small["ssm"] = replace(self.ssm, d_state=16, head_dim=16, chunk=16)
        if self.hybrid is not None:
            small["hybrid"] = replace(self.hybrid, lru_width=64, window=32)
        if self.mrope:
            small["mrope_sections"] = (2, 3, 3)  # scaled to head_dim 16
        return replace(self, **small)


@dataclass(frozen=True)
class ShapeConfig:
    name: str
    seq_len: int
    global_batch: int
    kind: str                        # train | prefill | decode


SHAPES: dict[str, ShapeConfig] = {
    "train_4k": ShapeConfig("train_4k", 4096, 256, "train"),
    "prefill_32k": ShapeConfig("prefill_32k", 32768, 32, "prefill"),
    "decode_32k": ShapeConfig("decode_32k", 32768, 128, "decode"),
    "long_500k": ShapeConfig("long_500k", 524288, 1, "decode"),
}


def applicable_shapes(cfg: ModelConfig) -> list[str]:
    """Shape cells for this arch; long_500k only for sub-quadratic decode."""
    shapes = ["train_4k", "prefill_32k", "decode_32k"]
    if cfg.supports_long_context:
        shapes.append("long_500k")
    return shapes
