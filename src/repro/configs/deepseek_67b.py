"""deepseek-67b [dense] — arXiv:2401.02954 / hf deepseek-ai/deepseek-llm-67b.

95L d_model=8192 64H (GQA kv=8) d_ff=22016 vocab=102400; llama-arch.
"""

from .base import ModelConfig

CONFIG = ModelConfig(
    name="deepseek-67b",
    family="dense",
    n_layers=95,
    d_model=8192,
    n_heads=64,
    n_kv_heads=8,
    d_ff=22016,
    vocab=102400,
    head_dim=128,
    fsdp=True,
    ckpt_compress="zfp",
)
