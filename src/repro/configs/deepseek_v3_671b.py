"""deepseek-v3-671b [moe] — arXiv:2412.19437 / hf deepseek-ai/DeepSeek-V3.

61L d_model=7168 128H (MLA) d_ff=2048(expert) vocab=129280;
MoE: 1 shared + 256 routed top-8; first 3 layers dense (d_ff 18432); MTP.
"""

from .base import MLAConfig, ModelConfig, MoEConfig

CONFIG = ModelConfig(
    name="deepseek-v3-671b",
    family="moe",
    n_layers=61,
    d_model=7168,
    n_heads=128,
    n_kv_heads=128,
    d_ff=18432,                    # dense-layer FFN width
    vocab=129280,
    head_dim=128,
    attn_type="mla",
    mla=MLAConfig(
        q_lora_rank=1536,
        kv_lora_rank=512,
        qk_nope_head_dim=128,
        qk_rope_head_dim=64,
        v_head_dim=128,
    ),
    moe=MoEConfig(
        n_experts=256,
        top_k=8,
        n_shared=1,
        d_ff_expert=2048,
        first_dense_layers=3,
        d_ff_dense=18432,
    ),
    mtp=True,
    fsdp=True,
    ckpt_compress="zfp",
)
