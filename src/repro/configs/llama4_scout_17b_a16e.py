"""llama4-scout-17b-a16e [moe] — hf:meta-llama/Llama-4-Scout-17B-16E (unverified).

48L d_model=5120 40H (GQA kv=8) d_ff=8192(expert) vocab=202048;
MoE: 16 routed experts top-1 + 1 shared, every layer.
"""

from .base import ModelConfig, MoEConfig

CONFIG = ModelConfig(
    name="llama4-scout-17b-a16e",
    family="moe",
    n_layers=48,
    d_model=5120,
    n_heads=40,
    n_kv_heads=8,
    d_ff=8192,
    vocab=202048,
    head_dim=128,
    moe=MoEConfig(
        n_experts=16,
        top_k=1,
        n_shared=1,
        d_ff_expert=8192,
        first_dense_layers=0,
    ),
    rope_theta=500000.0,
    fsdp=True,
    ckpt_compress="zfp",
)
