"""mamba2-370m [ssm] — arXiv:2405.21060 (unverified).

48L d_model=1024 attention-free, ssm_state=128, vocab=50280;
SSD (state-space duality) blocks.  O(1) decode state ⇒ runs long_500k.
"""

from .base import ModelConfig, SSMConfig

CONFIG = ModelConfig(
    name="mamba2-370m",
    family="ssm",
    n_layers=48,
    d_model=1024,
    n_heads=1,              # unused (attention-free)
    n_kv_heads=1,
    d_ff=0,
    vocab=50280,
    head_dim=64,
    ssm=SSMConfig(d_state=128, d_conv=4, expand=2, head_dim=64, n_groups=1, chunk=128),
    tie_embeddings=True,
    supports_long_context=True,
    ckpt_compress="zfp",
)
