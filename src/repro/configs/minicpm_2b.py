"""minicpm-2b [dense] — arXiv:2404.06395 / hf openbmb/MiniCPM-2B.

40L d_model=2304 36H (kv=36) d_ff=5760 vocab=122753; llama-like with μP
scaling (scale_emb=12, scale_depth=1.4, dim_model_base=256) and the WSD
schedule (implemented in optim/schedule.py).
"""

from .base import ModelConfig

CONFIG = ModelConfig(
    name="minicpm-2b",
    family="dense",
    n_layers=40,
    d_model=2304,
    n_heads=36,
    n_kv_heads=36,
    d_ff=5760,
    vocab=122753,
    head_dim=64,
    tie_embeddings=True,
    scale_emb=12.0,
    scale_depth=1.4,
    dim_model_base=256,
    ckpt_compress="zfp",
)
