"""qwen1.5-4b [dense] — hf:Qwen/Qwen1.5-4B family.

40L d_model=2560 20H (kv=20, full MHA) d_ff=6912 vocab=151936; QKV bias.
"""

from .base import ModelConfig

CONFIG = ModelConfig(
    name="qwen1.5-4b",
    family="dense",
    n_layers=40,
    d_model=2560,
    n_heads=20,
    n_kv_heads=20,
    d_ff=6912,
    vocab=151936,
    head_dim=128,
    qkv_bias=True,
    rope_theta=5000000.0,
    ckpt_compress="zfp",
)
