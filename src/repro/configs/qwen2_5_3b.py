"""qwen2.5-3b [dense] — hf:Qwen/Qwen2.5-3B family.

36L d_model=2048 16H (GQA kv=2) d_ff=11008 vocab=151936; QKV bias.
"""

from .base import ModelConfig

CONFIG = ModelConfig(
    name="qwen2.5-3b",
    family="dense",
    n_layers=36,
    d_model=2048,
    n_heads=16,
    n_kv_heads=2,
    d_ff=11008,
    vocab=151936,
    head_dim=128,
    qkv_bias=True,
    rope_theta=1000000.0,
    tie_embeddings=True,
    ckpt_compress="zfp",
)
