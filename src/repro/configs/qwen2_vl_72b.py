"""qwen2-vl-72b [vlm] — arXiv:2409.12191 / hf Qwen/Qwen2-VL-72B.

80L d_model=8192 64H (GQA kv=8) d_ff=29568 vocab=152064; M-RoPE
(t/h/w rotary sections), dynamic-resolution vision frontend is a STUB
(``input_specs`` supplies patch embeddings + 3-D position triplets).
"""

from .base import ModelConfig

CONFIG = ModelConfig(
    name="qwen2-vl-72b",
    family="vlm",
    n_layers=80,
    d_model=8192,
    n_heads=64,
    n_kv_heads=8,
    d_ff=29568,
    vocab=152064,
    head_dim=128,
    qkv_bias=True,
    mrope=True,
    mrope_sections=(16, 24, 24),
    rope_theta=1000000.0,
    frontend="vision_stub",
    fsdp=True,
    ckpt_compress="zfp",
)
