"""recurrentgemma-9b [hybrid] — arXiv:2402.19427 (Griffin) (unverified).

38L d_model=4096 16H (MQA kv=1) d_ff=12288 vocab=256000;
RG-LRU + local attention, pattern (rec, rec, attn); window 2048.
Sub-quadratic decode state ⇒ runs long_500k.
"""

from .base import HybridConfig, ModelConfig

CONFIG = ModelConfig(
    name="recurrentgemma-9b",
    family="hybrid",
    n_layers=38,
    d_model=4096,
    n_heads=16,
    n_kv_heads=1,
    d_ff=12288,
    vocab=256000,
    head_dim=256,
    hybrid=HybridConfig(
        pattern=("rec", "rec", "attn"),
        lru_width=4096,
        window=2048,
        conv_width=4,
    ),
    tie_embeddings=True,
    supports_long_context=True,
    ckpt_compress="zfp",
)
