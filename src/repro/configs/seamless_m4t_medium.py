"""seamless-m4t-medium [audio] — arXiv:2308.11596 / hf facebook/seamless-m4t-medium.

12L d_model=1024 16H (kv=16) d_ff=4096 vocab=256206; encoder-decoder.
Audio frontend is a STUB: ``input_specs`` supplies precomputed frame
embeddings (B, S_enc, D) per the brief.
"""

from .base import ModelConfig

CONFIG = ModelConfig(
    name="seamless-m4t-medium",
    family="encdec",
    n_layers=12,
    n_enc_layers=12,
    n_dec_layers=12,
    d_model=1024,
    n_heads=16,
    n_kv_heads=16,
    d_ff=4096,
    vocab=256206,
    head_dim=64,
    frontend="audio_stub",
    ckpt_compress="zfp",
)
