"""HPDR core — the paper's contribution: portable reduction framework.

Layers (paper Fig. 2, bottom-up): device adapters (`adapters`), machine
abstraction (`machine`: GEM/DEM, `context`: CMM, `pipeline`: HDEM), parallel
abstractions (`abstractions`), reduction pipelines (`mgard`, `zfp`,
`huffman`) behind the codec registry (`codecs`), and the high-level API
(`api`: spec → plan → execute, with the `container` byte format).
"""

from . import (  # noqa: F401
    abstractions,
    adapters,
    api,
    bitstream,
    codecs,
    container,
    context,
    huffman,
    machine,
    mgard,
    quantize,
    zfp,
)
from .api import (  # noqa: F401
    Compressed,
    CompressorStream,
    ContainerError,
    ReductionPlan,
    ReductionSpec,
    compress,
    compress_pytree,
    decompress,
    decompress_pytree,
)
