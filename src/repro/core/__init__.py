"""HPDR core — the paper's contribution: portable reduction framework.

Layers (paper Fig. 2, bottom-up): device adapters (`adapters`), machine
abstraction (`machine`: GEM/DEM, `context`: CMM, `pipeline`: HDEM), parallel
abstractions (`abstractions`), reduction pipelines (`mgard`, `zfp`,
`huffman`), and the high-level API (`api`).
"""

from . import (  # noqa: F401
    abstractions,
    adapters,
    api,
    bitstream,
    context,
    huffman,
    machine,
    mgard,
    quantize,
    zfp,
)
from .api import Compressed, compress, decompress  # noqa: F401
