"""Parallel abstractions — HPDR §III-A (Fig. 3).

Four abstractions through which reduction algorithms express fine-grain
parallelism.  Table I of the paper maps them onto execution models; we keep
that mapping (Locality/Iterative → GEM, Map&Process/Global → DEM):

  locality        block-wise f over (optionally halo'd) blocks     → GEM
  iterative       sequential f along one axis, batched over vectors → GEM
  map_and_process per-subset functions over a decomposed hierarchy  → DEM
  global_pipeline whole-domain multi-stage program                  → DEM
"""

from __future__ import annotations

import math
from typing import Callable, Sequence

import jax
import jax.numpy as jnp

from .machine import DEMProgram, GEMProgram, run_dem, run_gem

# ---------------------------------------------------------------------------
# block helpers
# ---------------------------------------------------------------------------


def padded_shape(shape: Sequence[int], block_shape: Sequence[int]) -> tuple[int, ...]:
    return tuple(int(math.ceil(d / b)) * b for d, b in zip(shape, block_shape))


def pad_to_blocks(
    data: jax.Array, block_shape: Sequence[int], mode: str = "edge"
) -> jax.Array:
    """Pad every dim of ``data`` up to a multiple of ``block_shape``.

    ``edge`` padding keeps block statistics (max exponent, value range) close
    to the real data so padded blocks stay compressible — same choice as zfp's
    partial-block extension.
    """
    target = padded_shape(data.shape, block_shape)
    pad = [(0, t - d) for d, t in zip(data.shape, target)]
    if all(p == (0, 0) for p in pad):
        return data
    return jnp.pad(data, pad, mode=mode)


def num_blocks(shape: Sequence[int], block_shape: Sequence[int]) -> int:
    return int(
        math.prod(math.ceil(d / b) for d, b in zip(shape, block_shape))
    )


# ---------------------------------------------------------------------------
# 1) Locality abstraction  (paper Fig. 3a)
# ---------------------------------------------------------------------------


def locality(
    data: jax.Array,
    fn: Callable,
    block_shape: Sequence[int],
    *args,
    halo: int = 0,
    name: str = "locality",
):
    """Apply ``fn`` cooperatively to each block of ``block_shape``.

    Blocks are 1:1 mapped to GEM groups (Table I); on TPU the hot-spot ops use
    Pallas kernels with the same block decomposition (BlockSpec), staged in
    VMEM.  ``halo`` extends each block read-only by ``halo`` elements per side
    (algorithms like MGARD's lerp need coarse-node neighbours).
    """
    block_shape = tuple(block_shape)
    if halo == 0:
        padded = pad_to_blocks(data, block_shape)
        prog = GEMProgram(block_shape=block_shape, stages=(fn,), name=name)
        out = run_gem(prog, padded, *args)
        if out.shape == padded.shape:
            return out[tuple(slice(0, d) for d in data.shape)]
        return out
    # Halo path: gather overlapping patches (XLA portable route).
    padded = pad_to_blocks(data, block_shape)
    halo_pad = jnp.pad(padded, [(halo, halo)] * data.ndim, mode="edge")
    counts = tuple(p // b for p, b in zip(padded.shape, block_shape))
    idx_grids = jnp.meshgrid(
        *[jnp.arange(c) * b for c, b in zip(counts, block_shape)], indexing="ij"
    )
    starts = jnp.stack([g.reshape(-1) for g in idx_grids], axis=-1)
    patch_shape = tuple(b + 2 * halo for b in block_shape)

    def one(start):
        patch = jax.lax.dynamic_slice(halo_pad, start, patch_shape)
        return fn(patch, *args)

    out_blocks = jax.vmap(one)(starts)
    if out_blocks.shape[1:] == block_shape:
        from .machine import unblock_view

        full = unblock_view(out_blocks, counts, block_shape)
        return full[tuple(slice(0, d) for d in data.shape)]
    return out_blocks


# ---------------------------------------------------------------------------
# 2) Iterative abstraction  (paper Fig. 3b)
# ---------------------------------------------------------------------------


def iterative(
    data: jax.Array,
    step: Callable,
    init_carry,
    axis: int,
    reverse: bool = False,
):
    """Run ``step`` sequentially along ``axis``, in parallel over all other dims.

    ``step(carry, x_slice) -> (carry, y_slice)`` where ``x_slice`` is the
    data with ``axis`` removed.  This is the B-vectors-per-group pattern:
    the vector (solve) axis is scanned with ``lax.scan``; every other axis is
    a batch lane, so the VPU's lane dimension is filled by construction
    (the paper's B:1 vector→group mapping).
    """
    moved = jnp.moveaxis(data, axis, 0)
    carry, out = jax.lax.scan(step, init_carry, moved, reverse=reverse)
    return carry, jnp.moveaxis(out, 0, axis)


# ---------------------------------------------------------------------------
# 3) Map & Process abstraction  (paper Fig. 3c)
# ---------------------------------------------------------------------------


def map_and_process(
    data: jax.Array,
    subset_ids: jax.Array,
    fns: Sequence[Callable],
):
    """Map elements to subsets, then process each subset with its own fn.

    TPU adaptation: instead of gather/scatter per subset (fast on GPUs, slow
    on TPUs), every ``fn`` is evaluated densely and combined with a subset
    mask — the masked-dense idiom.  For K small (MGARD levels: ≤ ~25) this
    is cheaper than any scatter on the MXU/VPU.
    """
    out = None
    for k, fn in enumerate(fns):
        val = fn(data)
        mask = subset_ids == k
        out = jnp.where(mask, val, out if out is not None else val)
    return out


def map_and_process_param(
    data: jax.Array,
    subset_ids: jax.Array,
    fn: Callable,
    params: jax.Array,
):
    """Map&Process special case: one fn, per-subset parameters.

    ``params[k]`` is gathered per element (K-entry table gather is fine on
    TPU), then ``fn(data, param)`` runs densely — this is how per-level
    quantisation bins are applied without K passes.
    """
    per_elem = params[subset_ids]
    return fn(data, per_elem)


# ---------------------------------------------------------------------------
# 4) Global pipeline abstraction  (paper Fig. 3d)
# ---------------------------------------------------------------------------


def global_pipeline(*stages: Callable, name: str = "global"):
    """Whole-domain multi-stage program with global sync between stages (DEM)."""
    prog = DEMProgram(stages=tuple(stages), name=name)

    def run(data, *args):
        return run_dem(prog, data, *args)

    return run
