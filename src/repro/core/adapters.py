"""Device adapters — HPDR §III-C, adapted to JAX backends.

The paper lowers its two execution models (GEM/DEM) through per-backend
*device adapters* (OpenMP / CUDA / HIP).  In JAX the portable layer is XLA
itself, so our adapters select *how a reduction op is lowered*, not a
hand-written backend:

  * ``xla``              — pure ``jnp`` program; lowers to CPU/GPU/TPU via XLA.
                           This is the portability baseline and the oracle.
  * ``pallas``           — hand-tiled TPU kernels (``pl.pallas_call`` +
                           ``BlockSpec`` VMEM staging).  Target path on TPU.
  * ``pallas_interpret`` — same kernels executed with ``interpret=True``
                           (Python/CPU), used for validation in this container.

The portability contract of the paper carries over: a bitstream produced
under any adapter decodes under any other (tested in
``tests/test_portability.py``).

Ops register one implementation per adapter in ``_REGISTRY``; callers go
through :func:`dispatch` so the choice is a runtime config, exactly like the
paper's pluggable adapters.
"""

from __future__ import annotations

import functools
from typing import Callable

import jax

XLA = "xla"
PALLAS = "pallas"
PALLAS_INTERPRET = "pallas_interpret"
AUTO = "auto"

ADAPTERS = (XLA, PALLAS, PALLAS_INTERPRET)

_REGISTRY: dict[tuple[str, str], Callable] = {}


def register(op: str, adapter: str) -> Callable[[Callable], Callable]:
    """Decorator: register ``fn`` as the implementation of ``op`` under ``adapter``."""
    if adapter not in ADAPTERS:
        raise ValueError(f"unknown adapter {adapter!r}; expected one of {ADAPTERS}")

    def deco(fn: Callable) -> Callable:
        _REGISTRY[(op, adapter)] = fn
        return fn

    return deco


@functools.cache
def default_adapter() -> str:
    """Pick the best adapter for the current platform (paper: 'best processor')."""
    platform = jax.devices()[0].platform
    if platform == "tpu":
        return PALLAS
    # Pallas-interpret is functionally correct everywhere but slow; XLA is the
    # fast portable path on CPU/GPU.
    return XLA


def resolve(adapter: str | None) -> str:
    if adapter is None or adapter == AUTO:
        return default_adapter()
    if adapter not in ADAPTERS:
        raise ValueError(f"unknown adapter {adapter!r}; expected one of {ADAPTERS}")
    return adapter


def dispatch(op: str, adapter: str | None = None) -> Callable:
    """Return the registered implementation of ``op`` for ``adapter``.

    Falls back to the ``xla`` implementation if the requested adapter has no
    specialised kernel for this op (mirrors the paper: not every algorithm
    stage needs a hand-written kernel on every backend).
    """
    a = resolve(adapter)
    impl = _REGISTRY.get((op, a))
    if impl is None:
        impl = _REGISTRY.get((op, XLA))
    if impl is None:
        raise KeyError(f"op {op!r} has no implementation (adapter={a!r})")
    return impl


def registered_ops() -> dict[tuple[str, str], Callable]:
    return dict(_REGISTRY)
