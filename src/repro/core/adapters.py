"""Device adapters — HPDR §III-C, adapted to JAX backends.

The paper lowers its two execution models (GEM/DEM) through per-backend
*device adapters* (OpenMP / CUDA / HIP).  In JAX the portable layer is XLA
itself, so our adapters select *how a reduction op is lowered*, not a
hand-written backend:

  * ``xla``              — pure ``jnp`` program; lowers to CPU/GPU/TPU via XLA.
                           This is the portability baseline and the oracle.
  * ``pallas``           — hand-tiled TPU kernels (``pl.pallas_call`` +
                           ``BlockSpec`` VMEM staging).  Target path on TPU.
  * ``pallas_interpret`` — same kernels executed with ``interpret=True``
                           (Python/CPU), used for validation in this container.

The portability contract of the paper carries over: a bitstream produced
under any adapter decodes under any other (tested in
``tests/test_portability.py``).

Ops register one implementation per adapter in ``_REGISTRY``; callers go
through :func:`dispatch` so the choice is a runtime config, exactly like the
paper's pluggable adapters.
"""

from __future__ import annotations

import functools
from typing import Callable

import jax

XLA = "xla"
PALLAS = "pallas"
PALLAS_INTERPRET = "pallas_interpret"
AUTO = "auto"

ADAPTERS = (XLA, PALLAS, PALLAS_INTERPRET)

_REGISTRY: dict[tuple[str, str], Callable] = {}


def register(op: str, adapter: str) -> Callable[[Callable], Callable]:
    """Decorator: register ``fn`` as the implementation of ``op`` under ``adapter``."""
    if adapter not in ADAPTERS:
        raise ValueError(f"unknown adapter {adapter!r}; expected one of {ADAPTERS}")

    def deco(fn: Callable) -> Callable:
        _REGISTRY[(op, adapter)] = fn
        return fn

    return deco


@functools.cache
def default_adapter() -> str:
    """Pick the best adapter for the current platform (paper: 'best processor')."""
    platform = jax.devices()[0].platform
    if platform == "tpu":
        return PALLAS
    # Pallas-interpret is functionally correct everywhere but slow; XLA is the
    # fast portable path on CPU/GPU.
    return XLA


@functools.cache
def available_backends() -> tuple[str, ...]:
    """Adapters that can actually execute on the current platform.

    ``pallas`` (compiled, ``interpret=False``) needs a Mosaic/Triton lowering
    and is only runnable on TPU/GPU; ``xla`` and ``pallas_interpret`` run
    everywhere.  This is the capability probe plan building uses to bind a
    spec's ``backend`` before any kernel is traced.
    """
    platform = jax.devices()[0].platform
    if platform in ("tpu", "gpu", "cuda", "rocm"):
        return (XLA, PALLAS, PALLAS_INTERPRET)
    return (XLA, PALLAS_INTERPRET)


def resolve_backend(backend: str | None) -> str:
    """Resolve a spec-level backend request to a concrete, runnable adapter.

    ``auto``/``None`` picks the platform default; an explicit request is
    validated against :func:`available_backends` so an unsupported backend
    fails loudly at plan time instead of deep inside a kernel trace.
    """
    if backend is None or backend == AUTO:
        return default_adapter()
    if backend not in ADAPTERS:
        raise ValueError(
            f"unknown backend {backend!r}; expected one of {(AUTO,) + ADAPTERS}"
        )
    if backend not in available_backends():
        raise ValueError(
            f"backend {backend!r} is not runnable on this platform "
            f"(available: {available_backends()})"
        )
    return backend


@functools.cache
def supports_donation() -> bool:
    """True where XLA implements input-output buffer aliasing (TPU/GPU)."""
    return jax.devices()[0].platform in ("tpu", "gpu", "cuda", "rocm")


def donating_jit(fn: Callable, *, donate_argnums: tuple[int, ...] = (), **jit_kwargs):
    """``jax.jit`` that donates ``donate_argnums`` only where donation exists.

    Plans route persistent workspace buffers through this so reuse is true
    in-place recycling on TPU/GPU while CPU (donation unimplemented) avoids
    a per-call "donated buffers were not usable" warning.
    """
    if supports_donation() and donate_argnums:
        return jax.jit(fn, donate_argnums=donate_argnums, **jit_kwargs)
    return jax.jit(fn, **jit_kwargs)


def resolve(adapter: str | None) -> str:
    if adapter is None or adapter == AUTO:
        return default_adapter()
    if adapter not in ADAPTERS:
        raise ValueError(f"unknown adapter {adapter!r}; expected one of {ADAPTERS}")
    return adapter


def dispatch(op: str, adapter: str | None = None) -> Callable:
    """Return the registered implementation of ``op`` for ``adapter``.

    Falls back to the ``xla`` implementation if the requested adapter has no
    specialised kernel for this op (mirrors the paper: not every algorithm
    stage needs a hand-written kernel on every backend).
    """
    a = resolve(adapter)
    impl = _REGISTRY.get((op, a))
    if impl is None:
        impl = _REGISTRY.get((op, XLA))
    if impl is None:
        raise KeyError(f"op {op!r} has no implementation (adapter={a!r})")
    return impl


def registered_ops() -> dict[tuple[str, str], Callable]:
    return dict(_REGISTRY)
