"""Public HPDR compression API — codec registry + plan architecture.

The paper's core claim (§III-B) is that per-call context management — plans,
workspace allocations, compiled executables — dominates reduction cost at
scale.  This layer therefore separates the three phases every call used to
re-run:

  1. **Specify** — :class:`ReductionSpec` describes a reduction: method,
     shape, dtype, and the method's parameters.  It is hashable; its
     ``key()`` is the CMM context key.
  2. **Plan** — :func:`get_plan` resolves the spec through the codec registry
     (:mod:`repro.core.codecs`) and stores the resulting
     :class:`ReductionPlan` — jitted executables with static arguments bound,
     plus persistent workspace buffers (level maps, permutations) — in the
     global CMM.  The second call with an identical spec is a cache *hit*
     with a non-``None`` plan: nothing is rebuilt.
  3. **Execute** — :func:`encode`/:func:`decode` run the planned executables
     on data and produce/consume :class:`Compressed` containers (the v2 byte
     format with per-section offsets and a payload checksum; v1 streams are
     still read — see :mod:`repro.core.container`).

``compress``/``decompress`` remain as thin back-compat wrappers that build a
spec from keyword arguments and dispatch through the registry — there is no
method if/elif chain anywhere.  Higher-level entry points:

  * :func:`compress_pytree` / :func:`decompress_pytree` — batch compression
    of parameter/KV pytrees with per-leaf method selection;
  * :func:`compress_leaf` / :func:`decompress_leaf` — single-tensor policy
    helpers (dtype casting, ZFP 4³ re-blocking, lossless byte view) shared by
    the checkpoint manager and the serving engine;
  * :class:`CompressorStream` — chunked streaming compression built on the
    HDEM :class:`~repro.core.pipeline.ChunkedPipeline`, with its own framed
    byte format for multi-chunk streams.

Methods
-------
  mgard          error-bounded lossy (float arrays, 1-4D)
  zfp            fixed-rate lossy (float arrays, 1-4D)
  huffman        lossless entropy coding of integer key arrays
  huffman-bytes  lossless byte-wise entropy coding of arbitrary arrays
                 (the LZ-class baseline analogue in the paper's comparisons)
"""

from __future__ import annotations

import dataclasses
import io
import json
import math
from typing import Any, Callable, Sequence

import jax
import jax.numpy as jnp
import numpy as np

from . import adapters
from . import pipeline as pl
from .codecs import available_methods, get_codec
from .codecs.base import Codec, ReductionPlan, ReductionSpec  # noqa: F401
from .container import Compressed, _jsonable  # noqa: F401
from .context import GLOBAL_CMM, ReductionContext
from .stages.base import CallEnv, Stage, StageGraph, TransferStats  # noqa: F401

METHODS = ("mgard", "zfp", "huffman", "huffman-bytes")

_STREAM_MAGIC = b"HPDS"
_STREAM_VERSION = 1


# ---------------------------------------------------------------------------
# spec / plan resolution (CMM-backed)
# ---------------------------------------------------------------------------


def make_spec(data: Any, method: str, **params: Any) -> ReductionSpec:
    """Build the canonical spec for compressing ``data`` with ``method``.

    Parameters irrelevant to the codec are dropped and omitted ones filled
    with the codec's defaults, so equivalent calls produce identical specs
    (and hit the same CMM entry).  ``backend=`` selects the device adapter
    the plan binds (``auto`` resolves to the platform default).
    """
    codec = get_codec(method)
    # NB: read dtype without materialising data — np.asarray on a device
    # array would force a full D2H copy just to inspect it.
    dtype = getattr(data, "dtype", None)
    if dtype is None:
        dtype = np.asarray(data).dtype
    return codec.make_spec(np.shape(data), dtype, **params)


def _build_context(key, codec: Codec, spec: ReductionSpec) -> ReductionContext:
    plan = codec.plan(spec)
    # Mirror the plan's persistent buffers into the context so CMM byte
    # accounting (ContextCache.nbytes/stats) sees them.
    return ReductionContext(key=key, plan=plan, buffers=plan.workspace)


def get_plan(spec: ReductionSpec) -> ReductionPlan:
    """CMM-cached plan for ``spec``; built by the codec on the first miss."""
    codec = get_codec(spec.method)
    key = spec.key()
    ctx = GLOBAL_CMM.get_or_create(key, lambda: _build_context(key, codec, spec))
    if ctx.plan is None:  # entry predating the plan architecture
        ctx.plan = codec.plan(spec)
        ctx.buffers = ctx.plan.workspace
    return ctx.plan


def encode(spec: ReductionSpec, data: jax.Array | np.ndarray) -> Compressed:
    """Compress ``data`` according to ``spec`` (plan reused via the CMM)."""
    return get_codec(spec.method).encode(get_plan(spec), data)


def encode_profiled(
    spec: ReductionSpec, data: jax.Array | np.ndarray
) -> tuple[Compressed, dict[str, float], "TransferStats"]:
    """Encode with per-stage observability (the ``bench stages`` hook).

    Returns ``(container, stage_seconds, transfers)``: wall time per
    pipeline stage (device segments blocked on for honest timings) and the
    run's host↔device transfer bytes — the quantities
    ``scripts/check.sh bench stages`` tracks against the paper's
    2.3%-transfer claim.
    """
    codec = get_codec(spec.method)
    plan = get_plan(spec)
    env = CallEnv(plan)
    profile: dict[str, float] = {}
    c = codec.encode(plan, data, env=env, profile=profile)
    return c, profile, env.transfers


def decode(c: Compressed, backend: str | None = None) -> jax.Array:
    """Decompress a container (the decode-side plan is CMM-cached too).

    Any backend decodes any stream (portability contract); ``backend``
    overrides the decode-side adapter, defaulting to the platform's best.
    Streams carrying a decode chunk index run the compiled inverse pipeline
    — one fused device dispatch, H2D = compressed bytes + metadata; older
    streams fall back to the host-orchestrated decoder transparently.
    """
    codec = get_codec(c.method)
    spec = codec.decode_spec(c)
    if backend is not None:
        spec = dataclasses.replace(spec, backend=adapters.resolve_backend(backend))
    return codec.decode(get_plan(spec), c)


def decode_profiled(
    c: Compressed, backend: str | None = None
) -> tuple[jax.Array, dict[str, float], "TransferStats"]:
    """Decode with per-stage observability (the ``bench stages`` decode hook).

    Returns ``(array, stage_seconds, transfers)``: wall time per inverse
    pipeline step (host prepares + the fused inverse segments, blocked on
    for honest timings) and the run's transfer bytes — on the pipeline
    path H2D is exactly the compressed sections plus the metadata-scale
    decode operands, never a raw-array-sized staging transfer.
    """
    codec = get_codec(c.method)
    spec = codec.decode_spec(c)
    if backend is not None:
        spec = dataclasses.replace(spec, backend=adapters.resolve_backend(backend))
    plan = get_plan(spec)
    env = CallEnv(plan)
    profile: dict[str, float] = {}
    out = codec.decode(plan, c, env=env, profile=profile)
    return out, profile, env.transfers


# ---------------------------------------------------------------------------
# compress / decompress — thin wrappers over the registry
# ---------------------------------------------------------------------------


def compress(
    data: jax.Array | np.ndarray,
    method: str = "mgard",
    *,
    error_bound: float = 1e-2,
    relative: bool = True,
    rate: int = 16,
    dict_size: int = 4096,
    backend: str | None = None,
    adapter: str | None = None,
) -> Compressed:
    """Compress ``data`` with the selected pipeline.

    ``error_bound`` is relative to the value range when ``relative=True``
    (the paper's evaluation convention).  This is a convenience wrapper: it
    builds a :class:`ReductionSpec` and dispatches through the codec
    registry, so repeated same-shaped calls reuse one cached plan.
    ``backend`` (alias: the legacy ``adapter`` keyword) binds the plan's
    device adapter; default ``auto``.
    """
    data = jnp.asarray(data)
    spec = make_spec(
        data, method,
        error_bound=error_bound, relative=relative, rate=rate,
        dict_size=dict_size, backend=backend or adapter or adapters.AUTO,
    )
    return encode(spec, data)


def decompress(c: Compressed) -> jax.Array:
    return decode(c)


# ---------------------------------------------------------------------------
# leaf policy helpers (shared by checkpoint + serving layers)
# ---------------------------------------------------------------------------


def as_blocked_3d(flat: np.ndarray) -> np.ndarray:
    """Flat → (n, 32, 32) (padded to 1024-multiples): ZFP blocks become 4³ so
    the per-block emax header is amortised over 64 values instead of 4."""
    x = np.asarray(flat).reshape(-1)
    pad = (-x.size) % 1024
    if pad:
        x = np.pad(x, (0, pad), mode="edge")
    return x.reshape(-1, 32, 32)


_HUFFMAN_MAX_ALPHABET = 1 << 16


def leaf_policy(
    arr: np.ndarray, method: str, params: dict | None = None
) -> tuple[np.ndarray, str, dict]:
    """Shared shape/dtype policy: ``(array, method, params)`` to compress.

    bfloat16 is cast to float32 for the lossy codecs, ZFP inputs are
    re-blocked to 4³-friendly (n, 32, 32), >4-D or 0-D MGARD inputs are
    flattened, ``huffman`` keeps genuine small-alphabet integer keys on the
    integer-key codec (data-dependent dictionary, tighter streams than the
    byte view), and anything else becomes a ``huffman-bytes`` byte view.
    Split out of :func:`compress_leaf` so the execution engine can bucket
    leaves by their *post-policy* spec before fanning out.
    """
    arr = np.asarray(arr)
    params = dict(params or {})
    if method in ("zfp", "mgard"):
        x = arr
        if x.dtype != np.float32 and x.dtype.kind in ("f", "V"):
            x = x.astype(np.float32)
        if method == "zfp":
            x = as_blocked_3d(x)
        elif x.ndim > 4 or x.ndim == 0:
            x = x.reshape(-1)
        return x, method, params
    if (
        method == "huffman"
        and arr.dtype.kind in ("i", "u")
        and arr.size
        and int(arr.min()) >= 0
        and int(arr.max()) < _HUFFMAN_MAX_ALPHABET
    ):
        return arr, "huffman", params
    return np.ascontiguousarray(arr).view(np.uint8), "huffman-bytes", {}


def finish_leaf_meta(c: Compressed, arr: np.ndarray) -> Compressed:
    """Record the pre-policy dtype/shape for :func:`decompress_leaf`."""
    c.meta["orig_dtype"] = str(arr.dtype)
    c.meta["orig_shape"] = list(arr.shape)
    return c


def compress_leaf(arr: np.ndarray, method: str, **params: Any) -> Compressed:
    """Compress one tensor with the shared shape/dtype policy.

    The original dtype/shape ride along in ``meta`` for
    :func:`decompress_leaf`; see :func:`leaf_policy` for the policy itself.
    """
    arr = np.asarray(arr)
    x, pol_method, pol_params = leaf_policy(arr, method, params)
    c = compress(jnp.asarray(x), pol_method, **pol_params)
    return finish_leaf_meta(c, arr)


def restore_leaf(out: np.ndarray, c: Compressed) -> np.ndarray:
    """Undo :func:`leaf_policy` on a decoded array: original dtype + shape.

    Split out of :func:`decompress_leaf` so the execution engine's stacked
    decode path can restore per-leaf rows it decoded in one batch.
    """
    out = np.asarray(out)
    dtype = np.dtype(c.meta["orig_dtype"])
    shape = tuple(c.meta["orig_shape"])
    n = math.prod(shape) if shape else 1
    if c.method == "huffman-bytes":
        out = out.view(dtype) if out.dtype == np.uint8 else out.astype(dtype)
        return out.reshape(shape) if n == out.size else out
    return out.reshape(-1)[:n].astype(dtype).reshape(shape)


def decompress_leaf(c: Compressed) -> np.ndarray:
    """Inverse of :func:`compress_leaf`: restores original dtype and shape."""
    return restore_leaf(np.asarray(decode(c)), c)


# ---------------------------------------------------------------------------
# pytree / batch entry points
# ---------------------------------------------------------------------------


def _path_key(path, sep: str) -> str:
    return sep.join(str(getattr(e, "key", getattr(e, "idx", ""))) for e in path)


def default_select(key: str, arr: np.ndarray) -> tuple[str, dict] | None:
    """Default per-leaf policy: ZFP for sizable float tensors, raw otherwise."""
    del key
    if arr.dtype.kind == "f" and arr.size >= 4096:
        return "zfp", {"rate": 16}
    return None


def compress_pytree(
    tree: Any,
    select: Callable[[str, np.ndarray], tuple[str, dict] | None] | None = None,
    *,
    sep: str = "/",
    engine: Any = None,
) -> tuple[dict[str, Any], dict]:
    """Compress every selected leaf of a pytree, sharded across devices.

    ``select(key, arr)`` returns ``(method, params)`` to compress a leaf or
    ``None`` to pass it through raw.  Returns ``(flat, stats)`` where
    ``flat`` maps path keys to :class:`Compressed` or raw arrays — identical
    shapes/dtypes restore via :func:`decompress_pytree`.

    Execution runs on an :class:`~repro.core.engine.ExecutionEngine`
    (default: the process-wide engine over every local device on one
    ``data`` axis): leaves are bucketed by post-policy spec — one plan build
    per shape-dtype bucket, every further leaf a CMM hit — and buckets fan
    out over the mesh's ``data``-axis devices.
    """
    from . import engine as engine_mod  # runtime import: peer layer

    eng = engine if engine is not None else engine_mod.default_engine()
    return eng.compress_pytree(tree, select, sep=sep)


def decompress_pytree(
    comp: dict[str, Any], like: Any, *, sep: str = "/", engine: Any = None
) -> Any:
    """Rebuild the pytree ``like`` from :func:`compress_pytree` output."""
    from . import engine as engine_mod

    eng = engine if engine is not None else engine_mod.default_engine()
    return eng.decompress_pytree(comp, like, sep=sep)


# ---------------------------------------------------------------------------
# chunked streaming (HDEM pipeline)
# ---------------------------------------------------------------------------


class CompressorStream:
    """Chunked streaming compression on the HDEM double-buffered pipeline.

    Chunks share a spec whenever their shapes agree, so every chunk after
    the first hits the CMM plan cache — the chunk-pipelined analogue of the
    paper's per-call context reuse.  ``to_bytes``/``from_bytes`` frame the
    per-chunk containers with an offset index so chunks can be located (and
    fetched lazily) independently.  Passing ``engine=`` schedules chunks
    round-robin across the engine's ``data``-axis devices.
    """

    def __init__(
        self,
        method: str = "zfp",
        mode: str = "adaptive",
        *,
        c_init_elems: int = 1 << 20,
        c_fixed_elems: int = 8 << 20,
        c_limit_elems: int = 1 << 28,
        phi=None,
        theta=None,
        engine: Any = None,
        backend: str | None = None,
        **params: Any,
    ):
        self.method = method
        self.params = params
        if backend is None and engine is not None:
            backend = engine.backend
        self.backend = backend or adapters.AUTO
        self.pipeline = pl.ChunkedPipeline(
            self._encode_chunk,
            mode=mode,
            c_init_elems=c_init_elems,
            c_fixed_elems=c_fixed_elems,
            c_limit_elems=c_limit_elems,
            phi=phi,
            theta=theta,
            devices=engine.devices if engine is not None else None,
        )

    def _encode_chunk(self, chunk: jax.Array) -> Compressed:
        return encode(
            make_spec(chunk, self.method, backend=self.backend, **self.params),
            chunk,
        )

    def compress(self, data: np.ndarray) -> pl.ChunkedResult:
        return self.pipeline.run(np.asarray(data))

    @staticmethod
    def decompress(result: pl.ChunkedResult) -> np.ndarray:
        return pl.decompress_chunked(result, decode)

    # -- framed multi-chunk byte format -------------------------------------

    @staticmethod
    def to_bytes(result: pl.ChunkedResult) -> bytes:
        blobs = [c.to_bytes() for c in result.chunks]
        offsets = []
        off = 0
        for b in blobs:
            offsets.append(off)
            off += len(b)
        header = {
            "axis": result.axis,
            "shape": list(result.shape),
            "boundaries": list(result.boundaries),
            "chunks": [
                {"offset": o, "nbytes": len(b)} for o, b in zip(offsets, blobs)
            ],
        }
        hbytes = json.dumps(header).encode()
        buf = io.BytesIO()
        buf.write(_STREAM_MAGIC)
        buf.write(np.uint32(_STREAM_VERSION).tobytes())
        buf.write(np.uint64(len(hbytes)).tobytes())
        buf.write(hbytes)
        for b in blobs:
            buf.write(b)
        return buf.getvalue()

    @staticmethod
    def from_bytes(raw: bytes, lazy: bool = True) -> pl.ChunkedResult:
        """Parse a framed stream; chunks are fetched lazily by default.

        Framing and every chunk's byte range are validated eagerly (a
        truncated stream raises here), but the per-chunk containers are only
        materialised on first access via the v2 per-section offsets — a
        reader restoring a prefix never touches the tail's bytes
        (progressive restore while the tail is still in flight).
        ``lazy=False`` restores the historical eager behaviour.
        """
        raw = bytes(raw)
        if len(raw) < 16 or raw[:4] != _STREAM_MAGIC:
            raise ValueError("not an HPDR chunked stream")
        version = int(np.frombuffer(raw[4:8], np.uint32)[0])
        if version != _STREAM_VERSION:
            raise ValueError(f"unsupported HPDR stream version {version}")
        hlen = int(np.frombuffer(raw[8:16], np.uint64)[0])
        if len(raw) < 16 + hlen:
            raise ValueError("truncated HPDR chunked stream")
        header = json.loads(raw[16 : 16 + hlen].decode())
        base = 16 + hlen
        ranges = []
        for entry in header["chunks"]:
            lo = base + entry["offset"]
            hi = lo + entry["nbytes"]
            if hi > len(raw):
                raise ValueError("truncated HPDR chunked stream")
            ranges.append((lo, hi))
        chunks: Sequence = LazyChunks(raw, ranges)
        if not lazy:
            chunks = list(chunks)
        return pl.ChunkedResult(
            chunks=chunks,
            boundaries=list(header["boundaries"]),
            axis=int(header["axis"]),
            shape=tuple(header["shape"]),
        )


class LazyChunks(Sequence):
    """Sequence of per-chunk containers, parsed on first access.

    Backed by the framed stream's byte buffer and the header's offset
    index; ``materialized`` counts how many chunks have actually been
    decoded from bytes (the observable for laziness tests).
    """

    def __init__(self, raw: bytes, ranges: list[tuple[int, int]]):
        self._raw = raw
        self._ranges = ranges
        self._cache: list[Compressed | None] = [None] * len(ranges)

    def __len__(self) -> int:
        return len(self._ranges)

    def __getitem__(self, i):
        if isinstance(i, slice):
            return [self[j] for j in range(*i.indices(len(self)))]
        if i < 0:
            i += len(self)
        if not 0 <= i < len(self):
            raise IndexError(i)
        if self._cache[i] is None:
            lo, hi = self._ranges[i]
            self._cache[i] = Compressed.from_bytes(self._raw[lo:hi])
        return self._cache[i]

    @property
    def materialized(self) -> int:
        return sum(c is not None for c in self._cache)
