"""Public HPDR compression API — codec registry + plan architecture.

The paper's core claim (§III-B) is that per-call context management — plans,
workspace allocations, compiled executables — dominates reduction cost at
scale.  This layer therefore separates the three phases every call used to
re-run:

  1. **Specify** — :class:`ReductionSpec` describes a reduction: method,
     shape, dtype, and the method's parameters.  It is hashable; its
     ``key()`` is the CMM context key.
  2. **Plan** — :func:`get_plan` resolves the spec through the codec registry
     (:mod:`repro.core.codecs`) and stores the resulting
     :class:`ReductionPlan` — jitted executables with static arguments bound,
     plus persistent workspace buffers (level maps, permutations) — in the
     global CMM.  The second call with an identical spec is a cache *hit*
     with a non-``None`` plan: nothing is rebuilt.
  3. **Execute** — :func:`encode`/:func:`decode` run the planned executables
     on data and produce/consume :class:`Compressed` containers (the v2 byte
     format with per-section offsets and a payload checksum; v1 streams are
     still read — see :mod:`repro.core.container`).

``compress``/``decompress`` remain as thin back-compat wrappers that build a
spec from keyword arguments and dispatch through the registry — there is no
method if/elif chain anywhere.  Higher-level entry points:

  * :func:`compress_pytree` / :func:`decompress_pytree` — batch compression
    of parameter/KV pytrees with per-leaf method selection;
  * :func:`compress_leaf` / :func:`decompress_leaf` — single-tensor policy
    helpers (dtype casting, ZFP 4³ re-blocking, lossless byte view) shared by
    the checkpoint manager and the serving engine;
  * :class:`CompressorStream` — chunked streaming compression built on the
    HDEM :class:`~repro.core.pipeline.ChunkedPipeline`, with its own framed
    byte format for multi-chunk streams.

Methods
-------
  mgard              error-bounded lossy (float arrays, 1-4D)
  mgard-progressive  error-bounded lossy refactored into precision tiers:
                     separately addressable container components, prefix
                     retrieval + incremental refinement
                     (:mod:`repro.core.progressive`)
  zfp                fixed-rate lossy (float arrays, 1-4D)
  huffman            lossless entropy coding of integer key arrays
  huffman-bytes      lossless byte-wise entropy coding of arbitrary arrays
                     (the LZ-class baseline analogue in the paper's
                     comparisons)
"""

from __future__ import annotations

import dataclasses
import io
import json
import math
import threading
from typing import Any, Callable, Sequence

import jax
import jax.numpy as jnp
import numpy as np

from . import adapters
from . import pipeline as pl
from .codecs import available_methods, get_codec
from .codecs.base import Codec, ReductionPlan, ReductionSpec  # noqa: F401
from .container import Compressed, ContainerError, _jsonable  # noqa: F401
from .context import GLOBAL_CMM, ReductionContext
from .stages.base import CallEnv, Stage, StageGraph, TransferStats  # noqa: F401

METHODS = ("mgard", "mgard-progressive", "zfp", "huffman", "huffman-bytes")

_STREAM_MAGIC = b"HPDS"
_STREAM_VERSION = 1


# ---------------------------------------------------------------------------
# spec / plan resolution (CMM-backed)
# ---------------------------------------------------------------------------


def make_spec(data: Any, method: str, **params: Any) -> ReductionSpec:
    """Build the canonical spec for compressing ``data`` with ``method``.

    Parameters irrelevant to the codec are dropped and omitted ones filled
    with the codec's defaults, so equivalent calls produce identical specs
    (and hit the same CMM entry).  ``backend=`` selects the device adapter
    the plan binds (``auto`` resolves to the platform default).
    """
    codec = get_codec(method)
    # NB: read dtype without materialising data — np.asarray on a device
    # array would force a full D2H copy just to inspect it.
    dtype = getattr(data, "dtype", None)
    if dtype is None:
        dtype = np.asarray(data).dtype
    return codec.make_spec(np.shape(data), dtype, **params)


def _build_context(key, codec: Codec, spec: ReductionSpec) -> ReductionContext:
    plan = codec.plan(spec)
    # Mirror the plan's persistent buffers into the context so CMM byte
    # accounting (ContextCache.nbytes/stats) sees them.
    return ReductionContext(key=key, plan=plan, buffers=plan.workspace)


def get_plan(spec: ReductionSpec) -> ReductionPlan:
    """CMM-cached plan for ``spec``; built by the codec on the first miss."""
    codec = get_codec(spec.method)
    key = spec.key()
    ctx = GLOBAL_CMM.get_or_create(key, lambda: _build_context(key, codec, spec))
    if ctx.plan is None:  # entry predating the plan architecture
        ctx.plan = codec.plan(spec)
        ctx.buffers = ctx.plan.workspace
    return ctx.plan


def encode(spec: ReductionSpec, data: jax.Array | np.ndarray) -> Compressed:
    """Compress ``data`` according to ``spec`` (plan reused via the CMM)."""
    return get_codec(spec.method).encode(get_plan(spec), data)


def encode_profiled(
    spec: ReductionSpec, data: jax.Array | np.ndarray
) -> tuple[Compressed, dict[str, float], "TransferStats"]:
    """Encode with per-stage observability (the ``bench stages`` hook).

    Returns ``(container, stage_seconds, transfers)``: wall time per
    pipeline stage (device segments blocked on for honest timings) and the
    run's host↔device transfer bytes — the quantities
    ``scripts/check.sh bench stages`` tracks against the paper's
    2.3%-transfer claim.
    """
    codec = get_codec(spec.method)
    plan = get_plan(spec)
    env = CallEnv(plan)
    profile: dict[str, float] = {}
    c = codec.encode(plan, data, env=env, profile=profile)
    return c, profile, env.transfers


def decode(c: Compressed, backend: str | None = None) -> jax.Array:
    """Decompress a container (the decode-side plan is CMM-cached too).

    Any backend decodes any stream (portability contract); ``backend``
    overrides the decode-side adapter, defaulting to the platform's best.
    Streams carrying a decode chunk index run the compiled inverse pipeline
    — one fused device dispatch, H2D = compressed bytes + metadata; older
    streams fall back to the host-orchestrated decoder transparently.
    """
    codec = get_codec(c.method)
    spec = codec.decode_spec(c)
    if backend is not None:
        spec = dataclasses.replace(spec, backend=adapters.resolve_backend(backend))
    return codec.decode(get_plan(spec), c)


def decode_profiled(
    c: Compressed, backend: str | None = None
) -> tuple[jax.Array, dict[str, float], "TransferStats"]:
    """Decode with per-stage observability (the ``bench stages`` decode hook).

    Returns ``(array, stage_seconds, transfers)``: wall time per inverse
    pipeline step (host prepares + the fused inverse segments, blocked on
    for honest timings) and the run's transfer bytes — on the pipeline
    path H2D is exactly the compressed sections plus the metadata-scale
    decode operands, never a raw-array-sized staging transfer.
    """
    codec = get_codec(c.method)
    spec = codec.decode_spec(c)
    if backend is not None:
        spec = dataclasses.replace(spec, backend=adapters.resolve_backend(backend))
    plan = get_plan(spec)
    env = CallEnv(plan)
    profile: dict[str, float] = {}
    out = codec.decode(plan, c, env=env, profile=profile)
    return out, profile, env.transfers


# ---------------------------------------------------------------------------
# compress / decompress — thin wrappers over the registry
# ---------------------------------------------------------------------------


def compress(
    data: jax.Array | np.ndarray,
    method: str = "mgard",
    *,
    error_bound: float = 1e-2,
    relative: bool = True,
    rate: int = 16,
    dict_size: int = 4096,
    tiers: int = 3,
    tier_ratio: float = 8.0,
    backend: str | None = None,
    adapter: str | None = None,
) -> Compressed:
    """Compress ``data`` with the selected pipeline.

    ``error_bound`` is relative to the value range when ``relative=True``
    (the paper's evaluation convention).  This is a convenience wrapper: it
    builds a :class:`ReductionSpec` and dispatches through the codec
    registry, so repeated same-shaped calls reuse one cached plan.
    ``backend`` (alias: the legacy ``adapter`` keyword) binds the plan's
    device adapter; default ``auto``.
    """
    data = jnp.asarray(data)
    spec = make_spec(
        data, method,
        error_bound=error_bound, relative=relative, rate=rate,
        dict_size=dict_size, tiers=tiers, tier_ratio=tier_ratio,
        backend=backend or adapter or adapters.AUTO,
    )
    return encode(spec, data)


def decompress(c: Compressed) -> jax.Array:
    return decode(c)


# ---------------------------------------------------------------------------
# leaf policy helpers (shared by checkpoint + serving layers)
# ---------------------------------------------------------------------------


def as_blocked_3d(flat: np.ndarray) -> np.ndarray:
    """Flat → (n, 32, 32) (padded to 1024-multiples): ZFP blocks become 4³ so
    the per-block emax header is amortised over 64 values instead of 4."""
    x = np.asarray(flat).reshape(-1)
    pad = (-x.size) % 1024
    if pad:
        x = np.pad(x, (0, pad), mode="edge")
    return x.reshape(-1, 32, 32)


_HUFFMAN_MAX_ALPHABET = 1 << 16


def leaf_policy(
    arr: np.ndarray, method: str, params: dict | None = None
) -> tuple[np.ndarray, str, dict]:
    """Shared shape/dtype policy: ``(array, method, params)`` to compress.

    bfloat16 is cast to float32 for the lossy codecs, ZFP inputs are
    re-blocked to 4³-friendly (n, 32, 32), >4-D or 0-D MGARD inputs are
    flattened, ``huffman`` keeps genuine small-alphabet integer keys on the
    integer-key codec (data-dependent dictionary, tighter streams than the
    byte view), and anything else becomes a ``huffman-bytes`` byte view.
    Split out of :func:`compress_leaf` so the execution engine can bucket
    leaves by their *post-policy* spec before fanning out.
    """
    arr = np.asarray(arr)
    params = dict(params or {})
    if method in ("zfp", "mgard", "mgard-progressive"):
        x = arr
        if x.dtype != np.float32 and x.dtype.kind in ("f", "V"):
            x = x.astype(np.float32)
        if method == "zfp":
            x = as_blocked_3d(x)
        elif x.ndim > 4 or x.ndim == 0:
            x = x.reshape(-1)
        return x, method, params
    if (
        method == "huffman"
        and arr.dtype.kind in ("i", "u")
        and arr.size
        and int(arr.min()) >= 0
        and int(arr.max()) < _HUFFMAN_MAX_ALPHABET
    ):
        return arr, "huffman", params
    return np.ascontiguousarray(arr).view(np.uint8), "huffman-bytes", {}


def finish_leaf_meta(c: Compressed, arr: np.ndarray) -> Compressed:
    """Record the pre-policy dtype/shape for :func:`decompress_leaf`."""
    c.meta["orig_dtype"] = str(arr.dtype)
    c.meta["orig_shape"] = list(arr.shape)
    return c


def compress_leaf(arr: np.ndarray, method: str, **params: Any) -> Compressed:
    """Compress one tensor with the shared shape/dtype policy.

    The original dtype/shape ride along in ``meta`` for
    :func:`decompress_leaf`; see :func:`leaf_policy` for the policy itself.
    """
    arr = np.asarray(arr)
    x, pol_method, pol_params = leaf_policy(arr, method, params)
    c = compress(jnp.asarray(x), pol_method, **pol_params)
    return finish_leaf_meta(c, arr)


def restore_leaf(out: np.ndarray, c: Compressed) -> np.ndarray:
    """Undo :func:`leaf_policy` on a decoded array: original dtype + shape.

    Split out of :func:`decompress_leaf` so the execution engine's stacked
    decode path can restore per-leaf rows it decoded in one batch.
    """
    out = np.asarray(out)
    dtype = np.dtype(c.meta["orig_dtype"])
    shape = tuple(c.meta["orig_shape"])
    n = math.prod(shape) if shape else 1
    if c.method == "huffman-bytes":
        out = out.view(dtype) if out.dtype == np.uint8 else out.astype(dtype)
        return out.reshape(shape) if n == out.size else out
    return out.reshape(-1)[:n].astype(dtype).reshape(shape)


def decompress_leaf(c: Compressed) -> np.ndarray:
    """Inverse of :func:`compress_leaf`: restores original dtype and shape."""
    return restore_leaf(np.asarray(decode(c)), c)


# ---------------------------------------------------------------------------
# pytree / batch entry points
# ---------------------------------------------------------------------------


def _path_key(path, sep: str) -> str:
    return sep.join(str(getattr(e, "key", getattr(e, "idx", ""))) for e in path)


def default_select(key: str, arr: np.ndarray) -> tuple[str, dict] | None:
    """Default per-leaf policy: ZFP for sizable float tensors, raw otherwise."""
    del key
    if arr.dtype.kind == "f" and arr.size >= 4096:
        return "zfp", {"rate": 16}
    return None


def compress_pytree(
    tree: Any,
    select: Callable[[str, np.ndarray], tuple[str, dict] | None] | None = None,
    *,
    sep: str = "/",
    engine: Any = None,
) -> tuple[dict[str, Any], dict]:
    """Compress every selected leaf of a pytree, sharded across devices.

    ``select(key, arr)`` returns ``(method, params)`` to compress a leaf or
    ``None`` to pass it through raw.  Returns ``(flat, stats)`` where
    ``flat`` maps path keys to :class:`Compressed` or raw arrays — identical
    shapes/dtypes restore via :func:`decompress_pytree`.

    Execution runs on an :class:`~repro.core.engine.ExecutionEngine`
    (default: the process-wide engine over every local device on one
    ``data`` axis): leaves are bucketed by post-policy spec — one plan build
    per shape-dtype bucket, every further leaf a CMM hit — and buckets fan
    out over the mesh's ``data``-axis devices.
    """
    from . import engine as engine_mod  # runtime import: peer layer

    eng = engine if engine is not None else engine_mod.default_engine()
    return eng.compress_pytree(tree, select, sep=sep)


def decompress_pytree(
    comp: dict[str, Any], like: Any, *, sep: str = "/", engine: Any = None
) -> Any:
    """Rebuild the pytree ``like`` from :func:`compress_pytree` output."""
    from . import engine as engine_mod

    eng = engine if engine is not None else engine_mod.default_engine()
    return eng.decompress_pytree(comp, like, sep=sep)


# ---------------------------------------------------------------------------
# chunked streaming (HDEM pipeline)
# ---------------------------------------------------------------------------


class CompressorStream:
    """Chunked streaming compression on the lane-overlapped HDEM pipeline.

    Chunks share a spec whenever their shapes agree, so every chunk after
    the first hits the CMM plan cache — the chunk-pipelined analogue of the
    paper's per-call context reuse.  Each chunk runs as a *two-phase*
    encode: the fused ``CompiledPipeline`` segments execute on the
    executor's compute lane (phase 1, device-resident) while the previous
    chunk's D2H fetch + container serialization runs on the io lane
    (phase 2) and the next chunk stages H2D — the paper's Fig. 9 overlap,
    bounded at ``window`` in-flight chunks.  Plans with persistent
    workspace get one donated copy per window slot, recycled across the
    chunks that reuse the slot, so concurrent chunk encodes never contend
    on the plan's shared buffers.

    ``to_bytes``/``from_bytes`` frame the per-chunk containers with an
    offset index so chunks can be located (and fetched lazily)
    independently; ``to_file``/``from_file`` add an aligned, aggregated
    on-disk layout with a segment directory, so a reader ``pread``s
    exactly the chunks it needs.  Passing ``engine=`` schedules chunks
    round-robin across the engine's ``data``-axis devices and runs the
    lanes on the engine's executor.

    ``chunk_size="auto"`` and/or ``window="auto"`` hand the decision to
    the auto-tuner (``core/tuner.py``): per payload, the calibrated
    machine cost model picks the (chunk, window) with the smallest
    predicted makespan — degrading to ``window=1`` whenever pipelining
    can't pay for its staging overhead.  The resolved values feed the
    exact same schedule/spec path as explicit settings, so auto streams
    are bit-identical to explicitly configured ones and share their CMM
    plans; the decision is observable at ``result.tuned``.  An explicit
    integer ``chunk_size`` (elements) is shorthand for ``mode="fixed",
    c_fixed_elems=chunk_size``.
    """

    def __init__(
        self,
        method: str = "zfp",
        mode: str = "adaptive",
        *,
        c_init_elems: int = 1 << 20,
        c_fixed_elems: int = 8 << 20,
        c_limit_elems: int = 1 << 28,
        phi=None,
        theta=None,
        engine: Any = None,
        backend: str | None = None,
        window: int | str = 2,
        chunk_size: int | str | None = None,
        frame: bool = False,
        **params: Any,
    ):
        self.method = method
        self.params = params
        if backend is None and engine is not None:
            backend = engine.backend
        self.backend = backend or adapters.AUTO
        self.window = window if window == "auto" else max(1, int(window))
        # frame=True moves wire serialization (container v2 framing + crc32)
        # onto the io lane too: each chunk's byte frame is produced while
        # the next chunk computes, and to_bytes/to_file reuse it
        self.frame = bool(frame)
        self._slot_ws: dict[tuple, tuple] = {}
        self._slot_lock = threading.Lock()
        auto = chunk_size == "auto" or window == "auto"
        self.pipeline = pl.ChunkedPipeline(
            mode=mode,
            c_init_elems=c_init_elems,
            c_fixed_elems=c_fixed_elems,
            c_limit_elems=c_limit_elems,
            phi=phi,
            theta=theta,
            devices=engine.devices if engine is not None else None,
            compute_fn=self._compute_chunk,
            finish_fn=self._finish_chunk,
            executor=engine.executor if engine is not None else None,
            window=window,
            chunk_size=chunk_size,
            tuner=self._tuned_plan if auto else None,
        )

    def _tuned_plan(self, total_elems: int, itemsize: int, dtype: str,
                    chunk_elems: int | None):
        """Tuner binding: this stream's codec/backend/params, the payload's
        size/dtype.  Called by the pipeline when resolving ``auto``."""
        from . import tuner as tuner_mod

        return tuner_mod.plan_stream(
            total_elems, itemsize, method=self.method, dtype=dtype,
            backend=self.backend, chunk_elems=chunk_elems,
            params=self.params,
        )

    # -- two-phase chunk encode ---------------------------------------------

    def _slot_workspace(self, plan: "ReductionPlan", slot: int) -> dict | None:
        """One private workspace copy per (plan, window slot).

        Donating segment executables invalidate their input buffers, so
        concurrent in-flight chunks must not share the plan's single
        workspace; the slot copy is donated into each dispatch and the
        recycled buffer re-stored under the same slot (the stream analogue
        of the engine's per-shard stacks).  Slots are reused serially —
        chunk *i* and *i+window* share a slot, but the window bound
        guarantees chunk *i* has fully finished first.
        """
        keys = {
            k
            for seg in plan.pipeline.device_segments
            for k in seg.workspace_keys
        }
        if not keys:
            return None
        # the entry pins the plan alive, so the id() key can never be
        # recycled onto a different plan while this stream exists
        cache_key = (id(plan), slot)
        with self._slot_lock:
            entry = self._slot_ws.get(cache_key)
            if entry is not None and entry[0] is plan:
                self._slot_ws[cache_key] = self._slot_ws.pop(cache_key)  # LRU
                return entry[1]
        with plan.lock:
            ws = {k: jnp.array(plan.workspace[k], copy=True) for k in keys}
        with self._slot_lock:
            # bounded: adaptive streams see a plan per chunk shape, and
            # workspaces are input-sized — keep the few most recent plans'
            # slots instead of pinning every plan the stream ever touched.
            # Evicting an entry an in-flight chunk still holds is safe:
            # the chunk owns its dict reference exclusively; a later chunk
            # simply rebuilds a fresh copy.
            while len(self._slot_ws) >= 4 * max(1, self.pipeline.window):
                self._slot_ws.pop(next(iter(self._slot_ws)))
            self._slot_ws[cache_key] = (plan, ws)
        return ws

    def _compute_chunk(self, chunk: jax.Array, slot: int):
        """Phase 1 (compute lane): fused device segments, state stays put."""
        spec = make_spec(chunk, self.method, backend=self.backend, **self.params)
        codec = get_codec(spec.method)
        plan = get_plan(spec)
        if plan.pipeline is None:  # codec without a stage graph: one phase
            return ("container", codec.encode(plan, jnp.asarray(chunk)))
        state, env = codec.encode_begin(
            plan, chunk, workspace=self._slot_workspace(plan, slot)
        )
        # block here, on the compute lane: serialization must only see
        # finished device buffers, and lane timings must be honest
        jax.block_until_ready([v for v in state.values()])
        return ("state", codec, plan, state, env)

    def _finish_chunk(self, payload, slot: int) -> Compressed:
        """Phase 2 (io lane): exact-sized D2H fetch + container build."""
        del slot
        if payload[0] == "container":
            c = payload[1]
            for k, v in list(c.arrays.items()):
                c.arrays[k] = np.asarray(v)
        else:
            _tag, codec, plan, state, env = payload
            c = codec.encode_finish(plan, state, env)
        if self.frame:
            c._frame_bytes = c.to_bytes()
        return c

    def compress(self, data: np.ndarray) -> pl.ChunkedResult:
        return self.pipeline.run(np.asarray(data))

    @staticmethod
    def decompress(result: pl.ChunkedResult) -> np.ndarray:
        return pl.decompress_chunked(result, decode)

    # -- framed multi-chunk byte format -------------------------------------

    @staticmethod
    def _chunk_blobs(result: pl.ChunkedResult) -> list[bytes]:
        """Per-chunk wire frames (reusing io-lane frames from ``frame=True``)."""
        return [
            getattr(c, "_frame_bytes", None) or c.to_bytes()
            for c in result.chunks
        ]

    @staticmethod
    def to_bytes(result: pl.ChunkedResult) -> bytes:
        blobs = CompressorStream._chunk_blobs(result)
        offsets = []
        off = 0
        for b in blobs:
            offsets.append(off)
            off += len(b)
        header = {
            "axis": result.axis,
            "shape": list(result.shape),
            "boundaries": list(result.boundaries),
            "chunks": [
                {"offset": o, "nbytes": len(b)} for o, b in zip(offsets, blobs)
            ],
        }
        hbytes = json.dumps(header).encode()
        buf = io.BytesIO()
        buf.write(_STREAM_MAGIC)
        buf.write(np.uint32(_STREAM_VERSION).tobytes())
        buf.write(np.uint64(len(hbytes)).tobytes())
        buf.write(hbytes)
        for b in blobs:
            buf.write(b)
        return buf.getvalue()

    @staticmethod
    def from_bytes(raw: bytes, lazy: bool = True) -> pl.ChunkedResult:
        """Parse a framed stream; chunks are fetched lazily by default.

        Framing and every chunk's byte range are validated eagerly (a
        truncated stream raises here), but the per-chunk containers are only
        materialised on first access via the v2 per-section offsets — a
        reader restoring a prefix never touches the tail's bytes
        (progressive restore while the tail is still in flight).
        ``lazy=False`` restores the historical eager behaviour.
        """
        raw = bytes(raw)
        if len(raw) < 16 or raw[:4] != _STREAM_MAGIC:
            raise ContainerError("not an HPDR chunked stream")
        version = int(np.frombuffer(raw[4:8], np.uint32)[0])
        if version != _STREAM_VERSION:
            raise ContainerError(f"unsupported HPDR stream version {version}")
        hlen = int(np.frombuffer(raw[8:16], np.uint64)[0])
        if len(raw) < 16 + hlen:
            raise ContainerError("truncated HPDR chunked stream")
        try:
            header = json.loads(raw[16 : 16 + hlen].decode())
        except (UnicodeDecodeError, json.JSONDecodeError) as e:
            raise ContainerError(f"corrupt HPDR stream header: {e}") from e
        base = 16 + hlen
        ranges = []
        for entry in header["chunks"]:
            lo = base + entry["offset"]
            hi = lo + entry["nbytes"]
            if hi > len(raw):
                raise ContainerError("truncated HPDR chunked stream")
            ranges.append((lo, hi))
        chunks: Sequence = LazyChunks(raw, ranges)
        if not lazy:
            chunks = list(chunks)
        return pl.ChunkedResult(
            chunks=chunks,
            boundaries=list(header["boundaries"]),
            axis=int(header["axis"]),
            shape=tuple(header["shape"]),
        )

    # -- aggregated on-disk layout (runtime/io segment directory) -----------

    @staticmethod
    def to_file(
        result: pl.ChunkedResult,
        path,
        *,
        align: int = 4096,
        parallel: bool = True,
    ) -> dict:
        """Write a framed stream to ``path`` with aligned, aggregated I/O.

        The layout is the ``to_bytes`` frame with every chunk placed at an
        ``align``-rounded offset (the header JSON is space-padded so the
        payload base is aligned too — JSON ignores trailing whitespace),
        written through :class:`repro.runtime.io.AggregatedWriter`: chunks
        coalesce into large positional writes flushed on a dedicated
        thread, and a **segment directory** trailer records every chunk's
        exact byte range + crc32.  Readers that predate the directory
        still parse the file with :meth:`from_bytes` — the header's chunk
        offsets point at the right places and the trailer is ignored.

        Returns the directory dict (``segments``, ``meta``).
        """
        from ..runtime.io import AggregatedWriter, align_up

        blobs = CompressorStream._chunk_blobs(result)
        offsets = []
        off = 0
        for b in blobs:
            offsets.append(off)
            off = align_up(off + len(b), align)
        header = {
            "axis": result.axis,
            "shape": list(result.shape),
            "boundaries": list(result.boundaries),
            "chunks": [
                {"offset": o, "nbytes": len(b)} for o, b in zip(offsets, blobs)
            ],
            "align": align,
        }
        hbytes = json.dumps(header).encode()
        # pad the header so the payload base (16 + len(hbytes)) is aligned:
        # aligned relative offsets then stay aligned absolutely
        pad = (-(16 + len(hbytes))) % align
        hbytes += b" " * pad
        meta = {k: header[k] for k in ("axis", "shape", "boundaries")}
        with AggregatedWriter(
            path, align=align, parallel=parallel, meta=meta
        ) as writer:
            writer.write_raw(_STREAM_MAGIC)
            writer.write_raw(np.uint32(_STREAM_VERSION).tobytes())
            writer.write_raw(np.uint64(len(hbytes)).tobytes())
            writer.write_raw(hbytes)
            for i, b in enumerate(blobs):
                got = writer.add(f"chunk/{i:05d}", b)
                assert got == 16 + len(hbytes) + offsets[i]
            directory = writer.close()
        return directory

    @staticmethod
    def from_file(path, lazy: bool = True) -> pl.ChunkedResult:
        """Open a :meth:`to_file` stream; chunks ``pread`` lazily on access.

        The segment directory locates every chunk, so restoring a prefix
        (or one chunk) reads exactly those byte ranges — nothing else is
        touched.  Files without a directory (e.g. raw :meth:`to_bytes`
        dumps) fall back to an in-memory parse via :meth:`from_bytes`.
        """
        from ..runtime import io as rio

        if not rio.has_directory(path):
            with open(path, "rb") as f:
                return CompressorStream.from_bytes(f.read(), lazy=lazy)
        reader = rio.AggregatedReader(path)
        # numeric sort: the zero-padded names widen past 5 digits on huge
        # streams, where a lexicographic sort would reorder chunks
        names = sorted(
            (n for n in reader.names() if n.startswith("chunk/")),
            key=lambda n: int(n.rsplit("/", 1)[1]),
        )
        chunks: Sequence = FileChunks(reader, names)
        if not lazy:
            chunks = list(chunks)
            reader.close()
        meta = reader.meta
        return pl.ChunkedResult(
            chunks=chunks,
            boundaries=list(meta["boundaries"]),
            axis=int(meta["axis"]),
            shape=tuple(meta["shape"]),
        )


class LazyChunks(Sequence):
    """Sequence of per-chunk containers, parsed on first access.

    Backed by the framed stream's byte buffer and the header's offset
    index; ``materialized`` counts how many chunks have actually been
    decoded from bytes (the observable for laziness tests).
    """

    def __init__(self, raw: bytes, ranges: list[tuple[int, int]]):
        self._raw = raw
        self._ranges = ranges
        self._cache: list[Compressed | None] = [None] * len(ranges)

    def __len__(self) -> int:
        return len(self._ranges)

    def __getitem__(self, i):
        if isinstance(i, slice):
            return [self[j] for j in range(*i.indices(len(self)))]
        if i < 0:
            i += len(self)
        if not 0 <= i < len(self):
            raise IndexError(i)
        if self._cache[i] is None:
            lo, hi = self._ranges[i]
            self._cache[i] = Compressed.from_bytes(self._raw[lo:hi])
        return self._cache[i]

    @property
    def materialized(self) -> int:
        return sum(c is not None for c in self._cache)


class FileChunks(Sequence):
    """Sequence of per-chunk containers backed by segment-file ``pread``s.

    The file-resident sibling of :class:`LazyChunks`: nothing is read at
    construction beyond the directory the caller already parsed; accessing
    chunk *i* ``pread``s exactly that chunk's byte range (crc-checked) and
    caches the parsed container.  ``materialized`` counts parsed chunks
    and ``reader.preads`` counts actual positional reads — the observables
    for "decode touches only what it needs" tests.
    """

    def __init__(self, reader, names: list[str]):
        self.reader = reader
        self._names = list(names)
        self._cache: list[Compressed | None] = [None] * len(names)

    def __len__(self) -> int:
        return len(self._names)

    def __getitem__(self, i):
        if isinstance(i, slice):
            return [self[j] for j in range(*i.indices(len(self)))]
        if i < 0:
            i += len(self)
        if not 0 <= i < len(self):
            raise IndexError(i)
        if self._cache[i] is None:
            self._cache[i] = Compressed.from_bytes(self.reader.read(self._names[i]))
        return self._cache[i]

    @property
    def materialized(self) -> int:
        return sum(c is not None for c in self._cache)
