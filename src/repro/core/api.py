"""Public HPDR compression API (paper Fig. 2 'High-level APIs' layer).

``compress``/``decompress`` front the three pipelines (MGARD-X, ZFP-X,
Huffman-X) behind one interface, route plan reuse through the CMM context
cache, and provide a portable byte serialization (header + sections) used by
the checkpoint manager and the I/O benchmarks.

Methods
-------
  mgard          error-bounded lossy (float arrays, 1-4D)
  zfp            fixed-rate lossy (float arrays, 1-4D)
  huffman        lossless entropy coding of integer key arrays
  huffman-bytes  lossless byte-wise entropy coding of arbitrary arrays
                 (the LZ-class baseline analogue in the paper's comparisons)
"""

from __future__ import annotations

import io
import json
import math
from dataclasses import dataclass
from typing import Any

import jax
import jax.numpy as jnp
import numpy as np

from . import huffman, mgard, zfp
from .context import GLOBAL_CMM, ReductionContext, context_key

_MAGIC = b"HPDR"
_VERSION = 1

METHODS = ("mgard", "zfp", "huffman", "huffman-bytes")


@dataclass
class Compressed:
    """Method-tagged compressed object with byte (de)serialization."""

    method: str
    meta: dict[str, Any]
    arrays: dict[str, np.ndarray]

    def nbytes(self) -> int:
        return sum(a.nbytes for a in self.arrays.values())

    def ratio(self) -> float:
        orig = math.prod(self.meta["shape"]) * np.dtype(self.meta["dtype"]).itemsize
        return orig / max(self.nbytes(), 1)

    # -- portable byte format (used by checkpoint/I-O layers) ---------------

    def to_bytes(self) -> bytes:
        buf = io.BytesIO()
        names = sorted(self.arrays)
        header = {
            "method": self.method,
            "meta": _jsonable(self.meta),
            "arrays": {
                n: {"dtype": str(self.arrays[n].dtype), "shape": list(self.arrays[n].shape)}
                for n in names
            },
        }
        hbytes = json.dumps(header).encode()
        buf.write(_MAGIC)
        buf.write(np.uint32(_VERSION).tobytes())
        buf.write(np.uint64(len(hbytes)).tobytes())
        buf.write(hbytes)
        for n in names:
            buf.write(np.ascontiguousarray(self.arrays[n]).tobytes())
        return buf.getvalue()

    @classmethod
    def from_bytes(cls, raw: bytes) -> "Compressed":
        if raw[:4] != _MAGIC:
            raise ValueError("not an HPDR stream")
        hlen = int(np.frombuffer(raw[8:16], np.uint64)[0])
        header = json.loads(raw[16 : 16 + hlen].decode())
        off = 16 + hlen
        arrays = {}
        for n in sorted(header["arrays"]):
            spec = header["arrays"][n]
            dt = np.dtype(spec["dtype"])
            count = math.prod(spec["shape"]) if spec["shape"] else 1
            nb = count * dt.itemsize
            arrays[n] = np.frombuffer(raw[off : off + nb], dt).reshape(spec["shape"])
            off += nb
        return cls(method=header["method"], meta=header["meta"], arrays=arrays)


def _jsonable(d: dict) -> dict:
    out = {}
    for k, v in d.items():
        if isinstance(v, (np.integer,)):
            v = int(v)
        elif isinstance(v, (np.floating,)):
            v = float(v)
        elif isinstance(v, tuple):
            v = list(v)
        out[k] = v
    return out


# ---------------------------------------------------------------------------
# compress / decompress
# ---------------------------------------------------------------------------


def compress(
    data: jax.Array | np.ndarray,
    method: str = "mgard",
    *,
    error_bound: float = 1e-2,
    relative: bool = True,
    rate: int = 16,
    dict_size: int = 4096,
    adapter: str | None = None,
) -> Compressed:
    """Compress ``data`` with the selected pipeline.

    ``error_bound`` is relative to the value range when ``relative=True``
    (the paper's evaluation convention).
    """
    del adapter  # plumbed through kernels' ops.py; the jnp path is portable
    data = jnp.asarray(data)
    key = context_key(method, data.shape, data.dtype,
                      eb=error_bound, rel=relative, rate=rate, dict=dict_size)
    GLOBAL_CMM.get_or_create(key, lambda: ReductionContext(key=key, plan=None))

    if method == "mgard":
        vrange = float(jnp.max(data) - jnp.min(data)) if relative else 1.0
        eb = error_bound * (vrange if relative else 1.0)
        obj = mgard.compress(data, eb if eb > 0 else error_bound, dict_size=dict_size)
        return Compressed(
            method=method,
            meta={
                "shape": tuple(obj.shape), "padded": tuple(obj.padded),
                "dtype": obj.dtype, "error_bound": obj.error_bound,
                "dict_size": obj.dict_size,
                "chunk_size": obj.entropy.chunk_size,
                "total_bits": obj.entropy.total_bits,
                "n_symbols": obj.entropy.n_symbols,
                "num_keys": obj.entropy.num_keys,
            },
            arrays={
                "words": np.asarray(obj.entropy.words),
                "chunk_offsets": np.asarray(obj.entropy.chunk_offsets),
                "length_table": obj.entropy.length_table,
                "outlier_idx": obj.outlier_idx,
                "outlier_val": obj.outlier_val,
                "bins": obj.bins,
            },
        )
    if method == "zfp":
        obj = zfp.compress(data, rate=rate)
        return Compressed(
            method=method,
            meta={"shape": tuple(obj.shape), "dtype": obj.dtype, "rate": obj.rate},
            arrays={"payload": np.asarray(obj.payload), "emax": np.asarray(obj.emax)},
        )
    if method == "huffman":
        if not jnp.issubdtype(data.dtype, jnp.integer):
            raise ValueError("huffman method expects integer keys; use huffman-bytes")
        num_keys = int(jnp.max(data)) + 1
        enc = huffman.compress(data, num_keys)
        return _huffman_compressed(enc, data.shape, str(data.dtype), "huffman")
    if method == "huffman-bytes":
        byte_view = jnp.asarray(np.asarray(data).view(np.uint8))
        enc = huffman.compress(byte_view.astype(jnp.int32), 256)
        return _huffman_compressed(
            enc, data.shape, str(data.dtype), "huffman-bytes"
        )
    raise ValueError(f"unknown method {method!r}; expected one of {METHODS}")


def _huffman_compressed(enc: huffman.Encoded, shape, dtype, method) -> Compressed:
    return Compressed(
        method=method,
        meta={
            "shape": tuple(shape), "dtype": dtype,
            "chunk_size": enc.chunk_size, "total_bits": enc.total_bits,
            "n_symbols": enc.n_symbols, "num_keys": enc.num_keys,
        },
        arrays={
            "words": np.asarray(enc.words),
            "chunk_offsets": np.asarray(enc.chunk_offsets),
            "length_table": enc.length_table,
        },
    )


def _huffman_encoded(c: Compressed) -> huffman.Encoded:
    return huffman.Encoded(
        words=jnp.asarray(c.arrays["words"]),
        total_bits=int(c.meta["total_bits"]),
        n_symbols=int(c.meta["n_symbols"]),
        chunk_size=int(c.meta["chunk_size"]),
        chunk_offsets=jnp.asarray(c.arrays["chunk_offsets"]),
        length_table=np.asarray(c.arrays["length_table"]),
        num_keys=int(c.meta["num_keys"]),
    )


def decompress(c: Compressed) -> jax.Array:
    if c.method == "mgard":
        obj = mgard.MGARDCompressed(
            entropy=_huffman_encoded(c),
            outlier_idx=np.asarray(c.arrays["outlier_idx"]),
            outlier_val=np.asarray(c.arrays["outlier_val"]),
            bins=np.asarray(c.arrays["bins"]),
            shape=tuple(c.meta["shape"]),
            padded=tuple(c.meta["padded"]),
            error_bound=float(c.meta["error_bound"]),
            dict_size=int(c.meta["dict_size"]),
            dtype=c.meta["dtype"],
        )
        return mgard.decompress(obj)
    if c.method == "zfp":
        obj = zfp.ZFPCompressed(
            payload=jnp.asarray(c.arrays["payload"]),
            emax=jnp.asarray(c.arrays["emax"]),
            shape=tuple(c.meta["shape"]),
            rate=int(c.meta["rate"]),
            dtype=c.meta["dtype"],
        )
        return zfp.decompress(obj)
    if c.method == "huffman":
        keys = huffman.decompress(_huffman_encoded(c))
        return keys.reshape(tuple(c.meta["shape"])).astype(jnp.dtype(c.meta["dtype"]))
    if c.method == "huffman-bytes":
        keys = np.asarray(huffman.decompress(_huffman_encoded(c))).astype(np.uint8)
        return jnp.asarray(
            keys.view(np.dtype(c.meta["dtype"])).reshape(tuple(c.meta["shape"]))
        )
    raise ValueError(f"unknown method {c.method!r}")
