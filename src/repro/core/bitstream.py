"""Parallel bitstream packing/unpacking — HPDR's global serialization stage.

GPU compressors compact variable-length codes with warp ballots and atomic
ORs.  TPUs have neither; the TPU-native equivalent used here:

  * offsets come from an exclusive scan of code lengths (DEM global stage);
  * every code contributes to exactly two consecutive 32-bit words, with
    **disjoint bit ownership**, so an unsigned ``segment_sum`` is exactly a
    bitwise OR (no carries can occur) — scatter-free compaction;
  * fixed-rate streams (ZFP) have affine offsets, so their bitplane packing
    is a pure reshape + shift-reduce (see ``bits_to_words``), which XLA/Pallas
    turn into vector ops.

All streams are MSB-first within 32-bit big-endian words — the natural order
for canonical-Huffman decoding.
"""

from __future__ import annotations

import jax
import jax.numpy as jnp
import numpy as np

WORD_BITS = 32
_U32 = jnp.uint32


def exclusive_cumsum(x: jax.Array) -> jax.Array:
    """Exclusive prefix sum along the last axis (global-pipeline scan stage)."""
    inc = jnp.cumsum(x, axis=-1)
    return inc - x


def _safe_shl(x: jax.Array, n: jax.Array) -> jax.Array:
    """x << n with n possibly >= 32 (result 0) or arbitrary; n >= 0 required."""
    n = jnp.asarray(n)
    big = n >= WORD_BITS
    return jnp.where(big, _U32(0), (x.astype(_U32) << jnp.minimum(n, WORD_BITS - 1).astype(_U32)))


def _safe_shr(x: jax.Array, n: jax.Array) -> jax.Array:
    """Logical x >> n with n possibly >= 32 (result 0); n >= 0 required."""
    n = jnp.asarray(n)
    big = n >= WORD_BITS
    return jnp.where(big, _U32(0), (x.astype(_U32) >> jnp.minimum(n, WORD_BITS - 1).astype(_U32)))


def _iota_desc(n: int) -> jax.Array:
    """[n-1, n-2, ..., 0] as uint32 via traced ops (Pallas-safe: no captured consts)."""
    return (n - 1) - jax.lax.iota(_U32, n)


def bits_to_words(bits: jax.Array) -> jax.Array:
    """Pack a ``(..., 32)`` array of 0/1 into ``(...,)`` uint32, MSB-first."""
    if bits.shape[-1] != WORD_BITS:
        raise ValueError(f"last dim must be {WORD_BITS}, got {bits.shape[-1]}")
    weights = jnp.left_shift(np.uint32(1), _iota_desc(WORD_BITS))
    return jnp.sum(bits.astype(_U32) * weights, axis=-1, dtype=_U32)


def words_to_bits(words: jax.Array) -> jax.Array:
    """Inverse of :func:`bits_to_words`: uint32 ``(...,)`` → 0/1 ``(..., 32)``."""
    shifts = _iota_desc(WORD_BITS)
    return ((words.astype(_U32)[..., None] >> shifts) & np.uint32(1)).astype(jnp.uint32)


def pack_bits(
    codes: jax.Array,
    lengths: jax.Array,
    total_bits: jax.Array | int,
    num_words: int,
) -> jax.Array:
    """Pack N variable-length codes (≤32 bits each) into a uint32 word stream.

    ``codes[i]`` holds the code right-aligned (low ``lengths[i]`` bits);
    bit position is MSB-first.  ``num_words`` must be a static bound
    ≥ ceil(total_bits/32).  Returns uint32[num_words].

    Each code lands in words ``w`` and ``w+1`` with disjoint bits, so the two
    ``segment_sum`` calls below are exact bitwise ORs (the paper's "global
    coordination" for compaction, scatter-free).
    """
    del total_bits  # static layout comes from num_words; kept for API clarity
    codes = codes.astype(_U32)
    lengths = lengths.astype(jnp.int32)
    offsets = exclusive_cumsum(lengths)
    w = offsets // WORD_BITS
    b = offsets % WORD_BITS

    # Mask codes to their length so stray high bits can't corrupt neighbours.
    mask = jnp.where(lengths >= WORD_BITS, _U32(0xFFFFFFFF), _safe_shl(jnp.asarray(_U32(1)), lengths) - _U32(1))
    codes = codes & mask

    shift_hi = WORD_BITS - b - lengths  # >=0: fits in word w entirely
    hi = jnp.where(
        shift_hi >= 0,
        _safe_shl(codes, jnp.maximum(shift_hi, 0)),
        _safe_shr(codes, jnp.maximum(-shift_hi, 0)),
    )
    lo = jnp.where(
        shift_hi >= 0,
        _U32(0),
        _safe_shl(codes, jnp.maximum(WORD_BITS + shift_hi, 0)),
    )
    valid = lengths > 0
    hi = jnp.where(valid, hi, _U32(0))
    lo = jnp.where(valid, lo, _U32(0))

    words = jax.ops.segment_sum(hi, w, num_segments=num_words)
    words = words + jax.ops.segment_sum(lo, jnp.minimum(w + 1, num_words - 1), num_segments=num_words)
    return words.astype(_U32)


def read_window(words: jax.Array, bit_offset: jax.Array) -> jax.Array:
    """Read a 32-bit MSB-aligned window starting at ``bit_offset``.

    Reads past the end of ``words`` return zero bits.
    """
    n = words.shape[0]
    w = bit_offset // WORD_BITS
    b = bit_offset % WORD_BITS
    w0 = jnp.where(w < n, words[jnp.minimum(w, n - 1)], _U32(0))
    w1 = jnp.where(w + 1 < n, words[jnp.minimum(w + 1, n - 1)], _U32(0))
    return _safe_shl(w0, b) | jnp.where(b == 0, _U32(0), _safe_shr(w1, WORD_BITS - b))


def unpack_bits(
    words: jax.Array, offsets: jax.Array, lengths: jax.Array
) -> jax.Array:
    """Extract N codes given their bit offsets/lengths (inverse of pack_bits)."""
    windows = jax.vmap(lambda o: read_window(words, o))(offsets)
    vals = _safe_shr(windows, WORD_BITS - lengths)
    return jnp.where(lengths > 0, vals, _U32(0))


def words_needed(total_bits: int) -> int:
    return (int(total_bits) + WORD_BITS - 1) // WORD_BITS
