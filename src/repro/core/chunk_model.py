"""Adaptive chunk sizing — HPDR §V-C (Algorithm 4, Fig. 11).

Two estimation functions drive the adaptive pipeline:

  Φ(C)  reduction throughput at chunk size C — the paper's *modified roofline
        model*: linear while the accelerator is under-occupied, constant γ
        once saturated::

            Φ(C) = α·C + β₀   if C < C_threshold
                 = γ          otherwise

  Θ(t)  max bytes transferable host→device in time t: Θ(t) = t / β, with β
        the per-byte transfer cost (interconnect treated as saturated).

Next chunk: C_next = min(Θ(C_curr / Φ(C_curr)), C_limit) — grow the chunk so
its transfer hides entirely under the current chunk's compute.

The model is fitted from profile points exactly as §V-C describes: γ is the
largest-chunk throughput; walk down through smaller chunks until throughput
drops below f·γ (f = 0.1 default); the linear segment is a least-squares fit
over the remaining (smaller) chunk sizes.
"""

from __future__ import annotations

from dataclasses import dataclass

import numpy as np


@dataclass(frozen=True)
class PhiModel:
    """Piecewise linear→constant throughput model Φ(C) (bytes/s vs bytes)."""

    alpha: float          # slope of the unsaturated segment ((bytes/s)/byte)
    beta0: float          # intercept (bytes/s)
    gamma: float          # saturated throughput (bytes/s)
    c_threshold: float    # saturation chunk size (bytes)

    def __call__(self, chunk_bytes) -> np.ndarray:
        c = np.asarray(chunk_bytes, dtype=np.float64)
        lin = self.alpha * c + self.beta0
        return np.where(c < self.c_threshold, np.minimum(lin, self.gamma), self.gamma)

    def time_for(self, chunk_bytes: float) -> float:
        return float(chunk_bytes) / float(self(chunk_bytes))


def fit_phi(
    chunk_sizes: np.ndarray, throughputs: np.ndarray, f: float = 0.1
) -> PhiModel:
    """Fit Φ from profile points (paper §V-C fitting procedure).

    Degenerate sweeps fit gracefully instead of raising: a single point or
    an all-saturated (flat) profile yields the constant model Φ ≡ γ; a
    noisy profile whose least-squares slope comes out non-positive is
    likewise treated as saturated (the linear segment carries no signal).
    An all-unsaturated (still-rising) profile fits the linear segment over
    every point and places ``c_threshold`` at the largest observed chunk.
    Empty or non-finite/non-positive profiles raise ``ValueError``.
    """
    c = np.atleast_1d(np.asarray(chunk_sizes, np.float64))
    p = np.atleast_1d(np.asarray(throughputs, np.float64))
    if c.size == 0:
        raise ValueError("fit_phi: need at least one (chunk_size, throughput) "
                         "profile point, got an empty sweep")
    if c.size != p.size:
        raise ValueError(f"fit_phi: {c.size} chunk sizes vs {p.size} "
                         "throughputs — profile arrays must align")
    if not (np.all(np.isfinite(c)) and np.all(np.isfinite(p))):
        raise ValueError("fit_phi: profile points must be finite")
    if np.any(c <= 0) or np.any(p <= 0):
        raise ValueError("fit_phi: chunk sizes and throughputs must be > 0")
    order = np.argsort(c)
    c, p = c[order], p[order]
    gamma = float(p[-1])
    if c.size == 1:
        return PhiModel(alpha=0.0, beta0=gamma, gamma=gamma,
                        c_threshold=float(c[0]))
    # walk down from the largest chunk until throughput < f·gamma
    cut = 0
    for i in range(len(c) - 1, -1, -1):
        if p[i] < f * gamma:
            cut = i + 1
            break
    lin_c, lin_p = c[:max(cut, 2)], p[:max(cut, 2)]
    if len(lin_c) >= 2 and np.ptp(lin_c) > 0:
        alpha, beta0 = np.polyfit(lin_c, lin_p, 1)
    else:  # degenerate profile: flat model
        alpha, beta0 = 0.0, gamma
    if not np.isfinite(alpha) or alpha <= 0:
        # saturated everywhere (or noise-dominated slope): constant Φ ≡ γ
        return PhiModel(alpha=0.0, beta0=gamma, gamma=gamma,
                        c_threshold=float(c[0]))
    c_threshold = float(np.clip((gamma - beta0) / alpha, c[0], c[-1]))
    return PhiModel(alpha=float(alpha), beta0=float(beta0), gamma=gamma,
                    c_threshold=c_threshold)


@dataclass(frozen=True)
class AffineCost:
    """Affine stage-cost model t(C) = t₀ + C/bps.

    The fixed term t₀ captures per-call latency (dispatch, syscall, GIL
    handoff) that dominates tiny chunks — exactly the regime where the
    auto-tuner must notice that pipelining cannot pay for itself.
    """

    t0: float    # fixed seconds per call
    bps: float   # marginal throughput, bytes/s

    def time_for(self, nbytes: float) -> float:
        return self.t0 + float(nbytes) / self.bps


def fit_affine(sizes_bytes: np.ndarray, times_s: np.ndarray) -> AffineCost:
    """Least-squares fit of t = t₀ + C/bps over measured (C, t) points."""
    c = np.atleast_1d(np.asarray(sizes_bytes, np.float64))
    t = np.atleast_1d(np.asarray(times_s, np.float64))
    if c.size == 0 or c.size != t.size:
        raise ValueError("fit_affine: need matched, non-empty size/time arrays")
    if np.any(c <= 0) or np.any(t <= 0) or not np.all(np.isfinite(t)):
        raise ValueError("fit_affine: sizes and times must be finite and > 0")
    if c.size == 1 or np.ptp(c) == 0:
        return AffineCost(t0=0.0, bps=float(c[0] / t[0]))
    slope, t0 = np.polyfit(c, t, 1)
    if not np.isfinite(slope) or slope <= 0:
        # noise-dominated: fall back to the largest point's secant rate
        return AffineCost(t0=0.0, bps=float(c[-1] / t[-1]))
    return AffineCost(t0=float(max(t0, 0.0)), bps=float(1.0 / slope))


@dataclass(frozen=True)
class ThetaModel:
    """Θ(t) = t/β : bytes transferable host→device in time t."""

    beta: float  # seconds per byte (1 / H2D bandwidth)

    def __call__(self, t: float) -> float:
        return float(t) / self.beta

    def time_for(self, nbytes: float) -> float:
        return float(nbytes) * self.beta


def adaptive_chunk_schedule(
    total_bytes: int,
    c_init: int,
    c_limit: int,
    phi: PhiModel,
    theta: ThetaModel,
) -> list[int]:
    """Chunk-size sequence of Algorithm 4 (host-side planning loop).

    Starts small (fast pipeline lead-in), grows each chunk to the largest
    size whose H2D transfer still hides under the current chunk's compute.
    """
    if total_bytes <= 0:
        return []
    sizes = []
    c_curr = int(min(c_init, total_bytes, c_limit))
    rest = total_bytes
    while rest > 0:
        c_curr = min(c_curr, rest)
        sizes.append(c_curr)
        rest -= c_curr
        if rest <= 0:
            break
        compute_t = phi.time_for(c_curr)
        c_next = int(min(theta(compute_t), c_limit, rest))
        c_curr = max(c_next, 1)
    return sizes


def fixed_chunk_schedule(total_bytes: int, chunk: int) -> list[int]:
    sizes = []
    rest = int(total_bytes)
    chunk = int(chunk)
    while rest > 0:
        sizes.append(min(chunk, rest))
        rest -= sizes[-1]
    return sizes
