"""HPDR codec registry — composable compression stages behind one API.

Every compression method is a :class:`~repro.core.codecs.base.Codec`
registered under its public name with :func:`register_codec`.  The API layer
(:mod:`repro.core.api`) dispatches ``compress``/``decompress`` through this
registry — there is no method if/elif chain anywhere — and stores each
codec's :class:`~repro.core.codecs.base.ReductionPlan` in the CMM so repeated
calls with the same :class:`~repro.core.codecs.base.ReductionSpec` reuse one
plan (jitted executables + workspace buffers).

Registering a new codec is one decorated class::

    from repro.core.codecs import register_codec
    from repro.core.codecs.base import Codec

    @register_codec("mymethod")
    class MyCodec(Codec):
        spec_defaults = {"level": 3}
        def plan(self, spec): ...
        def encode(self, plan, data): ...
        def decode(self, plan, c): ...
        def decode_spec(self, c): ...
"""

from __future__ import annotations

from .base import Codec, ReductionPlan, ReductionSpec  # noqa: F401

_REGISTRY: dict[str, Codec] = {}


def register_codec(name: str):
    """Class decorator: instantiate ``cls(name)`` and register it."""

    def deco(cls):
        _REGISTRY[name] = cls(name)
        return cls

    return deco


def get_codec(name: str) -> Codec:
    try:
        return _REGISTRY[name]
    except KeyError:
        raise ValueError(
            f"unknown method {name!r}; expected one of {available_methods()}"
        ) from None


def available_methods() -> tuple[str, ...]:
    return tuple(sorted(_REGISTRY))


# Import order defines nothing — each module self-registers on import.
from . import (  # noqa: E402,F401
    huffman_codec,
    mgard_codec,
    progressive_codec,
    zfp_codec,
)
