"""Codec protocol + plan objects for the HPDR codec registry.

The paper's CMM (§III-B) caches *contexts*: the plan (jitted executable) and
workspace allocations a reduction needs beyond its input/output.  This module
defines what a cached context holds in this framework:

  * :class:`ReductionSpec` — the hashable description of a reduction
    (method, shape, dtype, method parameters).  Its :meth:`ReductionSpec.key`
    is the CMM hash key ("similar data characteristics").
  * :class:`ReductionPlan` — what planning produces: jitted executables bound
    to the spec's static arguments plus persistent workspace buffers
    (level maps, permutations, codebooks) that repeated calls reuse.
  * :class:`Codec` — the three-method protocol every registered compressor
    implements: ``plan(spec)``, ``encode(plan, data)``, ``decode(plan, c)``.

Codecs are stateless; all per-(shape, dtype, params) state lives in the plan,
which the API layer stores in the global CMM so the second call with an
identical spec is a cache hit.
"""

from __future__ import annotations

import dataclasses
import threading
from dataclasses import dataclass, field
from typing import Any, Callable

import jax

from .. import adapters
from ..container import Compressed
from ..context import context_key


@dataclass(frozen=True)
class ReductionSpec:
    """Hashable description of one reduction: method + data characteristics.

    ``backend`` names the device adapter the plan's executables are bound to
    (``auto`` | ``xla`` | ``pallas`` | ``pallas_interpret``).  ``auto``
    resolves to the platform default through :func:`adapters.resolve_backend`
    capability probing, so a defaulted spec and an explicit platform-default
    spec share one CMM entry.
    """

    method: str
    shape: tuple[int, ...]
    dtype: str
    params: tuple[tuple[str, Any], ...] = ()
    backend: str = adapters.AUTO

    @classmethod
    def create(
        cls,
        method: str,
        shape: tuple[int, ...],
        dtype: Any,
        backend: str = adapters.AUTO,
        **params: Any,
    ) -> "ReductionSpec":
        return cls(
            method=method,
            shape=tuple(int(n) for n in shape),
            dtype=str(dtype),
            params=tuple(sorted(params.items())),
            backend=str(backend),
        )

    def param(self, name: str, default: Any = None) -> Any:
        for k, v in self.params:
            if k == name:
                return v
        return default

    def resolved(self) -> "ReductionSpec":
        """This spec with ``backend`` bound to a concrete, runnable adapter."""
        concrete = adapters.resolve_backend(self.backend)
        if concrete == self.backend:
            return self
        return dataclasses.replace(self, backend=concrete)

    def key(self) -> tuple:
        """Canonical CMM hash key for this spec (backend-resolved)."""
        return context_key(
            self.method, self.shape, self.dtype,
            backend=adapters.resolve_backend(self.backend),
            **dict(self.params),
        )


@dataclass
class ReductionPlan:
    """A built plan: jitted executables + persistent workspace buffers.

    ``executables`` maps stage name → jitted callable with the spec's static
    arguments already bound (tracing/compilation happens once per plan) and
    the spec's ``backend`` adapter baked in — kernel dispatch happens at plan
    time, never per call.  ``workspace`` holds device/host arrays that are
    data-independent for the spec (level maps, bin layouts, permutations,
    cached decode tables) — the paper's persistent context allocations.
    Executables that *donate* a workspace buffer return the recycled buffer;
    callers re-store it under :meth:`recycle` while holding :attr:`lock`
    (plans are shared across engine worker threads).

    ``pipeline`` is the compiled stage graph
    (:class:`repro.core.stages.base.CompiledPipeline`) for codecs declared
    as stage compositions: maximal device-stage runs fused into one jitted
    executable each, host barriers between them.  Both execution shapes —
    the per-leaf path and the engine's stacked ``shard_map`` path — run the
    same compiled segments.
    """

    spec: ReductionSpec
    executables: dict[str, Callable] = field(default_factory=dict)
    workspace: dict[str, Any] = field(default_factory=dict)
    meta: dict[str, Any] = field(default_factory=dict)
    pipeline: Any = field(default=None, repr=False, compare=False)
    lock: Any = field(default_factory=threading.Lock, repr=False, compare=False)

    def nbytes(self) -> int:
        return sum(int(getattr(b, "nbytes", 0)) for b in self.workspace.values())

    def recycle(self, name: str, buf: Any) -> None:
        """Re-store a donated-and-returned workspace buffer."""
        self.workspace[name] = buf


class Codec:
    """Base class for registered codecs (see :mod:`repro.core.codecs`).

    Subclasses set :attr:`spec_defaults` — the parameter names that belong
    in this codec's :class:`ReductionSpec` (and therefore in its CMM key),
    with their default values — and implement :meth:`plan` / :meth:`encode`
    / :meth:`decode` / :meth:`decode_spec`.
    """

    spec_defaults: dict[str, Any] = {}

    def __init__(self, name: str):
        self.name = name

    @property
    def spec_params(self) -> tuple[str, ...]:
        return tuple(self.spec_defaults)

    def make_spec(self, shape: tuple[int, ...], dtype: Any, **kwargs: Any) -> ReductionSpec:
        """Build a canonical spec from loose kwargs.

        Irrelevant kwargs are dropped and missing ones filled with the
        codec's defaults, so a defaulted call and an explicit-default call
        map to the same CMM key.  ``backend`` is resolved through adapter
        capability probing here — the spec a caller holds is already bound
        to a concrete adapter.
        """
        backend = adapters.resolve_backend(kwargs.pop("backend", None))
        params = {k: kwargs.get(k, d) for k, d in self.spec_defaults.items()}
        return ReductionSpec.create(self.name, shape, dtype, backend=backend, **params)

    # -- protocol ------------------------------------------------------------

    def plan(self, spec: ReductionSpec) -> ReductionPlan:
        """Build the persistent plan for ``spec`` (called once per CMM miss)."""
        raise NotImplementedError

    def encode_input(self, plan: ReductionPlan, data: Any) -> dict[str, Any]:
        """Initial pipeline state for ``data`` (the input-policy hook).

        Codecs whose pipeline consumes a host-side reinterpretation of the
        input (e.g. the huffman-bytes byte view) override this; everything
        downstream — serial encode, the engine's stacked path via
        ``leaf_policy``, and the chunk-pipelined stream — then feeds the
        pipeline identical bytes.
        """
        return {"data": data}

    def encode_begin(
        self,
        plan: ReductionPlan,
        data: Any,
        *,
        env: Any = None,
        profile: dict | None = None,
        workspace: dict | None = None,
    ) -> tuple[dict, Any]:
        """Phase 1 of a two-phase encode: run the forward pipeline only.

        Returns ``(state, env)`` with every array-scale product still
        device-resident — nothing has been fetched for serialisation yet.
        The chunk-pipelined scheduler runs this on the compute lane (with a
        per-slot ``workspace``) while the *previous* chunk's
        :meth:`encode_finish` runs on the io lane.
        """
        if plan.pipeline is None:
            raise NotImplementedError(
                f"codec {self.name!r} declares no stage graph; override "
                "encode() or implement build_stages()"
            )
        return plan.pipeline.run(
            self.encode_input(plan, data), env=env, profile=profile,
            workspace=workspace,
        )

    def encode_finish(self, plan: ReductionPlan, state: dict, env: Any) -> Compressed:
        """Phase 2: fetch the exact-sized sections and build the container."""
        from ..stages.base import LeafView  # local: codecs ↔ stages layering

        return self.finish_container(plan, env, LeafView(state, None, env))

    def encode(
        self,
        plan: ReductionPlan,
        data: jax.Array,
        *,
        env: Any = None,
        profile: dict | None = None,
    ) -> Compressed:
        """Default encode: run the compiled stage pipeline, then serialise.

        ``env``/``profile`` are the observability hooks ``api.encode_profiled``
        threads through (per-stage wall timings, host↔device transfer bytes).
        Exactly :meth:`encode_begin` followed by :meth:`encode_finish`, so
        the pipelined two-phase path is bit-identical by construction.
        """
        state, env = self.encode_begin(plan, data, env=env, profile=profile)
        return self.encode_finish(plan, state, env)

    def decode(
        self,
        plan: ReductionPlan,
        c: Compressed,
        *,
        env: Any = None,
        profile: dict | None = None,
    ) -> jax.Array:
        raise NotImplementedError

    def decode_spec(self, c: Compressed) -> ReductionSpec:
        """Spec keying the decode-side plan, recovered from container meta."""
        raise NotImplementedError

    # -- decode direction ----------------------------------------------------
    #
    # Codecs with an invertible stage graph expose the compiled decode path
    # through two hooks: decode_state() maps a container onto the inverse
    # pipeline's initial state (or None when the stream predates the decode
    # chunk index / needs the host fallback), and finish_decode() extracts
    # the result.  The default decode flow then mirrors encode: a single
    # fused device dispatch per inverse segment, H2D = compressed sections
    # plus metadata-scale operands.  The engine stacks whole buckets of
    # same-spec containers through the same hooks (invert_batched).

    def decode_state(
        self, plan: ReductionPlan, c: Compressed
    ) -> tuple[dict[str, Any], dict[str, Any]] | None:
        """``(inverse state0, env meta)`` for a container, or None."""
        return None

    def decode_bucket_key(self, c: Compressed) -> Any:
        """Per-stream decode *geometry* beyond the decode spec (hashable).

        Streams whose compiled-inverse statics differ — e.g. entropy
        streams packed with different ``chunk_size`` — must not share one
        stacked dispatch: merging their statics would decode garbage.  The
        engine groups decode buckets by ``(decode spec, this key)``; the
        default ``None`` groups purely by spec.
        """
        return None

    def finish_decode(
        self, plan: ReductionPlan, env: Any, state: dict, c: Compressed
    ) -> jax.Array:
        """Extract one leaf's decoded array from inverse pipeline state."""
        return state["data"]

    def _pipeline_decode(
        self,
        plan: ReductionPlan,
        c: Compressed,
        env: Any = None,
        profile: dict | None = None,
    ) -> jax.Array | None:
        """Run the compiled inverse pipeline; None → caller's host fallback."""
        if plan.pipeline is None or not plan.pipeline.invertible:
            return None
        prepared = self.decode_state(plan, c)
        if prepared is None:
            return None
        state0, meta = prepared
        from ..stages.base import CallEnv  # local: codecs ↔ stages layering

        env = env if env is not None else CallEnv(plan)
        env.meta.update(meta)
        state, env = plan.pipeline.invert(state0, env=env, profile=profile)
        return self.finish_decode(plan, env, state, c)

    @property
    def supports_batched_decode(self) -> bool:
        return (
            type(self).decode_state is not Codec.decode_state
        )

    # -- stage graph ---------------------------------------------------------
    #
    # Codecs declare their encode chain as a StageGraph; plan() attaches the
    # compiled pipeline via _attach_pipeline.  The execution engine reuses
    # the same compiled segments to stack same-spec leaves under one
    # shard_map over the mesh "data" axis (vmapped segments, host stages
    # looping over per-leaf metadata), so *every* stage-graph codec has a
    # batched encode path — the host-staged ones included, since their only
    # remaining host work is codebook construction.

    def build_stages(self, spec: ReductionSpec):
        """Return this codec's :class:`StageGraph` (or ``None``)."""
        return None

    def _attach_pipeline(self, plan: ReductionPlan) -> ReductionPlan:
        graph = self.build_stages(plan.spec)
        if graph is not None:
            plan.pipeline = graph.compile(plan)
        return plan

    def finish_container(self, plan: ReductionPlan, env: Any, view: Any) -> Compressed:
        """Serialise one leaf's pipeline state into a container."""
        raise NotImplementedError

    @property
    def supports_batched_encode(self) -> bool:
        return type(self).build_stages is not Codec.build_stages
