"""Codec protocol + plan objects for the HPDR codec registry.

The paper's CMM (§III-B) caches *contexts*: the plan (jitted executable) and
workspace allocations a reduction needs beyond its input/output.  This module
defines what a cached context holds in this framework:

  * :class:`ReductionSpec` — the hashable description of a reduction
    (method, shape, dtype, method parameters).  Its :meth:`ReductionSpec.key`
    is the CMM hash key ("similar data characteristics").
  * :class:`ReductionPlan` — what planning produces: jitted executables bound
    to the spec's static arguments plus persistent workspace buffers
    (level maps, permutations, codebooks) that repeated calls reuse.
  * :class:`Codec` — the three-method protocol every registered compressor
    implements: ``plan(spec)``, ``encode(plan, data)``, ``decode(plan, c)``.

Codecs are stateless; all per-(shape, dtype, params) state lives in the plan,
which the API layer stores in the global CMM so the second call with an
identical spec is a cache hit.
"""

from __future__ import annotations

import dataclasses
import threading
from dataclasses import dataclass, field
from typing import Any, Callable

import jax

from .. import adapters
from ..container import Compressed
from ..context import context_key


@dataclass(frozen=True)
class ReductionSpec:
    """Hashable description of one reduction: method + data characteristics.

    ``backend`` names the device adapter the plan's executables are bound to
    (``auto`` | ``xla`` | ``pallas`` | ``pallas_interpret``).  ``auto``
    resolves to the platform default through :func:`adapters.resolve_backend`
    capability probing, so a defaulted spec and an explicit platform-default
    spec share one CMM entry.
    """

    method: str
    shape: tuple[int, ...]
    dtype: str
    params: tuple[tuple[str, Any], ...] = ()
    backend: str = adapters.AUTO

    @classmethod
    def create(
        cls,
        method: str,
        shape: tuple[int, ...],
        dtype: Any,
        backend: str = adapters.AUTO,
        **params: Any,
    ) -> "ReductionSpec":
        return cls(
            method=method,
            shape=tuple(int(n) for n in shape),
            dtype=str(dtype),
            params=tuple(sorted(params.items())),
            backend=str(backend),
        )

    def param(self, name: str, default: Any = None) -> Any:
        for k, v in self.params:
            if k == name:
                return v
        return default

    def resolved(self) -> "ReductionSpec":
        """This spec with ``backend`` bound to a concrete, runnable adapter."""
        concrete = adapters.resolve_backend(self.backend)
        if concrete == self.backend:
            return self
        return dataclasses.replace(self, backend=concrete)

    def key(self) -> tuple:
        """Canonical CMM hash key for this spec (backend-resolved)."""
        return context_key(
            self.method, self.shape, self.dtype,
            backend=adapters.resolve_backend(self.backend),
            **dict(self.params),
        )


@dataclass
class ReductionPlan:
    """A built plan: jitted executables + persistent workspace buffers.

    ``executables`` maps stage name → jitted callable with the spec's static
    arguments already bound (tracing/compilation happens once per plan) and
    the spec's ``backend`` adapter baked in — kernel dispatch happens at plan
    time, never per call.  ``workspace`` holds device/host arrays that are
    data-independent for the spec (level maps, bin layouts, permutations) —
    the paper's persistent context allocations.  Executables that *donate* a
    workspace buffer return the recycled buffer; callers re-store it under
    :meth:`recycle` while holding :attr:`lock` (plans are shared across
    engine worker threads).
    """

    spec: ReductionSpec
    executables: dict[str, Callable] = field(default_factory=dict)
    workspace: dict[str, Any] = field(default_factory=dict)
    meta: dict[str, Any] = field(default_factory=dict)
    lock: Any = field(default_factory=threading.Lock, repr=False, compare=False)

    def nbytes(self) -> int:
        return sum(int(getattr(b, "nbytes", 0)) for b in self.workspace.values())

    def recycle(self, name: str, buf: Any) -> None:
        """Re-store a donated-and-returned workspace buffer."""
        self.workspace[name] = buf


class Codec:
    """Base class for registered codecs (see :mod:`repro.core.codecs`).

    Subclasses set :attr:`spec_defaults` — the parameter names that belong
    in this codec's :class:`ReductionSpec` (and therefore in its CMM key),
    with their default values — and implement :meth:`plan` / :meth:`encode`
    / :meth:`decode` / :meth:`decode_spec`.
    """

    spec_defaults: dict[str, Any] = {}

    def __init__(self, name: str):
        self.name = name

    @property
    def spec_params(self) -> tuple[str, ...]:
        return tuple(self.spec_defaults)

    def make_spec(self, shape: tuple[int, ...], dtype: Any, **kwargs: Any) -> ReductionSpec:
        """Build a canonical spec from loose kwargs.

        Irrelevant kwargs are dropped and missing ones filled with the
        codec's defaults, so a defaulted call and an explicit-default call
        map to the same CMM key.  ``backend`` is resolved through adapter
        capability probing here — the spec a caller holds is already bound
        to a concrete adapter.
        """
        backend = adapters.resolve_backend(kwargs.pop("backend", None))
        params = {k: kwargs.get(k, d) for k, d in self.spec_defaults.items()}
        return ReductionSpec.create(self.name, shape, dtype, backend=backend, **params)

    # -- protocol ------------------------------------------------------------

    def plan(self, spec: ReductionSpec) -> ReductionPlan:
        """Build the persistent plan for ``spec`` (called once per CMM miss)."""
        raise NotImplementedError

    def encode(self, plan: ReductionPlan, data: jax.Array) -> Compressed:
        raise NotImplementedError

    def decode(self, plan: ReductionPlan, c: Compressed) -> jax.Array:
        raise NotImplementedError

    def decode_spec(self, c: Compressed) -> ReductionSpec:
        """Spec keying the decode-side plan, recovered from container meta."""
        raise NotImplementedError

    # -- batched execution (engine fan-out) ----------------------------------
    #
    # Codecs whose whole encode chain is jittable can expose a vmappable
    # executable; the execution engine shards a stack of same-spec leaves
    # over the mesh "data" axis with shard_map and splits the results back
    # into per-leaf containers.  Codecs with host-side stages (codebook
    # builds, outlier extraction) leave this off and fan out over executor
    # futures instead.

    supports_batched_encode: bool = False

    def batched_encode_executable(self, plan: ReductionPlan) -> Callable:
        """Jittable ``(k, *spec.shape) -> stacked outputs`` encode, if any."""
        raise NotImplementedError(f"{self.name} has no batched encode path")

    def batched_encode_finish(
        self, plan: ReductionPlan, out: Any, k: int
    ) -> list[Compressed]:
        """Split stacked encode outputs into ``k`` per-leaf containers."""
        raise NotImplementedError(f"{self.name} has no batched encode path")
