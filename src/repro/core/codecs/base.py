"""Codec protocol + plan objects for the HPDR codec registry.

The paper's CMM (§III-B) caches *contexts*: the plan (jitted executable) and
workspace allocations a reduction needs beyond its input/output.  This module
defines what a cached context holds in this framework:

  * :class:`ReductionSpec` — the hashable description of a reduction
    (method, shape, dtype, method parameters).  Its :meth:`ReductionSpec.key`
    is the CMM hash key ("similar data characteristics").
  * :class:`ReductionPlan` — what planning produces: jitted executables bound
    to the spec's static arguments plus persistent workspace buffers
    (level maps, permutations, codebooks) that repeated calls reuse.
  * :class:`Codec` — the three-method protocol every registered compressor
    implements: ``plan(spec)``, ``encode(plan, data)``, ``decode(plan, c)``.

Codecs are stateless; all per-(shape, dtype, params) state lives in the plan,
which the API layer stores in the global CMM so the second call with an
identical spec is a cache hit.
"""

from __future__ import annotations

from dataclasses import dataclass, field
from typing import Any, Callable

import jax

from ..container import Compressed
from ..context import context_key


@dataclass(frozen=True)
class ReductionSpec:
    """Hashable description of one reduction: method + data characteristics."""

    method: str
    shape: tuple[int, ...]
    dtype: str
    params: tuple[tuple[str, Any], ...] = ()

    @classmethod
    def create(
        cls, method: str, shape: tuple[int, ...], dtype: Any, **params: Any
    ) -> "ReductionSpec":
        return cls(
            method=method,
            shape=tuple(int(n) for n in shape),
            dtype=str(dtype),
            params=tuple(sorted(params.items())),
        )

    def param(self, name: str, default: Any = None) -> Any:
        for k, v in self.params:
            if k == name:
                return v
        return default

    def key(self) -> tuple:
        """Canonical CMM hash key for this spec."""
        return context_key(self.method, self.shape, self.dtype, **dict(self.params))


@dataclass
class ReductionPlan:
    """A built plan: jitted executables + persistent workspace buffers.

    ``executables`` maps stage name → jitted callable with the spec's static
    arguments already bound (tracing/compilation happens once per plan).
    ``workspace`` holds device/host arrays that are data-independent for the
    spec (level maps, bin layouts, block permutations) — the paper's
    persistent context allocations.
    """

    spec: ReductionSpec
    executables: dict[str, Callable] = field(default_factory=dict)
    workspace: dict[str, Any] = field(default_factory=dict)
    meta: dict[str, Any] = field(default_factory=dict)

    def nbytes(self) -> int:
        return sum(int(getattr(b, "nbytes", 0)) for b in self.workspace.values())


class Codec:
    """Base class for registered codecs (see :mod:`repro.core.codecs`).

    Subclasses set :attr:`spec_defaults` — the parameter names that belong
    in this codec's :class:`ReductionSpec` (and therefore in its CMM key),
    with their default values — and implement :meth:`plan` / :meth:`encode`
    / :meth:`decode` / :meth:`decode_spec`.
    """

    spec_defaults: dict[str, Any] = {}

    def __init__(self, name: str):
        self.name = name

    @property
    def spec_params(self) -> tuple[str, ...]:
        return tuple(self.spec_defaults)

    def make_spec(self, shape: tuple[int, ...], dtype: Any, **kwargs: Any) -> ReductionSpec:
        """Build a canonical spec from loose kwargs.

        Irrelevant kwargs are dropped and missing ones filled with the
        codec's defaults, so a defaulted call and an explicit-default call
        map to the same CMM key.
        """
        params = {k: kwargs.get(k, d) for k, d in self.spec_defaults.items()}
        return ReductionSpec.create(self.name, shape, dtype, **params)

    # -- protocol ------------------------------------------------------------

    def plan(self, spec: ReductionSpec) -> ReductionPlan:
        """Build the persistent plan for ``spec`` (called once per CMM miss)."""
        raise NotImplementedError

    def encode(self, plan: ReductionPlan, data: jax.Array) -> Compressed:
        raise NotImplementedError

    def decode(self, plan: ReductionPlan, c: Compressed) -> jax.Array:
        raise NotImplementedError

    def decode_spec(self, c: Compressed) -> ReductionSpec:
        """Spec keying the decode-side plan, recovered from container meta."""
        raise NotImplementedError
