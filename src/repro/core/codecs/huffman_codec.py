"""Huffman-X codecs: integer-key entropy coding + the byte-wise variant.

Two registrations of the same stage composition (paper §IV-B, Fig. 6):

  * ``huffman``        lossless entropy coding of integer key arrays — the
                       dictionary size is data-dependent, so the graph opens
                       with a device max-key scan (``alphabet_scan``) and a
                       one-scalar host bind;
  * ``huffman-bytes``  lossless byte-wise coding of arbitrary arrays (fixed
                       256-key alphabet) — the LZ-class baseline analogue.

Both share the device-resident entropy tail declared here as
:data:`ENTROPY_TAIL`: histogram (device) → canonical codebook (the single
host barrier) → code/length gather → prefix-sum + bit-packing (device).
The codebook itself stays per-call metadata, exactly like the GPU
implementations rebuild the tree per buffer while reusing the kernel plan;
decode-side tables derived from it are cached on the plan
(:func:`plan_decode_tables`) so repeated decompress calls are CMM hits.
"""

from __future__ import annotations

import hashlib
import math
from functools import partial

import jax
import jax.numpy as jnp
import numpy as np

from .. import bitstream as bs
from .. import huffman
from .. import stages as sg
from ..container import Compressed
from . import register_codec
from .base import Codec, ReductionPlan, ReductionSpec

def entropy_tail_stages(num_bins: int | None = None) -> tuple:
    """The shared entropy tail, with a plan-static alphabet when known."""
    return (
        sg.HuffmanHistogram(num_bins),
        sg.CodebookBuild(),
        sg.HuffmanEntropy(),
        sg.BitPack(),
    )


def entropy_container(
    plan: ReductionPlan, env, view, method: str,
    shape: tuple, dtype, n_symbols: int,
) -> Compressed:
    """Serialise the entropy tail's pipeline state (exact-sized fetches).

    The word stream is sliced on device to ``words_needed(total_bits)``
    before the D2H copy (the exact count is host-known from
    ``freq · lengths``), so the transfer is the compressed size, never the
    padded device buffer.  Layout matches the historical host encoder
    byte-for-byte; the per-stage metadata rides in ``meta["stages"]``.
    """
    total_bits = int(env.meta["total_bits"])
    c = Compressed(
        method=method,
        meta={
            "shape": tuple(shape), "dtype": str(dtype),
            "chunk_size": int(env.meta["chunk_size"]),
            "total_bits": total_bits,
            "n_symbols": int(n_symbols),
            "num_keys": int(env.meta["num_keys"]),
        },
        arrays={
            "words": view.fetch("words", max(1, bs.words_needed(total_bits))),
            "chunk_offsets": view.fetch("chunk_offsets"),
            "length_table": np.asarray(env.meta["length_table"], np.int32),
        },
    )
    c.meta["stages"] = plan.meta.get("stage_graph", [])
    return c


def sections_to_encoded(c: Compressed) -> huffman.Encoded:
    return huffman.Encoded(
        words=jnp.asarray(c.arrays["words"]),
        total_bits=int(c.meta["total_bits"]),
        n_symbols=int(c.meta["n_symbols"]),
        chunk_size=int(c.meta["chunk_size"]),
        chunk_offsets=jnp.asarray(c.arrays["chunk_offsets"]),
        length_table=np.asarray(c.arrays["length_table"]),
        num_keys=int(c.meta["num_keys"]),
    )


_MAX_DECODE_TABLES = 8  # per-plan cap on cached decode-table variants


def plan_decode_tables(plan: ReductionPlan, length_table: np.ndarray):
    """Decode tables for ``length_table``, cached in the plan workspace.

    Keyed by the table's digest, so streams written with the same codebook
    (the common case: same data characteristics, repeated decompress calls)
    reuse one derived + device-staged table set, and CMM byte accounting
    sees them.  Bounded FIFO per plan.
    """
    lt = np.ascontiguousarray(np.asarray(length_table, np.int32))
    key = "decode_tables:" + hashlib.sha1(lt.tobytes()).hexdigest()
    with plan.lock:
        tables = plan.workspace.get(key)
    if tables is not None:
        return tables
    tables = huffman.decode_tables(lt)
    with plan.lock:
        tables = plan.workspace.setdefault(key, tables)
        cached = [k for k in plan.workspace
                  if isinstance(k, str) and k.startswith("decode_tables:")]
        for stale in cached[:-_MAX_DECODE_TABLES]:
            del plan.workspace[stale]
    return tables


@register_codec("huffman")
class HuffmanCodec(Codec):
    """Entropy coding of integer keys (alphabet sized per call)."""

    spec_defaults = {}

    def build_stages(self, spec: ReductionSpec) -> sg.StageGraph:
        return sg.StageGraph(
            stages=(sg.IntKeys(), sg.AlphabetScan(), sg.AlphabetBind())
            + entropy_tail_stages(),
            finish_keys=("words", "chunk_offsets"),
        )

    def plan(self, spec: ReductionSpec) -> ReductionPlan:
        spec = spec.resolved()
        # legacy per-stage executables stay addressable; the compiled stage
        # pipeline is what encode (and the engine's stacked path) runs
        plan = ReductionPlan(
            spec=spec,
            executables={
                "histogram": partial(huffman.histogram_op, adapter=spec.backend),
                "encode": partial(huffman.encode, adapter=spec.backend),
                "decode": huffman.decode,
            },
        )
        return self._attach_pipeline(plan)

    def encode(self, plan: ReductionPlan, data: jax.Array, **hooks) -> Compressed:
        data = jnp.asarray(data)
        if not jnp.issubdtype(data.dtype, jnp.integer):
            raise ValueError("huffman method expects integer keys; use huffman-bytes")
        return super().encode(plan, data, **hooks)

    def finish_container(self, plan, env, view) -> Compressed:
        spec = plan.spec
        return entropy_container(
            plan, env, view, self.name, spec.shape, spec.dtype,
            n_symbols=math.prod(spec.shape),
        )

    def decode(self, plan: ReductionPlan, c: Compressed) -> jax.Array:
        enc = sections_to_encoded(c)
        keys = huffman.decode(enc, tables=plan_decode_tables(plan, enc.length_table))
        return keys.reshape(tuple(c.meta["shape"])).astype(jnp.dtype(c.meta["dtype"]))

    def decode_spec(self, c: Compressed) -> ReductionSpec:
        return ReductionSpec.create(self.name, c.meta["shape"], c.meta["dtype"])


@register_codec("huffman-bytes")
class HuffmanBytesCodec(Codec):
    """Byte-wise lossless coding of arbitrary arrays (fixed 256-key alphabet)."""

    spec_defaults = {}

    def build_stages(self, spec: ReductionSpec) -> sg.StageGraph:
        return sg.StageGraph(
            stages=(sg.ByteKeys(),) + entropy_tail_stages(num_bins=256),
            finish_keys=("words", "chunk_offsets"),
        )

    def plan(self, spec: ReductionSpec) -> ReductionPlan:
        spec = spec.resolved()
        plan = ReductionPlan(
            spec=spec,
            executables={
                "histogram": partial(
                    huffman.histogram_op, num_bins=256, adapter=spec.backend
                ),
                "encode": partial(huffman.encode, adapter=spec.backend),
                "decode": huffman.decode,
            },
        )
        return self._attach_pipeline(plan)

    def encode(
        self, plan: ReductionPlan, data: jax.Array, *,
        env=None, profile: dict | None = None,
    ) -> Compressed:
        # The byte view is a host reinterpretation (no copy for contiguous
        # input); the engine's stacked path arrives here pre-viewed by
        # leaf_policy, so both shapes feed the pipeline identical bytes.
        byte_view = np.ascontiguousarray(np.asarray(data)).view(np.uint8)
        state, env = plan.pipeline.run({"data": byte_view}, env=env,
                                       profile=profile)
        return self.finish_container(
            plan, env, sg.LeafView(state, None, env)
        )

    def finish_container(self, plan, env, view) -> Compressed:
        spec = plan.spec
        n_symbols = math.prod(spec.shape) * np.dtype(spec.dtype).itemsize
        return entropy_container(
            plan, env, view, self.name, spec.shape, spec.dtype,
            n_symbols=n_symbols,
        )

    def decode(self, plan: ReductionPlan, c: Compressed) -> jax.Array:
        enc = sections_to_encoded(c)
        keys = np.asarray(
            huffman.decode(enc, tables=plan_decode_tables(plan, enc.length_table))
        )
        byte_view = keys.astype(np.uint8)
        return jnp.asarray(
            byte_view.view(np.dtype(c.meta["dtype"])).reshape(tuple(c.meta["shape"]))
        )

    def decode_spec(self, c: Compressed) -> ReductionSpec:
        return ReductionSpec.create(self.name, c.meta["shape"], c.meta["dtype"])
