"""Huffman-X codecs: integer-key entropy coding + the byte-wise variant.

Two registrations of the same stage composition (paper §IV-B, Fig. 6):

  * ``huffman``        lossless entropy coding of integer key arrays — the
                       dictionary size is data-dependent, so the graph opens
                       with a device max-key scan (``alphabet_scan``) and a
                       one-scalar host bind;
  * ``huffman-bytes``  lossless byte-wise coding of arbitrary arrays (fixed
                       256-key alphabet) — the LZ-class baseline analogue.

Both share the device-resident entropy tail declared here as
:data:`ENTROPY_TAIL`: histogram (device) → canonical codebook (the single
host barrier) → code/length gather → prefix-sum + bit-packing (device).
The codebook itself stays per-call metadata, exactly like the GPU
implementations rebuild the tree per buffer while reusing the kernel plan;
decode-side tables derived from it are cached on the plan
(:func:`plan_decode_tables`) so repeated decompress calls are CMM hits.
"""

from __future__ import annotations

import math
from functools import partial
from typing import Any

import jax
import jax.numpy as jnp
import numpy as np

from .. import bitstream as bs
from .. import huffman
from .. import stages as sg
from ..container import Compressed, ContainerError
from . import register_codec
from .base import Codec, ReductionPlan, ReductionSpec

def entropy_tail_stages(
    num_bins: int | None = None, chunk_size: int = huffman.DEFAULT_CHUNK
) -> tuple:
    """The shared entropy tail, with a plan-static alphabet when known.

    ``chunk_size`` sets the self-synchronisation granularity of the packed
    stream (symbols per independently-decodable chunk) — smaller chunks
    buy more decode parallelism for more ``chunk_offsets`` overhead.
    """
    return (
        sg.HuffmanHistogram(num_bins),
        sg.CodebookBuild(chunk_size),
        sg.HuffmanEntropy(),
        sg.BitPack(chunk_size),
    )


# decode-direction graph parameters shared by every entropy-tail codec: the
# compressed sections that seed the inverse state, and the 4 KiB word-stream
# bucket that bounds inverse retraces across stream sizes (the decode
# analogue of BitPack.jit_statics)
ENTROPY_INV_INPUTS = ("words", "chunk_offsets")
ENTROPY_INV_PADS = (("words", 1024),)


def entropy_container(
    plan: ReductionPlan, env, view, method: str,
    shape: tuple, dtype, n_symbols: int,
) -> Compressed:
    """Serialise the entropy tail's pipeline state (exact-sized fetches).

    The word stream is sliced on device to ``words_needed(total_bits)``
    before the D2H copy (the exact count is host-known from
    ``freq · lengths``), so the transfer is the compressed size, never the
    padded device buffer.  Layout matches the historical host encoder
    byte-for-byte; the per-stage metadata rides in ``meta["stages"]``.
    """
    total_bits = int(env.meta["total_bits"])
    c = Compressed(
        method=method,
        meta={
            "shape": tuple(shape), "dtype": str(dtype),
            "chunk_size": int(env.meta["chunk_size"]),
            "total_bits": total_bits,
            "n_symbols": int(n_symbols),
            "num_keys": int(env.meta["num_keys"]),
        },
        arrays={
            "words": view.fetch("words", max(1, bs.words_needed(total_bits))),
            "chunk_offsets": view.fetch("chunk_offsets"),
            "length_table": np.asarray(env.meta["length_table"], np.int32),
        },
    )
    # Per-stage metadata plus the decode chunk index: the bit_pack entry
    # records the chunk layout the chunk-parallel decoder fans out over.
    # Purely additive (still container v2); readers seeing a stream without
    # it — anything written before the stacked decode path existed — take
    # the host-orchestrated fallback (see stream_decode_index).
    n_chunks = int(c.arrays["chunk_offsets"].shape[0])
    stages = [dict(s) for s in plan.meta.get("stage_graph", [])]
    for s in stages:
        if s.get("stage") == "bit_pack":
            s["decode_index"] = {
                "n_chunks": n_chunks,
                "chunk_size": int(env.meta["chunk_size"]),
                "n_symbols": int(n_symbols),
            }
    c.meta["stages"] = stages
    return c


def stream_decode_index(c: Compressed) -> dict | None:
    """The stream's decode chunk index, or None for pre-index streams."""
    for s in c.meta.get("stages", ()) or ():
        if isinstance(s, dict) and s.get("stage") == "bit_pack":
            idx = s.get("decode_index")
            return dict(idx) if isinstance(idx, dict) else None
    return None


def entropy_decode_state(
    plan: ReductionPlan, c: Compressed
) -> tuple[dict, dict] | None:
    """Inverse-pipeline state for an entropy-tail stream (None: fallback).

    The state is exactly the compressed sections — ``words`` and the
    prefix-sum ``chunk_offsets`` the encoder persisted — so staging it is an
    H2D of the compressed bytes, nothing else.  The env metadata carries
    what the decode-direction host prepares consume (length table, chunk
    geometry); old streams without the chunk index return None and decode
    through the host path.  A *present but inconsistent* index is
    corruption, not age: it raises :class:`ContainerError` instead of
    silently decoding under the wrong chunk geometry.
    """
    idx = stream_decode_index(c)
    if idx is None:
        return None
    expected = {
        "n_chunks": int(c.arrays["chunk_offsets"].shape[0]),
        "chunk_size": int(c.meta["chunk_size"]),
        "n_symbols": int(c.meta["n_symbols"]),
    }
    for key, want in expected.items():
        if key not in idx or int(idx[key]) != want:
            raise ContainerError(
                f"corrupt HPDR stream: decode_index {key}={idx.get(key)!r} "
                f"disagrees with container metadata ({want})"
            )
    state0 = {
        "words": np.asarray(c.arrays["words"], np.uint32),
        "chunk_offsets": np.asarray(c.arrays["chunk_offsets"], np.int32),
    }
    meta = {
        "length_table": np.asarray(c.arrays["length_table"], np.int32),
        "chunk_size": int(idx["chunk_size"]),
        "n_symbols": int(idx["n_symbols"]),
        "num_keys": int(c.meta["num_keys"]),
        "total_bits": int(c.meta["total_bits"]),
    }
    return state0, meta


def entropy_bucket_key(c: Compressed) -> tuple:
    """Decode-geometry group key for entropy-tail streams.

    Streams with differing ``chunk_size`` bake different statics into the
    fused inverse executable, so the engine must not stack them into one
    dispatch (the old behaviour merged statics by max and decoded the
    smaller-chunk streams as garbage — ROADMAP mixed-chunk-size item).
    """
    return ("chunk_size", int(c.meta["chunk_size"]))


def sections_to_encoded(c: Compressed) -> huffman.Encoded:
    return huffman.Encoded(
        words=jnp.asarray(c.arrays["words"]),
        total_bits=int(c.meta["total_bits"]),
        n_symbols=int(c.meta["n_symbols"]),
        chunk_size=int(c.meta["chunk_size"]),
        chunk_offsets=jnp.asarray(c.arrays["chunk_offsets"]),
        length_table=np.asarray(c.arrays["length_table"]),
        num_keys=int(c.meta["num_keys"]),
    )


# Decode tables live in core.huffman since PR 4 so the stage library's
# decode-direction prepare step shares the same per-plan cache without a
# codecs → stages import cycle; this alias keeps the historical import path.
plan_decode_tables = huffman.plan_decode_tables


@register_codec("huffman")
class HuffmanCodec(Codec):
    """Entropy coding of integer keys (alphabet sized per call).

    ``chunk_size`` is an encode-side spec parameter: the number of symbols
    per independently-decodable packed chunk.  The default
    (:data:`repro.core.huffman.DEFAULT_CHUNK`) is canonicalised *out* of
    the spec key, so default encode specs and the (parameter-free) decode
    spec keep sharing one CMM plan; a non-default chunk size gets its own
    plan.  Decode always reads the geometry from the container, so one
    decode plan serves streams of any chunk size (grouped per geometry on
    the engine's stacked path).
    """

    spec_defaults = {}

    def make_spec(self, shape, dtype, **kwargs) -> ReductionSpec:
        import dataclasses

        chunk = int(kwargs.pop("chunk_size", huffman.DEFAULT_CHUNK))
        spec = super().make_spec(shape, dtype, **kwargs)
        if chunk != huffman.DEFAULT_CHUNK:
            spec = dataclasses.replace(spec, params=(("chunk_size", chunk),))
        return spec

    def build_stages(self, spec: ReductionSpec) -> sg.StageGraph:
        chunk = int(spec.param("chunk_size", huffman.DEFAULT_CHUNK))
        return sg.StageGraph(
            stages=(sg.IntKeys(), sg.AlphabetScan(), sg.AlphabetBind())
            + entropy_tail_stages(chunk_size=chunk),
            finish_keys=("words", "chunk_offsets"),
            inv_inputs=ENTROPY_INV_INPUTS,
            inv_pads=ENTROPY_INV_PADS,
        )

    def plan(self, spec: ReductionSpec) -> ReductionPlan:
        spec = spec.resolved()
        # legacy per-stage executables stay addressable; the compiled stage
        # pipeline is what encode (and the engine's stacked path) runs
        plan = ReductionPlan(
            spec=spec,
            executables={
                "histogram": partial(huffman.histogram_op, adapter=spec.backend),
                "encode": partial(huffman.encode, adapter=spec.backend),
                "decode": huffman.decode,
            },
        )
        return self._attach_pipeline(plan)

    def encode_input(self, plan: ReductionPlan, data: Any) -> dict:
        data = jnp.asarray(data)
        if not jnp.issubdtype(data.dtype, jnp.integer):
            raise ValueError("huffman method expects integer keys; use huffman-bytes")
        return {"data": data}

    def finish_container(self, plan, env, view) -> Compressed:
        spec = plan.spec
        return entropy_container(
            plan, env, view, self.name, spec.shape, spec.dtype,
            n_symbols=math.prod(spec.shape),
        )

    def decode_state(self, plan: ReductionPlan, c: Compressed):
        return entropy_decode_state(plan, c)

    def decode_bucket_key(self, c: Compressed) -> tuple:
        return entropy_bucket_key(c)

    def decode(
        self, plan: ReductionPlan, c: Compressed, *,
        env=None, profile: dict | None = None,
    ) -> jax.Array:
        out = self._pipeline_decode(plan, c, env=env, profile=profile)
        if out is not None:
            return out
        # host fallback: streams without a decode chunk index
        enc = sections_to_encoded(c)
        keys = huffman.decode(enc, tables=plan_decode_tables(plan, enc.length_table))
        return keys.reshape(tuple(c.meta["shape"])).astype(jnp.dtype(c.meta["dtype"]))

    def decode_spec(self, c: Compressed) -> ReductionSpec:
        return ReductionSpec.create(self.name, c.meta["shape"], c.meta["dtype"])


@register_codec("huffman-bytes")
class HuffmanBytesCodec(Codec):
    """Byte-wise lossless coding of arbitrary arrays (fixed 256-key alphabet)."""

    spec_defaults = {}

    def build_stages(self, spec: ReductionSpec) -> sg.StageGraph:
        return sg.StageGraph(
            stages=(sg.ByteKeys(),) + entropy_tail_stages(num_bins=256),
            finish_keys=("words", "chunk_offsets"),
            inv_inputs=ENTROPY_INV_INPUTS,
            inv_pads=ENTROPY_INV_PADS,
        )

    def plan(self, spec: ReductionSpec) -> ReductionPlan:
        spec = spec.resolved()
        plan = ReductionPlan(
            spec=spec,
            executables={
                "histogram": partial(
                    huffman.histogram_op, num_bins=256, adapter=spec.backend
                ),
                "encode": partial(huffman.encode, adapter=spec.backend),
                "decode": huffman.decode,
            },
        )
        return self._attach_pipeline(plan)

    def encode_input(self, plan: ReductionPlan, data: Any) -> dict:
        # The byte view is a host reinterpretation (no copy for contiguous
        # input); the engine's stacked path arrives here pre-viewed by
        # leaf_policy, so every execution shape — serial, stacked, and the
        # chunk-pipelined stream — feeds the pipeline identical bytes.
        return {"data": np.ascontiguousarray(np.asarray(data)).view(np.uint8)}

    def finish_container(self, plan, env, view) -> Compressed:
        spec = plan.spec
        n_symbols = math.prod(spec.shape) * np.dtype(spec.dtype).itemsize
        return entropy_container(
            plan, env, view, self.name, spec.shape, spec.dtype,
            n_symbols=n_symbols,
        )

    def decode_bucket_key(self, c: Compressed) -> tuple:
        return entropy_bucket_key(c)

    def decode_state(self, plan: ReductionPlan, c: Compressed):
        # the device-side inverse byte view is a bitcast, only expressible
        # for plain 1/2/4-byte element types — anything else (8-byte
        # doubles under 32-bit jax, structured dtypes) stays on the host
        # fallback, which reinterprets via numpy
        dt = np.dtype(plan.spec.dtype)
        if dt.kind not in "iuf" or dt.itemsize not in (1, 2, 4):
            return None
        return entropy_decode_state(plan, c)

    def decode(
        self, plan: ReductionPlan, c: Compressed, *,
        env=None, profile: dict | None = None,
    ) -> jax.Array:
        out = self._pipeline_decode(plan, c, env=env, profile=profile)
        if out is not None:
            return out
        enc = sections_to_encoded(c)
        keys = np.asarray(
            huffman.decode(enc, tables=plan_decode_tables(plan, enc.length_table))
        )
        byte_view = keys.astype(np.uint8)
        return jnp.asarray(
            byte_view.view(np.dtype(c.meta["dtype"])).reshape(tuple(c.meta["shape"]))
        )

    def decode_spec(self, c: Compressed) -> ReductionSpec:
        return ReductionSpec.create(self.name, c.meta["shape"], c.meta["dtype"])
