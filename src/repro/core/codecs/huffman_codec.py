"""Huffman-X codecs: integer-key entropy coding + the byte-wise variant.

Two registrations of the same machinery (paper §IV-B):

  * ``huffman``        lossless entropy coding of integer key arrays — the
                       dictionary size is data-dependent (max key + 1), so it
                       lives in the container meta, not the spec;
  * ``huffman-bytes``  lossless byte-wise coding of arbitrary arrays (256-key
                       alphabet) — the LZ-class baseline analogue.

The plan pins the jitted histogram executable; the codebook itself is
data-dependent (per-call), exactly like the GPU implementations rebuild the
tree per buffer while reusing the kernel plan.
"""

from __future__ import annotations

from functools import partial

import jax
import jax.numpy as jnp
import numpy as np

from .. import huffman
from ..container import Compressed
from . import register_codec
from .base import Codec, ReductionPlan, ReductionSpec


def encoded_to_sections(enc: huffman.Encoded, shape, dtype, method) -> Compressed:
    """Pack a :class:`huffman.Encoded` into a method-tagged container."""
    return Compressed(
        method=method,
        meta={
            "shape": tuple(shape), "dtype": str(dtype),
            "chunk_size": enc.chunk_size, "total_bits": enc.total_bits,
            "n_symbols": enc.n_symbols, "num_keys": enc.num_keys,
        },
        arrays={
            "words": np.asarray(enc.words),
            "chunk_offsets": np.asarray(enc.chunk_offsets),
            "length_table": enc.length_table,
        },
    )


def sections_to_encoded(c: Compressed) -> huffman.Encoded:
    return huffman.Encoded(
        words=jnp.asarray(c.arrays["words"]),
        total_bits=int(c.meta["total_bits"]),
        n_symbols=int(c.meta["n_symbols"]),
        chunk_size=int(c.meta["chunk_size"]),
        chunk_offsets=jnp.asarray(c.arrays["chunk_offsets"]),
        length_table=np.asarray(c.arrays["length_table"]),
        num_keys=int(c.meta["num_keys"]),
    )


@register_codec("huffman")
class HuffmanCodec(Codec):
    """Entropy coding of integer keys (alphabet sized per call)."""

    spec_defaults = {}

    def plan(self, spec: ReductionSpec) -> ReductionPlan:
        spec = spec.resolved()
        # adapter-bound DEM-global histogram + encode-lookup; the codebook
        # build is per-call metadata (host scale) under every backend
        return ReductionPlan(
            spec=spec,
            executables={
                "histogram": partial(huffman.histogram_op, adapter=spec.backend),
                "encode": partial(huffman.encode, adapter=spec.backend),
                "decode": huffman.decode,
            },
        )

    def encode(self, plan: ReductionPlan, data: jax.Array) -> Compressed:
        data = jnp.asarray(data)
        if not jnp.issubdtype(data.dtype, jnp.integer):
            raise ValueError("huffman method expects integer keys; use huffman-bytes")
        num_keys = int(jnp.max(data)) + 1
        freq = np.asarray(plan.executables["histogram"](data, num_keys))
        book = huffman.build_codebook(freq)
        enc = plan.executables["encode"](data, book)
        return encoded_to_sections(enc, data.shape, data.dtype, self.name)

    def decode(self, plan: ReductionPlan, c: Compressed) -> jax.Array:
        keys = plan.executables["decode"](sections_to_encoded(c))
        return keys.reshape(tuple(c.meta["shape"])).astype(jnp.dtype(c.meta["dtype"]))

    def decode_spec(self, c: Compressed) -> ReductionSpec:
        return ReductionSpec.create(self.name, c.meta["shape"], c.meta["dtype"])


@register_codec("huffman-bytes")
class HuffmanBytesCodec(Codec):
    """Byte-wise lossless coding of arbitrary arrays (fixed 256-key alphabet)."""

    spec_defaults = {}

    def plan(self, spec: ReductionSpec) -> ReductionPlan:
        spec = spec.resolved()
        return ReductionPlan(
            spec=spec,
            executables={
                "histogram": partial(
                    huffman.histogram_op, num_bins=256, adapter=spec.backend
                ),
                "encode": partial(huffman.encode, adapter=spec.backend),
                "decode": huffman.decode,
            },
        )

    def encode(self, plan: ReductionPlan, data: jax.Array) -> Compressed:
        orig_dtype = np.asarray(data).dtype
        byte_keys = jnp.asarray(
            np.ascontiguousarray(np.asarray(data)).view(np.uint8)
        ).astype(jnp.int32)
        freq = np.asarray(plan.executables["histogram"](byte_keys))
        book = huffman.build_codebook(freq)
        enc = plan.executables["encode"](byte_keys, book)
        return encoded_to_sections(enc, np.shape(data), orig_dtype, self.name)

    def decode(self, plan: ReductionPlan, c: Compressed) -> jax.Array:
        keys = np.asarray(plan.executables["decode"](sections_to_encoded(c)))
        byte_view = keys.astype(np.uint8)
        return jnp.asarray(
            byte_view.view(np.dtype(c.meta["dtype"])).reshape(tuple(c.meta["shape"]))
        )

    def decode_spec(self, c: Compressed) -> ReductionSpec:
        return ReductionSpec.create(self.name, c.meta["shape"], c.meta["dtype"])
