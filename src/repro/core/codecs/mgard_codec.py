"""MGARD-X codec: error-bounded lossy compression behind the registry.

The plan carries everything that depends only on (shape, dtype, dict_size):
the padded dyadic grid, the level map as a persistent device buffer, the
level count, and the jitted decompose/quantize/dequantize/recompose
executables with their static arguments bound.  Per-call work is reduced to
the data-dependent parts — value range (relative bounds), bin schedule,
entropy coding — which is exactly the split the paper's CMM caches.
"""

from __future__ import annotations

from functools import partial

import jax
import jax.numpy as jnp
import numpy as np

from .. import huffman, mgard
from ..container import Compressed
from ..quantize import unsigned_to_signed
from . import register_codec
from .base import Codec, ReductionPlan, ReductionSpec
from .huffman_codec import encoded_to_sections, sections_to_encoded

_unsigned_to_signed_jit = jax.jit(unsigned_to_signed)


@register_codec("mgard")
class MGARDCodec(Codec):
    """Multigrid error-bounded compression (paper §IV-A, Algorithm 1)."""

    spec_defaults = {"error_bound": 1e-2, "relative": True, "dict_size": 4096}

    def plan(self, spec: ReductionSpec) -> ReductionPlan:
        spec = spec.resolved()
        shape = spec.shape
        dict_size = int(spec.param("dict_size", 4096))
        padded = tuple(mgard.padded_dim(n) for n in shape)
        L = mgard.total_levels(padded)
        # Backend binding: the quantize/dequantize Map&Process stages and the
        # entropy stage dispatch through the kernel registry with the spec's
        # adapter baked in; decompose/recompose stay on the portable jnp path
        # under every backend (no per-backend kernel exists for them — the
        # paper's fallback rule), which also keeps the produced bitstream
        # backend-independent.  The level map is *donated* to the planned
        # stages and the recycled buffer re-stored (true in-place workspace
        # recycling where the platform supports donation).
        return ReductionPlan(
            spec=spec,
            executables={
                "decompose": partial(mgard.decompose, shape=shape),
                "recompose": partial(mgard.recompose, shape=shape),
                "quantize": mgard.planned_quantize_stage(
                    padded, dict_size, spec.backend
                ),
                "dequantize": mgard.planned_dequantize_stage(spec.backend),
            },
            workspace={"lmap": jnp.asarray(mgard.level_map(padded))},
            meta={"padded": padded, "L": L, "dict_size": dict_size,
                  "backend": spec.backend},
        )

    def encode(self, plan: ReductionPlan, data: jax.Array) -> Compressed:
        spec = plan.spec
        data = jnp.asarray(data)
        eb0 = float(spec.param("error_bound", 1e-2))
        relative = bool(spec.param("relative", True))
        dict_size = plan.meta["dict_size"]
        if relative:
            vrange = float(jnp.max(data) - jnp.min(data))
            eb = eb0 * vrange
        else:
            eb = eb0
        eb = eb if eb > 0 else eb0

        coeffs = plan.executables["decompose"](data)
        L = plan.meta["L"]
        bins = mgard.level_bins(eb, L)
        # Workspace donation: the executable consumes the level map and
        # returns the recycled buffer; serialize access so concurrent engine
        # workers sharing this plan never donate the same buffer twice.
        with plan.lock:
            q, keys, inlier, lmap = plan.executables["quantize"](
                coeffs, plan.workspace["lmap"], jnp.asarray(bins, jnp.float32)
            )
            plan.recycle("lmap", lmap)
        # Outliers: stored losslessly (sparse), like MGARD's escape path.
        inlier_np = np.asarray(inlier).reshape(-1)
        out_idx = np.nonzero(~inlier_np)[0]
        out_val = np.asarray(q).reshape(-1)[out_idx]
        enc = huffman.compress(keys, dict_size, adapter=plan.meta["backend"])

        c = encoded_to_sections(enc, data.shape, data.dtype, self.name)
        c.meta.update(
            padded=plan.meta["padded"],
            error_bound=float(eb),
            dict_size=dict_size,
        )
        c.arrays.update(
            outlier_idx=out_idx.astype(np.int64),
            outlier_val=out_val.astype(np.int32),
            bins=bins,
        )
        return c

    def decode(self, plan: ReductionPlan, c: Compressed) -> jax.Array:
        keys = huffman.decompress(sections_to_encoded(c))
        q = _unsigned_to_signed_jit(keys.astype(jnp.uint32))
        qf = np.asarray(q).reshape(-1)
        out_idx = np.asarray(c.arrays["outlier_idx"])
        if out_idx.size:
            qf = qf.copy()
            qf[out_idx] = np.asarray(c.arrays["outlier_val"])
        q = jnp.asarray(qf.reshape(plan.meta["padded"]))
        with plan.lock:
            coeffs, lmap = plan.executables["dequantize"](
                q, plan.workspace["lmap"],
                jnp.asarray(np.asarray(c.arrays["bins"]), jnp.float32),
            )
            plan.recycle("lmap", lmap)
        out = plan.executables["recompose"](coeffs)
        return out.astype(jnp.dtype(c.meta["dtype"]))

    def decode_spec(self, c: Compressed) -> ReductionSpec:
        # Decode plans depend only on geometry + dict size: streams written
        # with any error bound share one reconstruction plan.
        return ReductionSpec.create(
            self.name, c.meta["shape"], c.meta["dtype"],
            dict_size=int(c.meta["dict_size"]),
        )
