"""MGARD-X codec: error-bounded lossy compression behind the registry.

Declared as the full stage graph of paper Algorithm 1:

    mgard_decorrelate → [bin_schedule] → uniform_quantize →
    huffman_histogram → [codebook_build] → huffman_entropy → bit_pack

Bracketed stages are the two host barriers — the bin schedule reads one
(vmin, vmax) scalar pair and the codebook build reads the dict-size
histogram; everything else, *including the entropy stage and the escape
(outlier) compaction*, is device-resident.  The compiled pipeline therefore
has three fused device segments, which is what lets MGARD buckets ride the
execution engine's stacked ``shard_map`` path instead of fanning out over
host futures.

The plan still carries the classic per-stage executables
(decompose/recompose/quantize/dequantize) with the level map as a donated
persistent workspace buffer — the progressive refactor path shares them via
the same CMM entries.
"""

from __future__ import annotations

import math
from functools import partial

import jax
import jax.numpy as jnp
import numpy as np

from .. import huffman, mgard
from .. import stages as sg
from ..container import Compressed
from ..quantize import unsigned_to_signed
from . import register_codec
from .base import Codec, ReductionPlan, ReductionSpec
from .huffman_codec import (
    ENTROPY_INV_INPUTS,
    ENTROPY_INV_PADS,
    entropy_bucket_key,
    entropy_container,
    entropy_decode_state,
    entropy_tail_stages,
    plan_decode_tables,
    sections_to_encoded,
)

_unsigned_to_signed_jit = jax.jit(unsigned_to_signed)

# Outlier slots pad to this bucket (bounds inverse retraces across streams
# with differing escape counts) using an out-of-range index sentinel, which
# the device scatter drops — a negative fill would wrap.
_OUT_BUCKET = 64
_OUT_SENTINEL = np.int32(2**31 - 1)


@register_codec("mgard")
class MGARDCodec(Codec):
    """Multigrid error-bounded compression (paper §IV-A, Algorithm 1)."""

    spec_defaults = {"error_bound": 1e-2, "relative": True, "dict_size": 4096}

    def build_stages(self, spec: ReductionSpec) -> sg.StageGraph:
        shape = spec.shape
        dict_size = int(spec.param("dict_size", 4096))
        padded = tuple(mgard.padded_dim(n) for n in shape)
        L = mgard.total_levels(padded)
        return sg.StageGraph(
            stages=(
                sg.MgardDecorrelate(shape),
                sg.BinSchedule(
                    float(spec.param("error_bound", 1e-2)),
                    bool(spec.param("relative", True)),
                    L,
                ),
                sg.UniformQuantize(padded, dict_size),
            )
            + entropy_tail_stages(num_bins=dict_size),
            # q/keys stay device-resident; they are only fetched on the rare
            # outlier-cap overflow fallback (see finish_container)
            finish_keys=(
                "words", "chunk_offsets",
                "out_count", "out_idx", "out_val", "q", "keys",
            ),
            inv_inputs=ENTROPY_INV_INPUTS + ("out_idx", "out_val"),
            inv_pads=ENTROPY_INV_PADS,
            inv_fills=(("out_idx", int(_OUT_SENTINEL)),),
        )

    def plan(self, spec: ReductionSpec) -> ReductionPlan:
        spec = spec.resolved()
        shape = spec.shape
        dict_size = int(spec.param("dict_size", 4096))
        padded = tuple(mgard.padded_dim(n) for n in shape)
        L = mgard.total_levels(padded)
        # Classic executables (shared with core/progressive.py): the
        # quantize/dequantize Map&Process stages dispatch through the kernel
        # registry with the spec's adapter baked in; decompose/recompose
        # stay on the portable jnp path under every backend (the paper's
        # fallback rule), which also keeps streams backend-independent.
        # The level map is *donated* to the planned stages and the recycled
        # buffer re-stored — the stage pipeline's quantize segment routes
        # through the same workspace buffer and the same donation contract.
        plan = ReductionPlan(
            spec=spec,
            executables={
                "decompose": partial(mgard.decompose, shape=shape),
                "recompose": partial(mgard.recompose, shape=shape),
                "quantize": mgard.planned_quantize_stage(
                    padded, dict_size, spec.backend
                ),
                "dequantize": mgard.planned_dequantize_stage(spec.backend),
            },
            workspace={"lmap": jnp.asarray(mgard.level_map(padded))},
            meta={"padded": padded, "L": L, "dict_size": dict_size,
                  "backend": spec.backend},
        )
        return self._attach_pipeline(plan)

    def finish_container(self, plan, env, view) -> Compressed:
        spec = plan.spec
        dict_size = plan.meta["dict_size"]
        c = entropy_container(
            plan, env, view, self.name, spec.shape, spec.dtype,
            n_symbols=math.prod(plan.meta["padded"]),
        )
        # Outliers: stored losslessly (sparse), like MGARD's escape path.
        # The device compaction bounds the fetch to the occupied slots; a
        # leaf overflowing the cap falls back to a full fetch (escape keys
        # mark the outlier positions exactly).
        n_out = int(view.fetch("out_count"))
        if n_out <= plan.meta["out_cap"]:
            out_idx = view.fetch("out_idx", n_out).astype(np.int64)
            out_val = view.fetch("out_val", n_out).astype(np.int32)
        else:
            keys = view.fetch("keys").reshape(-1)
            qf = view.fetch("q").reshape(-1)
            out_idx = np.nonzero(keys == dict_size - 1)[0].astype(np.int64)
            out_val = qf[out_idx].astype(np.int32)
        c.meta.update(
            padded=plan.meta["padded"],
            error_bound=float(env.meta["error_bound"]),
            dict_size=dict_size,
        )
        c.arrays.update(
            outlier_idx=out_idx,
            outlier_val=out_val,
            bins=np.asarray(env.meta["bins"], np.float64),
        )
        return c

    def decode_bucket_key(self, c: Compressed) -> tuple:
        return entropy_bucket_key(c)

    def decode_state(self, plan: ReductionPlan, c: Compressed):
        prepared = entropy_decode_state(plan, c)
        if prepared is None:
            return None
        state0, meta = prepared
        out_idx = np.asarray(c.arrays["outlier_idx"], np.int64)
        if out_idx.size and out_idx.max(initial=0) >= int(_OUT_SENTINEL):
            return None  # grid too large for the int32 scatter: host path
        pad = (-out_idx.size) % _OUT_BUCKET
        state0["out_idx"] = np.concatenate(
            [out_idx.astype(np.int32), np.full(pad, _OUT_SENTINEL, np.int32)]
        )
        state0["out_val"] = np.concatenate(
            [np.asarray(c.arrays["outlier_val"], np.int32), np.zeros(pad, np.int32)]
        )
        meta["bins"] = np.asarray(c.arrays["bins"], np.float64)
        return state0, meta

    def decode(
        self, plan: ReductionPlan, c: Compressed, *,
        env=None, profile: dict | None = None,
    ) -> jax.Array:
        out = self._pipeline_decode(plan, c, env=env, profile=profile)
        if out is not None:
            return out
        # host fallback: streams without a decode chunk index
        enc = sections_to_encoded(c)
        keys = huffman.decode(enc, tables=plan_decode_tables(plan, enc.length_table))
        q = _unsigned_to_signed_jit(keys.astype(jnp.uint32))
        qf = np.asarray(q).reshape(-1)
        out_idx = np.asarray(c.arrays["outlier_idx"])
        if out_idx.size:
            qf = qf.copy()
            qf[out_idx] = np.asarray(c.arrays["outlier_val"])
        q = jnp.asarray(qf.reshape(plan.meta["padded"]))
        with plan.lock:
            coeffs, lmap = plan.executables["dequantize"](
                q, plan.workspace["lmap"],
                jnp.asarray(np.asarray(c.arrays["bins"]), jnp.float32),
            )
            plan.recycle("lmap", lmap)
        out = plan.executables["recompose"](coeffs)
        return out.astype(jnp.dtype(c.meta["dtype"]))

    def decode_spec(self, c: Compressed) -> ReductionSpec:
        # Decode plans depend only on geometry + dict size: streams written
        # with any error bound share one reconstruction plan.
        return ReductionSpec.create(
            self.name, c.meta["shape"], c.meta["dtype"],
            dict_size=int(c.meta["dict_size"]),
        )
