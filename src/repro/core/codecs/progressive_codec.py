"""Progressive MGARD codec: refactored precision tiers behind the registry.

``mgard-progressive`` containers hold one separately addressable section per
precision component (see :mod:`repro.core.progressive`), so a reader can
verify and decode a prefix of the payload without touching the rest — the
per-section crc32 entries container v2 records make that safe.  Registry
``decode`` reconstructs at full precision; progressive consumers open the
same bytes with :class:`repro.core.progressive.ProgressiveReader` instead.

The codec declares no stage graph of its own: every device executable it
runs comes from the geometry-keyed ``mgard`` plan and the shared Huffman
plan (both CMM entries), one per shape regardless of error bound.  The
engine's per-leaf fallback and the ``CompressorStream`` one-phase container
path handle pipeline-less codecs already, so checkpoint/serving integration
needs no special casing beyond the leaf policy.
"""

from __future__ import annotations

import jax
import jax.numpy as jnp
import numpy as np

from .. import mgard
from ..container import Compressed
from . import register_codec
from .base import Codec, ReductionPlan, ReductionSpec


@register_codec("mgard-progressive")
class ProgressiveMGARDCodec(Codec):
    """Multi-precision refactoring (HP-MDR model) as a registered codec."""

    spec_defaults = {
        "error_bound": 1e-2,
        "relative": True,
        "dict_size": 4096,
        "tiers": 3,
        "tier_ratio": 8.0,
    }

    def plan(self, spec: ReductionSpec) -> ReductionPlan:
        spec = spec.resolved()
        padded = tuple(mgard.padded_dim(n) for n in spec.shape)
        # No executables of its own: encode/decode borrow the geometry-keyed
        # mgard plan + the shared huffman plan through the CMM (see module
        # docstring), so this plan is metadata only.
        return ReductionPlan(
            spec=spec,
            meta={"padded": padded, "L": mgard.total_levels(padded),
                  "dict_size": int(spec.param("dict_size", 4096))},
        )

    def encode(
        self, plan: ReductionPlan, data: jax.Array, *,
        env=None, profile: dict | None = None,
    ) -> Compressed:
        from .. import progressive  # lazy: codecs package loads before it

        spec = plan.spec
        data = jnp.asarray(data)
        eb = float(spec.param("error_bound", 1e-2))
        if bool(spec.param("relative", True)):
            x = np.asarray(data)
            vrange = float(x.max() - x.min()) if x.size else 0.0
            scaled = eb * vrange
            eb = scaled if scaled > 0 else eb  # constant data: absolute bound
        stream = progressive.refactor(
            data, eb,
            tiers=int(spec.param("tiers", 3)),
            tier_ratio=float(spec.param("tier_ratio", 8.0)),
            dict_size=int(spec.param("dict_size", 4096)),
            backend=spec.backend,
        )
        c = stream.to_container()
        c.meta["dtype"] = spec.dtype
        c.meta["error_bound"] = float(spec.param("error_bound", 1e-2))
        c.meta["relative"] = bool(spec.param("relative", True))
        return c

    def decode(
        self, plan: ReductionPlan, c: Compressed, *,
        env=None, profile: dict | None = None,
    ) -> jax.Array:
        from .. import progressive  # lazy

        stream = progressive.ProgressiveStream.from_container(c)
        out = progressive.retrieve(stream, backend=plan.spec.backend)
        return out.astype(jnp.dtype(c.meta["dtype"]))

    def decode_spec(self, c: Compressed) -> ReductionSpec:
        # Reconstruction depends only on geometry + dictionary size; the
        # per-stream tier ladder rides in the container manifest.
        return ReductionSpec.create(
            self.name, c.meta["shape"], c.meta["dtype"],
            dict_size=int(c.meta["dict_size"]),
        )
