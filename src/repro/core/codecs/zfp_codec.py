"""ZFP-X codec: fixed-rate lossy compression behind the registry.

The whole transform chain is shape/rate-static, so the plan is simply the
two jitted executables with (rate, dims, shape) bound — a second call with
the same spec reuses the compiled program and its workspace without
re-tracing.  Validation (ndim ≤ 4, rate ∈ [1, 32]) happens at plan time:
an invalid spec never enters the CMM.
"""

from __future__ import annotations

from functools import partial

import jax
import jax.numpy as jnp
import numpy as np

from .. import zfp
from ..container import Compressed
from . import register_codec
from .base import Codec, ReductionPlan, ReductionSpec


@register_codec("zfp")
class ZFPCodec(Codec):
    """Fixed-rate block compression (paper §IV-C, Algorithm 3)."""

    spec_defaults = {"rate": 16}

    def plan(self, spec: ReductionSpec) -> ReductionPlan:
        spec = spec.resolved()
        rate = int(spec.param("rate", 16))
        dims = len(spec.shape)
        if dims > 4 or dims == 0:
            raise ValueError("zfp supports 1-4 dimensional data")
        if not 1 <= rate <= 32:
            raise ValueError("rate must be in [1, 32] bits/value")
        # The backend adapter is baked into the jitted executables here —
        # kernel dispatch happens once, at plan time.
        return ReductionPlan(
            spec=spec,
            executables={
                "encode": partial(
                    zfp.compress_jit, rate=rate, dims=dims, shape=spec.shape,
                    adapter=spec.backend,
                ),
                "decode": partial(
                    zfp.decompress_jit, rate=rate, dims=dims, shape=spec.shape,
                    adapter=spec.backend,
                ),
            },
            meta={"rate": rate, "dims": dims},
        )

    def encode(self, plan: ReductionPlan, data: jax.Array) -> Compressed:
        payload, emax = plan.executables["encode"](jnp.asarray(data))
        return Compressed(
            method=self.name,
            meta={
                "shape": plan.spec.shape,
                "dtype": plan.spec.dtype,
                "rate": plan.meta["rate"],
            },
            arrays={"payload": np.asarray(payload), "emax": np.asarray(emax)},
        )

    def decode(self, plan: ReductionPlan, c: Compressed) -> jax.Array:
        out = plan.executables["decode"](
            jnp.asarray(c.arrays["payload"]), jnp.asarray(c.arrays["emax"])
        )
        return out.astype(jnp.dtype(c.meta["dtype"]))

    def decode_spec(self, c: Compressed) -> ReductionSpec:
        # Backend deliberately defaults to auto: any backend decodes any
        # stream (portability contract), so the decode side picks the best
        # local adapter rather than whatever wrote the stream.
        return ReductionSpec.create(
            self.name, c.meta["shape"], c.meta["dtype"], rate=int(c.meta["rate"])
        )

    # -- batched execution (engine fan-out) ---------------------------------

    supports_batched_encode = True

    def batched_encode_executable(self, plan: ReductionPlan):
        enc = plan.executables["encode"]
        return jax.vmap(lambda x: enc(x))

    def batched_encode_finish(
        self, plan: ReductionPlan, out, k: int
    ) -> list[Compressed]:
        payload, emax = (np.asarray(a) for a in out)
        return [
            Compressed(
                method=self.name,
                meta={
                    "shape": plan.spec.shape,
                    "dtype": plan.spec.dtype,
                    "rate": plan.meta["rate"],
                },
                arrays={"payload": payload[i], "emax": emax[i]},
            )
            for i in range(k)
        ]
