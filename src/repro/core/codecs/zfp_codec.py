"""ZFP-X codec: fixed-rate lossy compression behind the registry.

The whole transform chain is shape/rate-static, so the plan is simply the
two jitted executables with (rate, dims, shape) bound — a second call with
the same spec reuses the compiled program and its workspace without
re-tracing.  Validation (ndim ≤ 4, rate ∈ [1, 32]) happens at plan time:
an invalid spec never enters the CMM.
"""

from __future__ import annotations

from functools import partial

import jax
import jax.numpy as jnp
import numpy as np

from .. import zfp
from ..container import Compressed
from . import register_codec
from .base import Codec, ReductionPlan, ReductionSpec


@register_codec("zfp")
class ZFPCodec(Codec):
    """Fixed-rate block compression (paper §IV-C, Algorithm 3)."""

    spec_defaults = {"rate": 16}

    def plan(self, spec: ReductionSpec) -> ReductionPlan:
        rate = int(spec.param("rate", 16))
        dims = len(spec.shape)
        if dims > 4 or dims == 0:
            raise ValueError("zfp supports 1-4 dimensional data")
        if not 1 <= rate <= 32:
            raise ValueError("rate must be in [1, 32] bits/value")
        return ReductionPlan(
            spec=spec,
            executables={
                "encode": partial(
                    zfp.compress_jit, rate=rate, dims=dims, shape=spec.shape
                ),
                "decode": partial(
                    zfp.decompress_jit, rate=rate, dims=dims, shape=spec.shape
                ),
            },
            meta={"rate": rate, "dims": dims},
        )

    def encode(self, plan: ReductionPlan, data: jax.Array) -> Compressed:
        payload, emax = plan.executables["encode"](jnp.asarray(data))
        return Compressed(
            method=self.name,
            meta={
                "shape": plan.spec.shape,
                "dtype": plan.spec.dtype,
                "rate": plan.meta["rate"],
            },
            arrays={"payload": np.asarray(payload), "emax": np.asarray(emax)},
        )

    def decode(self, plan: ReductionPlan, c: Compressed) -> jax.Array:
        out = plan.executables["decode"](
            jnp.asarray(c.arrays["payload"]), jnp.asarray(c.arrays["emax"])
        )
        return out.astype(jnp.dtype(c.meta["dtype"]))

    def decode_spec(self, c: Compressed) -> ReductionSpec:
        return ReductionSpec.create(
            self.name, c.meta["shape"], c.meta["dtype"], rate=int(c.meta["rate"])
        )
