"""ZFP-X codec: fixed-rate lossy compression behind the registry.

The stage graph is a single device stage — ZFP's whole transform chain is
shape/rate-static, so the compiled pipeline is one fused executable with no
host barrier at all (it was the first codec on the engine's stacked
``shard_map`` path for exactly that reason).  Validation (ndim ≤ 4,
rate ∈ [1, 32]) happens at plan time: an invalid spec never enters the CMM.
"""

from __future__ import annotations

from functools import partial

import jax
import jax.numpy as jnp
import numpy as np

from .. import zfp
from .. import stages as sg
from ..container import Compressed
from . import register_codec
from .base import Codec, ReductionPlan, ReductionSpec


@register_codec("zfp")
class ZFPCodec(Codec):
    """Fixed-rate block compression (paper §IV-C, Algorithm 3)."""

    spec_defaults = {"rate": 16}

    def build_stages(self, spec: ReductionSpec) -> sg.StageGraph:
        rate = int(spec.param("rate", 16))
        return sg.StageGraph(
            stages=(sg.ZfpBlockTransform(rate, len(spec.shape), spec.shape),),
            finish_keys=("payload", "emax"),
            inv_inputs=("payload", "emax"),
        )

    def plan(self, spec: ReductionSpec) -> ReductionPlan:
        spec = spec.resolved()
        rate = int(spec.param("rate", 16))
        dims = len(spec.shape)
        if dims > 4 or dims == 0:
            raise ValueError("zfp supports 1-4 dimensional data")
        if not 1 <= rate <= 32:
            raise ValueError("rate must be in [1, 32] bits/value")
        # The backend adapter is baked into the jitted executables here —
        # kernel dispatch happens once, at plan time.
        plan = ReductionPlan(
            spec=spec,
            executables={
                "encode": partial(
                    zfp.compress_jit, rate=rate, dims=dims, shape=spec.shape,
                    adapter=spec.backend,
                ),
                "decode": partial(
                    zfp.decompress_jit, rate=rate, dims=dims, shape=spec.shape,
                    adapter=spec.backend,
                ),
            },
            meta={"rate": rate, "dims": dims},
        )
        return self._attach_pipeline(plan)

    def finish_container(self, plan, env, view) -> Compressed:
        c = Compressed(
            method=self.name,
            meta={
                "shape": plan.spec.shape,
                "dtype": plan.spec.dtype,
                "rate": plan.meta["rate"],
            },
            arrays={"payload": view.fetch("payload"), "emax": view.fetch("emax")},
        )
        c.meta["stages"] = plan.meta.get("stage_graph", [])
        return c

    def decode_state(self, plan: ReductionPlan, c: Compressed):
        state0 = {
            "payload": np.asarray(c.arrays["payload"]),
            "emax": np.asarray(c.arrays["emax"]),
        }
        return state0, {}

    def decode(
        self, plan: ReductionPlan, c: Compressed, *,
        env=None, profile: dict | None = None,
    ) -> jax.Array:
        out = self._pipeline_decode(plan, c, env=env, profile=profile)
        if out is not None:
            return out
        out = plan.executables["decode"](
            jnp.asarray(c.arrays["payload"]), jnp.asarray(c.arrays["emax"])
        )
        return out.astype(jnp.dtype(c.meta["dtype"]))

    def decode_spec(self, c: Compressed) -> ReductionSpec:
        # Backend deliberately defaults to auto: any backend decodes any
        # stream (portability contract), so the decode side picks the best
        # local adapter rather than whatever wrote the stream.
        return ReductionSpec.create(
            self.name, c.meta["shape"], c.meta["dtype"], rate=int(c.meta["rate"])
        )
