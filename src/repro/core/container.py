"""Portable HPDR byte container (v1 + v2) for compressed objects.

A :class:`Compressed` is the method-tagged result of any registered codec:
JSON-able ``meta`` plus named numpy ``arrays`` (the sections).  The byte
layout is what the checkpoint manager, the serving engine's parked KV pages,
and the I/O benchmarks read and write.

v2 layout (written by default)::

    offset 0   magic  b"HPDR"
           4   uint32 version (= 2)
           8   uint64 header length H
          16   header JSON:
                 method, meta,
                 sections: {name: {dtype, shape, offset, nbytes}},
                 payload_bytes, crc32        # crc32 of the whole payload
        16+H   payload — sections back-to-back at their recorded offsets

Per-section offsets make single-section reads (e.g. a progressive prefix or
one array of a large stream) possible without parsing the other sections,
and the checksum turns torn writes into loud :class:`ValueError`s instead of
silently corrupt tensors.

v1 (the seed format: sorted sections, implicit offsets, no checksum) is
still read transparently; ``to_bytes(version=1)`` can still write it for
compatibility tests.  Unknown versions, truncated streams, and checksum
mismatches raise :class:`ContainerError` (a ``ValueError`` subclass) — the
version field is never ignored, and corruption is never silently decoded.
"""

from __future__ import annotations

import io
import json
import math
import zlib
from dataclasses import dataclass
from typing import Any

import numpy as np

MAGIC = b"HPDR"
CONTAINER_VERSION = 2
_HEADER_FIXED = 16  # magic + version + header-length words


class ContainerError(ValueError):
    """A malformed, truncated, or corrupt HPDR byte stream.

    Raised by every container/stream parser in the framework — a reader can
    catch this one type to handle any torn write, bit flip, or version
    mismatch.  Subclasses :class:`ValueError` so callers of the historical
    API keep working.
    """


def crc32_of(data: bytes | bytearray | memoryview) -> int:
    """The framework's canonical checksum: unsigned crc32 of ``data``.

    Shared by the container payload/section checksums, the aggregated-file
    segment directory, and the serving wire protocol's frame integrity
    field — one function so every layer hashes (and prints) checksums the
    same way.
    """
    return zlib.crc32(data) & 0xFFFFFFFF


def check_crc32(
    data: bytes | bytearray | memoryview,
    recorded: int,
    what: str,
    exc: type[Exception] = ContainerError,
) -> None:
    """Verify ``data`` against a recorded crc32; raise ``exc`` naming ``what``.

    The error message always carries both checksums in ``0x``-hex — torn
    writes and bit flips surface as loud, greppable mismatches rather than
    silently corrupt tensors (or, on the wire, silently corrupt frames).
    """
    crc = crc32_of(data)
    if crc != int(recorded):
        raise exc(
            f"corrupt {what}: crc32 {crc:#010x} != recorded {int(recorded):#010x}"
        )


def _jsonable(d: dict) -> dict:
    out = {}
    for k, v in d.items():
        if isinstance(v, (np.integer,)):
            v = int(v)
        elif isinstance(v, (np.floating,)):
            v = float(v)
        elif isinstance(v, tuple):
            v = list(v)
        out[k] = v
    return out


@dataclass
class Compressed:
    """Method-tagged compressed object with byte (de)serialization."""

    method: str
    meta: dict[str, Any]
    arrays: dict[str, np.ndarray]

    def nbytes(self) -> int:
        return sum(a.nbytes for a in self.arrays.values())

    def ratio(self) -> float:
        orig = math.prod(self.meta["shape"]) * np.dtype(self.meta["dtype"]).itemsize
        return orig / max(self.nbytes(), 1)

    # -- portable byte format (used by checkpoint/I-O layers) ---------------

    def to_bytes(self, version: int = CONTAINER_VERSION) -> bytes:
        if version == 1:
            return self._to_bytes_v1()
        if version != 2:
            raise ValueError(f"cannot write container version {version}")
        names = sorted(self.arrays)
        sections: dict[str, dict] = {}
        payload = io.BytesIO()
        for n in names:
            raw = np.ascontiguousarray(self.arrays[n]).tobytes()
            sections[n] = {
                "dtype": str(self.arrays[n].dtype),
                "shape": list(self.arrays[n].shape),
                "offset": payload.tell(),
                "nbytes": len(raw),
                # per-section checksum (additive): lets a reader verify and
                # decode one section — e.g. a progressive component prefix —
                # without touching the rest of the payload
                "crc32": zlib.crc32(raw) & 0xFFFFFFFF,
            }
            payload.write(raw)
        pbytes = payload.getvalue()
        header = {
            "method": self.method,
            "meta": _jsonable(self.meta),
            "sections": sections,
            "payload_bytes": len(pbytes),
            "crc32": zlib.crc32(pbytes) & 0xFFFFFFFF,
        }
        hbytes = json.dumps(header).encode()
        buf = io.BytesIO()
        buf.write(MAGIC)
        buf.write(np.uint32(2).tobytes())
        buf.write(np.uint64(len(hbytes)).tobytes())
        buf.write(hbytes)
        buf.write(pbytes)
        return buf.getvalue()

    def _to_bytes_v1(self) -> bytes:
        buf = io.BytesIO()
        names = sorted(self.arrays)
        header = {
            "method": self.method,
            "meta": _jsonable(self.meta),
            "arrays": {
                n: {"dtype": str(self.arrays[n].dtype), "shape": list(self.arrays[n].shape)}
                for n in names
            },
        }
        hbytes = json.dumps(header).encode()
        buf.write(MAGIC)
        buf.write(np.uint32(1).tobytes())
        buf.write(np.uint64(len(hbytes)).tobytes())
        buf.write(hbytes)
        for n in names:
            buf.write(np.ascontiguousarray(self.arrays[n]).tobytes())
        return buf.getvalue()

    @classmethod
    def from_bytes(cls, raw: bytes) -> "Compressed":
        raw = bytes(raw)
        if len(raw) < _HEADER_FIXED:
            raise ContainerError(
                f"truncated HPDR stream: {len(raw)} bytes < {_HEADER_FIXED}-byte header"
            )
        if raw[:4] != MAGIC:
            raise ContainerError("not an HPDR stream")
        version = int(np.frombuffer(raw[4:8], np.uint32)[0])
        if version not in (1, 2):
            raise ContainerError(
                f"unsupported HPDR container version {version} (supported: 1, 2)"
            )
        hlen = int(np.frombuffer(raw[8:16], np.uint64)[0])
        if len(raw) < _HEADER_FIXED + hlen:
            raise ContainerError("truncated HPDR stream: incomplete header")
        try:
            header = json.loads(raw[_HEADER_FIXED : _HEADER_FIXED + hlen].decode())
        except (UnicodeDecodeError, json.JSONDecodeError) as e:
            raise ContainerError(f"corrupt HPDR header: {e}") from e
        if version == 1:
            return cls._from_bytes_v1(raw, header, _HEADER_FIXED + hlen)
        return cls._from_bytes_v2(raw, header, _HEADER_FIXED + hlen)

    @classmethod
    def _from_bytes_v1(cls, raw: bytes, header: dict, off: int) -> "Compressed":
        arrays = {}
        for n in sorted(header["arrays"]):
            spec = header["arrays"][n]
            dt = np.dtype(spec["dtype"])
            count = math.prod(spec["shape"]) if spec["shape"] else 1
            nb = count * dt.itemsize
            if off + nb > len(raw):
                raise ContainerError(
                    f"truncated HPDR stream: section {n!r} needs {nb} bytes "
                    f"at offset {off}, stream has {len(raw)}"
                )
            arrays[n] = np.frombuffer(raw[off : off + nb], dt).reshape(spec["shape"])
            off += nb
        return cls(method=header["method"], meta=header["meta"], arrays=arrays)

    @classmethod
    def _from_bytes_v2(cls, raw: bytes, header: dict, base: int) -> "Compressed":
        pbytes = header["payload_bytes"]
        if base + pbytes > len(raw):
            raise ContainerError(
                f"truncated HPDR stream: payload needs {pbytes} bytes, "
                f"stream has {len(raw) - base} after header"
            )
        payload = raw[base : base + pbytes]
        check_crc32(payload, header["crc32"], "HPDR payload")
        arrays = {}
        for n, spec in header["sections"].items():
            dt = np.dtype(spec["dtype"])
            lo, hi = spec["offset"], spec["offset"] + spec["nbytes"]
            if hi > pbytes:
                raise ContainerError(f"corrupt HPDR stream: section {n!r} out of bounds")
            arrays[n] = np.frombuffer(payload[lo:hi], dt).reshape(spec["shape"])
        return cls(method=header["method"], meta=header["meta"], arrays=arrays)


# ---------------------------------------------------------------------------
# partial reads: header peek + single-section fetch
# ---------------------------------------------------------------------------


def peek_header(raw: bytes) -> tuple[dict, int]:
    """Parse a v2 container's header without touching the payload.

    Returns ``(header, payload_base)``.  Only v2 streams carry a section
    directory with offsets; v1 streams raise — callers wanting v1 compat go
    through :meth:`Compressed.from_bytes`.
    """
    raw = bytes(raw)
    if len(raw) < _HEADER_FIXED:
        raise ContainerError(
            f"truncated HPDR stream: {len(raw)} bytes < {_HEADER_FIXED}-byte header"
        )
    if raw[:4] != MAGIC:
        raise ContainerError("not an HPDR stream")
    version = int(np.frombuffer(raw[4:8], np.uint32)[0])
    if version != 2:
        raise ContainerError(
            f"HPDR container version {version} has no section directory "
            "(partial reads need v2)"
        )
    hlen = int(np.frombuffer(raw[8:16], np.uint64)[0])
    if len(raw) < _HEADER_FIXED + hlen:
        raise ContainerError("truncated HPDR stream: incomplete header")
    try:
        header = json.loads(raw[_HEADER_FIXED : _HEADER_FIXED + hlen].decode())
    except (UnicodeDecodeError, json.JSONDecodeError) as e:
        raise ContainerError(f"corrupt HPDR header: {e}") from e
    return header, _HEADER_FIXED + hlen


def read_section_bytes(raw: bytes, name: str) -> bytes:
    """One section's exact payload bytes, verified without a full-payload scan.

    Sections written with a per-section ``crc32`` entry are checked alone —
    the bytes of other sections are never hashed or required to be intact.
    Index-less older v2 streams (no per-section checksum) fall back to one
    whole-payload crc verification on the host.  Corruption raises
    :class:`ContainerError` naming the section.
    """
    header, base = peek_header(raw)
    sec = header["sections"].get(name)
    if sec is None:
        raise ContainerError(f"no section {name!r} in HPDR stream")
    lo, hi = base + int(sec["offset"]), base + int(sec["offset"]) + int(sec["nbytes"])
    if hi > len(raw):
        raise ContainerError(
            f"truncated HPDR stream: section {name!r} needs bytes "
            f"[{lo}:{hi}), stream has {len(raw)}"
        )
    blob = raw[lo:hi]
    if "crc32" in sec:
        check_crc32(blob, sec["crc32"], f"HPDR section {name!r}")
        return blob
    # host fallback for streams predating per-section checksums: the only
    # integrity record is the whole-payload crc32, so verify that once
    pbytes = int(header["payload_bytes"])
    if base + pbytes > len(raw):
        raise ContainerError(
            f"truncated HPDR stream: payload needs {pbytes} bytes, "
            f"stream has {len(raw) - base} after header"
        )
    payload = raw[base : base + pbytes]
    check_crc32(
        payload, header["crc32"], f"HPDR payload (verifying section {name!r})"
    )
    return blob


def read_section(raw: bytes, name: str) -> np.ndarray:
    """Like :func:`read_section_bytes`, shaped as the recorded array."""
    header, _ = peek_header(raw)
    sec = header["sections"].get(name)
    if sec is None:
        raise ContainerError(f"no section {name!r} in HPDR stream")
    blob = read_section_bytes(raw, name)
    return np.frombuffer(blob, np.dtype(sec["dtype"])).reshape(sec["shape"])
