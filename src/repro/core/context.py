"""Context Memory Model (CMM) — HPDR §III-B.

The paper identifies per-call memory management (allocations for the
reduction *context*: workspace buffers, plans, codebooks) as a dominant,
overlooked cost — and the one that destroys multi-accelerator scaling,
because concurrent allocator traffic serialises inside a shared runtime.
CMM fixes this by hash-caching contexts so repeated reductions with the
same characteristics reuse persistent allocations.

JAX adaptation:
  * the *plan* part of a context is the jitted executable — we pin it here so
    tracing/compilation happens once per (algorithm, shape, dtype, params)
    key, exactly like the paper's cached plans;
  * the *buffer* part is a dict of persistent device arrays that pipelines
    donate between calls (`jax.jit(..., donate_argnums=...)` turns reuse into
    true in-place buffer recycling on TPU);
  * cache statistics feed the Fig. 16 scalability benchmark: the modelled
    per-call allocator cost is zero on a hit.

The cache is LRU by entry count and thread-safe (multi-device nodes drive it
from one process in JAX, but serving engines may call from threads).
"""

from __future__ import annotations

import threading
from collections import OrderedDict
from dataclasses import dataclass, field
from typing import Any, Callable, Hashable


@dataclass
class ReductionContext:
    """A persistent reduction context (paper: plan + workspace allocations)."""

    key: Hashable
    plan: Any                       # usually a jitted callable
    buffers: dict[str, Any] = field(default_factory=dict)
    meta: dict[str, Any] = field(default_factory=dict)
    hits: int = 0

    def nbytes(self) -> int:
        total = 0
        for buf in self.buffers.values():
            nb = getattr(buf, "nbytes", 0)
            total += int(nb() if callable(nb) else nb)
        return total


class ContextCache:
    """Hash-map context cache with LRU eviction (HPDR CMM).

    Eviction runs on two policies: entry count (``capacity``, the classic
    plan-cache bound) and, when ``capacity_bytes`` is set, total tracked
    buffer bytes — the memory-pressure policy the serving engine's parked
    KV pages sit behind.  ``on_evict(ctx)`` fires for every evicted context
    *outside* the cache lock, so a spill handler can persist the evicted
    buffers (and must not call back into the cache).
    """

    def __init__(
        self,
        capacity: int = 64,
        capacity_bytes: int | None = None,
        on_evict: Callable[[ReductionContext], None] | None = None,
        group_fn: Callable[[Hashable], Any] | None = None,
    ):
        self.capacity = capacity
        self.capacity_bytes = capacity_bytes
        self.on_evict = on_evict
        # Tenant-scoped accounting: ``group_fn(key)`` names the group a
        # context's bytes are charged to; groups with a quota set via
        # ``set_group_capacity`` get their own LRU eviction pass, so one
        # tenant's parked sessions can never displace another tenant's
        # budget (the serving layer's per-tenant CMM quota).
        self.group_fn = group_fn
        self._group_capacity: dict[Any, int] = {}
        self._lock = threading.Lock()
        self._entries: OrderedDict[Hashable, ReductionContext] = OrderedDict()
        self.hit_count = 0
        self.miss_count = 0
        self.evict_count = 0
        self.group_evict_count: dict[Any, int] = {}

    def _evict_over_capacity(self) -> list[ReductionContext]:
        """Pop LRU entries past either capacity bound (lock held).

        The most recent entry is never evicted — a single context larger
        than the byte budget stays resident while in use.
        """
        evicted = []
        while len(self._entries) > self.capacity and len(self._entries) > 1:
            evicted.append(self._entries.popitem(last=False)[1])
            self.evict_count += 1
        if self.capacity_bytes is not None:
            # Recomputed (not a running counter) because tracked contexts
            # grow after insertion — plans accrete decode tables into their
            # workspace.  Byte-capacity caches hold few, large entries
            # (parked sessions), so the walk is cheap relative to the
            # compression that precedes every insert; the hot plan cache
            # (GLOBAL_CMM) sets no byte bound and never pays this.
            total = sum(c.nbytes() for c in self._entries.values())
            while total > self.capacity_bytes and len(self._entries) > 1:
                _, ctx = self._entries.popitem(last=False)
                total -= ctx.nbytes()
                evicted.append(ctx)
                self.evict_count += 1
        if self.group_fn is not None and self._group_capacity:
            evicted.extend(self._evict_over_group_quotas())
        return evicted

    def _evict_over_group_quotas(self) -> list[ReductionContext]:
        """Evict LRU entries of any group over its byte quota (lock held).

        The most recently used entry overall is exempt, matching the global
        byte policy: the context just touched stays resident even when it
        alone exceeds its group's quota.
        """
        evicted: list[ReductionContext] = []
        totals: dict[Any, int] = {}
        for key, ctx in self._entries.items():
            group = self.group_fn(key)
            if group in self._group_capacity:
                totals[group] = totals.get(group, 0) + ctx.nbytes()
        newest = next(reversed(self._entries)) if self._entries else None
        for group, cap in self._group_capacity.items():
            total = totals.get(group, 0)
            if total <= cap:
                continue
            for key in [
                k for k in self._entries if self.group_fn(k) == group
            ]:
                if total <= cap:
                    break
                if key == newest:
                    continue
                ctx = self._entries.pop(key)
                total -= ctx.nbytes()
                evicted.append(ctx)
                self.evict_count += 1
                self.group_evict_count[group] = (
                    self.group_evict_count.get(group, 0) + 1
                )
        return evicted

    def set_group_capacity(self, group: Any, capacity_bytes: int | None) -> None:
        """Set (or clear, with ``None``) one group's byte quota.

        Takes effect on the next insert; an already-over-quota group is
        trimmed then, not here (callers wanting immediate enforcement can
        touch the cache with any insert).
        """
        with self._lock:
            if capacity_bytes is None:
                self._group_capacity.pop(group, None)
            else:
                self._group_capacity[group] = int(capacity_bytes)

    def group_capacity(self, group: Any) -> int | None:
        with self._lock:
            return self._group_capacity.get(group)

    def nbytes_by_group(self) -> dict[Any, int]:
        """Tracked bytes per group (every group, quota'd or not)."""
        if self.group_fn is None:
            return {}
        with self._lock:
            totals: dict[Any, int] = {}
            for key, ctx in self._entries.items():
                group = self.group_fn(key)
                totals[group] = totals.get(group, 0) + ctx.nbytes()
            return totals

    def get_or_create(
        self, key: Hashable, builder: Callable[[], ReductionContext]
    ) -> ReductionContext:
        """Return the cached context for ``key``; build + insert on miss.

        The builder runs outside the lock on a miss is *not* safe for
        correctness of single-build (two threads may both build), but both
        results are identical and one wins — the paper makes the same
        idempotency assumption for its context table.
        """
        with self._lock:
            ctx = self._entries.get(key)
            if ctx is not None:
                self._entries.move_to_end(key)
                self.hit_count += 1
                ctx.hits += 1
                return ctx
            self.miss_count += 1
        ctx = builder()
        ctx.key = key
        with self._lock:
            self._entries[key] = ctx
            self._entries.move_to_end(key)
            evicted = self._evict_over_capacity()
        if self.on_evict is not None:
            for victim in evicted:
                self.on_evict(victim)
        return ctx

    def evict(self, key: Hashable) -> ReductionContext | None:
        """Explicitly drop one context (fires ``on_evict``); None if absent."""
        with self._lock:
            ctx = self._entries.pop(key, None)
            if ctx is not None:
                self.evict_count += 1
        if ctx is not None and self.on_evict is not None:
            self.on_evict(ctx)
        return ctx

    def discard(self, key: Hashable) -> ReductionContext | None:
        """Silently drop one context (no ``on_evict``, e.g. replacement)."""
        with self._lock:
            return self._entries.pop(key, None)

    def __len__(self) -> int:
        return len(self._entries)

    def __contains__(self, key: Hashable) -> bool:
        with self._lock:
            return key in self._entries

    def clear(self) -> None:
        with self._lock:
            self._entries.clear()

    def nbytes(self) -> int:
        with self._lock:
            return sum(c.nbytes() for c in self._entries.values())

    def stats(self) -> dict[str, int]:
        return {
            "entries": len(self._entries),
            "hits": self.hit_count,
            "misses": self.miss_count,
            "evictions": self.evict_count,
            "bytes": self.nbytes(),
        }


# Global CMM instance used by the pipelines/API (one per process, like the
# paper's per-runtime context table).
GLOBAL_CMM = ContextCache(capacity=128)


def context_key(algorithm: str, shape: tuple, dtype: Any, **params: Any) -> tuple:
    """Canonical context hash key (paper: 'similar data characteristics')."""
    return (algorithm, tuple(shape), str(dtype), tuple(sorted(params.items())))
