"""Execution engine — owns *where* and *how* a ReductionPlan runs.

This layer sits between the plan architecture (``ReductionSpec`` /
``ReductionPlan`` cached in the CMM) and the codec kernels, and implements
the two at-scale behaviours of the paper that the specify→plan→execute
split alone does not give:

  1. **Plan-bound backends** (§III-C): every spec carries a ``backend``
     (``auto`` | ``xla`` | ``pallas`` | ``pallas_interpret``); plan build
     resolves it through :func:`repro.core.adapters.resolve_backend`
     capability probing and bakes the chosen adapter into the jitted
     executables.  Kernel dispatch happens once, at plan time — never per
     call.
  2. **Sharded fan-out + async submission** (§V / Fig. 16): independent
     reductions — pytree leaves, stream chunks — are scheduled across the
     mesh's ``data``-axis devices.  Same-spec leaves are bucketed so each
     bucket builds *one* plan (a CMM miss) and every other leaf is a real
     CMM hit; every stage-graph codec's bucket is stacked and driven
     through the plan's compiled pipeline under ``shard_map`` over the
     ``data`` axis — each fused device segment is vmapped over the leaf
     axis, and the host barriers (codebook construction) loop over
     metadata-scale per-leaf fetches.  Since PR 3 that includes the
     formerly host-staged codecs (MGARD, Huffman): their entropy stage is
     device-resident, so the per-leaf host-future fan-out only remains for
     singleton buckets.  ``submit()/result()`` expose the future surface
     the checkpoint writer and the serving engine's KV parking run on.

Most callers use the process-wide :func:`default_engine` (all local devices
on one ``data`` axis) implicitly through ``api.compress_pytree``; custom
meshes/backends construct :class:`ExecutionEngine` directly::

    eng = ExecutionEngine(mesh=make_mesh((4,), ("data",)),
                          backend="pallas_interpret")
    flat, stats = eng.compress_pytree(params)
    sub = eng.submit_encode(spec, x)      # async single reduction
    c = sub.result()
"""

from __future__ import annotations

import threading
import weakref
from collections import OrderedDict
from typing import Any, Callable

import jax
import jax.numpy as jnp
import numpy as np
from jax.experimental.shard_map import shard_map
from jax.sharding import Mesh, PartitionSpec as P

from . import adapters
from .codecs import get_codec
from .codecs.base import ReductionSpec
from .container import Compressed
from .stages.base import CallEnv, LeafView, TransferStats
from ..runtime.executor import COMPUTE, MESH, DeviceExecutor, Submission


def data_devices(mesh: Mesh | None) -> list:
    """Devices holding distinct ``data``-axis shards (fan-out placement ring).

    For a multi-axis mesh this walks the ``data`` axis with every other axis
    pinned at index 0 — one device per data shard.  Meshes without a
    ``data`` axis fall back to every device.
    """
    if mesh is None:
        return list(jax.devices())
    names = list(mesh.axis_names)
    if "data" not in names:
        return list(np.asarray(mesh.devices).flat)
    dev = np.moveaxis(np.asarray(mesh.devices), names.index("data"), 0)
    return list(dev.reshape(dev.shape[0], -1)[:, 0])


def make_data_mesh(devices=None) -> Mesh:
    """One-axis ``("data",)`` mesh over ``devices`` (default: all local).

    The default path delegates to :func:`repro.launch.mesh.make_data_mesh`
    (the version-portable constructor) so the two stay one implementation;
    an explicit device list builds the mesh over exactly those devices.
    """
    if devices is None:
        from ..launch import mesh as launch_mesh  # runtime import: layering

        return launch_mesh.make_data_mesh()
    return Mesh(np.array(list(devices)), ("data",))


class ExecutionEngine:
    """Plan-bound, mesh-sharded, async reduction executor."""

    def __init__(
        self,
        mesh: Mesh | None = None,
        backend: str = adapters.AUTO,
        max_workers: int | None = None,
        io_workers: int = 1,
        topology=None,
    ):
        self.backend = adapters.resolve_backend(backend)
        self.mesh = mesh if mesh is not None else make_data_mesh()
        self.devices = data_devices(self.mesh)
        if topology is None:
            from ..launch import mesh as launch_mesh  # runtime import: layering

            topology = launch_mesh.detect_topology()
        #: which controller process this engine runs in (multi-host I/O
        #: routing): the checkpoint writer coalesces this host's leaf
        #: compressions into its local shard, and ``encode_leaf_jobs``
        #: can drop leaves owned by other hosts before any plan work
        self.topology = topology
        self.executor = DeviceExecutor(
            self.devices, max_workers=max_workers, io_workers=io_workers
        )
        self._lock = threading.Lock()
        # LRU-bounded: entries pin their vmapped segment (and its compiled
        # traces) alive, so an unbounded map would defeat CMM plan eviction
        # in long-running processes with high spec diversity.
        self._smap_cache: "OrderedDict[tuple, Callable]" = OrderedDict()
        self._smap_capacity = 128
        # per-shard workspace stacks for the donating batched path: keyed by
        # the vmapped segment, popped before dispatch and re-stored from the
        # executable's pass-through output (true recycling where XLA
        # implements donation)
        self._ws_stacks: dict[tuple, tuple] = {}
        self.shard_map_calls = 0
        self.sharded_leaves = 0
        self.sharded_decoded_leaves = 0
        self.transfer_h2d = 0
        self.transfer_d2h = 0
        self.ws_stack_builds = 0
        self.ws_donated_calls = 0

    # ----------------------------------------------------------- single spec

    def make_spec(self, data: Any, method: str, **params: Any) -> ReductionSpec:
        """Spec for ``data`` with this engine's backend bound (unless given)."""
        from . import api  # runtime import: api ↔ engine are peer layers

        params.setdefault("backend", self.backend)
        return api.make_spec(data, method, **params)

    def submit_encode(
        self, spec: ReductionSpec, data: Any, device: Any = None
    ) -> Submission:
        """Asynchronously compress ``data`` under ``spec``; returns a future."""
        from . import api

        return self.executor.submit(
            lambda: api.encode(spec, jnp.asarray(data)), device=device
        )

    def submit_decode(self, c: Compressed, device: Any = None) -> Submission:
        from . import api

        return self.executor.submit(lambda: api.decode(c), device=device)

    def stream(self, method: str = "zfp", **kwargs: Any):
        """A :class:`~repro.core.api.CompressorStream` bound to this engine.

        The stream's chunks fan out round-robin over the engine's
        ``data``-axis devices on the engine's executor lanes.  Defaults to
        the auto-tuned schedule (``chunk_size="auto", window="auto"`` —
        the calibrated machine cost model picks both); pass explicit
        values to override.  NB: build streams from caller threads, not
        from inside engine lane tasks — the stream's staging loop must not
        occupy the lane its own chunks need.
        """
        from . import api  # runtime import: api ↔ engine are peer layers

        kwargs.setdefault("chunk_size", "auto")
        kwargs.setdefault("window", "auto")
        return api.CompressorStream(method, engine=self, **kwargs)

    def submit(self, fn: Callable, /, *args: Any, **kwargs: Any) -> Submission:
        """Raw task submission (``lane="io"`` for orchestration work)."""
        return self.executor.submit(fn, *args, **kwargs)

    @staticmethod
    def result(sub: Submission, timeout: float | None = None) -> Any:
        return sub.result(timeout)

    def encode(self, spec: ReductionSpec, data: Any) -> Compressed:
        return self.submit_encode(spec, data).result()

    def decode(self, c: Compressed) -> jax.Array:
        return self.submit_decode(c).result()

    # ------------------------------------------------- bucket job surface
    #
    # The pytree entry points below and the serving layer's request
    # coalescer share these helpers: leaf-job construction (policy + spec +
    # per-leaf CMM resolution), bucketing by post-policy spec, and one
    # whole-mesh submission per stackable bucket.  The serving layer merges
    # jobs from *different requests* into one bucket — bit-identity holds
    # because stacked and per-leaf execution agree byte-for-byte.

    def encode_leaf_jobs(
        self,
        tree: Any,
        select: Callable[[str, np.ndarray], tuple[str, dict] | None] | None = None,
        *,
        sep: str = "/",
        owned_only: bool = False,
    ) -> tuple[list[str], dict[str, np.ndarray], list[tuple], dict]:
        """Flatten ``tree`` into encode jobs: ``(order, raw, jobs, stats)``.

        Each job is ``(key, arr, x, spec)`` — original leaf, post-policy
        array, and the engine-bound spec.  Plan resolution happens here,
        per leaf: the first leaf of a bucket builds the plan (CMM miss),
        every further leaf is a real CMM hit — the observable the
        scalability benchmark counts.

        ``owned_only=True`` is the multi-controller io-lane route: leaves
        owned by other hosts under ``self.topology`` are dropped *before*
        any plan or compression work (``stats["remote_leaves"]`` counts
        them), so each host's compute and io lanes carry exactly the
        leaves that coalesce into its local shard.
        """
        from . import api

        select = select or api.default_select
        stats = {
            "raw": 0, "compressed": 0, "leaves": 0, "compressed_leaves": 0,
            "buckets": 0, "sharded_leaves": 0, "devices": len(self.devices),
            "remote_leaves": 0,
        }
        order: list[str] = []
        raw_leaves: dict[str, np.ndarray] = {}
        jobs: list[tuple[str, np.ndarray, np.ndarray, ReductionSpec]] = []
        for path, leaf in jax.tree_util.tree_flatten_with_path(tree)[0]:
            key = api._path_key(path, sep)
            if owned_only and not self.topology.owns(key):
                stats["remote_leaves"] += 1
                continue
            arr = np.asarray(leaf)
            order.append(key)
            stats["raw"] += arr.nbytes
            stats["leaves"] += 1
            choice = select(key, arr)
            if choice is None:
                raw_leaves[key] = arr
                stats["compressed"] += arr.nbytes
                continue
            method, params = choice
            x, pol_method, pol_params = api.leaf_policy(arr, method, params)
            # a per-leaf backend in the policy overrides the engine default
            backend = pol_params.pop("backend", None) or self.backend
            spec = api.make_spec(x, pol_method, backend=backend, **pol_params)
            api.get_plan(spec)
            jobs.append((key, arr, x, spec))
        return order, raw_leaves, jobs, stats

    @staticmethod
    def bucket_encode_jobs(jobs: list[tuple]) -> dict[ReductionSpec, list]:
        """Group encode jobs by their post-policy spec (insertion-ordered)."""
        buckets: dict[ReductionSpec, list] = {}
        for job in jobs:
            buckets.setdefault(job[3], []).append(job)
        return buckets

    def encode_bucket_stackable(self, spec: ReductionSpec, items: list) -> bool:
        """Whether a bucket rides the stacked whole-mesh ``shard_map`` path."""
        from . import api

        codec = get_codec(spec.method)
        return (
            codec.supports_batched_encode
            and len(items) > 1
            and api.get_plan(spec).pipeline is not None
        )

    def submit_encode_bucket(
        self, spec: ReductionSpec, items: list, *, priority: str | None = None
    ) -> Submission:
        """One whole-mesh submission for a stackable bucket.

        Resolves to the per-item containers (leaf meta finished), aligned
        with ``items``.  Stacked buckets overlap each other's host barriers
        (codebook builds) on the compute pool.
        """
        from . import api

        codec = get_codec(spec.method)

        def run() -> list:
            out = self._encode_bucket_sharded(codec, spec, items)
            for (_key, arr, _x, _s), c in zip(items, out):
                api.finish_leaf_meta(c, arr)
            with self._lock:
                self.sharded_leaves += len(items)
            return out

        return self.executor.submit(run, device=MESH, priority=priority)

    def submit_encode_job(
        self, job: tuple, *, priority: str | None = None
    ) -> Submission:
        """Per-leaf fallback submission; resolves to one finished container."""
        key, arr, x, spec = job
        del key
        return self.executor.submit(
            self._encode_leaf, spec, x, arr, priority=priority
        )

    def decode_leaf_groups(
        self, comp: dict[str, Any]
    ) -> dict[tuple, list[tuple[str, Compressed]]]:
        """Group a flat compressed mapping into decode buckets.

        Keys group by ``(decode spec, decode geometry)`` — the codec's
        :meth:`~repro.core.codecs.base.Codec.decode_bucket_key` — with
        per-leaf plan resolution (CMM hit accounting) exactly mirroring the
        encode direction.  Raw (non-``Compressed``) entries are skipped.
        """
        import dataclasses as _dc

        from . import api

        buckets: dict[tuple, list] = {}
        for key, val in comp.items():
            if not isinstance(val, Compressed):
                continue
            codec = get_codec(val.method)
            spec = _dc.replace(codec.decode_spec(val), backend=self.backend)
            api.get_plan(spec)
            group = (spec, codec.decode_bucket_key(val))
            buckets.setdefault(group, []).append((key, val))
        return buckets

    def decode_bucket_prepared(
        self, spec: ReductionSpec, items: list
    ) -> list | None:
        """Per-item inverse-pipeline states, or ``None`` → per-leaf path."""
        from . import api

        codec = get_codec(spec.method)
        plan = api.get_plan(spec)
        if not (
            codec.supports_batched_decode
            and len(items) > 1
            and plan.pipeline is not None
            and plan.pipeline.invertible
        ):
            return None
        prepared = [codec.decode_state(plan, c) for _k, c in items]
        if any(p is None for p in prepared):
            return None  # old streams in the bucket: host path
        return prepared

    def submit_decode_bucket(
        self, spec: ReductionSpec, items: list, prepared: list,
        *, priority: str | None = None,
    ) -> Submission:
        """One whole-mesh submission for a stacked decode bucket.

        Resolves to the restored per-item leaves (original dtype/shape),
        aligned with ``items``.
        """
        codec = get_codec(spec.method)

        def run() -> list:
            out = self._decode_bucket_sharded(codec, spec, items, prepared)
            with self._lock:
                self.sharded_decoded_leaves += len(items)
            return out

        return self.executor.submit(run, device=MESH, priority=priority)

    def submit_decode_job(
        self, spec: ReductionSpec, c: Compressed, *, priority: str | None = None
    ) -> Submission:
        """Per-leaf decode fallback; resolves to the restored leaf."""
        return self.executor.submit(self._decode_leaf, spec, c, priority=priority)

    # -------------------------------------------------------- pytree fan-out

    def compress_pytree(
        self,
        tree: Any,
        select: Callable[[str, np.ndarray], tuple[str, dict] | None] | None = None,
        *,
        sep: str = "/",
        owned_only: bool = False,
    ) -> tuple[dict[str, Any], dict]:
        """Sharded-parallel :func:`repro.core.api.compress_pytree`.

        Leaves are bucketed by post-policy spec (shape, dtype, method,
        params, backend); each bucket builds one plan — further leaves are
        CMM hits — and buckets execute across the ``data``-axis devices:
        stacked under one ``shard_map`` where the codec's encode chain is
        fully jittable, as per-leaf executor futures otherwise.
        ``owned_only=True`` restricts the fan-out to this host's leaves
        under ``self.topology`` (multi-controller mode — each host emits
        exactly the flat mapping its local shard will hold).
        """
        order, raw_leaves, jobs, stats = self.encode_leaf_jobs(
            tree, select, sep=sep, owned_only=owned_only
        )

        buckets = self.bucket_encode_jobs(jobs)
        stats["buckets"] = len(buckets)

        results: dict[str, Compressed] = {}
        pending: list[tuple[str, Submission]] = []
        stacked: list[tuple[list, Submission]] = []
        for spec, items in buckets.items():
            if self.encode_bucket_stackable(spec, items):
                stacked.append((items, self.submit_encode_bucket(spec, items)))
            else:
                for key, arr, x, spec_i in items:
                    pending.append(
                        (key, self.executor.submit(self._encode_leaf, spec_i, x, arr))
                    )
        for items, sub in stacked:
            for (key, _arr, _x, _s), c in zip(items, sub.result()):
                results[key] = c
            stats["sharded_leaves"] += len(items)
        for key, sub in pending:
            results[key] = sub.result()

        flat: dict[str, Any] = {}
        for key in order:
            if key in raw_leaves:
                flat[key] = raw_leaves[key]
                continue
            c = results[key]
            flat[key] = c
            stats["compressed"] += c.nbytes()
            stats["compressed_leaves"] += 1
        stats["ratio"] = stats["raw"] / max(stats["compressed"], 1)
        return flat, stats

    def decompress_pytree(self, comp: dict[str, Any], like: Any, *, sep: str = "/") -> Any:
        """Sharded-parallel inverse of :meth:`compress_pytree`.

        The mirror image of the encode fan-out: leaves are bucketed by
        decode spec — one plan resolution per leaf, so repeat leaves are
        CMM hits — and every bucket whose codec compiled an inverse
        pipeline is stacked and driven through ``invert_batched`` under one
        whole-mesh ``shard_map`` submission (H2D = compressed sections plus
        metadata-scale decode operands, never a raw-array-sized transfer).
        Streams without a decode chunk index, singleton buckets, and
        codecs without a compiled inverse fall back to per-leaf futures.
        Buckets group by ``(decode spec, decode geometry)`` — the codec's
        :meth:`~repro.core.codecs.base.Codec.decode_bucket_key` — so
        same-shaped streams whose compiled-inverse statics differ (e.g.
        entropy streams packed with different ``chunk_size``) never share
        one stacked dispatch.
        """
        from . import api

        buckets = self.decode_leaf_groups(comp)

        results: dict[str, Any] = {}
        pending: list[tuple[str, Submission]] = []
        stacked: list[tuple[list, Submission]] = []
        for (spec, _geo), items in buckets.items():
            prepared = self.decode_bucket_prepared(spec, items)
            if prepared is not None:
                stacked.append(
                    (items, self.submit_decode_bucket(spec, items, prepared))
                )
            else:
                for key, c in items:
                    pending.append((key, self.submit_decode_job(spec, c)))
        for items, sub in stacked:
            for (key, _c), out in zip(items, sub.result()):
                results[key] = out
        for key, sub in pending:
            results[key] = sub.result()

        flat = {
            key: results[key] if isinstance(val, Compressed) else val
            for key, val in comp.items()
        }
        leaves_with_path, treedef = jax.tree_util.tree_flatten_with_path(like)
        out = [jnp.asarray(flat[api._path_key(p, sep)]) for p, _leaf in leaves_with_path]
        return jax.tree_util.tree_unflatten(treedef, out)

    # ------------------------------------------------------------- internals

    def _encode_leaf(self, spec: ReductionSpec, x: np.ndarray, arr: np.ndarray):
        from . import api

        plan = api.get_plan(spec)
        env = CallEnv(plan)
        c = get_codec(spec.method).encode(plan, jnp.asarray(x), env=env)
        api.finish_leaf_meta(c, arr)
        with self._lock:
            self.transfer_h2d += env.transfers.h2d
            self.transfer_d2h += env.transfers.d2h
        return c

    def _decode_leaf(self, spec: ReductionSpec, c: Compressed):
        """Per-leaf decode under the engine-bound spec (the plan the bucket
        loop already resolved), mirroring `_encode_leaf` — the fallback must
        not rebuild a second platform-default plan via `api.decode`."""
        from . import api

        plan = api.get_plan(spec)
        env = CallEnv(plan)
        out = get_codec(spec.method).decode(plan, c, env=env)
        with self._lock:
            self.transfer_h2d += env.transfers.h2d
            self.transfer_d2h += env.transfers.d2h
        return api.restore_leaf(np.asarray(out), c)

    def _encode_bucket_sharded(self, codec, spec: ReductionSpec, items) -> list:
        """Stack same-spec leaves and drive them through the plan's compiled
        stage pipeline, one ``shard_map`` per fused device segment.

        The bucket's plan was resolved per leaf during bucketing (CMM hit
        accounting); the stack is padded to a multiple of the ``data``-axis
        size and the pad rows dropped at serialisation.  Host stages (bin
        schedules, codebook construction) loop over per-leaf metadata-scale
        fetches — the only host work in the bucket — while every array-scale
        intermediate (coefficients, keys, codes, words) stays device
        resident until the exact-sized container fetch.
        """
        from . import api

        plan = api.get_plan(spec)
        stacked = np.stack([x for (_k, _a, x, _s) in items])
        k, n = len(items), len(self.devices)
        pad = (-k) % n
        if pad:
            stacked = np.concatenate([stacked, np.repeat(stacked[-1:], pad, 0)])
        transfers = TransferStats()
        envs = [CallEnv(plan, transfers) for _ in range(k + pad)]
        state = plan.pipeline.run_batched(
            {"data": stacked}, envs, self._mesh_segment_mapper(), transfers
        )
        out = [
            codec.finish_container(
                plan, envs[i], LeafView(state, i, envs[i], transfers)
            )
            for i in range(k)
        ]
        with self._lock:
            self.shard_map_calls += len(plan.pipeline.device_segments)
            self.transfer_h2d += transfers.h2d
            self.transfer_d2h += transfers.d2h
        return out

    def _decode_bucket_sharded(
        self, codec, spec: ReductionSpec, items, prepared
    ) -> list:
        """Stack same-spec containers and drive them through the plan's
        compiled inverse pipeline, one ``shard_map`` per fused inverse
        segment (in practice: one per bucket — the decode direction has no
        host barriers).

        The stack is padded to a multiple of the ``data``-axis size and the
        pad rows dropped at restore.  H2D is the compressed sections plus
        the decode-table/bin-schedule operands; the decoded arrays stay
        device-resident until the per-leaf restore slices them out.
        """
        from . import api

        plan = api.get_plan(spec)
        k, n = len(items), len(self.devices)
        pad = (-k) % n
        prepared = list(prepared) + [prepared[-1]] * pad
        transfers = TransferStats()
        envs = []
        for state0, meta in prepared:
            env = CallEnv(plan, transfers)
            env.meta.update(meta)
            envs.append(env)
        state = plan.pipeline.invert_batched(
            [p[0] for p in prepared], envs, self._mesh_segment_mapper(),
            transfers,
        )
        out = []
        for i, (_key, c) in enumerate(items):
            row = {key: arr[i] for key, arr in state.items()}
            leaf = codec.finish_decode(plan, envs[i], row, c)
            out.append(api.restore_leaf(np.asarray(leaf), c))
        with self._lock:
            self.shard_map_calls += len(plan.pipeline.inv_segments)
            self.transfer_h2d += transfers.h2d
            self.transfer_d2h += transfers.d2h
        return out

    def _mesh_segment_mapper(self) -> Callable:
        """Wrap a vmapped pipeline segment in this engine's mesh shard_map.

        State and per-leaf operands split over the ``data`` axis.  Plan
        workspace buffers take one of two routes:

          * **broadcast** (platforms without XLA buffer donation): the
            single plan copy is replicated to every shard and the vmapped
            segment's workspace pass-through is dropped;
          * **per-shard donation** (TPU/GPU, the ROADMAP "batched-path
            donation" item): the engine keeps a per-segment stack of one
            workspace copy per data shard, donates it into the dispatch,
            and re-stores the recycled stack the executable passes back —
            so stacked buckets reuse buffers in place exactly like the
            serial path's ``ReductionPlan.recycle`` contract.

        The wrapped executable is cached per vmapped segment (the pipeline
        keeps segment identity stable per statics tuple, so jit re-traces
        only on genuinely new shapes).
        """

        def shard(a) -> P:
            return P(*(["data"] + [None] * (np.ndim(a) - 1)))

        def mapper(seg, vfn, state_vals, operand_vals, ws_vals):
            donate = (
                bool(ws_vals)
                and seg.donate_keys == seg.workspace_keys
                and adapters.supports_donation()
            )
            key = (id(vfn), donate)
            with self._lock:
                exe = self._smap_cache.get(key)
                if exe is not None:
                    self._smap_cache.move_to_end(key)
            if exe is None:
                state_specs = tuple(shard(a) for a in state_vals)
                op_specs = tuple(shard(a) for a in operand_vals)
                outs_shapes, _ws_shapes = jax.eval_shape(
                    vfn, state_vals, operand_vals, ws_vals
                )
                outs_specs = tuple(
                    P(*(["data"] + [None] * (len(s.shape) - 1)))
                    for s in outs_shapes
                )
                if donate:
                    ws_specs = tuple(
                        P(*(["data"] + [None] * np.ndim(a))) for a in ws_vals
                    )

                    def wrapped(s, o, wstack):
                        outs, _ = vfn(s, o, tuple(w[0] for w in wstack))
                        return outs, wstack

                    exe = adapters.donating_jit(
                        shard_map(
                            wrapped, mesh=self.mesh,
                            in_specs=(state_specs, op_specs, ws_specs),
                            out_specs=(outs_specs, ws_specs),
                            check_rep=False,
                        ),
                        donate_argnums=(2,),
                    )
                else:
                    ws_specs = tuple(
                        P(*([None] * np.ndim(a))) for a in ws_vals
                    )
                    exe = shard_map(
                        lambda s, o, w: vfn(s, o, w)[0],
                        mesh=self.mesh,
                        in_specs=(state_specs, op_specs, ws_specs),
                        out_specs=outs_specs,
                        check_rep=False,
                    )
                with self._lock:
                    exe = self._smap_cache.setdefault(key, exe)
                    self._smap_cache.move_to_end(key)
                    while len(self._smap_cache) > self._smap_capacity:
                        old_key, _ = self._smap_cache.popitem(last=False)
                        # keep workspace stacks bounded with the exe cache;
                        # a re-run of the segment simply rebuilds its stack
                        self._ws_stacks.pop(old_key, None)
            if not donate:
                return exe(state_vals, operand_vals, ws_vals)
            stacks = self._take_ws_stacks(key, ws_vals, vfn)
            outs, stacks = exe(state_vals, operand_vals, stacks)
            with self._lock:
                self._ws_stacks[key] = stacks
                self.ws_donated_calls += 1
            return outs

        return mapper

    def _take_ws_stacks(self, key: tuple, ws_vals: tuple, vfn: Callable) -> tuple:
        """Pop (or build) the per-shard workspace stack for a segment.

        Popping under the lock gives each concurrent bucket exclusive
        ownership of a stack for the duration of its dispatch — donation
        invalidates the input buffer, so a shared reference would be a
        use-after-donate.  The entry's lifetime is tied to the vmapped
        segment itself: a ``weakref.finalize`` on ``vfn`` drops the stack
        when the segment (and its owning plan) is collected, so evicted
        plans release their device buffers AND a recycled ``id()`` can
        never resurrect another plan's workspace contents (the finalizer
        runs before the id can be reused).
        """
        with self._lock:
            stacks = self._ws_stacks.pop(key, None)
        if stacks is None:
            n = len(self.devices)
            stacks = tuple(
                jnp.stack([jnp.asarray(w)] * n) for w in ws_vals
            )
            # no engine lock in the callback: it may fire from GC at any
            # point, and dict.pop is GIL-atomic
            weakref.finalize(vfn, self._ws_stacks.pop, key, None)
            with self._lock:
                self.ws_stack_builds += 1
        return stacks

    # -------------------------------------------------------------- lifecycle

    def stats(self) -> dict[str, int]:
        s = self.executor.stats()
        with self._lock:
            s.update(
                backend=self.backend,
                shard_map_calls=self.shard_map_calls,
                sharded_leaves=self.sharded_leaves,
                sharded_decoded_leaves=self.sharded_decoded_leaves,
                transfer_h2d=self.transfer_h2d,
                transfer_d2h=self.transfer_d2h,
                ws_stack_builds=self.ws_stack_builds,
                ws_donated_calls=self.ws_donated_calls,
            )
        return s

    def close(self) -> None:
        self.executor.shutdown()

    def __enter__(self) -> "ExecutionEngine":
        return self

    def __exit__(self, *exc) -> None:
        self.close()


# ---------------------------------------------------------------------------
# process-wide default engine (all local devices on one "data" axis)
# ---------------------------------------------------------------------------

_DEFAULT_ENGINE: ExecutionEngine | None = None
_DEFAULT_LOCK = threading.Lock()


def default_engine() -> ExecutionEngine:
    """Lazily-built shared engine; what ``api.compress_pytree`` runs on."""
    global _DEFAULT_ENGINE
    with _DEFAULT_LOCK:
        if _DEFAULT_ENGINE is None:
            _DEFAULT_ENGINE = ExecutionEngine()
        return _DEFAULT_ENGINE


def set_default_engine(engine: ExecutionEngine | None) -> ExecutionEngine | None:
    """Swap the process default (tests / custom meshes); returns the old one."""
    global _DEFAULT_ENGINE
    with _DEFAULT_LOCK:
        old, _DEFAULT_ENGINE = _DEFAULT_ENGINE, engine
        return old
