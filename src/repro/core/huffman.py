"""Huffman-X — HPDR §IV-B (Algorithm 2), TPU-native.

Pipeline (paper Fig. 6):  histogram → (sort/filter) → two-phase codebook →
encode → compact serialization.

Stage → abstraction mapping (faithful to Table I):
  * ``histogram``      Global pipeline (DEM) — all threads update shared
                       counters; TPU lowering is one-hot × MXU matmul or
                       ``bincount`` (XLA adapter), Pallas kernel in
                       ``repro/kernels/histogram``.
  * codebook           two-phase treeless generation [paper ref 44]: phase 1
                       produces code *lengths* (two-queue O(n) merge after a
                       sort), phase 2 assigns canonical codes.  Runs on host:
                       it is metadata-scale (≤ 2^16 entries) and sits at the
                       same histogram→codebook sync point the GPU
                       implementations have.
  * encode             Locality (GEM) — each key encoded independently via
                       table gather.
  * serialize          Global pipeline (DEM) — exclusive scan of lengths +
                       conflict-free segment-sum bit OR (``core.bitstream``).

Decoding is self-synchronising per fixed-size symbol chunk (per-chunk bit
offsets are stored, as GPU Huffman decoders do), so chunks decode in
parallel (vmap) with a sequential ``lax.scan`` inside.

Canonical codes mean the codebook serialises as the *lengths array only*.
"""

from __future__ import annotations

import heapq
from dataclasses import dataclass
from functools import partial

import jax
import jax.numpy as jnp
import numpy as np

from . import bitstream as bs

MAX_CODE_LEN = 32
DEFAULT_CHUNK = 4096


# ---------------------------------------------------------------------------
# Global-pipeline stage: histogram
# ---------------------------------------------------------------------------


@partial(jax.jit, static_argnames=("num_bins",))
def histogram(keys: jax.Array, num_bins: int) -> jax.Array:
    """Frequency histogram over the whole domain (DEM global stage)."""
    return jnp.bincount(keys.reshape(-1).astype(jnp.int32), length=num_bins)


def histogram_op(keys: jax.Array, num_bins: int, adapter: str | None = None) -> jax.Array:
    """Adapter-dispatched histogram: plans bind a concrete backend here.

    ``adapter=None`` is the inline jnp path; a concrete adapter goes through
    the ``histogram`` kernel registry (one-hot × MXU matmul on Pallas).
    """
    if adapter is None:
        return histogram(keys, num_bins)
    from repro.kernels.histogram import ops as histogram_ops  # lazy: layer order

    return histogram_ops.histogram(keys, num_bins, adapter=adapter)


# ---------------------------------------------------------------------------
# Two-phase codebook generation (host / metadata scale)
# ---------------------------------------------------------------------------


def _huffman_code_lengths(freq: np.ndarray) -> np.ndarray:
    """Phase 1: code lengths from frequencies (two-queue merge, O(n log n) w/ sort)."""
    freq = np.asarray(freq, dtype=np.int64)
    n = freq.shape[0]
    lengths = np.zeros(n, dtype=np.int32)
    nz = np.nonzero(freq)[0]
    if nz.size == 0:
        return lengths
    if nz.size == 1:
        lengths[nz[0]] = 1
        return lengths
    # Heap of (weight, tiebreak, node_id); leaves are 0..n-1, internals follow.
    heap = [(int(freq[i]), int(i), int(i)) for i in nz]
    heapq.heapify(heap)
    parent = np.full(n + nz.size, -1, dtype=np.int64)
    next_id = n
    counter = n
    while len(heap) > 1:
        w1, _, a = heapq.heappop(heap)
        w2, _, b = heapq.heappop(heap)
        parent[a] = next_id
        parent[b] = next_id
        heapq.heappush(heap, (w1 + w2, counter, next_id))
        next_id += 1
        counter += 1
    root = heap[0][2]
    # Depth of each leaf by walking parent pointers from the top down:
    depth = np.zeros(next_id, dtype=np.int32)
    for node in range(next_id - 2, -1, -1):  # all non-root, parents have higher ids
        if parent[node] >= 0:
            depth[node] = depth[parent[node]] + 1
    depth[root] = max(depth[root], 0)
    lengths[nz] = depth[nz]
    return lengths


def _limit_lengths(lengths: np.ndarray, freq: np.ndarray, max_len: int) -> np.ndarray:
    """Clamp code lengths to ``max_len`` and repair the Kraft sum.

    Standard post-pass (zlib-style): clamp, then while Kraft > 1 lengthen the
    lowest-frequency symbols still shorter than max_len; finally shorten
    symbols (highest freq first) while Kraft + 2^-len stays ≤ 1.
    """
    lengths = lengths.copy()
    used = lengths > 0
    if not used.any():
        return lengths
    lengths[used & (lengths > max_len)] = max_len

    def kraft() -> float:
        return float(np.sum(np.exp2(-lengths[used].astype(np.float64))))

    if kraft() > 1.0:
        order = np.argsort(freq)  # least frequent first
        while kraft() > 1.0:
            changed = False
            for s in order:
                if used[s] and lengths[s] < max_len:
                    lengths[s] += 1
                    changed = True
                    if kraft() <= 1.0:
                        break
            if not changed:
                raise ValueError("cannot satisfy Kraft inequality")
    # Tighten: shorten most frequent symbols while staying prefix-feasible.
    order = np.argsort(-freq)
    improved = True
    while improved:
        improved = False
        for s in order:
            if used[s] and lengths[s] > 1:
                slack = 1.0 - kraft()
                if slack >= np.exp2(-float(lengths[s])):
                    lengths[s] -= 1
                    improved = True
    return lengths


@dataclass(frozen=True)
class Codebook:
    """Canonical Huffman codebook (decode tables derivable from lengths)."""

    lengths: np.ndarray          # int32[K], 0 = unused key
    codes: np.ndarray            # uint32[K]
    first_code: np.ndarray       # uint32[max_len+1]
    count: np.ndarray            # int32[max_len+1]
    sym_offset: np.ndarray       # int32[max_len+1] index into sym_sorted
    sym_sorted: np.ndarray       # int32[num_used]
    max_len: int

    @property
    def num_keys(self) -> int:
        return int(self.lengths.shape[0])


def canonical_codebook_from_lengths(lengths: np.ndarray) -> Codebook:
    """Phase 2: assign canonical codes given lengths (and build decode tables)."""
    lengths = np.asarray(lengths, dtype=np.int32)
    K = lengths.shape[0]
    used = np.nonzero(lengths)[0]
    max_len = int(lengths.max()) if used.size else 0
    count = np.zeros(max_len + 1, dtype=np.int32)
    for l in lengths[used]:
        count[l] += 1
    first_code = np.zeros(max_len + 1, dtype=np.uint32)
    code = 0
    for l in range(1, max_len + 1):
        code = (code + int(count[l - 1])) << 1
        first_code[l] = code
    # symbols sorted by (length, symbol): canonical order
    sym_sorted = used[np.lexsort((used, lengths[used]))].astype(np.int32)
    sym_offset = np.zeros(max_len + 1, dtype=np.int32)
    acc = 0
    for l in range(1, max_len + 1):
        sym_offset[l] = acc
        acc += int(count[l])
    codes = np.zeros(K, dtype=np.uint32)
    next_code = first_code.copy()
    for s in sym_sorted:
        l = lengths[s]
        codes[s] = next_code[l]
        next_code[l] += 1
    return Codebook(
        lengths=lengths,
        codes=codes,
        first_code=first_code,
        count=count,
        sym_offset=sym_offset,
        sym_sorted=sym_sorted,
        max_len=max_len,
    )


def build_codebook(freq: np.ndarray, max_len: int = MAX_CODE_LEN) -> Codebook:
    """Two-phase codebook generation (paper Alg. 2 line 5)."""
    freq = np.asarray(freq)
    lengths = _huffman_code_lengths(freq)
    if lengths.max(initial=0) > max_len:
        lengths = _limit_lengths(lengths, freq, max_len)
    return canonical_codebook_from_lengths(lengths)


# ---------------------------------------------------------------------------
# Encode (Locality gather) + serialize (Global scan + OR)
# ---------------------------------------------------------------------------


@dataclass
class Encoded:
    """A Huffman-X bitstream with self-synchronising chunk offsets."""

    words: jax.Array             # uint32[W]
    total_bits: int
    n_symbols: int
    chunk_size: int
    chunk_offsets: jax.Array     # int32[n_chunks] bit offsets
    length_table: np.ndarray     # int32[K] — serialised codebook
    num_keys: int

    def nbytes(self) -> int:
        return int(self.words.nbytes + self.chunk_offsets.nbytes + self.length_table.nbytes)


@partial(jax.jit, static_argnames=("num_words", "chunk_size", "adapter"))
def _encode_jit(
    keys: jax.Array,
    codes_t: jax.Array,
    lengths_t: jax.Array,
    num_words: int,
    chunk_size: int,
    adapter: str | None = None,
):
    keys = keys.reshape(-1).astype(jnp.int32)
    if adapter is None:
        code = codes_t[keys]
        length = lengths_t[keys]
    else:
        from repro.kernels.huffman_encode import ops as encode_ops  # lazy

        code, length = encode_ops.encode_lookup(
            keys, codes_t, lengths_t, adapter=adapter
        )
    if keys.shape[0] == 0:
        return (
            jnp.zeros(num_words, jnp.uint32),
            jnp.zeros(0, jnp.int32),
            jnp.int32(0),
        )
    # serialization tail shared with the stage pipeline's bit_pack stage —
    # one implementation, so host-encoder and device-pipeline streams can
    # never drift apart
    from repro.kernels.huffman_encode import ref as encode_ref  # lazy

    return encode_ref.pack_stream(code, length, num_words, chunk_size)


def symbol_lengths_total(keys: jax.Array, lengths_t: jax.Array) -> int:
    """Host-synced total bit count (needed to size the exact output buffer)."""
    total = jnp.sum(lengths_t[keys.reshape(-1).astype(jnp.int32)])
    return int(total)


def encode(
    keys: jax.Array, book: Codebook, chunk_size: int = DEFAULT_CHUNK,
    adapter: str | None = None,
) -> Encoded:
    """Encode ``keys`` (int in [0, K)) into a compact bitstream."""
    keys = keys.reshape(-1)
    lengths_t = jnp.asarray(book.lengths, jnp.int32)
    codes_t = jnp.asarray(book.codes, jnp.uint32)
    total_bits = symbol_lengths_total(keys, lengths_t)
    num_words = max(1, bs.words_needed(total_bits))
    words, chunk_offsets, _ = _encode_jit(
        keys, codes_t, lengths_t, num_words, chunk_size, adapter
    )
    return Encoded(
        words=words,
        total_bits=int(total_bits),
        n_symbols=int(keys.shape[0]),
        chunk_size=chunk_size,
        chunk_offsets=chunk_offsets,
        length_table=np.asarray(book.lengths, np.int32),
        num_keys=book.num_keys,
    )


# ---------------------------------------------------------------------------
# Decode (parallel over chunks, sequential scan within)
# ---------------------------------------------------------------------------


@partial(jax.jit, static_argnames=("chunk_size", "n_chunks", "max_len", "adapter"))
def _decode_jit(
    words: jax.Array,
    chunk_offsets: jax.Array,
    first_code: jax.Array,   # uint32[max_len+1]
    count: jax.Array,        # int32[max_len+1]
    sym_offset: jax.Array,   # int32[max_len+1]
    sym_sorted: jax.Array,   # int32[num_used]
    chunk_size: int,
    n_chunks: int,
    max_len: int,
    adapter: str | None = None,
):
    del n_chunks  # shape-derived; kept in the signature for trace keying
    if adapter is None:
        from repro.kernels.huffman_decode import ref as decode_ref  # lazy

        return decode_ref.decode_chunks(
            words, chunk_offsets, first_code, count, sym_offset, sym_sorted,
            chunk_size, max_len,
        )
    from repro.kernels.huffman_decode import ops as decode_ops  # lazy: layering

    return decode_ops.decode_chunks(
        words, chunk_offsets, first_code, count, sym_offset, sym_sorted,
        chunk_size, max_len, adapter=adapter,
    )


@dataclass
class DecodeTables:
    """Device-staged canonical decode tables derived from a length table.

    Rebuildable from ``length_table`` alone, but derivation + H2D staging is
    per-stream work worth caching: decode plans store these in their CMM
    workspace (keyed by the length table's digest), so repeated decompress
    calls of same-codebook streams are cache hits.  ``nbytes`` makes the
    cached bytes visible to CMM accounting.
    """

    first_code: jax.Array   # uint32[max_len+1]
    count: jax.Array        # int32[max_len+1]
    sym_offset: jax.Array   # int32[max_len+1]
    sym_sorted: jax.Array   # int32[num_used]
    max_len: int

    @property
    def nbytes(self) -> int:
        return int(
            self.first_code.nbytes + self.count.nbytes
            + self.sym_offset.nbytes + self.sym_sorted.nbytes
        )


def decode_tables(length_table: np.ndarray) -> DecodeTables:
    """Build (and device-stage) the decode tables for one length table."""
    book = canonical_codebook_from_lengths(np.asarray(length_table, np.int32))
    return DecodeTables(
        first_code=jnp.asarray(book.first_code, jnp.uint32),
        count=jnp.asarray(book.count, jnp.int32),
        sym_offset=jnp.asarray(book.sym_offset, jnp.int32),
        sym_sorted=jnp.asarray(book.sym_sorted, jnp.int32),
        max_len=int(book.max_len),
    )


def decode(
    enc: Encoded, tables: DecodeTables | None = None, adapter: str | None = None
) -> jax.Array:
    """Decode a Huffman-X bitstream back to keys (uint/int32 array).

    ``tables`` short-circuits the per-call codebook derivation — pass the
    plan-cached :class:`DecodeTables` when decoding repeatedly.  ``adapter``
    routes the chunk scan through the ``huffman_decode`` kernel registry
    (``None``: the inline jnp reference path).
    """
    if tables is None:
        tables = decode_tables(enc.length_table)
    n_chunks = int(enc.chunk_offsets.shape[0])
    syms = _decode_jit(
        enc.words,
        enc.chunk_offsets,
        tables.first_code,
        tables.count,
        tables.sym_offset,
        tables.sym_sorted,
        enc.chunk_size,
        n_chunks,
        max(tables.max_len, 1),
        adapter,
    )
    return syms.reshape(-1)[: enc.n_symbols]


_MAX_DECODE_TABLES = 8  # per-plan cap on cached decode-table variants


def plan_decode_tables(plan, length_table: np.ndarray) -> DecodeTables:
    """Decode tables for ``length_table``, cached in the plan workspace.

    Keyed by the table's digest, so streams written with the same codebook
    (the common case: same data characteristics, repeated decompress calls)
    reuse one derived + device-staged table set, and CMM byte accounting
    sees them.  Bounded FIFO per plan.  Shared by the legacy host decode
    path and the stage pipeline's inverse direction (its ``codebook_build``
    prepare step), so both hit the same cache.
    """
    import hashlib

    lt = np.ascontiguousarray(np.asarray(length_table, np.int32))
    key = "decode_tables:" + hashlib.sha1(lt.tobytes()).hexdigest()
    with plan.lock:
        tables = plan.workspace.get(key)
    if tables is not None:
        return tables
    tables = decode_tables(lt)
    with plan.lock:
        tables = plan.workspace.setdefault(key, tables)
        cached = [k for k in plan.workspace
                  if isinstance(k, str) and k.startswith("decode_tables:")]
        for stale in cached[:-_MAX_DECODE_TABLES]:
            del plan.workspace[stale]
    return tables


# ---------------------------------------------------------------------------
# End-to-end compress/decompress for integer keys (paper Alg. 2)
# ---------------------------------------------------------------------------


def compress(
    keys: jax.Array, num_keys: int, chunk_size: int = DEFAULT_CHUNK,
    adapter: str | None = None,
) -> Encoded:
    freq = np.asarray(histogram_op(keys, num_keys, adapter=adapter))
    book = build_codebook(freq)
    return encode(keys, book, chunk_size=chunk_size, adapter=adapter)


def decompress(enc: Encoded) -> jax.Array:
    return decode(enc)
