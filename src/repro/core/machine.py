"""Machine abstraction — HPDR §III-B: GEM / DEM execution models.

GEM (Group Execution Model): threads partitioned into independent groups;
multi-stage GEM programs stage working data in a fast memory tier between
stages (shared memory on GPU → **VMEM** on TPU, cache on CPU).

DEM (Domain Execution Model): all threads in one synchronised domain;
multi-stage DEM programs share working data through DRAM/HBM, with global
synchronisation between stages (cooperative-groups grid sync on GPU → XLA
program order on TPU).

JAX mapping
-----------
* GEM → one Pallas grid cell per group (``BlockSpec`` pins the group's block
  in VMEM; fused stages execute inside one kernel body so intermediates never
  leave VMEM).  The portable XLA path executes the same program as
  ``vmap(compose(stages))`` over the group axis — XLA's fusion keeps
  intermediates in registers/VMEM where it can.
* DEM → a single ``jit`` of the composed stages over the whole array; stage
  boundaries are HBM-resident values, global sync is XLA's data dependence.

These descriptors are what the parallel abstractions (``abstractions.py``)
lower to, mirroring Table I of the paper.
"""

from __future__ import annotations

import functools
from dataclasses import dataclass, field
from typing import Callable, Sequence

import jax
import jax.numpy as jnp

from . import adapters


@dataclass(frozen=True)
class GEMProgram:
    """A (possibly multi-stage) group-execution program.

    ``stages`` are functions ``block -> block_like``; they are fused so that
    between-stage data stays in the staging tier (VMEM / cache).
    """

    block_shape: tuple[int, ...]
    stages: tuple[Callable, ...]
    name: str = "gem"
    staging: str = "vmem"

    def fused(self) -> Callable:
        def run(block, *args):
            out = block
            for stage in self.stages:
                out = stage(out, *args)
            return out

        return run


@dataclass(frozen=True)
class DEMProgram:
    """A (possibly multi-stage) domain-execution program over the whole array."""

    stages: tuple[Callable, ...]
    name: str = "dem"

    def fused(self) -> Callable:
        def run(data, *args):
            out = data
            for stage in self.stages:
                out = stage(out, *args)
            return out

        return run


def block_view(data: jax.Array, block_shape: Sequence[int]) -> jax.Array:
    """Reshape ``data`` into ``(num_blocks, *block_shape)``.

    Requires every dim divisible by the block dim (pad first via
    ``abstractions.pad_to_blocks``).
    """
    bs = tuple(block_shape)
    if data.ndim != len(bs):
        raise ValueError(f"rank mismatch: data {data.shape} vs block {bs}")
    counts = []
    for d, b in zip(data.shape, bs):
        if d % b:
            raise ValueError(f"dim {d} not divisible by block {b}; pad first")
        counts.append(d // b)
    # (c0, b0, c1, b1, ...) -> (c0, c1, ..., b0, b1, ...)
    interleaved = data.reshape(tuple(x for cb in zip(counts, bs) for x in cb))
    perm = tuple(range(0, 2 * len(bs), 2)) + tuple(range(1, 2 * len(bs), 2))
    blocked = interleaved.transpose(perm)
    return blocked.reshape((-1,) + bs), tuple(counts)


def unblock_view(
    blocks: jax.Array, counts: tuple[int, ...], block_shape: tuple[int, ...]
) -> jax.Array:
    nd = len(block_shape)
    expanded = blocks.reshape(counts + tuple(block_shape))
    perm = tuple(x for pair in zip(range(nd), range(nd, 2 * nd)) for x in pair)
    interleaved = expanded.transpose(perm)
    full = tuple(c * b for c, b in zip(counts, block_shape))
    return interleaved.reshape(full)


def run_gem(prog: GEMProgram, data: jax.Array, *args, adapter: str | None = None):
    """Execute a GEM program.  XLA path: vmap over groups of the fused stages.

    Hot-spot ops ship hand-written Pallas kernels (``repro/kernels``) that are
    dispatched through the adapter registry by their ``ops.py`` wrappers; this
    generic executor is the portable fallback, so arbitrary algorithm-defined
    ``f`` (paper Fig. 3a) still runs everywhere.
    """
    del adapter  # generic executor is adapter-agnostic; kernels dispatch themselves
    blocks, counts = block_view(data, prog.block_shape)
    out_blocks = jax.vmap(lambda b: prog.fused()(b, *args))(blocks)
    if out_blocks.shape[1:] == tuple(prog.block_shape):
        return unblock_view(out_blocks, counts, prog.block_shape)
    return out_blocks  # stage changed block shape (e.g. block -> packed words)


def run_dem(prog: DEMProgram, data, *args):
    """Execute a DEM program: one fused jitted program over the whole domain."""
    return prog.fused()(data, *args)


@functools.cache
def jitted_dem(prog: DEMProgram) -> Callable:
    return jax.jit(prog.fused())
