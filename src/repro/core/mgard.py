"""MGARD-X lossy compression — HPDR §IV-A (Algorithm 1), TPU-native.

Multigrid decomposition on uniform tensor grids (the MGARD-GPU design):
for each level l (fine → coarse):

  1. ``lerp``        multilinear-interpolation coefficients
                     mc = (I − Π_{l−1}) Q_l u            → Locality (GEM)
  2. ``mass_trans``  load vector b = R · M_f · mc        → Locality (GEM)
  3. ``tridiag``     correction c = M_c^{-1} b, solved
                     dimension-by-dimension (mass matrix of multilinear
                     elements is a Kronecker product)     → Iterative (GEM,
                     B vectors per group = lax.scan batched over lanes)
  4. ``add``         Q_{l−1}u = Q_l u|coarse + c          → Locality (GEM)

then per-level linear quantization (Map&Process) and Huffman-X encoding.

Grid handling: each dim is edge-padded to 2^k+1 (per-dim k), the dyadic
hierarchy MGARD's uniform-grid theory assumes; dims stop decomposing when
they reach 2 nodes.  In-place coefficient layout: level-l coefficients live
at their original node positions (stride-2^l nodes with an odd view coord),
like MGARD's output; the level map is a closed-form function of index
trailing-zero counts.

Thomas-solver elimination coefficients depend only on (n, h), so they are
precomputed on host and streamed in as constants — the scan body is one
fused multiply-add per step (the paper's point that solver *context* should
be cached, CMM).
"""

from __future__ import annotations

import math
from dataclasses import dataclass
from functools import lru_cache, partial

import jax
import jax.numpy as jnp
import numpy as np

from . import huffman
from .abstractions import iterative, map_and_process_param
from .quantize import (
    dequantize_by_subset,
    quantize_by_subset,
    signed_to_unsigned,
    unsigned_to_signed,
)

# ---------------------------------------------------------------------------
# dyadic grid bookkeeping
# ---------------------------------------------------------------------------


def dim_levels(n: int) -> int:
    """k such that the padded dim is 2^k + 1 (0 for dims too small to split)."""
    if n < 3:
        return 0
    return int(math.ceil(math.log2(n - 1)))


def padded_dim(n: int) -> int:
    k = dim_levels(n)
    return (1 << k) + 1 if k > 0 else n


def pad_to_dyadic(u: jax.Array) -> jax.Array:
    target = tuple(padded_dim(n) for n in u.shape)
    pads = [(0, t - n) for n, t in zip(u.shape, target)]
    if any(p != (0, 0) for p in pads):
        u = jnp.pad(u, pads, mode="edge")
    return u


def total_levels(shape: tuple[int, ...]) -> int:
    return max(dim_levels(n) for n in shape)


@lru_cache(maxsize=None)
def _level_scores_1d(n: int, k: int) -> np.ndarray:
    """Per-index decomposition step score along one dim (∞ → stays nodal)."""
    idx = np.arange(n)
    tz = np.zeros(n, dtype=np.int64)
    nz = idx > 0
    tz[nz] = np.array([int(i & -i).bit_length() - 1 for i in idx[nz]])
    score = np.where((k > 0) & (idx % (1 << max(k, 1)) != 0), tz, np.iinfo(np.int32).max)
    return score.astype(np.int32)


def level_map(shape: tuple[int, ...]) -> np.ndarray:
    """Map node → quantization subset id: step l (0..L-1) or L for nodal values."""
    ks = [dim_levels(n) for n in shape]
    L = max(ks)
    score = None
    for axis, (n, k) in enumerate(zip(shape, ks)):
        s = _level_scores_1d(n, k)
        view = s.reshape([-1 if a == axis else 1 for a in range(len(shape))])
        score = view if score is None else np.minimum(score, view)
    return np.minimum(score, L).astype(np.int32)


# ---------------------------------------------------------------------------
# 1D operators (applied per axis; tensor-product structure)
# ---------------------------------------------------------------------------


def interp_1d(coarse: jax.Array, axis: int) -> jax.Array:
    """Prolongation along ``axis``: size m+1 → 2m+1 (linear midpoints)."""
    c = jnp.moveaxis(coarse, axis, 0)
    mids = 0.5 * (c[:-1] + c[1:])
    n_f = 2 * (c.shape[0] - 1) + 1
    out = jnp.zeros((n_f,) + c.shape[1:], c.dtype)
    out = out.at[0::2].set(c)
    out = out.at[1::2].set(mids)
    return jnp.moveaxis(out, 0, axis)


def mass_mult_1d(x: jax.Array, axis: int, h: float) -> jax.Array:
    """y = M x along ``axis``; M = h·tridiag(1/6, 2/3, 1/6), boundary h/3."""
    v = jnp.moveaxis(x, axis, 0)
    n = v.shape[0]
    left = jnp.concatenate([jnp.zeros_like(v[:1]), v[:-1]], axis=0)
    right = jnp.concatenate([v[1:], jnp.zeros_like(v[:1])], axis=0)
    diag = jnp.full((n,) + (1,) * (v.ndim - 1), 2.0 / 3.0, v.dtype)
    diag = diag.at[0].set(1.0 / 3.0).at[-1].set(1.0 / 3.0)
    y = h * (diag * v + (1.0 / 6.0) * (left + right))
    return jnp.moveaxis(y, 0, axis)


def restrict_1d(m: jax.Array, axis: int) -> jax.Array:
    """R = P^T along ``axis``: size 2m+1 → m+1: b_j = m_2j + ½(m_2j−1 + m_2j+1)."""
    v = jnp.moveaxis(m, axis, 0)
    even = v[0::2]
    odd = v[1::2]
    zeros = jnp.zeros_like(odd[:1])
    left = jnp.concatenate([zeros, odd], axis=0)   # odd node left of coarse j
    right = jnp.concatenate([odd, zeros], axis=0)  # odd node right of coarse j
    b = even + 0.5 * (left + right)
    return jnp.moveaxis(b, 0, axis)


@lru_cache(maxsize=None)
def _thomas_coeffs(n: int, h: float) -> tuple[np.ndarray, np.ndarray]:
    """Precompute Thomas forward-elimination constants for the 1D mass matrix.

    Returns (cp, denom_inv): cp[i] = c_i / d'_i, denom_inv[i] = 1 / d'_i.
    Data-independent (CMM-cached context), so the scan body is a single FMA.
    """
    a = np.full(n, h / 6.0)  # sub-diagonal
    b = np.full(n, 2.0 * h / 3.0)
    b[0] = b[-1] = h / 3.0
    c = np.full(n, h / 6.0)  # super-diagonal
    cp = np.zeros(n)
    denom_inv = np.zeros(n)
    denom = b[0]
    denom_inv[0] = 1.0 / denom
    cp[0] = c[0] / denom
    for i in range(1, n):
        denom = b[i] - a[i] * cp[i - 1]
        denom_inv[i] = 1.0 / denom
        cp[i] = c[i] / denom
    return cp, denom_inv


def tridiag_solve_1d(rhs: jax.Array, axis: int, h: float) -> jax.Array:
    """Solve M x = rhs along ``axis`` (Thomas; Iterative abstraction).

    Forward sweep and back-substitution are two ``lax.scan``s along the solve
    axis; every other axis is a batch lane (B-vectors-per-group, paper
    Fig. 3b).
    """
    n = rhs.shape[axis]
    cp_np, dinv_np = _thomas_coeffs(n, h)
    cp = jnp.asarray(cp_np, rhs.dtype)
    dinv = jnp.asarray(dinv_np, rhs.dtype)
    sub = h / 6.0

    def fwd(carry, inp):
        d_prev = carry
        r, di = inp
        d = (r - sub * d_prev) * di
        return d, d

    v = jnp.moveaxis(rhs, axis, 0)
    _, dp = jax.lax.scan(fwd, jnp.zeros_like(v[0]), (v, dinv.reshape(n, *([1] * (v.ndim - 1))) * jnp.ones_like(v)))
    # NB: dinv broadcast trick — scan inputs must share leading dim.

    def back(carry, inp):
        x_next = carry
        d, cpi = inp
        x = d - cpi * x_next
        return x, x

    _, xs = jax.lax.scan(
        back,
        jnp.zeros_like(v[0]),
        (dp, cp.reshape(n, *([1] * (v.ndim - 1))) * jnp.ones_like(v)),
        reverse=True,
    )
    return jnp.moveaxis(xs, 0, axis)


# ---------------------------------------------------------------------------
# per-level decompose / recompose
# ---------------------------------------------------------------------------


def _participating(shape: tuple[int, ...]) -> list[int]:
    """Axes with an odd-size view ≥ 3 (still decomposable)."""
    return [a for a, n in enumerate(shape) if n >= 3 and (n - 1) % 2 == 0]


def _decompose_level(view: jax.Array, h: float) -> jax.Array:
    """One level of MGARD decomposition on the current strided view."""
    axes = _participating(view.shape)
    coarse = view[tuple(slice(None, None, 2) if a in axes else slice(None) for a in range(view.ndim))]
    # (1) lerp: multilinear interpolation of coarse onto fine grid
    interp = coarse
    for a in axes:
        interp = interp_1d(interp, a)
    mc = view - interp
    # (2) mass transfer: b = R · M_f · mc per participating axis
    b = mc
    for a in axes:
        b = restrict_1d(mass_mult_1d(b, a, h), a)
    # (3) correction: c = M_c^{-1} b (Kronecker → dimension-split solves)
    c = b
    for a in axes:
        c = tridiag_solve_1d(c, a, 2.0 * h)
    # (4) add correction to coarse values
    corrected = coarse + c
    out = mc
    out = out.at[tuple(slice(None, None, 2) if a in axes else slice(None) for a in range(view.ndim))].set(corrected)
    return out


def _recompose_level(view: jax.Array, h: float) -> jax.Array:
    """Exact inverse of :func:`_decompose_level`."""
    axes = _participating(view.shape)
    sl = tuple(slice(None, None, 2) if a in axes else slice(None) for a in range(view.ndim))
    corrected = view[sl]
    mc = view.at[sl].set(0.0)
    b = mc
    for a in axes:
        b = restrict_1d(mass_mult_1d(b, a, h), a)
    c = b
    for a in axes:
        c = tridiag_solve_1d(c, a, 2.0 * h)
    coarse = corrected - c
    interp = coarse
    for a in axes:
        interp = interp_1d(interp, a)
    fine = mc + interp
    # coarse nodes: mc slot was zeroed, interp(coarse)=coarse there → exact.
    return fine


def _strided_slices(ndim: int, shape: tuple[int, ...], stride_per_axis: tuple[int, ...]):
    return tuple(slice(None, None, s) for s in stride_per_axis)


@partial(jax.jit, static_argnames=("shape",))
def decompose(u: jax.Array, shape: tuple[int, ...]) -> jax.Array:
    """Full multilevel decomposition (paper Alg. 1 lines 5–13), in-place layout."""
    u = u.reshape(shape).astype(jnp.float32)
    u = pad_to_dyadic(u)
    pshape = u.shape
    ks = [dim_levels(n) for n in shape]
    L = max(ks)
    for l in range(L):
        strides = tuple(1 << min(l, k) for k in ks)
        sl = _strided_slices(u.ndim, pshape, strides)
        view = u[sl]
        h = float(1 << l)
        u = u.at[sl].set(_decompose_level(view, h))
    return u


@partial(jax.jit, static_argnames=("shape",))
def recompose(coeffs: jax.Array, shape: tuple[int, ...]) -> jax.Array:
    """Inverse of :func:`decompose`; returns array of original ``shape``."""
    u = coeffs
    ks = [dim_levels(n) for n in shape]
    L = max(ks)
    for l in range(L - 1, -1, -1):
        strides = tuple(1 << min(l, k) for k in ks)
        sl = _strided_slices(u.ndim, u.shape, strides)
        view = u[sl]
        h = float(1 << l)
        u = u.at[sl].set(_recompose_level(view, h))
    return u[tuple(slice(0, n) for n in shape)]


# ---------------------------------------------------------------------------
# quantization (Map&Process) + entropy stage → full pipeline
# ---------------------------------------------------------------------------

# Empirically calibrated L∞ safety factor for the per-level bin schedule
# (see tests/test_mgard.py::test_error_bound): covers interpolation gain
# (L∞-norm 1 per level, additive across levels — hence the 1/(L+1) split)
# plus the correction-feedback gain of c = M_c^{-1}·R·M_f applied to the
# quantization noise during recomposition.
_SAFETY = 2.0


def level_bins(eb: float, L: int) -> np.ndarray:
    """Per-level quantization bin sizes τ_l (paper: 'different bin sizes').

    MGARD's uniform-norm (s=∞) budget: each of the L+1 levels contributes
    ≤ τ_l/2 · gain to the reconstruction error with gain ≈ 1, so the budget
    is split evenly; the nodal (coarsest) subset gets a tighter bin because
    its values seed every interpolation level below it.
    """
    w = np.ones(L + 1)
    w[L] = 0.5  # nodal values: tighter bin (seed of the recomposition)
    return (2.0 * eb / ((L + 1) * _SAFETY) * w).astype(np.float64)


@dataclass
class MGARDCompressed:
    entropy: huffman.Encoded
    outlier_idx: np.ndarray      # int64[n_out] flat indices (padded grid)
    outlier_val: np.ndarray      # int32[n_out] quantized values
    bins: np.ndarray             # float64[L+1]
    shape: tuple[int, ...]
    padded: tuple[int, ...]
    error_bound: float
    dict_size: int
    dtype: str = "float32"

    def nbytes(self) -> int:
        return int(
            self.entropy.nbytes()
            + self.outlier_idx.nbytes
            + self.outlier_val.nbytes
            + self.bins.nbytes
        )


def _quantize_stage_impl(coeffs, lmap, bins, shape, dict_size, adapter):
    if adapter is None:
        q = quantize_by_subset(coeffs, lmap, bins)
        u = signed_to_unsigned(q)
    else:
        from repro.kernels.quantize_map import ops as quantize_ops  # lazy

        u = quantize_ops.quantize(coeffs, lmap, bins, adapter=adapter).reshape(shape)
        q = unsigned_to_signed(u)
    escape = dict_size - 1
    inlier = u < escape
    keys = jnp.where(inlier, u, jnp.uint32(escape)).astype(jnp.int32)
    return q, keys, inlier


@partial(jax.jit, static_argnames=("shape", "dict_size"))
def _quantize_stage(coeffs, lmap, bins, shape, dict_size):
    return _quantize_stage_impl(coeffs, lmap, bins, shape, dict_size, None)


def planned_quantize_stage(shape, dict_size, adapter):
    """Plan-bound quantize executable with the level map *donated*.

    Returns the (aliased) level map as an extra output; the codec re-stores
    it in the plan workspace (``ReductionPlan.recycle``) so reuse is true
    in-place recycling where XLA implements donation (TPU/GPU) and a plain
    pass-through elsewhere.
    """
    from . import adapters

    def stage(coeffs, lmap, bins):
        q, keys, inlier = _quantize_stage_impl(
            coeffs, lmap, bins, shape, dict_size, adapter
        )
        return q, keys, inlier, lmap

    return adapters.donating_jit(stage, donate_argnums=(1,))


def planned_dequantize_stage(adapter):
    """Plan-bound dequantize executable (level map donated, see above)."""
    from . import adapters

    def stage(q, lmap, bins):
        if adapter is None:
            coeffs = dequantize_by_subset(q, lmap, bins)
        else:
            from repro.kernels.quantize_map import ops as quantize_ops  # lazy

            coeffs = quantize_ops.dequantize(
                signed_to_unsigned(q), lmap, bins, adapter=adapter
            ).reshape(q.shape)
        return coeffs, lmap

    return adapters.donating_jit(stage, donate_argnums=(1,))


def compress(
    data: jax.Array,
    error_bound: float,
    dict_size: int = 4096,
    chunk_size: int = huffman.DEFAULT_CHUNK,
) -> MGARDCompressed:
    """MGARD-X end-to-end compression (paper Algorithm 1)."""
    shape = tuple(data.shape)
    coeffs = decompose(data, shape)
    padded = tuple(coeffs.shape)
    lmap = jnp.asarray(level_map(padded))
    L = total_levels(padded)
    bins = level_bins(error_bound, L)
    q, keys, inlier = _quantize_stage(
        coeffs, lmap, jnp.asarray(bins, jnp.float32), padded, dict_size
    )
    # Outliers: stored losslessly (sparse), exactly like MGARD's escape path.
    inlier_np = np.asarray(inlier).reshape(-1)
    out_idx = np.nonzero(~inlier_np)[0]
    out_val = np.asarray(q).reshape(-1)[out_idx]
    enc = huffman.compress(keys, dict_size, chunk_size=chunk_size)
    return MGARDCompressed(
        entropy=enc,
        outlier_idx=out_idx.astype(np.int64),
        outlier_val=out_val.astype(np.int32),
        bins=bins,
        shape=shape,
        padded=padded,
        error_bound=float(error_bound),
        dict_size=dict_size,
        dtype=str(data.dtype),
    )


def decompress(obj: MGARDCompressed) -> jax.Array:
    keys = huffman.decompress(obj.entropy)
    u = keys.astype(jnp.uint32)
    q = unsigned_to_signed(u)
    qf = np.asarray(q).reshape(-1)
    if obj.outlier_idx.size:
        qf = qf.copy()
        qf[obj.outlier_idx] = obj.outlier_val
    q = jnp.asarray(qf.reshape(obj.padded))
    lmap = jnp.asarray(level_map(obj.padded))
    coeffs = dequantize_by_subset(q, lmap, jnp.asarray(obj.bins, jnp.float32))
    out = recompose(coeffs, obj.shape)
    return out.astype(jnp.dtype(obj.dtype))


def compression_ratio(obj: MGARDCompressed) -> float:
    orig = math.prod(obj.shape) * jnp.dtype(obj.dtype).itemsize
    return orig / obj.nbytes()
