"""HDEM — Host-Device Execution Model and the optimized pipeline (HPDR §V).

Machine abstraction (paper Fig. 8): one compute engine + two independent DMA
engines (H2D, D2H).  Restrictions (paper §V-B): one reduction kernel at a
time (structurally true per TPU core); one DMA per direction.

The optimized pipeline (paper Fig. 9) is a depth-3, two-buffer chunked DAG:

  queue i:   I_i (H2D) → R_i (compute) → O_i (D2H) → S_i (serialize)
  anti-dep:  I_i depends on S_{i-2}   — the (X+2)%3 rule that cuts the
             buffer requirement from 3 sets to 2;
  launch-order inversion (reconstruction): deserialize D_{i+1} is issued
             *before* output copy O_i on the shared DMA so the next
             reconstruction's compute is not delayed.

Two execution surfaces:

  * :class:`TimelineSimulator` — deterministic event-driven schedule for a
    task DAG with per-resource issue order (CUDA-stream semantics).  This is
    how Fig. 10/13 overlap numbers are derived on hardware we don't have:
    durations come from measured/modeled Φ and link bandwidths.
  * :class:`ChunkedPipeline` — real chunked execution: a double-buffered,
    lane-overlapped scheduler that drives each chunk through the fused
    ``CompiledPipeline`` segments on the executor's compute lane while the
    previous chunk's D2H + serialization runs on the io lane and the next
    chunk's H2D staging runs on the main thread, bounded at ``window``
    in-flight chunks.  Used by ``api.CompressorStream``, the benchmarks,
    and the compressed-checkpoint writer.
"""

from __future__ import annotations

import threading
import time
from dataclasses import dataclass, field
from typing import Callable, Sequence

import jax
import numpy as np

from . import chunk_model

H2D, D2H, COMPUTE = "h2d", "d2h", "compute"
RESOURCES = (H2D, D2H, COMPUTE)


# ---------------------------------------------------------------------------
# Task DAG + event-driven timeline simulator
# ---------------------------------------------------------------------------


@dataclass
class Task:
    name: str
    resource: str
    duration: float
    deps: tuple[str, ...] = ()


@dataclass
class ScheduledTask:
    name: str
    resource: str
    start: float
    end: float


class TimelineSimulator:
    """Schedule tasks in issue order with per-resource serialization.

    Tasks issue in list order; a task starts at
    ``max(resource_free, max(dep.end))`` — exactly the semantics of enqueueing
    onto per-engine hardware queues (CUDA streams / TPU DMA queues).
    """

    def run(self, tasks: Sequence[Task]) -> dict[str, ScheduledTask]:
        free = {r: 0.0 for r in RESOURCES}
        done: dict[str, ScheduledTask] = {}
        for t in tasks:
            dep_end = max((done[d].end for d in t.deps if d in done), default=0.0)
            start = max(free[t.resource], dep_end)
            end = start + t.duration
            done[t.name] = ScheduledTask(t.name, t.resource, start, end)
            free[t.resource] = end
        return done

    @staticmethod
    def makespan(sched: dict[str, ScheduledTask]) -> float:
        return max((s.end for s in sched.values()), default=0.0)

    @staticmethod
    def overlap_ratio(sched: dict[str, ScheduledTask]) -> float:
        """Paper §V-C: overlapped copy time / total copy time.

        A copy instant counts as overlapped ("hidden") when any *other*
        engine — compute or the opposite-direction DMA — is busy at that
        instant.
        """
        copies = [s for s in sched.values() if s.resource in (H2D, D2H)]
        total = sum(s.end - s.start for s in copies)
        if total == 0:
            return 1.0
        overlapped = 0.0
        for s in copies:
            others = [
                (o.start, o.end)
                for o in sched.values()
                if o.resource != s.resource
            ]
            # merge other-engine busy intervals, intersect with this copy
            others.sort()
            merged: list[tuple[float, float]] = []
            for st, en in others:
                if merged and st <= merged[-1][1]:
                    merged[-1] = (merged[-1][0], max(merged[-1][1], en))
                else:
                    merged.append((st, en))
            for cs, ce in merged:
                lo, hi = max(s.start, cs), min(s.end, ce)
                if hi > lo:
                    overlapped += hi - lo
        return overlapped / total


def build_reduction_dag(
    chunk_sizes: Sequence[int],
    h2d_time: Callable[[int], float],
    compute_time: Callable[[int], float],
    d2h_time: Callable[[int], float],
    serialize_time: Callable[[int], float],
    two_buffer_dep: bool = True,
    window: int | None = None,
) -> list[Task]:
    """Reduction pipeline DAG of paper Fig. 9 (top).

    ``window`` generalizes the two-buffer anti-dependency to an arbitrary
    in-flight bound: ``I_i`` waits for ``S_{i-window}`` (``window=2`` is
    the paper's (X+2)%3 rule, ``window=1`` the fully serial schedule).
    ``None`` keeps the legacy ``two_buffer_dep`` behaviour.
    """
    if window is None:
        window = 2 if two_buffer_dep else 0
    window = int(window)
    tasks: list[Task] = []
    for i, c in enumerate(chunk_sizes):
        deps_i = (f"S{i-window}",) if (window > 0 and i >= window) else ()
        tasks.append(Task(f"I{i}", H2D, h2d_time(c), deps_i))
        tasks.append(Task(f"R{i}", COMPUTE, compute_time(c), (f"I{i}",)))
        tasks.append(Task(f"O{i}", D2H, d2h_time(c), (f"R{i}",)))
        tasks.append(Task(f"S{i}", D2H, serialize_time(c), (f"O{i}",)))
    return tasks


def build_reconstruction_dag(
    chunk_sizes: Sequence[int],
    h2d_time: Callable[[int], float],
    compute_time: Callable[[int], float],
    d2h_time: Callable[[int], float],
    deserialize_time: Callable[[int], float],
    two_buffer_dep: bool = True,
    invert_launch_order: bool = True,
) -> list[Task]:
    """Reconstruction DAG of paper Fig. 9 (bottom).

    ``invert_launch_order=True`` applies the red-arrow optimization: the next
    chunk's deserialization is issued before the current chunk's output copy
    on the shared DMA engine, so reconstruction compute i+1 starts earlier
    and O_i overlaps with it.
    """
    per_chunk: list[dict[str, Task]] = []
    for i, c in enumerate(chunk_sizes):
        deps_i = (f"O{i-2}",) if (two_buffer_dep and i >= 2) else ()
        per_chunk.append(
            {
                "I": Task(f"I{i}", H2D, h2d_time(c), deps_i),
                "D": Task(f"D{i}", D2H, deserialize_time(c), (f"I{i}",)),
                "R": Task(f"R{i}", COMPUTE, compute_time(c), (f"D{i}",)),
                "O": Task(f"O{i}", D2H, d2h_time(c), (f"R{i}",)),
            }
        )
    tasks: list[Task] = []
    n = len(per_chunk)
    if invert_launch_order:
        # Issue: I0 D0 R0, then for i>0: I_i D_i (before O_{i-1}) R_i O_{i-1}; tail O_{n-1}.
        for i in range(n):
            tasks.append(per_chunk[i]["I"])
            tasks.append(per_chunk[i]["D"])
            tasks.append(per_chunk[i]["R"])
            if i > 0:
                tasks.append(per_chunk[i - 1]["O"])
        tasks.append(per_chunk[n - 1]["O"])
    else:
        for i in range(n):
            tasks.extend(per_chunk[i][k] for k in ("I", "D", "R", "O"))
    return tasks


@dataclass
class PipelineReport:
    makespan: float
    overlap_ratio: float
    sustained_bps: float
    chunk_sizes: list[int]
    schedule: dict[str, ScheduledTask]


def simulate_pipeline(
    total_bytes: int,
    mode: str,
    phi: chunk_model.PhiModel,
    h2d_bps: float,
    d2h_bps: float,
    output_fraction: float = 0.3,
    serialize_fraction: float = 0.02,
    c_init: int = 16 << 20,
    c_fixed: int = 100 << 20,
    c_limit: int = 2 << 30,
    reconstruction: bool = False,
    invert_launch_order: bool = True,
) -> PipelineReport:
    """End-to-end pipeline model: 'none' | 'fixed' | 'adaptive' (Fig. 13)."""
    theta = chunk_model.ThetaModel(beta=1.0 / h2d_bps)
    if mode == "none":
        sizes = [total_bytes]
        two_buf = False
    elif mode == "fixed":
        sizes = chunk_model.fixed_chunk_schedule(total_bytes, c_fixed)
        two_buf = True
    elif mode == "adaptive":
        sizes = chunk_model.adaptive_chunk_schedule(
            total_bytes, c_init, c_limit, phi, theta
        )
        two_buf = True
    else:
        raise ValueError(f"unknown mode {mode!r}")

    h2d = lambda c: c / h2d_bps
    d2h = lambda c: (c * output_fraction) / d2h_bps
    comp = lambda c: phi.time_for(c)
    ser = lambda c: (c * output_fraction * serialize_fraction) / d2h_bps
    if reconstruction:
        dag = build_reconstruction_dag(
            sizes, lambda c: c * output_fraction / h2d_bps, comp,
            lambda c: c / d2h_bps, ser, two_buf, invert_launch_order
        )
    else:
        dag = build_reduction_dag(sizes, h2d, comp, d2h, ser, two_buf)
    sched = TimelineSimulator().run(dag)
    makespan = TimelineSimulator.makespan(sched)
    return PipelineReport(
        makespan=makespan,
        overlap_ratio=TimelineSimulator.overlap_ratio(sched),
        sustained_bps=total_bytes / makespan if makespan else float("inf"),
        chunk_sizes=list(sizes),
        schedule=sched,
    )


# ---------------------------------------------------------------------------
# Real chunked execution (lane-overlapped, double-buffered scheduler)
# ---------------------------------------------------------------------------


@dataclass
class ChunkTiming:
    """Per-chunk lane timings.

    ``spans`` holds the ``(start, end)`` interval of each lane's work for
    this chunk, in seconds relative to the run start — the observable the
    overlap benchmark and the scheduling tests read.  ``h2d``/``compute``/
    ``serialize`` are the corresponding durations; ``d2h`` mirrors
    ``serialize`` (the D2H fetch happens inside serialization) for
    backward compatibility with pre-pipelined readers.
    """

    h2d: float
    compute: float
    d2h: float
    nbytes: int
    serialize: float = 0.0
    spans: dict = field(default_factory=dict)


@dataclass
class ChunkedResult:
    chunks: list                 # list[Compressed]
    boundaries: list[int]        # chunk starts along the split axis
    axis: int
    shape: tuple[int, ...]
    timings: list[ChunkTiming] = field(default_factory=list)
    wall_time: float = 0.0
    max_in_flight: int = 0       # peak staged-but-unserialized chunks
    window: int = 0              # resolved in-flight window of this run
    tuned: dict | None = None    # TunedPlan.to_dict() when auto-resolved

    def nbytes(self) -> int:
        return sum(c.nbytes() for c in self.chunks)

    def ratio(self) -> float:
        import math

        import numpy as _np

        orig = math.prod(self.shape) * _np.dtype(
            self.chunks[0].meta["dtype"]
        ).itemsize
        return orig / max(self.nbytes(), 1)

    def lane_seconds(self) -> dict[str, float]:
        """Summed per-lane busy time across chunks (the serial-sum bound)."""
        out = {"h2d": 0.0, "compute": 0.0, "serialize": 0.0}
        for t in self.timings:
            out["h2d"] += t.h2d
            out["compute"] += t.compute
            out["serialize"] += t.serialize
        return out

    def overlap_efficiency(self) -> float:
        """Serial sum of lane times / pipelined wall clock (>1 = overlap)."""
        total = sum(self.lane_seconds().values())
        return total / self.wall_time if self.wall_time else 1.0


class ChunkedPipeline:
    """Lane-overlapped chunked compression over the largest dimension.

    The JAX adaptation of the paper's Fig. 9 queue machinery, rebuilt on
    the execution engine's submission surface (PR 5): every chunk flows
    through three lanes —

      main thread   slice + ``device_put`` staging (the H2D DMA)
      compute lane  the fused ``CompiledPipeline`` segments (R_i)
      io lane       D2H fetch + container serialization (O_i, S_i)

    — with per-chunk :class:`~repro.runtime.executor.Submission` futures
    chaining compute → serialize, so chunk *i*'s compute runs while chunk
    *i−1* serializes and chunk *i+1* stages.  The in-flight window is
    bounded at ``window`` chunks (default 2, the paper's two-buffer
    (X+2)%3 anti-dependency): staging chunk *i* waits for chunk
    *i−window*'s serialization, which also bounds host+device memory.

    Two-phase codecs pass ``compute_fn(dev_chunk, slot)`` (must block until
    the device work is done — honest lane timings and real overlap
    boundaries depend on it) and ``finish_fn(payload, slot)``; the legacy
    single-phase ``compress_fn`` is still accepted and wrapped.  ``slot``
    is the chunk's window slot (``idx % window``) — callers keyed per-slot
    resources (donated workspaces) off it.

    ``window=1`` degrades to the fully serial schedule — the baseline the
    overlap benchmark and the bit-identity tests compare against.

    ``chunk_size="auto"`` / ``window="auto"`` defer the decision to the
    auto-tuner (``core/tuner.py``): resolution happens at :meth:`run`
    time (it needs the payload size and dtype), through the injected
    ``tuner`` callable — ``tuner(total_elems, itemsize, dtype_str,
    chunk_elems_or_None) -> TunedPlan`` — or the calibration-free
    heuristic when none is given.  Auto resolution only picks *values*;
    the schedule, specs, and bytes are identical to passing the resolved
    numbers explicitly.  Regardless of the tuner's answer, an auto window
    degrades to 1 whenever the run has ≤ 2 chunks (pipelining cannot
    amortize its staging overhead — the small-payload guard).
    """

    def __init__(
        self,
        compress_fn: Callable | None = None,   # (jax.Array chunk) -> Compressed
        mode: str = "adaptive",
        c_init_elems: int = 1 << 20,
        c_fixed_elems: int = 8 << 20,
        c_limit_elems: int = 1 << 28,
        phi: chunk_model.PhiModel | None = None,
        theta: chunk_model.ThetaModel | None = None,
        devices: Sequence | None = None,
        *,
        compute_fn: Callable | None = None,
        finish_fn: Callable | None = None,
        executor=None,
        window: int | str = 2,
        chunk_size: int | str | None = None,
        tuner: Callable | None = None,
    ):
        if compress_fn is None and compute_fn is None:
            raise ValueError("need compress_fn or compute_fn/finish_fn")
        self.compress_fn = compress_fn
        self.compute_fn = compute_fn
        self.finish_fn = finish_fn
        self.mode = mode
        self.c_init = c_init_elems
        self.c_fixed = c_fixed_elems
        self.c_limit = c_limit_elems
        self.phi = phi
        self.theta = theta
        # Chunk placement ring: chunk i lands on devices[i % n] (the engine's
        # data-axis fan-out); default is the single-device HDEM schedule.
        self.devices = list(devices) if devices else None
        self.executor = executor
        self.auto_chunk = chunk_size == "auto"
        if chunk_size is not None and not self.auto_chunk:
            self.mode = "fixed"
            self.c_fixed = int(chunk_size)
        self.auto_window = window == "auto"
        self.window = 2 if self.auto_window else max(1, int(window))
        self.tuner = tuner
        self.tuned = None  # TunedPlan of the most recent auto resolution

    # -- auto (tuner) resolution --------------------------------------------

    def _resolve_auto(self, data: np.ndarray) -> None:
        """Resolve ``auto`` chunk/window for this payload via the tuner."""
        from . import tuner as tuner_mod

        fixed_elems = (
            None if self.auto_chunk
            else (int(self.c_fixed) if self.mode == "fixed" else None)
        )
        plan = None
        if self.tuner is not None:
            try:
                plan = self.tuner(
                    int(data.size), int(data.dtype.itemsize),
                    str(data.dtype), fixed_elems,
                )
            except Exception:
                plan = None
        if plan is None:
            plan = tuner_mod.heuristic_plan(
                int(data.size), int(data.dtype.itemsize),
                chunk_elems=fixed_elems, c_limit_elems=self.c_limit,
                default_window=self.window, dtype=str(data.dtype),
            )
        if self.auto_chunk:
            self.mode = "fixed"
            self.c_fixed = int(plan.chunk_elems)
        if self.auto_window:
            self.window = max(1, int(plan.window))
        self.tuned = plan

    def _schedule(self, total: int) -> list[int]:
        if self.mode == "none":
            return [total]
        if self.mode == "fixed" or self.phi is None or self.theta is None:
            return chunk_model.fixed_chunk_schedule(total, self.c_fixed)
        return chunk_model.adaptive_chunk_schedule(
            total, self.c_init, self.c_limit, self.phi, self.theta
        )

    # -- chunk schedule ------------------------------------------------------

    def _row_schedule(self, data: np.ndarray, axis: int) -> list[int]:
        n = data.shape[axis]
        row_elems = data.size // n
        rows: list[int] = []
        acc = 0
        for s in self._schedule(data.size):
            r = max(1, int(round(s / row_elems)))
            r = min(r, n - acc)
            if r <= 0:
                break
            rows.append(r)
            acc += r
        if acc < n:
            rows.append(n - acc)
        return rows

    # -- phase wrappers ------------------------------------------------------

    def _legacy_compute(self, chunk, slot: int):
        del slot
        comp = self.compress_fn(chunk)
        jax.block_until_ready(
            [a for a in getattr(comp, "arrays", {}).values()] or chunk
        )
        return comp

    @staticmethod
    def _legacy_finish(comp, slot: int):
        del slot
        # D2H: materialize the compressed payload on host
        for k, v in list(getattr(comp, "arrays", {}).items()):
            comp.arrays[k] = np.asarray(v)
        return comp

    # -- the scheduler -------------------------------------------------------

    def run(self, data: np.ndarray) -> ChunkedResult:
        from ..runtime import executor as ex_mod  # runtime import: peer layer

        data = np.asarray(data)
        axis = int(np.argmax(data.shape))  # paper: LargestDim(u)
        if self.auto_chunk or self.auto_window:
            self._resolve_auto(data)
        rows = self._row_schedule(data, axis)
        if self.auto_window and len(rows) <= 2 and (
                self.tuned is None or self.tuned.source != "calibrated"):
            # heuristic small-payload guard: without a calibration, assume
            # ≤2 chunks cannot amortize pipelining.  A calibrated plan has
            # already priced the fixed stream/chunk costs (and may be
            # racing window=2 at 2 chunks), so it decides for itself.
            self.window = 1
        ring = self.devices or [jax.devices()[0]]
        compute_fn = self.compute_fn or self._legacy_compute
        finish_fn = self.finish_fn or self._legacy_finish

        ex = self.executor
        transient = ex is None
        if transient:
            # one compute worker per ring device — the HDEM restriction
            # (§V-B: one reduction kernel at a time per device); chunk
            # computes overlap the io lane and the main-thread staging,
            # never each other on one device
            ex = ex_mod.DeviceExecutor(
                ring, max_workers=len(ring), io_workers=1
            )

        t_wall = time.perf_counter()
        now = lambda: time.perf_counter() - t_wall
        lock = threading.Lock()
        state = {"inflight": 0, "max": 0}
        records: list[dict] = [
            {"nbytes": 0, "spans": {}} for _ in rows
        ]

        def compute_task(idx: int, dev_chunk):
            rec = records[idx]
            t0 = now()
            payload = compute_fn(dev_chunk, idx % self.window)
            rec["spans"]["compute"] = (t0, now())
            return payload

        def serialize_task(idx: int, comp_sub):
            # Cross-lane wait: the io thread blocks on this chunk's compute
            # future (a different pool, so no deadlock).  Serialize tasks
            # are submitted in staging order, which pins the S-engine issue
            # order of Fig. 9 — S_i never reorders behind S_{i+1} even when
            # compute completions race.
            payload = comp_sub.result()
            rec = records[idx]
            t0 = now()
            comp = finish_fn(payload, idx % self.window)
            rec["spans"]["serialize"] = (t0, now())
            with lock:
                state["inflight"] -= 1
            return comp

        boundaries: list[int] = []
        subs: list = []
        start = 0
        try:
            for idx, r in enumerate(rows):
                if idx >= self.window:
                    # bounded in-flight window: the (X+window)%(window+1)
                    # anti-dependency — stage chunk i only once chunk
                    # i−window has fully left the pipeline
                    subs[idx - self.window].result()
                sl = [slice(None)] * data.ndim
                sl[axis] = slice(start, start + r)
                host_chunk = np.ascontiguousarray(data[tuple(sl)])
                with lock:
                    state["inflight"] += 1
                    state["max"] = max(state["max"], state["inflight"])
                rec = records[idx]
                rec["nbytes"] = host_chunk.nbytes
                dev = ring[idx % len(ring)]
                t0 = now()
                dev_chunk = jax.device_put(host_chunk, dev)
                rec["spans"]["h2d"] = (t0, now())
                comp_sub = ex.submit(
                    compute_task, idx, dev_chunk, device=dev
                )
                subs.append(ex.submit(
                    serialize_task, idx, comp_sub, lane=ex_mod.IO
                ))
                boundaries.append(start)
                start += r
            chunks = [s.result() for s in subs]
        finally:
            if transient:
                ex.shutdown()

        timings = []
        for rec in records:
            sp = rec["spans"]
            dur = lambda k: sp[k][1] - sp[k][0] if k in sp else 0.0
            timings.append(ChunkTiming(
                h2d=dur("h2d"), compute=dur("compute"), d2h=dur("serialize"),
                serialize=dur("serialize"), nbytes=rec["nbytes"], spans=sp,
            ))
        wall = now()
        if self.tuned is not None:
            # feed the measured wall back into the tuner's residual so the
            # next prediction for this stream spec starts from reality
            try:
                from . import tuner as tuner_mod

                tuner_mod.observe(
                    self.tuned, int(data.size), int(data.dtype.itemsize), wall
                )
            except Exception:
                pass
        return ChunkedResult(
            chunks=chunks,
            boundaries=boundaries,
            axis=axis,
            shape=tuple(data.shape),
            timings=timings,
            wall_time=wall,
            max_in_flight=state["max"],
            window=self.window,
            tuned=self.tuned.to_dict() if self.tuned is not None else None,
        )


def decompress_chunked(result: ChunkedResult, decompress_fn: Callable) -> np.ndarray:
    parts = [np.asarray(decompress_fn(c)) for c in result.chunks]
    return np.concatenate(parts, axis=result.axis)
