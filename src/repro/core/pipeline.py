"""HDEM — Host-Device Execution Model and the optimized pipeline (HPDR §V).

Machine abstraction (paper Fig. 8): one compute engine + two independent DMA
engines (H2D, D2H).  Restrictions (paper §V-B): one reduction kernel at a
time (structurally true per TPU core); one DMA per direction.

The optimized pipeline (paper Fig. 9) is a depth-3, two-buffer chunked DAG:

  queue i:   I_i (H2D) → R_i (compute) → O_i (D2H) → S_i (serialize)
  anti-dep:  I_i depends on S_{i-2}   — the (X+2)%3 rule that cuts the
             buffer requirement from 3 sets to 2;
  launch-order inversion (reconstruction): deserialize D_{i+1} is issued
             *before* output copy O_i on the shared DMA so the next
             reconstruction's compute is not delayed.

Two execution surfaces:

  * :class:`TimelineSimulator` — deterministic event-driven schedule for a
    task DAG with per-resource issue order (CUDA-stream semantics).  This is
    how Fig. 10/13 overlap numbers are derived on hardware we don't have:
    durations come from measured/modeled Φ and link bandwidths.
  * :class:`ChunkedPipeline` — real chunked execution through JAX async
    dispatch with double-buffered ``device_put``/compute/fetch, used by the
    benchmarks and the compressed-checkpoint writer.
"""

from __future__ import annotations

import time
from dataclasses import dataclass, field
from typing import Callable, Sequence

import jax
import numpy as np

from . import chunk_model

H2D, D2H, COMPUTE = "h2d", "d2h", "compute"
RESOURCES = (H2D, D2H, COMPUTE)


# ---------------------------------------------------------------------------
# Task DAG + event-driven timeline simulator
# ---------------------------------------------------------------------------


@dataclass
class Task:
    name: str
    resource: str
    duration: float
    deps: tuple[str, ...] = ()


@dataclass
class ScheduledTask:
    name: str
    resource: str
    start: float
    end: float


class TimelineSimulator:
    """Schedule tasks in issue order with per-resource serialization.

    Tasks issue in list order; a task starts at
    ``max(resource_free, max(dep.end))`` — exactly the semantics of enqueueing
    onto per-engine hardware queues (CUDA streams / TPU DMA queues).
    """

    def run(self, tasks: Sequence[Task]) -> dict[str, ScheduledTask]:
        free = {r: 0.0 for r in RESOURCES}
        done: dict[str, ScheduledTask] = {}
        for t in tasks:
            dep_end = max((done[d].end for d in t.deps if d in done), default=0.0)
            start = max(free[t.resource], dep_end)
            end = start + t.duration
            done[t.name] = ScheduledTask(t.name, t.resource, start, end)
            free[t.resource] = end
        return done

    @staticmethod
    def makespan(sched: dict[str, ScheduledTask]) -> float:
        return max((s.end for s in sched.values()), default=0.0)

    @staticmethod
    def overlap_ratio(sched: dict[str, ScheduledTask]) -> float:
        """Paper §V-C: overlapped copy time / total copy time.

        A copy instant counts as overlapped ("hidden") when any *other*
        engine — compute or the opposite-direction DMA — is busy at that
        instant.
        """
        copies = [s for s in sched.values() if s.resource in (H2D, D2H)]
        total = sum(s.end - s.start for s in copies)
        if total == 0:
            return 1.0
        overlapped = 0.0
        for s in copies:
            others = [
                (o.start, o.end)
                for o in sched.values()
                if o.resource != s.resource
            ]
            # merge other-engine busy intervals, intersect with this copy
            others.sort()
            merged: list[tuple[float, float]] = []
            for st, en in others:
                if merged and st <= merged[-1][1]:
                    merged[-1] = (merged[-1][0], max(merged[-1][1], en))
                else:
                    merged.append((st, en))
            for cs, ce in merged:
                lo, hi = max(s.start, cs), min(s.end, ce)
                if hi > lo:
                    overlapped += hi - lo
        return overlapped / total


def build_reduction_dag(
    chunk_sizes: Sequence[int],
    h2d_time: Callable[[int], float],
    compute_time: Callable[[int], float],
    d2h_time: Callable[[int], float],
    serialize_time: Callable[[int], float],
    two_buffer_dep: bool = True,
) -> list[Task]:
    """Reduction pipeline DAG of paper Fig. 9 (top)."""
    tasks: list[Task] = []
    for i, c in enumerate(chunk_sizes):
        deps_i = (f"S{i-2}",) if (two_buffer_dep and i >= 2) else ()
        tasks.append(Task(f"I{i}", H2D, h2d_time(c), deps_i))
        tasks.append(Task(f"R{i}", COMPUTE, compute_time(c), (f"I{i}",)))
        tasks.append(Task(f"O{i}", D2H, d2h_time(c), (f"R{i}",)))
        tasks.append(Task(f"S{i}", D2H, serialize_time(c), (f"O{i}",)))
    return tasks


def build_reconstruction_dag(
    chunk_sizes: Sequence[int],
    h2d_time: Callable[[int], float],
    compute_time: Callable[[int], float],
    d2h_time: Callable[[int], float],
    deserialize_time: Callable[[int], float],
    two_buffer_dep: bool = True,
    invert_launch_order: bool = True,
) -> list[Task]:
    """Reconstruction DAG of paper Fig. 9 (bottom).

    ``invert_launch_order=True`` applies the red-arrow optimization: the next
    chunk's deserialization is issued before the current chunk's output copy
    on the shared DMA engine, so reconstruction compute i+1 starts earlier
    and O_i overlaps with it.
    """
    per_chunk: list[dict[str, Task]] = []
    for i, c in enumerate(chunk_sizes):
        deps_i = (f"O{i-2}",) if (two_buffer_dep and i >= 2) else ()
        per_chunk.append(
            {
                "I": Task(f"I{i}", H2D, h2d_time(c), deps_i),
                "D": Task(f"D{i}", D2H, deserialize_time(c), (f"I{i}",)),
                "R": Task(f"R{i}", COMPUTE, compute_time(c), (f"D{i}",)),
                "O": Task(f"O{i}", D2H, d2h_time(c), (f"R{i}",)),
            }
        )
    tasks: list[Task] = []
    n = len(per_chunk)
    if invert_launch_order:
        # Issue: I0 D0 R0, then for i>0: I_i D_i (before O_{i-1}) R_i O_{i-1}; tail O_{n-1}.
        for i in range(n):
            tasks.append(per_chunk[i]["I"])
            tasks.append(per_chunk[i]["D"])
            tasks.append(per_chunk[i]["R"])
            if i > 0:
                tasks.append(per_chunk[i - 1]["O"])
        tasks.append(per_chunk[n - 1]["O"])
    else:
        for i in range(n):
            tasks.extend(per_chunk[i][k] for k in ("I", "D", "R", "O"))
    return tasks


@dataclass
class PipelineReport:
    makespan: float
    overlap_ratio: float
    sustained_bps: float
    chunk_sizes: list[int]
    schedule: dict[str, ScheduledTask]


def simulate_pipeline(
    total_bytes: int,
    mode: str,
    phi: chunk_model.PhiModel,
    h2d_bps: float,
    d2h_bps: float,
    output_fraction: float = 0.3,
    serialize_fraction: float = 0.02,
    c_init: int = 16 << 20,
    c_fixed: int = 100 << 20,
    c_limit: int = 2 << 30,
    reconstruction: bool = False,
    invert_launch_order: bool = True,
) -> PipelineReport:
    """End-to-end pipeline model: 'none' | 'fixed' | 'adaptive' (Fig. 13)."""
    theta = chunk_model.ThetaModel(beta=1.0 / h2d_bps)
    if mode == "none":
        sizes = [total_bytes]
        two_buf = False
    elif mode == "fixed":
        sizes = chunk_model.fixed_chunk_schedule(total_bytes, c_fixed)
        two_buf = True
    elif mode == "adaptive":
        sizes = chunk_model.adaptive_chunk_schedule(
            total_bytes, c_init, c_limit, phi, theta
        )
        two_buf = True
    else:
        raise ValueError(f"unknown mode {mode!r}")

    h2d = lambda c: c / h2d_bps
    d2h = lambda c: (c * output_fraction) / d2h_bps
    comp = lambda c: phi.time_for(c)
    ser = lambda c: (c * output_fraction * serialize_fraction) / d2h_bps
    if reconstruction:
        dag = build_reconstruction_dag(
            sizes, lambda c: c * output_fraction / h2d_bps, comp,
            lambda c: c / d2h_bps, ser, two_buf, invert_launch_order
        )
    else:
        dag = build_reduction_dag(sizes, h2d, comp, d2h, ser, two_buf)
    sched = TimelineSimulator().run(dag)
    makespan = TimelineSimulator.makespan(sched)
    return PipelineReport(
        makespan=makespan,
        overlap_ratio=TimelineSimulator.overlap_ratio(sched),
        sustained_bps=total_bytes / makespan if makespan else float("inf"),
        chunk_sizes=list(sizes),
        schedule=sched,
    )


# ---------------------------------------------------------------------------
# Real chunked execution (double-buffered async dispatch)
# ---------------------------------------------------------------------------


@dataclass
class ChunkTiming:
    h2d: float
    compute: float
    d2h: float
    nbytes: int


@dataclass
class ChunkedResult:
    chunks: list                 # list[Compressed]
    boundaries: list[int]        # chunk starts along the split axis
    axis: int
    shape: tuple[int, ...]
    timings: list[ChunkTiming] = field(default_factory=list)
    wall_time: float = 0.0

    def nbytes(self) -> int:
        return sum(c.nbytes() for c in self.chunks)

    def ratio(self) -> float:
        import math

        import numpy as _np

        orig = math.prod(self.shape) * _np.dtype(
            self.chunks[0].meta["dtype"]
        ).itemsize
        return orig / max(self.nbytes(), 1)


class ChunkedPipeline:
    """Double-buffered chunked compression over the largest dimension.

    JAX adaptation of the paper's queue machinery: ``device_put`` is the H2D
    DMA (async), the jitted reduction is the compute engine, and host fetch
    (``np.asarray``) is the D2H DMA.  Issue order follows Fig. 9: put chunk
    i+1 before computing chunk i; fetch chunk i−1 after issuing compute i —
    on a real TPU runtime all three overlap; buffer reuse is bounded at two
    in-flight device chunks, matching the (X+2)%3 anti-dependency.
    """

    def __init__(
        self,
        compress_fn: Callable,   # (jax.Array chunk) -> Compressed-like
        mode: str = "adaptive",
        c_init_elems: int = 1 << 20,
        c_fixed_elems: int = 8 << 20,
        c_limit_elems: int = 1 << 28,
        phi: chunk_model.PhiModel | None = None,
        theta: chunk_model.ThetaModel | None = None,
        devices: Sequence | None = None,
    ):
        self.compress_fn = compress_fn
        self.mode = mode
        self.c_init = c_init_elems
        self.c_fixed = c_fixed_elems
        self.c_limit = c_limit_elems
        self.phi = phi
        self.theta = theta
        # Chunk placement ring: chunk i lands on devices[i % n] (the engine's
        # data-axis fan-out); default is the single-device HDEM schedule.
        self.devices = list(devices) if devices else None

    def _schedule(self, total: int) -> list[int]:
        if self.mode == "none":
            return [total]
        if self.mode == "fixed" or self.phi is None or self.theta is None:
            return chunk_model.fixed_chunk_schedule(total, self.c_fixed)
        return chunk_model.adaptive_chunk_schedule(
            total, self.c_init, self.c_limit, self.phi, self.theta
        )

    def run(self, data: np.ndarray) -> ChunkedResult:
        axis = int(np.argmax(data.shape))  # paper: LargestDim(u)
        n = data.shape[axis]
        row_elems = data.size // n
        sizes_elems = self._schedule(data.size)
        # convert element counts to row counts along the split axis
        rows: list[int] = []
        acc = 0
        for s in sizes_elems:
            r = max(1, int(round(s / row_elems)))
            r = min(r, n - acc)
            if r <= 0:
                break
            rows.append(r)
            acc += r
        if acc < n:
            rows.append(n - acc)

        boundaries, chunks, timings = [], [], []
        start = 0
        t_wall = time.perf_counter()
        ring = self.devices or [jax.devices()[0]]
        pending_put = None
        pending_rows = None

        idx = 0
        while idx < len(rows):
            r = rows[idx]
            sl = [slice(None)] * data.ndim
            sl[axis] = slice(start, start + r)
            host_chunk = np.ascontiguousarray(data[tuple(sl)])

            t0 = time.perf_counter()
            if pending_put is None:
                dev_chunk = jax.device_put(host_chunk, ring[idx % len(ring)])
            else:
                dev_chunk = pending_put
                host_chunk = pending_rows
            # issue H2D for the NEXT chunk before computing this one (Fig. 9);
            # the ring rotates chunks across the engine's data-axis devices
            nxt = idx + 1
            if nxt < len(rows):
                sl2 = [slice(None)] * data.ndim
                sl2[axis] = slice(start + r, start + r + rows[nxt])
                nxt_host = np.ascontiguousarray(data[tuple(sl2)])
                pending_put = jax.device_put(nxt_host, ring[nxt % len(ring)])
                pending_rows = nxt_host
            else:
                pending_put = None
            t1 = time.perf_counter()
            comp = self.compress_fn(dev_chunk)
            jax.block_until_ready(
                [a for a in getattr(comp, "arrays", {}).values()] or dev_chunk
            )
            t2 = time.perf_counter()
            # D2H: materialize compressed payload on host
            for k, v in list(getattr(comp, "arrays", {}).items()):
                comp.arrays[k] = np.asarray(v)
            t3 = time.perf_counter()

            boundaries.append(start)
            chunks.append(comp)
            timings.append(
                ChunkTiming(h2d=t1 - t0, compute=t2 - t1, d2h=t3 - t2,
                            nbytes=host_chunk.nbytes)
            )
            start += r
            idx += 1

        return ChunkedResult(
            chunks=chunks,
            boundaries=boundaries,
            axis=axis,
            shape=tuple(data.shape),
            timings=timings,
            wall_time=time.perf_counter() - t_wall,
        )


def decompress_chunked(result: ChunkedResult, decompress_fn: Callable) -> np.ndarray:
    parts = [np.asarray(decompress_fn(c)) for c in result.chunks]
    return np.concatenate(parts, axis=result.axis)
