"""Progressive retrieval — the data-refactoring side of the MGARD family.

HPDR's context (paper refs [23]–[25]) is *refactoring*: store the multilevel
decomposition so readers can retrieve a coarse-but-usable approximation
from a byte prefix and refine incrementally.  This module layers that on
MGARD-X:

  * ``refactor``      — decompose + per-level quantize + per-level Huffman
                        streams, ordered coarsest → finest (each level is an
                        independently decodable segment);
  * ``retrieve``      — reconstruct from the first ``levels`` segments:
                        missing fine coefficients are zero, so the result is
                        exactly the level-``l`` interpolant of the data;
  * error telescopes: each additional segment tightens the bound, and the
                        full set reproduces plain MGARD-X compression.

This is the checkpoint-streaming feature of the framework: a restarting pod
can begin warm-up from the coarse prefix while the tail is still in flight.
"""

from __future__ import annotations

import math
from dataclasses import dataclass

import jax
import jax.numpy as jnp
import numpy as np

from . import api, huffman, mgard
from .codecs.base import ReductionSpec
from .quantize import signed_to_unsigned, unsigned_to_signed


def _mgard_plan(shape: tuple[int, ...], dtype, error_bound: float, dict_size: int):
    """CMM-cached MGARD plan — shared with the compression API's contexts,
    so refactoring and plain compression of the same field reuse one set of
    jitted executables and one persistent level map."""
    spec = ReductionSpec.create(
        "mgard", shape, dtype,
        error_bound=float(error_bound), relative=False, dict_size=int(dict_size),
    )
    return api.get_plan(spec)


@dataclass
class ProgressiveStream:
    segments: list            # list[huffman.Encoded], coarsest level first
    level_of_segment: list    # int ids matching mgard.level_map subsets
    outlier_idx: np.ndarray
    outlier_val: np.ndarray
    bins: np.ndarray
    shape: tuple
    padded: tuple
    error_bound: float
    dict_size: int

    def nbytes_upto(self, n_segments: int) -> int:
        return sum(s.nbytes() for s in self.segments[:n_segments])

    def nbytes(self) -> int:
        return self.nbytes_upto(len(self.segments))


def refactor(
    data: jax.Array, error_bound: float, dict_size: int = 4096
) -> ProgressiveStream:
    """MGARD decomposition refactored into per-level entropy segments."""
    shape = tuple(data.shape)
    plan = _mgard_plan(shape, data.dtype, error_bound, dict_size)
    coeffs = plan.executables["decompose"](data)
    padded = plan.meta["padded"]
    L = plan.meta["L"]
    bins = mgard.level_bins(error_bound, L)
    # snapshot + executable call both under the lock: the quantize stage
    # donates the lmap buffer, so unlocked readers could see a dead buffer
    with plan.lock:
        lmap = np.asarray(plan.workspace["lmap"])
        q_dev, _keys, _inlier, recycled = plan.executables["quantize"](
            coeffs, plan.workspace["lmap"], jnp.asarray(bins, jnp.float32)
        )
        plan.recycle("lmap", recycled)
    q = np.asarray(q_dev)
    u = np.asarray(signed_to_unsigned(jnp.asarray(q))).reshape(-1)
    escape = dict_size - 1
    inlier = u < escape
    keys = np.where(inlier, u, escape).astype(np.int32)
    out_idx = np.nonzero(~inlier)[0]
    out_val = q.reshape(-1)[out_idx]

    flat_lmap = lmap.reshape(-1)
    segments, level_ids = [], []
    # coarsest (nodal values, id = L) first, then L-1 ... 0
    for lid in range(L, -1, -1):
        sel = flat_lmap == lid
        if not sel.any():
            continue
        seg_keys = jnp.asarray(keys[sel])
        segments.append(huffman.compress(seg_keys, dict_size))
        level_ids.append(lid)
    return ProgressiveStream(
        segments=segments,
        level_of_segment=level_ids,
        outlier_idx=out_idx.astype(np.int64),
        outlier_val=out_val.astype(np.int32),
        bins=bins,
        shape=shape,
        padded=padded,
        error_bound=float(error_bound),
        dict_size=dict_size,
    )


def retrieve(stream: ProgressiveStream, n_segments: int | None = None) -> jax.Array:
    """Reconstruct from the first ``n_segments`` level segments."""
    if n_segments is None:
        n_segments = len(stream.segments)
    n_segments = max(1, min(n_segments, len(stream.segments)))
    plan = _mgard_plan(stream.shape, "float32", stream.error_bound, stream.dict_size)
    with plan.lock:  # see refactor(): the workspace buffer may be donated
        lmap = np.asarray(plan.workspace["lmap"])
    flat_lmap = lmap.reshape(-1)
    q = np.zeros(int(np.prod(stream.padded)), np.int32)
    loaded_levels = set()
    for seg, lid in zip(stream.segments[:n_segments],
                        stream.level_of_segment[:n_segments]):
        keys = np.asarray(huffman.decompress(seg))
        vals = np.asarray(unsigned_to_signed(jnp.asarray(keys.astype(np.uint32))))
        q[flat_lmap == lid] = vals
        loaded_levels.add(lid)
    # outliers only for loaded levels (they index the padded flat array)
    if stream.outlier_idx.size:
        mask = np.isin(flat_lmap[stream.outlier_idx], list(loaded_levels))
        q[stream.outlier_idx[mask]] = stream.outlier_val[mask]
    with plan.lock:
        coeffs, recycled = plan.executables["dequantize"](
            jnp.asarray(q.reshape(stream.padded)), plan.workspace["lmap"],
            jnp.asarray(stream.bins, jnp.float32),
        )
        plan.recycle("lmap", recycled)
    return plan.executables["recompose"](coeffs)


def error_curve(stream: ProgressiveStream, data: np.ndarray) -> list[dict]:
    """Max-error and cumulative bytes after each retrieved segment."""
    out = []
    for n in range(1, len(stream.segments) + 1):
        approx = np.asarray(retrieve(stream, n))
        out.append(
            {
                "segments": n,
                "level": stream.level_of_segment[n - 1],
                "bytes": stream.nbytes_upto(n),
                "max_err": float(np.abs(approx - data).max()),
            }
        )
    return out
