"""Progressive multi-precision retrieval — the HP-MDR side of MGARD.

HPDR's refactoring context (paper refs [23]–[25]): store a field as a
sequence of *precision components* so a reader fetches only the bytes a
requested error bound needs, and refines incrementally later:

  * ``refactor``          — MGARD-decompose once, then quantize the residual
                            coefficients at a geometric ladder of error
                            bounds (tier 0 coarsest); each tier's keys ride
                            the stage-graph Huffman pipeline and become one
                            self-contained, separately addressable component;
  * ``ProgressiveStream`` — the manifest + component blobs, serialisable as
                            a v2 container (per-section crc32) or written as
                            an ``AggregatedWriter`` segment file;
  * ``ProgressiveReader`` — opens either form and answers ``retrieve(err=…)``
                            by pread-ing exactly the component prefix that
                            bound needs; ``refine(err'=…)`` preads only the
                            delta and extends the cached coefficient sum, so
                            earlier bytes are never re-read.

Error contract: after loading tiers ``0..t`` the reconstruction satisfies
``max|x − x̂| ≤ tier_bounds[t]`` — the residual left after tier ``t`` is
exactly tier ``t``'s quantization error, so the plain MGARD bin-schedule
proof applies per tier.  Retrieval accumulates dequantized tiers in fixed
coarse→fine order, which makes ``retrieve(e)`` + ``refine(e')`` bit-identical
to a direct ``retrieve(e')``.

All plans resolve through the CMM: the MGARD executables come from the same
geometry-keyed entry plain ``mgard`` decoding uses (one plan per shape
regardless of bound), and per-tier entropy coding goes through
``api.encode``/``api.decode`` on a shared Huffman spec — no plan-less legacy
calls remain.
"""

from __future__ import annotations

import math
from dataclasses import dataclass, field

import jax
import jax.numpy as jnp
import numpy as np

from . import api, container, mgard
from .codecs import get_codec
from .codecs.base import ReductionSpec
from .container import Compressed, ContainerError
from .quantize import unsigned_to_signed

METHOD = "mgard-progressive"
DEFAULT_TIERS = 3
DEFAULT_TIER_RATIO = 8.0

_unsigned_to_signed_jit = jax.jit(unsigned_to_signed)


def component_name(tier: int) -> str:
    """Canonical section/segment name of one precision component."""
    return f"component/{int(tier):05d}"


def tier_bounds(
    error_bound: float,
    tiers: int = DEFAULT_TIERS,
    tier_ratio: float = DEFAULT_TIER_RATIO,
) -> list[float]:
    """Geometric ladder of absolute bounds, coarsest first; the last entry
    is ``error_bound`` itself (full precision)."""
    eb = float(error_bound)
    tiers = int(tiers)
    ratio = float(tier_ratio)
    if eb <= 0:
        raise ValueError(f"error_bound must be positive, got {eb}")
    if tiers < 1:
        raise ValueError(f"need at least one tier, got {tiers}")
    if ratio <= 1.0:
        raise ValueError(f"tier_ratio must exceed 1, got {ratio}")
    return [eb * ratio ** (tiers - 1 - t) for t in range(tiers)]


def _mgard_plan(shape: tuple[int, ...], dict_size: int, backend=None):
    """CMM-cached MGARD plan keyed on geometry only (no error bound): every
    tier, every retrieval, and plain ``mgard`` decoding of the same shape
    share one set of jitted executables and one persistent level map."""
    kwargs = {} if backend is None else {"backend": backend}
    spec = ReductionSpec.create(
        "mgard", shape, "float32", dict_size=int(dict_size), **kwargs
    )
    return api.get_plan(spec)


def _huffman_spec(n: int, backend=None) -> ReductionSpec:
    """Shared CMM spec for per-tier key streams (one plan per grid size)."""
    kwargs = {} if backend is None else {"backend": backend}
    return get_codec("huffman").make_spec((int(n),), "int32", **kwargs)


# ---------------------------------------------------------------------------
# stream object: manifest + component blobs
# ---------------------------------------------------------------------------


@dataclass
class ProgressiveStream:
    """A refactored field: JSON-able manifest + per-tier component blobs.

    ``components`` may be a *prefix* of the manifest's tiers (a reader that
    only fetched the coarse tiers still holds a valid stream); component
    ``t`` is a self-contained v2 container (Huffman key stream + outliers).
    """

    manifest: dict
    components: list = field(default_factory=list)

    # ------------------------------------------------------------ accessors

    @property
    def shape(self) -> tuple[int, ...]:
        return tuple(self.manifest["shape"])

    @property
    def padded(self) -> tuple[int, ...]:
        return tuple(self.manifest["padded"])

    @property
    def dict_size(self) -> int:
        return int(self.manifest["dict_size"])

    @property
    def tier_bounds(self) -> list[float]:
        return [float(b) for b in self.manifest["tier_bounds"]]

    @property
    def tiers(self) -> int:
        return len(self.manifest["tier_bounds"])

    def tiers_for(self, err: float | None) -> int:
        """Smallest component prefix whose bound satisfies ``err``."""
        if err is None:
            return self.tiers
        for k, b in enumerate(self.tier_bounds, start=1):
            if b <= float(err):
                return k
        return self.tiers

    def nbytes_upto(self, k: int) -> int:
        return sum(int(n) for n in self.manifest["component_nbytes"][:k])

    def nbytes(self) -> int:
        return self.nbytes_upto(self.tiers)

    # ----------------------------------------------- monolithic container

    def to_container(self) -> Compressed:
        """One v2 container: manifest in meta, one uint8 section per tier.

        Per-section crc32 entries (container v2, additive) let
        :meth:`ProgressiveReader.from_bytes` verify and decode a component
        prefix without touching the later sections' bytes.
        """
        arrays = {
            component_name(t): np.frombuffer(blob, np.uint8)
            for t, blob in enumerate(self.components)
        }
        meta = dict(self.manifest)
        meta.setdefault("dtype", "float32")
        return Compressed(method=METHOD, meta=meta, arrays=arrays)

    @classmethod
    def from_container(cls, c: Compressed) -> "ProgressiveStream":
        manifest = {
            k: c.meta[k]
            for k in (
                "shape", "padded", "L", "dict_size",
                "tier_bounds", "component_nbytes",
            )
        }
        components = []
        for t in range(len(manifest["tier_bounds"])):
            name = component_name(t)
            if name not in c.arrays:
                break  # a reader may hold only a prefix
            components.append(np.asarray(c.arrays[name], np.uint8).tobytes())
        return cls(manifest=manifest, components=components)

    def to_bytes(self) -> bytes:
        return self.to_container().to_bytes()

    @classmethod
    def from_bytes(cls, raw: bytes) -> "ProgressiveStream":
        return cls.from_container(Compressed.from_bytes(raw))

    # ------------------------------------------------------ aggregated file

    def write(self, path, *, align: int = 4096, **writer_kwargs) -> dict:
        """Write an ``AggregatedWriter`` segment file: one crc-checked
        segment per component, manifest in the directory meta.  Returns the
        writer's closing directory."""
        from ..runtime.io import AggregatedWriter  # lazy: core ↔ runtime

        with AggregatedWriter(
            path, align=align, meta=container._jsonable(self.manifest),
            **writer_kwargs,
        ) as w:
            for t, blob in enumerate(self.components):
                w.add(component_name(t), blob)
        return w.directory()


# ---------------------------------------------------------------------------
# refactor: decompose once, residual-quantize per tier
# ---------------------------------------------------------------------------


def refactor(
    data,
    error_bound: float,
    *,
    tiers: int = DEFAULT_TIERS,
    tier_ratio: float = DEFAULT_TIER_RATIO,
    dict_size: int = 4096,
    backend=None,
) -> ProgressiveStream:
    """Refactor ``data`` into ``tiers`` precision components.

    ``error_bound`` is the *absolute* L∞ bound of the finest tier; tier
    ``t`` targets ``error_bound * tier_ratio**(tiers-1-t)``.  Each tier
    quantizes the residual the previous tiers left, so components telescope
    and a prefix read honours that prefix's bound exactly.
    """
    data = jnp.asarray(data)
    if data.dtype != jnp.float32:
        data = data.astype(jnp.float32)
    shape = tuple(data.shape)
    plan = _mgard_plan(shape, dict_size, backend)
    padded, L = plan.meta["padded"], plan.meta["L"]
    bounds = tier_bounds(error_bound, tiers, tier_ratio)
    escape = int(dict_size) - 1

    coeffs = plan.executables["decompose"](data)
    partial = None
    hspec = _huffman_spec(max(1, math.prod(padded)), backend)
    components: list[bytes] = []
    for t, eb_t in enumerate(bounds):
        bins = jnp.asarray(mgard.level_bins(eb_t, L), jnp.float32)
        residual = coeffs if partial is None else coeffs - partial
        with plan.lock:  # quantize donates the lmap workspace buffer
            q_dev, keys_dev, inlier_dev, recycled = plan.executables["quantize"](
                residual, plan.workspace["lmap"], bins
            )
            plan.recycle("lmap", recycled)
        keys = np.asarray(keys_dev).reshape(-1)
        inlier = np.asarray(inlier_dev).reshape(-1)
        out_idx = np.nonzero(~inlier)[0].astype(np.int64)
        out_val = np.asarray(q_dev).reshape(-1)[out_idx].astype(np.int32)

        c = api.encode(hspec, jnp.asarray(keys))
        c.meta.update(tier=t, error_bound=float(eb_t), escape=escape)
        c.arrays.update(outlier_idx=out_idx, outlier_val=out_val)
        components.append(c.to_bytes())

        # Advance the encoder's partial with *exactly* what a reader will
        # reconstruct for this tier (dequantized unclamped q), so the next
        # residual telescopes without drift.
        with plan.lock:
            coeffs_t, recycled = plan.executables["dequantize"](
                q_dev, plan.workspace["lmap"], bins
            )
            plan.recycle("lmap", recycled)
        partial = coeffs_t if partial is None else partial + coeffs_t

    manifest = {
        "shape": list(shape),
        "padded": list(padded),
        "L": int(L),
        "dict_size": int(dict_size),
        "tier_bounds": [float(b) for b in bounds],
        "component_nbytes": [len(b) for b in components],
    }
    return ProgressiveStream(manifest=manifest, components=components)


# ---------------------------------------------------------------------------
# retrieval: decode a component prefix, accumulate coarse→fine
# ---------------------------------------------------------------------------


def _component_q(blob: bytes, padded: tuple[int, ...], dict_size: int) -> np.ndarray:
    """Decode one component blob back to its flat quantized values."""
    c = Compressed.from_bytes(blob)
    keys = np.asarray(api.decode(c), np.uint32).reshape(-1)
    q = np.asarray(_unsigned_to_signed_jit(jnp.asarray(keys))).reshape(-1)
    out_idx = np.asarray(c.arrays.get("outlier_idx", np.empty(0, np.int64)))
    if out_idx.size:
        q = q.copy()
        q[out_idx] = np.asarray(c.arrays["outlier_val"], np.int32)
    return q.astype(np.int32)


def _accumulate(plan, manifest: dict, blobs: list, start: int, coeff_sum):
    """Dequantize components ``start..start+len(blobs)`` into ``coeff_sum``.

    Both the whole-stream path and :class:`ProgressiveReader.refine` run
    through here, with the same left-to-right float accumulation order —
    that shared order is what makes retrieve+refine bit-identical to a
    direct retrieve at the finer bound.
    """
    padded = tuple(manifest["padded"])
    L = int(manifest["L"])
    dict_size = int(manifest["dict_size"])
    bounds = manifest["tier_bounds"]
    for i, blob in enumerate(blobs):
        t = start + i
        q = _component_q(blob, padded, dict_size).reshape(padded)
        bins = jnp.asarray(mgard.level_bins(float(bounds[t]), L), jnp.float32)
        with plan.lock:
            coeffs_t, recycled = plan.executables["dequantize"](
                jnp.asarray(q), plan.workspace["lmap"], bins
            )
            plan.recycle("lmap", recycled)
        coeff_sum = coeffs_t if coeff_sum is None else coeff_sum + coeffs_t
    return coeff_sum


def retrieve(
    stream: ProgressiveStream,
    err: float | None = None,
    *,
    tiers: int | None = None,
    backend=None,
) -> jax.Array:
    """Reconstruct from the component prefix satisfying ``err`` (or the
    first ``tiers`` components; default: everything the stream holds)."""
    if tiers is None:
        k = stream.tiers_for(err)
    else:
        k = max(1, min(int(tiers), stream.tiers))
    k = max(1, min(k, len(stream.components)))
    plan = _mgard_plan(stream.shape, stream.dict_size, backend)
    coeff = _accumulate(plan, stream.manifest, stream.components[:k], 0, None)
    return plan.executables["recompose"](coeff)


def error_curve(stream: ProgressiveStream, data) -> list[dict]:
    """Achieved max-error and cumulative bytes after each component."""
    data = np.asarray(data, np.float32)
    out = []
    for k in range(1, len(stream.components) + 1):
        approx = np.asarray(retrieve(stream, tiers=k))
        out.append(
            {
                "tier": k - 1,
                "bound": stream.tier_bounds[k - 1],
                "bytes": stream.nbytes_upto(k),
                "max_err": float(np.abs(approx - data).max()) if data.size else 0.0,
            }
        )
    return out


# ---------------------------------------------------------------------------
# reader: prefix preads + delta refinement
# ---------------------------------------------------------------------------


class _SegmentSource:
    """Components from an aggregated segment file (one pread per tier)."""

    def __init__(self, path):
        from ..runtime.io import AggregatedReader  # lazy: core ↔ runtime

        self.reader = AggregatedReader(path)
        self.manifest = dict(self.reader.meta)

    def read(self, tier: int) -> bytes:
        return self.reader.read(component_name(tier))

    def close(self) -> None:
        self.reader.close()


class _SectionSource:
    """Components from a monolithic v2 container held in memory.

    Per-section crc32 entries verify each component alone; old streams
    written before per-section checksums fall back to one whole-payload
    host verification (see :func:`repro.core.container.read_section_bytes`).
    """

    def __init__(self, raw: bytes):
        self.raw = bytes(raw)
        header, _ = container.peek_header(self.raw)
        if header["method"] != METHOD:
            raise ContainerError(
                f"not a progressive stream: method {header['method']!r}"
            )
        self.manifest = dict(header["meta"])

    def read(self, tier: int) -> bytes:
        return container.read_section_bytes(self.raw, component_name(tier))

    def close(self) -> None:
        pass


class ProgressiveReader:
    """Incremental reader: ``retrieve`` fetches a prefix, ``refine`` a delta.

    Accounting attributes (the acceptance surface):

    * ``bytes_fetched`` — component payload bytes read so far;
    * ``preads``        — component reads issued (one per tier, ever);
    * ``tiers_loaded``  — components decoded into the cached coefficient sum.

    A second call never re-reads earlier components: refinement decodes only
    the new tiers and extends the cached sum in the same accumulation order
    a direct retrieve would use, so the results are bit-identical.
    """

    def __init__(self, path=None, *, backend=None, _source=None):
        self._source = _source if _source is not None else _SegmentSource(path)
        self.manifest = self._source.manifest
        self._backend = backend
        self._plan = _mgard_plan(
            tuple(self.manifest["shape"]), int(self.manifest["dict_size"]), backend
        )
        self.bytes_fetched = 0
        self.preads = 0
        self.tiers_loaded = 0
        self._coeff = None

    @classmethod
    def from_bytes(cls, raw: bytes, *, backend=None) -> "ProgressiveReader":
        """Reader over a monolithic container blob (section-prefix reads)."""
        return cls(backend=backend, _source=_SectionSource(raw))

    # ------------------------------------------------------------ accessors

    @property
    def shape(self) -> tuple[int, ...]:
        return tuple(self.manifest["shape"])

    @property
    def tier_bounds(self) -> list[float]:
        return [float(b) for b in self.manifest["tier_bounds"]]

    @property
    def tiers(self) -> int:
        return len(self.manifest["tier_bounds"])

    def tiers_for(self, err: float | None) -> int:
        if err is None:
            return self.tiers
        for k, b in enumerate(self.tier_bounds, start=1):
            if b <= float(err):
                return k
        return self.tiers

    # ------------------------------------------------------------- retrieval

    def _load_upto(self, k: int) -> None:
        blobs = []
        for t in range(self.tiers_loaded, k):
            blob = self._source.read(t)  # crc-checked, names the component
            self.bytes_fetched += len(blob)
            self.preads += 1
            blobs.append(blob)
        if blobs:
            self._coeff = _accumulate(
                self._plan, self.manifest, blobs, self.tiers_loaded, self._coeff
            )
            self.tiers_loaded = k

    def retrieve(
        self, err: float | None = None, *, tiers: int | None = None
    ) -> jax.Array:
        """Reconstruct at ``err`` (or a component count), fetching only the
        not-yet-loaded part of the needed prefix."""
        if tiers is None:
            k = self.tiers_for(err)
        else:
            k = max(1, min(int(tiers), self.tiers))
        # never discard precision already paid for: a coarser second call
        # reuses the finer cached sum (still within the requested bound)
        self._load_upto(max(k, self.tiers_loaded))
        return self._plan.executables["recompose"](self._coeff)

    def refine(
        self, err: float | None = None, *, tiers: int | None = None
    ) -> jax.Array:
        """Tighten a previous retrieval; reads only the delta components."""
        return self.retrieve(err, tiers=tiers)

    # -------------------------------------------------------------- lifecycle

    def close(self) -> None:
        self._source.close()

    def __enter__(self) -> "ProgressiveReader":
        return self

    def __exit__(self, *exc) -> None:
        self.close()
