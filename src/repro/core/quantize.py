"""Linear quantization — the Map&Process stage of MGARD (paper Alg. 1 l.14).

MGARD distributes the user error budget across decomposition levels by giving
each level its own quantization bin size; elements are mapped to their level
(subset) and quantized with that level's bin — a textbook Map&Process
abstraction.  The TPU lowering is the masked-dense / param-gather idiom from
``abstractions.map_and_process_param``.

Error property (tested): |x - dequantize(quantize(x))| <= bin/2 elementwise.
"""

from __future__ import annotations

import jax
import jax.numpy as jnp

from .abstractions import map_and_process_param


def quantize(x: jax.Array, bin_size) -> jax.Array:
    """Uniform scalar quantizer: q = round(x / bin)."""
    return jnp.round(x / bin_size).astype(jnp.int32)


def dequantize(q: jax.Array, bin_size, dtype=jnp.float32) -> jax.Array:
    return (q.astype(jnp.float64) * jnp.asarray(bin_size, jnp.float64)).astype(dtype)


def quantize_by_subset(
    x: jax.Array, subset_ids: jax.Array, bins: jax.Array
) -> jax.Array:
    """Per-subset (per-level) quantization via Map&Process."""
    return map_and_process_param(
        x, subset_ids, lambda v, b: jnp.round(v / b), bins
    ).astype(jnp.int32)


def dequantize_by_subset(
    q: jax.Array, subset_ids: jax.Array, bins: jax.Array, dtype=jnp.float32
) -> jax.Array:
    return map_and_process_param(
        q.astype(dtype), subset_ids, lambda v, b: v * b, bins.astype(dtype)
    )


def signed_to_unsigned(q: jax.Array) -> jax.Array:
    """Zig-zag map int32 → uint32 so Huffman sees small magnitudes as small keys."""
    q = q.astype(jnp.int32)
    return ((q << 1) ^ (q >> 31)).astype(jnp.uint32)


def unsigned_to_signed(u: jax.Array) -> jax.Array:
    u = u.astype(jnp.uint32)
    return ((u >> 1).astype(jnp.int32)) ^ -(u & jnp.uint32(1)).astype(jnp.int32)
