"""Stage-graph codec pipeline (see :mod:`repro.core.stages.base`).

Codecs declare their pipelines as :class:`StageGraph` compositions of the
concrete stages in :mod:`repro.core.stages.library`;
``ReductionPlan.pipeline`` holds the compiled form (fused device segments +
host barriers).  Custom stages subclass :class:`Stage` and slot into a
codec's ``build_stages`` — see docs/api.md, "Stage graph".
"""

from __future__ import annotations

from .base import (  # noqa: F401
    CallEnv,
    CompiledPipeline,
    LeafView,
    Stage,
    StageGraph,
    TraceEnv,
    TransferStats,
)
from .library import (  # noqa: F401
    AlphabetBind,
    AlphabetScan,
    BinSchedule,
    BitPack,
    ByteKeys,
    CodebookBuild,
    HuffmanEntropy,
    HuffmanHistogram,
    IntKeys,
    MgardDecorrelate,
    UniformQuantize,
    ZfpBlockTransform,
)
