"""Stage-graph codec pipeline — reductions as composable device stages.

HPDR's architectural claim (paper §III, Fig. 1) is that a reduction is a
*pipeline of composable stages* — decorrelate → quantize → entropy → pack —
that runs end-to-end on the device, with host↔device traffic reduced to the
few metadata-scale synchronisation points the algorithm genuinely needs
(2.3% of runtime in the paper's measurement).  This package makes that
structure explicit:

  * :class:`Stage` — the protocol one pipeline stage implements.  *Device*
    stages expose pure, jittable ``apply``/``invert`` transformations of the
    flowing state; *host* stages are the explicit synchronisation points
    (e.g. canonical-codebook construction from the device histogram) and
    declare exactly which state keys they pull to host (``fetches``) — the
    quantity the transfer-bytes benchmark tracks.
  * :class:`StageGraph` — a codec's declarative stage composition plus the
    state keys its container serialiser consumes (``finish_keys``).
  * :class:`CompiledPipeline` — what ``StageGraph.compile(plan)`` produces
    and ``ReductionPlan.pipeline`` stores: maximal runs of device stages
    fused into **one jitted executable per segment** (host barriers are the
    only cut points), with liveness-pruned inputs/outputs so intermediate
    arrays never leave the device.

The same compiled segments serve both execution shapes: the per-leaf path
(:meth:`CompiledPipeline.run`) and the execution engine's stacked
``shard_map`` path (:meth:`CompiledPipeline.run_batched`), where every
device segment is vmapped over the leaf axis and the host stages loop over
metadata-scale per-leaf fetches.  That is what lets the host-staged codecs
(MGARD, Huffman) join ZFP on the engine's stacked fan-out: the only host
work left per bucket is codebook construction.

State is a flat ``dict[str, Array]``; stages declare ``reads``/``writes``
so the compiler can partition and prune without tracing.  Statics (e.g. the
packed word-buffer size) flow through :class:`CallEnv` — host stages set
them, and each later segment is re-jitted per distinct static tuple (with
:meth:`Stage.jit_statics` rounding, so e.g. word buffers bucket to 4 KiB
multiples instead of retracing per byte-length).
"""

from __future__ import annotations

import threading
from dataclasses import dataclass, field
from typing import Any, Callable, Sequence

import jax
import jax.numpy as jnp
import numpy as np

from .. import adapters


# ---------------------------------------------------------------------------
# transfer accounting
# ---------------------------------------------------------------------------


def _nbytes(a: Any) -> int:
    return int(getattr(a, "nbytes", 0))


@dataclass
class TransferStats:
    """Host↔device byte accounting for pipeline executions.

    ``d2h`` counts exactly the bytes host stages fetch plus the bytes the
    container serialiser pulls (:meth:`LeafView.fetch`); ``h2d`` counts the
    input staging plus operands host stages ship back.  This is the
    observable behind the paper's 2.3%-transfer claim, emitted per codec by
    ``scripts/check.sh bench stages``.
    """

    h2d: int = 0
    d2h: int = 0

    def count_h2d(self, *arrays: Any) -> None:
        self.h2d += sum(_nbytes(a) for a in arrays)

    def count_d2h(self, *arrays: Any) -> None:
        self.d2h += sum(_nbytes(a) for a in arrays)

    def as_dict(self) -> dict[str, int]:
        return {"h2d_bytes": self.h2d, "d2h_bytes": self.d2h}


# ---------------------------------------------------------------------------
# per-call environment
# ---------------------------------------------------------------------------


class CallEnv:
    """Mutable per-call environment threaded through one pipeline run.

    Host stages write three kinds of products here:
      * ``meta``     — per-call metadata destined for the container header
                       (per-stage sections, see :meth:`StageGraph.describe`);
      * ``operands`` — host-built arrays later device segments consume
                       (canonical codebook tables, bin schedules), shipped
                       H2D once per call;
      * ``statics``  — python ints later segments are specialised on
                       (packed word count, alphabet size).
    """

    __slots__ = ("plan", "spec", "meta", "operands", "statics", "transfers")

    def __init__(self, plan: Any, transfers: TransferStats | None = None):
        self.plan = plan
        self.spec = plan.spec
        self.meta: dict[str, Any] = {}
        self.operands: dict[str, Any] = {}
        self.statics: dict[str, int] = dict(plan.meta.get("statics", ()) or {})
        self.transfers = transfers if transfers is not None else TransferStats()


class TraceEnv:
    """What a device stage sees inside a fused jitted segment: traced
    operand/workspace arrays plus the segment's static values."""

    __slots__ = ("statics", "backend", "_operands", "_workspace")

    def __init__(self, statics: dict, backend: str, operands: dict, workspace: dict):
        self.statics = statics
        self.backend = backend
        self._operands = operands
        self._workspace = workspace

    def static(self, name: str) -> Any:
        return self.statics[name]

    def operand(self, name: str) -> jax.Array:
        return self._operands[name]

    def workspace(self, name: str) -> jax.Array:
        return self._workspace[name]


# ---------------------------------------------------------------------------
# the Stage protocol
# ---------------------------------------------------------------------------


class Stage:
    """One named, composable pipeline stage.

    Device stages (``device = True``) implement :meth:`apply` (and
    :meth:`invert` for the decode direction) as *pure jittable* functions:
    they may only read the declared ``reads`` state keys, ``operands``,
    ``workspace`` buffers and ``statics``, and must return the declared
    ``writes``.  The compiler fuses consecutive device stages into one
    jitted executable — a stage never implies a dispatch boundary.

    Host stages (``device = False``) implement :meth:`host_apply`.  They are
    the explicit synchronisation points of the graph: ``fetches`` names the
    state keys pulled D2H (metadata scale by design), and anything they put
    in ``env.operands`` is shipped H2D for the segments that follow.

    ``stage_meta`` is the stage's metadata contract: the static,
    plan-derived parameters recorded per stage in the container header so a
    reader can reconstruct the pipeline that wrote a stream.
    """

    name: str = "stage"
    device: bool = True
    reads: tuple[str, ...] = ()
    writes: tuple[str, ...] = ()
    operands: tuple[str, ...] = ()
    workspace: tuple[str, ...] = ()
    donates: tuple[str, ...] = ()
    statics: tuple[str, ...] = ()
    fetches: tuple[str, ...] = ()         # host stages only
    static_outputs: tuple[str, ...] = ()  # host stages only

    # -- decode direction ----------------------------------------------------
    # Device stages with a non-empty ``inv_writes`` participate in the
    # compiled inverse pipeline: ``invert`` is fused exactly like ``apply``,
    # with its own reads/writes/operands/statics declarations.  Host stages
    # implement ``host_prepare`` instead of a device fetch: the decode
    # direction has *no* device→host synchronisation points — everything a
    # host stage contributed at encode time (codebooks, bin schedules) is in
    # the container, so preparation only reads ``env.meta`` and ships
    # operands.  That is why a codec's whole decode chain fuses into a
    # single jitted executable (see CompiledPipeline.invert).
    inv_reads: tuple[str, ...] = ()
    inv_writes: tuple[str, ...] = ()
    inv_operands: tuple[str, ...] = ()
    inv_workspace: tuple[str, ...] = ()
    inv_donates: tuple[str, ...] = ()
    inv_statics: tuple[str, ...] = ()
    inv_static_outputs: tuple[str, ...] = ()  # host stages only

    def planned(self, plan: Any) -> None:
        """Plan-time hook: record plan-constant statics/workspace/meta."""

    # -- device stages -------------------------------------------------------

    def apply(self, env: TraceEnv, state: dict) -> dict:
        raise NotImplementedError(f"{self.name} is not a device stage")

    def invert(self, env: TraceEnv, state: dict) -> dict:
        raise NotImplementedError(f"{self.name} has no inverse")

    # -- host stages ---------------------------------------------------------

    def host_apply(self, env: CallEnv, fetched: dict[str, np.ndarray]) -> None:
        raise NotImplementedError(f"{self.name} is not a host stage")

    def host_prepare(self, env: CallEnv) -> None:
        """Decode-direction preparation: derive operands/statics from the
        container metadata in ``env.meta`` (never a device fetch)."""

    def merge_static(self, name: str, values: Sequence[int]) -> int:
        """Combine per-leaf statics for a stacked batch (default: must agree)."""
        v0 = values[0]
        if any(v != v0 for v in values):
            raise ValueError(
                f"stage {self.name}: static {name!r} differs across leaves "
                f"({sorted(set(values))}); override merge_static to combine"
            )
        return v0

    def jit_statics(self, statics: dict[str, int]) -> dict[str, int]:
        """Statics as baked into the jitted segment (hook for bucketing
        data-dependent sizes so traces are reused across calls)."""
        return statics

    def stage_meta(self, plan: Any) -> dict[str, Any]:
        return {}


# ---------------------------------------------------------------------------
# graph → compiled pipeline
# ---------------------------------------------------------------------------


@dataclass(frozen=True)
class StageGraph:
    """A codec's declarative stage composition.

    ``finish_keys`` are the state keys the codec's container serialiser may
    fetch after the run — the liveness roots that keep segment outputs
    alive.  ``inputs`` names the initial state (default: the raw ``data``
    array).
    """

    stages: tuple[Stage, ...]
    finish_keys: tuple[str, ...]
    inputs: tuple[str, ...] = ("data",)
    # decode direction: ``inv_inputs`` names the state the codec rebuilds
    # from container sections (empty: the graph has no compiled inverse);
    # ``inv_finish`` the keys the inverse run must produce; ``inv_pads``
    # rounds named state arrays up to a size bucket before the fused
    # executable sees them (bounds retraces across stream sizes, the decode
    # analogue of BitPack.jit_statics); ``inv_fills`` sets the pad fill
    # value per key (e.g. an out-of-range sentinel for scatter indices).
    inv_inputs: tuple[str, ...] = ()
    inv_finish: tuple[str, ...] = ("data",)
    inv_pads: tuple[tuple[str, int], ...] = ()
    inv_fills: tuple[tuple[str, int], ...] = ()

    def compile(self, plan: Any) -> "CompiledPipeline":
        return CompiledPipeline(self, plan)

    def describe(self, plan: Any) -> list[dict]:
        """Per-stage metadata layout recorded in the container header."""
        out = []
        for st in self.stages:
            entry = {"stage": st.name, "kind": "device" if st.device else "host"}
            entry.update(st.stage_meta(plan))
            out.append(entry)
        return out


@dataclass
class _Segment:
    """A maximal run of device stages fused into one jitted executable.

    ``direction`` selects which side of the Stage protocol the fused
    executable calls: ``"fwd"`` runs ``apply`` in graph order, ``"inv"``
    runs ``invert`` with ``stages`` already stored in inverse execution
    order (the compiler reverses the graph when partitioning).
    """

    index: int
    stages: list[Stage]
    direction: str = "fwd"
    in_keys: tuple[str, ...] = ()
    out_keys: tuple[str, ...] = ()
    operand_keys: tuple[str, ...] = ()
    workspace_keys: tuple[str, ...] = ()
    donate_keys: tuple[str, ...] = ()
    static_keys: tuple[str, ...] = ()

    @property
    def name(self) -> str:
        sep = "+" if self.direction == "fwd" else "·"
        base = sep.join(st.name for st in self.stages)
        return base if self.direction == "fwd" else f"invert[{base}]"


def _dedup(items) -> tuple:
    seen, out = set(), []
    for it in items:
        if it not in seen:
            seen.add(it)
            out.append(it)
    return tuple(out)


class CompiledPipeline:
    """Compiled stage graph bound to one :class:`ReductionPlan`.

    Segment executables are built lazily per distinct static tuple and
    cached here (the plan lives in the CMM, so the cache has plan lifetime —
    the stage-graph analogue of the paper's cached plans).  ``run`` executes
    the per-leaf path; ``run_batched`` drives a stacked leaf batch, with the
    engine supplying the mesh mapping for each device segment.
    """

    def __init__(self, graph: StageGraph, plan: Any):
        self.graph = graph
        self.plan = plan
        self._lock = threading.Lock()
        self._exe: dict[tuple, Callable] = {}
        for st in graph.stages:
            st.planned(plan)
        self.steps = self._partition()
        self.inv_preps, self.inv_segments = self._partition_inverse()
        plan.meta.setdefault("stage_graph", graph.describe(plan))

    @property
    def invertible(self) -> bool:
        """True when the graph compiled a device-resident decode direction."""
        return bool(self.inv_segments)

    # -- compilation ---------------------------------------------------------

    def _partition(self) -> list[Any]:
        """Group consecutive device stages; compute liveness per boundary."""
        groups: list[Any] = []
        for st in self.graph.stages:
            if st.device and groups and isinstance(groups[-1], _Segment):
                groups[-1].stages.append(st)
            elif st.device:
                groups.append(_Segment(index=len(groups), stages=[st]))
            else:
                groups.append(st)

        # keys needed after each step: later reads/fetches + finish keys
        needed_after: list[set[str]] = []
        needed = set(self.graph.finish_keys)
        for step in reversed(groups):
            needed_after.append(set(needed))
            if isinstance(step, _Segment):
                for st in step.stages:
                    needed |= set(st.reads)
            else:
                needed |= set(step.fetches)
        needed_after.reverse()

        available = set(self.graph.inputs)
        for step, after in zip(groups, needed_after):
            if not isinstance(step, _Segment):
                missing = set(step.fetches) - available
                if missing:
                    raise ValueError(
                        f"host stage {step.name} fetches {sorted(missing)} "
                        "which no earlier stage produces"
                    )
                continue
            written: set[str] = set()
            ins: list[str] = []
            for st in step.stages:
                for k in st.reads:
                    if k not in written:
                        if k not in available:
                            raise ValueError(
                                f"stage {st.name} reads {k!r} which no earlier "
                                "stage produces"
                            )
                        ins.append(k)
                written |= set(st.writes)
            step.in_keys = _dedup(ins)
            step.out_keys = _dedup(k for k in written if k in after)
            step.operand_keys = _dedup(k for st in step.stages for k in st.operands)
            step.workspace_keys = _dedup(k for st in step.stages for k in st.workspace)
            step.donate_keys = _dedup(k for st in step.stages for k in st.donates)
            step.static_keys = _dedup(k for st in step.stages for k in st.statics)
            available |= written
        return groups

    def _partition_inverse(self) -> tuple[list[Stage], list[_Segment]]:
        """Compile the decode direction: host prepares + fused inverse runs.

        Host stages become *prepare* steps (container metadata → operands/
        statics, no device fetch), hoisted ahead of all device work; every
        device stage with a declared inverse joins a maximal inverse run,
        walking the graph backwards.  Stages without an inverse contract
        (histograms, scans — encode-only analysis) are identities in the
        decode direction and never cut a run, so with no host barriers left
        the whole decode chain typically fuses into ONE jitted executable —
        the mirror image of the forward direction's segment structure.
        """
        if not self.graph.inv_inputs:
            return [], []
        preps = [st for st in self.graph.stages if not st.device]
        segs: list[_Segment] = []
        for st in reversed(self.graph.stages):
            if not (st.device and st.inv_writes):
                continue
            if segs:
                segs[-1].stages.append(st)
            else:
                segs.append(_Segment(index=0, stages=[st], direction="inv"))
        available = set(self.graph.inv_inputs)
        for seg in segs:
            written: set[str] = set()
            ins: list[str] = []
            for st in seg.stages:
                for k in st.inv_reads:
                    if k not in written:
                        if k not in available:
                            raise ValueError(
                                f"inverse of {st.name} reads {k!r} which "
                                "neither inv_inputs nor an earlier inverse "
                                "stage produces"
                            )
                        ins.append(k)
                written |= set(st.inv_writes)
            seg.in_keys = _dedup(ins)
            seg.out_keys = _dedup(
                k for k in self.graph.inv_finish if k in written
            )
            seg.operand_keys = _dedup(
                k for st in seg.stages for k in st.inv_operands
            )
            seg.workspace_keys = _dedup(
                k for st in seg.stages for k in st.inv_workspace
            )
            seg.donate_keys = _dedup(
                k for st in seg.stages for k in st.inv_donates
            )
            seg.static_keys = _dedup(
                k for st in seg.stages for k in st.inv_statics
            )
            available |= written
        missing = set(self.graph.inv_finish) - available
        if missing:
            raise ValueError(
                f"inverse pipeline never produces {sorted(missing)}"
            )
        return preps, segs

    def _seg_statics(self, seg: _Segment, statics: dict) -> tuple[tuple, dict]:
        sub = {k: statics[k] for k in seg.static_keys}
        for st in seg.stages:
            sub = st.jit_statics(sub)
        return tuple(sorted(sub.items())), sub

    def _raw_fn(self, seg: _Segment, jit_statics: dict, with_ws_out: bool) -> Callable:
        backend = self.plan.spec.backend
        inverse = seg.direction == "inv"

        def fn(state_vals, operand_vals, ws_vals):
            state = dict(zip(seg.in_keys, state_vals))
            env = TraceEnv(
                jit_statics, backend,
                dict(zip(seg.operand_keys, operand_vals)),
                dict(zip(seg.workspace_keys, ws_vals)),
            )
            for st in seg.stages:
                state.update(st.invert(env, state) if inverse
                             else st.apply(env, state))
            outs = tuple(state[k] for k in seg.out_keys)
            if not with_ws_out:
                return outs
            return outs, tuple(env._workspace[k] for k in seg.workspace_keys)

        return fn

    def segment_exe(self, seg: _Segment, statics: dict, batched: bool) -> Callable:
        """Jitted (serial) or vmapped-raw (batched) segment executable.

        Serial executables donate the plan workspace where the platform
        supports it (the PR-2 recycle contract); batched executables return
        ``(outs, workspace)`` with the workspace un-vmapped, leaving the
        broadcast-vs-donate decision to the engine's mesh mapper.
        """
        key_statics, jit_statics = self._seg_statics(seg, statics)
        key = (seg.index, seg.direction, key_statics, batched)
        with self._lock:
            exe = self._exe.get(key)
        if exe is not None:
            return exe
        if batched:
            # Workspace rides along un-vmapped (one copy per shard) and is
            # passed back out, so the engine's mesh mapper can either drop
            # it (broadcast semantics) or donate per-shard stacks and
            # recycle the returned buffers (see ExecutionEngine).
            raw = self._raw_fn(seg, jit_statics, with_ws_out=True)
            exe = jax.vmap(raw, in_axes=(0, 0, None), out_axes=(0, None))
        else:
            raw = self._raw_fn(seg, jit_statics, with_ws_out=True)
            donate = ()
            if seg.donate_keys and seg.donate_keys == seg.workspace_keys:
                donate = (2,)
            exe = adapters.donating_jit(raw, donate_argnums=donate)
        with self._lock:
            exe = self._exe.setdefault(key, exe)
        return exe

    # -- execution: per-leaf -------------------------------------------------

    def run(
        self,
        state0: dict[str, Any],
        env: CallEnv | None = None,
        profile: dict[str, float] | None = None,
        workspace: dict[str, Any] | None = None,
    ) -> tuple[dict[str, Any], CallEnv]:
        """Execute the encode direction for one leaf.

        Device segments run as single fused dispatches; host stages fetch
        exactly their declared keys (counted in ``env.transfers``).  When
        ``profile`` is given, per-stage wall times accumulate into it keyed
        by stage name (device results are blocked on for honest timings).

        ``workspace`` overrides the plan's shared workspace buffers with a
        caller-owned dict — the chunk-pipelined scheduler passes one such
        dict per in-flight slot, so concurrent chunk encodes on one plan
        neither contend on ``plan.lock`` nor donate each other's buffers;
        donated-and-returned buffers are recycled back into the caller's
        dict (the per-slot analogue of ``ReductionPlan.recycle``).
        """
        plan = self.plan
        env = env or CallEnv(plan)
        env.transfers.count_h2d(*state0.values())
        state = {k: jnp.asarray(v) for k, v in state0.items()}
        shipped: set[str] = set()
        for step in self.steps:
            t0 = _clock() if profile is not None else 0.0
            if isinstance(step, _Segment):
                operand_vals = tuple(
                    self._ship(env, k, shipped) for k in step.operand_keys
                )
                exe = self.segment_exe(step, env.statics, batched=False)
                state_vals = tuple(state[k] for k in step.in_keys)
                if step.workspace_keys and workspace is not None:
                    # caller-owned slot workspace: no plan.lock needed —
                    # the slot is exclusively ours for this run
                    ws_vals = tuple(
                        workspace[k] for k in step.workspace_keys
                    )
                    outs, ws_out = exe(state_vals, operand_vals, ws_vals)
                    for k, buf in zip(step.workspace_keys, ws_out):
                        workspace[k] = buf
                elif step.workspace_keys:
                    # Read the workspace inside the lock: a concurrent
                    # donating dispatch invalidates and replaces these
                    # buffers under the same lock, so a reference captured
                    # outside it could be a use-after-donate.
                    with plan.lock:
                        ws_vals = tuple(
                            plan.workspace[k] for k in step.workspace_keys
                        )
                        outs, ws_out = exe(state_vals, operand_vals, ws_vals)
                        for k, buf in zip(step.workspace_keys, ws_out):
                            plan.recycle(k, buf)
                else:
                    outs, _ = exe(state_vals, operand_vals, ())
                state.update(zip(step.out_keys, outs))
                if profile is not None:
                    jax.block_until_ready(outs)
            else:
                fetched = {k: np.asarray(state[k]) for k in step.fetches}
                env.transfers.count_d2h(*fetched.values())
                step.host_apply(env, fetched)
            if profile is not None:
                profile[step.name] = profile.get(step.name, 0.0) + (_clock() - t0)
        return state, env

    def _ship(self, env: CallEnv, name: str, shipped: set[str]) -> jax.Array:
        val = env.operands[name]
        arr = jnp.asarray(val)
        if name not in shipped:
            env.transfers.count_h2d(arr)
            shipped.add(name)
        env.operands[name] = arr
        return arr

    # -- execution: stacked batch (engine shard_map path) --------------------

    def run_batched(
        self,
        state0: dict[str, Any],
        envs: list[CallEnv],
        device_mapper: Callable,
        transfers: TransferStats,
    ) -> dict[str, Any]:
        """Drive a stacked leaf batch through the pipeline.

        ``state0`` holds arrays with a leading leaf axis of ``len(envs)``;
        ``device_mapper(seg, vfn, state_vals, operand_vals, ws_vals)`` is
        supplied by the execution engine and wraps the vmapped segment in
        its mesh ``shard_map``.  Host stages loop over per-leaf fetches —
        metadata scale — and their statics are merged across leaves
        (:meth:`Stage.merge_static`) before the next segment is specialised.
        """
        plan = self.plan
        transfers.count_h2d(*state0.values())
        state = {k: jnp.asarray(v) for k, v in state0.items()}
        merged: dict[str, int] = dict(envs[0].statics)
        stacked_ops: dict[str, jax.Array] = {}
        for step in self.steps:
            if isinstance(step, _Segment):
                for k in step.operand_keys:
                    if k not in stacked_ops:
                        arr = jnp.asarray(_stack_pad(
                            [np.asarray(e.operands[k]) for e in envs]
                        ))
                        transfers.count_h2d(arr)
                        stacked_ops[k] = arr
                operand_vals = tuple(stacked_ops[k] for k in step.operand_keys)
                vfn = self.segment_exe(step, merged, batched=True)
                state_vals = tuple(state[k] for k in step.in_keys)
                if step.workspace_keys:
                    # Dispatch under plan.lock: the serial path *donates*
                    # these buffers under the same lock, so a concurrent
                    # per-leaf encode can neither invalidate the buffer we
                    # captured before our dispatch nor donate it mid-window
                    # (after dispatch XLA holds its own reference).
                    with plan.lock:
                        ws_vals = tuple(
                            plan.workspace[k] for k in step.workspace_keys
                        )
                        outs = device_mapper(
                            step, vfn, state_vals, operand_vals, ws_vals
                        )
                else:
                    outs = device_mapper(step, vfn, state_vals, operand_vals, ())
                state.update(zip(step.out_keys, outs))
            else:
                fetched = {k: np.asarray(state[k]) for k in step.fetches}
                transfers.count_d2h(*fetched.values())
                for i, env in enumerate(envs):
                    step.host_apply(env, {k: fetched[k][i] for k in step.fetches})
                for name in step.static_outputs:
                    merged[name] = step.merge_static(
                        name, [env.statics[name] for env in envs]
                    )
        return state

    @property
    def device_segments(self) -> list[_Segment]:
        return [s for s in self.steps if isinstance(s, _Segment)]

    # -- execution: decode direction ----------------------------------------

    def _pad_state(self, state: dict) -> dict:
        """Round ``inv_pads`` keys up to their bucket on device (a cheap
        concat, no H2D) so nearby stream sizes share one fused trace."""
        for key, mult in self.graph.inv_pads:
            arr = state.get(key)
            if arr is None:
                continue
            pad = (-arr.shape[0]) % mult
            if pad:
                state[key] = jnp.concatenate(
                    [arr, jnp.zeros((pad,) + arr.shape[1:], arr.dtype)]
                )
        return state

    def invert(
        self,
        state0: dict[str, Any],
        env: CallEnv | None = None,
        profile: dict[str, float] | None = None,
    ) -> tuple[dict[str, Any], CallEnv]:
        """Execute the decode direction for one leaf.

        ``state0`` is the container-section state (``graph.inv_inputs``);
        ``env.meta`` must already hold the stream's metadata.  Host stages
        run as *prepare* steps — metadata-only, no device fetch — then the
        fused inverse segments run back-to-back, so H2D is exactly the
        compressed sections plus the prepared operands, and nothing comes
        back D2H until the caller looks at the output.
        """
        if not self.invertible:
            raise NotImplementedError(
                f"codec {self.plan.spec.method!r} has no compiled inverse"
            )
        plan = self.plan
        env = env or CallEnv(plan)
        for st in self.inv_preps:
            t0 = _clock() if profile is not None else 0.0
            st.host_prepare(env)
            if profile is not None:
                profile[st.name] = profile.get(st.name, 0.0) + (_clock() - t0)
        env.transfers.count_h2d(*state0.values())
        state = self._pad_state({k: jnp.asarray(v) for k, v in state0.items()})
        shipped: set[str] = set()
        for seg in self.inv_segments:
            t0 = _clock() if profile is not None else 0.0
            operand_vals = tuple(
                self._ship(env, k, shipped) for k in seg.operand_keys
            )
            exe = self.segment_exe(seg, env.statics, batched=False)
            state_vals = tuple(state[k] for k in seg.in_keys)
            if seg.workspace_keys:
                # workspace read under the lock — see run() for the
                # use-after-donate rationale
                with plan.lock:
                    ws_vals = tuple(
                        plan.workspace[k] for k in seg.workspace_keys
                    )
                    outs, ws_out = exe(state_vals, operand_vals, ws_vals)
                    for k, buf in zip(seg.workspace_keys, ws_out):
                        plan.recycle(k, buf)
            else:
                outs, _ = exe(state_vals, operand_vals, ())
            state.update(zip(seg.out_keys, outs))
            if profile is not None:
                jax.block_until_ready(outs)
                profile[seg.name] = profile.get(seg.name, 0.0) + (_clock() - t0)
        return state, env

    def invert_batched(
        self,
        states: list[dict[str, Any]],
        envs: list[CallEnv],
        device_mapper: Callable,
        transfers: TransferStats,
    ) -> dict[str, Any]:
        """Drive a stacked leaf batch through the decode direction.

        ``states`` holds one container-section state dict per leaf; they are
        stacked here with ``inv_fills`` padding (e.g. out-of-range scatter
        sentinels) and ``inv_pads`` bucketing, so streams of differing sizes
        share one vmapped trace.  Host prepares run per leaf — metadata
        scale — and their statics merge (:meth:`Stage.merge_static`) before
        the fused inverse segments dispatch under the engine's mesh
        ``shard_map``, exactly like the forward ``run_batched`` path.
        """
        plan = self.plan
        for st in self.inv_preps:
            for env in envs:
                st.host_prepare(env)
        merged: dict[str, int] = dict(envs[0].statics)
        for st in self.inv_preps:
            for name in st.inv_static_outputs:
                merged[name] = st.merge_static(
                    name, [env.statics[name] for env in envs]
                )
        fills = dict(self.graph.inv_fills)
        pads = dict(self.graph.inv_pads)
        state: dict[str, Any] = {}
        for key in states[0]:
            arr = _stack_pad(
                [np.asarray(s[key]) for s in states], fill=fills.get(key, 0)
            )
            mult = pads.get(key)
            if mult and (-arr.shape[1]) % mult:
                pad = (-arr.shape[1]) % mult
                arr = np.concatenate(
                    [arr, np.full((arr.shape[0], pad) + arr.shape[2:],
                                  fills.get(key, 0), arr.dtype)], axis=1,
                )
            a = jnp.asarray(arr)
            transfers.count_h2d(a)
            state[key] = a
        stacked_ops: dict[str, jax.Array] = {}
        for seg in self.inv_segments:
            for k in seg.operand_keys:
                if k not in stacked_ops:
                    arr = jnp.asarray(_stack_pad(
                        [np.asarray(e.operands[k]) for e in envs]
                    ))
                    transfers.count_h2d(arr)
                    stacked_ops[k] = arr
            operand_vals = tuple(stacked_ops[k] for k in seg.operand_keys)
            vfn = self.segment_exe(seg, merged, batched=True)
            state_vals = tuple(state[k] for k in seg.in_keys)
            if seg.workspace_keys:
                with plan.lock:
                    ws_vals = tuple(
                        plan.workspace[k] for k in seg.workspace_keys
                    )
                    outs = device_mapper(
                        seg, vfn, state_vals, operand_vals, ws_vals
                    )
            else:
                outs = device_mapper(seg, vfn, state_vals, operand_vals, ())
            state.update(zip(seg.out_keys, outs))
        return state


def _clock() -> float:
    import time

    return time.perf_counter()


def _stack_pad(arrs: list[np.ndarray], fill: int = 0) -> np.ndarray:
    """Stack per-leaf operands, padding axis 0 to the widest leaf.

    Needed when a host stage builds data-dependent tables per leaf (e.g.
    per-leaf codebooks over differing alphabets): zero-length codes are
    never gathered for keys inside a leaf's own alphabet, so zero padding
    is inert by construction.  ``fill`` overrides the pad value for state
    whose neutral element is not zero (e.g. scatter indices, which pad with
    an out-of-range sentinel so the padded rows drop).
    """
    if all(a.shape == arrs[0].shape for a in arrs):
        return np.stack(arrs)
    width = max(a.shape[0] for a in arrs)
    out = np.full((len(arrs), width) + arrs[0].shape[1:], fill, arrs[0].dtype)
    for i, a in enumerate(arrs):
        out[i, : a.shape[0]] = a
    return out


# ---------------------------------------------------------------------------
# container-side fetch view
# ---------------------------------------------------------------------------


class LeafView:
    """One leaf's window onto (possibly stacked) pipeline state.

    The container serialiser pulls arrays through :meth:`fetch`, which
    slices the leaf row (batched runs) and an optional leading-axis prefix
    *on device* before the D2H copy — so a Huffman stream whose exact word
    count is known host-side transfers exactly its compressed bytes, never
    the worst-case buffer.
    """

    def __init__(
        self,
        state: dict[str, Any],
        index: int | None,
        env: CallEnv,
        transfers: TransferStats | None = None,
    ):
        self.state = state
        self.index = index
        self.env = env
        self.transfers = transfers if transfers is not None else env.transfers

    def fetch(self, key: str, length: int | None = None) -> np.ndarray:
        arr = self.state[key]
        if self.index is not None:
            arr = arr[self.index]
        if length is not None:
            arr = arr[:length]
        out = np.asarray(arr)
        self.transfers.count_d2h(out)
        return out
