"""Concrete pipeline stages for the registered HPDR codecs.

Each stage maps one box of the paper's reduction pipelines onto the Stage
protocol (see :mod:`repro.core.stages.base`):

  device stages (fused into jitted segments, adapter-dispatched)
    * :class:`MgardDecorrelate`   multigrid decomposition (§IV-A)
    * :class:`UniformQuantize`    per-level linear quantization + escape keys
                                  + device outlier compaction
    * :class:`IntKeys` / :class:`ByteKeys`  entry normalisation to int32 keys
    * :class:`AlphabetScan`       device max-key reduction (huffman alphabet)
    * :class:`HuffmanHistogram`   DEM-global frequency histogram
    * :class:`HuffmanEntropy`     codebook gather (code, length) per key —
                                  the device-resident entropy stage, lowered
                                  through ``kernels/huffman_encode``
    * :class:`BitPack`            prefix-sum offsets + scatter-free word
                                  packing (+ self-sync chunk offsets)
    * :class:`ZfpBlockTransform`  fixed-rate block transform + bitplane pack

  host stages (the graph's explicit synchronisation points)
    * :class:`AlphabetBind`       reads the device max key → alphabet size
    * :class:`BinSchedule`        value range → error bound + bin schedule
    * :class:`CodebookBuild`      canonical codebook from the device
                                  histogram — the *only* host compute in the
                                  Huffman-family encode path

The entropy tail ``histogram → (host codebook) → entropy → pack`` is shared
verbatim by ``mgard``, ``huffman`` and ``huffman-bytes``; the codecs differ
only in the stages in front of it (see ``core/codecs/*``).
"""

from __future__ import annotations

import math

import jax
import jax.numpy as jnp
import numpy as np

from .. import bitstream as bs
from .. import huffman
from .base import CallEnv, Stage, TraceEnv

_WORD_BUCKET = 1024  # jitted word-buffer granularity (4 KiB) — bounds retraces


# ---------------------------------------------------------------------------
# entry normalisation
# ---------------------------------------------------------------------------


class IntKeys(Stage):
    """Flatten an integer array into the int32 key stream."""

    name = "int_keys"
    reads = ("data",)
    writes = ("keys",)
    inv_reads = ("keys",)
    inv_writes = ("data",)

    def planned(self, plan) -> None:
        self._shape = tuple(plan.spec.shape)
        self._dtype = plan.spec.dtype

    def apply(self, env: TraceEnv, state: dict) -> dict:
        return {"keys": state["data"].reshape(-1).astype(jnp.int32)}

    def invert(self, env: TraceEnv, state: dict) -> dict:
        keys = state["keys"]
        return {"data": keys.reshape(self._shape).astype(jnp.dtype(self._dtype))}


class ByteKeys(IntKeys):
    """Byte view of the input as the key stream (256-key alphabet)."""

    name = "byte_keys"

    def invert(self, env: TraceEnv, state: dict) -> dict:
        # device-side inverse of the host byte view: bitcast the decoded
        # byte stream back to the element dtype (little-endian layouts
        # match numpy's .view on every supported platform)
        dt = np.dtype(self._dtype)
        raw = state["keys"].astype(jnp.uint8)
        if dt.itemsize == 1:
            data = raw.astype(jnp.dtype(self._dtype))
        else:
            data = jax.lax.bitcast_convert_type(
                raw.reshape(-1, dt.itemsize), jnp.dtype(self._dtype)
            )
        return {"data": data.reshape(self._shape)}


class AlphabetScan(Stage):
    """Device max-key reduction — sizes the data-dependent alphabet."""

    name = "alphabet_scan"
    reads = ("keys",)
    writes = ("kmax",)

    def apply(self, env: TraceEnv, state: dict) -> dict:
        return {"kmax": jnp.max(state["keys"]).astype(jnp.int32)}


class AlphabetBind(Stage):
    """Host barrier: bind the histogram width to the observed alphabet.

    The fetch is one int32 per leaf.  In a stacked batch the bound width is
    the max across leaves (`merge_static`); each leaf still records its own
    ``num_keys`` so its codebook (and stream) is identical to a serial
    encode.
    """

    name = "alphabet_bind"
    device = False
    fetches = ("kmax",)
    static_outputs = ("num_bins",)

    def host_apply(self, env: CallEnv, fetched: dict) -> None:
        num_keys = int(fetched["kmax"]) + 1
        env.meta["num_keys"] = num_keys
        env.statics["num_bins"] = num_keys

    def merge_static(self, name: str, values) -> int:
        return max(values)


# ---------------------------------------------------------------------------
# MGARD front end
# ---------------------------------------------------------------------------


class MgardDecorrelate(Stage):
    """Multigrid decomposition (+ the value-range reduction the relative
    error bound needs, so the range sync is one pair of scalars)."""

    name = "mgard_decorrelate"
    reads = ("data",)
    writes = ("coeffs", "vmin", "vmax")
    inv_reads = ("coeffs",)
    inv_writes = ("data",)

    def __init__(self, shape: tuple[int, ...]):
        self.shape = tuple(shape)

    def planned(self, plan) -> None:
        self._dtype = plan.spec.dtype

    def apply(self, env: TraceEnv, state: dict) -> dict:
        from .. import mgard

        data = state["data"]
        return {
            "coeffs": mgard.decompose(data, shape=self.shape),
            "vmin": jnp.min(data),
            "vmax": jnp.max(data),
        }

    def invert(self, env: TraceEnv, state: dict) -> dict:
        from .. import mgard

        out = mgard.recompose(state["coeffs"], shape=self.shape)
        return {"data": out.astype(jnp.dtype(self._dtype))}

    def stage_meta(self, plan) -> dict:
        return {"shape": list(self.shape)}


class BinSchedule(Stage):
    """Host barrier: value range → effective bound + per-level bin sizes."""

    name = "bin_schedule"
    device = False
    fetches = ("vmin", "vmax")

    def __init__(self, eb0: float, relative: bool, L: int):
        self.eb0 = float(eb0)
        self.relative = bool(relative)
        self.L = int(L)

    def host_apply(self, env: CallEnv, fetched: dict) -> None:
        from .. import mgard

        if self.relative:
            eb = self.eb0 * float(fetched["vmax"] - fetched["vmin"])
        else:
            eb = self.eb0
        eb = eb if eb > 0 else self.eb0
        bins = mgard.level_bins(eb, self.L)
        env.meta["error_bound"] = float(eb)
        env.meta["bins"] = bins
        env.operands["bins"] = np.asarray(bins, np.float32)

    def host_prepare(self, env: CallEnv) -> None:
        # decode direction: the bin schedule was recorded in the container —
        # ship it back as the dequantize operand, no device sync needed
        env.operands["bins"] = np.asarray(env.meta["bins"], np.float32)

    def stage_meta(self, plan) -> dict:
        return {"error_bound": self.eb0, "relative": self.relative,
                "levels": self.L + 1}


class UniformQuantize(Stage):
    """Per-level linear quantization, escape keys, device outlier compaction.

    The escape path (paper: outliers stored losslessly) is compacted *on
    device* with an exclusive-scan scatter into a bounded slot buffer, so
    the host only ever fetches ``out_count`` plus the occupied slots — never
    the full quantized grid.  A leaf whose outliers overflow the cap falls
    back to fetching ``q`` (kept device-resident otherwise).
    """

    name = "uniform_quantize"
    reads = ("coeffs",)
    writes = ("q", "keys", "out_count", "out_idx", "out_val")
    operands = ("bins",)
    workspace = ("lmap",)
    donates = ("lmap",)
    inv_reads = ("keys", "out_idx", "out_val")
    inv_writes = ("coeffs",)
    inv_operands = ("bins",)
    inv_workspace = ("lmap",)
    inv_donates = ("lmap",)

    def __init__(self, padded: tuple[int, ...], dict_size: int):
        self.padded = tuple(padded)
        self.dict_size = int(dict_size)
        n = math.prod(self.padded)
        self.out_cap = max(64, n // 16)

    def planned(self, plan) -> None:
        plan.meta["out_cap"] = self.out_cap

    def apply(self, env: TraceEnv, state: dict) -> dict:
        from .. import mgard

        q, keys, inlier = mgard._quantize_stage_impl(
            state["coeffs"], env.workspace("lmap"), env.operand("bins"),
            self.padded, self.dict_size, env.backend,
        )
        out_mask = ~inlier.reshape(-1)
        cap = self.out_cap
        pos = jnp.cumsum(out_mask.astype(jnp.int32)) - out_mask.astype(jnp.int32)
        slot = jnp.where(out_mask, jnp.minimum(pos, cap), cap)
        n = out_mask.shape[0]
        idx = jax.lax.iota(jnp.int32, n)
        out_idx = jnp.zeros(cap + 1, jnp.int32).at[slot].set(idx)[:cap]
        out_val = jnp.zeros(cap + 1, jnp.int32).at[slot].set(q.reshape(-1))[:cap]
        return {
            "q": q,
            "keys": keys,
            "out_count": jnp.sum(out_mask).astype(jnp.int32),
            "out_idx": out_idx,
            "out_val": out_val,
        }

    def invert(self, env: TraceEnv, state: dict) -> dict:
        from ..quantize import signed_to_unsigned, unsigned_to_signed
        from repro.kernels.quantize_map import ops as quantize_ops

        # zig-zag back to signed, restore escaped outliers losslessly (the
        # padded index rows carry an out-of-range sentinel and drop), then
        # dequantize through the same planned kernel the encode side used
        q = unsigned_to_signed(state["keys"].astype(jnp.uint32)).reshape(-1)
        q = q.at[state["out_idx"]].set(
            state["out_val"].astype(jnp.int32), mode="drop"
        )
        q = q.reshape(self.padded)
        coeffs = quantize_ops.dequantize(
            signed_to_unsigned(q), env.workspace("lmap"), env.operand("bins"),
            adapter=env.backend,
        ).reshape(q.shape)
        return {"coeffs": coeffs}

    def stage_meta(self, plan) -> dict:
        return {"padded": list(self.padded), "dict_size": self.dict_size,
                "outlier_cap": self.out_cap}


# ---------------------------------------------------------------------------
# Huffman entropy tail (shared by mgard / huffman / huffman-bytes)
# ---------------------------------------------------------------------------


class HuffmanHistogram(Stage):
    """DEM-global frequency histogram over the key stream."""

    name = "huffman_histogram"
    reads = ("keys",)
    writes = ("freq",)
    statics = ("num_bins",)

    def __init__(self, num_bins: int | None = None):
        self.num_bins = num_bins  # None: bound per call by AlphabetBind

    def planned(self, plan) -> None:
        if self.num_bins is not None:
            plan.meta.setdefault("statics", {})["num_bins"] = int(self.num_bins)

    def apply(self, env: TraceEnv, state: dict) -> dict:
        from repro.kernels.histogram import ops as histogram_ops

        return {
            "freq": histogram_ops.histogram(
                state["keys"], env.static("num_bins"), adapter=env.backend
            )
        }

    def stage_meta(self, plan) -> dict:
        return {"num_bins": self.num_bins}


class CodebookBuild(Stage):
    """Host barrier: canonical two-phase codebook from the device histogram.

    This is the one genuinely sequential, metadata-scale step of Huffman-X
    (paper Fig. 6 — the same histogram→codebook sync point GPU encoders
    have).  It ships the (code, length) tables back as device operands,
    records the serialised ``length_table``, and derives the exact packed
    size host-side from ``freq · lengths`` — so no device sync is needed to
    size the output buffer.
    """

    name = "codebook_build"
    device = False
    fetches = ("freq",)
    static_outputs = ("num_words",)
    inv_static_outputs = ("chunk_size", "n_symbols")

    def __init__(self, chunk_size: int = huffman.DEFAULT_CHUNK):
        self.chunk_size = int(chunk_size)

    def host_apply(self, env: CallEnv, fetched: dict) -> None:
        freq = np.asarray(fetched["freq"])
        num_keys = int(env.meta.get("num_keys", freq.shape[0]))
        freq = freq[:num_keys]
        book = huffman.build_codebook(freq)
        total_bits = int(
            np.sum(freq.astype(np.int64) * book.lengths.astype(np.int64))
        )
        env.meta.setdefault("num_keys", num_keys)
        env.meta["total_bits"] = total_bits
        env.meta["length_table"] = np.asarray(book.lengths, np.int32)
        env.meta["chunk_size"] = self.chunk_size
        env.statics["num_words"] = max(1, bs.words_needed(total_bits))
        env.operands["codes_t"] = np.asarray(book.codes, np.uint32)
        env.operands["lens_t"] = np.asarray(book.lengths, np.int32)

    def host_prepare(self, env: CallEnv) -> None:
        """Decode direction: canonical decode tables from the serialised
        length table — the plan-cached derivation (`plan_decode_tables`),
        so repeated decodes of same-codebook streams reuse one table set.
        The tables are metadata-scale operands; nothing is fetched from the
        device, which is what keeps the whole inverse pipeline fused."""
        tables = huffman.plan_decode_tables(
            env.plan, np.asarray(env.meta["length_table"], np.int32)
        )
        fc = np.asarray(tables.first_code, np.uint32)
        ct = np.asarray(tables.count, np.int32)
        so = np.asarray(tables.sym_offset, np.int32)
        ss = np.asarray(tables.sym_sorted, np.int32)
        if tables.max_len == 0:  # degenerate empty alphabet: keep width ≥ 2
            fc, ct, so = (np.pad(a, (0, 1)) for a in (fc, ct, so))
        if ss.size == 0:
            ss = np.zeros(1, np.int32)
        env.operands["first_code"] = fc
        env.operands["count"] = ct
        env.operands["sym_offset"] = so
        env.operands["sym_sorted"] = ss
        env.statics["chunk_size"] = int(env.meta["chunk_size"])
        env.statics["n_symbols"] = int(env.meta["n_symbols"])

    def merge_static(self, name: str, values) -> int:
        # chunk_size is decode *geometry*: a stream packed with 1 KiB
        # chunks decodes garbage under a 4 KiB grid, so it must agree
        # across a stacked batch (the engine groups decode buckets by
        # chunk geometry — see Codec.decode_bucket_key — and the strict
        # base merge is the backstop).  n_symbols may safely pad to the
        # widest leaf: each chunk's decoded tail past its own symbol
        # count is sliced off per leaf.
        if name == "chunk_size":
            return super().merge_static(name, values)
        return max(values)

    def stage_meta(self, plan) -> dict:
        return {"chunk_size": self.chunk_size, "canonical": True}


class HuffmanEntropy(Stage):
    """Device-resident entropy encoding: per-key (code, length) gather.

    Lowered through the ``huffman_encode`` kernel registry — the codebook
    tables live in VMEM under the Pallas adapters — so MGARD/Huffman encode
    never stages key streams through the host.
    """

    name = "huffman_entropy"
    reads = ("keys",)
    writes = ("codes", "lens")
    operands = ("codes_t", "lens_t")
    inv_reads = ("words", "chunk_offsets")
    inv_writes = ("keys",)
    inv_operands = ("first_code", "count", "sym_offset", "sym_sorted")
    inv_statics = ("chunk_size", "n_symbols")

    def apply(self, env: TraceEnv, state: dict) -> dict:
        from repro.kernels.huffman_encode import ops as encode_ops

        codes, lens = encode_ops.encode_lookup(
            state["keys"].reshape(-1).astype(jnp.int32),
            env.operand("codes_t"),
            env.operand("lens_t"),
            adapter=env.backend,
        )
        return {"codes": codes, "lens": lens}

    def invert(self, env: TraceEnv, state: dict) -> dict:
        # The packed stream is self-synchronising per chunk: all chunks
        # decode in parallel through the huffman_decode kernel registry
        # (the decode mirror of the encode_lookup gather above).  max_len
        # comes from the staged table width, so a stacked batch padded to
        # its widest codebook specialises one shared trace.
        from repro.kernels.huffman_decode import ops as decode_ops

        first_code = env.operand("first_code")
        syms = decode_ops.decode_chunks(
            state["words"],
            state["chunk_offsets"],
            first_code,
            env.operand("count"),
            env.operand("sym_offset"),
            env.operand("sym_sorted"),
            env.static("chunk_size"),
            max(int(first_code.shape[0]) - 1, 1),
            adapter=env.backend,
        )
        return {"keys": syms.reshape(-1)[: env.static("n_symbols")]}


class BitPack(Stage):
    """Prefix-sum offsets + scatter-free word packing (DEM global stage).

    Runs on device via the ``huffman_encode`` kernel registry's
    ``pack_stream`` op.  The jitted word-buffer size buckets to 4 KiB
    multiples (:meth:`jit_statics`) so nearby stream sizes share one trace;
    the container serialiser slices to the exact word count on device
    before the D2H copy.
    """

    name = "bit_pack"
    reads = ("codes", "lens")
    writes = ("words", "chunk_offsets", "total_bits")
    statics = ("num_words",)

    def __init__(self, chunk_size: int = huffman.DEFAULT_CHUNK):
        self.chunk_size = int(chunk_size)

    def jit_statics(self, statics: dict) -> dict:
        w = int(statics["num_words"])
        out = dict(statics)
        out["num_words"] = max(_WORD_BUCKET, -(-w // _WORD_BUCKET) * _WORD_BUCKET)
        return out

    def apply(self, env: TraceEnv, state: dict) -> dict:
        from repro.kernels.huffman_encode import ops as encode_ops

        codes, lens = state["codes"], state["lens"]
        num_words = env.static("num_words")
        if lens.shape[0] == 0:
            return {
                "words": jnp.zeros(num_words, jnp.uint32),
                "chunk_offsets": jnp.zeros(0, jnp.int32),
                "total_bits": jnp.int32(0),
            }
        words, chunk_offsets, total_bits = encode_ops.pack_stream(
            codes, lens, num_words, self.chunk_size, adapter=env.backend
        )
        return {
            "words": words, "chunk_offsets": chunk_offsets,
            "total_bits": total_bits,
        }

    # Variable-length codes cannot be unpacked independently of the
    # codebook, so BitPack declares no inverse of its own: the decode
    # direction is fused into HuffmanEntropy.invert (self-synchronising
    # chunked scan over the packed words), and the inverse compiler treats
    # this stage as an identity.

    def stage_meta(self, plan) -> dict:
        return {"chunk_size": self.chunk_size, "word_bits": bs.WORD_BITS}


# ---------------------------------------------------------------------------
# ZFP
# ---------------------------------------------------------------------------


class ZfpBlockTransform(Stage):
    """Fixed-rate block transform + bitplane packing (paper §IV-C).

    One stage because ZFP's whole chain is shape/rate-static — it compiles
    to a single fused executable with no host barrier at all.
    """

    name = "zfp_block_transform"
    reads = ("data",)
    writes = ("payload", "emax")
    inv_reads = ("payload", "emax")
    inv_writes = ("data",)

    def __init__(self, rate: int, dims: int, shape: tuple[int, ...]):
        self.rate = int(rate)
        self.dims = int(dims)
        self.shape = tuple(shape)

    def planned(self, plan) -> None:
        self._dtype = plan.spec.dtype

    def apply(self, env: TraceEnv, state: dict) -> dict:
        from .. import zfp

        payload, emax = zfp.compress_jit(
            state["data"], rate=self.rate, dims=self.dims, shape=self.shape,
            adapter=env.backend,
        )
        return {"payload": payload, "emax": emax}

    def invert(self, env: TraceEnv, state: dict) -> dict:
        from .. import zfp

        out = zfp.decompress_jit(
            state["payload"], state["emax"], rate=self.rate,
            dims=self.dims, shape=self.shape, adapter=env.backend,
        )
        return {"data": out.astype(jnp.dtype(self._dtype))}

    def stage_meta(self, plan) -> dict:
        return {"rate": self.rate, "dims": self.dims}
