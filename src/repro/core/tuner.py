"""Chunk/window auto-tuner — solve the HPDR §V-C schedule instead of guessing.

Combines the persisted machine calibration (``runtime/calibrate.py``) with
the lane-accurate stream simulator (``runtime/roofline.simulate_stream``,
built on ``core/pipeline.TimelineSimulator``) to pick the ``(chunk_size,
window)`` minimizing *predicted* makespan for a stream of ``total_elems``
elements:

  * candidate chunk sizes split the payload into k ∈ {1, 2, 3, 4, 6, 8,
    12, 16, 24, 32} chunks (every candidate is a real ``fixed`` schedule,
    so the winner is exactly reproducible with an explicit
    ``chunk_size=N``);
  * candidate windows come from ``windows`` (default 1–3); single-chunk
    payloads are pinned to ``window=1``, and the measured per-stream
    (``stream_t0``) and per-chunk (``chunk_t0``) fixed costs make
    over-splitting and premature pipelining visibly expensive — the
    `BENCH_pipeline.json` small-payload regression fix: a tiny payload's
    predicted overlap gain goes negative and the final guard degrades it
    to serial;
  * each candidate's makespan is simulated with the calibrated Φ /
    ``AffineCost`` stage costs plus the measured fixed costs and window
    overhead; the final guard re-simulates the winner at ``window=1`` and
    degrades to serial whenever predicted overlap gain is non-positive.

The model ranks; measurements decide.  For a store-backed full-auto
spec the tuner *races* the top-``_EXPLORE_K`` predicted candidates: the
first K real runs of that spec each execute a different candidate (fed
back by ``observe``), after which the plan is pinned to the measured
winner.  A spec run once gets the model's argmin, exactly as before;
a spec run repeatedly converges onto the true best schedule even where
the monotone Φ model mis-ranks (e.g. codecs whose throughput is
non-monotone in chunk size).

Without a calibration (and with measurement disabled or failing) the
tuner falls back to a deterministic heuristic: ~8 chunks, ``window=1``
when ≤ 2 chunks result, else the default window.  Auto-resolved settings
never enter the CMM plan key — a chunk schedule is just row slices, so
``chunk_size="auto"`` resolving to N builds byte-identical specs (and
hits the same cached plans) as an explicit ``chunk_size=N``.
"""

from __future__ import annotations

import threading
from dataclasses import asdict, dataclass

import numpy as np

from . import chunk_model

#: payload-split candidates: number of chunks each chunk-size candidate yields
DEFAULT_SPLITS = (1, 2, 3, 4, 6, 8, 12, 16, 24, 32)
DEFAULT_WINDOWS = (1, 2, 3)

#: at or below this many chunks, pipelining cannot pay its staging
#: overhead (a single chunk has nothing to overlap with); 2-chunk
#: schedules may still race ``window=2`` — the predicted-gain guard and
#: the measured fixed costs decide
SERIAL_CHUNK_FLOOR = 1

_HEURISTIC_SPLITS = 8
_MIN_CHUNK_ELEMS = 1 << 10

#: how many candidates a repeatedly-run spec explores with real
#: measurements before pinning the measured winner, and how many runs
#: each candidate gets (the first run of a fresh chunk spec carries
#: plan compilation; the second is warm — racing on cold walls mis-ranks)
_EXPLORE_K = 5
_EXPLORE_RUNS = 2
#: race exploration is stratified across chunk counts — the best
#: predicted candidate in each stratum races, because Φ extrapolation
#: across chunk size is the model's least-trusted axis (real codec
#: throughput can be non-monotone in chunk size: cache effects,
#: per-chunk table builds).  1 and 2 chunks are separate strata: they
#: are the configs the model most often confuses (whole-payload Φ vs
#: one overlap opportunity)
_RACE_STRATA = ((1, 1), (2, 2), (3, 8), (9, None))

_LOCK = threading.Lock()
#: solved plans keyed by the full stream spec — repeated auto streams of
#: the same payload resolve with a dict lookup, not a candidate sweep
_PLAN_CACHE: dict[tuple, "TunedPlan"] = {}
#: online measured/predicted residual per (method, dtype, total, itemsize)
#: — fed back by ChunkedPipeline after each auto run (see ``observe``)
_RESIDUALS: dict[tuple, float] = {}
#: candidate races per (method, dtype, total, itemsize):
#: {"order": [(chunk_elems, window), ...],
#:  "measured": {(ce, w): best wall}, "count": {(ce, w): runs}}
_RACES: dict[tuple, dict] = {}
#: process-wide count of candidate races started *with exploration runs*
#: (a race seeded from a persisted winner does not count).  The
#: race-persistence acceptance test asserts a warm process stays at 0.
RACES_STARTED = 0
#: residual changes smaller than this keep the cached plan (hysteresis)
_RESIDUAL_DEADBAND = 0.05


def clear_caches() -> None:
    """Drop solved plans, races, and residuals (calibration dir changed)."""
    with _LOCK:
        _PLAN_CACHE.clear()
        _RESIDUALS.clear()
        _RACES.clear()


def _residual_key(method, dtype, total_elems, itemsize) -> tuple:
    return (str(method), str(np.dtype(dtype).name),
            int(total_elems), int(itemsize))


def _persisted_race(
    method, dtype, total_elems, itemsize, backend, cands
) -> dict | None:
    """A pre-converged race seeded from the calibration store, or ``None``.

    A prior process that finished racing this exact spec geometry persisted
    its measured winner next to the calibration; a fresh process starts
    pinned to it — zero exploration runs — while ``observe`` feedback can
    still dethrone it.  A winner outside the current candidate grid (e.g.
    a changed ``c_limit_elems``) is ignored and the spec re-races.
    """
    try:
        from ..runtime import calibrate

        rec = calibrate.get_race_winner(
            method, dtype, total_elems, itemsize, backend
        )
    except Exception:
        return None
    if rec is None:
        return None
    cand = (int(rec["chunk_elems"]), int(rec["window"]))
    wall = float(rec.get("measured_s", 0.0))
    if cand not in cands or wall <= 0:
        return None
    return {
        "order": [cand],
        "measured": {cand: wall},
        "count": {cand: _EXPLORE_RUNS},
        "persisted": True,
    }


def _persist_winner(
    method, dtype, total_elems, itemsize, backend, ce, w, wall
) -> None:
    """Best-effort: record a converged race winner in the calibration store."""
    try:
        from ..runtime import calibrate

        calibrate.record_race_winner(
            method, dtype, total_elems, itemsize, backend,
            chunk_elems=ce, window=w, measured_s=wall,
        )
    except Exception:
        pass


def observe(
    plan: "TunedPlan", total_elems: int, itemsize: int, measured_s: float
) -> None:
    """Feed one measured auto-run wall back into future predictions.

    The calibrated model is fit on synthetic sweep geometry; real payload
    shapes (e.g. MGARD's dimension-dependent multigrid) can deviate.  The
    residual is the *minimum* observed measured/predicted ratio — the
    best-achieved wall, matching best-of-N measurement semantics (a first
    run inflated by plan compilation is superseded by the first warm
    run).  Predictions for the same spec then track reality to within
    run-to-run noise.  Updates inside a ±5% deadband are dropped so
    cached plans survive.
    """
    if plan is None or plan.source != "calibrated" or plan.method is None:
        return
    raw = plan.predicted_raw_s
    if not (raw and measured_s) or raw <= 0 or measured_s <= 0:
        return
    key = _residual_key(plan.method, plan.dtype or "float32",
                        total_elems, itemsize)
    new = float(np.clip(measured_s / raw, 0.1, 10.0))
    with _LOCK:
        invalidate = False
        # race lane: per-candidate best-achieved wall
        race = _RACES.get(key)
        if race is not None:
            cand = (int(plan.chunk_elems), int(plan.window))
            if cand in race["order"]:
                race["count"][cand] = race["count"].get(cand, 0) + 1
                prev = race["measured"].get(cand)
                if prev is None or measured_s < prev:
                    race["measured"][cand] = float(measured_s)
                    invalidate = True
        # residual lane: global measured/predicted scale
        old = _RESIDUALS.get(key)
        if old is not None:
            new = min(new, old)
        if old is None or abs(new / old - 1.0) > _RESIDUAL_DEADBAND:
            _RESIDUALS[key] = new
            invalidate = True
        if invalidate:
            for k in [k for k in _PLAN_CACHE if k[:4] == key]:
                del _PLAN_CACHE[k]


@dataclass(frozen=True)
class TunedPlan:
    """The tuner's decision plus the predictions that justified it."""

    chunk_elems: int
    window: int
    n_chunks: int
    predicted_s: float          # predicted makespan of the chosen schedule
    predicted_serial_s: float   # same chunking at window=1 (the guard rail)
    source: str                 # "calibrated" | "heuristic"
    method: str | None = None
    dtype: str | None = None
    predicted_raw_s: float = 0.0  # before the observed residual (``observe``)

    def to_dict(self) -> dict:
        return asdict(self)


def predict_makespan(
    cal,
    total_bytes: int,
    chunk_bytes: int,
    window: int,
    window_overhead_s: float = 0.0,
) -> tuple[float, int]:
    """Predicted stream makespan for one (chunk, window) candidate.

    ``cal`` is a :class:`~repro.runtime.calibrate.MethodCalibration`.
    Returns ``(seconds, n_chunks)``.
    """
    from ..runtime import roofline

    sizes = chunk_model.fixed_chunk_schedule(int(total_bytes), int(chunk_bytes))
    makespan, _ = roofline.simulate_stream(
        sizes,
        h2d_time=cal.h2d.time_for,
        compute_time=cal.phi.time_for,
        serialize_time=cal.serialize.time_for,
        window=window,
        window_overhead_s=window_overhead_s,
    )
    # fixed per-stream and per-chunk costs, then the calibrated
    # measured/simulated residual: lanes that contend (CPU backends) make
    # the raw pipelined simulation optimistic
    makespan += getattr(cal, "stream_t0", 0.0)
    makespan += getattr(cal, "chunk_t0", 0.0) * len(sizes)
    if window > 1:
        makespan *= getattr(cal, "overlap_scale", 1.0)
    else:
        makespan *= getattr(cal, "serial_scale", 1.0)
    return makespan, len(sizes)


def heuristic_plan(
    total_elems: int,
    itemsize: int,
    *,
    chunk_elems: int | None = None,
    c_limit_elems: int = 1 << 28,
    default_window: int = 2,
    method: str | None = None,
    dtype: str | None = None,
) -> TunedPlan:
    """Calibration-free fallback: ~8 chunks, serial when ≤ 2 result."""
    total_elems = max(1, int(total_elems))
    if chunk_elems is None:
        chunk_elems = -(-total_elems // _HEURISTIC_SPLITS)
        chunk_elems = int(np.clip(chunk_elems, _MIN_CHUNK_ELEMS, c_limit_elems))
    n = len(chunk_model.fixed_chunk_schedule(total_elems, chunk_elems))
    window = 1 if n <= SERIAL_CHUNK_FLOOR else max(1, int(default_window))
    return TunedPlan(
        chunk_elems=int(chunk_elems), window=window, n_chunks=n,
        predicted_s=0.0, predicted_serial_s=0.0, source="heuristic",
        method=method, dtype=dtype,
    )


def plan_stream(
    total_elems: int,
    itemsize: int,
    method: str | None = None,
    dtype: str = "float32",
    backend: str | None = None,
    *,
    chunk_elems: int | None = None,
    windows: tuple = DEFAULT_WINDOWS,
    c_limit_elems: int = 1 << 28,
    default_window: int = 2,
    measure: bool = True,
    params: dict | None = None,
    calibration=None,
    window_overhead_s: float | None = None,
) -> TunedPlan:
    """Solve for the (chunk_elems, window) minimizing predicted makespan.

    ``chunk_elems`` pins the chunk size (auto-window-only mode, e.g. the
    caller chose an explicit chunk); ``calibration`` injects a
    :class:`MethodCalibration` directly (tests / dry-run planning).  When
    no calibration can be obtained the deterministic heuristic decides.
    """
    total_elems = max(1, int(total_elems))
    itemsize = max(1, int(itemsize))
    # solved-plan cache: only for the store-backed path (injected
    # calibrations/overheads are test/dry-run inputs that may vary)
    use_cache = (calibration is None and window_overhead_s is None
                 and method is not None)
    cache_key = None
    if use_cache:
        cache_key = _residual_key(method, dtype, total_elems, itemsize) + (
            backend, chunk_elems, tuple(windows), default_window,
            c_limit_elems,
        )
        with _LOCK:
            cached = _PLAN_CACHE.get(cache_key)
        if cached is not None:
            return cached
    cal = calibration
    ov = window_overhead_s
    if cal is None and method is not None:
        try:
            from ..runtime import calibrate

            cal = calibrate.get_method_calibration(
                method, dtype, backend, measure=measure, params=params
            )
            if ov is None:
                ov = calibrate.window_overhead_s(backend)
        except Exception:
            cal = None
    if cal is None:
        return heuristic_plan(
            total_elems, itemsize, chunk_elems=chunk_elems,
            c_limit_elems=c_limit_elems, default_window=default_window,
            method=method, dtype=dtype,
        )
    ov = float(ov or 0.0)

    total_bytes = total_elems * itemsize
    if chunk_elems is not None:
        cand_elems = [int(np.clip(chunk_elems, 1, c_limit_elems))]
    else:
        cand_elems = sorted(
            {
                int(np.clip(-(-total_elems // k), _MIN_CHUNK_ELEMS,
                            c_limit_elems))
                for k in DEFAULT_SPLITS
            },
            reverse=True,  # fewest chunks first: deterministic tie-breaks
        )

    # rank every (chunk, window) candidate by predicted makespan; ties
    # break toward smaller windows (serial is the safer schedule)
    cands: dict[tuple[int, int], tuple[float, int]] = {}  # (ce,w)->(mk,n)
    for ce in cand_elems:
        cb = ce * itemsize
        n = len(chunk_model.fixed_chunk_schedule(total_bytes, cb))
        ws = (1,) if n <= SERIAL_CHUNK_FLOOR else tuple(
            sorted({max(1, int(w)) for w in windows})
        )
        for w in ws:
            mk, n = predict_makespan(cal, total_bytes, cb, w, ov)
            cands.setdefault((ce, w), (mk, n))
    ranked = sorted(cands, key=lambda c: (cands[c][0], c[1]))
    ce, w = ranked[0]
    mk, n = cands[(ce, w)]
    serial_mk, _ = predict_makespan(cal, total_bytes, ce * itemsize, 1, 0.0)
    if w > 1 and mk >= serial_mk:
        # predicted overlap gain non-positive: degrade to the serial schedule
        w, mk = 1, serial_mk
        n = cands.get((ce, 1), (serial_mk, n))[1]

    def build(ce, w, n, mk, pred, pred_serial):
        return TunedPlan(
            chunk_elems=int(ce), window=int(w), n_chunks=int(n),
            predicted_s=pred, predicted_serial_s=pred_serial,
            source="calibrated", method=method,
            dtype=str(np.dtype(dtype).name), predicted_raw_s=mk,
        )

    if not use_cache:
        return build(ce, w, n, mk, mk, serial_mk)

    rkey = _residual_key(method, dtype, total_elems, itemsize)
    with _LOCK:
        residual = _RESIDUALS.get(rkey, 1.0)

    race = None
    if chunk_elems is None:
        # candidate race: the model winner, the best predicted candidate
        # in each chunk-count stratum, and the winner's serial twin (so
        # "never worse than serial" is measured, not assumed)
        global RACES_STARTED
        with _LOCK:
            race = _RACES.get(rkey)
        persisted = None
        if race is None:
            # store lookup outside the tuner lock (it takes the
            # calibration store's own lock)
            persisted = _persisted_race(
                method, dtype, total_elems, itemsize, backend, cands
            )
        with _LOCK:
            race = _RACES.get(rkey)
            if race is None:
                if persisted is not None:
                    race = persisted
                else:
                    order = [(ce, w)]
                    for lo, hi in _RACE_STRATA:
                        pick = next(
                            (c for c in ranked
                             if lo <= cands[c][1] and (hi is None
                                                       or cands[c][1] <= hi)),
                            None,
                        )
                        if pick is not None and pick not in order:
                            order.append(pick)
                    twin = (ce, 1)
                    if twin in cands and twin not in order:
                        order.append(twin)
                    order = order[:_EXPLORE_K]
                    race = {"order": order, "measured": {}, "count": {}}
                    RACES_STARTED += 1
                _RACES[rkey] = race
            measured = dict(race["measured"])
            counts = dict(race["count"])
        unexplored = [c for c in race["order"]
                      if c in cands and counts.get(c, 0) < _EXPLORE_RUNS]
        if unexplored:
            # explore: run the next untried candidate for real; its wall
            # comes back through ``observe``
            ce, w = unexplored[0]
            mk, n = cands[(ce, w)]
            serial_mk = cands.get((ce, 1), (mk, n))[0]
            return build(ce, w, n, mk, mk * residual, serial_mk * residual)
        if measured:
            # exploit: pin the measured winner; the prediction IS its
            # best-achieved wall (the converged empirical cost model)
            ce, w = min(measured, key=measured.get)
            mk, n = cands.get((ce, w), (mk, n))
            pred = measured[(ce, w)]
            pred_serial = measured.get(
                (ce, 1), cands.get((ce, 1), (mk, n))[0] * residual)
            plan = build(ce, w, n, mk, pred, pred_serial)
            with _LOCK:
                _PLAN_CACHE[cache_key] = plan
            # persist the converged winner so fresh processes start here
            # (idempotent: re-pinning the same winner is a no-op save)
            _persist_winner(
                method, dtype, total_elems, itemsize, backend, ce, w, pred
            )
            return plan

    plan = build(ce, w, n, mk, mk * residual, serial_mk * residual)
    with _LOCK:
        _PLAN_CACHE[cache_key] = plan
    return plan
