"""ZFP-X fixed-rate compression — HPDR §IV-C (Algorithm 3), TPU-native.

Per 4^d block (paper Fig. 7):
  1. exponent alignment: block values → common fixed-point scale 2^(30-emax);
  2. forward near-orthogonal lifting transform along each dimension
     (the exact zfp integer lift — lossy in the lowest ~2 bits by design,
     identical to libzfp's non-reversible path);
  3. two's-complement → negabinary so sign information lives in high bits;
  4. coefficient reordering by total sequency (low frequencies first);
  5. bitplane truncation + serialization: keep the top ``rate`` bitplanes,
     pack them plane-major (transposed) into 32-bit words.

Every stage is blockwise (Locality → GEM); fixed rate means every block's
output has identical size, so serialization needs **no** global coordination
(paper: "this can be done without global coordination") — offsets are affine.

TPU adaptation notes (DESIGN.md §2): GPU zfp packs bits with per-thread shifts
inside a warp; here bitplane packing is a dense ``(plane, coeff)`` bit matrix
reduction (``bits_to_words``), which XLA/Pallas lower to vector ops, and the
hot path has a Pallas kernel in ``repro/kernels/zfp_block``.

Header layout per block: 1 × int32 emax word.  Payload: ceil(rate·4^d/32)
uint32 words per block.  ``rate`` is bits/value, 1..32.
"""

from __future__ import annotations

import math
from dataclasses import dataclass
from functools import partial

import jax
import jax.numpy as jnp
import numpy as np

from . import bitstream as bs
from .abstractions import pad_to_blocks
from .machine import block_view, unblock_view

NBMASK = 0xAAAAAAAA  # Python int → inlined literal (Pallas-safe)
_I32 = jnp.int32
_U32 = jnp.uint32


# ---------------------------------------------------------------------------
# Stage 2: the zfp integer lifting transform (exact libzfp arithmetic)
# ---------------------------------------------------------------------------


def fwd_lift_vec(v: jax.Array) -> jax.Array:
    """Forward lift of 4-vectors along the last axis (int32)."""
    x, y, z, w = v[..., 0], v[..., 1], v[..., 2], v[..., 3]
    x = x + w
    x = x >> 1
    w = w - x
    z = z + y
    z = z >> 1
    y = y - z
    x = x + z
    x = x >> 1
    z = z - x
    w = w + y
    w = w >> 1
    y = y - w
    w = w + (y >> 1)
    y = y - (w >> 1)
    return jnp.stack([x, y, z, w], axis=-1)


def inv_lift_vec(v: jax.Array) -> jax.Array:
    """Inverse lift of 4-vectors along the last axis (int32)."""
    x, y, z, w = v[..., 0], v[..., 1], v[..., 2], v[..., 3]
    y = y + (w >> 1)
    w = w - (y >> 1)
    y = y + w
    w = w << 1
    w = w - y
    z = z + x
    x = x << 1
    x = x - z
    y = y + z
    z = z << 1
    z = z - y
    w = w + x
    x = x << 1
    x = x - w
    return jnp.stack([x, y, z, w], axis=-1)


def fwd_transform(block: jax.Array) -> jax.Array:
    """Apply the forward lift along every dimension of a 4^d block."""
    for axis in range(block.ndim):
        moved = jnp.moveaxis(block, axis, -1)
        moved = fwd_lift_vec(moved)
        block = jnp.moveaxis(moved, -1, axis)
    return block


def inv_transform(block: jax.Array) -> jax.Array:
    for axis in reversed(range(block.ndim)):
        moved = jnp.moveaxis(block, axis, -1)
        moved = inv_lift_vec(moved)
        block = jnp.moveaxis(moved, -1, axis)
    return block


# ---------------------------------------------------------------------------
# Stage 3: negabinary
# ---------------------------------------------------------------------------


def int_to_negabinary(q: jax.Array) -> jax.Array:
    u = q.astype(_I32).view(_U32)
    return (u + np.uint32(NBMASK)) ^ np.uint32(NBMASK)


def negabinary_to_int(u: jax.Array) -> jax.Array:
    return ((u.astype(_U32) ^ np.uint32(NBMASK)) - np.uint32(NBMASK)).view(_I32)


# ---------------------------------------------------------------------------
# Stage 4: sequency (total-order) permutation
# ---------------------------------------------------------------------------


def sequency_permutation(dims: int) -> np.ndarray:
    """Flat indices of a 4^d block ordered by total sequency (i+j+k...).

    libzfp ships hand-tuned tie-break tables; any *fixed* permutation keyed
    by total order preserves the energy-compaction property — ties are broken
    by flat index (documented format deviation, versioned in the header).
    """
    coords = np.stack(
        np.meshgrid(*([np.arange(4)] * dims), indexing="ij"), axis=-1
    ).reshape(-1, dims)
    total = coords.sum(axis=1)
    flat = np.arange(coords.shape[0])
    order = np.lexsort((flat, total))
    return order.astype(np.int32)


# ---------------------------------------------------------------------------
# Stage 1: exponent alignment
# ---------------------------------------------------------------------------


def block_emax(block: jax.Array) -> jax.Array:
    """Max binary exponent e with |x| < 2^e over the block (0 for all-zero)."""
    absmax = jnp.max(jnp.abs(block))
    _, e = jnp.frexp(absmax)  # absmax = m * 2^e, 0.5 <= m < 1
    return jnp.where(absmax > 0, e, _I32(0)).astype(_I32)


def to_fixed_point(block: jax.Array, emax: jax.Array) -> jax.Array:
    """float → int32 at scale 2^(30-emax): |q| < 2^30 (2 headroom bits)."""
    scale = jnp.exp2(30.0 - emax.astype(jnp.float32))
    return jnp.round(block.astype(jnp.float32) * scale).astype(_I32)


def from_fixed_point(q: jax.Array, emax: jax.Array, dtype=jnp.float32) -> jax.Array:
    scale = jnp.exp2(emax.astype(jnp.float32) - 30.0)
    return (q.astype(jnp.float32) * scale).astype(dtype)


# ---------------------------------------------------------------------------
# Stage 5: bitplane truncation + serialization (fixed rate)
# ---------------------------------------------------------------------------


def plane_bits(block_size: int, rate: int) -> int:
    """Total kept bits per block (excluding the emax header word)."""
    return rate * block_size


def words_per_block(block_size: int, rate: int) -> int:
    return bs.words_needed(plane_bits(block_size, rate))


def pack_bitplanes(u: jax.Array, rate: int) -> jax.Array:
    """``u``: (..., block_size) negabinary coeffs → (..., wpb) uint32 words.

    Plane-major (transposed) layout: all block bits of plane 0 (MSB), then
    plane 1, ... — so truncation is a prefix cut, like zfp's embedded stream.
    """
    block_size = u.shape[-1]
    shifts = 31 - jax.lax.iota(_U32, rate)  # MSB-first planes (traced, Pallas-safe)
    bits = (u[..., None, :] >> shifts[:, None]) & np.uint32(1)  # (..., rate, bs)
    flat = bits.reshape(bits.shape[:-2] + (rate * block_size,))
    pad = (-flat.shape[-1]) % 32
    if pad:
        flat = jnp.pad(flat, [(0, 0)] * (flat.ndim - 1) + [(0, pad)])
    grouped = flat.reshape(flat.shape[:-1] + (flat.shape[-1] // 32, 32))
    return bs.bits_to_words(grouped)


def unpack_bitplanes(words: jax.Array, rate: int, block_size: int) -> jax.Array:
    """Inverse of :func:`pack_bitplanes`; dropped planes read as zero."""
    bits = bs.words_to_bits(words)  # (..., wpb, 32)
    flat = bits.reshape(bits.shape[:-2] + (bits.shape[-2] * 32,))
    flat = flat[..., : rate * block_size]
    planes = flat.reshape(flat.shape[:-1] + (rate, block_size))
    shifts = 31 - jax.lax.iota(_U32, rate)
    return jnp.sum(planes.astype(_U32) << shifts[:, None], axis=-2, dtype=_U32)


# ---------------------------------------------------------------------------
# Whole-array fixed-rate compress / decompress (Locality over blocks)
# ---------------------------------------------------------------------------


@dataclass
class ZFPCompressed:
    """Fixed-rate ZFP-X stream: per-block emax headers + bitplane payload."""

    payload: jax.Array           # uint32[n_blocks, words_per_block]
    emax: jax.Array              # int32[n_blocks]
    shape: tuple[int, ...]       # original array shape
    rate: int                    # bits per value
    dtype: str = "float32"
    layout_version: int = 1

    def nbytes(self) -> int:
        return int(self.payload.nbytes + self.emax.nbytes)

    @property
    def dims(self) -> int:
        return len(self.shape)


def _compress_blocks(blocks: jax.Array, rate: int, perm: jax.Array):
    """blocks: (nb, 4, 4, ...) float → (payload, emax).  One GEM stage chain."""
    nb = blocks.shape[0]
    block_size = int(np.prod(blocks.shape[1:]))

    def one(block):
        emax = block_emax(block)
        q = to_fixed_point(block, emax)
        t = fwd_transform(q)
        u = int_to_negabinary(t)
        u = u.reshape(block_size)[perm]
        return pack_bitplanes(u, rate), emax

    payload, emax = jax.vmap(one)(blocks)
    return payload.reshape(nb, -1), emax


def _decompress_blocks(
    payload: jax.Array, emax: jax.Array, rate: int, inv_perm: jax.Array,
    block_shape: tuple[int, ...],
):
    block_size = int(np.prod(block_shape))

    def one(words, e):
        u = unpack_bitplanes(words, rate, block_size)
        u = u[inv_perm].reshape(block_shape)
        t = negabinary_to_int(u)
        q = inv_transform(t)
        return from_fixed_point(q, e)

    return jax.vmap(one)(payload, emax)


@partial(jax.jit, static_argnames=("rate", "dims", "shape", "adapter"))
def compress_jit(
    data: jax.Array, rate: int, dims: int, shape: tuple[int, ...],
    adapter: str | None = None,
):
    """Whole-array fixed-rate compress; ``adapter`` binds the block kernel.

    ``adapter=None`` keeps the historical inline jnp path; a concrete adapter
    routes the block stage through the ``zfp_block`` kernel registry
    (xla | pallas | pallas_interpret) — the dispatch happens at trace time,
    i.e. once per plan.
    """
    block_shape = (4,) * dims
    padded = pad_to_blocks(data.reshape(shape), block_shape)
    blocks, _counts = block_view(padded, block_shape)
    if adapter is None:
        perm = jnp.asarray(sequency_permutation(dims))
        return _compress_blocks(blocks, rate, perm)
    from repro.kernels.zfp_block import ops as zfp_block_ops  # lazy: layer order

    nb = blocks.shape[0]
    return zfp_block_ops.compress_blocks(
        blocks.reshape(nb, -1), rate, dims, adapter=adapter
    )


@partial(jax.jit, static_argnames=("rate", "dims", "shape", "adapter"))
def decompress_jit(
    payload: jax.Array, emax: jax.Array, rate: int, dims: int,
    shape: tuple[int, ...], adapter: str | None = None,
):
    block_shape = (4,) * dims
    if adapter is None:
        perm = sequency_permutation(dims)
        inv_perm = jnp.asarray(np.argsort(perm).astype(np.int32))
        blocks = _decompress_blocks(payload, emax, rate, inv_perm, block_shape)
    else:
        from repro.kernels.zfp_block import ops as zfp_block_ops  # lazy

        flat = zfp_block_ops.decompress_blocks(
            payload, emax, rate, dims, adapter=adapter
        )
        blocks = flat.reshape((flat.shape[0],) + block_shape)
    from .abstractions import padded_shape

    counts = tuple(p // 4 for p in padded_shape(shape, block_shape))
    full = unblock_view(blocks, counts, block_shape)
    return full[tuple(slice(0, d) for d in shape)]


def compress(data: jax.Array, rate: int = 16) -> ZFPCompressed:
    """Fixed-rate compress an N-d float array (N ≤ 4)."""
    if data.ndim > 4:
        raise ValueError("zfp supports 1-4 dimensional data")
    if not 1 <= rate <= 32:
        raise ValueError("rate must be in [1, 32] bits/value")
    payload, emax = compress_jit(data, rate, data.ndim, tuple(data.shape))
    return ZFPCompressed(
        payload=payload, emax=emax, shape=tuple(data.shape), rate=rate,
        dtype=str(data.dtype),
    )


def decompress(z: ZFPCompressed) -> jax.Array:
    out = decompress_jit(z.payload, z.emax, z.rate, z.dims, z.shape)
    return out.astype(jnp.dtype(z.dtype))


def compression_ratio(z: ZFPCompressed) -> float:
    orig = math.prod(z.shape) * jnp.dtype(z.dtype).itemsize
    return orig / z.nbytes()
