from .pipeline import DataConfig, SyntheticLMStream  # noqa: F401
