"""Deterministic, resumable, shardable synthetic LM data pipeline.

Production framing: every batch is a pure function of (seed, step), so
  * restart-from-checkpoint resumes the stream exactly (fault tolerance);
  * each data-parallel host materialises only its shard
    (``jax.make_array_from_callback`` — no host ever holds the global batch);
  * elastic re-scaling changes only the per-host slice, not the stream.

The token distribution is a Zipf-like categorical with a per-sequence drift
so losses move during the e2e examples (pure-uniform tokens give a flat CE).
"""

from __future__ import annotations

from dataclasses import dataclass
from typing import Iterator

import jax
import jax.numpy as jnp
import numpy as np
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P


@dataclass(frozen=True)
class DataConfig:
    vocab: int
    seq_len: int
    global_batch: int
    seed: int = 0


def _batch_tokens(cfg: DataConfig, step: int) -> np.ndarray:
    """(B, S+1) tokens for ``step`` — pure function of (seed, step)."""
    rng = np.random.default_rng(np.random.SeedSequence([cfg.seed, step]))
    b, s = cfg.global_batch, cfg.seq_len
    # Zipf-ish marginal + AR(1)-style repetition gives learnable structure
    ranks = np.arange(1, cfg.vocab + 1)
    probs = 1.0 / ranks**1.1
    probs /= probs.sum()
    base = rng.choice(cfg.vocab, size=(b, s + 1), p=probs)
    repeat = rng.random((b, s + 1)) < 0.3
    shifted = np.roll(base, 1, axis=1)
    tokens = np.where(repeat, shifted, base)
    return tokens.astype(np.int32)


class SyntheticLMStream:
    """Stateless stream facade with checkpointable position."""

    def __init__(self, cfg: DataConfig, mesh: Mesh | None = None):
        self.cfg = cfg
        self.mesh = mesh
        self.step = 0

    def state_dict(self) -> dict:
        return {"step": self.step, "seed": self.cfg.seed}

    def load_state_dict(self, state: dict) -> None:
        assert state["seed"] == self.cfg.seed, "stream seed mismatch on restore"
        self.step = int(state["step"])

    def next_batch(self) -> dict:
        tokens = _batch_tokens(self.cfg, self.step)
        self.step += 1
        batch_np = {
            "tokens": tokens[:, :-1],
            "labels": tokens[:, 1:],
        }
        if self.mesh is None:
            return {k: jnp.asarray(v) for k, v in batch_np.items()}
        dp = tuple(n for n in ("pod", "data") if n in self.mesh.axis_names)
        sharding = NamedSharding(self.mesh, P(dp, None))

        def put(arr: np.ndarray):
            return jax.make_array_from_callback(
                arr.shape, sharding, lambda idx: arr[idx]
            )

        return {k: put(v) for k, v in batch_np.items()}

    def __iter__(self) -> Iterator[dict]:
        while True:
            yield self.next_batch()
