"""Pallas TPU kernels for HPDR's compute hot-spots.

Each kernel package has:
  kernel.py — ``pl.pallas_call`` body + ``BlockSpec`` VMEM tiling (TPU target)
  ops.py    — jit'd wrapper with adapter dispatch (pallas | pallas_interpret | xla)
  ref.py    — pure-jnp oracle used for validation and as the XLA adapter impl

Kernels:
  zfp_block      — ZFP-X per-4^d-block compress/decompress (GEM: block→grid cell)
  histogram      — one-hot × MXU matmul histogram (DEM global stage)
  huffman_encode — VMEM-staged codebook gather (encode stage of Huffman-X)
  quantize_map   — fused per-level quantize + zigzag (Map&Process)
  mgard_lerp     — level-0 interpolation-coefficient stencil (Locality)
  tridiag        — B-vectors-per-group Thomas solver (Iterative)
"""

from . import (  # noqa: F401
    histogram,
    huffman_encode,
    mgard_lerp,
    quantize_map,
    tridiag,
    zfp_block,
)
