"""Histogram kernel — Pallas TPU (DEM global stage of Huffman-X).

GPU histograms use shared-memory atomics [paper ref 43]; TPUs have no
atomics, so the TPU-native formulation is a **one-hot compare + reduce**
over a 2-D grid: grid axis 0 tiles the key stream, grid axis 1 tiles the bin
range (so the per-cell one-hot block ``(KT, BT)`` fits VMEM).  Accumulation
across key tiles uses the sequential-grid read-modify-write pattern — the
TPU analogue of the paper's global-synchronisation stage.
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

DEFAULT_KT = 8192   # keys per grid cell
DEFAULT_BT = 512    # bins per grid cell


def _hist_kernel(keys_ref, out_ref, *, bt):
    i = pl.program_id(0)

    @pl.when(i == 0)
    def _init():
        out_ref[...] = jnp.zeros_like(out_ref)

    keys = keys_ref[...]  # (KT,) int32
    j = pl.program_id(1)
    base = j * bt
    local = keys[:, None] - (base + jax.lax.iota(jnp.int32, bt)[None, :])
    onehot = (local == 0).astype(jnp.int32)  # (KT, BT)
    out_ref[...] += jnp.sum(onehot, axis=0)


@functools.partial(jax.jit, static_argnames=("num_bins", "kt", "bt", "interpret"))
def histogram(
    keys: jax.Array,
    num_bins: int,
    kt: int = DEFAULT_KT,
    bt: int = DEFAULT_BT,
    interpret: bool = True,
) -> jax.Array:
    keys = keys.reshape(-1).astype(jnp.int32)
    n = keys.shape[0]
    n_pad = (-n) % kt
    if n_pad:
        keys = jnp.pad(keys, (0, n_pad), constant_values=-1)  # -1 matches no bin
    bins_pad = (-num_bins) % bt
    nb = num_bins + bins_pad
    out = pl.pallas_call(
        functools.partial(_hist_kernel, bt=bt),
        grid=(keys.shape[0] // kt, nb // bt),
        in_specs=[pl.BlockSpec((kt,), lambda i, j: (i,))],
        out_specs=pl.BlockSpec((bt,), lambda i, j: (j,)),
        out_shape=jax.ShapeDtypeStruct((nb,), jnp.int32),
        interpret=interpret,
    )(keys)
    return out[:num_bins]
