"""Adapter-dispatched entry points for the histogram kernel."""

from __future__ import annotations

import jax

from repro.core import adapters

from . import kernel, ref


@adapters.register("histogram", adapters.XLA)
def _hist_xla(keys, num_bins):
    return ref.histogram(keys, num_bins)


@adapters.register("histogram", adapters.PALLAS)
def _hist_pallas(keys, num_bins):
    return kernel.histogram(keys, num_bins, interpret=False)


@adapters.register("histogram", adapters.PALLAS_INTERPRET)
def _hist_interp(keys, num_bins):
    return kernel.histogram(keys, num_bins, interpret=True)


def histogram(keys: jax.Array, num_bins: int, adapter: str | None = None) -> jax.Array:
    return adapters.dispatch("histogram", adapter)(keys, num_bins)
