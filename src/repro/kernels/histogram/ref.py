"""Pure-jnp oracle for the histogram kernel (XLA adapter implementation)."""

from __future__ import annotations

import jax
import jax.numpy as jnp


def histogram(keys: jax.Array, num_bins: int) -> jax.Array:
    return jnp.bincount(keys.reshape(-1).astype(jnp.int32), length=num_bins).astype(
        jnp.int32
    )
