"""Chunk-parallel canonical-Huffman decode kernel (decode mirror of
``kernels/huffman_encode``): every self-synchronising chunk of the packed
word stream decodes independently from its recorded bit offset."""
