"""Huffman decode kernel — Pallas TPU (chunk-parallel canonical scan).

Per grid cell: one self-synchronising chunk decodes its ``chunk_size``
symbols with a sequential ``fori_loop`` over the packed words staged in
VMEM.  The canonical decode tables (first_code/count/sym_offset/sym_sorted)
are replicated in VMEM exactly like the encode kernel's codebook — every
table probe is an on-chip gather, the same shared-memory placement GPU
Huffman decoders rely on.

VMEM budget: the word stream is the compressed payload (≤ a few MiB for the
per-shard leaves this decodes) and the tables are metadata-scale, so both
stay resident; chunks are independent, so the grid parallelises freely.
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl


def _decode_kernel(
    off_ref, words_ref, fc_ref, ct_ref, so_ref, sym_ref, out_ref,
    *, chunk_size: int, max_len: int,
):
    from repro.core import bitstream as bs

    words = words_ref[...]
    # traced iota, not jnp.arange: Pallas kernels cannot capture host consts
    lens = jax.lax.iota(jnp.int32, max_len) + 1
    fc = fc_ref[...][1:]
    ct = ct_ref[...][1:]
    so = so_ref[...][1:]
    sym_sorted = sym_ref[...]

    def body(i, cursor):
        # bs.read_window is the shared bit-exact window primitive (also
        # used by the jnp reference decoder) — one implementation, so the
        # cross-backend bit-identity invariant cannot drift
        window = bs.read_window(words, cursor)
        cands = bs._safe_shr(jnp.broadcast_to(window, (max_len,)), 32 - lens)
        rel = cands - fc
        valid = (cands >= fc) & (rel < ct.astype(jnp.uint32))
        li = jnp.argmax(valid)
        l = lens[li]
        sym = sym_sorted[so[li] + rel[li].astype(jnp.int32)]
        out_ref[0, i] = sym
        return cursor + l

    jax.lax.fori_loop(0, chunk_size, body, off_ref[0].astype(jnp.int32))


@functools.partial(
    jax.jit, static_argnames=("chunk_size", "max_len", "interpret")
)
def decode_chunks(
    words: jax.Array,
    chunk_offsets: jax.Array,
    first_code: jax.Array,
    count: jax.Array,
    sym_offset: jax.Array,
    sym_sorted: jax.Array,
    chunk_size: int,
    max_len: int,
    interpret: bool = True,
) -> jax.Array:
    n_chunks = chunk_offsets.shape[0]
    w = words.shape[0]
    t = first_code.shape[0]
    s = max(1, sym_sorted.shape[0])
    sym_sorted = sym_sorted.reshape(-1)
    if sym_sorted.shape[0] == 0:  # empty alphabet: keep the gather well-formed
        sym_sorted = jnp.zeros(1, jnp.int32)
    return pl.pallas_call(
        functools.partial(
            _decode_kernel, chunk_size=chunk_size, max_len=max_len
        ),
        grid=(n_chunks,),
        in_specs=[
            pl.BlockSpec((1,), lambda i: (i,)),
            pl.BlockSpec((w,), lambda i: (0,)),  # stream replicated in VMEM
            pl.BlockSpec((t,), lambda i: (0,)),  # canonical tables in VMEM
            pl.BlockSpec((t,), lambda i: (0,)),
            pl.BlockSpec((t,), lambda i: (0,)),
            pl.BlockSpec((s,), lambda i: (0,)),
        ],
        out_specs=pl.BlockSpec((1, chunk_size), lambda i: (i, 0)),
        out_shape=jax.ShapeDtypeStruct((n_chunks, chunk_size), jnp.int32),
        interpret=interpret,
    )(
        chunk_offsets.astype(jnp.int32),
        words.astype(jnp.uint32),
        first_code.astype(jnp.uint32),
        count.astype(jnp.int32),
        sym_offset.astype(jnp.int32),
        sym_sorted.astype(jnp.int32),
    )
