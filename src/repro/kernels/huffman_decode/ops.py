"""Adapter-dispatched entry points for the huffman_decode kernel."""

from __future__ import annotations

import jax

from repro.core import adapters

from . import kernel, ref


@adapters.register("huffman_decode_chunks", adapters.XLA)
def _dec_xla(words, chunk_offsets, first_code, count, sym_offset, sym_sorted,
             chunk_size, max_len):
    return ref.decode_chunks(
        words, chunk_offsets, first_code, count, sym_offset, sym_sorted,
        chunk_size, max_len,
    )


@adapters.register("huffman_decode_chunks", adapters.PALLAS)
def _dec_pallas(words, chunk_offsets, first_code, count, sym_offset,
                sym_sorted, chunk_size, max_len):
    return kernel.decode_chunks(
        words, chunk_offsets, first_code, count, sym_offset, sym_sorted,
        chunk_size, max_len, interpret=False,
    )


@adapters.register("huffman_decode_chunks", adapters.PALLAS_INTERPRET)
def _dec_interp(words, chunk_offsets, first_code, count, sym_offset,
                sym_sorted, chunk_size, max_len):
    return kernel.decode_chunks(
        words, chunk_offsets, first_code, count, sym_offset, sym_sorted,
        chunk_size, max_len, interpret=True,
    )


def decode_chunks(
    words: jax.Array,
    chunk_offsets: jax.Array,
    first_code: jax.Array,
    count: jax.Array,
    sym_offset: jax.Array,
    sym_sorted: jax.Array,
    chunk_size: int,
    max_len: int,
    adapter: str | None = None,
) -> jax.Array:
    """Chunk-parallel canonical-Huffman decode: int32[n_chunks, chunk_size]."""
    return adapters.dispatch("huffman_decode_chunks", adapter)(
        words, chunk_offsets, first_code, count, sym_offset, sym_sorted,
        chunk_size, max_len,
    )
