"""Pure-jnp oracle for the huffman_decode kernel ops.

The packed stream is self-synchronising per fixed-size symbol chunk: the
encoder's ``pack_stream`` records the bit offset of every chunk boundary
(an exclusive prefix sum sampled every ``chunk_size`` symbols), so chunks
decode in parallel — a ``vmap`` over chunk offsets with a sequential
canonical-prefix scan inside.  This is the device mirror of the GPU
decoders the paper compares against, and the exact implementation the
historical host-orchestrated ``huffman.decode`` ran; both directions share
it so the chunk-parallel and legacy paths can never drift apart.
"""

from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.core import bitstream as bs


def decode_chunks(
    words: jax.Array,          # uint32[W] packed stream (MSB-first words)
    chunk_offsets: jax.Array,  # int32[n_chunks] bit offset of each chunk
    first_code: jax.Array,     # uint32[max_len+1] canonical table
    count: jax.Array,          # int32[max_len+1]
    sym_offset: jax.Array,     # int32[max_len+1] index into sym_sorted
    sym_sorted: jax.Array,     # int32[num_used]
    chunk_size: int,
    max_len: int,
) -> jax.Array:
    """Decode every chunk in parallel; returns int32[n_chunks, chunk_size].

    Each chunk runs the canonical-Huffman scan: read a 32-bit MSB-aligned
    window at the cursor, find the shortest length ``l`` whose prefix is a
    valid code (``first_code[l] <= window >> (32-l) < first_code[l] +
    count[l]``), emit the symbol, advance the cursor by ``l``.  Reads past
    ``total_bits`` return zero bits (see :func:`bs.read_window`); symbols
    decoded there are padding the caller slices off.
    """
    lens = jnp.arange(1, max_len + 1, dtype=jnp.int32)
    fc = first_code[1:]
    ct = count[1:]
    so = sym_offset[1:]

    def step(cursor, _):
        window = bs.read_window(words, cursor)
        cands = bs._safe_shr(jnp.broadcast_to(window, (max_len,)), 32 - lens)
        rel = cands - fc  # uint32; wraps when cands < fc, guarded below
        valid = (cands >= fc) & (rel < ct.astype(jnp.uint32))
        li = jnp.argmax(valid)  # first (shortest) valid length index
        l = lens[li]
        sym = sym_sorted[so[li] + rel[li].astype(jnp.int32)]
        return cursor + l, sym

    def chunk(off):
        _, syms = jax.lax.scan(step, off, None, length=chunk_size)
        return syms

    return jax.vmap(chunk)(chunk_offsets.astype(jnp.int32))
