"""Huffman encode kernel — Pallas TPU (Locality stage of Huffman-X).

Per grid cell: a tile of keys is encoded by gathering (code, length) from the
canonical codebook staged in VMEM — the exact analogue of the GPU kernel's
shared-memory codebook.  The downstream global compaction (exclusive scan +
segment-OR) stays a DEM/XLA stage because it needs the global prefix.

VMEM budget: a 2^16-key codebook is 2 × 256 KiB — comfortably resident, so
every gather hits VMEM (on GPU this is the difference between L2 and shared
memory; the paper's Fig. 12 Huffman numbers depend on it).
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

DEFAULT_T = 16384  # keys per grid cell


def _encode_kernel(keys_ref, codes_t_ref, lens_t_ref, codes_ref, lens_ref):
    keys = keys_ref[...]
    codes_ref[...] = jnp.take(codes_t_ref[...], keys, axis=0)
    lens_ref[...] = jnp.take(lens_t_ref[...], keys, axis=0)


@functools.partial(jax.jit, static_argnames=("t", "interpret"))
def encode_lookup(
    keys: jax.Array,       # (N,) int32 in [0, K)
    codes_table: jax.Array,  # (K,) uint32
    lens_table: jax.Array,   # (K,) int32
    t: int = DEFAULT_T,
    interpret: bool = True,
) -> tuple[jax.Array, jax.Array]:
    keys = keys.reshape(-1).astype(jnp.int32)
    n = keys.shape[0]
    n_pad = (-n) % t
    if n_pad:
        keys = jnp.pad(keys, (0, n_pad))
    k = codes_table.shape[0]
    codes, lens = pl.pallas_call(
        _encode_kernel,
        grid=(keys.shape[0] // t,),
        in_specs=[
            pl.BlockSpec((t,), lambda i: (i,)),
            pl.BlockSpec((k,), lambda i: (0,)),  # codebook replicated in VMEM
            pl.BlockSpec((k,), lambda i: (0,)),
        ],
        out_specs=(
            pl.BlockSpec((t,), lambda i: (i,)),
            pl.BlockSpec((t,), lambda i: (i,)),
        ),
        out_shape=(
            jax.ShapeDtypeStruct((keys.shape[0],), jnp.uint32),
            jax.ShapeDtypeStruct((keys.shape[0],), jnp.int32),
        ),
        interpret=interpret,
    )(keys, codes_table.astype(jnp.uint32), lens_table.astype(jnp.int32))
    return codes[:n], lens[:n]
