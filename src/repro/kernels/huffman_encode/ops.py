"""Adapter-dispatched entry points for the huffman_encode kernel."""

from __future__ import annotations

import jax

from repro.core import adapters

from . import kernel, ref


@adapters.register("huffman_encode_lookup", adapters.XLA)
def _enc_xla(keys, codes_table, lens_table):
    return ref.encode_lookup(keys, codes_table, lens_table)


@adapters.register("huffman_encode_lookup", adapters.PALLAS)
def _enc_pallas(keys, codes_table, lens_table):
    return kernel.encode_lookup(keys, codes_table, lens_table, interpret=False)


@adapters.register("huffman_encode_lookup", adapters.PALLAS_INTERPRET)
def _enc_interp(keys, codes_table, lens_table):
    return kernel.encode_lookup(keys, codes_table, lens_table, interpret=True)


def encode_lookup(
    keys: jax.Array,
    codes_table: jax.Array,
    lens_table: jax.Array,
    adapter: str | None = None,
):
    return adapters.dispatch("huffman_encode_lookup", adapter)(
        keys, codes_table, lens_table
    )


# The serialization tail of the device-resident entropy stage: exclusive
# prefix sum of code lengths + disjoint-bit segment-sum packing.  One
# portable implementation (registered under the XLA adapter) serves every
# backend through the registry's fallback rule — the scan/segment-sum
# lowering is already the TPU-native formulation (see core/bitstream.py),
# so no hand-tiled kernel is needed for this op.


@adapters.register("huffman_pack_stream", adapters.XLA)
def _pack_xla(codes, lens, num_words, chunk_size):
    return ref.pack_stream(codes, lens, num_words, chunk_size)


def pack_stream(
    codes: jax.Array,
    lens: jax.Array,
    num_words: int,
    chunk_size: int,
    adapter: str | None = None,
):
    """Device bit-packing of (code, length) pairs into the word stream."""
    return adapters.dispatch("huffman_pack_stream", adapter)(
        codes, lens, num_words, chunk_size
    )
