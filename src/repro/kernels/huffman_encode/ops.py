"""Adapter-dispatched entry points for the huffman_encode kernel."""

from __future__ import annotations

import jax

from repro.core import adapters

from . import kernel, ref


@adapters.register("huffman_encode_lookup", adapters.XLA)
def _enc_xla(keys, codes_table, lens_table):
    return ref.encode_lookup(keys, codes_table, lens_table)


@adapters.register("huffman_encode_lookup", adapters.PALLAS)
def _enc_pallas(keys, codes_table, lens_table):
    return kernel.encode_lookup(keys, codes_table, lens_table, interpret=False)


@adapters.register("huffman_encode_lookup", adapters.PALLAS_INTERPRET)
def _enc_interp(keys, codes_table, lens_table):
    return kernel.encode_lookup(keys, codes_table, lens_table, interpret=True)


def encode_lookup(
    keys: jax.Array,
    codes_table: jax.Array,
    lens_table: jax.Array,
    adapter: str | None = None,
):
    return adapters.dispatch("huffman_encode_lookup", adapter)(
        keys, codes_table, lens_table
    )
