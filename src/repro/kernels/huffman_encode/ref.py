"""Pure-jnp oracle for the huffman_encode kernel."""

from __future__ import annotations

import jax
import jax.numpy as jnp


def encode_lookup(
    keys: jax.Array, codes_table: jax.Array, lens_table: jax.Array
) -> tuple[jax.Array, jax.Array]:
    keys = keys.reshape(-1).astype(jnp.int32)
    return (
        codes_table.astype(jnp.uint32)[keys],
        lens_table.astype(jnp.int32)[keys],
    )
