"""Pure-jnp oracle for the huffman_encode kernel ops."""

from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.core import bitstream as bs


def encode_lookup(
    keys: jax.Array, codes_table: jax.Array, lens_table: jax.Array
) -> tuple[jax.Array, jax.Array]:
    keys = keys.reshape(-1).astype(jnp.int32)
    return (
        codes_table.astype(jnp.uint32)[keys],
        lens_table.astype(jnp.int32)[keys],
    )


def pack_stream(
    codes: jax.Array, lens: jax.Array, num_words: int, chunk_size: int
) -> tuple[jax.Array, jax.Array, jax.Array]:
    """Prefix-sum offset pass + scatter-free word packing (DEM stage).

    Returns ``(words[num_words] uint32, chunk_offsets int32, total_bits
    int32)``.  ``num_words`` is a static upper bound; words past
    ``total_bits`` are zero, so a caller holding the exact bit count can
    slice the stream without re-packing.
    """
    lens = lens.astype(jnp.int32)
    offsets = bs.exclusive_cumsum(lens)
    total_bits = (offsets[-1] + lens[-1]).astype(jnp.int32)
    words = bs.pack_bits(codes, lens, total_bits, num_words)
    chunk_offsets = offsets[::chunk_size].astype(jnp.int32)
    return words, chunk_offsets, total_bits
