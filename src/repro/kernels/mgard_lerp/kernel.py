"""MGARD lerp kernel — Pallas TPU (Locality stage, paper Alg. 1 line 6).

Computes 1-D interpolation coefficients mc_i = u_{2i+1} − ½(u_{2i} + u_{2i+2})
for a batch of vectors: each grid cell stages ``R`` full rows in VMEM and
evaluates the stencil with strided slices — no halo exchange needed because
the full solve axis is resident (MGARD grids after padding are ≤ 2^k+1 ≈ 4 K
elements: a (R=8, 4097) f32 tile is 128 KiB).

The multi-dimensional / multi-level coefficient computation in ``core.mgard``
composes this axis kernel, exactly as MGARD-GPU composes its 1-D passes.
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

DEFAULT_R = 8  # rows per grid cell


def _lerp_kernel(u_ref, mc_ref):
    u = u_ref[...]  # (R, n) with n = 2m+1
    mc_ref[...] = u[:, 1::2] - 0.5 * (u[:, 0:-2:2] + u[:, 2::2])


@functools.partial(jax.jit, static_argnames=("r", "interpret"))
def lerp_coefficients(
    rows: jax.Array,  # (B, n) float32, n odd
    r: int = DEFAULT_R,
    interpret: bool = True,
) -> jax.Array:
    b, n = rows.shape
    assert n % 2 == 1 and n >= 3, "solve axis must be odd-sized (2m+1)"
    m = (n - 1) // 2
    b_pad = (-b) % r
    if b_pad:
        rows = jnp.pad(rows, ((0, b_pad), (0, 0)))
    out = pl.pallas_call(
        _lerp_kernel,
        grid=(rows.shape[0] // r,),
        in_specs=[pl.BlockSpec((r, n), lambda i: (i, 0))],
        out_specs=pl.BlockSpec((r, m), lambda i: (i, 0)),
        out_shape=jax.ShapeDtypeStruct((rows.shape[0], m), jnp.float32),
        interpret=interpret,
    )(rows.astype(jnp.float32))
    return out[:b]
