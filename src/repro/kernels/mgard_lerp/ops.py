"""Adapter-dispatched entry points for the mgard_lerp kernel."""

from __future__ import annotations

import jax

from repro.core import adapters

from . import kernel, ref


@adapters.register("mgard_lerp", adapters.XLA)
def _lerp_xla(rows):
    return ref.lerp_coefficients(rows)


@adapters.register("mgard_lerp", adapters.PALLAS)
def _lerp_pallas(rows):
    return kernel.lerp_coefficients(rows, interpret=False)


@adapters.register("mgard_lerp", adapters.PALLAS_INTERPRET)
def _lerp_interp(rows):
    return kernel.lerp_coefficients(rows, interpret=True)


def lerp_coefficients(rows: jax.Array, adapter: str | None = None) -> jax.Array:
    return adapters.dispatch("mgard_lerp", adapter)(rows)
