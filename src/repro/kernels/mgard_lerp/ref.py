"""Pure-jnp oracle for the mgard_lerp kernel."""

from __future__ import annotations

import jax


def lerp_coefficients(rows: jax.Array) -> jax.Array:
    u = rows
    return u[:, 1::2] - 0.5 * (u[:, 0:-2:2] + u[:, 2::2])
