"""Quantize kernel — Pallas TPU (Map&Process stage of MGARD-X).

Fuses per-level bin gather + uniform quantization + zig-zag in one pass over
the coefficient array: each grid cell stages a tile of coefficients and the
(tiny) per-level bin table in VMEM.  The inverse kernel fuses the matching
dequantize.  This is the masked-dense / param-gather lowering of the paper's
Map&Process abstraction (Fig. 3c).
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
import numpy as np
from jax.experimental import pallas as pl

DEFAULT_T = 65536


def _quant_kernel(x_ref, lvl_ref, bins_ref, q_ref):
    x = x_ref[...]
    bins = jnp.take(bins_ref[...], lvl_ref[...], axis=0)
    q = jnp.round(x / bins).astype(jnp.int32)
    q_ref[...] = ((q << 1) ^ (q >> 31)).view(jnp.uint32)  # zig-zag


def _dequant_kernel(u_ref, lvl_ref, bins_ref, x_ref):
    u = u_ref[...].astype(jnp.uint32)
    q = ((u >> 1).astype(jnp.int32)) ^ -(u & np.uint32(1)).astype(jnp.int32)
    bins = jnp.take(bins_ref[...], lvl_ref[...], axis=0)
    x_ref[...] = q.astype(jnp.float32) * bins


@functools.partial(jax.jit, static_argnames=("t", "interpret"))
def quantize(
    x: jax.Array,        # (N,) float32 coefficients
    levels: jax.Array,   # (N,) int32 subset ids
    bins: jax.Array,     # (L+1,) float32
    t: int = DEFAULT_T,
    interpret: bool = True,
) -> jax.Array:
    x = x.reshape(-1).astype(jnp.float32)
    levels = levels.reshape(-1).astype(jnp.int32)
    n = x.shape[0]
    n_pad = (-n) % t
    if n_pad:
        x = jnp.pad(x, (0, n_pad))
        levels = jnp.pad(levels, (0, n_pad))
    nl = bins.shape[0]
    out = pl.pallas_call(
        _quant_kernel,
        grid=(x.shape[0] // t,),
        in_specs=[
            pl.BlockSpec((t,), lambda i: (i,)),
            pl.BlockSpec((t,), lambda i: (i,)),
            pl.BlockSpec((nl,), lambda i: (0,)),
        ],
        out_specs=pl.BlockSpec((t,), lambda i: (i,)),
        out_shape=jax.ShapeDtypeStruct((x.shape[0],), jnp.uint32),
        interpret=interpret,
    )(x, levels, bins.astype(jnp.float32))
    return out[:n]


@functools.partial(jax.jit, static_argnames=("t", "interpret"))
def dequantize(
    u: jax.Array,
    levels: jax.Array,
    bins: jax.Array,
    t: int = DEFAULT_T,
    interpret: bool = True,
) -> jax.Array:
    u = u.reshape(-1).astype(jnp.uint32)
    levels = levels.reshape(-1).astype(jnp.int32)
    n = u.shape[0]
    n_pad = (-n) % t
    if n_pad:
        u = jnp.pad(u, (0, n_pad))
        levels = jnp.pad(levels, (0, n_pad))
    nl = bins.shape[0]
    out = pl.pallas_call(
        _dequant_kernel,
        grid=(u.shape[0] // t,),
        in_specs=[
            pl.BlockSpec((t,), lambda i: (i,)),
            pl.BlockSpec((t,), lambda i: (i,)),
            pl.BlockSpec((nl,), lambda i: (0,)),
        ],
        out_specs=pl.BlockSpec((t,), lambda i: (i,)),
        out_shape=jax.ShapeDtypeStruct((u.shape[0],), jnp.float32),
        interpret=interpret,
    )(u, levels, bins.astype(jnp.float32))
    return out[:n]
