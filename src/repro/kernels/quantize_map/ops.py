"""Adapter-dispatched entry points for the quantize_map kernel."""

from __future__ import annotations

import jax

from repro.core import adapters

from . import kernel, ref


@adapters.register("quantize_map", adapters.XLA)
def _q_xla(x, levels, bins):
    return ref.quantize(x, levels, bins)


@adapters.register("quantize_map", adapters.PALLAS)
def _q_pallas(x, levels, bins):
    return kernel.quantize(x, levels, bins, interpret=False)


@adapters.register("quantize_map", adapters.PALLAS_INTERPRET)
def _q_interp(x, levels, bins):
    return kernel.quantize(x, levels, bins, interpret=True)


@adapters.register("dequantize_map", adapters.XLA)
def _dq_xla(u, levels, bins):
    return ref.dequantize(u, levels, bins)


@adapters.register("dequantize_map", adapters.PALLAS)
def _dq_pallas(u, levels, bins):
    return kernel.dequantize(u, levels, bins, interpret=False)


@adapters.register("dequantize_map", adapters.PALLAS_INTERPRET)
def _dq_interp(u, levels, bins):
    return kernel.dequantize(u, levels, bins, interpret=True)


def quantize(x, levels, bins, adapter: str | None = None) -> jax.Array:
    return adapters.dispatch("quantize_map", adapter)(x, levels, bins)


def dequantize(u, levels, bins, adapter: str | None = None) -> jax.Array:
    return adapters.dispatch("dequantize_map", adapter)(u, levels, bins)
