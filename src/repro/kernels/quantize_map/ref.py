"""Pure-jnp oracle for the quantize_map kernel (reuses core.quantize)."""

from __future__ import annotations

import jax

from repro.core.quantize import (
    dequantize_by_subset,
    quantize_by_subset,
    signed_to_unsigned,
    unsigned_to_signed,
)


def quantize(x: jax.Array, levels: jax.Array, bins: jax.Array) -> jax.Array:
    q = quantize_by_subset(x.reshape(-1), levels.reshape(-1), bins)
    return signed_to_unsigned(q)


def dequantize(u: jax.Array, levels: jax.Array, bins: jax.Array) -> jax.Array:
    q = unsigned_to_signed(u.reshape(-1))
    return dequantize_by_subset(q, levels.reshape(-1), bins)
