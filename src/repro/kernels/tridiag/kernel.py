"""Tridiagonal mass-solve kernel — Pallas TPU (Iterative abstraction).

Thomas algorithm for M x = b with the 1-D FEM mass matrix, batched over B
vectors per grid cell (the paper's B:1 vector→group mapping, Fig. 3b): a
``(B, n)`` tile plus the precomputed elimination constants (cp, d_inv — the
CMM-cached solver context) live in VMEM; the forward/backward sweeps are
``lax.scan`` over the solve axis with all B lanes advancing together, so the
VPU lane dimension stays full while the recurrence is sequential — the exact
TPU analogue of the paper's iterative execution model.
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

from repro.core.mgard import _thomas_coeffs

DEFAULT_B = 64  # vectors per grid cell


def _tridiag_kernel(rhs_ref, cp_ref, dinv_ref, x_ref, *, sub):
    rhs = rhs_ref[...]          # (B, n)
    cp = cp_ref[...]            # (n,)
    dinv = dinv_ref[...]        # (n,)
    v = rhs.T                   # (n, B): scan over axis 0

    def fwd(carry, inp):
        r, di = inp
        d = (r - sub * carry) * di
        return d, d

    _, dp = jax.lax.scan(fwd, jnp.zeros_like(v[0]), (v, dinv))

    def back(carry, inp):
        d, cpi = inp
        x = d - cpi * carry
        return x, x

    _, xs = jax.lax.scan(back, jnp.zeros_like(v[0]), (dp, cp), reverse=True)
    x_ref[...] = xs.T


@functools.partial(jax.jit, static_argnames=("h", "b", "interpret"))
def solve_mass(
    rhs: jax.Array,  # (N, n) float32 — N independent systems
    h: float,
    b: int = DEFAULT_B,
    interpret: bool = True,
) -> jax.Array:
    nsys, n = rhs.shape
    cp_np, dinv_np = _thomas_coeffs(n, h)
    n_pad = (-nsys) % b
    if n_pad:
        rhs = jnp.pad(rhs, ((0, n_pad), (0, 0)))
    out = pl.pallas_call(
        functools.partial(_tridiag_kernel, sub=h / 6.0),
        grid=(rhs.shape[0] // b,),
        in_specs=[
            pl.BlockSpec((b, n), lambda i: (i, 0)),
            pl.BlockSpec((n,), lambda i: (0,)),
            pl.BlockSpec((n,), lambda i: (0,)),
        ],
        out_specs=pl.BlockSpec((b, n), lambda i: (i, 0)),
        out_shape=jax.ShapeDtypeStruct(rhs.shape, jnp.float32),
        interpret=interpret,
    )(rhs.astype(jnp.float32), jnp.asarray(cp_np, jnp.float32), jnp.asarray(dinv_np, jnp.float32))
    return out[:nsys]
