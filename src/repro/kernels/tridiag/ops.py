"""Adapter-dispatched entry points for the tridiag kernel."""

from __future__ import annotations

import jax

from repro.core import adapters

from . import kernel, ref


@adapters.register("tridiag_solve", adapters.XLA)
def _tri_xla(rhs, h):
    return ref.solve_mass(rhs, h)


@adapters.register("tridiag_solve", adapters.PALLAS)
def _tri_pallas(rhs, h):
    return kernel.solve_mass(rhs, h, interpret=False)


@adapters.register("tridiag_solve", adapters.PALLAS_INTERPRET)
def _tri_interp(rhs, h):
    return kernel.solve_mass(rhs, h, interpret=True)


def solve_mass(rhs: jax.Array, h: float, adapter: str | None = None) -> jax.Array:
    return adapters.dispatch("tridiag_solve", adapter)(rhs, h)
