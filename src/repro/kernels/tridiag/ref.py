"""Pure-jnp oracle for the tridiag kernel (reuses core.mgard's solver)."""

from __future__ import annotations

import jax

from repro.core.mgard import tridiag_solve_1d


def solve_mass(rhs: jax.Array, h: float) -> jax.Array:
    return tridiag_solve_1d(rhs, axis=1, h=h)
