"""ZFP-X block kernel — Pallas TPU implementation (GEM lowering).

One grid cell processes ``TB`` 4^d blocks staged in VMEM (the paper's
block→SM mapping becomes block-batch→grid-cell: TPU grid cells consume whole
tiles, so we batch blocks to fill the 8×128 VPU registers).  All five stages
(exponent align → lift → negabinary → permute → bitplane pack) run fused in
VMEM — the multi-stage GEM execution of Table I/II.

Layout: ``(TB, block_size)`` with TB a multiple of 8 sublanes; the
block-coefficient axis rides the 128-wide lane dimension.  The sequency
permutation is passed as a (replicated) VMEM operand — the same pattern GPU
kernels use for constant tables in shared memory.  Matmul-free: this kernel
is VPU (shift/add) bound, which is why ZFP is the highest-throughput
pipeline on every backend (paper Fig. 12).
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
import numpy as np
from jax.experimental import pallas as pl

from repro.core import zfp as core_zfp

DEFAULT_TB = 256  # blocks per grid cell


def _compress_tile(blocks_f32: jax.Array, perm: jax.Array, rate: int, dims: int):
    """(TB, 4^dims) float32 → ((TB, wpb) uint32, (TB,) int32). Pure jnp on VMEM."""
    tb, bs = blocks_f32.shape
    shaped = blocks_f32.reshape((tb,) + (4,) * dims)
    absmax = jnp.max(jnp.abs(blocks_f32), axis=1)
    _, e = jnp.frexp(absmax)
    emax = jnp.where(absmax > 0, e, 0).astype(jnp.int32)
    scale = jnp.exp2(30.0 - emax.astype(jnp.float32))
    q = jnp.round(shaped * scale.reshape((tb,) + (1,) * dims)).astype(jnp.int32)
    t = q
    for axis in range(1, dims + 1):
        moved = jnp.moveaxis(t, axis, -1)
        moved = core_zfp.fwd_lift_vec(moved)
        t = jnp.moveaxis(moved, -1, axis)
    u = core_zfp.int_to_negabinary(t.reshape(tb, bs))
    u = jnp.take(u, perm, axis=1)
    payload = core_zfp.pack_bitplanes(u, rate)
    return payload, emax


def _decompress_tile(
    payload: jax.Array, emax: jax.Array, inv_perm: jax.Array, rate: int, dims: int
):
    tb = payload.shape[0]
    bs = 4 ** dims
    u = core_zfp.unpack_bitplanes(payload, rate, bs)
    u = jnp.take(u, inv_perm, axis=1)
    t = core_zfp.negabinary_to_int(u).reshape((tb,) + (4,) * dims)
    for axis in range(dims, 0, -1):
        moved = jnp.moveaxis(t, axis, -1)
        moved = core_zfp.inv_lift_vec(moved)
        t = jnp.moveaxis(moved, -1, axis)
    scale = jnp.exp2(emax.astype(jnp.float32) - 30.0)
    return t.reshape(tb, bs).astype(jnp.float32) * scale[:, None]


def _compress_kernel(x_ref, perm_ref, payload_ref, emax_ref, *, rate, dims):
    payload, emax = _compress_tile(x_ref[...], perm_ref[...], rate, dims)
    payload_ref[...] = payload
    emax_ref[...] = emax


def _decompress_kernel(p_ref, e_ref, iperm_ref, out_ref, *, rate, dims):
    out_ref[...] = _decompress_tile(p_ref[...], e_ref[...], iperm_ref[...], rate, dims)


@functools.partial(jax.jit, static_argnames=("rate", "dims", "tb", "interpret"))
def compress_blocks(
    blocks: jax.Array,  # (N, 4^dims) float32
    rate: int,
    dims: int,
    tb: int = DEFAULT_TB,
    interpret: bool = True,
) -> tuple[jax.Array, jax.Array]:
    n, bs = blocks.shape
    assert bs == 4 ** dims
    wpb = core_zfp.words_per_block(bs, rate)
    n_pad = (-n) % tb
    if n_pad:
        blocks = jnp.pad(blocks, ((0, n_pad), (0, 0)))
    n_t = blocks.shape[0]
    perm = jnp.asarray(core_zfp.sequency_permutation(dims))
    payload, emax = pl.pallas_call(
        functools.partial(_compress_kernel, rate=rate, dims=dims),
        grid=(n_t // tb,),
        in_specs=[
            pl.BlockSpec((tb, bs), lambda i: (i, 0)),
            pl.BlockSpec((bs,), lambda i: (0,)),  # replicated table (VMEM-staged)
        ],
        out_specs=(
            pl.BlockSpec((tb, wpb), lambda i: (i, 0)),
            pl.BlockSpec((tb,), lambda i: (i,)),
        ),
        out_shape=(
            jax.ShapeDtypeStruct((n_t, wpb), jnp.uint32),
            jax.ShapeDtypeStruct((n_t,), jnp.int32),
        ),
        interpret=interpret,
    )(blocks, perm)
    return payload[:n], emax[:n]


@functools.partial(jax.jit, static_argnames=("rate", "dims", "tb", "interpret"))
def decompress_blocks(
    payload: jax.Array,  # (N, wpb) uint32
    emax: jax.Array,     # (N,) int32
    rate: int,
    dims: int,
    tb: int = DEFAULT_TB,
    interpret: bool = True,
) -> jax.Array:
    n, wpb = payload.shape
    bs = 4 ** dims
    n_pad = (-n) % tb
    if n_pad:
        payload = jnp.pad(payload, ((0, n_pad), (0, 0)))
        emax = jnp.pad(emax, (0, n_pad))
    n_t = payload.shape[0]
    inv_perm = jnp.asarray(
        np.argsort(core_zfp.sequency_permutation(dims)).astype(np.int32)
    )
    out = pl.pallas_call(
        functools.partial(_decompress_kernel, rate=rate, dims=dims),
        grid=(n_t // tb,),
        in_specs=[
            pl.BlockSpec((tb, wpb), lambda i: (i, 0)),
            pl.BlockSpec((tb,), lambda i: (i,)),
            pl.BlockSpec((bs,), lambda i: (0,)),
        ],
        out_specs=pl.BlockSpec((tb, bs), lambda i: (i, 0)),
        out_shape=jax.ShapeDtypeStruct((n_t, bs), jnp.float32),
        interpret=interpret,
    )(payload, emax, inv_perm)
    return out[:n]
