"""Adapter-dispatched entry points for the zfp_block kernel."""

from __future__ import annotations

import jax

from repro.core import adapters

from . import kernel, ref


@adapters.register("zfp_block_compress", adapters.XLA)
def _compress_xla(blocks, rate, dims):
    return ref.compress_blocks(blocks, rate, dims)


@adapters.register("zfp_block_compress", adapters.PALLAS)
def _compress_pallas(blocks, rate, dims):
    return kernel.compress_blocks(blocks, rate, dims, interpret=False)


@adapters.register("zfp_block_compress", adapters.PALLAS_INTERPRET)
def _compress_interp(blocks, rate, dims):
    return kernel.compress_blocks(blocks, rate, dims, interpret=True)


@adapters.register("zfp_block_decompress", adapters.XLA)
def _decompress_xla(payload, emax, rate, dims):
    return ref.decompress_blocks(payload, emax, rate, dims)


@adapters.register("zfp_block_decompress", adapters.PALLAS)
def _decompress_pallas(payload, emax, rate, dims):
    return kernel.decompress_blocks(payload, emax, rate, dims, interpret=False)


@adapters.register("zfp_block_decompress", adapters.PALLAS_INTERPRET)
def _decompress_interp(payload, emax, rate, dims):
    return kernel.decompress_blocks(payload, emax, rate, dims, interpret=True)


def compress_blocks(blocks: jax.Array, rate: int, dims: int, adapter: str | None = None):
    return adapters.dispatch("zfp_block_compress", adapter)(blocks, rate, dims)


def decompress_blocks(
    payload: jax.Array, emax: jax.Array, rate: int, dims: int, adapter: str | None = None
):
    return adapters.dispatch("zfp_block_decompress", adapter)(payload, emax, rate, dims)
