"""Pure-jnp oracle for the zfp_block kernel (XLA adapter implementation)."""

from __future__ import annotations

import jax
import jax.numpy as jnp
import numpy as np

from repro.core import zfp as core_zfp


def compress_blocks(blocks: jax.Array, rate: int, dims: int):
    """(N, 4^dims) float32 → ((N, wpb) uint32, (N,) int32) — vmapped core path."""
    perm = jnp.asarray(core_zfp.sequency_permutation(dims))
    shaped = blocks.reshape((blocks.shape[0],) + (4,) * dims)
    return core_zfp._compress_blocks(shaped, rate, perm)


def decompress_blocks(payload: jax.Array, emax: jax.Array, rate: int, dims: int):
    inv_perm = jnp.asarray(
        np.argsort(core_zfp.sequency_permutation(dims)).astype(np.int32)
    )
    out = core_zfp._decompress_blocks(payload, emax, rate, inv_perm, (4,) * dims)
    return out.reshape(out.shape[0], -1)
