"""Launchers: mesh construction, multi-pod dry-run, training, input specs.

NB: do not import ``dryrun`` here — it sets XLA_FLAGS at import time and
must only ever be run as a standalone entry point.
"""

from . import mesh, specs  # noqa: F401
