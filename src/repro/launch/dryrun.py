import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=512"

"""Multi-pod dry-run driver (brief: MULTI-POD DRY-RUN).

Lowers + compiles every (architecture × input-shape × mesh) cell against the
production meshes — (16,16) "data","model" single-pod and (2,16,16)
"pod","data","model" multi-pod — on 512 placeholder CPU devices, records
``memory_analysis()`` / ``cost_analysis()`` / HLO collective bytes per cell
into ``results/dryrun/*.json``, which §Roofline and §Perf read.

Usage:
  PYTHONPATH=src python -m repro.launch.dryrun                 # all cells
  PYTHONPATH=src python -m repro.launch.dryrun --arch qwen2.5-3b --shape train_4k
  PYTHONPATH=src python -m repro.launch.dryrun --multi-pod     # only 512-chip mesh
"""

import argparse
import json
import time
import traceback
from pathlib import Path

import jax

from repro.configs import ARCHS, SHAPES, applicable_shapes, get_config
from repro.launch import specs as S
from repro.launch.mesh import make_production_mesh
from repro.models import build_model
from repro.optim import adamw
from repro.runtime import hlo_analysis, roofline
from repro.runtime import sharding as shr

RESULTS = Path(__file__).resolve().parents[3] / "results" / "dryrun"

# §Perf hillclimb levers per (architecture × step kind) — variant "opt".
# Every lever is a config knob so the baseline (paper-faithful naive
# sharding) stays reproducible.  Keys: train / prefill / decode / "*".
_ZERO1 = {"sharding_policy": "dp_zero1", "param_dtype": "bfloat16"}
# inference wants the serving layout: TP-only bf16 params (no FSDP regather),
# scatter-free masked cache writes on the seq-sharded cache
_SERVE = {"fsdp": False, "param_dtype": "bfloat16", "decode_masked_update": True}
OPT_OVERRIDES: dict[str, dict[str, dict]] = {
    # ZeRO-1 for small dense archs: TP activation ARs dominated their baseline
    "qwen2.5-3b": {"train": _ZERO1},
    "minicpm-2b": {"train": _ZERO1},
    "qwen1.5-4b": {"train": _ZERO1},
    "mamba2-370m": {"train": _ZERO1},
    # group-blocked MoE dispatch (GShard groups) kills the (T,E,C) pathology;
    # bf16 params halve the FSDP regather + fit the optimizer in HBM.
    # NOT applied at decode: grouped dispatch on 128-token steps regressed
    # 2.1–2.4× in the sweep (capacity quantisation) — see the §Perf appendix.
    "deepseek-v3-671b": {
        "train": {"moe_group_size": 4096, "param_dtype": "bfloat16", "moe_impl": "a2a"},
        "prefill": {"moe_group_size": 4096, "param_dtype": "bfloat16", "moe_impl": "a2a"},
        # decode: the dense _SERVE layout regressed 2.7× (unsharded expert
        # weights exceed HBM and dominate reads) — MoE serving needs
        # full-mesh EP + token-level a2a, left as documented future work.
    },
    "llama4-scout-17b-a16e": {
        "train": {"moe_group_size": 4096, "param_dtype": "bfloat16"},
        "prefill": {"moe_group_size": 4096, "param_dtype": "bfloat16"},
    },
    # prefill is inference too: the FSDP-regather pathology applies equally
    "deepseek-67b": {"decode": _SERVE, "prefill": _SERVE},
    "qwen2-vl-72b": {"decode": _SERVE, "prefill": _SERVE},
    "recurrentgemma-9b": {},
    "seamless-m4t-medium": {},
}


def opt_overrides_for(arch: str, kind: str) -> dict:
    table = OPT_OVERRIDES.get(arch, {})
    out = dict(table.get("*", {}))
    out.update(table.get(kind, {}))
    return out


def _mem_dict(mem) -> dict:
    if mem is None:
        return {}
    keys = (
        "generated_code_size_in_bytes", "argument_size_in_bytes",
        "output_size_in_bytes", "alias_size_in_bytes", "temp_size_in_bytes",
    )
    return {k: int(getattr(mem, k, 0)) for k in keys}


def run_cell(arch: str, shape_name: str, multi_pod: bool, out_dir: Path,
             force: bool = False, variant: str = "baseline") -> dict:
    mesh_tag = "pod512" if multi_pod else "pod256"
    out_path = out_dir / f"{arch}__{shape_name}__{mesh_tag}__{variant}.json"
    if out_path.exists() and not force:
        return json.loads(out_path.read_text())

    cfg = get_config(arch)
    shape = SHAPES[shape_name]
    if variant == "opt":
        from dataclasses import replace as _replace

        cfg = _replace(cfg, **opt_overrides_for(arch, shape.kind))
    mesh = make_production_mesh(multi_pod=multi_pod)
    model = build_model(cfg)
    record: dict = {
        "arch": arch, "shape": shape_name, "mesh": list(mesh.shape.values()),
        "multi_pod": multi_pod, "variant": variant, "kind": shape.kind,
    }
    t0 = time.time()
    try:
        param_sds = S.param_specs(model, mesh)
        params_shape = jax.eval_shape(lambda: model.init(jax.random.PRNGKey(0)))
        record["param_report"] = shr.sharding_report(params_shape, cfg, mesh)
        counts = roofline.count_params(params_shape)
        record["param_counts"] = counts

        from repro.launch.mesh import use_mesh

        with use_mesh(mesh):  # ambient mesh: activation constraints resolve
            if shape.kind == "train":
                # opt variant for FSDP giants: bf16 moments (memory-roofline lever)
                moment_dtype = "bfloat16" if (variant == "opt" and cfg.fsdp) else "float32"
                opt_cfg = adamw.AdamWConfig(moment_dtype=moment_dtype)
                opt_sds = S.opt_state_specs(param_sds, mesh, opt_cfg, cfg)
                batch_sds = S.batch_specs(cfg, shape, mesh)
                step = S.make_train_step(model, opt_cfg)
                lowered = jax.jit(step, donate_argnums=(0, 1)).lower(
                    param_sds, opt_sds, batch_sds
                )
            elif shape.kind == "prefill":
                batch_sds = S.batch_specs(cfg, shape, mesh)
                step = S.make_prefill_step(model)
                lowered = jax.jit(step).lower(param_sds, batch_sds)
            else:  # decode
                cache_sds = S.cache_specs(model, shape, mesh)
                tok_sds = S.token_specs(cfg, shape, mesh)
                step = S.make_decode_step(model)
                lowered = jax.jit(step, donate_argnums=(2,)).lower(
                    param_sds, tok_sds, cache_sds,
                    jax.ShapeDtypeStruct((), jax.numpy.int32),
                )
            record["lower_s"] = time.time() - t0

            t1 = time.time()
            compiled = lowered.compile()
            record["compile_s"] = time.time() - t1

        mem = compiled.memory_analysis()
        print(mem)   # proves it fits (per-device bytes)
        cost = hlo_analysis.cost_analysis_dict(compiled)
        print({k: cost[k] for k in ("flops", "bytes accessed") if k in cost})
        record["memory"] = _mem_dict(mem)
        record["cost"] = {
            k: float(v)
            for k, v in (cost or {}).items()
            if isinstance(v, (int, float)) and "{" not in k
        }
        hlo = compiled.as_text()
        record["collectives_raw"] = hlo_analysis.parse_collectives(hlo).to_dict()
        coll = hlo_analysis.parse_collectives_scaled(hlo)  # while-body × trips
        record["collectives"] = coll.to_dict()
        record["hlo_bytes"] = len(hlo)

        chips = mesh.size
        mf = roofline.model_flops(cfg, shape, counts)
        record["model_flops"] = mf
        analytic_mem = roofline.analytic_memory_bytes(
            cfg, shape, counts,
            record["param_report"]["bytes_per_device"], chips,
        )
        record["analytic_memory_bytes_per_device"] = analytic_mem
        # Three-term roofline: compute from analytic MODEL_FLOPS (HLO cost
        # counts while bodies once — raw kept alongside for transparency),
        # memory = max(HLO bytes, analytic traffic), collective = scaled HLO.
        hlo_bytes_dev = record["cost"].get("bytes accessed", 0.0)
        terms = roofline.RooflineTerms(
            t_compute=(mf["model_flops"] / chips) / roofline.PEAK_FLOPS,
            t_memory=max(hlo_bytes_dev, analytic_mem) / roofline.HBM_BW,
            t_collective=coll.total_link_bytes / roofline.ICI_BW,
            flops=mf["model_flops"] / chips,
            bytes_accessed=max(hlo_bytes_dev, analytic_mem),
            link_bytes=coll.total_link_bytes,
        )
        record["roofline"] = terms.to_dict()
        record["roofline_raw_hlo"] = roofline.terms_from_analysis(
            record["cost"], record["collectives_raw"]["total_link_bytes"]
        ).to_dict()
        hlo_flops_global = record["cost"].get("flops", 0.0) * chips
        record["useful_flops_ratio_vs_raw_hlo"] = (
            mf["model_flops"] / hlo_flops_global if hlo_flops_global else None
        )
        record["status"] = "ok"
    except Exception as e:  # noqa: BLE001 — record the failure, keep sweeping
        record["status"] = "error"
        record["error"] = f"{type(e).__name__}: {e}"
        record["traceback"] = traceback.format_exc()[-4000:]
    record["total_s"] = time.time() - t0

    out_dir.mkdir(parents=True, exist_ok=True)
    out_path.write_text(json.dumps(record, indent=2))
    status = record["status"]
    print(f"[{status}] {arch} × {shape_name} × {mesh_tag} ({record['total_s']:.1f}s)",
          flush=True)
    return record


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default=None)
    ap.add_argument("--shape", default=None)
    ap.add_argument("--multi-pod", action="store_true", help="only the 512-chip mesh")
    ap.add_argument("--single-pod", action="store_true", help="only the 256-chip mesh")
    ap.add_argument("--force", action="store_true")
    ap.add_argument("--variant", default="baseline", choices=["baseline", "opt"])
    ap.add_argument("--out", default=str(RESULTS))
    args = ap.parse_args()

    out_dir = Path(args.out)
    archs = [args.arch] if args.arch else list(ARCHS)
    meshes = [False, True]
    if args.multi_pod:
        meshes = [True]
    if args.single_pod:
        meshes = [False]

    results = []
    for arch in archs:
        cfg = get_config(arch)
        shapes = [args.shape] if args.shape else applicable_shapes(cfg)
        for shape_name in shapes:
            for mp in meshes:
                results.append(
                    run_cell(arch, shape_name, mp, out_dir, args.force,
                             variant=args.variant)
                )
    ok = sum(r["status"] == "ok" for r in results)
    print(f"\n{ok}/{len(results)} cells OK")
    if ok < len(results):
        for r in results:
            if r["status"] != "ok":
                print(f"  FAILED {r['arch']} × {r['shape']} × "
                      f"{'pod512' if r['multi_pod'] else 'pod256'}: {r.get('error')}")


if __name__ == "__main__":
    main()
