"""Production mesh construction (DESIGN.md §6 / brief MULTI-POD DRY-RUN).

A function, not a module-level constant: importing this module never touches
JAX device state (device count is locked on first backend init, and only
``launch/dryrun.py`` is allowed to request 512 placeholder devices).
"""

from __future__ import annotations

import os
import time
import zlib
from dataclasses import dataclass
from pathlib import Path

import jax


def _axis_type_kwargs(n: int) -> dict:
    """``axis_types`` keyword for ``jax.make_mesh``, when this JAX has it.

    ``jax.sharding.AxisType`` (and the matching ``axis_types=`` parameter)
    only exist on newer JAX; on 0.4.x every mesh axis is implicitly Auto, so
    omitting the keyword is behaviour-identical.
    """
    axis_type = getattr(jax.sharding, "AxisType", None)
    if axis_type is None:
        return {}
    return {"axis_types": (axis_type.Auto,) * n}


def make_mesh(shape: tuple[int, ...], axes: tuple[str, ...]):
    """Version-portable ``jax.make_mesh`` with Auto axis types."""
    return jax.make_mesh(shape, axes, **_axis_type_kwargs(len(axes)))


def use_mesh(mesh):
    """Context manager that activates ``mesh`` as ambient default.

    Newer JAX spells this ``jax.set_mesh``; on 0.4.x the ``Mesh`` object is
    its own context manager with the same effect for jit/pjit name
    resolution.
    """
    set_mesh = getattr(jax, "set_mesh", None)
    if set_mesh is not None:
        return set_mesh(mesh)
    return mesh


def make_production_mesh(*, multi_pod: bool = False):
    shape = (2, 16, 16) if multi_pod else (16, 16)
    axes = ("pod", "data", "model") if multi_pod else ("data", "model")
    return make_mesh(shape, axes)


def make_data_mesh(n: int | None = None):
    """One-axis ``("data",)`` mesh over ``n`` local devices (default: all).

    The execution engine's canonical mesh: independent reductions (pytree
    leaves, stream chunks) shard over this axis.
    """
    devs = jax.devices()
    n = len(devs) if n is None else min(n, len(devs))
    return make_mesh((n,), ("data",))


def data_axis_size(mesh) -> int:
    """Size of the ``data`` axis (1 when the mesh has none)."""
    return int(dict(mesh.shape).get("data", 1))


# ---------------------------------------------------------------------------
# multi-controller host topology (paper Figs. 15/17/18 setting)
# ---------------------------------------------------------------------------

ENV_HOST_ID = "HPDR_HOST_ID"
ENV_HOST_COUNT = "HPDR_HOST_COUNT"


@dataclass(frozen=True)
class HostTopology:
    """Which controller process this is, out of how many.

    The multi-host I/O layer (per-host aggregated shard files, global
    manifest, topology-aware restore) is parameterised by exactly two
    integers; everything else — leaf ownership, shard naming, restore
    locality — derives deterministically from them, so every host computes
    the same assignment without communicating.
    """

    host_id: int = 0
    n_hosts: int = 1

    def __post_init__(self):
        if not 0 <= self.host_id < max(1, self.n_hosts):
            raise ValueError(
                f"host_id {self.host_id} out of range for {self.n_hosts} hosts"
            )

    @property
    def multi_host(self) -> bool:
        return self.n_hosts > 1

    def owner(self, key: str) -> int:
        """Deterministic leaf→host assignment (stable across processes).

        crc32 is byte-stable everywhere (unlike ``hash`` under
        ``PYTHONHASHSEED``), so every host — and every *later* process with
        the same host count — derives the identical mapping; that identity
        is what makes a same-topology restore purely shard-local.
        """
        return zlib.crc32(str(key).encode()) % max(1, self.n_hosts)

    def owns(self, key: str) -> bool:
        return self.owner(key) == self.host_id


def detect_topology() -> HostTopology:
    """This process's :class:`HostTopology`.

    Resolution order: the ``HPDR_HOST_ID`` / ``HPDR_HOST_COUNT`` environment
    override (the subprocess-simulated multi-controller setting used by the
    tests and benchmarks), then ``jax.distributed`` process indices, then
    single-host.
    """
    env_n = os.environ.get(ENV_HOST_COUNT)
    if env_n is not None:
        return HostTopology(int(os.environ.get(ENV_HOST_ID, 0)), int(env_n))
    try:
        return HostTopology(jax.process_index(), jax.process_count())
    except Exception:
        return HostTopology(0, 1)


def fs_barrier(
    directory: str | Path,
    name: str,
    topology: HostTopology,
    *,
    timeout: float = 120.0,
    poll_s: float = 0.005,
    payload: str = "ok",
) -> None:
    """Shared-filesystem rendezvous: block until every host arrives.

    Each host writes ``<directory>/.barrier-<name>.<host>`` (atomically, via
    rename) and polls until all ``n_hosts`` marker files exist.  This is the
    coordinator rendezvous for the multi-controller checkpoint writer — the
    only requirement is a shared filesystem, matching the subprocess-
    simulated test setting.  Markers are left behind (names are unique per
    step) so a late arrival still sees the full barrier.
    """
    directory = Path(directory)
    directory.mkdir(parents=True, exist_ok=True)
    mine = directory / f".barrier-{name}.{topology.host_id}"
    tmp = mine.with_name(mine.name + f".tmp{os.getpid()}")
    tmp.write_text(payload)
    os.replace(tmp, mine)
    deadline = time.monotonic() + timeout
    while True:
        present = {
            suffix
            for p in directory.glob(f".barrier-{name}.*")
            if (suffix := p.name.rsplit(".", 1)[-1]).isdigit()
        }
        if len(present) >= topology.n_hosts:
            return
        if time.monotonic() > deadline:
            raise TimeoutError(
                f"fs_barrier {name!r}: {len(present)}/{topology.n_hosts} "
                f"hosts after {timeout}s (present: {sorted(present)})"
            )
        time.sleep(poll_s)


def barrier_payloads(
    directory: str | Path, name: str, topology: HostTopology
) -> dict[int, str]:
    """Read every host's barrier marker payload (post-``fs_barrier``).

    The checkpoint coordinator uses the payloads as a zero-extra-round-trip
    side channel: each host's marker carries its shard's write stats.
    """
    out: dict[int, str] = {}
    for h in range(topology.n_hosts):
        p = Path(directory) / f".barrier-{name}.{h}"
        if p.exists():
            out[h] = p.read_text()
    return out


def make_test_mesh(n_data: int = 2, n_model: int = 2):
    """Small mesh over however many devices exist (tests / examples)."""
    n = len(jax.devices())
    n_data = min(n_data, n)
    n_model = min(n_model, max(1, n // n_data))
    return make_mesh((n_data, n_model), ("data", "model"))
