"""Production mesh construction (DESIGN.md §6 / brief MULTI-POD DRY-RUN).

A function, not a module-level constant: importing this module never touches
JAX device state (device count is locked on first backend init, and only
``launch/dryrun.py`` is allowed to request 512 placeholder devices).
"""

from __future__ import annotations

import jax


def _axis_type_kwargs(n: int) -> dict:
    """``axis_types`` keyword for ``jax.make_mesh``, when this JAX has it.

    ``jax.sharding.AxisType`` (and the matching ``axis_types=`` parameter)
    only exist on newer JAX; on 0.4.x every mesh axis is implicitly Auto, so
    omitting the keyword is behaviour-identical.
    """
    axis_type = getattr(jax.sharding, "AxisType", None)
    if axis_type is None:
        return {}
    return {"axis_types": (axis_type.Auto,) * n}


def make_mesh(shape: tuple[int, ...], axes: tuple[str, ...]):
    """Version-portable ``jax.make_mesh`` with Auto axis types."""
    return jax.make_mesh(shape, axes, **_axis_type_kwargs(len(axes)))


def use_mesh(mesh):
    """Context manager that activates ``mesh`` as ambient default.

    Newer JAX spells this ``jax.set_mesh``; on 0.4.x the ``Mesh`` object is
    its own context manager with the same effect for jit/pjit name
    resolution.
    """
    set_mesh = getattr(jax, "set_mesh", None)
    if set_mesh is not None:
        return set_mesh(mesh)
    return mesh


def make_production_mesh(*, multi_pod: bool = False):
    shape = (2, 16, 16) if multi_pod else (16, 16)
    axes = ("pod", "data", "model") if multi_pod else ("data", "model")
    return make_mesh(shape, axes)


def make_data_mesh(n: int | None = None):
    """One-axis ``("data",)`` mesh over ``n`` local devices (default: all).

    The execution engine's canonical mesh: independent reductions (pytree
    leaves, stream chunks) shard over this axis.
    """
    devs = jax.devices()
    n = len(devs) if n is None else min(n, len(devs))
    return make_mesh((n,), ("data",))


def data_axis_size(mesh) -> int:
    """Size of the ``data`` axis (1 when the mesh has none)."""
    return int(dict(mesh.shape).get("data", 1))


def make_test_mesh(n_data: int = 2, n_model: int = 2):
    """Small mesh over however many devices exist (tests / examples)."""
    n = len(jax.devices())
    n_data = min(n_data, n)
    n_model = min(n_model, max(1, n // n_data))
    return make_mesh((n_data, n_model), ("data", "model"))
