"""Production mesh construction (DESIGN.md §6 / brief MULTI-POD DRY-RUN).

A function, not a module-level constant: importing this module never touches
JAX device state (device count is locked on first backend init, and only
``launch/dryrun.py`` is allowed to request 512 placeholder devices).
"""

from __future__ import annotations

import jax


def _auto(n: int):
    return (jax.sharding.AxisType.Auto,) * n


def make_production_mesh(*, multi_pod: bool = False):
    shape = (2, 16, 16) if multi_pod else (16, 16)
    axes = ("pod", "data", "model") if multi_pod else ("data", "model")
    return jax.make_mesh(shape, axes, axis_types=_auto(len(axes)))


def make_test_mesh(n_data: int = 2, n_model: int = 2):
    """Small mesh over however many devices exist (tests / examples)."""
    n = len(jax.devices())
    n_data = min(n_data, n)
    n_model = min(n_model, max(1, n // n_data))
    return jax.make_mesh((n_data, n_model), ("data", "model"), axis_types=_auto(2))
