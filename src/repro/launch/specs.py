"""Input specs (ShapeDtypeStruct stand-ins) + step builders for every cell.

``input_specs(cfg, shape)`` returns sharded ShapeDtypeStructs for every model
input — weak-type-correct, shardable, zero allocation.  Modality frontends
are stubs per the brief: audio/vlm cells receive precomputed frame/patch
embeddings (and 3-D M-RoPE position triplets for qwen2-vl).

``make_train_step`` / ``make_prefill_step`` / ``make_decode_step`` build the
pure step functions the dry-run lowers and the trainer executes.
"""

from __future__ import annotations

import functools
from typing import Any

import jax
import jax.numpy as jnp
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P

from ..configs.base import ModelConfig, ShapeConfig
from ..models.model import Model
from ..optim import adamw
from ..runtime import sharding as shr


def _sds(shape, dtype, sharding=None):
    return jax.ShapeDtypeStruct(shape, dtype, sharding=sharding)


def batch_specs(cfg: ModelConfig, shape: ShapeConfig, mesh: Mesh) -> dict:
    """Abstract train/prefill batch for this (arch × shape) cell."""
    b, s = shape.global_batch, shape.seq_len
    dp = shr.dp_axes(mesh)
    dp = dp if (dp and b % shr._axis_size(mesh, dp) == 0) else None
    dt = jnp.dtype(cfg.dtype)
    tok = lambda *sh: _sds(sh, jnp.int32, NamedSharding(mesh, P(dp, *[None] * (len(sh) - 1))))
    emb = lambda *sh: _sds(sh, dt, NamedSharding(mesh, P(dp, *[None] * (len(sh) - 1))))
    batch: dict[str, Any] = {}
    if cfg.family == "encdec":
        batch["enc_embeds"] = emb(b, s, cfg.d_model)
        batch["tokens"] = tok(b, s)
        batch["labels"] = tok(b, s)
    elif cfg.family == "vlm":
        batch["embeds"] = emb(b, s, cfg.d_model)
        batch["positions_3d"] = tok(b, s, 3)
        batch["labels"] = tok(b, s)
    else:
        batch["tokens"] = tok(b, s)
        batch["labels"] = tok(b, s)
    if shape.kind == "prefill":
        batch.pop("labels", None)
    return batch


def param_specs(model: Model, mesh: Mesh):
    params_shape = jax.eval_shape(lambda: model.init(jax.random.PRNGKey(0)))
    shardings = shr.param_shardings(params_shape, model.cfg, mesh)
    return jax.tree.map(
        lambda s, sh: _sds(s.shape, s.dtype, sh), params_shape, shardings
    )


def opt_state_specs(
    param_sds, mesh: Mesh, opt_cfg: adamw.AdamWConfig,
    cfg: ModelConfig | None = None,
):
    state_shape = jax.eval_shape(lambda p: adamw.init_state(p, opt_cfg), param_sds)
    zero1 = cfg is not None and cfg.sharding_policy == "dp_zero1"

    def attach(path, leaf):
        names = [str(getattr(e, "key", getattr(e, "idx", ""))) for e in path]
        if names and names[0] in ("m", "v"):
            if zero1:
                # ZeRO-1: moments sharded over "model" even though params
                # are replicated — the update computes on moment shards and
                # all-gathers the new params once per step.
                from ..runtime.sharding import _param_spec_fsdp_dp

                spec = _param_spec_fsdp_dp(names[1:] or ["_"], leaf, cfg, mesh)
                return _sds(leaf.shape, leaf.dtype, NamedSharding(mesh, spec))
            # mirror the param sharding at the same subpath
            sub = param_sds
            for n in names[1:]:
                sub = sub[int(n)] if isinstance(sub, (list, tuple)) else sub[n]
            return _sds(leaf.shape, leaf.dtype, sub.sharding)
        return _sds(leaf.shape, leaf.dtype, NamedSharding(mesh, P()))

    return jax.tree_util.tree_map_with_path(attach, state_shape)


def cache_specs(model: Model, shape: ShapeConfig, mesh: Mesh):
    cfg = model.cfg
    b, s = shape.global_batch, shape.seq_len
    cache_shape = jax.eval_shape(
        lambda: model.init_cache(b, s, jnp.bfloat16)
    )
    if cfg.family == "encdec":
        # cross K/V filled at prefill: (L, B, S_enc, KH, hd)
        hd = cfg.resolved_head_dim
        n_dec = cfg.n_dec_layers or cfg.n_layers
        cross = jax.ShapeDtypeStruct((n_dec, b, s, cfg.n_kv_heads, hd), jnp.bfloat16)
        cache_shape = dict(cache_shape)
        cache_shape["cross_k"] = cross
        cache_shape["cross_v"] = cross
    shardings = shr.cache_shardings(cache_shape, cfg, mesh)
    return jax.tree.map(
        lambda sds, sh: _sds(sds.shape, sds.dtype, sh), cache_shape, shardings
    )


def token_specs(cfg: ModelConfig, shape: ShapeConfig, mesh: Mesh):
    b = shape.global_batch
    dp = shr.dp_axes(mesh)
    dp = dp if (dp and b % shr._axis_size(mesh, dp) == 0) else None
    return _sds((b,), jnp.int32, NamedSharding(mesh, P(dp)))


# ---------------------------------------------------------------------------
# step builders
# ---------------------------------------------------------------------------


def make_train_step(model: Model, opt_cfg: adamw.AdamWConfig, lr: float = 3e-4):
    cfg = model.cfg

    def train_step(params, opt_state, batch):
        (loss, metrics), grads = jax.value_and_grad(model.loss, has_aux=True)(
            params, batch
        )
        if cfg.sharding_policy == "dp_zero1":
            # ZeRO-1, made structural: constrain each grad onto the moment
            # shards so XLA lowers the cross-replica sum as reduce-scatter
            # (link ≈ D) instead of all-reduce (≈ 2D); the updated params are
            # all-gathered once on output.  (The AR→RS folding pass exists on
            # TPU; the constraint makes the dry-run — and any backend —
            # produce the intended schedule.)
            grads = _constrain_tree_model_shard(grads, cfg)
        params, opt_state, om = adamw.apply_updates(
            params, grads, opt_state, lr, opt_cfg
        )
        metrics.update(om)
        return params, opt_state, metrics

    return train_step


def _constrain_tree_model_shard(tree, cfg: ModelConfig):
    from ..runtime.sharding import _param_spec_fsdp_dp

    try:
        mesh = jax.sharding.get_abstract_mesh()
        if mesh is None or not mesh.axis_names:
            return tree
    except Exception:  # pragma: no cover
        return tree

    def con(path, leaf):
        names = [str(getattr(e, "key", getattr(e, "idx", ""))) for e in path]
        spec = _param_spec_fsdp_dp(names or ["_"], leaf, cfg, mesh)
        try:
            return jax.lax.with_sharding_constraint(leaf, spec)
        except Exception:
            return leaf

    return jax.tree_util.tree_map_with_path(con, tree)


def make_prefill_step(model: Model):
    cfg = model.cfg

    def prefill_step(params, batch):
        if cfg.family == "encdec":
            from ..models import encdec as ed

            memory = ed.encode(
                params, batch["enc_embeds"].astype(jnp.dtype(cfg.dtype)), cfg
            )
            logits = ed.decode_train(params, batch["tokens"], memory, cfg)
            return logits[:, -1]
        x = model._embed_in(params, batch)
        h, _ = model._backbone(params, x, batch)
        from ..models.layers import rms_norm

        h = rms_norm(h, params["ln_f"]["scale"], cfg.norm_eps)
        return model._head(params, h[:, -1:, :])[:, 0]

    return prefill_step


def make_decode_step(model: Model):
    def decode_step(params, token, cache, cache_len):
        return model.decode_step(params, token, cache, cache_len)

    return decode_step
