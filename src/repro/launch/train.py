"""Training launcher: --arch config → sharded train loop with HPDR features.

Production path exercised end-to-end (CPU-scale in this container):
  data stream → jitted train step (sharded params/opt) → straggler watchdog
  → async HPDR-compressed checkpoints → auto-restore on restart.

Usage:
  PYTHONPATH=src python -m repro.launch.train --arch qwen2.5-3b --smoke \
      --steps 50 --batch 8 --seq 128 --ckpt-dir /tmp/ckpt --ckpt-every 20
"""

from __future__ import annotations

import argparse
import time
from dataclasses import replace
from pathlib import Path

import jax
import jax.numpy as jnp

from ..configs import get_config
from ..checkpoint import CheckpointManager, CheckpointPolicy
from ..data import DataConfig, SyntheticLMStream
from ..models import build_model
from ..optim import adamw, schedule
from ..runtime import fault
from ..runtime import sharding as shr
from . import specs as S
from .mesh import make_test_mesh


def train_loop(
    arch: str,
    steps: int = 50,
    batch: int = 8,
    seq: int = 128,
    smoke: bool = True,
    ckpt_dir: str | None = None,
    ckpt_every: int = 0,
    lr: float = 3e-4,
    sched: str = "cosine",
    log_every: int = 10,
    exact_ckpt: bool = True,
    inject_failure_at: int | None = None,
    sync_ckpt: bool = False,
) -> dict:
    cfg = get_config(arch)
    if smoke:
        cfg = cfg.smoke()
    cfg = replace(cfg, remat=False) if seq * batch <= 16384 else cfg
    mesh = make_test_mesh()
    model = build_model(cfg)

    key = jax.random.PRNGKey(0)
    params = model.init(key)
    opt_cfg = adamw.AdamWConfig()
    opt_state = adamw.init_state(params, opt_cfg)

    # shard onto the test mesh
    p_sh = shr.param_shardings(jax.eval_shape(lambda: model.init(key)), cfg, mesh)
    params = jax.device_put(params, p_sh)
    opt_state = {
        "m": jax.device_put(opt_state["m"], p_sh),
        "v": jax.device_put(opt_state["v"], p_sh),
        "step": jax.device_put(opt_state["step"]),
    }

    sched_fn = schedule.SCHEDULES[sched]
    data = SyntheticLMStream(
        DataConfig(vocab=cfg.vocab, seq_len=seq, global_batch=batch), mesh
    )

    def train_step(params, opt_state, batch_):
        (loss, metrics), grads = jax.value_and_grad(model.loss, has_aux=True)(
            params, batch_
        )
        lr_t = sched_fn(opt_state["step"], peak_lr=lr, warmup=max(steps // 10, 1),
                        total=steps)
        new_params, new_opt, om = adamw.apply_updates(
            params, grads, opt_state, lr_t, opt_cfg
        )
        new_params, finite = fault.skip_nonfinite_update(new_params, params, grads)
        metrics.update(om)
        metrics["finite"] = finite
        return new_params, new_opt, metrics

    step_jit = jax.jit(train_step, donate_argnums=(0, 1))

    mgr = None
    start_step = 0
    if ckpt_dir:
        mgr = CheckpointManager(ckpt_dir, CheckpointPolicy(exact=exact_ckpt))
        latest = mgr.latest_step()
        if latest is not None:
            tree, manifest = mgr.restore(
                latest,
                target={"params": params, "opt": opt_state},
                shardings={
                    "params": p_sh,
                    "opt": {"m": p_sh, "v": p_sh, "step": shr.replicated(mesh)},
                },
            )
            params, opt_state = tree["params"], tree["opt"]
            data.load_state_dict(manifest["extra"]["data"])
            start_step = latest
            print(f"[restore] resumed from step {latest} "
                  f"(ratio {manifest['ratio']:.2f}x)")

    watchdog = fault.StragglerWatchdog()
    losses = []
    for step in range(start_step, steps):
        if inject_failure_at is not None and step == inject_failure_at:
            raise RuntimeError(f"injected failure at step {step}")
        t0 = time.perf_counter()
        batch_ = data.next_batch()
        params, opt_state, metrics = step_jit(params, opt_state, batch_)
        loss = float(metrics["loss"])
        dt = time.perf_counter() - t0
        slow = watchdog.observe(dt)
        losses.append(loss)
        if step % log_every == 0 or step == steps - 1:
            print(f"step {step:5d} loss {loss:.4f} {dt*1e3:7.1f} ms"
                  + (" [straggler]" if slow else ""))
        if mgr and ckpt_every and (step + 1) % ckpt_every == 0:
            save = mgr.save if sync_ckpt else mgr.save_async
            save(step + 1, {"params": params, "opt": opt_state},
                 extra={"data": data.state_dict()})
    if mgr:
        mgr.wait()
    return {
        "first_loss": losses[0] if losses else None,
        "last_loss": losses[-1] if losses else None,
        "steps_run": len(losses),
        "stragglers": watchdog.flagged,
        "ckpt_report": mgr.last_report if mgr else None,
    }


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", required=True)
    ap.add_argument("--steps", type=int, default=50)
    ap.add_argument("--batch", type=int, default=8)
    ap.add_argument("--seq", type=int, default=128)
    ap.add_argument("--smoke", action="store_true", default=True)
    ap.add_argument("--full", dest="smoke", action="store_false")
    ap.add_argument("--ckpt-dir", default=None)
    ap.add_argument("--ckpt-every", type=int, default=0)
    ap.add_argument("--lr", type=float, default=3e-4)
    ap.add_argument("--schedule", default="cosine", choices=list(schedule.SCHEDULES))
    args = ap.parse_args()
    out = train_loop(
        args.arch, steps=args.steps, batch=args.batch, seq=args.seq,
        smoke=args.smoke, ckpt_dir=args.ckpt_dir, ckpt_every=args.ckpt_every,
        lr=args.lr, sched=args.schedule,
    )
    print(out)


if __name__ == "__main__":
    main()
