"""Model substrate: the 10 assigned architectures as composable JAX modules."""

from . import attention, encdec, layers, moe, model, rglru, ssm, transformer  # noqa: F401
from .model import Model, build_model, cross_entropy  # noqa: F401
