"""Attention variants: GQA (causal / local / cross), MLA, decode paths.

Shapes: hidden (B, S, D); q/k/v (B, S, H, hd).  All masks are additive
float32 −inf masks computed from position iotas (TPU-friendly: no boolean
gather).  Decode steps take a KV cache pytree and a scalar ``cache_len``.
"""

from __future__ import annotations

from dataclasses import dataclass

import jax
import jax.numpy as jnp
import numpy as np

from ..configs.base import MLAConfig, ModelConfig
from .layers import apply_mrope, apply_rope, init_linear, init_rms_norm, linear, rms_norm

NEG_INF = -1e9


def causal_mask(s_q: int, s_k: int, q_offset=0) -> jax.Array:
    q_pos = jax.lax.iota(jnp.int32, s_q)[:, None] + q_offset
    k_pos = jax.lax.iota(jnp.int32, s_k)[None, :]
    return jnp.where(k_pos <= q_pos, 0.0, NEG_INF).astype(jnp.float32)


def local_causal_mask(s_q: int, s_k: int, window: int, q_offset=0) -> jax.Array:
    q_pos = jax.lax.iota(jnp.int32, s_q)[:, None] + q_offset
    k_pos = jax.lax.iota(jnp.int32, s_k)[None, :]
    ok = (k_pos <= q_pos) & (k_pos > q_pos - window)
    return jnp.where(ok, 0.0, NEG_INF).astype(jnp.float32)


def _sdpa(q, k, v, mask, scale):
    """q/k: (B,S,·,qk_dim), v: (B,Sk,KH,v_dim); H = G·KH (GQA repeat).

    qk_dim and v_dim may differ (MLA: 192 vs 128).
    """
    b, sq, h, _ = q.shape
    kh = k.shape[2]
    g = h // kh
    vd = v.shape[-1]
    dtype = q.dtype
    q = q.reshape(b, sq, kh, g, q.shape[-1])
    scores = jnp.einsum("bqkgd,bskd->bkgqs", q.astype(jnp.float32), k.astype(jnp.float32))
    scores = scores * scale
    if mask is not None:
        scores = scores + mask  # (Sq, Sk) broadcast
    probs = jax.nn.softmax(scores, axis=-1)
    out = jnp.einsum("bkgqs,bskd->bqkgd", probs, v.astype(jnp.float32))
    return out.reshape(b, sq, h, vd).astype(dtype)


# ---------------------------------------------------------------------------
# GQA attention block
# ---------------------------------------------------------------------------


def init_gqa(key, cfg: ModelConfig, dtype=jnp.float32) -> dict:
    hd = cfg.resolved_head_dim
    ks = jax.random.split(key, 4)
    return {
        "wq": init_linear(ks[0], cfg.d_model, cfg.n_heads * hd, cfg.qkv_bias, dtype),
        "wk": init_linear(ks[1], cfg.d_model, cfg.n_kv_heads * hd, cfg.qkv_bias, dtype),
        "wv": init_linear(ks[2], cfg.d_model, cfg.n_kv_heads * hd, cfg.qkv_bias, dtype),
        "wo": init_linear(ks[3], cfg.n_heads * hd, cfg.d_model, False, dtype),
    }


def gqa_qkv(x, p, cfg: ModelConfig):
    b, s, _ = x.shape
    hd = cfg.resolved_head_dim
    q = linear(x, p["wq"]).reshape(b, s, cfg.n_heads, hd)
    k = linear(x, p["wk"]).reshape(b, s, cfg.n_kv_heads, hd)
    v = linear(x, p["wv"]).reshape(b, s, cfg.n_kv_heads, hd)
    return q, k, v


def gqa_attention(
    x: jax.Array,
    p: dict,
    cfg: ModelConfig,
    positions: jax.Array | None = None,
    window: int = 0,
    mrope_positions: jax.Array | None = None,
) -> jax.Array:
    b, s, _ = x.shape
    hd = cfg.resolved_head_dim
    q, k, v = gqa_qkv(x, p, cfg)
    if positions is None:
        positions = jnp.broadcast_to(jax.lax.iota(jnp.int32, s)[None], (b, s))
    if cfg.mrope and mrope_positions is not None:
        q = apply_mrope(q, mrope_positions, cfg.rope_theta, cfg.mrope_sections)
        k = apply_mrope(k, mrope_positions, cfg.rope_theta, cfg.mrope_sections)
    else:
        q = apply_rope(q, positions, cfg.rope_theta)
        k = apply_rope(k, positions, cfg.rope_theta)
    mask = (
        local_causal_mask(s, s, window) if window > 0 else causal_mask(s, s)
    )
    out = _sdpa(q, k, v, mask, 1.0 / np.sqrt(hd))
    return linear(out.reshape(b, s, -1), p["wo"])


def gqa_decode(
    x: jax.Array,               # (B, 1, D)
    p: dict,
    cfg: ModelConfig,
    cache: dict,                # {"k": (B, S_max, KH, hd), "v": ...}
    cache_len: jax.Array,       # scalar int32 — tokens already in cache
    window: int = 0,
) -> tuple[jax.Array, dict]:
    b = x.shape[0]
    hd = cfg.resolved_head_dim
    q, k, v = gqa_qkv(x, p, cfg)
    pos = jnp.full((b, 1), cache_len, jnp.int32)
    q = apply_rope(q, pos, cfg.rope_theta)
    k = apply_rope(k, pos, cfg.rope_theta)
    if cfg.kv_replicate > 1:
        # §Perf decode lever: physically replicate KV heads so the cache's
        # head dim fills the model axis — updates stay shard-local and the
        # per-device cache shrinks by model_size/replicate.
        k = jnp.repeat(k, cfg.kv_replicate, axis=2)
        v = jnp.repeat(v, cfg.kv_replicate, axis=2)
    # Ring-buffer write: window caches are sized `window`, full caches are
    # sized max_len (write_pos == cache_len there).  RoPE is absolute, so
    # ring order does not matter — validity is all that's masked.
    s_max = cache["k"].shape[1]
    write_pos = jnp.remainder(cache_len, s_max)
    if cfg.decode_masked_update:
        # §Perf decode lever: scatter-free masked write — elementwise on the
        # sequence-sharded cache, so no shard ever moves (the baseline's
        # dynamic_update_slice makes GSPMD all-gather the whole cache).
        sel = (jax.lax.iota(jnp.int32, s_max) == write_pos)[None, :, None, None]
        k_cache = jnp.where(sel, k.astype(cache["k"].dtype), cache["k"])
        v_cache = jnp.where(sel, v.astype(cache["v"].dtype), cache["v"])
    else:
        k_cache = jax.lax.dynamic_update_slice(
            cache["k"], k.astype(cache["k"].dtype), (0, write_pos, 0, 0)
        )
        v_cache = jax.lax.dynamic_update_slice(
            cache["v"], v.astype(cache["v"].dtype), (0, write_pos, 0, 0)
        )
    slot = jax.lax.iota(jnp.int32, s_max)[None, :]
    valid = slot <= cache_len  # ring-full ⇒ every slot holds a live token
    del window
    mask = jnp.where(valid, 0.0, NEG_INF).astype(jnp.float32)[0][None, :]  # (1,S)
    out = _sdpa(q, k_cache, v_cache, mask, 1.0 / np.sqrt(hd))
    y = linear(out.reshape(b, 1, -1), p["wo"])
    return y, {"k": k_cache, "v": v_cache}


def cross_attention(
    x: jax.Array,       # (B, Sq, D) decoder states
    memory: jax.Array,  # (B, Sk, D) encoder output
    p: dict,
    cfg: ModelConfig,
) -> jax.Array:
    b, sq, _ = x.shape
    sk = memory.shape[1]
    hd = cfg.resolved_head_dim
    q = linear(x, p["wq"]).reshape(b, sq, cfg.n_heads, hd)
    k = linear(memory, p["wk"]).reshape(b, sk, cfg.n_kv_heads, hd)
    v = linear(memory, p["wv"]).reshape(b, sk, cfg.n_kv_heads, hd)
    out = _sdpa(q, k, v, None, 1.0 / np.sqrt(hd))
    return linear(out.reshape(b, sq, -1), p["wo"])


# ---------------------------------------------------------------------------
# MLA — Multi-head Latent Attention (DeepSeek-V3)
# ---------------------------------------------------------------------------


def init_mla(key, cfg: ModelConfig, dtype=jnp.float32) -> dict:
    m: MLAConfig = cfg.mla
    ks = jax.random.split(key, 7)
    qk_dim = m.qk_nope_head_dim + m.qk_rope_head_dim
    return {
        "wq_a": init_linear(ks[0], cfg.d_model, m.q_lora_rank, False, dtype),
        "q_norm": init_rms_norm(m.q_lora_rank, dtype),
        "wq_b": init_linear(ks[1], m.q_lora_rank, cfg.n_heads * qk_dim, False, dtype),
        "wkv_a": init_linear(
            ks[2], cfg.d_model, m.kv_lora_rank + m.qk_rope_head_dim, False, dtype
        ),
        "kv_norm": init_rms_norm(m.kv_lora_rank, dtype),
        "wkv_b": init_linear(
            ks[3],
            m.kv_lora_rank,
            cfg.n_heads * (m.qk_nope_head_dim + m.v_head_dim),
            False,
            dtype,
        ),
        "wo": init_linear(ks[4], cfg.n_heads * m.v_head_dim, cfg.d_model, False, dtype),
    }


def _mla_qkv(x, p, cfg: ModelConfig, positions):
    """Expand MLA latents to per-head q, k, v (paper-faithful shapes)."""
    m: MLAConfig = cfg.mla
    b, s, _ = x.shape
    h = cfg.n_heads
    q = linear(rms_norm(linear(x, p["wq_a"]), p["q_norm"]["scale"], cfg.norm_eps), p["wq_b"])
    q = q.reshape(b, s, h, m.qk_nope_head_dim + m.qk_rope_head_dim)
    q_nope, q_rope = jnp.split(q, [m.qk_nope_head_dim], axis=-1)
    q_rope = apply_rope(q_rope, positions, cfg.rope_theta)

    kv_a = linear(x, p["wkv_a"])  # (B,S, kv_rank + rope_dim)
    c_kv, k_rope = jnp.split(kv_a, [m.kv_lora_rank], axis=-1)
    c_kv = rms_norm(c_kv, p["kv_norm"]["scale"], cfg.norm_eps)
    k_rope = apply_rope(k_rope[:, :, None, :], positions, cfg.rope_theta)  # shared head
    kv = linear(c_kv, p["wkv_b"]).reshape(
        b, s, h, m.qk_nope_head_dim + m.v_head_dim
    )
    k_nope, v = jnp.split(kv, [m.qk_nope_head_dim], axis=-1)
    k_rope_b = jnp.broadcast_to(k_rope, (b, s, h, m.qk_rope_head_dim))
    q_full = jnp.concatenate([q_nope, q_rope], axis=-1)
    k_full = jnp.concatenate([k_nope, k_rope_b], axis=-1)
    return q_full, k_full, v, (c_kv, k_rope)


def mla_attention(
    x: jax.Array, p: dict, cfg: ModelConfig, positions: jax.Array | None = None
) -> jax.Array:
    m: MLAConfig = cfg.mla
    b, s, _ = x.shape
    if positions is None:
        positions = jnp.broadcast_to(jax.lax.iota(jnp.int32, s)[None], (b, s))
    q, k, v, _ = _mla_qkv(x, p, cfg, positions)
    scale = 1.0 / np.sqrt(m.qk_nope_head_dim + m.qk_rope_head_dim)
    out = _sdpa(q, k, v, causal_mask(s, s), scale)
    return linear(out.reshape(b, s, -1), p["wo"])


def mla_decode(
    x: jax.Array,           # (B, 1, D)
    p: dict,
    cfg: ModelConfig,
    cache: dict,            # {"c_kv": (B,S,kv_rank), "k_rope": (B,S,1,rope_dim)}
    cache_len: jax.Array,
) -> tuple[jax.Array, dict]:
    """MLA decode with the *compressed* latent cache — MLA's core trade:
    cache kv_rank+rope (576) floats/token instead of 2·H·hd (32768)."""
    m: MLAConfig = cfg.mla
    b = x.shape[0]
    h = cfg.n_heads
    pos = jnp.full((b, 1), cache_len, jnp.int32)
    q, k_new, v_new, (c_kv_new, k_rope_new) = _mla_qkv(x, p, cfg, pos)
    if cfg.decode_masked_update:
        s_max = cache["c_kv"].shape[1]
        sel = (jax.lax.iota(jnp.int32, s_max) == cache_len)[None, :, None]
        c_cache = jnp.where(sel, c_kv_new.astype(cache["c_kv"].dtype), cache["c_kv"])
        r_cache = jnp.where(
            sel[..., None], k_rope_new.astype(cache["k_rope"].dtype), cache["k_rope"]
        )
    else:
        c_cache = jax.lax.dynamic_update_slice(
            cache["c_kv"], c_kv_new.astype(cache["c_kv"].dtype), (0, cache_len, 0)
        )
        r_cache = jax.lax.dynamic_update_slice(
            cache["k_rope"], k_rope_new.astype(cache["k_rope"].dtype),
            (0, cache_len, 0, 0),
        )
    # expand latents for attention (weight-absorbed form is the perf option;
    # the faithful expanded form keeps the oracle simple)
    kv = linear(c_cache, p["wkv_b"]).reshape(
        b, -1, h, m.qk_nope_head_dim + m.v_head_dim
    )
    k_nope, v = jnp.split(kv, [m.qk_nope_head_dim], axis=-1)
    s_max = k_nope.shape[1]
    k_rope_b = jnp.broadcast_to(r_cache, (b, s_max, h, m.qk_rope_head_dim))
    k = jnp.concatenate([k_nope, k_rope_b], axis=-1)
    k_pos = jax.lax.iota(jnp.int32, s_max)[None, :]
    mask = jnp.where(k_pos <= cache_len, 0.0, NEG_INF).astype(jnp.float32)
    scale = 1.0 / np.sqrt(m.qk_nope_head_dim + m.qk_rope_head_dim)
    out = _sdpa(q, k, v, mask, scale)
    y = linear(out.reshape(b, 1, -1), p["wo"])
    return y, {"c_kv": c_cache, "k_rope": r_cache}
