"""Encoder-decoder backbone (seamless-m4t-medium text/audio stub).

Encoder: bidirectional self-attention + GELU FFN over precomputed frame
embeddings (the audio frontend is a stub per the brief — ``input_specs``
supplies (B, S_enc, D) features).  Decoder: causal self-attention +
cross-attention + FFN over text tokens.  Both stacks are scanned.
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp

from ..configs.base import ModelConfig
from . import attention as attn
from . import transformer as tfm
from .layers import (
    embed,
    init_embedding,
    init_gelu_mlp,
    init_linear,
    init_rms_norm,
    gelu_mlp,
    linear,
    rms_norm,
)


def init_enc_layer(key, cfg: ModelConfig, dtype=jnp.float32) -> dict:
    ks = jax.random.split(key, 2)
    return {
        "ln1": init_rms_norm(cfg.d_model, dtype),
        "attn": attn.init_gqa(ks[0], cfg, dtype),
        "ln2": init_rms_norm(cfg.d_model, dtype),
        "mlp": init_gelu_mlp(ks[1], cfg.d_model, cfg.d_ff, dtype),
    }


def init_dec_layer(key, cfg: ModelConfig, dtype=jnp.float32) -> dict:
    ks = jax.random.split(key, 3)
    return {
        "ln1": init_rms_norm(cfg.d_model, dtype),
        "attn": attn.init_gqa(ks[0], cfg, dtype),
        "lnx": init_rms_norm(cfg.d_model, dtype),
        "cross": attn.init_gqa(ks[1], cfg, dtype),
        "ln2": init_rms_norm(cfg.d_model, dtype),
        "mlp": init_gelu_mlp(ks[2], cfg.d_model, cfg.d_ff, dtype),
    }


def init_encdec(key, cfg: ModelConfig, dtype=jnp.float32) -> dict:
    ks = jax.random.split(key, 6)
    n_enc = cfg.n_enc_layers or cfg.n_layers
    n_dec = cfg.n_dec_layers or cfg.n_layers
    return {
        "embed": init_embedding(ks[0], cfg.vocab, cfg.d_model, dtype),
        "enc_layers": tfm.init_stack(ks[1], n_enc, lambda k: init_enc_layer(k, cfg, dtype)),
        "dec_layers": tfm.init_stack(ks[2], n_dec, lambda k: init_dec_layer(k, cfg, dtype)),
        "ln_enc": init_rms_norm(cfg.d_model, dtype),
        "ln_dec": init_rms_norm(cfg.d_model, dtype),
        "head": init_linear(ks[3], cfg.d_model, cfg.vocab, False, dtype),
    }


def _enc_block(x, p, cfg: ModelConfig):
    h = rms_norm(x, p["ln1"]["scale"], cfg.norm_eps)
    b, s, _ = h.shape
    hd = cfg.resolved_head_dim
    q, k, v = attn.gqa_qkv(h, p["attn"], cfg)
    pos = jnp.broadcast_to(jax.lax.iota(jnp.int32, s)[None], (b, s))
    from .layers import apply_rope

    q = apply_rope(q, pos, cfg.rope_theta)
    k = apply_rope(k, pos, cfg.rope_theta)
    a = attn._sdpa(q, k, v, None, 1.0 / jnp.sqrt(float(hd)))  # bidirectional
    x = x + linear(a.reshape(b, s, -1), p["attn"]["wo"])
    h = rms_norm(x, p["ln2"]["scale"], cfg.norm_eps)
    return x + gelu_mlp(h, p["mlp"])


def _dec_block(x, memory, p, cfg: ModelConfig):
    h = rms_norm(x, p["ln1"]["scale"], cfg.norm_eps)
    x = x + attn.gqa_attention(h, p["attn"], cfg)
    h = rms_norm(x, p["lnx"]["scale"], cfg.norm_eps)
    x = x + attn.cross_attention(h, memory, p["cross"], cfg)
    h = rms_norm(x, p["ln2"]["scale"], cfg.norm_eps)
    return x + gelu_mlp(h, p["mlp"])


def encode(params, enc_embeds: jax.Array, cfg: ModelConfig) -> jax.Array:
    block = functools.partial(_enc_block, cfg=cfg)
    x = tfm.scan_stack(enc_embeds, params["enc_layers"], block, cfg.remat)
    return rms_norm(x, params["ln_enc"]["scale"], cfg.norm_eps)


def decode_train(params, tokens: jax.Array, memory: jax.Array, cfg: ModelConfig):
    x = embed(tokens, params["embed"], memory.dtype)
    fn = functools.partial(_dec_block, cfg=cfg)
    fn = jax.checkpoint(fn, static_argnums=()) if cfg.remat else fn

    def step(h, lp):
        return fn(h, memory, lp), None

    x, _ = jax.lax.scan(step, x, params["dec_layers"])
    h = rms_norm(x, params["ln_dec"]["scale"], cfg.norm_eps)
    return linear(h, params["head"])


def encdec_loss(params, batch, cfg: ModelConfig):
    from .model import cross_entropy

    memory = encode(params, batch["enc_embeds"].astype(jnp.dtype(cfg.dtype)), cfg)
    logits = decode_train(params, batch["tokens"], memory, cfg)
    ce = cross_entropy(logits, batch["labels"])
    return ce, {"ce": ce, "loss": ce}


# ---------------------------------------------------------------------------
# serving path
# ---------------------------------------------------------------------------


def init_cache(cfg: ModelConfig, batch_size: int, max_len: int, dtype=jnp.bfloat16):
    hd = cfg.resolved_head_dim
    n_dec = cfg.n_dec_layers or cfg.n_layers
    # cross-attention K/V are filled by ``precompute_cross`` after encoding
    return {
        "k": jnp.zeros((n_dec, batch_size, max_len, cfg.n_kv_heads, hd), dtype),
        "v": jnp.zeros((n_dec, batch_size, max_len, cfg.n_kv_heads, hd), dtype),
        "cross_k": None,
        "cross_v": None,
    }


def precompute_cross(params, memory: jax.Array, cfg: ModelConfig):
    """Stacked cross-attention K/V from encoder memory (computed once)."""
    b, sk, _ = memory.shape
    hd = cfg.resolved_head_dim

    def one(lp):
        k = linear(memory, lp["cross"]["wk"]).reshape(b, sk, cfg.n_kv_heads, hd)
        v = linear(memory, lp["cross"]["wv"]).reshape(b, sk, cfg.n_kv_heads, hd)
        return k, v

    ks, vs = jax.vmap(one)(params["dec_layers"])
    return ks, vs


def decode_step(params, token: jax.Array, cache, cache_len, cfg: ModelConfig):
    x = embed(token[:, None], params["embed"], jnp.dtype(cfg.dtype))
    b = x.shape[0]
    hd = cfg.resolved_head_dim

    def block(h, inp):
        lp, kc, vc, xk, xv = inp
        hh = rms_norm(h, lp["ln1"]["scale"], cfg.norm_eps)
        a, new_c = attn.gqa_decode(hh, lp["attn"], cfg, {"k": kc, "v": vc}, cache_len)
        h = h + a
        hh = rms_norm(h, lp["lnx"]["scale"], cfg.norm_eps)
        q = linear(hh, lp["cross"]["wq"]).reshape(b, 1, cfg.n_heads, hd)
        a = attn._sdpa(q, xk, xv, None, 1.0 / jnp.sqrt(float(hd)))
        h = h + linear(a.reshape(b, 1, -1), lp["cross"]["wo"])
        hh = rms_norm(h, lp["ln2"]["scale"], cfg.norm_eps)
        h = h + gelu_mlp(hh, lp["mlp"])
        return h, (new_c["k"], new_c["v"])

    (x, (new_k, new_v)) = _scan_with_cache(
        block, x, params["dec_layers"], cache["k"], cache["v"],
        cache["cross_k"], cache["cross_v"],
    )
    h = rms_norm(x, params["ln_dec"]["scale"], cfg.norm_eps)
    logits = linear(h, params["head"])[:, 0]
    new_cache = dict(cache)
    new_cache["k"] = new_k
    new_cache["v"] = new_v
    return logits, new_cache


def _scan_with_cache(block, x, layers, kc, vc, xk, xv):
    def step(h, inp):
        h, (nk, nv) = block(h, inp)
        return h, (nk, nv)

    x, (new_k, new_v) = jax.lax.scan(step, x, (layers, kc, vc, xk, xv))
    return x, (new_k, new_v)
