"""Shared layers: norms, embeddings, MLPs, rotary embeddings (incl. M-RoPE).

Parameters are plain pytrees (dicts of arrays); init functions take an RNG
key and return the pytree, so the whole model works under ``jax.eval_shape``
for the allocation-free dry-run.
"""

from __future__ import annotations

import jax
import jax.numpy as jnp
import numpy as np


def rms_norm(x: jax.Array, scale: jax.Array, eps: float = 1e-6) -> jax.Array:
    dtype = x.dtype
    x = x.astype(jnp.float32)
    var = jnp.mean(jnp.square(x), axis=-1, keepdims=True)
    out = x * jax.lax.rsqrt(var + eps)
    return (out * (1.0 + scale.astype(jnp.float32))).astype(dtype)


def init_rms_norm(d: int, dtype=jnp.float32) -> dict:
    return {"scale": jnp.zeros((d,), dtype)}


def linear(x: jax.Array, p: dict) -> jax.Array:
    out = x @ p["w"].astype(x.dtype)
    if "b" in p:
        out = out + p["b"].astype(x.dtype)
    return out


def init_linear(key, d_in: int, d_out: int, bias: bool = False,
                dtype=jnp.float32, scale: float | None = None) -> dict:
    std = float(scale) if scale is not None else float(1.0 / np.sqrt(d_in))
    p = {"w": jax.random.normal(key, (d_in, d_out), dtype) * std}
    if bias:
        p["b"] = jnp.zeros((d_out,), dtype)
    return p


def swiglu(x: jax.Array, p: dict) -> jax.Array:
    """SwiGLU MLP: (silu(x W_g) ⊙ x W_u) W_d — the LM-family standard."""
    g = jax.nn.silu(x @ p["wg"].astype(x.dtype))
    u = x @ p["wu"].astype(x.dtype)
    return (g * u) @ p["wd"].astype(x.dtype)


def init_swiglu(key, d_model: int, d_ff: int, dtype=jnp.float32) -> dict:
    k1, k2, k3 = jax.random.split(key, 3)
    s_in = float(1.0 / np.sqrt(d_model))
    s_out = float(1.0 / np.sqrt(d_ff))
    return {
        "wg": jax.random.normal(k1, (d_model, d_ff), dtype) * s_in,
        "wu": jax.random.normal(k2, (d_model, d_ff), dtype) * s_in,
        "wd": jax.random.normal(k3, (d_ff, d_model), dtype) * s_out,
    }


def gelu_mlp(x: jax.Array, p: dict) -> jax.Array:
    """GELU MLP (seamless-m4t / classic transformer FFN)."""
    h = jax.nn.gelu(x @ p["w1"].astype(x.dtype) + p["b1"].astype(x.dtype))
    return h @ p["w2"].astype(x.dtype) + p["b2"].astype(x.dtype)


def init_gelu_mlp(key, d_model: int, d_ff: int, dtype=jnp.float32) -> dict:
    k1, k2 = jax.random.split(key)
    return {
        "w1": jax.random.normal(k1, (d_model, d_ff), dtype) / float(np.sqrt(d_model)),
        "b1": jnp.zeros((d_ff,), dtype),
        "w2": jax.random.normal(k2, (d_ff, d_model), dtype) / float(np.sqrt(d_ff)),
        "b2": jnp.zeros((d_model,), dtype),
    }


def init_embedding(key, vocab: int, d_model: int, dtype=jnp.float32) -> dict:
    return {"table": jax.random.normal(key, (vocab, d_model), dtype) * 0.02}


def embed(tokens: jax.Array, p: dict, dtype=jnp.bfloat16) -> jax.Array:
    return p["table"].astype(dtype)[tokens]


# ---------------------------------------------------------------------------
# Rotary position embeddings
# ---------------------------------------------------------------------------


def rope_freqs(head_dim: int, theta: float) -> jax.Array:
    """Inverse frequencies for standard RoPE (half the head dim)."""
    exponents = jnp.arange(0, head_dim, 2, dtype=jnp.float32) / head_dim
    return 1.0 / (theta ** exponents)


def apply_rope(x: jax.Array, positions: jax.Array, theta: float) -> jax.Array:
    """x: (..., seq, heads, head_dim); positions: (..., seq)."""
    head_dim = x.shape[-1]
    inv = rope_freqs(head_dim, theta)  # (hd/2,)
    angles = positions[..., None].astype(jnp.float32) * inv  # (..., seq, hd/2)
    cos = jnp.cos(angles)[..., None, :]
    sin = jnp.sin(angles)[..., None, :]
    x1, x2 = jnp.split(x.astype(jnp.float32), 2, axis=-1)
    out = jnp.concatenate([x1 * cos - x2 * sin, x1 * sin + x2 * cos], axis=-1)
    return out.astype(x.dtype)


def apply_mrope(
    x: jax.Array,
    positions: jax.Array,       # (..., seq, 3) — (t, h, w) position triplets
    theta: float,
    sections: tuple[int, ...],  # splits of head_dim/2 across (t, h, w)
) -> jax.Array:
    """Multimodal RoPE (Qwen2-VL): rotary sections keyed by 3-D positions.

    Text tokens carry t=h=w so M-RoPE degenerates to standard RoPE on them —
    property-tested in tests/test_models.py.
    """
    head_dim = x.shape[-1]
    half = head_dim // 2
    assert sum(sections) == half, (sections, half)
    inv = rope_freqs(head_dim, theta)  # (half,)
    # angle per frequency using the section's coordinate
    sect_id = np.concatenate(
        [np.full(s, i) for i, s in enumerate(sections)]
    )  # (half,) in {0,1,2}
    sect_id = jnp.asarray(sect_id, jnp.int32)
    pos_per_freq = jnp.take_along_axis(
        positions.astype(jnp.float32),
        jnp.broadcast_to(sect_id, positions.shape[:-1] + (half,)),
        axis=-1,
    )  # (..., seq, half)
    angles = pos_per_freq * inv
    cos = jnp.cos(angles)[..., None, :]
    sin = jnp.sin(angles)[..., None, :]
    x1, x2 = jnp.split(x.astype(jnp.float32), 2, axis=-1)
    out = jnp.concatenate([x1 * cos - x2 * sin, x1 * sin + x2 * cos], axis=-1)
    return out.astype(x.dtype)
