"""Model facade: init / train forward / prefill / decode for all 10 archs.

``build_model(cfg)`` returns a :class:`Model` whose methods are pure
functions of (params, batch) — they trace under ``jax.eval_shape`` (the
allocation-free dry-run), ``jax.jit`` with shardings, and plain CPU eval for
smoke tests.

Batch conventions
-----------------
train   {"tokens": (B,S) i32, "labels": (B,S) i32}
        vlm/audio stubs add {"embeds": (B,S,D)} (+ {"positions_3d": (B,S,3)}
        for M-RoPE); encdec uses {"enc_embeds": (B,Se,D), "tokens": (B,Sd),
        "labels": (B,Sd)}.
prefill same inputs, returns (last_logits, cache)
decode  {"token": (B,) i32} + cache + cache_len → (logits, new_cache)
"""

from __future__ import annotations

import functools
from dataclasses import dataclass
from typing import Any

import jax
import jax.numpy as jnp
import numpy as np

from ..configs.base import ModelConfig
from . import attention as attn
from . import encdec as encdec_mod
from . import rglru as rglru_mod
from . import ssm as ssm_mod
from . import transformer as tfm
from .layers import embed, init_embedding, init_linear, init_rms_norm, linear, rms_norm


def _dtype(cfg: ModelConfig):
    return jnp.dtype(cfg.dtype)


def _pdtype(cfg: ModelConfig):
    return jnp.dtype(cfg.param_dtype)


def cross_entropy(logits: jax.Array, labels: jax.Array) -> jax.Array:
    logits = logits.astype(jnp.float32)
    logz = jax.nn.logsumexp(logits, axis=-1)
    ll = jnp.take_along_axis(logits, labels[..., None], axis=-1)[..., 0]
    return jnp.mean(logz - ll)


@dataclass(frozen=True)
class Model:
    cfg: ModelConfig

    # ---------------- init ----------------

    def init(self, key) -> dict:
        cfg = self.cfg
        dt = _pdtype(cfg)
        ks = jax.random.split(key, 8)
        params: dict[str, Any] = {}
        if cfg.family == "encdec":
            return encdec_mod.init_encdec(key, cfg, dt)
        params["embed"] = init_embedding(ks[0], cfg.vocab, cfg.d_model, dt)
        params["ln_f"] = init_rms_norm(cfg.d_model, dt)
        if not cfg.tie_embeddings:
            params["head"] = init_linear(ks[1], cfg.d_model, cfg.vocab, False, dt)

        if cfg.family in ("dense", "vlm"):
            params["layers"] = tfm.init_stack(
                ks[2], cfg.n_layers, lambda k: tfm.init_dense_layer(k, cfg, dt)
            )
        elif cfg.family == "moe":
            nd = cfg.moe.first_dense_layers
            if nd:
                dense_cfg = self._dense_ffn_cfg()
                params["dense_layers"] = tfm.init_stack(
                    ks[2], nd, lambda k: tfm.init_dense_layer(k, dense_cfg, dt)
                )
            params["moe_layers"] = tfm.init_stack(
                ks[3], cfg.n_layers - nd, lambda k: tfm.init_moe_layer(k, cfg, dt)
            )
            if cfg.mtp:
                params["mtp"] = {
                    "proj": init_linear(ks[4], 2 * cfg.d_model, cfg.d_model, False, dt),
                    "ln_h": init_rms_norm(cfg.d_model, dt),
                    "ln_e": init_rms_norm(cfg.d_model, dt),
                    "block": tfm.init_dense_layer(ks[5], self._dense_ffn_cfg(), dt),
                }
        elif cfg.family == "ssm":
            params["layers"] = tfm.init_stack(
                ks[2], cfg.n_layers, lambda k: tfm.init_ssm_layer(k, cfg, dt)
            )
        elif cfg.family == "hybrid":
            nsuper, tail = divmod(cfg.n_layers, len(cfg.hybrid.pattern))
            params["super"] = {
                "rec_a": tfm.init_stack(
                    ks[2], nsuper, lambda k: tfm.init_hybrid_sublayer(k, cfg, "rec", dt)
                ),
                "rec_b": tfm.init_stack(
                    ks[3], nsuper, lambda k: tfm.init_hybrid_sublayer(k, cfg, "rec", dt)
                ),
                "attn": tfm.init_stack(
                    ks[4], nsuper, lambda k: tfm.init_hybrid_sublayer(k, cfg, "attn", dt)
                ),
            }
            params["tail"] = [
                tfm.init_hybrid_sublayer(jax.random.fold_in(ks[5], i), cfg, "rec", dt)
                for i in range(tail)
            ]
        else:
            raise ValueError(f"unknown family {cfg.family}")
        return params

    def _dense_ffn_cfg(self) -> ModelConfig:
        from dataclasses import replace

        d_ff = self.cfg.moe.d_ff_dense or self.cfg.d_ff
        return replace(self.cfg, d_ff=d_ff)

    # ---------------- embedding / head ----------------

    def _embed_in(self, params, batch) -> jax.Array:
        cfg = self.cfg
        if "embeds" in batch:
            x = batch["embeds"].astype(_dtype(cfg))
        else:
            x = embed(batch["tokens"], params["embed"], _dtype(cfg))
        return x * cfg.scale_emb if cfg.scale_emb != 1.0 else x

    def _head(self, params, h: jax.Array) -> jax.Array:
        cfg = self.cfg
        if cfg.scale_depth > 0:  # minicpm μP output scaling
            h = h / (cfg.d_model / cfg.dim_model_base)
        if cfg.tie_embeddings:
            return h @ params["embed"]["table"].astype(h.dtype).T
        return linear(h, params["head"])

    # ---------------- backbone ----------------

    def _backbone(self, params, x: jax.Array, batch) -> tuple[jax.Array, jax.Array]:
        """Returns (hidden, aux_loss)."""
        cfg = self.cfg
        aux = jnp.float32(0.0)
        if cfg.family in ("dense", "vlm"):
            mrope_pos = batch.get("positions_3d") if cfg.mrope else None
            block = functools.partial(tfm.dense_block, cfg=cfg, mrope_positions=mrope_pos)
            x = tfm.scan_stack(x, params["layers"], block, cfg.remat)
        elif cfg.family == "moe":
            if "dense_layers" in params:
                dense_cfg = self._dense_ffn_cfg()
                block = functools.partial(tfm.dense_block, cfg=dense_cfg)
                x = tfm.scan_stack(x, params["dense_layers"], block, cfg.remat)
            block = functools.partial(tfm.moe_block, cfg=cfg)
            fn = jax.checkpoint(block) if cfg.remat else block

            def step(carry, lp):
                return fn(carry, lp), None

            (x, aux), _ = jax.lax.scan(step, (x, aux), params["moe_layers"])
        elif cfg.family == "ssm":
            block = functools.partial(tfm.ssm_block, cfg=cfg)
            x = tfm.scan_stack(x, params["layers"], block, cfg.remat)
        elif cfg.family == "hybrid":
            def superblock(h, lp):
                h = tfm.hybrid_sublayer(h, lp["rec_a"], cfg, "rec")
                h = tfm.hybrid_sublayer(h, lp["rec_b"], cfg, "rec")
                h = tfm.hybrid_sublayer(h, lp["attn"], cfg, "attn")
                return h

            x = tfm.scan_stack(x, params["super"], superblock, cfg.remat)
            for tp in params["tail"]:
                x = tfm.hybrid_sublayer(x, tp, cfg, "rec")
        else:
            raise ValueError(cfg.family)
        return x, aux

    # ---------------- train ----------------

    def loss(self, params, batch) -> tuple[jax.Array, dict]:
        cfg = self.cfg
        if cfg.family == "encdec":
            return encdec_mod.encdec_loss(params, batch, cfg)
        x = self._embed_in(params, batch)
        h, aux = self._backbone(params, x, batch)
        h = rms_norm(h, params["ln_f"]["scale"], cfg.norm_eps)
        logits = self._head(params, h)
        ce = cross_entropy(logits, batch["labels"])
        total = ce + aux
        metrics = {"ce": ce, "aux": aux}
        if cfg.mtp and "mtp" in params:
            mtp = params["mtp"]
            emb_next = embed(batch["labels"], params["embed"], h.dtype)
            merged = jnp.concatenate(
                [
                    rms_norm(h, mtp["ln_h"]["scale"], cfg.norm_eps),
                    rms_norm(emb_next, mtp["ln_e"]["scale"], cfg.norm_eps),
                ],
                axis=-1,
            )
            h2 = linear(merged, mtp["proj"])
            h2 = tfm.dense_block(h2, mtp["block"], self._dense_ffn_cfg())
            logits2 = self._head(params, h2)
            # MTP predicts token t+2: shift labels left by one
            mtp_labels = jnp.concatenate(
                [batch["labels"][:, 1:], batch["labels"][:, -1:]], axis=1
            )
            mtp_ce = cross_entropy(logits2[:, :-1], mtp_labels[:, :-1])
            total = total + 0.3 * mtp_ce
            metrics["mtp_ce"] = mtp_ce
        metrics["loss"] = total
        return total, metrics

    # ---------------- serving: cache init / prefill / decode ----------------

    def init_cache(self, batch_size: int, max_len: int, dtype=jnp.bfloat16) -> Any:
        cfg = self.cfg
        hd = cfg.resolved_head_dim
        if cfg.family == "encdec":
            return encdec_mod.init_cache(cfg, batch_size, max_len, dtype)
        if cfg.family in ("dense", "vlm") or (
            cfg.family == "moe" and cfg.attn_type != "mla"
        ):
            kh = cfg.n_kv_heads * cfg.kv_replicate
            kv = lambda n: {
                "k": jnp.zeros((n, batch_size, max_len, kh, hd), dtype),
                "v": jnp.zeros((n, batch_size, max_len, kh, hd), dtype),
            }
            if cfg.family == "moe":
                nd = cfg.moe.first_dense_layers
                return {"dense": kv(nd) if nd else None, "moe": kv(cfg.n_layers - nd)}
            return kv(cfg.n_layers)
        if cfg.family == "moe":  # MLA compressed cache
            m = cfg.mla
            nd = cfg.moe.first_dense_layers
            mk = lambda n: {
                "c_kv": jnp.zeros((n, batch_size, max_len, m.kv_lora_rank), dtype),
                "k_rope": jnp.zeros(
                    (n, batch_size, max_len, 1, m.qk_rope_head_dim), dtype
                ),
            }
            return {"dense": mk(nd) if nd else None, "moe": mk(cfg.n_layers - nd)}
        if cfg.family == "ssm":
            d_inner, h, p_, g, n = ssm_mod._dims(cfg)
            conv_dim = d_inner + 2 * g * n
            return {
                "state": jnp.zeros((cfg.n_layers, batch_size, h, p_, n), jnp.float32),
                "conv": jnp.zeros(
                    (cfg.n_layers, batch_size, cfg.ssm.d_conv - 1, conv_dim), dtype
                ),
            }
        if cfg.family == "hybrid":
            nsuper, tail = divmod(cfg.n_layers, len(cfg.hybrid.pattern))
            w = cfg.hybrid.lru_width or cfg.d_model
            cw = cfg.hybrid.conv_width
            window = min(cfg.hybrid.window, max_len)
            rec = lambda n: {
                "h": jnp.zeros((n, batch_size, w), jnp.float32),
                "conv": jnp.zeros((n, batch_size, cw - 1, w), dtype),
            }
            return {
                "rec_a": rec(nsuper),
                "rec_b": rec(nsuper),
                "attn": {
                    "k": jnp.zeros((nsuper, batch_size, window, cfg.n_kv_heads, hd), dtype),
                    "v": jnp.zeros((nsuper, batch_size, window, cfg.n_kv_heads, hd), dtype),
                },
                "tail": rec(tail),
            }
        raise ValueError(cfg.family)

    def decode_step(self, params, token: jax.Array, cache, cache_len):
        """One decode step.  token: (B,) i32 (or {"embeds": (B,1,D)} for stubs)."""
        cfg = self.cfg
        if cfg.family == "encdec":
            return encdec_mod.decode_step(params, token, cache, cache_len, cfg)
        x = embed(token[:, None], params["embed"], _dtype(cfg))
        if cfg.scale_emb != 1.0:
            x = x * cfg.scale_emb

        if cfg.family in ("dense", "vlm"):
            block = lambda h, lp, lc: tfm.dense_block_decode(h, lp, cfg, lc, cache_len)
            x, cache = tfm.scan_stack_decode(x, params["layers"], cache, block)
        elif cfg.family == "moe":
            new_cache = dict(cache)
            if "dense_layers" in params:
                dense_cfg = self._dense_ffn_cfg()
                block = lambda h, lp, lc: tfm.dense_block_decode(
                    h, lp, dense_cfg, lc, cache_len
                )
                x, new_cache["dense"] = tfm.scan_stack_decode(
                    x, params["dense_layers"], cache["dense"], block
                )
            block = lambda h, lp, lc: tfm.moe_block_decode(h, lp, cfg, lc, cache_len)
            x, new_cache["moe"] = tfm.scan_stack_decode(
                x, params["moe_layers"], cache["moe"], block
            )
            cache = new_cache
        elif cfg.family == "ssm":
            block = lambda h, lp, lc: tfm.ssm_block_decode(h, lp, cfg, lc)
            x, cache = tfm.scan_stack_decode(x, params["layers"], cache, block)
        elif cfg.family == "hybrid":
            new_cache = dict(cache)

            def superblock(h, lp, lc):
                h, ca = tfm.hybrid_sublayer_decode(h, lp["rec_a"], cfg, "rec", lc["rec_a"], cache_len)
                h, cb = tfm.hybrid_sublayer_decode(h, lp["rec_b"], cfg, "rec", lc["rec_b"], cache_len)
                h, cc = tfm.hybrid_sublayer_decode(h, lp["attn"], cfg, "attn", lc["attn"], cache_len)
                return h, {"rec_a": ca, "rec_b": cb, "attn": cc}

            stacked_cache = {
                "rec_a": cache["rec_a"], "rec_b": cache["rec_b"], "attn": cache["attn"]
            }
            x, sc = tfm.scan_stack_decode(x, params["super"], stacked_cache, superblock)
            new_cache.update(sc)
            tail_cache = []
            for i, tp in enumerate(params["tail"]):
                lc = jax.tree.map(lambda a: a[i], cache["tail"])
                x, lc = tfm.hybrid_sublayer_decode(x, tp, cfg, "rec", lc, cache_len)
                tail_cache.append(lc)
            if tail_cache:
                new_cache["tail"] = jax.tree.map(
                    lambda *xs: jnp.stack(xs), *tail_cache
                )
            cache = new_cache
        else:
            raise ValueError(cfg.family)

        h = rms_norm(x, params["ln_f"]["scale"], cfg.norm_eps)
        logits = self._head(params, h)[:, 0]
        return logits, cache


def build_model(cfg: ModelConfig) -> Model:
    return Model(cfg)
