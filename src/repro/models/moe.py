"""Mixture-of-Experts layer — GShard/Switch-style dense dispatch (TPU/GSPMD).

Token-choice top-k routing with capacity, einsum dispatch/combine (the
MaxText/GShard lowering that XLA SPMD partitions cleanly over the expert
axis), optional shared experts (DeepSeek-V3: 1 shared + 256 routed top-8;
Llama-4 Scout: 1 shared + 16 routed top-1), and the standard load-balancing
auxiliary loss.
"""

from __future__ import annotations

import math

import jax
import jax.numpy as jnp
import numpy as np

from ..configs.base import ModelConfig
from .layers import init_swiglu, swiglu


def init_moe(key, cfg: ModelConfig, dtype=jnp.float32) -> dict:
    m = cfg.moe
    ks = jax.random.split(key, 5)
    e = m.n_experts
    d, f = cfg.d_model, m.d_ff_expert
    s_in, s_out = float(1.0 / np.sqrt(d)), float(1.0 / np.sqrt(f))
    p = {
        "router": jax.random.normal(ks[0], (d, e), dtype) * s_in,
        "wg": jax.random.normal(ks[1], (e, d, f), dtype) * s_in,
        "wu": jax.random.normal(ks[2], (e, d, f), dtype) * s_in,
        "wd": jax.random.normal(ks[3], (e, f, d), dtype) * s_out,
    }
    if m.n_shared:
        p["shared"] = init_swiglu(ks[4], d, m.d_ff_expert * m.n_shared, dtype)
    return p


def _top_k_gating(logits: jax.Array, k: int):
    """Top-k gates normalised over the selected experts (DeepSeek-V3 style)."""
    probs = jax.nn.softmax(logits.astype(jnp.float32), axis=-1)
    gate_vals, idx = jax.lax.top_k(probs, k)
    gate_vals = gate_vals / jnp.sum(gate_vals, axis=-1, keepdims=True)
    return probs, gate_vals, idx


def moe_layer(
    x: jax.Array,  # (B, S, D)
    p: dict,
    cfg: ModelConfig,
    capacity_factor: float = 1.25,
) -> tuple[jax.Array, jax.Array]:
    """Returns (output, aux_loss).  Dense dispatch: FLOPs ∝ top_k·T·d·f + dispatch."""
    if cfg.moe_impl == "a2a":
        out = moe_layer_a2a(x, p, cfg, capacity_factor)
        if out is not None:
            return out
    if cfg.moe_group_size > 0:
        return moe_layer_grouped(x, p, cfg, capacity_factor)
    m = cfg.moe
    b, s, d = x.shape
    t = b * s
    e, k = m.n_experts, m.top_k
    xt = x.reshape(t, d)

    logits = xt.astype(jnp.float32) @ p["router"].astype(jnp.float32)
    probs, gates, idx = _top_k_gating(logits, k)  # (T,E), (T,k), (T,k)

    capacity = max(1, int(math.ceil(t * k / e * capacity_factor)))
    # slot-major positions: slot 0 choices get priority (GShard ordering)
    onehot = jax.nn.one_hot(idx, e, dtype=jnp.int32)          # (T, k, E)
    slot_major = jnp.swapaxes(onehot, 0, 1)                   # (k, T, E)
    pos_in_expert = jnp.cumsum(slot_major.reshape(k * t, e), axis=0).reshape(
        k, t, e
    ) - slot_major
    pos = jnp.sum(pos_in_expert * slot_major, axis=-1)        # (k, T)
    expert_of_slot = jnp.swapaxes(idx, 0, 1)                  # (k, T)
    keep = pos < capacity
    gates_km = jnp.swapaxes(gates, 0, 1) * keep.astype(jnp.float32)  # (k, T)

    # dispatch/combine tensors (T, E, C)
    pos_onehot = jax.nn.one_hot(pos, capacity, dtype=jnp.float32) * keep[..., None]
    disp = jnp.einsum(
        "kte,ktc->tec", slot_major.astype(jnp.float32), pos_onehot
    )
    comb = jnp.einsum(
        "kte,ktc,kt->tec", slot_major.astype(jnp.float32), pos_onehot, gates_km
    )

    xin = jnp.einsum("tec,td->ecd", disp.astype(x.dtype), xt)        # (E, C, D)
    g = jax.nn.silu(jnp.einsum("ecd,edf->ecf", xin, p["wg"].astype(x.dtype)))
    u = jnp.einsum("ecd,edf->ecf", xin, p["wu"].astype(x.dtype))
    hexp = jnp.einsum("ecf,efd->ecd", g * u, p["wd"].astype(x.dtype))  # (E, C, D)
    y = jnp.einsum("tec,ecd->td", comb.astype(x.dtype), hexp)

    if m.n_shared:
        y = y + swiglu(xt, p["shared"])

    # load-balance aux loss (Switch): E · Σ_e fraction_e · router_prob_e
    frac = jnp.mean(
        jnp.sum(onehot.astype(jnp.float32), axis=1), axis=0
    )  # (E,) fraction of tokens routed
    prob_mean = jnp.mean(probs, axis=0)
    aux = e * jnp.sum(frac * prob_mean) * m.aux_loss_coef

    del expert_of_slot
    return y.reshape(b, s, d), aux


def moe_layer_grouped(
    x: jax.Array,  # (B, S, D)
    p: dict,
    cfg: ModelConfig,
    capacity_factor: float = 1.25,
) -> tuple[jax.Array, jax.Array]:
    """§Perf hillclimb variant: GShard *group-blocked* dispatch.

    The naive dispatch materialises a (T, E, C) tensor with C ∝ T — at
    train_4k/deepseek-v3 scale that is the 10 TB temp / 489 TB all-reduce
    pathology in the baseline dry-run.  Blocking tokens into groups of
    ``Tg = cfg.moe_group_size`` makes per-group capacity Cg ∝ Tg (constant),
    so dispatch tensors are (G, Tg, E, Cg) — G·Tg·E·Cg = T·E·Cg elements,
    ~T/Tg× smaller — and shard cleanly: G on the DP axes, E on "model" (EP);
    one-hots are bf16 so the dispatch einsums run on the MXU.
    """
    m = cfg.moe
    b, s, d = x.shape
    t = b * s
    e, k = m.n_experts, m.top_k
    tg = min(cfg.moe_group_size, t)
    assert t % tg == 0, (t, tg)
    g = t // tg
    cap = max(1, int(math.ceil(tg * k / e * capacity_factor)))
    dt = x.dtype

    from jax.sharding import PartitionSpec as _P

    def wsc(v, spec):
        try:
            return jax.lax.with_sharding_constraint(v, _P(*spec))
        except Exception:  # no ambient mesh (CPU smoke tests): no-op
            return v

    def _mesh_axes_for(dim: int, include_model: bool = True):
        """Largest axis prefix whose product divides ``dim``."""
        try:
            mesh = jax.sharding.get_abstract_mesh()
            names = tuple(mesh.axis_names) if mesh is not None else ()
        except Exception:
            return None
        pool = ("pod", "data", "model") if include_model else ("pod", "data")
        avail = [n for n in pool if n in names]
        best = None
        for kk in range(1, len(avail) + 1):
            prod = 1
            for a in avail[:kk]:
                prod *= mesh.shape[a]
            if dim % prod == 0:
                best = tuple(avail[:kk])
        return best

    # Full-mesh expert parallelism: experts spread over every mesh axis
    # (256 experts / 256 chips ⇒ 1 expert per chip) — expert weights need no
    # inner-dim sharding, so no partial-sum all-reduces and no FSDP
    # regathers; the groups→experts hop is the classic MoE all-to-all of
    # (E, G, Cg, D) activations (small).  Groups stay on the DP axes —
    # pinned explicitly: GSPMD loses the batch sharding through the
    # (B,S,D)→(G,Tg,D) reshape and falls back to full replication otherwise.
    eax = _mesh_axes_for(e)
    gax = _mesh_axes_for(g, include_model=False)  # groups ride the DP axes
    xg = x.reshape(g, tg, d)
    if gax:
        xg = wsc(xg, (gax, None, None))
    logits = xg.astype(jnp.float32) @ p["router"].astype(jnp.float32)  # (G,Tg,E)
    probs = jax.nn.softmax(logits, axis=-1)
    gates, idx = jax.lax.top_k(probs, k)                               # (G,Tg,k)
    gates = gates / jnp.sum(gates, axis=-1, keepdims=True)

    onehot = jax.nn.one_hot(idx, e, dtype=jnp.int32)                   # (G,Tg,k,E)
    slot_major = jnp.moveaxis(onehot, 2, 1)                            # (G,k,Tg,E)
    flat = slot_major.reshape(g, k * tg, e)
    pos = jnp.cumsum(flat, axis=1) - flat                              # pos within (g,e)
    pos = jnp.sum(pos.reshape(g, k, tg, e) * slot_major, axis=-1)      # (G,k,Tg)
    keep = pos < cap
    gates_km = jnp.moveaxis(gates, 2, 1) * keep.astype(jnp.float32)    # (G,k,Tg)
    pos_oh = jax.nn.one_hot(pos, cap, dtype=dt) * keep[..., None].astype(dt)

    disp = jnp.einsum("gkte,gktc->gtec", slot_major.astype(dt), pos_oh)
    comb = jnp.einsum(
        "gkte,gktc,gkt->gtec", slot_major.astype(dt), pos_oh, gates_km.astype(dt)
    )
    if gax:
        disp = wsc(disp, (gax, None, None, None))
        comb = wsc(comb, (gax, None, None, None))

    xin = jnp.einsum("gtec,gtd->egcd", disp, xg)                       # (E,G,Cg,D)
    if eax:
        xin = wsc(xin, (eax, None, None, None))  # → a2a onto expert shards
    gact = jax.nn.silu(jnp.einsum("egcd,edf->egcf", xin, p["wg"].astype(dt)))
    uact = jnp.einsum("egcd,edf->egcf", xin, p["wu"].astype(dt))
    hexp = jnp.einsum("egcf,efd->egcd", gact * uact, p["wd"].astype(dt))
    if eax:
        hexp = wsc(hexp, (eax, None, None, None))
    y = jnp.einsum("gtec,egcd->gtd", comb, hexp)

    if m.n_shared:
        y = y + swiglu(xg.reshape(t, d), p["shared"]).reshape(g, tg, d)

    frac = jnp.mean(jnp.sum(onehot.astype(jnp.float32), axis=2), axis=(0, 1))
    prob_mean = jnp.mean(probs, axis=(0, 1))
    aux = e * jnp.sum(frac * prob_mean) * m.aux_loss_coef
    return y.reshape(b, s, d), aux


# ---------------------------------------------------------------------------
# shard_map expert-parallel all-to-all MoE (§Perf — the production routing)
# ---------------------------------------------------------------------------


def moe_layer_a2a(
    x: jax.Array,  # (B, S, D)
    p: dict,
    cfg: ModelConfig,
    capacity_factor: float = 1.25,
):
    """Explicit expert-parallel MoE: local dispatch → all_to_all → local
    expert FFN → all_to_all → local combine (DeepSeek-V3's own EP layout).

    GSPMD cannot synthesise token-routing all-to-all from one-hot dispatch
    einsums — every auto-partitioning of them all-gathers activations (§Perf
    iteration log).  ``shard_map`` makes the routing explicit: per-device
    payloads are (E, C, D) send buffers (≈ top_k·T_loc·D·cf bytes), so the
    collective cost scales with *routed tokens*, not with tokens × experts.

    Requires E divisible over the ("data","model") mesh axes and T divisible
    by the device count; returns None to fall back to the einsum path
    otherwise (CPU tests, decode micro-batches).
    """
    from jax.experimental.shard_map import shard_map
    from jax.sharding import PartitionSpec as _P

    m = cfg.moe
    b, s, d = x.shape
    t = b * s
    e, k = m.n_experts, m.top_k
    try:
        mesh = jax.sharding.get_abstract_mesh()
        names = tuple(mesh.axis_names) if mesh is not None else ()
    except Exception:
        return None
    if not names:
        return None
    a2a_axes = tuple(n for n in ("data", "model") if n in names)
    n_a2a = 1
    for a in a2a_axes:
        n_a2a *= mesh.shape[a]
    all_axes = tuple(n for n in ("pod", "data", "model") if n in names)
    n_dev = 1
    for a in all_axes:
        n_dev *= mesh.shape[a]
    if e != n_a2a or t % n_dev != 0:
        return None

    t_loc = t // n_dev
    cap = max(1, int(math.ceil(t_loc * k / e * capacity_factor)))
    dt = x.dtype

    def local(x_loc, router_w, wg, wu, wd, shared):
        # x_loc: (T_loc, D); wg/wu/wd: (1, D, F)/(1, F, D) — one local expert
        logits = x_loc.astype(jnp.float32) @ router_w.astype(jnp.float32)
        probs = jax.nn.softmax(logits, axis=-1)                  # (T_loc, E)
        gates, idx = jax.lax.top_k(probs, k)
        gates = gates / jnp.sum(gates, axis=-1, keepdims=True)

        onehot = jax.nn.one_hot(idx, e, dtype=jnp.int32)         # (T_loc,k,E)
        slot_major = jnp.swapaxes(onehot, 0, 1)                  # (k,T_loc,E)
        flat = slot_major.reshape(k * t_loc, e)
        pos = (jnp.cumsum(flat, axis=0) - flat).reshape(k, t_loc, e)
        pos = jnp.sum(pos * slot_major, axis=-1)                 # (k,T_loc)
        keep = pos < cap
        gates_km = jnp.swapaxes(gates, 0, 1) * keep.astype(jnp.float32)
        pos_oh = jax.nn.one_hot(pos, cap, dtype=dt) * keep[..., None].astype(dt)

        disp = jnp.einsum("kte,ktc->tec", slot_major.astype(dt), pos_oh)
        comb = jnp.einsum("kte,ktc,kt->tec", slot_major.astype(dt), pos_oh,
                          gates_km.astype(dt))

        send = jnp.einsum("tec,td->ecd", disp, x_loc)            # (E, C, D)
        recv = jax.lax.all_to_all(
            send, a2a_axes, split_axis=0, concat_axis=0, tiled=True
        )                                                        # (E·1? → (E,C,D) rows for MY expert)
        h = recv.reshape(e * cap, d)
        g_act = jax.nn.silu(h @ wg[0].astype(dt))
        u_act = h @ wu[0].astype(dt)
        h_out = (g_act * u_act) @ wd[0].astype(dt)               # (E·C, D)
        back = jax.lax.all_to_all(
            h_out.reshape(e, cap, d), a2a_axes, split_axis=0, concat_axis=0,
            tiled=True,
        )                                                        # (E, C, D) back at source
        y = jnp.einsum("tec,ecd->td", comb, back)
        if m.n_shared:
            y = y + swiglu(x_loc, shared)
        frac = jnp.mean(jnp.sum(onehot.astype(jnp.float32), axis=1), axis=0)
        prob_mean = jnp.mean(probs, axis=0)
        aux = e * jnp.sum(frac * prob_mean) * m.aux_loss_coef
        aux = jax.lax.pmean(aux, all_axes)
        return y, aux

    shared = p.get("shared")
    if shared is None:
        shared = {"wg": jnp.zeros((d, 1), dt), "wu": jnp.zeros((d, 1), dt),
                  "wd": jnp.zeros((1, d), dt)}
    flat_spec = _P(all_axes)
    expert_spec = _P(a2a_axes, None, None)
    rep = _P()
    out = shard_map(
        local,
        mesh=mesh,
        in_specs=(
            _P(all_axes, None), rep, expert_spec, expert_spec,
            _P(a2a_axes, None, None), jax.tree.map(lambda _: rep, shared),
        ),
        out_specs=(_P(all_axes, None), rep),
        check_rep=False,
    )(x.reshape(t, d), p["router"], p["wg"], p["wu"], p["wd"], shared)
    y, aux = out
    return y.reshape(b, s, d), aux
