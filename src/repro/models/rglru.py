"""RG-LRU recurrent block (RecurrentGemma, arXiv:2402.19427).

Recurrence: h_t = a_t ⊙ h_{t−1} + √(1 − a_t²) ⊙ (i_t ⊙ x_t), with
a_t = exp(c · r_t · log σ(Λ)), r/i input gates.  A *linear* recurrence, so
training/prefill use ``jax.lax.associative_scan`` — O(log L) depth on TPU
(the natural TPU mapping of the paper's sequential iterative abstraction);
decode is a single fused step on the (B, W) state.

Block layout (the "recurrent block" of the paper): two branches —
gate branch (GeLU) and recurrence branch (causal conv1d → RG-LRU) — merged
multiplicatively, then an output projection.
"""

from __future__ import annotations

import jax
import jax.numpy as jnp
import numpy as np

from ..configs.base import ModelConfig
from .layers import init_linear, linear

_C = 8.0  # paper constant


def _lru_width(cfg: ModelConfig) -> int:
    return cfg.hybrid.lru_width or cfg.d_model


def init_rglru_block(key, cfg: ModelConfig, dtype=jnp.float32) -> dict:
    w = _lru_width(cfg)
    ks = jax.random.split(key, 6)
    # Λ init so that a ∈ [0.9, 0.999] (paper appendix)
    u = jax.random.uniform(ks[0], (w,), jnp.float32, 0.9**2, 0.999**2)
    lam = jnp.log(jnp.sqrt(u) / jnp.sqrt(1.0 - u))  # σ(Λ)=sqrt(u)
    return {
        "in_x": init_linear(ks[1], cfg.d_model, w, True, dtype),
        "in_gate": init_linear(ks[2], cfg.d_model, w, True, dtype),
        "conv_w": jax.random.normal(ks[3], (cfg.hybrid.conv_width, w), dtype) * 0.1,
        "conv_b": jnp.zeros((w,), dtype),
        "wr": init_linear(ks[4], w, w, True, dtype),
        "wi": init_linear(ks[5], w, w, True, dtype),
        "lam": lam.astype(dtype),
        "out": init_linear(jax.random.fold_in(key, 7), w, cfg.d_model, False, dtype),
    }


def _gates(x: jax.Array, p: dict) -> tuple[jax.Array, jax.Array]:
    """log a_t (f32) and gated input contribution."""
    r = jax.nn.sigmoid(linear(x, p["wr"]).astype(jnp.float32))
    i = jax.nn.sigmoid(linear(x, p["wi"]).astype(jnp.float32))
    log_a = _C * r * jax.nn.log_sigmoid(p["lam"].astype(jnp.float32))  # ≤ 0
    a = jnp.exp(log_a)
    beta = jnp.sqrt(jnp.maximum(1.0 - a * a, 1e-12))
    contrib = beta * (i * x.astype(jnp.float32))
    return a, contrib


def rglru_scan(x: jax.Array, p: dict) -> jax.Array:
    """(B, L, W) linear recurrence via associative_scan over (a, b) pairs."""
    a, contrib = _gates(x, p)

    def combine(left, right):
        a1, b1 = left
        a2, b2 = right
        return a1 * a2, a2 * b1 + b2

    aa, bb = jax.lax.associative_scan(combine, (a, contrib), axis=1)
    del aa
    return bb.astype(x.dtype)


def rglru_block(x: jax.Array, p: dict, cfg: ModelConfig) -> jax.Array:
    """Full recurrent block: conv1d + RG-LRU branch ⊙ GeLU gate branch."""
    cw = cfg.hybrid.conv_width
    gate = jax.nn.gelu(linear(x, p["in_gate"]))
    u = linear(x, p["in_x"])
    u_pad = jnp.pad(u, ((0, 0), (cw - 1, 0), (0, 0)))
    l = u.shape[1]
    conv = sum(
        u_pad[:, k : k + l, :] * p["conv_w"][k].astype(u.dtype)[None, None, :]
        for k in range(cw)
    ) + p["conv_b"].astype(u.dtype)[None, None, :]
    h = rglru_scan(conv, p)
    return linear(h * gate, p["out"])


def rglru_block_decode(
    x: jax.Array,      # (B, 1, D)
    p: dict,
    cfg: ModelConfig,
    cache: dict,       # {"h": (B, W) f32, "conv": (B, cw-1, W)}
) -> tuple[jax.Array, dict]:
    gate = jax.nn.gelu(linear(x, p["in_gate"]))
    u = linear(x, p["in_x"])[:, 0]  # (B, W)
    conv_buf = jnp.concatenate([cache["conv"], u[:, None, :].astype(cache["conv"].dtype)], axis=1)
    conv = (
        jnp.sum(conv_buf * p["conv_w"].astype(conv_buf.dtype)[None, :, :], axis=1)
        + p["conv_b"].astype(conv_buf.dtype)[None, :]
    )
    a, contrib = _gates(conv[:, None, :], p)
    h = a[:, 0] * cache["h"] + contrib[:, 0]
    y = linear((h[:, None, :].astype(x.dtype)) * gate, p["out"])
    return y, {"h": h, "conv": conv_buf[:, 1:, :]}
