"""Mamba-2 block — SSD (state-space duality) chunked algorithm.

Train/prefill use the chunked SSD form (arXiv:2405.21060 §6): intra-chunk
attention-like matmuls + an inter-chunk state recurrence — matmul-rich, so
the MXU does the work (the TPU-native choice; a token-sequential scan would
be VPU-serial).  Decode keeps the (H, P, N) state and does one
rank-1 update per token.

Shapes: x (B, L, D); inner D_i = expand·D split into H heads of P=head_dim;
B/C projections have G groups of state size N (GQA-like sharing).
"""

from __future__ import annotations

import jax
import jax.numpy as jnp
import numpy as np

from ..configs.base import ModelConfig
from .layers import init_linear, init_rms_norm, linear, rms_norm


def _dims(cfg: ModelConfig):
    s = cfg.ssm
    d_inner = s.expand * cfg.d_model
    n_heads = d_inner // s.head_dim
    return d_inner, n_heads, s.head_dim, s.n_groups, s.d_state


def init_mamba2(key, cfg: ModelConfig, dtype=jnp.float32) -> dict:
    s = cfg.ssm
    d_inner, h, p_, g, n = _dims(cfg)
    ks = jax.random.split(key, 4)
    d_in_proj = 2 * d_inner + 2 * g * n + h
    conv_dim = d_inner + 2 * g * n
    return {
        "in_proj": init_linear(ks[0], cfg.d_model, d_in_proj, False, dtype),
        "conv_w": jax.random.normal(ks[1], (s.d_conv, conv_dim), dtype) * 0.1,
        "conv_b": jnp.zeros((conv_dim,), dtype),
        "A_log": jnp.log(jnp.linspace(1.0, 16.0, h).astype(dtype)),
        "D": jnp.ones((h,), dtype),
        "dt_bias": jnp.log(jnp.expm1(jnp.full((h,), 0.01, jnp.float32))).astype(dtype),
        "norm": init_rms_norm(d_inner, dtype),
        "out_proj": init_linear(ks[2], d_inner, cfg.d_model, False, dtype),
    }


def _segsum(a: jax.Array) -> jax.Array:
    """(..., q) log-decays → (..., q, q) lower-tri segment sums (SSD helper)."""
    q = a.shape[-1]
    cum = jnp.cumsum(a, axis=-1)
    diff = cum[..., :, None] - cum[..., None, :]
    i = jax.lax.iota(jnp.int32, q)
    mask = i[:, None] >= i[None, :]
    return jnp.where(mask, diff, -jnp.inf)


def ssd_chunked(
    x: jax.Array,    # (B, L, H, P)
    dt: jax.Array,   # (B, L, H) — positive step sizes
    A: jax.Array,    # (H,) — negative decay rates
    Bm: jax.Array,   # (B, L, G, N)
    Cm: jax.Array,   # (B, L, G, N)
    chunk: int,
) -> jax.Array:
    b, l, h, p_ = x.shape
    g, n = Bm.shape[2], Bm.shape[3]
    rep = h // g
    pad = (-l) % chunk
    if pad:
        x = jnp.pad(x, ((0, 0), (0, pad), (0, 0), (0, 0)))
        dt = jnp.pad(dt, ((0, 0), (0, pad), (0, 0)))
        Bm = jnp.pad(Bm, ((0, 0), (0, pad), (0, 0), (0, 0)))
        Cm = jnp.pad(Cm, ((0, 0), (0, pad), (0, 0), (0, 0)))
    lq = x.shape[1]
    nc = lq // chunk
    q = chunk

    xc = x.reshape(b, nc, q, h, p_)
    dtc = dt.reshape(b, nc, q, h)
    Bc = Bm.reshape(b, nc, q, g, n)
    Cc = Cm.reshape(b, nc, q, g, n)
    Bh = jnp.repeat(Bc, rep, axis=3)  # (b,nc,q,h,n)
    Ch = jnp.repeat(Cc, rep, axis=3)

    a = dtc * A  # (b,nc,q,h) log decay per step (negative)
    a_hq = jnp.moveaxis(a, -1, 2)  # (b,nc,h,q)
    L = jnp.exp(_segsum(a_hq))     # (b,nc,h,q,q)

    dtx = xc * dtc[..., None]      # Δt·x

    # 1) intra-chunk (diagonal blocks): Y_d = (C Bᵀ ⊙ L) · (Δt X)
    cb = jnp.einsum("bzqhn,bzkhn->bzhqk", Ch, Bh)
    yd = jnp.einsum("bzhqk,bzhqk,bzkhp->bzqhp", cb, L, dtx)

    # 2) chunk-final states: S_z = Σ_j exp(Σ_{i>j} a_i) Δt x_j ⊗ B_j
    cum = jnp.cumsum(a_hq, axis=-1)
    decay_to_end = jnp.exp(cum[..., -1:] - cum)  # (b,nc,h,q)
    states = jnp.einsum(
        "bzhq,bzqhn,bzqhp->bzhpn", decay_to_end, Bh, dtx
    )

    # 3) inter-chunk recurrence over chunk states
    chunk_decay = jnp.exp(jnp.sum(a_hq, axis=-1))  # (b,nc,h)

    def step(s_prev, inp):
        st, dec = inp
        s_new = s_prev * dec[..., None, None] + st
        return s_new, s_prev

    init = jnp.zeros((b, h, p_, n), jnp.float32)
    _, s_before = jax.lax.scan(
        step,
        init,
        (jnp.moveaxis(states, 1, 0).astype(jnp.float32),
         jnp.moveaxis(chunk_decay, 1, 0).astype(jnp.float32)),
    )
    s_before = jnp.moveaxis(s_before, 0, 1)  # (b,nc,h,p,n) state entering chunk

    # 4) inter-chunk contribution: Y_off = C_t · exp(cum_t) · S_before
    decay_in = jnp.exp(cum)  # (b,nc,h,q)
    yoff = jnp.einsum(
        "bzqhn,bzhq,bzhpn->bzqhp", Ch, decay_in, s_before.astype(Ch.dtype)
    )

    y = (yd + yoff).reshape(b, lq, h, p_)
    return y[:, :l]


def mamba2_forward(x: jax.Array, p: dict, cfg: ModelConfig) -> jax.Array:
    """Full Mamba-2 mixer over a sequence (train/prefill path)."""
    s = cfg.ssm
    d_inner, h, p_, g, n = _dims(cfg)
    b, l, _ = x.shape
    zxbcdt = linear(x, p["in_proj"])
    z, xbc, dt = jnp.split(zxbcdt, [d_inner, 2 * d_inner + 2 * g * n], axis=-1)
    # causal depthwise conv over (x, B, C)
    xbc_pad = jnp.pad(xbc, ((0, 0), (s.d_conv - 1, 0), (0, 0)))
    idx = jax.lax.iota(jnp.int32, l)
    conv = sum(
        xbc_pad[:, k : k + l, :] * p["conv_w"][k][None, None, :]
        for k in range(s.d_conv)
    ) + p["conv_b"][None, None, :]
    del idx
    xbc = jax.nn.silu(conv)
    xs, Bm, Cm = jnp.split(xbc, [d_inner, d_inner + g * n], axis=-1)
    xs = xs.reshape(b, l, h, p_)
    Bm = Bm.reshape(b, l, g, n)
    Cm = Cm.reshape(b, l, g, n)
    dt = jax.nn.softplus(dt.astype(jnp.float32) + p["dt_bias"].astype(jnp.float32))
    A = -jnp.exp(p["A_log"].astype(jnp.float32))
    y = ssd_chunked(xs.astype(jnp.float32), dt, A, Bm.astype(jnp.float32),
                    Cm.astype(jnp.float32), s.chunk)
    y = y + xs.astype(jnp.float32) * p["D"].astype(jnp.float32)[None, None, :, None]
    y = y.reshape(b, l, d_inner).astype(x.dtype)
    y = rms_norm(y * jax.nn.silu(z), p["norm"]["scale"], cfg.norm_eps)
    return linear(y, p["out_proj"])


def mamba2_decode(
    x: jax.Array,       # (B, 1, D)
    p: dict,
    cfg: ModelConfig,
    cache: dict,        # {"state": (B,H,P,N) f32, "conv": (B, d_conv-1, conv_dim)}
) -> tuple[jax.Array, dict]:
    s = cfg.ssm
    d_inner, h, p_, g, n = _dims(cfg)
    b = x.shape[0]
    zxbcdt = linear(x, p["in_proj"])[:, 0]  # (B, ·)
    z, xbc, dt = jnp.split(zxbcdt, [d_inner, 2 * d_inner + 2 * g * n], axis=-1)
    conv_buf = jnp.concatenate([cache["conv"], xbc[:, None, :]], axis=1)
    conv = (
        jnp.sum(conv_buf * p["conv_w"][None, :, :], axis=1) + p["conv_b"][None, :]
    )
    xbc_t = jax.nn.silu(conv)
    xs, Bm, Cm = jnp.split(xbc_t, [d_inner, d_inner + g * n], axis=-1)
    xs = xs.reshape(b, h, p_).astype(jnp.float32)
    Bm = Bm.reshape(b, g, n).astype(jnp.float32)
    Cm = Cm.reshape(b, g, n).astype(jnp.float32)
    rep = h // g
    Bh = jnp.repeat(Bm, rep, axis=1)  # (B,H,N)
    Ch = jnp.repeat(Cm, rep, axis=1)
    dt = jax.nn.softplus(dt.astype(jnp.float32) + p["dt_bias"].astype(jnp.float32))
    A = -jnp.exp(p["A_log"].astype(jnp.float32))
    decay = jnp.exp(dt * A)  # (B,H)
    state = cache["state"] * decay[..., None, None] + jnp.einsum(
        "bhp,bhn,bh->bhpn", xs, Bh, dt
    )
    y = jnp.einsum("bhpn,bhn->bhp", state, Ch) + xs * p["D"].astype(jnp.float32)[None, :, None]
    y = y.reshape(b, 1, d_inner).astype(x.dtype)
    y = rms_norm(y * jax.nn.silu(z[:, None, :]), p["norm"]["scale"], cfg.norm_eps)
    out = linear(y, p["out_proj"])
    return out, {"state": state, "conv": conv_buf[:, 1:, :]}
