"""Decoder-only transformer assembly for all LM families.

Layer stacks are ``lax.scan`` over stacked per-layer params — bounded HLO
size and compile time at 512-way GSPMD partitioning (DESIGN.md §6); remat
(``jax.checkpoint``) wraps the block body when ``cfg.remat``.

Families:
  dense   — [GQA attn + SwiGLU] × L              (qwen*, minicpm, deepseek-67b, qwen2-vl)
  moe     — [attn + MoE-FFN] × L, optional leading dense layers (deepseek-v3, llama4)
  ssm     — [Mamba-2 mixer] × L                  (mamba2-370m)
  hybrid  — [(rec, rec, local-attn) superblock] × L/3 + remainder (recurrentgemma)
"""

from __future__ import annotations

import functools
from typing import Any

import jax
import jax.numpy as jnp
import numpy as np

from ..configs.base import ModelConfig
from . import attention as attn
from . import moe as moe_mod
from . import rglru as rglru_mod
from . import ssm as ssm_mod
from .layers import (
    embed,
    init_embedding,
    init_rms_norm,
    init_swiglu,
    rms_norm,
    swiglu,
)


def _res_scale(cfg: ModelConfig) -> float:
    """MiniCPM depth-scaled residuals (μP): scale_depth/√L; 1.0 otherwise."""
    if cfg.scale_depth > 0:
        return cfg.scale_depth / float(np.sqrt(cfg.n_layers))
    return 1.0


def _constrain(x, cfg: ModelConfig):
    """fsdp_dp: pin the residual stream to the DP axes (see sharding.py)."""
    if cfg.sharding_policy in ("fsdp_dp", "dp_zero1"):
        from ..runtime.sharding import constrain_activation_dp

        return constrain_activation_dp(x)
    return x


# ---------------------------------------------------------------------------
# per-layer blocks (forward + decode variants)
# ---------------------------------------------------------------------------


def init_dense_layer(key, cfg: ModelConfig, dtype=jnp.float32) -> dict:
    ks = jax.random.split(key, 4)
    init_attn = attn.init_mla if cfg.attn_type == "mla" else attn.init_gqa
    return {
        "ln1": init_rms_norm(cfg.d_model, dtype),
        "attn": init_attn(ks[0], cfg, dtype),
        "ln2": init_rms_norm(cfg.d_model, dtype),
        "mlp": init_swiglu(ks[1], cfg.d_model, cfg.d_ff, dtype),
    }


def dense_block(x, p, cfg: ModelConfig, mrope_positions=None):
    x = _constrain(x, cfg)
    s = _res_scale(cfg)
    h = rms_norm(x, p["ln1"]["scale"], cfg.norm_eps)
    if cfg.attn_type == "mla":
        a = attn.mla_attention(h, p["attn"], cfg)
    else:
        a = attn.gqa_attention(h, p["attn"], cfg, mrope_positions=mrope_positions)
    x = x + s * a
    h = rms_norm(x, p["ln2"]["scale"], cfg.norm_eps)
    x = x + s * swiglu(h, p["mlp"])
    return x


def dense_block_decode(x, p, cfg: ModelConfig, cache, cache_len):
    s = _res_scale(cfg)
    h = rms_norm(x, p["ln1"]["scale"], cfg.norm_eps)
    if cfg.attn_type == "mla":
        a, cache = attn.mla_decode(h, p["attn"], cfg, cache, cache_len)
    else:
        a, cache = attn.gqa_decode(h, p["attn"], cfg, cache, cache_len)
    x = x + s * a
    h = rms_norm(x, p["ln2"]["scale"], cfg.norm_eps)
    x = x + s * swiglu(h, p["mlp"])
    return x, cache


def init_moe_layer(key, cfg: ModelConfig, dtype=jnp.float32) -> dict:
    ks = jax.random.split(key, 2)
    init_attn = attn.init_mla if cfg.attn_type == "mla" else attn.init_gqa
    return {
        "ln1": init_rms_norm(cfg.d_model, dtype),
        "attn": init_attn(ks[0], cfg, dtype),
        "ln2": init_rms_norm(cfg.d_model, dtype),
        "moe": moe_mod.init_moe(ks[1], cfg, dtype),
    }


def moe_block(x_aux, p, cfg: ModelConfig):
    x, aux = x_aux
    x = _constrain(x, cfg)
    h = rms_norm(x, p["ln1"]["scale"], cfg.norm_eps)
    if cfg.attn_type == "mla":
        a = attn.mla_attention(h, p["attn"], cfg)
    else:
        a = attn.gqa_attention(h, p["attn"], cfg)
    x = x + a
    h = rms_norm(x, p["ln2"]["scale"], cfg.norm_eps)
    y, aux_l = moe_mod.moe_layer(h, p["moe"], cfg)
    return (x + y, aux + aux_l)


def moe_block_decode(x, p, cfg: ModelConfig, cache, cache_len):
    h = rms_norm(x, p["ln1"]["scale"], cfg.norm_eps)
    if cfg.attn_type == "mla":
        a, cache = attn.mla_decode(h, p["attn"], cfg, cache, cache_len)
    else:
        a, cache = attn.gqa_decode(h, p["attn"], cfg, cache, cache_len)
    x = x + a
    h = rms_norm(x, p["ln2"]["scale"], cfg.norm_eps)
    y, _ = moe_mod.moe_layer(h, p["moe"], cfg, capacity_factor=2.0)
    return x + y, cache


def init_ssm_layer(key, cfg: ModelConfig, dtype=jnp.float32) -> dict:
    return {
        "ln": init_rms_norm(cfg.d_model, dtype),
        "mixer": ssm_mod.init_mamba2(key, cfg, dtype),
    }


def ssm_block(x, p, cfg: ModelConfig):
    x = _constrain(x, cfg)
    h = rms_norm(x, p["ln"]["scale"], cfg.norm_eps)
    return x + ssm_mod.mamba2_forward(h, p["mixer"], cfg)


def ssm_block_decode(x, p, cfg: ModelConfig, cache):
    h = rms_norm(x, p["ln"]["scale"], cfg.norm_eps)
    y, cache = ssm_mod.mamba2_decode(h, p["mixer"], cfg, cache)
    return x + y, cache


def init_hybrid_sublayer(key, cfg: ModelConfig, kind: str, dtype=jnp.float32) -> dict:
    ks = jax.random.split(key, 2)
    p: dict[str, Any] = {
        "ln1": init_rms_norm(cfg.d_model, dtype),
        "ln2": init_rms_norm(cfg.d_model, dtype),
        "mlp": init_swiglu(ks[0], cfg.d_model, cfg.d_ff, dtype),
    }
    if kind == "attn":
        p["temporal"] = attn.init_gqa(ks[1], cfg, dtype)
    else:
        p["temporal"] = rglru_mod.init_rglru_block(ks[1], cfg, dtype)
    return p


def hybrid_sublayer(x, p, cfg: ModelConfig, kind: str):
    x = _constrain(x, cfg)
    h = rms_norm(x, p["ln1"]["scale"], cfg.norm_eps)
    if kind == "attn":
        t = attn.gqa_attention(h, p["temporal"], cfg, window=cfg.hybrid.window)
    else:
        t = rglru_mod.rglru_block(h, p["temporal"], cfg)
    x = x + t
    h = rms_norm(x, p["ln2"]["scale"], cfg.norm_eps)
    return x + swiglu(h, p["mlp"])


def hybrid_sublayer_decode(x, p, cfg: ModelConfig, kind: str, cache, cache_len):
    h = rms_norm(x, p["ln1"]["scale"], cfg.norm_eps)
    if kind == "attn":
        t, cache = attn.gqa_decode(
            h, p["temporal"], cfg, cache, cache_len, window=cfg.hybrid.window
        )
    else:
        t, cache = rglru_mod.rglru_block_decode(h, p["temporal"], cfg, cache)
    x = x + t
    h = rms_norm(x, p["ln2"]["scale"], cfg.norm_eps)
    return x + swiglu(h, p["mlp"]), cache


# ---------------------------------------------------------------------------
# stacked scans
# ---------------------------------------------------------------------------


def init_stack(key, n: int, init_fn) -> dict:
    keys = jax.random.split(key, max(n, 1))
    return jax.vmap(init_fn)(keys) if n > 0 else None


def scan_stack(x, stacked, block_fn, remat: bool):
    fn = jax.checkpoint(block_fn) if remat else block_fn

    def step(h, layer_params):
        return fn(h, layer_params), None

    out, _ = jax.lax.scan(step, x, stacked)
    return out


def scan_stack_decode(x, stacked_params, stacked_cache, block_fn):
    """Scan layers threading both hidden state and per-layer cache."""

    def step(h, inp):
        lp, lc = inp
        h, lc_new = block_fn(h, lp, lc)
        return h, lc_new

    out, new_cache = jax.lax.scan(step, x, (stacked_params, stacked_cache))
    return out, new_cache
