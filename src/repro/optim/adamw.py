"""AdamW with shardable state pytrees (ZeRO via GSPMD sharding specs).

State mirrors the param tree (m, v share the params' PartitionSpec — the
sharding rules in ``runtime/sharding.py`` shard them over data+model, which
is exactly ZeRO-* expressed declaratively).  ``dtype`` knobs allow bf16
moments for the biggest archs (deepseek-v3 on one pod) — recorded as a
memory-roofline lever in EXPERIMENTS.md.
"""

from __future__ import annotations

from dataclasses import dataclass
from typing import Any, Callable

import jax
import jax.numpy as jnp


@dataclass(frozen=True)
class AdamWConfig:
    b1: float = 0.9
    b2: float = 0.95
    eps: float = 1e-8
    weight_decay: float = 0.1
    moment_dtype: str = "float32"    # "bfloat16" halves optimizer memory
    grad_clip: float = 1.0


def init_state(params, cfg: AdamWConfig) -> dict:
    dt = jnp.dtype(cfg.moment_dtype)
    zeros = lambda p: jnp.zeros(p.shape, dt)
    return {
        "m": jax.tree.map(zeros, params),
        "v": jax.tree.map(zeros, params),
        "step": jnp.zeros((), jnp.int32),
    }


def _global_norm(tree) -> jax.Array:
    return jnp.sqrt(
        sum(jnp.sum(jnp.square(x.astype(jnp.float32))) for x in jax.tree.leaves(tree))
    )


def apply_updates(
    params, grads, state, lr, cfg: AdamWConfig
) -> tuple[Any, dict, dict]:
    """One AdamW step; returns (new_params, new_state, metrics)."""
    step = state["step"] + 1
    gnorm = _global_norm(grads)
    scale = jnp.minimum(1.0, cfg.grad_clip / (gnorm + 1e-12)) if cfg.grad_clip else 1.0

    b1, b2 = cfg.b1, cfg.b2
    bc1 = 1.0 - b1 ** step.astype(jnp.float32)
    bc2 = 1.0 - b2 ** step.astype(jnp.float32)

    def upd(p, g, m, v):
        g = g.astype(jnp.float32) * scale
        m_new = b1 * m.astype(jnp.float32) + (1 - b1) * g
        v_new = b2 * v.astype(jnp.float32) + (1 - b2) * jnp.square(g)
        mhat = m_new / bc1
        vhat = v_new / bc2
        delta = mhat / (jnp.sqrt(vhat) + cfg.eps) + cfg.weight_decay * p.astype(jnp.float32)
        p_new = p.astype(jnp.float32) - lr * delta
        return p_new.astype(p.dtype), m_new.astype(m.dtype), v_new.astype(v.dtype)

    out = jax.tree.map(upd, params, grads, state["m"], state["v"])
    new_params = jax.tree.map(lambda t: t[0], out, is_leaf=lambda t: isinstance(t, tuple))
    new_m = jax.tree.map(lambda t: t[1], out, is_leaf=lambda t: isinstance(t, tuple))
    new_v = jax.tree.map(lambda t: t[2], out, is_leaf=lambda t: isinstance(t, tuple))
    return (
        new_params,
        {"m": new_m, "v": new_v, "step": step},
        {"grad_norm": gnorm},
    )
