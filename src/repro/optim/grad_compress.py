"""Error-feedback gradient compression for cross-pod data parallelism.

HPDR's insight applied to training (DESIGN.md §3): the pod-to-pod gradient
reduction is the slowest collective in a multi-pod mesh, and its payload is
exactly the kind of low-entropy float field the paper compresses.  We apply
ZFP-style fixed-rate block quantization (per-block max-exponent scale +
int8/intN mantissas) to the gradient *before* crossing the pod axis:

  all-reduce(bf16 grads)  →  all-gather(int8 blocks + f32 scales) + local sum

which cuts pod-axis collective bytes ~2× vs bf16 (4× vs f32) at 8 bits, and
error feedback keeps SGD unbiased-in-the-limit (the residual is replayed
into the next step — standard EF-SGD).

Used via ``shard_map`` over the "pod" axis in ``launch/train.py`` and the
collective-bound hillclimb in EXPERIMENTS.md §Perf.
"""

from __future__ import annotations

from functools import partial

import jax
import jax.numpy as jnp

from ..core.context import GLOBAL_CMM, ReductionContext, context_key

BLOCK = 256


def _pad_to_block(x: jax.Array) -> tuple[jax.Array, int]:
    flat = x.reshape(-1)
    pad = (-flat.shape[0]) % BLOCK
    if pad:
        flat = jnp.pad(flat, (0, pad))
    return flat, pad


def quantize_blocks(g: jax.Array, bits: int = 8) -> tuple[jax.Array, jax.Array]:
    """g → (int8 mantissas, f32 per-block scales); ZFP-style exponent align."""
    flat, _ = _pad_to_block(g)
    blocks = flat.reshape(-1, BLOCK).astype(jnp.float32)
    absmax = jnp.max(jnp.abs(blocks), axis=1, keepdims=True)
    qmax = float(2 ** (bits - 1) - 1)
    scale = jnp.where(absmax > 0, absmax / qmax, 1.0)
    q = jnp.clip(jnp.round(blocks / scale), -qmax, qmax).astype(jnp.int8)
    return q, scale[:, 0]


def dequantize_blocks(
    q: jax.Array, scale: jax.Array, shape: tuple[int, ...], dtype=jnp.float32
) -> jax.Array:
    vals = q.astype(jnp.float32) * scale[:, None]
    flat = vals.reshape(-1)
    import numpy as np

    n = int(np.prod(shape))
    return flat[:n].reshape(shape).astype(dtype)


def _ef_core(grad: jax.Array, residual: jax.Array, bits: int):
    corrected = grad.astype(jnp.float32) + residual
    q, s = quantize_blocks(corrected, bits)
    approx = dequantize_blocks(q, s, grad.shape)
    return (q, s), corrected - approx


def _ef_plan(shape: tuple[int, ...], dtype, bits: int):
    """CMM-cached jitted EF executable, one per (shape, dtype, bits).

    The optimizer's per-step gradient compression is exactly the repeated
    same-characteristics reduction the paper's CMM targets: the plan (jitted
    quantize/dequantize round-trip) is built once and reused every step.
    """
    key = context_key("grad-ef", shape, dtype, bits=bits)

    def build():
        return ReductionContext(
            key=key, plan=jax.jit(partial(_ef_core, bits=bits))
        )

    return GLOBAL_CMM.get_or_create(key, build).plan


def compress_decompress(g: jax.Array, bits: int = 8) -> jax.Array:
    """Round-trip (for error-feedback residual computation)."""
    q, s = quantize_blocks(g, bits)
    return dequantize_blocks(q, s, g.shape, g.dtype)


def ef_step(grad: jax.Array, residual: jax.Array, bits: int = 8):
    """Error feedback: compress (grad + residual), return (compressed, new_residual).

    Outside a trace this dispatches through the CMM-cached jitted plan;
    inside jit/shard_map it inlines (the enclosing program is the plan).
    """
    if isinstance(grad, jax.core.Tracer) or isinstance(residual, jax.core.Tracer):
        return _ef_core(grad, residual, bits)
    return _ef_plan(tuple(grad.shape), str(jnp.asarray(grad).dtype), bits)(
        grad, residual
    )


def pod_compressed_mean(
    grad: jax.Array, axis_name: str = "pod", bits: int = 8
) -> jax.Array:
    """Mean-reduce a gradient across ``axis_name`` with compressed payload.

    Inside ``shard_map``: quantize locally, all-gather the int8 mantissas
    (bytes/link = N·1B vs ring-all-reduce's ≈2·N·2B for bf16), then reduce
    locally in f32.  Exact for the scales (f32, tiny).
    """
    q, s = quantize_blocks(grad, bits)
    q_all = jax.lax.all_gather(q, axis_name)        # (P, nb, BLOCK) int8
    s_all = jax.lax.all_gather(s, axis_name)        # (P, nb) f32
    vals = q_all.astype(jnp.float32) * s_all[..., None]
    mean_blocks = jnp.mean(vals, axis=0)
    flat = mean_blocks.reshape(-1)
    import numpy as np

    n = int(np.prod(grad.shape))
    return flat[:n].reshape(grad.shape).astype(grad.dtype)


def tree_pod_compressed_mean(grads, axis_name: str = "pod", bits: int = 8):
    return jax.tree.map(
        partial(pod_compressed_mean, axis_name=axis_name, bits=bits), grads
    )
