"""LR schedules: cosine (default) and WSD (MiniCPM, arXiv:2404.06395).

WSD — Warmup-Stable-Decay: linear warmup → constant plateau → short
exponential/linear decay tail; the schedule MiniCPM's data-scaling law study
depends on, exposed because minicpm-2b is an assigned architecture.
"""

from __future__ import annotations

import jax.numpy as jnp


def cosine(step, *, peak_lr: float, warmup: int, total: int, min_ratio: float = 0.1):
    step = jnp.asarray(step, jnp.float32)
    warm = peak_lr * step / jnp.maximum(warmup, 1)
    prog = jnp.clip((step - warmup) / jnp.maximum(total - warmup, 1), 0.0, 1.0)
    cos = peak_lr * (min_ratio + (1 - min_ratio) * 0.5 * (1 + jnp.cos(jnp.pi * prog)))
    return jnp.where(step < warmup, warm, cos)


def wsd(step, *, peak_lr: float, warmup: int, total: int,
        decay_fraction: float = 0.1, min_ratio: float = 0.01):
    step = jnp.asarray(step, jnp.float32)
    decay_steps = jnp.maximum(total * decay_fraction, 1.0)
    decay_start = total - decay_steps
    warm = peak_lr * step / jnp.maximum(warmup, 1)
    stable = jnp.full_like(warm, peak_lr)
    prog = jnp.clip((step - decay_start) / decay_steps, 0.0, 1.0)
    decay = peak_lr * (min_ratio ** prog)  # exponential tail (paper's choice)
    out = jnp.where(step < warmup, warm, stable)
    return jnp.where(step >= decay_start, decay, out)


SCHEDULES = {"cosine": cosine, "wsd": wsd}
