from . import fault, hlo_analysis, roofline, sharding  # noqa: F401
