from . import executor, fault, hlo_analysis, roofline, sharding  # noqa: F401
