"""Measured per-machine cost calibration — HPDR §V-C made empirical.

The adaptive-chunking model (``core/chunk_model.py``) and the timeline
simulator (``core/pipeline.py`` + ``runtime/roofline.simulate_stream``)
are only predictive once their inputs are *measured on the machine at
hand*.  This module closes that loop:

  calibrate  — micro-benchmark each pipeline stage over a small chunk-size
               sweep, best-of-N with warm plans (the CMM caches the
               compiled executables, so timings measure execution, not
               tracing):
                 * H2D staging       ``jax.device_put`` wall per chunk
                 * compute lane      two-phase ``encode_begin`` (fused
                                     device segments, blocked)
                 * io lane           ``encode_finish`` + wire framing
                                     (exact-sized D2H + container bytes)
               plus two machine-level scalars: the per-chunk scheduling
               overhead a ``window>1`` pipeline pays over serial, and the
               host framing throughput from ``runtime.io``'s
               ``serialization_probe`` (crc32 + coalescing-buffer copy).
  fit        — compute throughput → ``PhiModel`` (piecewise roofline fit,
               paper Fig. 11); H2D and serialize → ``AffineCost``
               (t₀ + C/bps, so per-call latency is modeled — decisive in
               the small-payload regime).
  persist    — versioned JSON keyed by (platform, device kind, backend)
               under ``$HPDR_CALIBRATION_DIR`` (default
               ``~/.cache/hpdr``).  Later runs — including *other
               processes* — load the file and perform **zero** measurement
               sweeps; ``SWEEPS_RUN`` counts sweeps performed by this
               process, the observable the persistence tests assert on.

Invalidation: a calibration file is ignored (and re-measured) when its
``version`` differs from :data:`CALIBRATION_VERSION`, or when its machine
key (platform + device kind + device count) or backend no longer matches
the running process.  Delete the file to force re-measurement.

Every timing path reads an injectable ``clock`` (default
``time.perf_counter``) so the fast test tier calibrates with a stubbed
clock in milliseconds of wall time.
"""

from __future__ import annotations

import json
import os
import tempfile
import threading
import time
from dataclasses import dataclass, field
from pathlib import Path
from typing import Any, Callable

import numpy as np

from ..core import chunk_model

CALIBRATION_VERSION = 3
ENV_DIR = "HPDR_CALIBRATION_DIR"

#: chunk-size sweep (elements) — small enough that a cold calibration is a
#: few plan compiles + milliseconds of execution, wide enough (64x) to
#: expose the Φ knee between latency- and throughput-bound chunks
DEFAULT_SWEEP_ELEMS = (4 << 10, 16 << 10, 64 << 10, 256 << 10)

#: process-wide count of measurement sweeps actually executed (method
#: sweeps + machine-overhead probes).  The persistence acceptance test
#: asserts a warm process stays at 0.
SWEEPS_RUN = 0

_LOCK = threading.RLock()
_STORES: dict[str, "MachineCalibration"] = {}
_DIR_OVERRIDE: str | None = None


# ---------------------------------------------------------------------------
# location + machine identity
# ---------------------------------------------------------------------------


def set_calibration_dir(path: str | Path | None) -> None:
    """Override the calibration directory (tests, docs examples).

    ``None`` restores the default resolution order.  Clears the in-process
    store cache so the next access reloads from the new location.
    """
    global _DIR_OVERRIDE
    with _LOCK:
        _DIR_OVERRIDE = str(path) if path is not None else None
        _STORES.clear()
    try:  # solved plans / residuals derive from the old store: drop them
        from ..core import tuner as _tuner

        _tuner.clear_caches()
    except Exception:
        pass


def calibration_dir() -> Path:
    if _DIR_OVERRIDE is not None:
        return Path(_DIR_OVERRIDE)
    env = os.environ.get(ENV_DIR)
    if env:
        return Path(env)
    return Path.home() / ".cache" / "hpdr"


def machine_key(backend: str | None = None) -> str:
    """Stable identity for *this* machine+backend: what the file is keyed by."""
    import jax

    dev = jax.devices()[0]
    kind = getattr(dev, "device_kind", dev.platform)
    slug = "".join(ch if ch.isalnum() else "-" for ch in str(kind)).strip("-")
    return f"{dev.platform}_{slug}_x{jax.device_count()}_{_resolve_backend(backend)}"


def calibration_path(backend: str | None = None) -> Path:
    return calibration_dir() / f"calibration_{machine_key(backend)}.json"


def _resolve_backend(backend: str | None) -> str:
    from ..core import adapters

    return adapters.resolve_backend(backend)


def method_key(method: str, dtype: Any) -> str:
    return f"{method}:{np.dtype(dtype).name}"


def race_key(method: str, dtype: Any, total_elems: int, itemsize: int) -> str:
    """Store key for one tuner candidate race (spec geometry included)."""
    return (
        f"{method}:{np.dtype(dtype).name}:{int(total_elems)}:{int(itemsize)}"
    )


# ---------------------------------------------------------------------------
# calibration records
# ---------------------------------------------------------------------------


@dataclass(frozen=True)
class MethodCalibration:
    """Fitted per-stage cost model for one (codec, dtype) on this machine."""

    method: str
    dtype: str
    phi: chunk_model.PhiModel            # compute-lane throughput Φ(C)
    h2d: chunk_model.AffineCost          # staging cost t(C)
    serialize: chunk_model.AffineCost    # io-lane cost t(C): D2H + framing
    output_fraction: float               # compressed bytes / raw bytes
    profile_bytes: tuple = ()            # the sweep, for re-fit / reporting
    profile_bps: tuple = ()
    #: measured/simulated residual on a real mini-stream probe.  The lane
    #: simulator assumes independent resources; on machines where lanes
    #: contend (a CPU backend runs every "lane" on the same cores) the
    #: pipelined prediction is optimistic.  ``overlap_scale`` multiplies
    #: window>1 predictions, ``serial_scale`` window=1 predictions — the
    #: correction that makes the serial-degrade guard honest.
    serial_scale: float = 1.0
    overlap_scale: float = 1.0
    #: fixed per-stream cost (transient executor spin-down, scheduling,
    #: result assembly) — measured as (tiny 1-chunk stream wall − its
    #: simulated lane cost).  Added to every predicted makespan; decisive
    #: for small payloads where it rivals the lane work itself.
    stream_t0: float = 0.0
    #: fixed per-chunk cost inside a stream (dispatch, thread hop, slot
    #: bookkeeping) that the per-stage sweep cannot see — it times the
    #: stage bodies, not the scheduling around them.  Charged once per
    #: chunk; the term that makes over-splitting visibly expensive.
    chunk_t0: float = 0.0

    def to_json(self) -> dict:
        return {
            "method": self.method,
            "dtype": self.dtype,
            "phi": {
                "alpha": self.phi.alpha, "beta0": self.phi.beta0,
                "gamma": self.phi.gamma, "c_threshold": self.phi.c_threshold,
            },
            "h2d": {"t0": self.h2d.t0, "bps": self.h2d.bps},
            "serialize": {"t0": self.serialize.t0, "bps": self.serialize.bps},
            "output_fraction": self.output_fraction,
            "profile_bytes": list(self.profile_bytes),
            "profile_bps": list(self.profile_bps),
            "serial_scale": self.serial_scale,
            "overlap_scale": self.overlap_scale,
            "stream_t0": self.stream_t0,
            "chunk_t0": self.chunk_t0,
        }

    @staticmethod
    def from_json(d: dict) -> "MethodCalibration":
        return MethodCalibration(
            method=str(d["method"]),
            dtype=str(d["dtype"]),
            phi=chunk_model.PhiModel(**d["phi"]),
            h2d=chunk_model.AffineCost(**d["h2d"]),
            serialize=chunk_model.AffineCost(**d["serialize"]),
            output_fraction=float(d["output_fraction"]),
            profile_bytes=tuple(d.get("profile_bytes", ())),
            profile_bps=tuple(d.get("profile_bps", ())),
            serial_scale=float(d.get("serial_scale", 1.0)),
            overlap_scale=float(d.get("overlap_scale", 1.0)),
            stream_t0=float(d.get("stream_t0", 0.0)),
            chunk_t0=float(d.get("chunk_t0", 0.0)),
        )


@dataclass
class MachineCalibration:
    """Everything measured for one (machine, backend), persisted as JSON."""

    machine: str
    backend: str
    window_overhead_s: float | None = None   # per-chunk pipelined-over-serial
    host_frame_bps: float | None = None      # runtime.io serialization probe
    methods: dict[str, MethodCalibration] = field(default_factory=dict)
    #: persisted tuner race winners, keyed by :func:`race_key` — the
    #: ``(chunk_elems, window)`` the candidate race converged on plus its
    #: measured per-element cost.  Additive field (older files load with an
    #: empty dict); rides the same versioning/invalidation as the rest of
    #: the store, so a machine or backend change re-races from scratch.
    races: dict[str, dict] = field(default_factory=dict)
    path: Path | None = None
    loaded_from_disk: bool = False

    def to_json(self) -> dict:
        return {
            "version": CALIBRATION_VERSION,
            "machine": self.machine,
            "backend": self.backend,
            "window_overhead_s": self.window_overhead_s,
            "host_frame_bps": self.host_frame_bps,
            "methods": {k: m.to_json() for k, m in self.methods.items()},
            "races": dict(self.races),
        }

    def save(self) -> None:
        """Atomic write (tmp + rename) so readers never see a torn file."""
        if self.path is None:
            return
        self.path.parent.mkdir(parents=True, exist_ok=True)
        fd, tmp = tempfile.mkstemp(
            dir=self.path.parent, prefix=self.path.name, suffix=".tmp"
        )
        try:
            with os.fdopen(fd, "w") as f:
                json.dump(self.to_json(), f, indent=1)
            os.replace(tmp, self.path)
        except BaseException:
            try:
                os.unlink(tmp)
            except OSError:
                pass
            raise


def _load_file(path: Path, machine: str, backend: str) -> MachineCalibration | None:
    try:
        d = json.loads(path.read_text())
    except (OSError, json.JSONDecodeError):
        return None
    # invalidation rules: version, machine identity, backend must all match
    if d.get("version") != CALIBRATION_VERSION:
        return None
    if d.get("machine") != machine or d.get("backend") != backend:
        return None
    try:
        methods = {
            k: MethodCalibration.from_json(m)
            for k, m in d.get("methods", {}).items()
        }
    except (KeyError, TypeError, ValueError):
        return None
    races = {
        k: r for k, r in d.get("races", {}).items()
        if isinstance(r, dict) and "chunk_elems" in r and "window" in r
    }
    return MachineCalibration(
        machine=machine,
        backend=backend,
        window_overhead_s=d.get("window_overhead_s"),
        host_frame_bps=d.get("host_frame_bps"),
        methods=methods,
        races=races,
        path=path,
        loaded_from_disk=True,
    )


def load_store(backend: str | None = None) -> MachineCalibration:
    """The process-wide calibration store for (this machine, backend).

    Loads the persisted JSON on first access; a missing/invalid file yields
    an empty store that fills (and persists) as methods are measured.
    """
    be = _resolve_backend(backend)
    key = machine_key(be)
    with _LOCK:
        store = _STORES.get(key)
        if store is None:
            path = calibration_path(be)
            store = _load_file(path, key, be) or MachineCalibration(
                machine=key, backend=be, path=path
            )
            _STORES[key] = store
        return store


# ---------------------------------------------------------------------------
# the calibrator
# ---------------------------------------------------------------------------


class Calibrator:
    """Micro-benchmark per-stage costs and fit the machine cost model.

    ``clock`` is injectable (stub clocks make the fast-test tier
    deterministic and sub-second); ``best_of`` guards against scheduler
    noise; ``sweep_elems`` sets the chunk-size sweep in elements.
    """

    def __init__(
        self,
        backend: str | None = None,
        *,
        clock: Callable[[], float] = time.perf_counter,
        best_of: int = 3,
        sweep_elems: tuple = DEFAULT_SWEEP_ELEMS,
    ):
        self.backend = _resolve_backend(backend)
        self.clock = clock
        self.best_of = max(1, int(best_of))
        self.sweep_elems = tuple(int(e) for e in sweep_elems)

    # -- timing helpers ------------------------------------------------------

    def _best_of(self, fn: Callable[[], Any]) -> float:
        best = float("inf")
        for _ in range(self.best_of):
            t0 = self.clock()
            fn()
            t1 = self.clock()
            best = min(best, t1 - t0)
        return max(best, 1e-9)

    @staticmethod
    def _chunk_shape(elems: int) -> tuple[int, int, int]:
        # the stream slices rows off the largest axis; calibrate on the
        # same row-major geometry (1024 elements per row plane)
        return (max(1, int(elems) // 1024), 32, 32)

    @staticmethod
    def _sweep_data(shape: tuple, dtype: Any) -> np.ndarray:
        rng = np.random.default_rng(12345)
        g = np.linspace(0.0, 4.0 * np.pi, shape[0], dtype=np.float64)
        base = np.sin(g)[:, None, None] + 0.1 * rng.standard_normal(shape)
        return np.ascontiguousarray(base.astype(np.dtype(dtype)))

    # -- per-method sweep ----------------------------------------------------

    def measure_method(
        self, method: str, dtype: Any = "float32", params: dict | None = None
    ) -> MethodCalibration:
        """One chunk-size sweep → fitted :class:`MethodCalibration`."""
        global SWEEPS_RUN
        import jax

        from ..core import api as core_api

        params = dict(params or {})
        sizes_b: list[int] = []
        t_h2d: list[float] = []
        t_comp: list[float] = []
        t_ser: list[float] = []
        out_frac: list[float] = []
        for elems in self.sweep_elems:
            arr = self._sweep_data(self._chunk_shape(elems), dtype)
            spec = core_api.make_spec(
                arr, method, backend=self.backend, **params
            )
            codec = core_api.get_codec(spec.method)
            plan = core_api.get_plan(spec)  # warm plan via the CMM
            dev = jax.device_put(arr)
            jax.block_until_ready(dev)
            # warm up compile + one finish before timing anything
            payload = self._encode_once(codec, plan, dev)
            frame = self._finish_once(codec, plan, payload)
            sizes_b.append(arr.nbytes)
            t_h2d.append(self._best_of(
                lambda: jax.block_until_ready(jax.device_put(arr))
            ))
            t_comp.append(self._best_of(
                lambda: self._encode_once(codec, plan, dev)
            ))
            t_ser.append(self._best_of(
                lambda: self._finish_once(codec, plan, payload)
            ))
            out_frac.append(len(frame) / arr.nbytes)
        SWEEPS_RUN += 1
        sizes_arr = np.asarray(sizes_b, np.float64)
        comp_bps = sizes_arr / np.asarray(t_comp, np.float64)
        phi = chunk_model.fit_phi(sizes_arr, comp_bps)
        h2d = chunk_model.fit_affine(sizes_arr, t_h2d)
        ser = chunk_model.fit_affine(sizes_arr, t_ser)
        stream_t0, chunk_t0, serial_scale, overlap_scale = (
            self._measure_stream_scales(method, dtype, params, phi, h2d, ser)
        )
        return MethodCalibration(
            method=method,
            dtype=np.dtype(dtype).name,
            phi=phi,
            h2d=h2d,
            serialize=ser,
            output_fraction=float(np.mean(out_frac)),
            profile_bytes=tuple(int(s) for s in sizes_b),
            profile_bps=tuple(float(b) for b in comp_bps),
            serial_scale=serial_scale,
            overlap_scale=overlap_scale,
            stream_t0=stream_t0,
            chunk_t0=chunk_t0,
        )

    def _measure_stream_scales(
        self, method, dtype, params, phi, h2d, ser,
        n_probe: int = 4,
    ) -> tuple[float, float, float, float]:
        """``(stream_t0, chunk_t0, serial_scale, overlap_scale)``.

        Probes through the *actual* ``CompressorStream``:

          * a tiny 1-chunk stream isolates the fixed per-stream cost
            (``stream_t0`` = wall − simulated lane cost);
          * an ``n_probe``-chunk serial stream at the largest sweep size
            isolates the fixed per-chunk cost (``chunk_t0`` = excess wall
            over simulation + ``stream_t0``, divided by ``n_probe``) —
            the dispatch/scheduling overhead the per-stage sweep cannot
            see, and the term that penalizes over-splitting;
          * the same stream at window 2 yields the measured/simulated
            overlap residual.  The lane simulator assumes H2D / compute /
            io are independent resources; where they contend (every lane
            of a CPU backend runs on the same cores) the window>1
            prediction is optimistic by a machine-and-codec factor.

        Walls come from the stream's own ``perf_counter`` (not the
        injectable sweep clock); degenerate ratios clamp to [0.2, 50].
        """
        from ..core import api as core_api
        from . import roofline

        itemsize = np.dtype(dtype).itemsize

        def wall(window: int, chunk_elems: int, n_chunks: int) -> float:
            rows, y, z = self._chunk_shape(chunk_elems)
            data = self._sweep_data((rows * n_chunks, y, z), dtype)
            stream = core_api.CompressorStream(
                method, mode="fixed", c_fixed_elems=chunk_elems,
                window=window, backend=self.backend, frame=True, **params)
            stream.compress(data)  # warm
            return min(
                stream.compress(data).wall_time for _ in range(self.best_of)
            )

        def sim(window: int, chunk_elems: int, n_chunks: int) -> float:
            mk, _ = roofline.simulate_stream(
                [chunk_elems * itemsize] * n_chunks,
                h2d.time_for, phi.time_for, ser.time_for, window=window)
            return mk

        try:
            tiny = int(self.sweep_elems[0])
            stream_t0 = max(0.0, wall(1, tiny, 1) - sim(1, tiny, 1))

            big = int(self.sweep_elems[-1])
            serial_wall = wall(1, big, n_probe)
            chunk_t0 = max(
                0.0,
                (serial_wall - sim(1, big, n_probe) - stream_t0) / n_probe,
            )
            fixed = stream_t0 + n_probe * chunk_t0

            def scale(measured: float, window: int) -> float:
                predicted = sim(window, big, n_probe) + fixed
                if not (np.isfinite(measured) and np.isfinite(predicted)) \
                        or predicted <= 0:
                    return 1.0
                return float(np.clip(measured / predicted, 0.2, 50.0))

            return (stream_t0, chunk_t0, scale(serial_wall, 1),
                    scale(wall(2, big, n_probe), 2))
        except Exception:
            return 0.0, 0.0, 1.0, 1.0

    @staticmethod
    def _encode_once(codec, plan, dev):
        """Phase 1 exactly as the stream's compute lane runs it."""
        import jax

        if plan.pipeline is None:  # codec without a stage graph: one phase
            c = codec.encode(plan, dev)
            jax.block_until_ready(list(c.arrays.values()) or dev)
            return ("container", c)
        state, env = codec.encode_begin(plan, dev)
        jax.block_until_ready([v for v in state.values()])
        return ("state", state, env)

    @staticmethod
    def _finish_once(codec, plan, payload) -> bytes:
        """Phase 2 (io lane): exact-sized D2H + container wire bytes."""
        if payload[0] == "container":
            c = payload[1]
            for k, v in list(c.arrays.items()):
                c.arrays[k] = np.asarray(v)
        else:
            c = codec.encode_finish(plan, payload[1], payload[2])
        return c.to_bytes()

    # -- machine-level probes ------------------------------------------------

    def measure_window_overhead(
        self, chunks: int = 6, chunk_elems: int = 16 << 10
    ) -> float:
        """Per-chunk cost of the pipelined schedule over serial.

        Runs the *real* ``ChunkedPipeline`` with trivial stage functions at
        ``window`` 1 and 2; the wall-clock difference per chunk is pure
        scheduling overhead (thread handoff, future chaining, staging
        bookkeeping) — the term that makes overlap a net loss on tiny
        chunks.  Clamped at ≥ 0.
        """
        global SWEEPS_RUN
        from ..core import pipeline as pl

        rows_per_chunk = 8
        data = np.zeros(
            (chunks * rows_per_chunk, chunk_elems // rows_per_chunk),
            np.float32,
        )

        def compute_fn(chunk, slot):
            del slot
            return chunk

        def finish_fn(payload, slot):
            del slot
            return np.asarray(payload)

        walls = {}
        for w in (1, 2):
            pipe = pl.ChunkedPipeline(
                mode="fixed", c_fixed_elems=chunk_elems,
                compute_fn=compute_fn, finish_fn=finish_fn, window=w,
            )
            pipe.run(data)  # warm the lanes
            walls[w] = self._best_of(lambda: pipe.run(data))
        SWEEPS_RUN += 1
        return max(0.0, (walls[2] - walls[1]) / chunks)

    def measure_host_frame_bps(self, nbytes: int = 1 << 20) -> float:
        from . import io as rio

        t = rio.serialization_probe(nbytes, clock=self.clock)
        return float(nbytes) / t


# ---------------------------------------------------------------------------
# the public entry: load-or-measure
# ---------------------------------------------------------------------------


def get_method_calibration(
    method: str,
    dtype: Any = "float32",
    backend: str | None = None,
    *,
    measure: bool = True,
    params: dict | None = None,
    clock: Callable[[], float] = time.perf_counter,
    best_of: int = 3,
    sweep_elems: tuple = DEFAULT_SWEEP_ELEMS,
) -> MethodCalibration | None:
    """Calibration for (method, dtype) on this machine: load, else measure.

    A persisted calibration loads with zero sweeps.  A missing method is
    measured once (``measure=True``), merged into the store, and persisted
    for every later process.  Returns ``None`` when unavailable and
    measurement is disabled or fails.
    """
    store = load_store(backend)
    key = method_key(method, dtype)
    with _LOCK:
        mc = store.methods.get(key)
    if mc is not None or not measure:
        return mc
    cal = Calibrator(
        backend, clock=clock, best_of=best_of, sweep_elems=sweep_elems
    )
    mc = cal.measure_method(method, dtype, params=params)
    with _LOCK:
        store.methods[key] = mc
        if store.window_overhead_s is None:
            store.window_overhead_s = cal.measure_window_overhead()
        if store.host_frame_bps is None:
            store.host_frame_bps = cal.measure_host_frame_bps()
        store.save()
    return mc


def window_overhead_s(backend: str | None = None) -> float:
    """The machine's calibrated per-chunk pipelining overhead (0.0 cold)."""
    store = load_store(backend)
    return float(store.window_overhead_s or 0.0)


# ---------------------------------------------------------------------------
# persisted tuner race winners
# ---------------------------------------------------------------------------


def get_race_winner(
    method: str,
    dtype: Any,
    total_elems: int,
    itemsize: int,
    backend: str | None = None,
) -> dict | None:
    """The persisted race winner for this spec geometry, or ``None``.

    A hit lets a fresh process start its candidate race pre-converged on
    the previously measured winner — zero exploration runs — while
    ``tuner.observe`` feedback can still dethrone it if the machine
    changed behaviour.
    """
    store = load_store(backend)
    with _LOCK:
        r = store.races.get(race_key(method, dtype, total_elems, itemsize))
        return dict(r) if r is not None else None


def record_race_winner(
    method: str,
    dtype: Any,
    total_elems: int,
    itemsize: int,
    backend: str | None,
    *,
    chunk_elems: int,
    window: int,
    measured_s: float,
) -> None:
    """Persist a converged race winner (idempotent; atomic store save)."""
    store = load_store(backend)
    key = race_key(method, dtype, total_elems, itemsize)
    entry = {
        "chunk_elems": int(chunk_elems),
        "window": int(window),
        "measured_s": float(measured_s),
    }
    with _LOCK:
        prev = store.races.get(key)
        if prev is not None and (
            (prev.get("chunk_elems"), prev.get("window"))
            == (entry["chunk_elems"], entry["window"])
            and abs(entry["measured_s"] - prev.get("measured_s", 0.0))
            <= 0.05 * max(entry["measured_s"], 1e-12)
        ):
            return  # same winner within noise: don't rewrite the file
        store.races[key] = entry
        store.save()
