"""Device-aware asynchronous executor — the host side of HDEM fan-out.

The paper's multi-accelerator result (Fig. 16: 96% of theoretical speedup)
comes from running independent reductions concurrently on separate devices
while the shared runtime does no per-call allocation (CMM).  This module is
the submission machinery the execution engine (:mod:`repro.core.engine`)
schedules through:

  * :class:`DeviceExecutor` — a thread pool that round-robins work over an
    explicit device list; each task runs under ``jax.default_device`` for
    its assigned device, so JAX async dispatch overlaps device compute
    across the pool while host-side stages (codebook builds, container
    packing) overlap on threads.
  * :class:`Submission` — the ``submit()/result()`` future handle.  It also
    carries the device the work was placed on, which tests and benchmarks
    use to assert real fan-out.

Two lanes, mirroring the HDEM machine model: ``compute`` (per-device
reduction work, pool sized to the device count) and ``io`` (long-running
orchestration such as an async checkpoint save, single-threaded so saves
serialize against each other and can safely *wait on* compute-lane work
without deadlocking the pool).
"""

from __future__ import annotations

import itertools
import threading
import time
from concurrent.futures import Future, ThreadPoolExecutor
from typing import Any, Callable, Sequence

import jax

COMPUTE, IO = "compute", "io"

# Placement sentinel: run on the compute pool WITHOUT pinning a default
# device.  Used for whole-mesh work — e.g. the engine's stacked shard_map
# buckets, which span every data-axis device and must not be confined to
# one ring slot (a pinned default_device would fight the mesh sharding).
MESH = object()


class Submission:
    """Handle for one submitted task (the engine's future type)."""

    def __init__(self, future: Future, device: Any = None, lane: str = COMPUTE):
        self._future = future
        self.device = device
        self.lane = lane

    def done(self) -> bool:
        return self._future.done()

    def result(self, timeout: float | None = None) -> Any:
        return self._future.result(timeout)

    def exception(self, timeout: float | None = None):
        return self._future.exception(timeout)

    def add_done_callback(self, fn: Callable[["Submission"], None]) -> None:
        """Invoke ``fn(self)`` when the submission resolves (any outcome).

        The serving layer's request demultiplexer rides this: a coalesced
        bucket submission fans its per-leaf results back out to every
        participating request without a thread parked on ``result()``.
        """
        self._future.add_done_callback(lambda _f: fn(self))


class DeviceExecutor:
    """Round-robin device-aware async executor.

    ``devices`` is the placement ring — normally the mesh's ``data``-axis
    devices.  Tasks submitted without an explicit ``device`` are assigned the
    next ring slot; the task body runs with that device as JAX's default, so
    arrays it creates (and the compute they feed) land there.
    """

    def __init__(
        self,
        devices: Sequence[Any] | None = None,
        max_workers: int | None = None,
        io_workers: int = 1,
    ):
        self.devices = list(devices) if devices else list(jax.devices()[:1])
        self._pool = ThreadPoolExecutor(
            max_workers=max_workers or max(2, len(self.devices)),
            thread_name_prefix="hpdr-compute",
        )
        self._io_pool = ThreadPoolExecutor(
            max_workers=io_workers, thread_name_prefix="hpdr-io"
        )
        self._rr = itertools.count()
        self._lock = threading.Lock()
        self._idle = threading.Condition(self._lock)
        self._closed = False
        self.submitted = 0
        self.completed = 0
        self.mesh_submitted = 0  # whole-mesh (device=MESH) tasks
        # per-lane service metrics: queue depth (submitted - started) and
        # cumulative time tasks spent waiting for a pool thread — the
        # executor-level half of the serving layer's ServiceStats surface
        self._lane_submitted = {COMPUTE: 0, IO: 0}
        self._lane_started = {COMPUTE: 0, IO: 0}
        self._lane_completed = {COMPUTE: 0, IO: 0}
        self._lane_wait_s = {COMPUTE: 0.0, IO: 0.0}
        # per-priority counters (priority is an opaque caller label — the
        # serving layer tags submissions "interactive"/"bulk" so operators
        # can see which class is eating each lane)
        self._prio: dict[str, dict[str, float]] = {}

    # ------------------------------------------------------------ submission

    def next_device(self) -> Any:
        return self.devices[next(self._rr) % len(self.devices)]

    def submit(
        self,
        fn: Callable,
        /,
        *args: Any,
        device: Any = None,
        lane: str = COMPUTE,
        priority: str | None = None,
        **kwargs: Any,
    ) -> Submission:
        """Schedule ``fn(*args, **kwargs)``; returns a :class:`Submission`.

        ``lane="io"`` routes to the single-threaded orchestration pool (used
        by async checkpoint saves); ``lane="compute"`` (default) round-robins
        over the device ring.  ``device=MESH`` runs on the compute pool with
        no default-device pin — for tasks that span the whole mesh (stacked
        shard_map buckets).  ``priority`` is an optional caller label
        accumulated into :meth:`priority_stats` (the serving layer tags
        interactive vs bulk work).
        """
        if lane == IO:
            pool, dev = self._io_pool, None
        elif device is MESH:
            pool, dev = self._pool, None
        else:
            pool, dev = self._pool, (device if device is not None else self.next_device())
        lane_key = IO if lane == IO else COMPUTE
        with self._lock:
            if self._closed:
                raise RuntimeError(
                    "DeviceExecutor is shut down: submit after close"
                )
            self.submitted += 1
            self._lane_submitted[lane_key] += 1
            if priority is not None:
                self._prio_entry(priority)["submitted"] += 1
            if device is MESH:
                self.mesh_submitted += 1
        t_sub = time.perf_counter()
        out: Future = Future()
        try:
            pool.submit(
                self._run, out, dev, lane_key, priority, t_sub, fn, args, kwargs
            )
        except RuntimeError as e:
            # lost the race with a concurrent shutdown(): undo the counters
            # so drain() still converges, and surface a clear error instead
            # of the pool's (or, worse, a hang on a never-run future)
            with self._lock:
                self.submitted -= 1
                self._lane_submitted[lane_key] -= 1
                if priority is not None:
                    self._prio_entry(priority)["submitted"] -= 1
                if device is MESH:
                    self.mesh_submitted -= 1
            raise RuntimeError(
                "DeviceExecutor is shut down: submit after close"
            ) from e
        return Submission(out, dev, lane)

    def _prio_entry(self, priority: str) -> dict[str, float]:
        # caller holds self._lock
        return self._prio.setdefault(
            priority,
            {"submitted": 0, "started": 0, "completed": 0, "wait_s": 0.0},
        )

    def submit_after(
        self,
        sub: Submission,
        fn: Callable,
        /,
        *args: Any,
        device: Any = None,
        lane: str = COMPUTE,
        priority: str | None = None,
        **kwargs: Any,
    ) -> Submission:
        """Schedule ``fn(sub.result(), *args, **kwargs)`` once ``sub`` resolves.

        The continuation is *submitted* only when the upstream future
        completes, so it never occupies a pool thread while waiting — the
        chunk-pipelined scheduler chains each chunk's io-lane serialization
        off its compute-lane future this way without ever blocking the
        single io thread on device work.  Upstream failures propagate to
        the returned :class:`Submission` without running ``fn``.
        """
        out: Future = Future()

        def _copy(src: Future) -> None:
            exc = src.exception()
            if exc is not None:
                out.set_exception(exc)
            else:
                out.set_result(src.result())

        def _chain(upstream: Future) -> None:
            exc = upstream.exception()
            if exc is not None:
                out.set_exception(exc)
                return
            try:
                inner = self.submit(
                    fn, upstream.result(), *args,
                    device=device, lane=lane, priority=priority, **kwargs
                )
            except BaseException as e:  # e.g. pool already shut down —
                # done-callbacks swallow exceptions, so surface it on the
                # returned Submission instead of hanging its waiters
                out.set_exception(e)
                return
            inner._future.add_done_callback(_copy)

        sub._future.add_done_callback(_chain)
        return Submission(out, device, lane)

    def _run(
        self, out: Future, device: Any, lane: str, priority: str | None,
        t_sub: float, fn: Callable, args: tuple, kwargs: dict,
    ) -> None:
        t_start = time.perf_counter()
        with self._lock:
            self._lane_started[lane] += 1
            self._lane_wait_s[lane] += t_start - t_sub
            if priority is not None:
                e = self._prio_entry(priority)
                e["started"] += 1
                e["wait_s"] += t_start - t_sub
        try:
            try:
                if device is None:
                    res = fn(*args, **kwargs)
                else:
                    with jax.default_device(device):
                        res = fn(*args, **kwargs)
            except BaseException as exc:
                out.set_exception(exc)
            else:
                # resolve BEFORE counting the task complete: done-callbacks
                # (the serving demux, submit_after continuations) run inline
                # here, so drain() cannot return while a completion callback
                # is still fanning results out or chaining io-lane work
                out.set_result(res)
        finally:
            with self._lock:
                self.completed += 1
                self._lane_completed[lane] += 1
                if priority is not None:
                    self._prio_entry(priority)["completed"] += 1
                self._idle.notify_all()

    def map(self, fn: Callable, items: Sequence[Any]) -> list[Any]:
        """Fan ``fn`` over ``items`` across the device ring; ordered results."""
        return [s.result() for s in [self.submit(fn, it) for it in items]]

    # ------------------------------------------------------------- lifecycle

    def stats(self) -> dict[str, int]:
        with self._lock:
            return {
                "devices": len(self.devices),
                "submitted": self.submitted,
                "completed": self.completed,
                "mesh_submitted": self.mesh_submitted,
            }

    def lane_stats(self) -> dict[str, dict[str, float]]:
        """Per-lane service counters: depth, in-flight and cumulative wait.

        ``depth`` is tasks submitted but not yet started (queued for a pool
        thread); ``wait_s`` is the total time started tasks spent in that
        queue.  The serving layer snapshots this into ``ServiceStats`` so
        operators can see which lane is the bottleneck under load.
        """
        with self._lock:
            return {
                lane: {
                    "submitted": self._lane_submitted[lane],
                    "started": self._lane_started[lane],
                    "completed": self._lane_completed[lane],
                    "depth": self._lane_submitted[lane] - self._lane_started[lane],
                    "inflight": self._lane_started[lane] - self._lane_completed[lane],
                    "wait_s": self._lane_wait_s[lane],
                }
                for lane in (COMPUTE, IO)
            }

    def priority_stats(self) -> dict[str, dict[str, float]]:
        """Per-priority counters for submissions tagged with ``priority=``.

        Keys are whatever labels callers used (the serving layer submits
        ``"interactive"`` and ``"bulk"``); values mirror the lane counters:
        submitted/started/completed, ``depth`` (queued for a thread) and
        cumulative ``wait_s``.
        """
        with self._lock:
            return {
                p: {
                    **e,
                    "depth": e["submitted"] - e["started"],
                    "inflight": e["started"] - e["completed"],
                }
                for p, e in self._prio.items()
            }

    @property
    def closed(self) -> bool:
        with self._lock:
            return self._closed

    def drain(self, timeout: float | None = None) -> bool:
        """Block until every submitted task has completed; True on quiesce.

        Safe to call concurrently with ``submit`` (tasks submitted while
        draining extend the wait) and idempotent.  A task counts as
        complete only after its :class:`Submission` resolved and every
        ``add_done_callback`` ran — so continuations chained with
        ``submit_after`` are *submitted* (and therefore awaited) before the
        upstream task can satisfy drain.  A full dataflow chain quiesces
        under one ``drain()`` call; it cannot return between a submission
        completing and its io-lane completion callbacks finishing (the
        pre-PR-10 shutdown race).
        """
        deadline = None if timeout is None else time.monotonic() + timeout
        with self._idle:
            while self.completed < self.submitted:
                remaining = None
                if deadline is not None:
                    remaining = deadline - time.monotonic()
                    if remaining <= 0:
                        return False
                self._idle.wait(remaining)
        return True

    def shutdown(self, wait: bool = True) -> None:
        """Stop accepting work and (optionally) wait for in-flight tasks.

        Idempotent: repeated calls are no-ops.  Submissions racing a
        shutdown either run to completion or raise the clear
        ``RuntimeError`` from :meth:`submit` — they never hang.
        """
        with self._lock:
            already = self._closed
            self._closed = True
        if already:
            if wait:
                # second caller still honours wait=True semantics
                self._pool.shutdown(wait=True)
                self._io_pool.shutdown(wait=True)
            return
        self._pool.shutdown(wait=wait)
        self._io_pool.shutdown(wait=wait)
