"""Device-aware asynchronous executor — the host side of HDEM fan-out.

The paper's multi-accelerator result (Fig. 16: 96% of theoretical speedup)
comes from running independent reductions concurrently on separate devices
while the shared runtime does no per-call allocation (CMM).  This module is
the submission machinery the execution engine (:mod:`repro.core.engine`)
schedules through:

  * :class:`DeviceExecutor` — a thread pool that round-robins work over an
    explicit device list; each task runs under ``jax.default_device`` for
    its assigned device, so JAX async dispatch overlaps device compute
    across the pool while host-side stages (codebook builds, container
    packing) overlap on threads.
  * :class:`Submission` — the ``submit()/result()`` future handle.  It also
    carries the device the work was placed on, which tests and benchmarks
    use to assert real fan-out.

Two lanes, mirroring the HDEM machine model: ``compute`` (per-device
reduction work, pool sized to the device count) and ``io`` (long-running
orchestration such as an async checkpoint save, single-threaded so saves
serialize against each other and can safely *wait on* compute-lane work
without deadlocking the pool).
"""

from __future__ import annotations

import itertools
import threading
from concurrent.futures import Future, ThreadPoolExecutor
from typing import Any, Callable, Sequence

import jax

COMPUTE, IO = "compute", "io"

# Placement sentinel: run on the compute pool WITHOUT pinning a default
# device.  Used for whole-mesh work — e.g. the engine's stacked shard_map
# buckets, which span every data-axis device and must not be confined to
# one ring slot (a pinned default_device would fight the mesh sharding).
MESH = object()


class Submission:
    """Handle for one submitted task (the engine's future type)."""

    def __init__(self, future: Future, device: Any = None, lane: str = COMPUTE):
        self._future = future
        self.device = device
        self.lane = lane

    def done(self) -> bool:
        return self._future.done()

    def result(self, timeout: float | None = None) -> Any:
        return self._future.result(timeout)

    def exception(self, timeout: float | None = None):
        return self._future.exception(timeout)


class DeviceExecutor:
    """Round-robin device-aware async executor.

    ``devices`` is the placement ring — normally the mesh's ``data``-axis
    devices.  Tasks submitted without an explicit ``device`` are assigned the
    next ring slot; the task body runs with that device as JAX's default, so
    arrays it creates (and the compute they feed) land there.
    """

    def __init__(
        self,
        devices: Sequence[Any] | None = None,
        max_workers: int | None = None,
        io_workers: int = 1,
    ):
        self.devices = list(devices) if devices else list(jax.devices()[:1])
        self._pool = ThreadPoolExecutor(
            max_workers=max_workers or max(2, len(self.devices)),
            thread_name_prefix="hpdr-compute",
        )
        self._io_pool = ThreadPoolExecutor(
            max_workers=io_workers, thread_name_prefix="hpdr-io"
        )
        self._rr = itertools.count()
        self._lock = threading.Lock()
        self.submitted = 0
        self.completed = 0
        self.mesh_submitted = 0  # whole-mesh (device=MESH) tasks

    # ------------------------------------------------------------ submission

    def next_device(self) -> Any:
        return self.devices[next(self._rr) % len(self.devices)]

    def submit(
        self,
        fn: Callable,
        /,
        *args: Any,
        device: Any = None,
        lane: str = COMPUTE,
        **kwargs: Any,
    ) -> Submission:
        """Schedule ``fn(*args, **kwargs)``; returns a :class:`Submission`.

        ``lane="io"`` routes to the single-threaded orchestration pool (used
        by async checkpoint saves); ``lane="compute"`` (default) round-robins
        over the device ring.  ``device=MESH`` runs on the compute pool with
        no default-device pin — for tasks that span the whole mesh (stacked
        shard_map buckets).
        """
        if lane == IO:
            pool, dev = self._io_pool, None
        elif device is MESH:
            pool, dev = self._pool, None
        else:
            pool, dev = self._pool, (device if device is not None else self.next_device())
        with self._lock:
            self.submitted += 1
            if device is MESH:
                self.mesh_submitted += 1
        return Submission(pool.submit(self._run, dev, fn, args, kwargs), dev, lane)

    def submit_after(
        self,
        sub: Submission,
        fn: Callable,
        /,
        *args: Any,
        device: Any = None,
        lane: str = COMPUTE,
        **kwargs: Any,
    ) -> Submission:
        """Schedule ``fn(sub.result(), *args, **kwargs)`` once ``sub`` resolves.

        The continuation is *submitted* only when the upstream future
        completes, so it never occupies a pool thread while waiting — the
        chunk-pipelined scheduler chains each chunk's io-lane serialization
        off its compute-lane future this way without ever blocking the
        single io thread on device work.  Upstream failures propagate to
        the returned :class:`Submission` without running ``fn``.
        """
        out: Future = Future()

        def _copy(src: Future) -> None:
            exc = src.exception()
            if exc is not None:
                out.set_exception(exc)
            else:
                out.set_result(src.result())

        def _chain(upstream: Future) -> None:
            exc = upstream.exception()
            if exc is not None:
                out.set_exception(exc)
                return
            try:
                inner = self.submit(
                    fn, upstream.result(), *args,
                    device=device, lane=lane, **kwargs
                )
            except BaseException as e:  # e.g. pool already shut down —
                # done-callbacks swallow exceptions, so surface it on the
                # returned Submission instead of hanging its waiters
                out.set_exception(e)
                return
            inner._future.add_done_callback(_copy)

        sub._future.add_done_callback(_chain)
        return Submission(out, device, lane)

    def _run(self, device: Any, fn: Callable, args: tuple, kwargs: dict) -> Any:
        try:
            if device is None:
                return fn(*args, **kwargs)
            with jax.default_device(device):
                return fn(*args, **kwargs)
        finally:
            with self._lock:
                self.completed += 1

    def map(self, fn: Callable, items: Sequence[Any]) -> list[Any]:
        """Fan ``fn`` over ``items`` across the device ring; ordered results."""
        return [s.result() for s in [self.submit(fn, it) for it in items]]

    # ------------------------------------------------------------- lifecycle

    def stats(self) -> dict[str, int]:
        with self._lock:
            return {
                "devices": len(self.devices),
                "submitted": self.submitted,
                "completed": self.completed,
                "mesh_submitted": self.mesh_submitted,
            }

    def shutdown(self, wait: bool = True) -> None:
        self._pool.shutdown(wait=wait)
        self._io_pool.shutdown(wait=wait)
