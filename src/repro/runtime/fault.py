"""Fault tolerance & elasticity for multi-pod runs.

Mechanisms (design scales to 1000+ nodes; single-process mechanics here):

  * **Checkpoint/restart** — committed-marker checkpoints every N steps via
    the HPDR-compressed manager; on start, auto-restore from the latest
    committed step; the data stream position is part of the checkpoint, so
    the token stream resumes exactly.
  * **Preemption safety** — SIGTERM triggers a synchronous save before exit
    (`install_preemption_handler`).
  * **Elastic re-scaling** — restore accepts a different mesh: leaves are
    resharded by device_put; only the DP batch slice changes (the data
    stream is a pure function of step, not of host count).
  * **Straggler mitigation** — SPMD steps are bulk-synchronous, so the unit
    of mitigation is the *step time*: a watchdog tracks a rolling p50 and
    flags steps exceeding ``threshold ×`` median.  On a real fleet the flag
    feeds the pod-replacement policy (drain + restore on spares — exactly
    the checkpoint/restart path above, which is why checkpoint cost is the
    paper-critical number); here it logs and counts.
  * **In-graph failure containment** — gradient all-reduces pass through a
    finite-ness gate (`skip_nonfinite_update`): a pod producing NaN/Inf
    (SDC, chip fault) causes that step's update to be skipped rather than
    poisoning the weights.
"""

from __future__ import annotations

import signal
import time
from collections import deque
from dataclasses import dataclass, field
from typing import Any, Callable

import jax
import jax.numpy as jnp


@dataclass
class StragglerWatchdog:
    threshold: float = 2.0
    window: int = 50
    history: deque = field(default_factory=lambda: deque(maxlen=200))
    flagged: int = 0

    def observe(self, step_time: float) -> bool:
        self.history.append(step_time)
        if len(self.history) < 10:
            return False
        med = sorted(self.history)[len(self.history) // 2]
        slow = step_time > self.threshold * med
        if slow:
            self.flagged += 1
        return slow


def install_preemption_handler(save_fn: Callable[[], None]) -> None:
    def handler(signum, frame):
        save_fn()
        raise SystemExit(143)

    signal.signal(signal.SIGTERM, handler)


def skip_nonfinite_update(new_params: Any, old_params: Any, grads: Any):
    """Keep old params when any gradient is non-finite (SDC containment)."""
    finite = jnp.array(True)
    for g in jax.tree.leaves(grads):
        finite &= jnp.all(jnp.isfinite(g.astype(jnp.float32)))
    pick = lambda n, o: jnp.where(finite, n, o)
    return jax.tree.map(pick, new_params, old_params), finite
