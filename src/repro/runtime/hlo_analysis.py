"""Collective-traffic analysis of compiled (post-SPMD) HLO text.

``compiled.cost_analysis()`` does not expose collective bytes, so we parse
the optimized HLO: every ``all-reduce`` / ``all-gather`` / ``reduce-scatter``
/ ``all-to-all`` / ``collective-permute`` op's result shape (which is the
*per-device local* shape after partitioning) is costed with a per-type link
factor (ring all-reduce moves ≈2× payload; gather/scatter/permute ≈1×).
"""

from __future__ import annotations

import re
from collections import defaultdict
from dataclasses import dataclass, field

_DTYPE_BYTES = {
    "pred": 1, "s8": 1, "u8": 1, "s16": 2, "u16": 2, "bf16": 2, "f16": 2,
    "s32": 4, "u32": 4, "f32": 4, "s64": 8, "u64": 8, "f64": 8,
    "c64": 8, "c128": 16,
}

_COLLECTIVES = (
    "all-reduce", "all-gather", "reduce-scatter", "all-to-all",
    "collective-permute",
)

_LINK_FACTOR = {
    "all-reduce": 2.0,        # ring: reduce-scatter + all-gather, ≈2·R
    "all-gather": 1.0,        # result R, link ≈ R·(n−1)/n
    "reduce-scatter": None,   # result R = D/n, link ≈ D ⇒ factor = group size
    "all-to-all": 1.0,
    "collective-permute": 1.0,
}

_GROUPS_RE = re.compile(r"replica_groups=(?:\[[\d,]*\]<=\[\d+\]|\{\{[\d,]+\})")
_GROUPS_IOTA_RE = re.compile(r"replica_groups=\[([\d,]*)\]<=\[(\d+)\]")
_GROUPS_LIST_RE = re.compile(r"replica_groups=\{\{([\d,]+)\}")


def _group_size(op_line: str) -> int:
    m = _GROUPS_IOTA_RE.search(op_line)
    if m:
        dims = [int(x) for x in m.group(1).split(",") if x]
        return dims[-1] if dims else 1
    m = _GROUPS_LIST_RE.search(op_line)
    if m:
        return len(m.group(1).split(","))
    return 1

# result shapes before the op name, e.g.:
#   %ar = f32[8,128]{1,0} all-reduce(...)
#   %t = (f32[4]{0}, bf16[2,2]{1,0}) all-gather(...)
_OP_RE = re.compile(
    r"=\s*(?P<result>\([^)]*\)|\S+)\s+(?P<op>" + "|".join(_COLLECTIVES) + r")\(",
)
_SHAPE_RE = re.compile(r"(\w+)\[([\d,]*)\]")


@dataclass
class CollectiveStats:
    count: int = 0
    result_bytes: int = 0
    link_bytes: float = 0.0


@dataclass
class HloCollectives:
    by_type: dict = field(default_factory=lambda: defaultdict(CollectiveStats))

    @property
    def total_result_bytes(self) -> int:
        return sum(s.result_bytes for s in self.by_type.values())

    @property
    def total_link_bytes(self) -> float:
        return sum(s.link_bytes for s in self.by_type.values())

    def to_dict(self) -> dict:
        return {
            "total_result_bytes": self.total_result_bytes,
            "total_link_bytes": self.total_link_bytes,
            "by_type": {
                k: {"count": v.count, "result_bytes": v.result_bytes,
                    "link_bytes": v.link_bytes}
                for k, v in self.by_type.items()
            },
        }


def _shape_bytes(result: str) -> int:
    total = 0
    for dtype, dims in _SHAPE_RE.findall(result):
        if dtype not in _DTYPE_BYTES:
            continue
        n = 1
        if dims:
            for d in dims.split(","):
                n *= int(d)
        total += n * _DTYPE_BYTES[dtype]
    return total


def parse_collectives(hlo_text: str, scale: float = 1.0) -> HloCollectives:
    out = HloCollectives()
    for m in _OP_RE.finditer(hlo_text):
        op = m.group("op")
        nbytes = _shape_bytes(m.group("result"))
        factor = _LINK_FACTOR[op]
        if factor is None:  # reduce-scatter: link bytes ≈ result × group size
            line_end = hlo_text.find("\n", m.end())
            factor = float(_group_size(hlo_text[m.start(): line_end]))
        st = out.by_type[op]
        st.count += 1
        st.result_bytes += int(nbytes * scale)
        st.link_bytes += nbytes * factor * scale
    return out


def count_op(hlo_text: str, opname: str) -> int:
    return len(re.findall(rf"\b{re.escape(opname)}\(", hlo_text))


# ---------------------------------------------------------------------------
# while-loop-aware accounting
#
# XLA's cost_analysis (and a naive text scan) counts a while body's ops ONCE,
# regardless of trip count — with lax.scan over layers that undercounts
# per-layer collectives by ~L×.  We split the HLO into computations, find
# each while's (condition, body, trip_count), and scale body computations by
# their trip counts (nested whiles multiply).
# ---------------------------------------------------------------------------

_COMP_HEAD_RE = re.compile(r"^(?:ENTRY\s+)?%?([\w\.\-]+)\s*\(", re.M)
_WHILE_RE = re.compile(
    r"while\([^)]*\)\s*,?\s*condition=\s*%?([\w\.\-]+)\s*,\s*body=\s*%?([\w\.\-]+)"
)
_TRIP_RE = re.compile(r"constant\((\d+)\)")


def split_computations(hlo_text: str) -> dict[str, str]:
    """Map computation name → its body text (brace-balanced sections).

    Headers look like ``%name (args...) -> result {`` (possibly with nested
    parens/layout braces in the signature), so the opening brace is the last
    ``{`` on the header line; bodies are brace-balanced from there.
    """
    sections: dict[str, str] = {}
    for m in _COMP_HEAD_RE.finditer(hlo_text):
        # only top-level headers: column 0 (op lines inside bodies are indented)
        if m.start() > 0 and hlo_text[m.start() - 1] != "\n":
            continue
        name = m.group(1)
        line_end = hlo_text.find("\n", m.end())
        if line_end < 0:
            line_end = len(hlo_text)
        start = hlo_text.rfind("{", m.end(), line_end + 1)
        if start < 0:
            continue
        depth, i = 0, start
        while i < len(hlo_text):
            c = hlo_text[i]
            if c == "{":
                depth += 1
            elif c == "}":
                depth -= 1
                if depth == 0:
                    break
            i += 1
        sections[name] = hlo_text[start : i + 1]
    return sections


def _trip_count(cond_text: str) -> int:
    """Best-effort loop bound from the condition computation's constant."""
    consts = [int(x) for x in _TRIP_RE.findall(cond_text)]
    consts = [c for c in consts if c > 1]
    return max(consts) if consts else 1


def computation_scales(hlo_text: str) -> dict[str, float]:
    """Execution multiplicity per computation (nested whiles multiply)."""
    sections = split_computations(hlo_text)
    # edges: computation -> (callee_body, trip)
    calls: dict[str, list[tuple[str, int]]] = {name: [] for name in sections}
    for name, body in sections.items():
        for wm in _WHILE_RE.finditer(body):
            cond, wbody = wm.group(1), wm.group(2)
            trip = _trip_count(sections.get(cond, ""))
            calls[name].append((wbody, trip))
    scales: dict[str, float] = {name: 1.0 for name in sections}

    # propagate from entry outward (computations are a DAG of calls)
    def visit(name: str, scale: float, depth=0):
        if depth > 16 or name not in sections:
            return
        scales[name] = max(scales.get(name, 1.0), scale)
        for child, trip in calls.get(name, []):
            visit(child, scale * trip, depth + 1)

    # entry = the computation not referenced as a body/cond: approximate by
    # visiting every section from scale of 1 and whiles multiplying downward.
    referenced = {c for lst in calls.values() for c, _ in lst}
    roots = [n for n in sections if n not in referenced]
    for r in roots:
        visit(r, 1.0)
    return scales


def parse_collectives_scaled(hlo_text: str) -> HloCollectives:
    """Collective traffic with while-body ops scaled by their trip counts."""
    sections = split_computations(hlo_text)
    scales = computation_scales(hlo_text)
    out = HloCollectives()
    for name, body in sections.items():
        sub = parse_collectives(body, scale=scales.get(name, 1.0))
        for op, st in sub.by_type.items():
            agg = out.by_type[op]
            agg.count += st.count
            agg.result_bytes += st.result_bytes
            agg.link_bytes += st.link_bytes
    return out


def cost_analysis_dict(compiled) -> dict:
    """``Compiled.cost_analysis()`` normalized across JAX versions.

    JAX 0.4.x returns a one-element list of per-program dicts; newer JAX
    returns the dict directly.  Either way the result here is a plain dict
    (empty when XLA reports nothing).
    """
    cost = compiled.cost_analysis()
    if isinstance(cost, (list, tuple)):
        cost = cost[0] if cost else {}
    return dict(cost) if cost else {}
