"""Aggregated parallel-I/O writer — coalesced, aligned segment files.

The paper's at-scale I/O result (up to 4x parallel-write acceleration,
Figs. 17-18) comes from *aggregation*: many small per-leaf/per-chunk
compressed blobs are coalesced into a few large, aligned writes instead of
one syscall (or one file) per object.  This module is the framework's
node-local analogue of the ADIOS2 aggregating writer:

  * :class:`AggregatedWriter` — append-only segment file writer.  ``add``
    places each named blob at the next aligned offset and buffers it into a
    large write buffer (a zero-copy iovec list); full buffers are flushed
    with one gathered positional ``pwritev`` on a dedicated flush thread,
    so serialization of leaf *i+1* overlaps the disk write of leaf *i*.  ``close`` appends a JSON **segment
    directory** plus a fixed trailer, so a reader can locate (and
    integrity-check) any segment without scanning the file.
  * :class:`AggregatedReader` — the decode side: parses the trailer once,
    then serves exact-range ``os.pread`` calls per segment — a restore that
    needs three leaves touches exactly three byte ranges.

The directory is *additive*: the bytes before it are whatever the caller
streamed (e.g. a framed ``HPDS`` chunk stream, or back-to-back ``HPDR``
containers), so readers that predate the directory still parse the file as
a plain byte stream and simply ignore the trailer.

Trailer layout (fixed 24 bytes at EOF)::

    [directory JSON] [uint64 dir_offset] [uint64 dir_nbytes] [b"HPDRSEG1"]
"""

from __future__ import annotations

import json
import os
import threading
import zlib
from concurrent.futures import Future, ThreadPoolExecutor
from pathlib import Path
from typing import Any, Iterator

import numpy as np

TRAILER_MAGIC = b"HPDRSEG1"
_TRAILER_FIXED = 8 + 8 + len(TRAILER_MAGIC)
DIRECTORY_VERSION = 1
DEFAULT_ALIGN = 4096
DEFAULT_BUFFER = 4 << 20


def _container_error(msg: str) -> Exception:
    # runtime-layer module: core.container is imported lazily so importing
    # repro.runtime.io never drags the whole core package (and its jax
    # surface) in at module-import time
    from ..core.container import ContainerError

    return ContainerError(msg)


def align_up(n: int, align: int) -> int:
    return n if align <= 1 else -(-n // align) * align


def _pwrite_full(fd: int, data: bytes, offset: int) -> None:
    """Positional write that survives short writes (signals, quotas, NFS).

    A partial transfer silently recorded as complete would only surface at
    restore time as a crc mismatch — after the data is already lost — so
    the writer loops until every byte lands and raises on a zero-progress
    write.
    """
    view = memoryview(data)
    while view:
        n = os.pwrite(fd, view, offset)
        if n <= 0:
            raise OSError(f"pwrite wrote {n} of {len(view)} bytes")
        view = view[n:]
        offset += n


#: Linux IOV_MAX is 1024; stay under it per gathered write
_IOV_MAX = 1024


def _pwritev_full(fd: int, buffers: list, offset: int) -> None:
    """Gathered positional write of a buffer list, zero intermediate copies.

    The coalescing buffer is a *list* of caller blobs (plus padding runs);
    joining them into one ``bytes`` before ``pwrite`` would memcpy the
    entire payload a second time.  ``os.pwritev`` writes the scatter list
    directly from the caller's buffers.  Short writes advance through the
    iovec (slicing only the one partially-written buffer); platforms
    without ``pwritev`` fall back to per-buffer ``pwrite``.
    """
    bufs = [memoryview(b) for b in buffers if len(b)]
    if not hasattr(os, "pwritev"):  # pragma: no cover - non-Linux fallback
        for b in bufs:
            _pwrite_full(fd, b, offset)
            offset += len(b)
        return
    while bufs:
        iov = bufs[:_IOV_MAX]
        n = os.pwritev(fd, iov, offset)
        if n <= 0:
            raise OSError(f"pwritev wrote {n} bytes")
        offset += n
        consumed = 0
        while iov and n >= len(iov[0]):
            n -= len(iov[0])
            iov.pop(0)
            consumed += 1
        del bufs[:consumed]
        if n:  # partial buffer: keep its unwritten tail at the head
            bufs[0] = bufs[0][n:]


class AggregatedWriter:
    """Coalescing aligned segment writer with an async flush lane.

    ``add(name, blob)`` assigns the blob the next ``align``-rounded offset
    and appends it (plus padding) to an in-memory write buffer; once the
    buffer exceeds ``buffer_bytes`` it is handed to the single flush thread
    as one positional ``pwrite`` — large, aligned, order-independent
    writes, which is what parallel filesystems reward.  ``parallel=False``
    degrades to synchronous writes (same bytes, same layout).

    ``meta`` rides in the directory verbatim (JSON-able) — stream headers,
    step numbers, anything a reader needs before touching segments.

    Durability knobs (both default off — pure streaming writers pay
    nothing):  ``fsync=True`` fsyncs the file (and, with ``atomic``, its
    parent directory) before close returns; ``atomic=True`` stages the
    whole file — data, directory, trailer — under a temp name and commits
    it with one ``os.replace``, so a crash mid-close never leaves ``path``
    parsing as a valid segment file with a stale or truncated directory.
    """

    def __init__(
        self,
        path: str | Path,
        *,
        align: int = DEFAULT_ALIGN,
        buffer_bytes: int = DEFAULT_BUFFER,
        parallel: bool = True,
        meta: dict | None = None,
        fsync: bool = False,
        atomic: bool = False,
    ):
        self.path = Path(path)
        self.align = max(1, int(align))
        self.buffer_bytes = int(buffer_bytes)
        self.meta = dict(meta or {})
        self.fsync = bool(fsync)
        self.atomic = bool(atomic)
        # atomic mode: every byte — data, directory, trailer — lands in a
        # temp file that is renamed over `path` only after a fully-written
        # (and optionally fsynced) trailer.  A crash mid-close can never
        # leave `path` parsing as a valid segment file with a stale or
        # partial directory: either the old file is intact or the new one
        # is complete.
        self._write_path = (
            self.path.with_name(f"{self.path.name}.tmp{os.getpid()}")
            if self.atomic
            else self.path
        )
        self._fd = os.open(
            str(self._write_path), os.O_WRONLY | os.O_CREAT | os.O_TRUNC, 0o644
        )
        self._offset = 0          # logical end-of-data offset
        # coalescing buffer: a LIST of caller blobs + padding runs, written
        # with one gathered pwritev per flush — zero intermediate memcpy
        # (the naive bytearray accumulator copied every payload byte twice
        # before the syscall, which on a page-cached filesystem cost more
        # than the syscalls it saved)
        self._buf: list[bytes] = []
        self._buf_len = 0
        self._buf_off = 0         # file offset of the buffer's first byte
        self._segments: dict[str, dict] = {}
        self._flusher: ThreadPoolExecutor | None = (
            ThreadPoolExecutor(1, thread_name_prefix="hpdr-io-flush")
            if parallel
            else None
        )
        self._pending: list[Future] = []
        self._lock = threading.Lock()
        self._closed = False
        self.stats = {"segments": 0, "data_bytes": 0, "pad_bytes": 0,
                      "writes": 0, "async_writes": 0}

    # ------------------------------------------------------------ write path

    def write_raw(self, raw: bytes) -> int:
        """Append unaligned preamble bytes (e.g. a stream header); returns
        the offset they were placed at.  Not recorded as a segment."""
        off = self._offset
        self._buf.append(bytes(raw))
        self._buf_len += len(raw)
        self._offset += len(raw)
        self._maybe_flush()
        return off

    def add(self, name: str, blob: bytes) -> int:
        """Append one named segment at the next aligned offset; returns the
        absolute file offset the segment starts at."""
        if self._closed:
            raise ValueError("writer is closed")
        if name in self._segments:
            raise ValueError(f"duplicate segment {name!r}")
        blob = bytes(blob)
        target = align_up(self._offset, self.align)
        pad = target - self._offset
        if pad:
            self._buf.append(b"\x00" * pad)
            self._buf_len += pad
            self.stats["pad_bytes"] += pad
        self._buf.append(blob)
        self._buf_len += len(blob)
        self._offset = target + len(blob)
        self._segments[name] = {
            "offset": target,
            "nbytes": len(blob),
            "crc32": zlib.crc32(blob) & 0xFFFFFFFF,
        }
        self.stats["segments"] += 1
        self.stats["data_bytes"] += len(blob)
        self._maybe_flush()
        return target

    def _maybe_flush(self) -> None:
        if self._buf_len >= self.buffer_bytes:
            self.flush()

    def flush(self) -> None:
        """Hand the current buffer list to the flush lane as one pwritev."""
        if not self._buf:
            return
        chunk, off = self._buf, self._buf_off
        self._buf = []
        self._buf_len = 0
        self._buf_off = self._offset
        self.stats["writes"] += 1
        if self._flusher is not None:
            self.stats["async_writes"] += 1
            self._pending.append(
                self._flusher.submit(_pwritev_full, self._fd, chunk, off)
            )
        else:
            _pwritev_full(self._fd, chunk, off)

    # -------------------------------------------------------------- lifecycle

    def directory(self) -> dict:
        return {
            "version": DIRECTORY_VERSION,
            "align": self.align,
            "segments": {k: dict(v) for k, v in self._segments.items()},
            "meta": self.meta,
        }

    def close(self) -> dict:
        """Flush everything, append directory + trailer; returns the
        directory dict (what :class:`AggregatedReader` will see)."""
        if self._closed:
            return self.directory()
        directory = self.directory()
        dbytes = json.dumps(directory).encode()
        trailer = (
            dbytes
            + np.uint64(self._offset).tobytes()
            + np.uint64(len(dbytes)).tobytes()
            + TRAILER_MAGIC
        )
        self._buf.append(trailer)
        self._buf_len += len(trailer)
        self._offset += len(trailer)
        self.flush()
        for f in self._pending:
            f.result()
        if self._flusher is not None:
            self._flusher.shutdown(wait=True)
        if self.fsync:
            os.fsync(self._fd)
        os.close(self._fd)
        if self.atomic:
            os.replace(self._write_path, self.path)
            if self.fsync:
                # the rename is only durable once the parent directory
                # entry is — fsync it so a crash cannot roll the commit back
                dfd = os.open(str(self.path.parent), os.O_RDONLY)
                try:
                    os.fsync(dfd)
                finally:
                    os.close(dfd)
        self._closed = True
        return directory

    def __enter__(self) -> "AggregatedWriter":
        return self

    def __exit__(self, exc_type, *exc) -> None:
        if exc_type is not None and not self._closed:
            # abandon WITHOUT writing a directory: a torn write must never
            # look like a committed file.  Queued flushes are cancelled but
            # a pwrite already running cannot be — drain the flush thread
            # before closing the fd, or the close races the in-flight
            # write (and a recycled fd number could corrupt another file).
            for f in self._pending:
                f.cancel()
            if self._flusher is not None:
                self._flusher.shutdown(wait=True)
            os.close(self._fd)
            if self.atomic:
                try:  # abandon the temp file; `path` was never touched
                    os.unlink(self._write_path)
                except OSError:
                    pass
            self._closed = True
            return
        self.close()


class AggregatedReader:
    """Exact-range ``pread`` access to an aggregated segment file."""

    def __init__(self, path: str | Path):
        self.path = Path(path)
        self._fd = os.open(str(self.path), os.O_RDONLY)
        self._lock = threading.Lock()
        self._closed = False
        self.preads = 0  # observable for "reads exactly what it needs" tests
        self.pread_bytes = 0  # bytes actually fetched (progressive-prefix stat)
        try:
            self.directory = self._read_directory()
        except Exception:
            os.close(self._fd)
            self._closed = True
            raise
        self.segments: dict[str, dict] = self.directory["segments"]
        self.meta: dict = self.directory.get("meta", {})

    def _read_directory(self) -> dict:
        size = os.fstat(self._fd).st_size
        if size < _TRAILER_FIXED:
            raise _container_error(
                f"{self.path}: no segment directory (file too short)"
            )
        tail = os.pread(self._fd, _TRAILER_FIXED, size - _TRAILER_FIXED)
        if tail[-len(TRAILER_MAGIC):] != TRAILER_MAGIC:
            raise _container_error(
                f"{self.path}: no segment directory trailer"
            )
        dir_off = int(np.frombuffer(tail[:8], np.uint64)[0])
        dir_len = int(np.frombuffer(tail[8:16], np.uint64)[0])
        if dir_off + dir_len + _TRAILER_FIXED > size:
            raise _container_error(
                f"{self.path}: segment directory out of bounds"
            )
        raw = os.pread(self._fd, dir_len, dir_off)
        try:
            directory = json.loads(raw.decode())
        except (UnicodeDecodeError, json.JSONDecodeError) as e:
            raise _container_error(
                f"{self.path}: corrupt segment directory: {e}"
            ) from e
        if directory.get("version") != DIRECTORY_VERSION:
            raise _container_error(
                f"{self.path}: unsupported directory version "
                f"{directory.get('version')!r}"
            )
        return directory

    # ------------------------------------------------------------- read path

    def names(self) -> list[str]:
        return list(self.segments)

    def __contains__(self, name: str) -> bool:
        return name in self.segments

    def __iter__(self) -> Iterator[str]:
        return iter(self.segments)

    def pread(self, offset: int, nbytes: int) -> bytes:
        raw = os.pread(self._fd, nbytes, offset)
        with self._lock:
            self.preads += 1
            self.pread_bytes += len(raw)
        return raw

    def read(self, name: str, *, verify: bool = True) -> bytes:
        """One segment's exact bytes (crc-checked unless ``verify=False``)."""
        try:
            seg = self.segments[name]
        except KeyError:
            raise _container_error(
                f"{self.path}: no segment {name!r} in directory"
            ) from None
        raw = self.pread(int(seg["offset"]), int(seg["nbytes"]))
        if len(raw) != int(seg["nbytes"]):
            raise _container_error(
                f"{self.path}: segment {name!r} truncated "
                f"({len(raw)} bytes < {seg['nbytes']})"
            )
        if verify:
            crc = zlib.crc32(raw) & 0xFFFFFFFF
            if crc != int(seg["crc32"]):
                raise _container_error(
                    f"{self.path}: segment {name!r} crc32 {crc:#010x} != "
                    f"recorded {int(seg['crc32']):#010x}"
                )
        return raw

    # -------------------------------------------------------------- lifecycle

    def close(self) -> None:
        if not self._closed:
            os.close(self._fd)
            self._closed = True

    def __enter__(self) -> "AggregatedReader":
        return self

    def __exit__(self, *exc) -> None:
        self.close()

    def __del__(self) -> None:  # pragma: no cover - GC safety net
        try:
            self.close()
        except Exception:
            pass


def has_directory(path: str | Path) -> bool:
    """Cheap probe: does ``path`` end in an aggregated-segment trailer?"""
    try:
        size = os.path.getsize(path)
        if size < _TRAILER_FIXED:
            return False
        with open(path, "rb") as f:
            f.seek(size - len(TRAILER_MAGIC))
            return f.read(len(TRAILER_MAGIC)) == TRAILER_MAGIC
    except OSError:
        return False


# ---------------------------------------------------------------------------
# multi-host shard sets (per-host aggregated files + global manifest)
# ---------------------------------------------------------------------------


def shard_file_name(host_id: int) -> str:
    """Canonical per-host shard file name: ``leaves-<host>.hpdr``."""
    return f"leaves-{int(host_id):04d}.hpdr"


def stitch_shard_directories(
    directory: str | Path, shard_files: dict[str, str]
) -> dict:
    """Merge per-host shard segment directories into one global view.

    The coordinator's half of the multi-host save: opens each host's shard
    (trailer parse only — zero segment preads), validates it, and returns::

        {"shards": {host: {"file", "segments": {...}, "meta": {...}}},
         "segments": total, "data_bytes": total}

    Any shard whose trailer is missing/corrupt raises ``ContainerError``
    naming that shard — a torn host write fails the global commit loudly.
    """
    directory = Path(directory)
    out: dict = {"shards": {}, "segments": 0, "data_bytes": 0}
    for host, fname in sorted(shard_files.items(), key=lambda kv: str(kv[0])):
        with AggregatedReader(directory / fname) as r:
            segs = {k: dict(v) for k, v in r.segments.items()}
            out["shards"][str(host)] = {
                "file": fname,
                "segments": segs,
                "meta": dict(r.meta),
            }
            out["segments"] += len(segs)
            out["data_bytes"] += sum(int(s["nbytes"]) for s in segs.values())
    return out


class ShardSetReader:
    """Topology-aware reads across a set of per-host shard files.

    ``local`` names the shard owned by the calling host (or ``None`` when
    the reader has no locality — e.g. a single-process restore of a
    multi-host checkpoint).  Shards open *lazily*: a restore scoped to
    healthy shards never touches a corrupt one, and a same-topology restore
    opens exactly its local shard.  ``stats`` is the observable the
    locality tests assert on::

        {"local_preads": n, "cross_preads": n,
         "local_bytes": n, "cross_bytes": n,
         "shards_opened": [...], "preads_by_shard": {shard: n}}
    """

    def __init__(
        self,
        directory: str | Path,
        shard_files: dict[str, str],
        *,
        local: str | None = None,
    ):
        self.directory = Path(directory)
        self.shard_files = {str(k): v for k, v in shard_files.items()}
        self.local = str(local) if local is not None else None
        self._readers: dict[str, AggregatedReader] = {}
        self.stats: dict = {
            "local_preads": 0,
            "cross_preads": 0,
            "local_bytes": 0,
            "cross_bytes": 0,
            "shards_opened": [],
            "preads_by_shard": {},
        }

    def reader(self, shard: str) -> AggregatedReader:
        shard = str(shard)
        r = self._readers.get(shard)
        if r is None:
            fname = self.shard_files.get(shard)
            if fname is None:
                raise _container_error(
                    f"{self.directory}: no shard {shard!r} in manifest "
                    f"(shards: {sorted(self.shard_files)})"
                )
            r = AggregatedReader(self.directory / fname)
            self._readers[shard] = r
            self.stats["shards_opened"].append(shard)
        return r

    def read(self, shard: str, name: str, *, verify: bool = True) -> bytes:
        shard = str(shard)
        raw = self.reader(shard).read(name, verify=verify)
        local = shard == self.local
        self.stats["local_preads" if local else "cross_preads"] += 1
        self.stats["local_bytes" if local else "cross_bytes"] += len(raw)
        by = self.stats["preads_by_shard"]
        by[shard] = by.get(shard, 0) + 1
        return raw

    def close(self) -> None:
        for r in self._readers.values():
            r.close()
        self._readers.clear()

    def __enter__(self) -> "ShardSetReader":
        return self

    def __exit__(self, *exc) -> None:
        self.close()


def serialization_probe(
    nbytes: int,
    *,
    repeat: int = 3,
    clock=None,
) -> float:
    """Measure the host serialization cost the writer pays per segment.

    Times exactly the per-``add`` host work of :class:`AggregatedWriter` —
    a crc32 pass plus a copy into the (aligned) coalescing buffer — over a
    ``nbytes`` payload, best-of-``repeat``.  The calibration layer
    (``runtime/calibrate.py``) uses this to separate wire-framing cost
    from codec D2H cost when fitting the io-lane model.

    ``clock`` defaults to ``time.perf_counter``; tests inject a stub.
    Returns seconds (≥ 1 ns to keep downstream throughput fits finite).
    """
    import time as _time

    clock = clock or _time.perf_counter
    payload = np.random.default_rng(0).integers(
        0, 256, size=max(int(nbytes), 1), dtype=np.uint8
    ).tobytes()
    buf = bytearray(align_up(len(payload), DEFAULT_ALIGN))
    best = float("inf")
    for _ in range(max(1, int(repeat))):
        t0 = clock()
        zlib.crc32(payload)
        buf[: len(payload)] = payload
        t1 = clock()
        best = min(best, t1 - t0)
    return max(best, 1e-9)
