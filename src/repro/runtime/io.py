"""Aggregated parallel-I/O writer — coalesced, aligned segment files.

The paper's at-scale I/O result (up to 4x parallel-write acceleration,
Figs. 17-18) comes from *aggregation*: many small per-leaf/per-chunk
compressed blobs are coalesced into a few large, aligned writes instead of
one syscall (or one file) per object.  This module is the framework's
node-local analogue of the ADIOS2 aggregating writer:

  * :class:`AggregatedWriter` — append-only segment file writer.  ``add``
    places each named blob at the next aligned offset and buffers it into a
    large write buffer; full buffers are flushed with positional ``pwrite``
    on a dedicated flush thread, so serialization of leaf *i+1* overlaps
    the disk write of leaf *i*.  ``close`` appends a JSON **segment
    directory** plus a fixed trailer, so a reader can locate (and
    integrity-check) any segment without scanning the file.
  * :class:`AggregatedReader` — the decode side: parses the trailer once,
    then serves exact-range ``os.pread`` calls per segment — a restore that
    needs three leaves touches exactly three byte ranges.

The directory is *additive*: the bytes before it are whatever the caller
streamed (e.g. a framed ``HPDS`` chunk stream, or back-to-back ``HPDR``
containers), so readers that predate the directory still parse the file as
a plain byte stream and simply ignore the trailer.

Trailer layout (fixed 24 bytes at EOF)::

    [directory JSON] [uint64 dir_offset] [uint64 dir_nbytes] [b"HPDRSEG1"]
"""

from __future__ import annotations

import json
import os
import threading
import zlib
from concurrent.futures import Future, ThreadPoolExecutor
from pathlib import Path
from typing import Any, Iterator

import numpy as np

TRAILER_MAGIC = b"HPDRSEG1"
_TRAILER_FIXED = 8 + 8 + len(TRAILER_MAGIC)
DIRECTORY_VERSION = 1
DEFAULT_ALIGN = 4096
DEFAULT_BUFFER = 4 << 20


def _container_error(msg: str) -> Exception:
    # runtime-layer module: core.container is imported lazily so importing
    # repro.runtime.io never drags the whole core package (and its jax
    # surface) in at module-import time
    from ..core.container import ContainerError

    return ContainerError(msg)


def align_up(n: int, align: int) -> int:
    return n if align <= 1 else -(-n // align) * align


def _pwrite_full(fd: int, data: bytes, offset: int) -> None:
    """Positional write that survives short writes (signals, quotas, NFS).

    A partial transfer silently recorded as complete would only surface at
    restore time as a crc mismatch — after the data is already lost — so
    the writer loops until every byte lands and raises on a zero-progress
    write.
    """
    view = memoryview(data)
    while view:
        n = os.pwrite(fd, view, offset)
        if n <= 0:
            raise OSError(f"pwrite wrote {n} of {len(view)} bytes")
        view = view[n:]
        offset += n


class AggregatedWriter:
    """Coalescing aligned segment writer with an async flush lane.

    ``add(name, blob)`` assigns the blob the next ``align``-rounded offset
    and appends it (plus padding) to an in-memory write buffer; once the
    buffer exceeds ``buffer_bytes`` it is handed to the single flush thread
    as one positional ``pwrite`` — large, aligned, order-independent
    writes, which is what parallel filesystems reward.  ``parallel=False``
    degrades to synchronous writes (same bytes, same layout).

    ``meta`` rides in the directory verbatim (JSON-able) — stream headers,
    step numbers, anything a reader needs before touching segments.
    """

    def __init__(
        self,
        path: str | Path,
        *,
        align: int = DEFAULT_ALIGN,
        buffer_bytes: int = DEFAULT_BUFFER,
        parallel: bool = True,
        meta: dict | None = None,
    ):
        self.path = Path(path)
        self.align = max(1, int(align))
        self.buffer_bytes = int(buffer_bytes)
        self.meta = dict(meta or {})
        self._fd = os.open(
            str(self.path), os.O_WRONLY | os.O_CREAT | os.O_TRUNC, 0o644
        )
        self._offset = 0          # logical end-of-data offset
        self._buf = bytearray()
        self._buf_off = 0         # file offset of the buffer's first byte
        self._segments: dict[str, dict] = {}
        self._flusher: ThreadPoolExecutor | None = (
            ThreadPoolExecutor(1, thread_name_prefix="hpdr-io-flush")
            if parallel
            else None
        )
        self._pending: list[Future] = []
        self._lock = threading.Lock()
        self._closed = False
        self.stats = {"segments": 0, "data_bytes": 0, "pad_bytes": 0,
                      "writes": 0, "async_writes": 0}

    # ------------------------------------------------------------ write path

    def write_raw(self, raw: bytes) -> int:
        """Append unaligned preamble bytes (e.g. a stream header); returns
        the offset they were placed at.  Not recorded as a segment."""
        off = self._offset
        self._buf += raw
        self._offset += len(raw)
        self._maybe_flush()
        return off

    def add(self, name: str, blob: bytes) -> int:
        """Append one named segment at the next aligned offset; returns the
        absolute file offset the segment starts at."""
        if self._closed:
            raise ValueError("writer is closed")
        if name in self._segments:
            raise ValueError(f"duplicate segment {name!r}")
        blob = bytes(blob)
        target = align_up(self._offset, self.align)
        pad = target - self._offset
        if pad:
            self._buf += b"\x00" * pad
            self.stats["pad_bytes"] += pad
        self._buf += blob
        self._offset = target + len(blob)
        self._segments[name] = {
            "offset": target,
            "nbytes": len(blob),
            "crc32": zlib.crc32(blob) & 0xFFFFFFFF,
        }
        self.stats["segments"] += 1
        self.stats["data_bytes"] += len(blob)
        self._maybe_flush()
        return target

    def _maybe_flush(self) -> None:
        if len(self._buf) >= self.buffer_bytes:
            self.flush()

    def flush(self) -> None:
        """Hand the current buffer to the flush lane as one pwrite."""
        if not self._buf:
            return
        chunk, off = bytes(self._buf), self._buf_off
        self._buf = bytearray()
        self._buf_off = self._offset
        self.stats["writes"] += 1
        if self._flusher is not None:
            self.stats["async_writes"] += 1
            self._pending.append(
                self._flusher.submit(_pwrite_full, self._fd, chunk, off)
            )
        else:
            _pwrite_full(self._fd, chunk, off)

    # -------------------------------------------------------------- lifecycle

    def directory(self) -> dict:
        return {
            "version": DIRECTORY_VERSION,
            "align": self.align,
            "segments": {k: dict(v) for k, v in self._segments.items()},
            "meta": self.meta,
        }

    def close(self) -> dict:
        """Flush everything, append directory + trailer; returns the
        directory dict (what :class:`AggregatedReader` will see)."""
        if self._closed:
            return self.directory()
        directory = self.directory()
        dbytes = json.dumps(directory).encode()
        trailer = (
            dbytes
            + np.uint64(self._offset).tobytes()
            + np.uint64(len(dbytes)).tobytes()
            + TRAILER_MAGIC
        )
        self._buf += trailer
        self._offset += len(trailer)
        self.flush()
        for f in self._pending:
            f.result()
        if self._flusher is not None:
            self._flusher.shutdown(wait=True)
        os.close(self._fd)
        self._closed = True
        return directory

    def __enter__(self) -> "AggregatedWriter":
        return self

    def __exit__(self, exc_type, *exc) -> None:
        if exc_type is not None and not self._closed:
            # abandon WITHOUT writing a directory: a torn write must never
            # look like a committed file.  Queued flushes are cancelled but
            # a pwrite already running cannot be — drain the flush thread
            # before closing the fd, or the close races the in-flight
            # write (and a recycled fd number could corrupt another file).
            for f in self._pending:
                f.cancel()
            if self._flusher is not None:
                self._flusher.shutdown(wait=True)
            os.close(self._fd)
            self._closed = True
            return
        self.close()


class AggregatedReader:
    """Exact-range ``pread`` access to an aggregated segment file."""

    def __init__(self, path: str | Path):
        self.path = Path(path)
        self._fd = os.open(str(self.path), os.O_RDONLY)
        self._lock = threading.Lock()
        self._closed = False
        self.preads = 0  # observable for "reads exactly what it needs" tests
        try:
            self.directory = self._read_directory()
        except Exception:
            os.close(self._fd)
            self._closed = True
            raise
        self.segments: dict[str, dict] = self.directory["segments"]
        self.meta: dict = self.directory.get("meta", {})

    def _read_directory(self) -> dict:
        size = os.fstat(self._fd).st_size
        if size < _TRAILER_FIXED:
            raise _container_error(
                f"{self.path}: no segment directory (file too short)"
            )
        tail = os.pread(self._fd, _TRAILER_FIXED, size - _TRAILER_FIXED)
        if tail[-len(TRAILER_MAGIC):] != TRAILER_MAGIC:
            raise _container_error(
                f"{self.path}: no segment directory trailer"
            )
        dir_off = int(np.frombuffer(tail[:8], np.uint64)[0])
        dir_len = int(np.frombuffer(tail[8:16], np.uint64)[0])
        if dir_off + dir_len + _TRAILER_FIXED > size:
            raise _container_error(
                f"{self.path}: segment directory out of bounds"
            )
        raw = os.pread(self._fd, dir_len, dir_off)
        try:
            directory = json.loads(raw.decode())
        except (UnicodeDecodeError, json.JSONDecodeError) as e:
            raise _container_error(
                f"{self.path}: corrupt segment directory: {e}"
            ) from e
        if directory.get("version") != DIRECTORY_VERSION:
            raise _container_error(
                f"{self.path}: unsupported directory version "
                f"{directory.get('version')!r}"
            )
        return directory

    # ------------------------------------------------------------- read path

    def names(self) -> list[str]:
        return list(self.segments)

    def __contains__(self, name: str) -> bool:
        return name in self.segments

    def __iter__(self) -> Iterator[str]:
        return iter(self.segments)

    def pread(self, offset: int, nbytes: int) -> bytes:
        with self._lock:
            self.preads += 1
        return os.pread(self._fd, nbytes, offset)

    def read(self, name: str, *, verify: bool = True) -> bytes:
        """One segment's exact bytes (crc-checked unless ``verify=False``)."""
        try:
            seg = self.segments[name]
        except KeyError:
            raise _container_error(
                f"{self.path}: no segment {name!r} in directory"
            ) from None
        raw = self.pread(int(seg["offset"]), int(seg["nbytes"]))
        if len(raw) != int(seg["nbytes"]):
            raise _container_error(
                f"{self.path}: segment {name!r} truncated "
                f"({len(raw)} bytes < {seg['nbytes']})"
            )
        if verify:
            crc = zlib.crc32(raw) & 0xFFFFFFFF
            if crc != int(seg["crc32"]):
                raise _container_error(
                    f"{self.path}: segment {name!r} crc32 {crc:#010x} != "
                    f"recorded {int(seg['crc32']):#010x}"
                )
        return raw

    # -------------------------------------------------------------- lifecycle

    def close(self) -> None:
        if not self._closed:
            os.close(self._fd)
            self._closed = True

    def __enter__(self) -> "AggregatedReader":
        return self

    def __exit__(self, *exc) -> None:
        self.close()

    def __del__(self) -> None:  # pragma: no cover - GC safety net
        try:
            self.close()
        except Exception:
            pass


def has_directory(path: str | Path) -> bool:
    """Cheap probe: does ``path`` end in an aggregated-segment trailer?"""
    try:
        size = os.path.getsize(path)
        if size < _TRAILER_FIXED:
            return False
        with open(path, "rb") as f:
            f.seek(size - len(TRAILER_MAGIC))
            return f.read(len(TRAILER_MAGIC)) == TRAILER_MAGIC
    except OSError:
        return False


def serialization_probe(
    nbytes: int,
    *,
    repeat: int = 3,
    clock=None,
) -> float:
    """Measure the host serialization cost the writer pays per segment.

    Times exactly the per-``add`` host work of :class:`AggregatedWriter` —
    a crc32 pass plus a copy into the (aligned) coalescing buffer — over a
    ``nbytes`` payload, best-of-``repeat``.  The calibration layer
    (``runtime/calibrate.py``) uses this to separate wire-framing cost
    from codec D2H cost when fitting the io-lane model.

    ``clock`` defaults to ``time.perf_counter``; tests inject a stub.
    Returns seconds (≥ 1 ns to keep downstream throughput fits finite).
    """
    import time as _time

    clock = clock or _time.perf_counter
    payload = np.random.default_rng(0).integers(
        0, 256, size=max(int(nbytes), 1), dtype=np.uint8
    ).tobytes()
    buf = bytearray(align_up(len(payload), DEFAULT_ALIGN))
    best = float("inf")
    for _ in range(max(1, int(repeat))):
        t0 = clock()
        zlib.crc32(payload)
        buf[: len(payload)] = payload
        t1 = clock()
        best = min(best, t1 - t0)
    return max(best, 1e-9)
