"""Roofline model — TPU v5e-like hardware constants + the three terms.

    compute    = HLO_FLOPs_per_device / peak_FLOP/s
    memory     = HLO_bytes_per_device / HBM_bw
    collective = per-device link bytes / link_bw

``compiled.cost_analysis()`` on a partitioned executable reports *per-device*
program costs (the analyzed module is the per-device HLO), so terms divide by
per-chip rates directly; the brief's "/(chips × rate)" form is equivalent.

MODEL_FLOPS uses 6·N·D (dense train), 6·N_active·D (MoE), and matching
analytic forms for prefill/decode (incl. attention and KV-read bytes).

The measured-machine section at the bottom (:func:`simulate_stream`,
:func:`stream_lane_seconds`) replaces these *datasheet* constants with
*calibrated* per-stage cost functions from ``runtime/calibrate.py``: it
replays the lane-overlapped ``ChunkedPipeline`` schedule (main-thread H2D
staging, compute lane, io lane, in-flight ``window`` anti-dependency)
through the event-driven ``TimelineSimulator`` to predict a stream's
makespan for a candidate (chunk size, window) — the solver substrate of
``core/tuner.py``.
"""

from __future__ import annotations

from dataclasses import dataclass

import jax
import numpy as np

from ..configs.base import ModelConfig, ShapeConfig

PEAK_FLOPS = 197e12     # bf16 / chip
HBM_BW = 819e9          # B/s / chip
ICI_BW = 50e9           # B/s / link
HBM_PER_CHIP = 16e9     # v5e


@dataclass
class RooflineTerms:
    t_compute: float
    t_memory: float
    t_collective: float
    flops: float
    bytes_accessed: float
    link_bytes: float

    @property
    def dominant(self) -> str:
        terms = {
            "compute": self.t_compute,
            "memory": self.t_memory,
            "collective": self.t_collective,
        }
        return max(terms, key=terms.get)

    @property
    def bound_time(self) -> float:
        return max(self.t_compute, self.t_memory, self.t_collective)

    def to_dict(self) -> dict:
        return {
            "t_compute_s": self.t_compute,
            "t_memory_s": self.t_memory,
            "t_collective_s": self.t_collective,
            "dominant": self.dominant,
            "flops_per_device": self.flops,
            "bytes_per_device": self.bytes_accessed,
            "link_bytes_per_device": self.link_bytes,
        }


def terms_from_analysis(
    cost: dict | None, link_bytes: float, flops_override: float | None = None
) -> RooflineTerms:
    flops = float(flops_override if flops_override is not None else (cost or {}).get("flops", 0.0))
    nbytes = float((cost or {}).get("bytes accessed", 0.0))
    return RooflineTerms(
        t_compute=flops / PEAK_FLOPS,
        t_memory=nbytes / HBM_BW,
        t_collective=link_bytes / ICI_BW,
        flops=flops,
        bytes_accessed=nbytes,
        link_bytes=link_bytes,
    )


# ---------------------------------------------------------------------------
# analytic MODEL_FLOPS
# ---------------------------------------------------------------------------


def count_params(params_shape) -> dict:
    """Split param counts: embedding / expert / other (from an eval_shape tree)."""
    import jax.tree_util as jtu

    counts = {"embed": 0, "expert": 0, "other": 0}
    for path, leaf in jtu.tree_flatten_with_path(params_shape)[0]:
        names = [str(getattr(e, "key", getattr(e, "idx", ""))) for e in path]
        n = int(np.prod(leaf.shape))
        if "table" in names or ("head" in names):
            counts["embed"] += n
        elif "moe" in names and names[-1] in {"wg", "wu", "wd"}:
            counts["expert"] += n
        else:
            counts["other"] += n
    return counts


def active_params(cfg: ModelConfig, counts: dict) -> float:
    """N_active: experts scaled by (top_k + shared-equivalent)/n_experts."""
    n = counts["other"]
    if cfg.moe is not None and counts["expert"]:
        frac = cfg.moe.top_k / max(cfg.moe.n_experts, 1)
        n += counts["expert"] * frac
        # shared experts are inside "other" via the shared swiglu params
    return float(n)


def model_flops(cfg: ModelConfig, shape: ShapeConfig, counts: dict) -> dict:
    """Analytic FLOPs for the whole (global) step + useful-compute ratio base."""
    hd = cfg.resolved_head_dim
    n_act = active_params(cfg, counts)
    n_total = float(counts["other"] + counts["expert"])
    b, s = shape.global_batch, shape.seq_len
    tokens = b * s
    attn_layers = cfg.n_layers
    if cfg.family == "ssm":
        attn_layers = 0
    if cfg.family == "hybrid":
        attn_layers = cfg.n_layers // 3  # 1-in-3 local attention
        s_eff = min(s, cfg.hybrid.window)
    else:
        s_eff = s

    if shape.kind == "train":
        mm = 6.0 * n_act * tokens
        attn = 3.0 * attn_layers * 2.0 * b * s * s_eff * cfg.n_heads * hd  # fwd≈2·B·S·S_eff·H·hd (causal ≈ /2 folded in)
        return {"model_flops": mm + attn, "matmul_flops": mm, "attn_flops": attn}
    if shape.kind == "prefill":
        mm = 2.0 * n_act * tokens
        attn = attn_layers * 2.0 * b * s * s_eff * cfg.n_heads * hd
        return {"model_flops": mm + attn, "matmul_flops": mm, "attn_flops": attn}
    # decode: one token per sequence; S is the cache length
    mm = 2.0 * n_act * b
    attn = attn_layers * 4.0 * b * min(s, s_eff if cfg.family == "hybrid" else s) * cfg.n_heads * hd
    kv_bytes = _decode_state_bytes(cfg, b, s)
    return {
        "model_flops": mm + attn, "matmul_flops": mm, "attn_flops": attn,
        "state_read_bytes": kv_bytes,
    }


def analytic_memory_bytes(
    cfg: ModelConfig, shape: ShapeConfig, counts: dict,
    bytes_per_device: int, chips: int,
) -> float:
    """Per-device HBM traffic estimate (HLO 'bytes accessed' undercounts
    while-loop bodies, so the memory term uses max(reported, analytic)).

    train:   params f32 read(fwd)+read(bwd)+write + m/v read+write (f32)
             + layer-carry activations write+read (bf16) + logits traffic
    prefill: params read + activations write
    decode:  active params read + state read/write
    """
    p_local = float(bytes_per_device)  # param bytes per device (param_dtype)
    b, s = shape.global_batch, shape.seq_len
    tokens_local = b * (s if shape.kind != "decode" else 1) / chips
    d = cfg.d_model
    act_carry = tokens_local * d * 2.0 * 2.0 * cfg.n_layers  # bf16 write+read
    vocab_local = cfg.vocab / chips
    if shape.kind == "train":
        logits = tokens_local * vocab_local * 4.0 * 3.0 * chips / max(chips, 1)
        return 8.0 * p_local + act_carry + logits
    if shape.kind == "prefill":
        return p_local + act_carry
    # decode
    n_total = max(counts["other"] + counts["expert"], 1)
    active_frac = active_params(cfg, counts) / n_total
    state = _decode_state_bytes(cfg, b, s) / chips
    return p_local * active_frac + 2.0 * state


# ---------------------------------------------------------------------------
# measured-machine stream model (HPDR §V-C auto-tuner substrate)
# ---------------------------------------------------------------------------


def simulate_stream(
    chunk_sizes,
    h2d_time,
    compute_time,
    serialize_time,
    window: int,
    window_overhead_s: float = 0.0,
):
    """Predict the lane-overlapped ``ChunkedPipeline`` makespan.

    Mirrors the *real* scheduler exactly (three lanes, not the Fig. 9
    four-task form): chunk *i* is ``I_i`` (main-thread slice +
    ``device_put``) → ``R_i`` (compute lane) → ``S_i`` (io lane: D2H fetch
    + container serialization), with the bounded-window anti-dependency
    ``I_i ← S_{i-window}``.  ``window=1`` therefore reproduces the fully
    serial schedule.  ``window_overhead_s`` is the calibrated per-chunk
    staging/scheduling cost the pipelined schedule pays over serial
    (thread handoff, future chaining); it is charged on the staging task
    only when ``window > 1``.

    ``h2d_time``/``compute_time``/``serialize_time`` map chunk bytes →
    seconds (e.g. ``AffineCost.time_for`` / ``PhiModel.time_for``).
    Returns ``(makespan_seconds, schedule_dict)``.
    """
    from ..core import pipeline as pl  # lazy: keep layering acyclic

    window = max(1, int(window))
    ov = float(window_overhead_s) if window > 1 else 0.0
    tasks = []
    for i, c in enumerate(chunk_sizes):
        deps = (f"S{i - window}",) if i >= window else ()
        tasks.append(pl.Task(f"I{i}", pl.H2D, h2d_time(c) + ov, deps))
        tasks.append(pl.Task(f"R{i}", pl.COMPUTE, compute_time(c), (f"I{i}",)))
        tasks.append(pl.Task(f"S{i}", pl.D2H, serialize_time(c), (f"R{i}",)))
    sched = pl.TimelineSimulator().run(tasks)
    return pl.TimelineSimulator.makespan(sched), sched


def stream_lane_seconds(
    chunk_sizes, h2d_time, compute_time, serialize_time
) -> dict:
    """Per-lane serial-sum seconds for a chunk schedule (the no-overlap
    bound the measured ``ChunkedResult.lane_seconds()`` is compared to)."""
    return {
        "h2d": sum(h2d_time(c) for c in chunk_sizes),
        "compute": sum(compute_time(c) for c in chunk_sizes),
        "serialize": sum(serialize_time(c) for c in chunk_sizes),
    }


def _decode_state_bytes(cfg: ModelConfig, batch: int, s: int) -> float:
    hd = cfg.resolved_head_dim
    if cfg.family == "ssm":
        ssm = cfg.ssm
        d_inner = ssm.expand * cfg.d_model
        h = d_inner // ssm.head_dim
        return cfg.n_layers * batch * h * ssm.head_dim * ssm.d_state * 4.0
    if cfg.family == "hybrid":
        nsuper = cfg.n_layers // 3
        w = cfg.hybrid.lru_width or cfg.d_model
        rec = 2 * nsuper * batch * w * 4.0
        attn_cache = nsuper * batch * min(s, cfg.hybrid.window) * cfg.n_kv_heads * hd * 2 * 2.0
        return rec + attn_cache
    if cfg.attn_type == "mla":
        m = cfg.mla
        return cfg.n_layers * batch * s * (m.kv_lora_rank + m.qk_rope_head_dim) * 2.0
    return cfg.n_layers * batch * s * cfg.n_kv_heads * hd * 2 * 2.0
