"""Sharding rules: param / batch / cache / optimizer-state PartitionSpecs.

Policy (DESIGN.md §6):
  * TP on "model": attention heads, FFN width, experts (EP), vocab;
  * DP on ("pod","data"): batch;
  * FSDP (cfg.fsdp): the non-TP weight dim additionally sharded over "data"
    — ZeRO-3 expressed declaratively through GSPMD;
  * decode caches shard batch over DP and the *sequence* dim over "model"
    (flash-decoding style: XLA inserts the max/sum combines for the softmax
    over the sharded axis) — KV memory scales with the full mesh even when
    kv_heads < model-axis size.

Every axis assignment is divisibility-guarded: a dim that doesn't divide
falls back to replication (recorded by ``sharding_report``), so odd vocabs
(50280, 122753, 256206) lower cleanly — vocab padding is the §Perf lever
for those.

Rules are name-based over the param tree path; stacked-layer leading dims
are auto-padded with None.
"""

from __future__ import annotations

from typing import Any

import jax
import numpy as np
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P

from ..configs.base import ModelConfig

MODEL = "model"


def dp_axes(mesh: Mesh) -> tuple:
    """Data-parallel meta-axis: ("pod","data") on multi-pod, ("data",) else."""
    names = mesh.axis_names
    return tuple(n for n in ("pod", "data") if n in names)


def _axis_size(mesh: Mesh, axis) -> int:
    if axis is None:
        return 1
    if isinstance(axis, tuple):
        return int(np.prod([mesh.shape[a] for a in axis]))
    return mesh.shape[axis]


def _fit(mesh: Mesh, dim: int, axis):
    """axis if dim divides its size, else None (replicate)."""
    return axis if axis is not None and dim % _axis_size(mesh, axis) == 0 else None


def _path_names(path) -> list[str]:
    names = []
    for entry in path:
        if hasattr(entry, "key"):
            names.append(str(entry.key))
        elif hasattr(entry, "idx"):
            names.append(str(entry.idx))
    return names


_STACK_KEYS = (
    "layers", "moe_layers", "dense_layers", "enc_layers", "dec_layers",
    "rec_a", "rec_b", "attn_stack", "super",
)

# trailing-dim rules: name -> (spec builder taking (mesh, trailing_shape, fsdp))
_IN_WEIGHTS = {
    "wq", "wk", "wv", "wu", "wg", "w1", "in_proj", "in_x", "in_gate",
    "wq_a", "wq_b", "wkv_a", "wkv_b", "wr", "wi",
}
_OUT_WEIGHTS = {"wo", "wd", "out_proj", "out", "w2"}


def flat_axes(mesh: Mesh) -> tuple:
    """Every mesh axis flattened (pure-DP / ZeRO sharding target)."""
    return tuple(mesh.axis_names)


def best_dp_axes(mesh: Mesh, dim: int) -> tuple | None:
    """Largest prefix of (pod, data, model) whose product divides ``dim``."""
    axes = [n for n in ("pod", "data", "model") if n in mesh.axis_names]
    best = None
    for k in range(1, len(axes) + 1):
        cand = tuple(axes[:k])
        if dim % _axis_size(mesh, cand) == 0:
            best = cand
    return best


def param_spec(path, leaf, cfg: ModelConfig, mesh: Mesh) -> P:
    names = _path_names(path)
    shape = leaf.shape

    if cfg.sharding_policy == "fsdp_dp":
        return _param_spec_fsdp_dp(names, leaf, cfg, mesh)
    if cfg.sharding_policy == "dp_zero1":
        # ZeRO-1: params replicated (bf16 — they must fit per chip);
        # only optimizer moments are sharded (see specs.opt_state_specs).
        return P(*([None] * leaf.ndim))

    fsdp_axis = "data" if (cfg.fsdp and "data" in mesh.axis_names) else None
    in_moe_experts = "moe" in names and names[-1] in {"wg", "wu", "wd"}

    # stacked leading dims: anything whose ancestors include a stack key
    n_lead = 0
    if any(k in names for k in _STACK_KEYS) and leaf.ndim >= 1:
        n_lead = 1
    trailing = shape[n_lead:]
    name = names[-1]

    def pad(spec_tail: tuple) -> P:
        return P(*([None] * n_lead + list(spec_tail)))

    if name == "table":  # embedding (vocab, d)
        return pad((_fit(mesh, trailing[0], MODEL), _fit(mesh, trailing[1], fsdp_axis)))
    if name == "scale":  # norm scales: replicated
        return pad((None,) * len(trailing))
    if name in {"lam", "conv_b", "dt_bias", "A_log", "D", "b"} and len(trailing) == 1:
        return pad((_fit(mesh, trailing[0], MODEL),))
    if name == "conv_w":  # (k, dim)
        return pad((None, _fit(mesh, trailing[1], MODEL)))
    if name == "router":  # (d, E)
        return pad((None, _fit(mesh, trailing[1], MODEL)))
    if in_moe_experts and len(trailing) == 3:
        e, d1, d2 = trailing
        if cfg.moe_group_size > 0:
            # grouped-dispatch variant: full-mesh expert parallelism — no
            # inner-dim sharding (kills partial-sum ARs + FSDP regathers)
            espec = best_dp_axes(mesh, e)
            return pad((espec, None, None))
        espec = _fit(mesh, e, MODEL)
        if name in {"wg", "wu"}:  # (E, d_model, d_ff)
            return pad((espec, _fit(mesh, d1, fsdp_axis), None))
        return pad((espec, None, _fit(mesh, d2, fsdp_axis)))  # wd (E, f, d)
    if len(trailing) == 2:
        d_in, d_out = trailing
        if name in _IN_WEIGHTS or (name == "w" and _parent(names) in _IN_WEIGHTS):
            return pad((_fit(mesh, d_in, fsdp_axis), _fit(mesh, d_out, MODEL)))
        if name in _OUT_WEIGHTS or (name == "w" and _parent(names) in _OUT_WEIGHTS):
            return pad((_fit(mesh, d_in, MODEL), _fit(mesh, d_out, fsdp_axis)))
        if name == "w" and _parent(names) in {"head", "proj"}:
            return pad((_fit(mesh, d_in, fsdp_axis), _fit(mesh, d_out, MODEL)))
        # default 2-D: out dim on model
        return pad((_fit(mesh, d_in, fsdp_axis), _fit(mesh, d_out, MODEL)))
    if len(trailing) == 1:
        # biases: shard if the matching weight's out-dim is model-sharded
        return pad((_fit(mesh, trailing[0], MODEL),))
    return pad((None,) * len(trailing))


def _parent(names: list[str]) -> str:
    return names[-2] if len(names) >= 2 else ""


def _param_spec_fsdp_dp(names, leaf, cfg: ModelConfig, mesh: Mesh) -> P:
    """fsdp_dp policy: no tensor parallelism — batch spreads over the whole
    mesh while weights are FSDP-sharded over the "model" axis only (MaxText's
    data/fsdp split): XLA all-gathers each layer's params inside the scan
    step (small, weight-sized) and reduce-scatters grads; activations never
    cross the mesh.  Right choice when a model's TP activation all-reduces
    dominate its roofline (small dense archs: the qwen2.5-3b hillclimb).

    NB: sharding weights over the *same flattened axes as the batch* was
    tried first and regressed 9× (resharding storm) — see EXPERIMENTS.md
    §Perf iteration log.
    """
    fsdp_axis = MODEL
    shape = leaf.shape
    n_lead = 1 if any(k in names for k in _STACK_KEYS) and leaf.ndim >= 1 else 0
    trailing = shape[n_lead:]
    if not trailing or names[-1] == "scale":
        return P(*([None] * leaf.ndim))
    # shard the largest trailing dim divisible by the fsdp axis
    sizes = list(trailing)
    order = sorted(range(len(sizes)), key=lambda i: -sizes[i])
    spec = [None] * len(sizes)
    for i in order:
        if sizes[i] % _axis_size(mesh, fsdp_axis) == 0:
            spec[i] = fsdp_axis
            break
    return P(*([None] * n_lead + spec))


def param_shardings(params_shape: Any, cfg: ModelConfig, mesh: Mesh):
    """Map an eval_shape param tree to NamedShardings."""
    return jax.tree_util.tree_map_with_path(
        lambda path, leaf: NamedSharding(mesh, param_spec(path, leaf, cfg, mesh)),
        params_shape,
    )


def batch_shardings(batch_shape: Any, cfg: ModelConfig, mesh: Mesh):
    dp = dp_axes(mesh)

    def spec(path, leaf):
        b = leaf.shape[0] if leaf.ndim else 1
        if cfg.sharding_policy in ("fsdp_dp", "dp_zero1"):
            baxis = best_dp_axes(mesh, b)  # spread batch over the whole mesh
        else:
            baxis = dp if (dp and b % _axis_size(mesh, dp) == 0) else None
        return NamedSharding(mesh, P(baxis, *([None] * (leaf.ndim - 1))))

    return jax.tree_util.tree_map_with_path(spec, batch_shape)


def cache_shardings(cache_shape: Any, cfg: ModelConfig, mesh: Mesh):
    """Decode caches: (L, B, S, ...) → batch on DP, sequence on model."""
    dp = dp_axes(mesh)

    def spec(path, leaf):
        names = _path_names(path)
        nd = leaf.ndim
        parts = [None] * nd
        if nd >= 2:
            b = leaf.shape[1]
            if dp and b % _axis_size(mesh, dp) == 0:
                parts[1] = dp
        name = names[-1]
        if name in {"k", "v", "cross_k", "cross_v"} and nd == 5 and cfg.kv_replicate > 1:
            # opt variant: replicated KV heads fill the model axis → cache
            # stays sequence-local (no gather on update), heads sharded.
            if leaf.shape[3] % _axis_size(mesh, MODEL) == 0:
                parts[3] = MODEL
        elif name in {"k", "v", "c_kv", "k_rope", "cross_k", "cross_v"} and nd >= 3:
            if leaf.shape[2] % _axis_size(mesh, MODEL) == 0:
                parts[2] = MODEL  # sequence dim (flash-decoding split)
        elif name == "state" and nd >= 3:  # ssm (L,B,H,P,N)
            if leaf.shape[2] % _axis_size(mesh, MODEL) == 0:
                parts[2] = MODEL
        elif name == "h" and nd == 3:  # rglru (L,B,W)
            if leaf.shape[2] % _axis_size(mesh, MODEL) == 0:
                parts[2] = MODEL
        elif name == "conv" and nd >= 4:  # (L,B,cw-1,dim)
            if leaf.shape[3] % _axis_size(mesh, MODEL) == 0:
                parts[3] = MODEL
        return NamedSharding(mesh, P(*parts))

    return jax.tree_util.tree_map_with_path(spec, cache_shape)


def replicated(mesh: Mesh):
    return NamedSharding(mesh, P())


def constrain_activation_dp(x, batch_dim: int = 0):
    """Pin an activation's batch dim to the DP axes of the *ambient* mesh.

    The fsdp_dp policy relies on this: without an explicit constraint GSPMD
    prefers resharding activations onto the weights' "model" axis (TP-style),
    which is exactly the collective storm the policy exists to avoid.  Under
    no ambient mesh (CPU smoke tests) this is a no-op.
    """
    try:
        mesh = jax.sharding.get_abstract_mesh()
        names = tuple(mesh.axis_names) if mesh is not None else ()
    except Exception:  # pragma: no cover
        names = ()
    if not names:
        return x
    avail = [n for n in ("pod", "data", "model") if n in names]
    b = x.shape[batch_dim]
    best = None
    size = 1
    for k in range(1, len(avail) + 1):
        prod = 1
        for a in avail[:k]:
            prod *= mesh.shape[a]
        if b % prod == 0:
            best, size = tuple(avail[:k]), prod
    if best is None or size == 1:
        return x
    spec = [None] * x.ndim
    spec[batch_dim] = best if len(best) > 1 else best[0]
    return jax.lax.with_sharding_constraint(x, P(*spec))


def sharding_report(params_shape, cfg: ModelConfig, mesh: Mesh) -> dict:
    """Bytes per device + replication diagnostics (consumed by EXPERIMENTS.md)."""
    shardings = param_shardings(params_shape, cfg, mesh)
    total, per_dev, replicated_bytes = 0, 0, 0
    for leaf, sh in zip(
        jax.tree.leaves(params_shape), jax.tree.leaves(shardings)
    ):
        nbytes = int(np.prod(leaf.shape)) * leaf.dtype.itemsize
        spec = list(sh.spec) + [None] * (len(leaf.shape) - len(sh.spec))
        shards = 1
        for dim, axis in zip(leaf.shape, spec):
            if axis is not None:
                shards *= _axis_size(mesh, axis)
        total += nbytes
        per_dev += nbytes // shards
        if shards == 1:
            replicated_bytes += nbytes
    return {
        "total_bytes": total,
        "bytes_per_device": per_dev,
        "replicated_bytes": replicated_bytes,
        "devices": mesh.size,
    }
