from .engine import (  # noqa: F401
    KVPageStore,
    Request,
    ServingEngine,
    compress_kv_cache,
    decompress_kv_cache,
    park_kv_cache_async,
)
from .service import (  # noqa: F401
    OVERLOAD_POLICIES,
    ReductionService,
    ServiceOverloaded,
    ServiceStats,
)
