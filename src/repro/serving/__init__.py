from .client import ReductionClient  # noqa: F401
from .engine import (  # noqa: F401
    KVPageStore,
    Request,
    ServingEngine,
    compress_kv_cache,
    decompress_kv_cache,
    park_kv_cache_async,
)
from .protocol import (  # noqa: F401
    MAX_FRAME_BYTES,
    PROTOCOL_VERSION,
    Frame,
    ProtocolError,
    encode_frame,
    parse_frame,
)
from .server import ReductionServer  # noqa: F401
from .service import (  # noqa: F401
    BULK,
    INTERACTIVE,
    OVERLOAD_POLICIES,
    PRIORITIES,
    ReductionService,
    ServiceOverloaded,
    ServiceStats,
)
