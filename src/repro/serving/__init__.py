from .engine import Request, ServingEngine, compress_kv_cache, decompress_kv_cache  # noqa: F401
