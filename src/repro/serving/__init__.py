from .engine import (  # noqa: F401
    Request,
    ServingEngine,
    compress_kv_cache,
    decompress_kv_cache,
    park_kv_cache_async,
)
