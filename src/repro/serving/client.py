"""Blocking wire client for :class:`~repro.serving.server.ReductionServer`.

:class:`ReductionClient` speaks the :mod:`repro.serving.protocol` frame
format over a Unix-domain socket or localhost TCP.  It is deliberately
simple — one request in flight per client, synchronous result — because
the *server* side is where concurrency lives: run N clients (threads or
processes) and their requests coalesce into shared engine buckets.

Reliability model:

  * transport faults (connect refused, reset, torn response) are retried
    up to ``retries`` times with exponential backoff, reconnecting a
    fresh socket each time;
  * :class:`~repro.serving.service.ServiceOverloaded` is retried the same
    way — overload is transient by construction;
  * server-reported application errors (bad codec, unknown session, quota
    exceeded) are raised immediately with the server's message — a retry
    would just fail identically;
  * a response whose ``request_id`` does not echo the request's is a
    protocol violation: the connection is dropped and the request retried
    on a new one.
"""

from __future__ import annotations

import socket
import time
from typing import Any

import numpy as np

from . import protocol as P
from .service import ServiceOverloaded


class ReductionClient:
    """Blocking client for one server address.

    Parameters
    ----------
    address:
        A UDS path (``str`` / ``os.PathLike``) or a ``(host, port)`` tuple
        for TCP — match the server's :attr:`unix_address` /
        :attr:`tcp_address`.
    tenant:
        Tenant name stamped on every request frame (quota accounting and
        per-tenant stats happen server-side under this name).
    timeout:
        Socket timeout per send/recv, seconds.
    retries:
        Transport-fault retry budget per request (0 disables retry).
    backoff:
        Initial retry sleep, doubled per attempt.
    """

    def __init__(
        self,
        address: Any,
        *,
        tenant: str = "default",
        timeout: float = 30.0,
        retries: int = 3,
        backoff: float = 0.05,
        max_frame: int = P.MAX_FRAME_BYTES,
    ):
        self.address = address
        self.tenant = str(tenant)
        self.timeout = float(timeout)
        self.retries = int(retries)
        self.backoff = float(backoff)
        self.max_frame = int(max_frame)
        self._sock: socket.socket | None = None
        self._rid = 0
        self._m = {"requests": 0, "retries": 0, "reconnects": 0}

    # ------------------------------------------------------------- transport

    def _connect(self) -> socket.socket:
        if isinstance(self.address, tuple):
            sock = socket.create_connection(self.address,
                                            timeout=self.timeout)
        else:
            sock = socket.socket(socket.AF_UNIX, socket.SOCK_STREAM)
            sock.settimeout(self.timeout)
            sock.connect(str(self.address))
        return sock

    def _drop(self) -> None:
        if self._sock is not None:
            try:
                self._sock.close()
            except OSError:
                pass
            self._sock = None

    def _roundtrip(self, opcode: int, payload: bytes) -> P.Frame:
        """Send one frame, block for its echo-id response, retrying."""
        self._rid += 1
        rid = self._rid
        blob = P.encode_frame(opcode, rid, payload, tenant=self.tenant)
        self._m["requests"] += 1
        delay = self.backoff
        last: Exception | None = None
        for attempt in range(self.retries + 1):
            if attempt:
                self._m["retries"] += 1
                time.sleep(delay)
                delay *= 2
            try:
                if self._sock is None:
                    self._sock = self._connect()
                    self._m["reconnects"] += 1
                self._sock.sendall(blob)
                frame = P.recv_frame(self._sock, max_frame=self.max_frame)
                if frame is None:
                    raise P.ProtocolError(
                        "server closed the connection before responding",
                        field="truncated",
                    )
                if frame.request_id != rid:
                    raise P.ProtocolError(
                        f"response id {frame.request_id} != request id "
                        f"{rid}",
                        field="request_id",
                    )
            except (ConnectionError, socket.timeout, OSError) as e:
                self._drop()
                last = e
                continue
            except P.ProtocolError as e:
                # torn/mismatched response: the stream is unusable, but the
                # request may still succeed on a fresh connection
                self._drop()
                last = e
                continue
            if frame.flags & P.FLAG_ERROR or frame.opcode == P.OP_ERROR:
                try:
                    P.raise_error_payload(frame.payload)
                except ServiceOverloaded as e:
                    last = e  # transient by definition — retry
                    continue
            return frame
        raise last if last is not None else RuntimeError("retry loop empty")

    # --------------------------------------------------------------- service

    def ping(self, payload: bytes = b"") -> bytes:
        """Liveness check; the server echoes ``payload`` back verbatim."""
        return self._roundtrip(P.OP_PING, payload).payload

    def stats(self) -> dict:
        """Fetch the server's :class:`ServiceStats` snapshot as a dict."""
        return P.loads_json(self._roundtrip(P.OP_STATS, b"").payload)

    def compress(self, tree: dict, *, method: str | None = None,
                 **params: Any) -> tuple[dict, dict]:
        """Compress a flat ``{key: array}`` dict; returns ``(comp, stats)``.

        ``method``/``params`` pick one codec for every leaf; omit them to
        let the server's default policy choose per leaf.  ``comp`` values
        are :class:`~repro.core.container.Compressed` — byte-identical to
        the in-process :meth:`ReductionService.compress` result.
        """
        extra: dict[str, Any] = {}
        if method is not None:
            extra = {"method": method, "params": params}
        payload = P.dumps_payload(
            {k: np.asarray(v) for k, v in tree.items()}, extra
        )
        frame = self._roundtrip(P.OP_COMPRESS, payload)
        flat, ex = P.loads_payload(frame.payload)
        return flat, ex.get("stats", {})

    def decompress(self, comp: dict) -> dict:
        """Restore a flat dict of :class:`Compressed` back to arrays."""
        payload = P.dumps_payload(dict(comp))
        frame = self._roundtrip(P.OP_DECOMPRESS, payload)
        flat, _ = P.loads_payload(frame.payload)
        return flat

    def compress_stream(self, data: np.ndarray, method: str = "zfp", *,
                        chunk_size: int | str = "auto",
                        window: int | str = "auto",
                        **params: Any) -> tuple[bytes, dict]:
        """Chunked-stream compress; returns ``(stream_bytes, info)``."""
        payload = P.dumps_payload(
            {"data": np.asarray(data)},
            {"method": method, "chunk_size": chunk_size, "window": window,
             "params": params},
        )
        frame = self._roundtrip(P.OP_COMPRESS_STREAM, payload)
        flat, ex = P.loads_payload(frame.payload)
        return flat["stream"], ex.get("info", {})

    def decompress_stream(self, source: Any, *,
                          chunks: tuple[int, int] | None = None,
                          ) -> tuple[np.ndarray, dict]:
        """Decode a stream (bytes, or a *server-visible* file path).

        Returns ``(array, info)``; ``chunks=(lo, hi)`` restores only that
        range.  Concurrent requests for the same stream coalesce
        server-side — each chunk decodes once.
        """
        extra: dict[str, Any] = {"chunks": list(chunks) if chunks else None}
        if isinstance(source, (bytes, bytearray, memoryview)):
            payload = P.dumps_payload({"stream": bytes(source)}, extra)
        else:
            extra["path"] = str(source)
            payload = P.dumps_payload(None, extra)
        frame = self._roundtrip(P.OP_DECOMPRESS_STREAM, payload)
        flat, ex = P.loads_payload(frame.payload)
        return flat["array"], ex.get("info", {})

    def quicklook(self, path: Any, *, err: float | None = None,
                  tiers: int | None = None) -> tuple[np.ndarray, dict]:
        """Low-precision preview of a progressive file (interactive lane)."""
        payload = P.dumps_json(
            {"path": str(path), "err": err, "tiers": tiers}
        )
        frame = self._roundtrip(P.OP_QUICKLOOK, payload)
        flat, ex = P.loads_payload(frame.payload)
        return flat["array"], ex.get("info", {})

    def park_kv(self, session_id: str, cache: dict) -> dict:
        """Park a flat ``{name: array}`` KV cache; returns park stats."""
        payload = P.dumps_payload(
            {k: np.asarray(v) for k, v in cache.items()},
            {"session": str(session_id)},
        )
        frame = self._roundtrip(P.OP_PARK_KV, payload)
        _, ex = P.loads_payload(frame.payload)
        return ex.get("stats", {})

    def fetch_kv(self, session_id: str) -> dict:
        """Fetch a parked session's compressed containers (interactive)."""
        payload = P.dumps_json({"session": str(session_id)})
        frame = self._roundtrip(P.OP_FETCH_KV, payload)
        flat, _ = P.loads_payload(frame.payload)
        return flat

    def release_kv(self, session_id: str) -> None:
        """Release a parked session's pages and quota."""
        self._roundtrip(P.OP_RELEASE_KV,
                        P.dumps_json({"session": str(session_id)}))

    # --------------------------------------------------------------- helpers

    def client_stats(self) -> dict:
        """Local transport counters (requests / retries / reconnects)."""
        return dict(self._m)

    def close(self) -> None:
        self._drop()

    def __enter__(self) -> "ReductionClient":
        return self

    def __exit__(self, *exc) -> None:
        self.close()
