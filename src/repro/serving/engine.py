"""Batched serving engine: prefill + decode with optional KV compression.

Production shape: fixed batch slots, greedy continuous refill from a request
queue, jitted single-token decode over stacked layer caches.  Prefill runs
as a scanned decode over the prompt (exact, compile-once; the dry-run's
``prefill_step`` covers the fused-prefill lowering path at scale).

HPDR integration: ``compress_kv_cache``/``decompress_kv_cache`` push cold KV
pages through ZFP-X fixed-rate blocks — the serving-side analogue of the
paper's reduction-before-I/O, used when parking long-context sessions.
Parking runs on the execution engine: cache leaves shard over the mesh's
``data``-axis devices, and ``park_kv_cache_async`` returns a future so the
decode loop keeps stepping while a session is parked in the background.
"""

from __future__ import annotations

import time
from dataclasses import dataclass, field
from typing import Any, Callable

import jax
import jax.numpy as jnp
import numpy as np

from ..core import api
from ..core import engine as engine_mod
from ..models.model import Model
from ..runtime.executor import Submission


@dataclass
class Request:
    uid: int
    prompt: np.ndarray           # (S,) int32
    max_new_tokens: int = 16
    out_tokens: list = field(default_factory=list)
    done: bool = False


class ServingEngine:
    def __init__(self, model: Model, params: Any, batch_size: int, max_len: int,
                 cache_dtype=jnp.float32):
        self.model = model
        self.params = params
        self.batch_size = batch_size
        self.max_len = max_len
        self.cache = model.init_cache(batch_size, max_len, cache_dtype)
        self.lens = np.zeros(batch_size, np.int32)
        self.slots: list[Request | None] = [None] * batch_size
        self._decode = jax.jit(model.decode_step)

    # ------------------------------------------------------------- prefill

    def _prefill_slot(self, slot: int, prompt: np.ndarray) -> int:
        """Feed prompt tokens through decode steps (slot-batched)."""
        last = 0
        for i, tok in enumerate(prompt):
            toks = np.zeros(self.batch_size, np.int32)
            toks[slot] = tok
            logits, self.cache = self._decode(
                self.params, jnp.asarray(toks), self.cache,
                jnp.int32(int(self.lens[slot])),
            )
            self.lens[slot] += 1
            last = int(jnp.argmax(logits[slot]))
        return last

    # --------------------------------------------------------------- serve

    def serve(self, requests: list[Request]) -> dict:
        """Run all requests to completion with continuous slot refill."""
        queue = list(requests)
        active: dict[int, Request] = {}
        t0 = time.perf_counter()
        steps = 0
        pending_tok = np.zeros(self.batch_size, np.int32)

        def refill():
            for s in range(self.batch_size):
                if self.slots[s] is None and queue:
                    req = queue.pop(0)
                    self.slots[s] = req
                    active[s] = req
                    pending_tok[s] = self._prefill_slot(s, req.prompt)

        refill()
        while active:
            toks = jnp.asarray(pending_tok)
            # NB: single shared cache_len per decode call requires equal
            # lens; the engine keeps slots aligned by prefilling through the
            # same decode path.  Mixed-length batches use per-slot masks.
            cache_len = jnp.int32(int(self.lens.max()))
            logits, self.cache = self._decode(self.params, toks, self.cache, cache_len)
            steps += 1
            nxt = np.asarray(jnp.argmax(logits, axis=-1)).astype(np.int32)
            for s, req in list(active.items()):
                req.out_tokens.append(int(nxt[s]))
                self.lens[s] += 1
                pending_tok[s] = nxt[s]
                if len(req.out_tokens) >= req.max_new_tokens or self.lens[s] >= self.max_len - 1:
                    req.done = True
                    self.slots[s] = None
                    del active[s]
            refill()
        dt = time.perf_counter() - t0
        total_tokens = sum(len(r.out_tokens) for r in requests)
        return {
            "requests": len(requests),
            "decode_steps": steps,
            "new_tokens": total_tokens,
            "wall_s": dt,
            "tokens_per_s": total_tokens / dt if dt else float("inf"),
        }


# ---------------------------------------------------------------------------
# KV-cache compression (HPDR integration)
# ---------------------------------------------------------------------------


def _kv_select(rate: int):
    def select(key: str, arr: np.ndarray):
        del key
        if arr.dtype.kind == "f" and arr.size >= 4096:
            return "zfp", {"rate": rate}
        return None

    return select


def compress_kv_cache(
    cache: Any, rate: int = 12, engine: engine_mod.ExecutionEngine | None = None
) -> tuple[Any, dict]:
    """ZFP-X fixed-rate compression of float cache leaves (park a session).

    Thin policy over :func:`api.compress_pytree`, executed on the execution
    engine: same-shape KV pages bucket into one plan (cached in the CMM so
    parking session N+1 reuses session N's jitted executables) and shard
    across the mesh ``data`` axis; everything else is passed through raw.
    """
    return api.compress_pytree(cache, _kv_select(rate), engine=engine)


def park_kv_cache_async(
    cache: Any, rate: int = 12, engine: engine_mod.ExecutionEngine | None = None
) -> Submission:
    """Park a session in the background: future resolving to (flat, stats).

    The cache is snapshotted to host first (the only sync point, as in
    ``CheckpointManager.save_async``); compression then runs on the
    engine's io lane while decode steps continue.
    """
    eng = engine if engine is not None else engine_mod.default_engine()
    snapshot = jax.tree.map(np.asarray, cache)
    return eng.submit(
        api.compress_pytree, snapshot, _kv_select(rate), engine=eng, lane="io"
    )


def decompress_kv_cache(
    comp: Any, like: Any, engine: engine_mod.ExecutionEngine | None = None
) -> Any:
    return api.decompress_pytree(comp, like, engine=engine)
