"""Batched serving engine: prefill + decode with optional KV compression.

Production shape: fixed batch slots, greedy continuous refill from a request
queue, jitted single-token decode over stacked layer caches.  Prefill runs
as a scanned decode over the prompt (exact, compile-once; the dry-run's
``prefill_step`` covers the fused-prefill lowering path at scale).

HPDR integration: ``compress_kv_cache``/``decompress_kv_cache`` push cold KV
pages through ZFP-X fixed-rate blocks — the serving-side analogue of the
paper's reduction-before-I/O, used when parking long-context sessions.
Parking runs on the execution engine: cache leaves shard over the mesh's
``data``-axis devices, and ``park_kv_cache_async`` returns a future so the
decode loop keeps stepping while a session is parked in the background.
:class:`KVPageStore` bounds the memory parked sessions hold: tracked bytes
sit behind a CMM byte-budget LRU whose evictions spill containers to disk,
and evicted sessions re-materialise transparently on next access.
"""

from __future__ import annotations

import hashlib
import io
import json
import tempfile
import threading
import time
from concurrent.futures import Future
from dataclasses import dataclass, field
from pathlib import Path
from typing import Any, Callable

import jax
import jax.numpy as jnp
import numpy as np

from ..core import api
from ..core import engine as engine_mod
from ..core.context import ContextCache, ReductionContext
from ..models.model import Model
from ..runtime.executor import Submission


@dataclass
class Request:
    uid: int
    prompt: np.ndarray           # (S,) int32
    max_new_tokens: int = 16
    out_tokens: list = field(default_factory=list)
    done: bool = False


class ServingEngine:
    def __init__(self, model: Model, params: Any, batch_size: int, max_len: int,
                 cache_dtype=jnp.float32):
        self.model = model
        self.params = params
        self.batch_size = batch_size
        self.max_len = max_len
        self.cache = model.init_cache(batch_size, max_len, cache_dtype)
        self.lens = np.zeros(batch_size, np.int32)
        self.slots: list[Request | None] = [None] * batch_size
        self._decode = jax.jit(model.decode_step)

    # ------------------------------------------------------------- prefill

    def _prefill_slot(self, slot: int, prompt: np.ndarray) -> int:
        """Feed prompt tokens through decode steps (slot-batched)."""
        last = 0
        for i, tok in enumerate(prompt):
            toks = np.zeros(self.batch_size, np.int32)
            toks[slot] = tok
            logits, self.cache = self._decode(
                self.params, jnp.asarray(toks), self.cache,
                jnp.int32(int(self.lens[slot])),
            )
            self.lens[slot] += 1
            last = int(jnp.argmax(logits[slot]))
        return last

    # --------------------------------------------------------------- serve

    def serve(self, requests: list[Request]) -> dict:
        """Run all requests to completion with continuous slot refill."""
        queue = list(requests)
        active: dict[int, Request] = {}
        t0 = time.perf_counter()
        steps = 0
        pending_tok = np.zeros(self.batch_size, np.int32)

        def refill():
            for s in range(self.batch_size):
                if self.slots[s] is None and queue:
                    req = queue.pop(0)
                    self.slots[s] = req
                    active[s] = req
                    pending_tok[s] = self._prefill_slot(s, req.prompt)

        refill()
        while active:
            toks = jnp.asarray(pending_tok)
            # NB: single shared cache_len per decode call requires equal
            # lens; the engine keeps slots aligned by prefilling through the
            # same decode path.  Mixed-length batches use per-slot masks.
            cache_len = jnp.int32(int(self.lens.max()))
            logits, self.cache = self._decode(self.params, toks, self.cache, cache_len)
            steps += 1
            nxt = np.asarray(jnp.argmax(logits, axis=-1)).astype(np.int32)
            for s, req in list(active.items()):
                req.out_tokens.append(int(nxt[s]))
                self.lens[s] += 1
                pending_tok[s] = nxt[s]
                if len(req.out_tokens) >= req.max_new_tokens or self.lens[s] >= self.max_len - 1:
                    req.done = True
                    self.slots[s] = None
                    del active[s]
            refill()
        dt = time.perf_counter() - t0
        total_tokens = sum(len(r.out_tokens) for r in requests)
        return {
            "requests": len(requests),
            "decode_steps": steps,
            "new_tokens": total_tokens,
            "wall_s": dt,
            "tokens_per_s": total_tokens / dt if dt else float("inf"),
        }


# ---------------------------------------------------------------------------
# KV-cache compression (HPDR integration)
# ---------------------------------------------------------------------------


def _kv_select(rate: int):
    def select(key: str, arr: np.ndarray):
        del key
        if arr.dtype.kind == "f" and arr.size >= 4096:
            return "zfp", {"rate": rate}
        return None

    return select


def compress_kv_cache(
    cache: Any, rate: int = 12, engine: engine_mod.ExecutionEngine | None = None
) -> tuple[Any, dict]:
    """ZFP-X fixed-rate compression of float cache leaves (park a session).

    Thin policy over :func:`api.compress_pytree`, executed on the execution
    engine: same-shape KV pages bucket into one plan (cached in the CMM so
    parking session N+1 reuses session N's jitted executables) and shard
    across the mesh ``data`` axis; everything else is passed through raw.
    """
    return api.compress_pytree(cache, _kv_select(rate), engine=engine)


def park_kv_cache_async(
    cache: Any, rate: int = 12, engine: engine_mod.ExecutionEngine | None = None
) -> Submission:
    """Park a session in the background: future resolving to (flat, stats).

    The cache is snapshotted to host first (the only sync point, as in
    ``CheckpointManager.save_async``); compression then runs on the
    engine's io lane while decode steps continue.
    """
    eng = engine if engine is not None else engine_mod.default_engine()
    snapshot = jax.tree.map(np.asarray, cache)
    return eng.submit(
        api.compress_pytree, snapshot, _kv_select(rate), engine=eng, lane="io"
    )


def decompress_kv_cache(
    comp: Any, like: Any, engine: engine_mod.ExecutionEngine | None = None
) -> Any:
    return api.decompress_pytree(comp, like, engine=engine)


# ---------------------------------------------------------------------------
# parked-session store: CMM byte-budget LRU + transparent disk spill
# ---------------------------------------------------------------------------

_KV_MAGIC = b"HPKV"
_KV_VERSION = 1


def _dump_flat(flat: dict[str, Any]) -> bytes:
    """Serialise one parked session's ``compress_kv_cache`` output."""
    entries, blobs = [], []
    off = 0
    for key, val in flat.items():
        if isinstance(val, api.Compressed):
            kind, blob = "hpdr", val.to_bytes()
        else:
            buf = io.BytesIO()
            np.save(buf, np.asarray(val), allow_pickle=False)
            kind, blob = "npy", buf.getvalue()
        entries.append({"key": key, "kind": kind, "offset": off,
                        "nbytes": len(blob)})
        off += len(blob)
        blobs.append(blob)
    header = json.dumps({"entries": entries}).encode()
    out = io.BytesIO()
    out.write(_KV_MAGIC)
    out.write(np.uint32(_KV_VERSION).tobytes())
    out.write(np.uint64(len(header)).tobytes())
    out.write(header)
    for blob in blobs:
        out.write(blob)
    return out.getvalue()


def _load_flat(raw: bytes) -> dict[str, Any]:
    if len(raw) < 16 or raw[:4] != _KV_MAGIC:
        raise ValueError("not an HPDR parked-KV stream")
    version = int(np.frombuffer(raw[4:8], np.uint32)[0])
    if version != _KV_VERSION:
        raise ValueError(f"unsupported parked-KV version {version}")
    hlen = int(np.frombuffer(raw[8:16], np.uint64)[0])
    header = json.loads(raw[16:16 + hlen].decode())
    base = 16 + hlen
    flat: dict[str, Any] = {}
    for entry in header["entries"]:
        lo = base + entry["offset"]
        blob = raw[lo:lo + entry["nbytes"]]
        if entry["kind"] == "hpdr":
            flat[entry["key"]] = api.Compressed.from_bytes(blob)
        else:
            flat[entry["key"]] = np.load(io.BytesIO(blob), allow_pickle=False)
    return flat


_DEFAULT_TENANT = "default"


class KVPageStore:
    """Parked serving sessions behind the CMM's byte-budget LRU.

    ``park`` compresses a session's KV cache on the execution engine
    (stacked over the mesh ``data`` axis, plans CMM-cached) and tracks the
    resulting containers as a :class:`~repro.core.context.ContextCache`
    entry, so total parked bytes are bounded: under memory pressure the
    least-recently-used sessions are evicted through the cache's
    ``on_evict`` hook, which *spills their containers to disk*.  A later
    ``fetch``/``restore`` of an evicted session re-materialises it from the
    spill transparently (observable as ``load_count``).

    Sessions are **tenant-scoped**: every entry is keyed by
    ``(tenant, session_id)``, and :meth:`set_tenant_quota` bounds one
    tenant's resident bytes independently of the global budget — over
    quota, that tenant's own LRU sessions spill first, so a heavy tenant
    cannot displace a light one (the serving layer's per-tenant CMM
    quota).  :meth:`park_async` registers its in-flight submission so a
    concurrent ``fetch``/``restore``/``release`` of the same session waits
    for the park to land instead of observing a half-written view.
    """

    def __init__(
        self,
        capacity_bytes: int = 256 << 20,
        spill_dir: str | Path | None = None,
        rate: int = 12,
        engine: engine_mod.ExecutionEngine | None = None,
        tenant_quota_bytes: dict[str, int] | None = None,
    ):
        self.rate = rate
        self.engine = engine
        self.spill_dir = Path(
            spill_dir if spill_dir is not None
            else tempfile.mkdtemp(prefix="hpdr-kv-")
        )
        self.spill_dir.mkdir(parents=True, exist_ok=True)
        self.cache = ContextCache(
            capacity=1 << 30,  # bounded by bytes, not entry count
            capacity_bytes=capacity_bytes,
            on_evict=self._spill,
            group_fn=lambda key: key[1],  # ("kv_page", tenant, session)
        )
        for tenant, quota in (tenant_quota_bytes or {}).items():
            self.cache.set_group_capacity(str(tenant), quota)
        # Store-level mutation lock (reentrant: an insert may trigger an
        # eviction spill while the lock is held).  Serialises park / fetch /
        # release against in-flight LRU spills, so releasing a session
        # cannot interleave with its own eviction and resurrect it from a
        # spill written after the release.
        self._lock = threading.RLock()
        # session key -> in-flight async park (a concurrent.futures.Future
        # registered *before* the submission exists, so fetch can never
        # slip between submit and registration)
        self._inflight: dict[tuple, Future] = {}
        self.spill_count = 0
        self.load_count = 0

    # -- internals -----------------------------------------------------------

    @staticmethod
    def _key(session_id: str, tenant: str = _DEFAULT_TENANT) -> tuple:
        return ("kv_page", str(tenant), str(session_id))

    def _path(self, session_id: str, tenant: str = _DEFAULT_TENANT) -> Path:
        # digest suffix: sanitization alone could collide distinct session
        # ids ("user:1" vs "user_1") onto one spill file — and silently
        # serve one session's KV state for another after re-materialising.
        # The digest covers the tenant too, so same-named sessions of
        # different tenants never share a spill.
        sid, tid = str(session_id), str(tenant)
        safe = "".join(c if c.isalnum() or c in "-_." else "_" for c in sid)
        digest = hashlib.sha1(f"{tid}\x00{sid}".encode()).hexdigest()[:8]
        return self.spill_dir / f"{safe[:80]}-{digest}.hpkv"

    def _spill(self, ctx) -> None:
        _tag, tenant, session_id = ctx.key
        self._path(session_id, tenant).write_bytes(_dump_flat(ctx.buffers))
        with self._lock:
            self.spill_count += 1

    def _wait_inflight(self, session_id: str, tenant: str) -> None:
        """Block until any in-flight async park of this session lands.

        A park *failure* is swallowed here — it surfaces on the
        ``park_async`` submission; the waiter then simply sees whatever
        state preceded the failed park (usually ``KeyError``).
        """
        with self._lock:
            fut = self._inflight.get(self._key(session_id, tenant))
        if fut is not None:
            try:
                fut.result()
            except Exception:
                pass

    # -- public API ----------------------------------------------------------

    def set_tenant_quota(self, tenant: str, capacity_bytes: int | None) -> None:
        """Bound one tenant's resident parked bytes (``None`` clears)."""
        self.cache.set_group_capacity(str(tenant), capacity_bytes)

    def park(
        self, session_id: str, cache: Any, *, tenant: str = _DEFAULT_TENANT
    ) -> dict:
        """Compress + track one session; returns the compression stats."""
        snapshot = jax.tree.map(np.asarray, cache)
        flat, stats = compress_kv_cache(snapshot, rate=self.rate,
                                        engine=self.engine)
        key = self._key(session_id, tenant)
        with self._lock:
            self.cache.discard(key)  # re-park replaces the tracked entry
            ctx = ReductionContext(key=key, plan=None, buffers=flat)
            self.cache.get_or_create(key, lambda: ctx)
        return stats

    def park_async(
        self, session_id: str, cache: Any, *, tenant: str = _DEFAULT_TENANT
    ) -> Submission:
        """Background park on the engine's io lane (decode keeps stepping).

        The in-flight park is registered under the session key before the
        io-lane submission exists, so a concurrent :meth:`fetch` /
        :meth:`release` of the same session waits for it to land — it can
        never observe the store mid-park.
        """
        eng = self.engine if self.engine is not None else engine_mod.default_engine()
        snapshot = jax.tree.map(np.asarray, cache)
        key = self._key(session_id, tenant)
        done: Future = Future()
        with self._lock:
            self._inflight[key] = done

        def _do() -> dict:
            try:
                out = self.park(session_id, snapshot, tenant=tenant)
            except BaseException as e:
                done.set_exception(e)
                raise
            else:
                done.set_result(out)
                return out
            finally:
                with self._lock:
                    if self._inflight.get(key) is done:
                        del self._inflight[key]

        return eng.submit(_do, lane="io")

    def fetch(
        self, session_id: str, *, tenant: str = _DEFAULT_TENANT
    ) -> dict[str, Any]:
        """The session's compressed containers; re-materialises a spilled
        session from disk transparently and waits on an in-flight async
        park of the same session."""
        self._wait_inflight(session_id, tenant)

        def rematerialize():
            path = self._path(session_id, tenant)
            if not path.exists():
                raise KeyError(f"unknown parked session {session_id!r}")
            flat = _load_flat(path.read_bytes())
            self.load_count += 1
            return ReductionContext(key=self._key(session_id, tenant),
                                    plan=None, buffers=flat)

        with self._lock:
            return self.cache.get_or_create(
                self._key(session_id, tenant), rematerialize
            ).buffers

    def restore(
        self, session_id: str, like: Any, *, tenant: str = _DEFAULT_TENANT
    ) -> Any:
        """Decompress a parked session back into ``like``'s structure."""
        return decompress_kv_cache(self.fetch(session_id, tenant=tenant),
                                   like, engine=self.engine)

    def release(
        self, session_id: str, *, tenant: str = _DEFAULT_TENANT
    ) -> None:
        """Forget a session entirely (cache entry + spill file)."""
        self._wait_inflight(session_id, tenant)
        with self._lock:
            self.cache.discard(self._key(session_id, tenant))
            path = self._path(session_id, tenant)
            if path.exists():
                path.unlink()

    def tenant_bytes(self) -> dict[str, int]:
        """Resident parked bytes per tenant (the ServiceStats surface)."""
        return self.cache.nbytes_by_group()

    def stats(self) -> dict[str, Any]:
        with self._lock:
            return {
                "sessions": len(self.cache),
                "parked_bytes": self.cache.nbytes(),
                "capacity_bytes": self.cache.capacity_bytes,
                "spills": self.spill_count,
                "loads": self.load_count,
                "evictions": self.cache.evict_count,
                "tenant_bytes": self.cache.nbytes_by_group(),
                "tenant_evictions": dict(self.cache.group_evict_count),
            }
