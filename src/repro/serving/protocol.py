"""HPDR serving wire protocol: length-prefixed binary frames.

The reduction service (:mod:`repro.serving.service`) scales within one
process; this module defines the byte protocol that lets *independent*
client processes — e.g. the per-host writers of the paper's Figs. 15/17/18
— share one engine through :class:`~repro.serving.server.ReductionServer`.
Every message is one frame::

    offset 0   uint32  frame_len      # bytes that follow (length prefix)
           4   magic   b"HPRW"
           8   uint16  version (= 1)
          10   uint16  opcode
          12   uint64  request_id     # echoed verbatim on the response
          20   uint16  tenant_len
          22   uint16  flags          # bit 0: response is an error detail
          24   uint32  payload crc32
          28   tenant  utf-8 (tenant_len bytes)
     28+tlen   payload (frame_len - 24 - tenant_len bytes)

Validation mirrors the byte container's (:mod:`repro.core.container`):
every field is checked on parse and failures raise a *typed*
:class:`ProtocolError` that names the offending field (``magic``,
``version``, ``opcode``, ``length``, ``tenant``, ``crc32``, ``payload``,
``truncated``, ``request_id``) — a fuzzer mutating any byte of a frame gets
a loud, field-attributed error, never a hang or a silently mis-parsed
request.  The crc32 is :func:`repro.core.container.crc32_of` — the same
checksum (and the same mismatch wording) the container format uses.

Payloads are either raw bytes (opcode-defined), a JSON object, or the
*flat-dict* encoding produced by :func:`dumps_payload`: a JSON directory of
``(key, kind, offset, nbytes)`` entries followed by the concatenated blobs,
where each entry is an HPDR container (``kind="hpdr"``), an ``.npy`` array
(``"npy"``), or opaque bytes (``"bytes"``).  This is what carries pytrees
of arrays and compressed containers across the socket byte-identically.
"""

from __future__ import annotations

import io
import json
import struct
from dataclasses import dataclass, field
from typing import Any

import numpy as np

from ..core.container import Compressed, ContainerError, crc32_of

MAGIC = b"HPRW"
PROTOCOL_VERSION = 1

# Default ceiling on one frame's body.  A length prefix beyond the limit is
# rejected *before* any allocation — an adversarial (or bit-flipped) prefix
# cannot make the server reserve gigabytes or stall reading a frame that
# will never arrive.
MAX_FRAME_BYTES = 1 << 30

_PREFIX = struct.Struct("<I")
_HEADER = struct.Struct("<4sHHQHHI")
HEADER_BYTES = _HEADER.size  # 24

# request opcodes
OP_PING = 0x01
OP_COMPRESS = 0x02
OP_DECOMPRESS = 0x03
OP_COMPRESS_STREAM = 0x04
OP_DECOMPRESS_STREAM = 0x05
OP_QUICKLOOK = 0x06
OP_FETCH_KV = 0x07
OP_PARK_KV = 0x08
OP_RELEASE_KV = 0x09
OP_STATS = 0x0A
# response opcodes
OP_OK = 0x80
OP_ERROR = 0x81

OPCODE_NAMES = {
    OP_PING: "ping",
    OP_COMPRESS: "compress",
    OP_DECOMPRESS: "decompress",
    OP_COMPRESS_STREAM: "compress_stream",
    OP_DECOMPRESS_STREAM: "decompress_stream",
    OP_QUICKLOOK: "quicklook",
    OP_FETCH_KV: "fetch_kv",
    OP_PARK_KV: "park_kv",
    OP_RELEASE_KV: "release_kv",
    OP_STATS: "stats",
    OP_OK: "ok",
    OP_ERROR: "error",
}

FLAG_ERROR = 0x1


class ProtocolError(ContainerError):
    """A malformed, truncated, or corrupt wire frame.

    ``field`` names the frame field that failed validation — fuzz tests
    assert on it, and operators can aggregate protocol errors by field.
    Subclasses :class:`~repro.core.container.ContainerError` so one
    ``except`` arm covers corruption at every layer (file, container,
    wire).
    """

    def __init__(self, message: str, *, field: str):
        super().__init__(f"{message} [field={field}]")
        self.field = field


@dataclass
class Frame:
    """One parsed wire frame."""

    opcode: int
    request_id: int
    payload: bytes = b""
    tenant: str = "default"
    flags: int = 0

    @property
    def opcode_name(self) -> str:
        return OPCODE_NAMES.get(self.opcode, f"0x{self.opcode:02x}")


def encode_frame(
    opcode: int,
    request_id: int,
    payload: bytes = b"",
    *,
    tenant: str = "default",
    flags: int = 0,
) -> bytes:
    """Serialise one frame, length prefix included."""
    if opcode not in OPCODE_NAMES:
        raise ProtocolError(f"unknown opcode 0x{opcode:02x}", field="opcode")
    tenant_b = tenant.encode("utf-8")
    if len(tenant_b) > 0xFFFF:
        raise ProtocolError(
            f"tenant name too long ({len(tenant_b)} bytes)", field="tenant"
        )
    header = _HEADER.pack(
        MAGIC, PROTOCOL_VERSION, opcode, request_id,
        len(tenant_b), flags, crc32_of(payload),
    )
    body = header + tenant_b + payload
    return _PREFIX.pack(len(body)) + body


def parse_frame(body: bytes, *, max_frame: int = MAX_FRAME_BYTES) -> Frame:
    """Parse one frame *body* (the bytes after the length prefix).

    Every field is validated; any mutation of a valid frame — truncation,
    bit flips in magic/version/opcode/tenant-length, a tampered checksum or
    payload — raises :class:`ProtocolError` naming the field.
    """
    if len(body) > max_frame:
        raise ProtocolError(
            f"frame body {len(body)} bytes exceeds limit {max_frame}",
            field="length",
        )
    if len(body) < HEADER_BYTES:
        raise ProtocolError(
            f"truncated frame: {len(body)} bytes < {HEADER_BYTES}-byte header",
            field="truncated",
        )
    magic, version, opcode, request_id, tenant_len, flags, crc = _HEADER.unpack(
        body[:HEADER_BYTES]
    )
    if magic != MAGIC:
        raise ProtocolError(f"bad frame magic {magic!r}", field="magic")
    if version != PROTOCOL_VERSION:
        raise ProtocolError(
            f"unsupported wire protocol version {version} "
            f"(speaking {PROTOCOL_VERSION})",
            field="version",
        )
    def _err(message: str, field: str) -> ProtocolError:
        # past the fixed header the request id is trustworthy enough to
        # address an error response to — attach it for the server
        e = ProtocolError(message, field=field)
        e.request_id = request_id
        return e

    if opcode not in OPCODE_NAMES:
        raise _err(f"unknown opcode 0x{opcode:02x}", field="opcode")
    if HEADER_BYTES + tenant_len > len(body):
        raise _err(
            f"tenant field ({tenant_len} bytes) overruns frame "
            f"({len(body)} bytes)",
            field="tenant",
        )
    try:
        tenant = body[HEADER_BYTES : HEADER_BYTES + tenant_len].decode("utf-8")
    except UnicodeDecodeError as e:
        raise _err(f"tenant is not valid utf-8: {e}", field="tenant") from e
    payload = body[HEADER_BYTES + tenant_len :]
    actual = crc32_of(payload)
    if actual != crc:
        raise _err(
            f"corrupt frame payload: crc32 {actual:#010x} != recorded "
            f"{crc:#010x}",
            field="crc32",
        )
    return Frame(
        opcode=opcode, request_id=request_id, payload=payload,
        tenant=tenant, flags=flags,
    )


def read_length_prefix(prefix: bytes, *, max_frame: int = MAX_FRAME_BYTES) -> int:
    """Validate a 4-byte length prefix; returns the frame body length.

    An oversized (or zero/undersized) prefix is rejected here, before any
    buffer is allocated for the body.
    """
    if len(prefix) != _PREFIX.size:
        raise ProtocolError(
            f"truncated length prefix ({len(prefix)} bytes)", field="truncated"
        )
    (n,) = _PREFIX.unpack(prefix)
    if n < HEADER_BYTES:
        raise ProtocolError(
            f"length prefix {n} smaller than the {HEADER_BYTES}-byte header",
            field="length",
        )
    if n > max_frame:
        raise ProtocolError(
            f"length prefix {n} exceeds frame limit {max_frame}", field="length"
        )
    return n


def recv_exact(sock, n: int) -> bytes | None:
    """Read exactly ``n`` bytes from a socket.

    Returns ``None`` on a clean EOF *before any byte* (peer closed between
    frames); raises :class:`ProtocolError` (``field="truncated"``) if the
    stream ends mid-read — a torn frame.
    """
    chunks: list[bytes] = []
    got = 0
    while got < n:
        chunk = sock.recv(min(n - got, 1 << 20))
        if not chunk:
            if got == 0:
                return None
            raise ProtocolError(
                f"connection closed mid-frame: got {got} of {n} bytes",
                field="truncated",
            )
        chunks.append(chunk)
        got += len(chunk)
    return b"".join(chunks)


def recv_frame(sock, *, max_frame: int = MAX_FRAME_BYTES) -> Frame | None:
    """Read one frame from a blocking socket; ``None`` on clean EOF."""
    prefix = recv_exact(sock, _PREFIX.size)
    if prefix is None:
        return None
    n = read_length_prefix(prefix, max_frame=max_frame)
    body = recv_exact(sock, n)
    if body is None:
        raise ProtocolError(
            "connection closed between length prefix and frame body",
            field="truncated",
        )
    return parse_frame(body, max_frame=max_frame)


# ---------------------------------------------------------------------------
# payload encodings
# ---------------------------------------------------------------------------


def _deep_jsonable(obj: Any) -> Any:
    if isinstance(obj, dict):
        return {str(k): _deep_jsonable(v) for k, v in obj.items()}
    if isinstance(obj, (list, tuple)):
        return [_deep_jsonable(v) for v in obj]
    if isinstance(obj, (np.integer,)):
        return int(obj)
    if isinstance(obj, (np.floating,)):
        return float(obj)
    if isinstance(obj, (np.bool_,)):
        return bool(obj)
    return obj


def dumps_json(obj: Any) -> bytes:
    return json.dumps(_deep_jsonable(obj)).encode("utf-8")


def loads_json(payload: bytes) -> Any:
    try:
        return json.loads(payload.decode("utf-8"))
    except (UnicodeDecodeError, json.JSONDecodeError) as e:
        raise ProtocolError(f"corrupt JSON payload: {e}", field="payload") from e


def dumps_payload(
    entries: dict[str, Any] | None = None, extra: dict | None = None
) -> bytes:
    """Flat-dict payload: JSON directory + concatenated per-entry blobs.

    ``entries`` values may be :class:`~repro.core.container.Compressed`
    (serialised with :meth:`to_bytes` — the wire carries the *container
    bytes*, so socket and in-process results compare byte-identical),
    numpy arrays (``.npy``), or raw ``bytes``.  ``extra`` is an arbitrary
    JSON-able side dict (request kwargs, response stats).
    """
    dir_entries, blobs = [], []
    off = 0
    for key, val in (entries or {}).items():
        if isinstance(val, Compressed):
            kind, blob = "hpdr", val.to_bytes()
        elif isinstance(val, (bytes, bytearray, memoryview)):
            kind, blob = "bytes", bytes(val)
        else:
            buf = io.BytesIO()
            np.save(buf, np.asarray(val), allow_pickle=False)
            kind, blob = "npy", buf.getvalue()
        dir_entries.append(
            {"key": key, "kind": kind, "offset": off, "nbytes": len(blob)}
        )
        off += len(blob)
        blobs.append(blob)
    header = dumps_json({"entries": dir_entries, "extra": extra or {}})
    out = io.BytesIO()
    out.write(_PREFIX.pack(len(header)))
    out.write(header)
    for blob in blobs:
        out.write(blob)
    return out.getvalue()


def loads_payload(payload: bytes) -> tuple[dict[str, Any], dict]:
    """Parse a :func:`dumps_payload` blob → ``(entries, extra)``.

    Corruption — truncated directory, out-of-bounds entry, un-parseable
    container/array blob — raises :class:`ProtocolError`
    (``field="payload"``).
    """
    if len(payload) < _PREFIX.size:
        raise ProtocolError(
            f"flat payload truncated at {len(payload)} bytes", field="payload"
        )
    (hlen,) = _PREFIX.unpack(payload[: _PREFIX.size])
    base = _PREFIX.size + hlen
    if base > len(payload):
        raise ProtocolError(
            f"flat payload directory ({hlen} bytes) overruns payload "
            f"({len(payload)} bytes)",
            field="payload",
        )
    header = loads_json(payload[_PREFIX.size : base])
    try:
        dir_entries = header["entries"]
        extra = header["extra"]
    except (TypeError, KeyError) as e:
        raise ProtocolError(
            f"flat payload directory missing {e}", field="payload"
        ) from e
    flat: dict[str, Any] = {}
    for entry in dir_entries:
        try:
            key, kind = entry["key"], entry["kind"]
            lo = base + int(entry["offset"])
            hi = lo + int(entry["nbytes"])
        except (TypeError, KeyError, ValueError) as e:
            raise ProtocolError(
                f"malformed flat payload entry {entry!r}: {e}", field="payload"
            ) from e
        if hi > len(payload) or lo < base:
            raise ProtocolError(
                f"flat payload entry {key!r} [{lo}:{hi}) out of bounds "
                f"({len(payload)} bytes)",
                field="payload",
            )
        blob = payload[lo:hi]
        try:
            if kind == "hpdr":
                flat[key] = Compressed.from_bytes(blob)
            elif kind == "npy":
                flat[key] = np.load(io.BytesIO(blob), allow_pickle=False)
            elif kind == "bytes":
                flat[key] = blob
            else:
                raise ValueError(f"unknown entry kind {kind!r}")
        except ProtocolError:
            raise
        except Exception as e:
            raise ProtocolError(
                f"corrupt flat payload entry {key!r} ({kind}): {e}",
                field="payload",
            ) from e
    return flat, extra


def error_payload(exc: BaseException) -> bytes:
    """Serialise an exception for an ``OP_ERROR`` response frame."""
    message = str(exc)
    fld = getattr(exc, "field", None)
    if fld is not None and message.endswith(f" [field={fld}]"):
        # strip the rendered suffix: the client re-raises with the same
        # field and would otherwise double it
        message = message[: -len(f" [field={fld}]")]
    detail: dict[str, Any] = {"error": type(exc).__name__, "message": message}
    if fld is not None:
        detail["field"] = fld
    return dumps_json(detail)


def raise_error_payload(payload: bytes) -> None:
    """Re-raise a server-side error from an ``OP_ERROR`` payload.

    Known types map back to their client-visible classes:
    :class:`ProtocolError` (with its ``field``),
    :class:`~repro.serving.service.ServiceOverloaded`, and
    :class:`~repro.core.container.ContainerError`; anything else surfaces
    as ``RuntimeError`` with the server-side type name prefixed.
    """
    from .service import ServiceOverloaded  # cycle-free at call time

    detail = loads_json(payload)
    name = detail.get("error", "RuntimeError")
    message = detail.get("message", "remote error")
    if name == "ProtocolError":
        raise ProtocolError(message, field=detail.get("field", "unknown"))
    if name == "ServiceOverloaded":
        raise ServiceOverloaded(message)
    if name == "ContainerError":
        raise ContainerError(message)
    raise RuntimeError(f"{name}: {message}")
