"""Wire-protocol front door: sockets in, :class:`ReductionService` behind.

:class:`ReductionServer` accepts connections on a Unix-domain socket and/or
localhost TCP, parses :mod:`repro.serving.protocol` frames, admits each
request into the shared :class:`~repro.serving.service.ReductionService`
(quicklook / fetch-KV ride the ``interactive`` priority lane, reduction the
``bulk`` lane), and demultiplexes responses back per connection — requests
from one connection resolve out of order without blocking each other, and
requests from *different* connections coalesce into the same stacked
engine buckets exactly as in-process threads do.

Fault containment is the design center (this is a trust boundary):

  * every frame field is validated before any allocation or dispatch; a
    malformed frame gets an ``OP_ERROR`` response naming the field and —
    when the failure means framing sync is lost (bad length prefix, torn
    body, wrong magic/version) — the connection is closed, never the
    server;
  * a client dying mid-request just ends its reader loop: its socket is
    reclaimed, its in-flight responses are dropped on the floor
    (``send_failures``), and every other connection keeps streaming;
  * per-connection byte/frame counters are pushed into the service's
    :attr:`~repro.serving.service.ServiceStats.connections` so overload
    and abuse are observable per peer.
"""

from __future__ import annotations

import itertools
import os
import socket
import tempfile
import threading
from pathlib import Path
from typing import Any, Callable

import numpy as np

from ..core.container import Compressed
from . import protocol as P
from .service import INTERACTIVE, ReductionService

_BACKLOG = 64


class _Connection:
    """One accepted peer: its socket, write lock, and identity."""

    def __init__(self, conn_id: str, sock: socket.socket):
        self.id = conn_id
        self.sock = sock
        self.wlock = threading.Lock()
        self.alive = True

    def close(self) -> None:
        self.alive = False
        try:
            self.sock.shutdown(socket.SHUT_RDWR)
        except OSError:
            pass
        try:
            self.sock.close()
        except OSError:
            pass


class ReductionServer:
    """Serve a :class:`ReductionService` over UDS and/or localhost TCP.

    Parameters
    ----------
    service:
        The service to front.  ``None`` builds a private one from
        ``service_kwargs`` and closes it with the server.
    unix_path:
        Unix-domain socket path.  ``None`` with ``tcp=None`` auto-creates
        one under a temp directory (see :attr:`unix_address`); pass
        ``False`` to disable the UDS listener.
    tcp:
        ``(host, port)`` for a TCP listener, ``port=0`` picks a free port
        (see :attr:`tcp_address`).  The default binds no TCP socket; hosts
        outside the loopback are refused — the wire protocol is
        *unauthenticated* and must not be exposed off-host.
    max_frame:
        Per-frame byte ceiling (oversized length prefixes are rejected
        before allocation).
    request_timeout:
        Admission timeout forwarded to the service for each request.
    """

    def __init__(
        self,
        service: ReductionService | None = None,
        *,
        unix_path: Any = None,
        tcp: tuple[str, int] | None = None,
        max_frame: int = P.MAX_FRAME_BYTES,
        request_timeout: float | None = None,
        **service_kwargs: Any,
    ):
        self._own_service = service is None
        self.service = service if service is not None else ReductionService(
            **service_kwargs
        )
        self.max_frame = int(max_frame)
        self.request_timeout = request_timeout
        self._closing = False
        self._lock = threading.Lock()
        self._conn_seq = itertools.count(1)
        self._conns: dict[str, _Connection] = {}
        self._threads: list[threading.Thread] = []
        self._listeners: list[socket.socket] = []
        self._tmpdir: tempfile.TemporaryDirectory | None = None
        # server-local counters (connection byte counters live in the
        # service so ServiceStats is the one-stop snapshot)
        self._m = {
            "accepted": 0, "reclaimed": 0, "requests": 0, "responses": 0,
            "protocol_errors": 0, "send_failures": 0, "torn_frames": 0,
        }

        self.unix_address: str | None = None
        self.tcp_address: tuple[str, int] | None = None
        if unix_path is None and tcp is None:
            self._tmpdir = tempfile.TemporaryDirectory(prefix="hpdr-serve-")
            unix_path = Path(self._tmpdir.name) / "hpdr.sock"
        if unix_path not in (None, False):
            ls = socket.socket(socket.AF_UNIX, socket.SOCK_STREAM)
            ls.bind(str(unix_path))
            ls.listen(_BACKLOG)
            self.unix_address = str(unix_path)
            self._listeners.append(ls)
            self._spawn(self._accept_loop, ls, "unix")
        if tcp is not None:
            host, port = tcp
            if host not in ("127.0.0.1", "localhost", "::1"):
                raise ValueError(
                    f"refusing non-loopback bind {host!r}: the wire protocol "
                    "is unauthenticated (use an ssh tunnel or a mesh proxy)"
                )
            ls = socket.socket(socket.AF_INET, socket.SOCK_STREAM)
            ls.setsockopt(socket.SOL_SOCKET, socket.SO_REUSEADDR, 1)
            ls.bind((host, port))
            ls.listen(_BACKLOG)
            self.tcp_address = ls.getsockname()
            self._listeners.append(ls)
            self._spawn(self._accept_loop, ls, "tcp")

    # ---------------------------------------------------------------- accept

    def _spawn(self, fn: Callable, *args: Any) -> None:
        t = threading.Thread(target=fn, args=args, daemon=True,
                             name="hpdr-server")
        t.start()
        self._threads.append(t)

    def _accept_loop(self, listener: socket.socket, kind: str) -> None:
        while not self._closing:
            try:
                sock, _addr = listener.accept()
            except OSError:
                return  # listener closed
            conn = _Connection(f"{kind}:{next(self._conn_seq)}", sock)
            with self._lock:
                if self._closing:
                    conn.close()
                    return
                self._conns[conn.id] = conn
                self._m["accepted"] += 1
            self.service.note_connection(conn.id, opened=True)
            self._spawn(self._reader_loop, conn)

    # ---------------------------------------------------------------- reader

    def _reader_loop(self, conn: _Connection) -> None:
        """Frame pump for one connection; exits only when the peer is gone."""
        try:
            while not self._closing:
                try:
                    frame = P.recv_frame(conn.sock, max_frame=self.max_frame)
                except P.ProtocolError as e:
                    with self._lock:
                        self._m["protocol_errors"] += 1
                        if e.field == "truncated":
                            self._m["torn_frames"] += 1
                    self.service.note_connection(conn.id, protocol_errors=1)
                    rid = getattr(e, "request_id", 0)
                    if e.field in ("length", "truncated", "magic", "version"):
                        # framing sync is lost (or the peer doesn't speak
                        # HPRW at all): tell it why, then hang up
                        self._send_error(conn, rid, e)
                        return
                    # body-level fault in a well-delimited frame: report and
                    # keep the connection — the next frame is readable
                    self._send_error(conn, rid, e)
                    continue
                except OSError:
                    return  # socket reclaimed under us
                if frame is None:
                    return  # clean EOF
                self.service.note_connection(
                    conn.id, frames_rx=1,
                    rx_bytes=4 + P.HEADER_BYTES
                    + len(frame.tenant.encode()) + len(frame.payload),
                )
                with self._lock:
                    self._m["requests"] += 1
                try:
                    self._handle(conn, frame)
                except P.ProtocolError as e:
                    with self._lock:
                        self._m["protocol_errors"] += 1
                    self.service.note_connection(conn.id, protocol_errors=1)
                    self._send_error(conn, frame.request_id, e)
                except Exception as e:
                    self._send_error(conn, frame.request_id, e)
        finally:
            self._reclaim(conn)

    def _reclaim(self, conn: _Connection) -> None:
        with self._lock:
            known = self._conns.pop(conn.id, None) is not None
            if known:
                self._m["reclaimed"] += 1
        conn.close()
        if known:
            self.service.note_connection(conn.id, closed=True)

    # -------------------------------------------------------------- dispatch

    def _handle(self, conn: _Connection, frame: P.Frame) -> None:
        op, rid, tenant = frame.opcode, frame.request_id, frame.tenant
        svc = self.service
        if op == P.OP_PING:
            self._send(conn, rid, frame.payload)
            return
        if op == P.OP_STATS:
            self._send(conn, rid, P.dumps_json(svc.stats().as_dict()))
            return
        if op == P.OP_RELEASE_KV:
            extra = P.loads_json(frame.payload)
            svc.release_kv(extra["session"], tenant=tenant)
            self._send(conn, rid, P.dumps_json({}))
            return

        if op == P.OP_COMPRESS:
            entries, extra = P.loads_payload(frame.payload)
            tree = {k: np.asarray(v) for k, v in entries.items()}
            select = _wire_select(extra)
            sub = svc.submit_compress(
                tree, select, tenant=tenant, timeout=self.request_timeout
            )
            on_ok = lambda res: P.dumps_payload(res[0], {"stats": res[1]})
        elif op == P.OP_DECOMPRESS:
            entries, _extra = P.loads_payload(frame.payload)
            like = {
                k: (np.empty(tuple(v.meta["shape"]),
                             np.dtype(v.meta["dtype"]))
                    if isinstance(v, Compressed) else v)
                for k, v in entries.items()
            }
            sub = svc.submit_decompress(
                entries, like, tenant=tenant, timeout=self.request_timeout
            )
            on_ok = lambda tree: P.dumps_payload(
                {k: np.asarray(v) for k, v in tree.items()}
            )
        elif op == P.OP_COMPRESS_STREAM:
            entries, extra = P.loads_payload(frame.payload)
            kwargs = dict(extra.get("params", {}))
            sub = svc.submit_compress_stream(
                np.asarray(entries["data"]), extra.get("method", "zfp"),
                tenant=tenant,
                chunk_size=extra.get("chunk_size", "auto"),
                window=extra.get("window", "auto"),
                timeout=self.request_timeout, **kwargs,
            )
            on_ok = lambda res: P.dumps_payload(
                {"stream": res[0]}, {"info": res[1]}
            )
        elif op == P.OP_DECOMPRESS_STREAM:
            entries, extra = P.loads_payload(frame.payload)
            source = extra.get("path") or entries.get("stream")
            if source is None:
                raise P.ProtocolError(
                    "decompress_stream needs a 'path' extra or a 'stream' "
                    "entry",
                    field="payload",
                )
            sel = extra.get("chunks")
            sub = svc.submit_decompress_stream(
                source, chunks=tuple(sel) if sel else None,
                tenant=tenant, timeout=self.request_timeout,
            )
            on_ok = lambda res: P.dumps_payload(
                {"array": res[0]}, {"info": res[1]}
            )
        elif op == P.OP_QUICKLOOK:
            extra = P.loads_json(frame.payload)
            sub = svc.submit_quicklook(
                extra["path"], err=extra.get("err"),
                tiers=extra.get("tiers"), tenant=tenant,
                timeout=self.request_timeout,
            )
            on_ok = lambda res: P.dumps_payload(
                {"array": res[0]}, {"info": res[1]}
            )
        elif op == P.OP_FETCH_KV:
            extra = P.loads_json(frame.payload)
            sub = svc.submit_fetch_kv(
                extra["session"], tenant=tenant, timeout=self.request_timeout
            )
            on_ok = lambda flat: P.dumps_payload(dict(flat))
        elif op == P.OP_PARK_KV:
            entries, extra = P.loads_payload(frame.payload)
            cache = {k: np.asarray(v) for k, v in entries.items()}
            sub = svc.submit_park_kv(
                extra["session"], cache, tenant=tenant,
                timeout=self.request_timeout,
            )
            on_ok = lambda res: P.dumps_payload(None, {"stats": res})
        else:  # response opcodes arriving as requests
            raise P.ProtocolError(
                f"opcode {frame.opcode_name!r} is not a request",
                field="opcode",
            )

        sub.add_done_callback(
            lambda s, c=conn, r=rid, f=on_ok: self._complete(c, r, s, f)
        )

    def _complete(self, conn: _Connection, rid: int, sub, serialize) -> None:
        exc = sub.exception()
        if exc is not None:
            self._send_error(conn, rid, exc)
            return
        try:
            payload = serialize(sub.result())
        except Exception as e:
            self._send_error(conn, rid, e)
            return
        self._send(conn, rid, payload)

    # ------------------------------------------------------------- responses

    def _send(self, conn: _Connection, rid: int, payload: bytes,
              *, opcode: int = P.OP_OK, flags: int = 0) -> None:
        blob = P.encode_frame(opcode, rid, payload, tenant="", flags=flags)
        try:
            with conn.wlock:
                conn.sock.sendall(blob)
        except OSError:
            # the peer died between request and response: its reader loop
            # reclaims the socket, this response just evaporates
            with self._lock:
                self._m["send_failures"] += 1
            conn.close()
            return
        with self._lock:
            self._m["responses"] += 1
        self.service.note_connection(conn.id, frames_tx=1, tx_bytes=len(blob))

    def _send_error(self, conn: _Connection, rid: int,
                    exc: BaseException) -> None:
        self._send(conn, rid, P.error_payload(exc),
                   opcode=P.OP_ERROR, flags=P.FLAG_ERROR)

    # --------------------------------------------------------------- metrics

    def stats(self) -> dict[str, Any]:
        with self._lock:
            out = dict(self._m)
            out["open_connections"] = len(self._conns)
        return out

    # ------------------------------------------------------------- lifecycle

    def close(self, timeout: float | None = None) -> None:
        """Stop accepting, drop connections, close an owned service."""
        self._closing = True
        for ls in self._listeners:
            try:
                ls.close()
            except OSError:
                pass
        with self._lock:
            conns = list(self._conns.values())
        for conn in conns:
            self._reclaim(conn)
        for t in self._threads:
            t.join(timeout if timeout is not None else 5.0)
        if self.unix_address and os.path.exists(self.unix_address):
            try:
                os.unlink(self.unix_address)
            except OSError:
                pass
        if self._own_service:
            self.service.close(timeout)
        if self._tmpdir is not None:
            self._tmpdir.cleanup()

    def __enter__(self) -> "ReductionServer":
        return self

    def __exit__(self, *exc) -> None:
        self.close()


def _wire_select(extra: dict):
    """Uniform codec selector from a request's ``method``/``params`` extra.

    Callables can't cross the wire, so remote compress requests name one
    ``(method, params)`` applied to every leaf; with no method the
    service-side default policy decides per leaf.
    """
    method = extra.get("method")
    if not method:
        return None
    params = dict(extra.get("params", {}))

    def select(key: str, arr: np.ndarray):
        del key, arr
        return method, dict(params)

    return select
