"""Multi-tenant reduction service — admission, coalescing, backpressure.

The engine's headline throughput comes from *aggregated* dispatch: stacked
``shard_map`` buckets that keep every data-axis device saturated, one plan
per spec with every further leaf a CMM hit.  Direct library calls leave
that aggregation to the caller; under heavy concurrent traffic each client
request would dispatch its own (often singleton) buckets and the substrate
degenerates to per-request launches.  :class:`ReductionService` is the
request layer that restores aggregation *across* clients:

  * **Admission queue** — a bounded queue in front of the dispatcher; the
    ``overload`` policy decides what happens when it fills: ``"block"``
    (backpressure on the producer, optionally bounded by a timeout),
    ``"reject"`` (fail fast with :class:`ServiceOverloaded`), or
    ``"shed"`` (drop the *oldest* queued request — freshest-first under
    overload, the classic load-shedding rule; bulk is shed before
    interactive).
  * **Priority classes** (PR 10) — admission splits into ``interactive``
    (quicklook, admitted KV fetch) and ``bulk`` (compress/decompress,
    streams, parks) lanes.  The dispatcher's weighted dequeue prefers
    interactive but forces one bulk request through after
    ``starvation_limit`` consecutive interactive pops while bulk waits,
    so neither class starves.  Per-priority wait histograms
    (p50/p99/mean/max) land in :class:`ServiceStats` — and cross-request
    ``decompress_stream`` requests for the SAME stream coalesce per
    dispatch cycle through the container chunk index (each distinct
    chunk decoded once).
  * **Request coalescing** — the dispatcher drains whatever arrives within
    a short ``batch_window`` and merges same-``(spec, shape, dtype)`` leaf
    jobs *from different requests* into ONE stacked bucket submission on
    the engine's existing ``shard_map`` path.  Responses stay bit-identical
    to the direct API because stacked and per-leaf execution agree
    byte-for-byte; when a bucket can't fill (singleton) or specs are
    heterogeneous, jobs degrade gracefully to per-leaf dispatch.
  * **Auto-tuned streams** — :meth:`ReductionService.compress_stream`
    routes one large array through the chunked ``CompressorStream`` with
    ``chunk_size="auto", window="auto"``: the dispatch path consults the
    calibrated chunk/window tuner (``core/tuner.py``) per payload, and the
    chunks ride the engine's compute/io lanes while staging runs on a
    dedicated stream pool.
  * **Per-tenant quotas** — parked KV sessions ride a tenant-scoped
    :class:`~repro.serving.engine.KVPageStore`: each tenant's resident
    bytes are bounded independently (LRU spill within the tenant), so one
    heavy tenant cannot displace another's sessions.
  * **Service metrics** — :meth:`ReductionService.stats` snapshots a
    :class:`ServiceStats`: queue depth, admission wait times, batch fill
    ratio, coalesce hits, shed/reject counts, per-tenant bytes, per-
    priority wait histograms, per-connection byte counters (fed by the
    wire server), and the executor's per-lane and per-priority
    queue-depth/wait-time counters.

Cross-process clients reach the same service through the wire protocol —
:class:`~repro.serving.server.ReductionServer` /
:class:`~repro.serving.client.ReductionClient` (``serving/protocol.py``
frames; socket results byte-identical to these in-process calls).

Typical use::

    svc = ReductionService(max_queue=64, overload="reject",
                           batch_window=0.002)
    flat, stats = svc.compress(tree, tenant="team-a")   # many client threads
    restored = svc.decompress(flat, tree, tenant="team-a")
    snap = svc.stats()
    svc.close()
"""

from __future__ import annotations

import dataclasses
import os
import threading
import time
from collections import deque
from concurrent.futures import Future, ThreadPoolExecutor
from dataclasses import dataclass, field
from typing import Any, Callable

import jax
import jax.numpy as jnp
import numpy as np

from ..core import api
from ..core import engine as engine_mod
from ..runtime.executor import Submission
from .engine import KVPageStore

_DEFAULT_TENANT = "default"

OVERLOAD_POLICIES = ("block", "reject", "shed")

# Priority classes (PR 10): latency-sensitive reads vs bulk reduction.
# ``interactive`` work is answered from metadata-scale or single-pread
# paths (progressive quicklooks, parked-KV fetches); ``bulk`` is the
# engine-bound compress/decompress traffic.  The dispatcher dequeues
# interactive first, with a starvation bound guaranteeing bulk progress.
INTERACTIVE, BULK = "interactive", "bulk"
PRIORITIES = (INTERACTIVE, BULK)

_KIND_PRIORITY = {
    "quicklook": INTERACTIVE,
    "fetch_kv": INTERACTIVE,
    "compress": BULK,
    "decompress": BULK,
    "stream": BULK,
    "decompress_stream": BULK,
    "park_kv": BULK,
}

# bounded reservoir per priority for wait-time histograms: enough samples
# for a stable p99 without unbounded growth on long-lived services
_WAIT_SAMPLES = 4096


class ServiceOverloaded(RuntimeError):
    """Raised when the admission queue is full (``reject``), an admission
    wait times out (``block`` with timeout), or a queued request is dropped
    to make room for a newer one (``shed``)."""


@dataclass
class _Request:
    """One admitted client request, resolved through ``future``."""

    kind: str  # "compress" | "decompress" | "park_kv" | "stream" | "quicklook"
    tenant: str
    future: Future
    t_enqueue: float
    # payload (by kind)
    tree: Any = None
    select: Callable | None = None
    comp: dict | None = None
    like: Any = None
    session_id: str | None = None
    sep: str = "/"
    method: str | None = None      # stream: codec name
    stream_kwargs: dict = field(default_factory=dict)
    # dispatcher bookkeeping
    order: list = field(default_factory=list)
    raw: dict = field(default_factory=dict)
    stats: dict = field(default_factory=dict)
    results: dict = field(default_factory=dict)
    remaining: int = 0
    failed: bool = False
    coalesced: bool = False
    lock: threading.Lock = field(default_factory=threading.Lock)

    @property
    def priority(self) -> str:
        return _KIND_PRIORITY.get(self.kind, BULK)


@dataclass
class ServiceStats:
    """One consistent snapshot of the service's observable state."""

    queue_depth: int
    max_queue: int
    inflight_requests: int
    admitted: int
    completed: int
    failed: int
    rejected: int
    shed: int
    dispatch_cycles: int
    wait_s_mean: float
    wait_s_max: float
    stacked_buckets: int
    stacked_leaves: int
    coalesced_buckets: int
    coalesced_requests: int
    fallback_leaves: int
    batch_fill_ratio: float        # leaves per stacked bucket
    requests_per_bucket: float     # distinct requests per stacked bucket
    decode_stacked_buckets: int
    decode_stacked_leaves: int
    decode_fallback_leaves: int
    stream_requests: int
    stream_serial_degrades: int    # auto-tuned streams degraded to window=1
    quicklook_requests: int
    quicklook_bytes: int           # component bytes fetched by quicklooks
    stream_decode_requests: int
    chunk_decodes: int             # stream chunks actually decoded
    chunk_coalesce_hits: int       # chunk needs served from another request's decode
    per_tenant: dict[str, dict[str, Any]]
    # per-priority admission view: depth, admitted/dispatched counts, the
    # starvation-bound trips ("forced"), and the wait histogram
    # (mean/max/p50/p99 over a bounded reservoir)
    priorities: dict[str, dict[str, float]]
    executor_lanes: dict[str, dict[str, float]]
    executor_priorities: dict[str, dict[str, float]]
    # wire-server connection accounting (empty when no server is attached):
    # open/opened/closed counts, aggregate+per-connection byte counters
    connections: dict[str, Any]
    kv: dict[str, Any]

    def as_dict(self) -> dict:
        return dataclasses.asdict(self)


class ReductionService:
    """Thread-safe multi-tenant front-end over one execution engine.

    Client threads call :meth:`compress` / :meth:`decompress` /
    :meth:`park_kv` (or their ``submit_*`` async forms); a single
    dispatcher thread admits, batches, and coalesces the work onto the
    engine, and per-leaf results fan back out to each request's future on
    the executor's completion threads — no client thread ever blocks
    another's progress except through the admission queue itself.

    Parameters
    ----------
    engine:
        The :class:`~repro.core.engine.ExecutionEngine` to run on
        (default: the process-wide engine).  The service never closes it.
    max_queue:
        Admission queue bound (requests).
    overload:
        ``"block"`` | ``"reject"`` | ``"shed"`` — what a full queue does.
    batch_window:
        Seconds the dispatcher lingers collecting more requests to coalesce
        after the first arrives.  ``0`` still coalesces whatever is already
        queued (burst batching) without adding latency.
    max_batch_requests:
        Upper bound on requests merged into one dispatch cycle.
    starvation_limit:
        The weighted-dequeue starvation bound: at most this many
        consecutive ``interactive`` requests are dequeued while ``bulk``
        work waits, after which one bulk request is forced through.
        Interactive work (quicklook, fetch_kv) therefore waits behind at
        most ONE in-progress dispatch plus the batch window, and bulk can
        be delayed by at most ``starvation_limit`` interactive dequeues.
    kv_store:
        A pre-built tenant-scoped :class:`KVPageStore`; by default one is
        created with ``kv_capacity_bytes`` / ``tenant_quota_bytes``.
    """

    def __init__(
        self,
        engine: engine_mod.ExecutionEngine | None = None,
        *,
        max_queue: int = 64,
        overload: str = "block",
        batch_window: float = 0.002,
        max_batch_requests: int = 32,
        starvation_limit: int = 4,
        kv_store: KVPageStore | None = None,
        kv_capacity_bytes: int = 256 << 20,
        kv_rate: int = 12,
        tenant_quota_bytes: dict[str, int] | None = None,
        spill_dir: Any = None,
    ):
        if overload not in OVERLOAD_POLICIES:
            raise ValueError(
                f"overload must be one of {OVERLOAD_POLICIES}, got {overload!r}"
            )
        if starvation_limit < 1:
            raise ValueError("starvation_limit must be >= 1")
        self.engine = engine if engine is not None else engine_mod.default_engine()
        self.max_queue = int(max_queue)
        self.overload = overload
        self.batch_window = float(batch_window)
        self.max_batch_requests = int(max_batch_requests)
        self.starvation_limit = int(starvation_limit)
        self.kv = kv_store if kv_store is not None else KVPageStore(
            capacity_bytes=kv_capacity_bytes,
            spill_dir=spill_dir,
            rate=kv_rate,
            engine=self.engine,
            tenant_quota_bytes=tenant_quota_bytes,
        )
        self._queues: dict[str, deque[_Request]] = {
            p: deque() for p in PRIORITIES
        }
        self._interactive_run = 0  # consecutive interactive dequeues
        self._cond = threading.Condition()
        self._closing = False
        self._inflight = 0
        # metrics (all under _mlock)
        self._mlock = threading.Lock()
        self._m = {
            "admitted": 0, "completed": 0, "failed": 0, "rejected": 0,
            "shed": 0, "dispatch_cycles": 0, "wait_s_total": 0.0,
            "wait_count": 0, "wait_s_max": 0.0, "stacked_buckets": 0,
            "stacked_leaves": 0, "coalesced_buckets": 0,
            "coalesced_requests": 0, "fallback_leaves": 0,
            "bucket_requests_sum": 0, "decode_stacked_buckets": 0,
            "decode_stacked_leaves": 0, "decode_fallback_leaves": 0,
            "stream_requests": 0, "stream_serial_degrades": 0,
            "quicklook_requests": 0, "quicklook_bytes": 0,
            "stream_decode_requests": 0, "chunk_decodes": 0,
            "chunk_coalesce_hits": 0,
        }
        self._prio_m = {
            p: {"admitted": 0, "dispatched": 0, "forced": 0,
                "wait_s_total": 0.0, "wait_s_max": 0.0}
            for p in PRIORITIES
        }
        self._wait_samples: dict[str, deque[float]] = {
            p: deque(maxlen=_WAIT_SAMPLES) for p in PRIORITIES
        }
        # wire-server connection counters (fed by ReductionServer)
        self._conns: dict[str, dict[str, int]] = {}
        self._conn_totals = {
            "opened": 0, "closed": 0, "rx_bytes": 0, "tx_bytes": 0,
            "frames_rx": 0, "frames_tx": 0, "protocol_errors": 0,
        }
        self._tenants: dict[str, dict[str, Any]] = {}
        # chunked single-array streams run on their own small pool: each
        # stream's staging loop lives on a pool thread while its chunk
        # compute/serialize tasks ride the engine's lanes — staging must
        # never occupy a lane its own chunks are queued behind
        self._stream_pool = ThreadPoolExecutor(
            max_workers=2, thread_name_prefix="hpdr-service-stream"
        )
        self._thread = threading.Thread(
            target=self._loop, name="hpdr-service-dispatch", daemon=True
        )
        self._thread.start()

    # ------------------------------------------------------------- admission

    def _depth(self) -> int:
        # caller holds _cond
        return sum(len(q) for q in self._queues.values())

    def _shed_victim(self) -> _Request | None:
        # caller holds _cond.  Shed the oldest BULK request first: under
        # overload the latency-sensitive class is the last to be dropped.
        for prio in (BULK, INTERACTIVE):
            if self._queues[prio]:
                return self._queues[prio].popleft()
        return None

    def _admit(self, req: _Request, timeout: float | None) -> None:
        with self._cond:
            if self._closing:
                raise RuntimeError("ReductionService is closed")
            deadline = None if timeout is None else time.monotonic() + timeout
            while self._depth() >= self.max_queue:
                if self.overload == "reject":
                    with self._mlock:
                        self._m["rejected"] += 1
                    raise ServiceOverloaded(
                        f"admission queue full ({self.max_queue} requests)"
                    )
                if self.overload == "shed":
                    victim = self._shed_victim()
                    with self._mlock:
                        self._m["shed"] += 1
                    # resolve outside _cond?  set_exception is lock-free and
                    # never calls back into the service — safe to fail here
                    self._fail(victim, ServiceOverloaded(
                        "request shed: queue overflow, newer work preferred"
                    ), counted="shed")
                    break
                remaining = None
                if deadline is not None:
                    remaining = deadline - time.monotonic()
                    if remaining <= 0:
                        with self._mlock:
                            self._m["rejected"] += 1
                        raise ServiceOverloaded(
                            f"admission wait exceeded {timeout}s"
                        )
                self._cond.wait(remaining)
                if self._closing:
                    raise RuntimeError("ReductionService is closed")
            self._queues[req.priority].append(req)
            self._inflight += 1
            with self._mlock:
                self._m["admitted"] += 1
                self._prio_m[req.priority]["admitted"] += 1
                t = self._tenants.setdefault(
                    req.tenant, {"requests": 0, "raw_bytes": 0}
                )
                t["requests"] += 1
            self._cond.notify_all()

    def _submit(self, req: _Request, timeout: float | None) -> Submission:
        self._admit(req, timeout)
        return Submission(req.future, device=None, lane="service")

    # ------------------------------------------------------------ client API

    def submit_compress(
        self,
        tree: Any,
        select: Callable | None = None,
        *,
        tenant: str = _DEFAULT_TENANT,
        sep: str = "/",
        timeout: float | None = None,
    ) -> Submission:
        """Admit a compress request; future resolves to ``(flat, stats)``.

        Bit-identical to :func:`repro.core.api.compress_pytree` on the same
        engine — including leaves served from a coalesced cross-request
        bucket and leaves that took the per-leaf fallback.
        """
        req = _Request(
            kind="compress", tenant=str(tenant), future=Future(),
            t_enqueue=time.monotonic(), tree=tree, select=select, sep=sep,
        )
        return self._submit(req, timeout)

    def compress(self, tree, select=None, *, tenant=_DEFAULT_TENANT,
                 sep="/", timeout=None):
        return self.submit_compress(
            tree, select, tenant=tenant, sep=sep, timeout=timeout
        ).result()

    def submit_decompress(
        self,
        comp: dict[str, Any],
        like: Any,
        *,
        tenant: str = _DEFAULT_TENANT,
        sep: str = "/",
        timeout: float | None = None,
    ) -> Submission:
        """Admit a decompress request; future resolves to the restored tree."""
        req = _Request(
            kind="decompress", tenant=str(tenant), future=Future(),
            t_enqueue=time.monotonic(), comp=comp, like=like, sep=sep,
        )
        return self._submit(req, timeout)

    def decompress(self, comp, like, *, tenant=_DEFAULT_TENANT, sep="/",
                   timeout=None):
        return self.submit_decompress(
            comp, like, tenant=tenant, sep=sep, timeout=timeout
        ).result()

    def submit_compress_stream(
        self,
        data: Any,
        method: str = "zfp",
        *,
        tenant: str = _DEFAULT_TENANT,
        chunk_size: int | str = "auto",
        window: int | str = "auto",
        timeout: float | None = None,
        **params: Any,
    ) -> Submission:
        """Admit a chunked-stream compress of one large array.

        The dispatch path consults the auto-tuner: with the default
        ``chunk_size="auto", window="auto"`` the calibrated machine cost
        model picks the chunking and in-flight window per payload
        (degrading to the serial schedule when overlap can't pay), and the
        chunks ride the engine's compute/io lanes.  The future resolves to
        ``(stream_bytes, info)`` — a framed ``HPDS`` stream (decode with
        :meth:`repro.core.api.CompressorStream.from_bytes`) plus the
        tuner's decision and measured wall/ratio.  Bit-identical to an
        explicitly configured :class:`CompressorStream` with the same
        resolved settings.
        """
        req = _Request(
            kind="stream", tenant=str(tenant), future=Future(),
            t_enqueue=time.monotonic(), tree=data, method=str(method),
            stream_kwargs={"chunk_size": chunk_size, "window": window,
                           **params},
        )
        return self._submit(req, timeout)

    def compress_stream(self, data, method="zfp", *, tenant=_DEFAULT_TENANT,
                        chunk_size="auto", window="auto", timeout=None,
                        **params):
        return self.submit_compress_stream(
            data, method, tenant=tenant, chunk_size=chunk_size,
            window=window, timeout=timeout, **params,
        ).result()

    def submit_quicklook(
        self,
        path: Any,
        *,
        err: float | None = None,
        tiers: int | None = None,
        tenant: str = _DEFAULT_TENANT,
        timeout: float | None = None,
    ) -> Submission:
        """Admit a quicklook read of a progressive stream file.

        ``path`` names an aggregated progressive file (written by
        :meth:`repro.core.progressive.ProgressiveStream.write`).  With no
        ``err``/``tiers`` the coarsest precision tier is answered from ONE
        component ``pread`` — the cheap low-precision preview; an explicit
        ``err`` (absolute bound) or ``tiers`` fetches exactly that prefix.
        The future resolves to ``(array, info)`` with ``info`` carrying
        ``bytes_fetched`` / ``preads`` / ``tiers_loaded`` / ``tier_bound`` /
        ``file_bytes`` — the prefix-vs-full accounting.
        """
        req = _Request(
            kind="quicklook", tenant=str(tenant), future=Future(),
            t_enqueue=time.monotonic(), tree=path,
            stream_kwargs={"err": err, "tiers": tiers},
        )
        return self._submit(req, timeout)

    def quicklook(self, path, *, err=None, tiers=None,
                  tenant=_DEFAULT_TENANT, timeout=None):
        return self.submit_quicklook(
            path, err=err, tiers=tiers, tenant=tenant, timeout=timeout
        ).result()

    def submit_decompress_stream(
        self,
        source: Any,
        *,
        chunks: tuple[int, int] | None = None,
        tenant: str = _DEFAULT_TENANT,
        timeout: float | None = None,
    ) -> Submission:
        """Admit a chunked-stream decode; future resolves to ``(array, info)``.

        ``source`` is a stream file path (written by
        :meth:`~repro.core.api.CompressorStream.to_file`) or framed stream
        bytes (:meth:`to_bytes`); ``chunks=(lo, hi)`` restores only that
        chunk range (concatenated along the stream axis), reading only
        those chunks' byte ranges via the container chunk index.

        Requests for the SAME stream admitted within one dispatch cycle are
        coalesced: each distinct chunk is decoded once and shared — the
        ``chunk_coalesce_hits`` counter is the win.  ``info`` carries the
        chunk range, the group's decode/hit counts, and ``bytes_read``.
        """
        req = _Request(
            kind="decompress_stream", tenant=str(tenant), future=Future(),
            t_enqueue=time.monotonic(), tree=source,
            stream_kwargs={"chunks": chunks},
        )
        return self._submit(req, timeout)

    def decompress_stream(self, source, *, chunks=None,
                          tenant=_DEFAULT_TENANT, timeout=None):
        return self.submit_decompress_stream(
            source, chunks=chunks, tenant=tenant, timeout=timeout
        ).result()

    def submit_fetch_kv(
        self,
        session_id: str,
        *,
        tenant: str = _DEFAULT_TENANT,
        timeout: float | None = None,
    ) -> Submission:
        """Admit a parked-KV fetch on the ``interactive`` priority lane.

        Unlike the direct :meth:`fetch_kv` (which bypasses admission
        entirely), this admitted form is what remote clients ride: it
        contends through the priority queue — where interactive work
        preempts bulk — and its wait lands in the interactive histogram.
        The future resolves to the session's compressed containers.
        """
        req = _Request(
            kind="fetch_kv", tenant=str(tenant), future=Future(),
            t_enqueue=time.monotonic(), session_id=str(session_id),
        )
        return self._submit(req, timeout)

    def submit_park_kv(
        self,
        session_id: str,
        cache: Any,
        *,
        tenant: str = _DEFAULT_TENANT,
        timeout: float | None = None,
    ) -> Submission:
        """Admit a KV-park request; future resolves to the park stats."""
        req = _Request(
            kind="park_kv", tenant=str(tenant), future=Future(),
            t_enqueue=time.monotonic(), session_id=str(session_id),
            tree=cache,
        )
        return self._submit(req, timeout)

    def park_kv(self, session_id, cache, *, tenant=_DEFAULT_TENANT,
                timeout=None):
        return self.submit_park_kv(
            session_id, cache, tenant=tenant, timeout=timeout
        ).result()

    # KV reads bypass admission: they are metadata-scale (or a single
    # spill pread) and must stay responsive under compute overload.

    def fetch_kv(self, session_id, *, tenant=_DEFAULT_TENANT):
        return self.kv.fetch(session_id, tenant=tenant)

    def restore_kv(self, session_id, like, *, tenant=_DEFAULT_TENANT):
        return self.kv.restore(session_id, like, tenant=tenant)

    def release_kv(self, session_id, *, tenant=_DEFAULT_TENANT):
        self.kv.release(session_id, tenant=tenant)

    def set_tenant_quota(self, tenant: str, capacity_bytes: int | None) -> None:
        self.kv.set_tenant_quota(tenant, capacity_bytes)

    # ------------------------------------------------- connection accounting

    def note_connection(
        self,
        conn_id: str,
        *,
        opened: bool = False,
        closed: bool = False,
        rx_bytes: int = 0,
        tx_bytes: int = 0,
        frames_rx: int = 0,
        frames_tx: int = 0,
        protocol_errors: int = 0,
    ) -> None:
        """Accumulate wire-server byte/frame counters for one connection.

        Called by :class:`~repro.serving.server.ReductionServer`; the
        per-connection entries (and the aggregate totals, which survive the
        connection) surface in :attr:`ServiceStats.connections`.
        """
        with self._mlock:
            if opened:
                self._conn_totals["opened"] += 1
                self._conns.setdefault(conn_id, {
                    "rx_bytes": 0, "tx_bytes": 0, "frames_rx": 0,
                    "frames_tx": 0, "protocol_errors": 0,
                })
            entry = self._conns.get(conn_id)
            for k, v in (("rx_bytes", rx_bytes), ("tx_bytes", tx_bytes),
                         ("frames_rx", frames_rx), ("frames_tx", frames_tx),
                         ("protocol_errors", protocol_errors)):
                self._conn_totals[k] += v
                if entry is not None:
                    entry[k] += v
            if closed:
                self._conn_totals["closed"] += 1
                self._conns.pop(conn_id, None)

    # ------------------------------------------------------------ dispatcher

    def _loop(self) -> None:
        while True:
            batch = self._collect()
            if batch is None:
                return
            if batch:
                self._dispatch(batch)

    def _pop_next(self) -> _Request | None:
        """Weighted priority dequeue with a starvation bound.

        ``interactive`` wins every pop — unless it has won
        ``starvation_limit`` consecutive pops while bulk work waited, in
        which case one bulk request is forced through (counted as
        ``forced`` in the priority stats).  Caller holds ``_cond``.
        """
        qi, qb = self._queues[INTERACTIVE], self._queues[BULK]
        if qi and qb and self._interactive_run >= self.starvation_limit:
            self._interactive_run = 0
            with self._mlock:
                self._prio_m[BULK]["forced"] += 1
            return qb.popleft()
        if qi:
            self._interactive_run += 1
            return qi.popleft()
        if qb:
            self._interactive_run = 0
            return qb.popleft()
        return None

    def _collect(self) -> list[_Request] | None:
        """Block for the first request, then linger ``batch_window`` for more."""
        with self._cond:
            while not self._depth() and not self._closing:
                self._cond.wait()
            if not self._depth() and self._closing:
                return None
            batch = [self._pop_next()]
            self._cond.notify_all()  # space freed: wake blocked producers
        deadline = time.monotonic() + self.batch_window
        while len(batch) < self.max_batch_requests:
            with self._cond:
                if self._depth():
                    batch.append(self._pop_next())
                    self._cond.notify_all()
                    continue
                if self._closing:
                    break
                remaining = deadline - time.monotonic()
                if remaining <= 0:
                    break
                self._cond.wait(remaining)
                if not self._depth() and time.monotonic() >= deadline:
                    break
        return batch

    def _dispatch(self, batch: list[_Request]) -> None:
        """Split the batch into leaf jobs, coalesce by spec, submit."""
        now = time.monotonic()
        with self._mlock:
            self._m["dispatch_cycles"] += 1
            for req in batch:
                wait = now - req.t_enqueue
                self._m["wait_s_total"] += wait
                self._m["wait_count"] += 1
                self._m["wait_s_max"] = max(self._m["wait_s_max"], wait)
                pm = self._prio_m[req.priority]
                pm["dispatched"] += 1
                pm["wait_s_total"] += wait
                pm["wait_s_max"] = max(pm["wait_s_max"], wait)
                self._wait_samples[req.priority].append(wait)

        encode_groups: dict[Any, list[tuple[_Request, tuple]]] = {}
        decode_groups: dict[tuple, list[tuple[_Request, str, Any]]] = {}
        stream_decode_groups: dict[Any, list[_Request]] = {}
        for req in batch:
            try:
                if req.kind == "compress":
                    order, raw, jobs, stats = self.engine.encode_leaf_jobs(
                        req.tree, req.select, sep=req.sep
                    )
                    req.order, req.raw, req.stats = order, raw, stats
                    req.stats["buckets"] = len({j[3] for j in jobs})
                    req.remaining = len(jobs)
                    with self._mlock:
                        self._tenants[req.tenant]["raw_bytes"] += stats["raw"]
                    if not jobs:
                        self._resolve_compress(req)
                        continue
                    for job in jobs:
                        encode_groups.setdefault(job[3], []).append((req, job))
                elif req.kind == "decompress":
                    groups = self.engine.decode_leaf_groups(req.comp)
                    req.remaining = sum(len(v) for v in groups.values())
                    if req.remaining == 0:
                        self._resolve_decompress(req)
                        continue
                    for group, items in groups.items():
                        decode_groups.setdefault(group, []).extend(
                            (req, key, c) for key, c in items
                        )
                elif req.kind == "stream":
                    # off the dispatcher thread: the stream's staging loop
                    # blocks on its in-flight window
                    self._stream_pool.submit(self._run_stream, req)
                elif req.kind == "quicklook":
                    # one (or a prefix of) pread + a small reconstruction;
                    # never let file I/O block the dispatcher
                    self._stream_pool.submit(self._run_quicklook, req)
                elif req.kind == "fetch_kv":
                    # a dict lookup or a single spill pread — interactive
                    self._stream_pool.submit(self._run_fetch_kv, req)
                elif req.kind == "decompress_stream":
                    # cross-request coalescing: same-stream requests in one
                    # dispatch cycle share per-chunk decodes via the
                    # container chunk index (ROADMAP "service hardening")
                    stream_decode_groups.setdefault(
                        self._stream_key(req), []
                    ).append(req)
                else:  # park_kv
                    sub = self.kv.park_async(
                        req.session_id, req.tree, tenant=req.tenant
                    )
                    sub.add_done_callback(
                        lambda s, r=req: self._resolve_from_submission(r, s)
                    )
            except Exception as e:
                self._fail(req, e)

        for spec, entries in encode_groups.items():
            items = [job for (_r, job) in entries]
            reqs = {id(r): r for r, _ in entries}
            if self.engine.encode_bucket_stackable(spec, items):
                self._note_stacked(len(items), reqs.values(), encode=True)
                sub = self.engine.submit_encode_bucket(spec, items, priority=BULK)
                sub.add_done_callback(
                    lambda s, es=entries: self._on_encode_bucket(es, s)
                )
            else:
                with self._mlock:
                    self._m["fallback_leaves"] += len(items)
                for req, job in entries:
                    sub = self.engine.submit_encode_job(job, priority=BULK)
                    sub.add_done_callback(
                        lambda s, r=req, k=job[0]: self._on_leaf(r, k, s)
                    )

        for (spec, _geo), entries in decode_groups.items():
            items = [(key, c) for (_r, key, c) in entries]
            reqs = {id(r): r for r, _k, _c in entries}
            prepared = self.engine.decode_bucket_prepared(spec, items)
            if prepared is not None:
                self._note_stacked(len(items), reqs.values(), encode=False)
                sub = self.engine.submit_decode_bucket(
                    spec, items, prepared, priority=BULK
                )
                sub.add_done_callback(
                    lambda s, es=entries: self._on_decode_bucket(es, s)
                )
            else:
                with self._mlock:
                    self._m["decode_fallback_leaves"] += len(items)
                for req, key, c in entries:
                    sub = self.engine.submit_decode_job(spec, c, priority=BULK)
                    sub.add_done_callback(
                        lambda s, r=req, k=key: self._on_leaf(r, k, s)
                    )

        for _key, reqs_same_stream in stream_decode_groups.items():
            self._stream_pool.submit(
                self._run_stream_decode_group, reqs_same_stream
            )

    def _run_stream(self, req: _Request) -> None:
        """One auto-tuned CompressorStream run on a stream-pool thread."""
        try:
            data = np.asarray(req.tree)
            stream = api.CompressorStream(
                req.method, engine=self.engine, frame=True,
                **req.stream_kwargs,
            )
            res = stream.compress(data)
            blob = api.CompressorStream.to_bytes(res)
            info = {
                "tuned": res.tuned,
                "window": res.window,
                "chunks": len(res.chunks),
                "wall_s": res.wall_time,
                "raw_bytes": int(data.nbytes),
                "stream_bytes": len(blob),
                "ratio": data.nbytes / max(len(blob), 1),
            }
            with self._mlock:
                self._m["stream_requests"] += 1
                if res.tuned is not None and res.window == 1:
                    self._m["stream_serial_degrades"] += 1
                self._tenants[req.tenant]["raw_bytes"] += int(data.nbytes)
            self._resolve(req, (blob, info))
        except Exception as e:
            self._fail(req, e)

    def _run_quicklook(self, req: _Request) -> None:
        """Answer a precision-tier read from a progressive stream file."""
        try:
            from ..core import progressive  # lazy: serving ↔ core layering

            err = req.stream_kwargs.get("err")
            tiers = req.stream_kwargs.get("tiers")
            with progressive.ProgressiveReader(req.tree) as r:
                if err is None and tiers is None:
                    tiers = 1  # default preview: coarsest tier, one pread
                arr = np.asarray(r.retrieve(err, tiers=tiers))
                info = {
                    "bytes_fetched": r.bytes_fetched,
                    "preads": r.preads,
                    "tiers_loaded": r.tiers_loaded,
                    "tier_bound": r.tier_bounds[r.tiers_loaded - 1],
                    "file_bytes": int(os.path.getsize(req.tree)),
                }
            with self._mlock:
                self._m["quicklook_requests"] += 1
                self._m["quicklook_bytes"] += info["bytes_fetched"]
            self._resolve(req, (arr, info))
        except Exception as e:
            self._fail(req, e)

    def _run_fetch_kv(self, req: _Request) -> None:
        """Admitted (interactive-priority) parked-KV fetch."""
        try:
            self._resolve(
                req, self.kv.fetch(req.session_id, tenant=req.tenant)
            )
        except Exception as e:
            self._fail(req, e)

    @staticmethod
    def _stream_key(req: _Request) -> tuple:
        """Identity of a stream source: same key ⇒ same chunk index."""
        from ..core.container import crc32_of

        src = req.tree
        if isinstance(src, (bytes, bytearray, memoryview)):
            raw = bytes(src)
            return ("bytes", len(raw), crc32_of(raw))
        return ("file", os.path.realpath(str(src)))

    def _run_stream_decode_group(self, reqs: list[_Request]) -> None:
        """Decode one stream for N coalesced requests, each chunk once.

        The stream's chunk index locates every chunk, so only the union of
        the requested ranges is ever read or decoded; a chunk needed by
        several requests decodes once and the rest are ``coalesce`` hits.
        """
        try:
            src = reqs[0].tree
            if isinstance(src, (bytes, bytearray, memoryview)):
                result = api.CompressorStream.from_bytes(bytes(src))
            else:
                result = api.CompressorStream.from_file(str(src))
            n = len(result.chunks)
        except Exception as e:
            for req in reqs:
                self._fail(req, e)
            return
        cache: dict[int, np.ndarray] = {}
        decoded = hits = 0
        for req in reqs:
            try:
                sel = req.stream_kwargs.get("chunks")
                lo, hi = (0, n) if sel is None else (int(sel[0]), int(sel[1]))
                if not 0 <= lo < hi <= n:
                    raise IndexError(
                        f"chunk range [{lo}, {hi}) out of bounds for "
                        f"{n}-chunk stream"
                    )
                parts = []
                for i in range(lo, hi):
                    if i in cache:
                        hits += 1
                    else:
                        cache[i] = np.asarray(api.decode(result.chunks[i]))
                        decoded += 1
                    parts.append(cache[i])
                arr = np.concatenate(parts, axis=result.axis)
                reader = getattr(result.chunks, "reader", None)
                info = {
                    "chunks": [lo, hi],
                    "stream_chunks": n,
                    "axis": result.axis,
                    "group_requests": len(reqs),
                    "group_chunk_decodes": decoded,
                    "group_coalesce_hits": hits,
                }
                if reader is not None:
                    info["bytes_read"] = int(
                        getattr(reader, "pread_bytes", 0) or 0
                    )
                self._resolve(req, (arr, info))
            except Exception as e:
                self._fail(req, e)
        reader = getattr(result.chunks, "reader", None)
        if reader is not None:
            reader.close()
        with self._mlock:
            self._m["stream_decode_requests"] += len(reqs)
            self._m["chunk_decodes"] += decoded
            self._m["chunk_coalesce_hits"] += hits

    def _note_stacked(self, n_leaves: int, reqs, *, encode: bool) -> None:
        reqs = list(reqs)
        with self._mlock:
            if encode:
                self._m["stacked_buckets"] += 1
                self._m["stacked_leaves"] += n_leaves
                self._m["bucket_requests_sum"] += len(reqs)
                if len(reqs) > 1:
                    self._m["coalesced_buckets"] += 1
            else:
                self._m["decode_stacked_buckets"] += 1
                self._m["decode_stacked_leaves"] += n_leaves
            if len(reqs) > 1:
                for req in reqs:
                    if not req.coalesced:
                        req.coalesced = True
                        self._m["coalesced_requests"] += 1

    # ------------------------------------------------------------ completion

    def _on_encode_bucket(self, entries, sub: Submission) -> None:
        exc = sub.exception()
        if exc is not None:
            for req, _job in entries:
                self._fail(req, exc)
            return
        for (req, job), c in zip(entries, sub.result()):
            self._deliver(req, job[0], c)

    def _on_decode_bucket(self, entries, sub: Submission) -> None:
        exc = sub.exception()
        if exc is not None:
            for req, _key, _c in entries:
                self._fail(req, exc)
            return
        for (req, key, _c), out in zip(entries, sub.result()):
            self._deliver(req, key, out)

    def _on_leaf(self, req: _Request, key: str, sub: Submission) -> None:
        exc = sub.exception()
        if exc is not None:
            self._fail(req, exc)
            return
        self._deliver(req, key, sub.result())

    def _deliver(self, req: _Request, key: str, value: Any) -> None:
        with req.lock:
            if req.failed:
                return
            req.results[key] = value
            req.remaining -= 1
            finished = req.remaining == 0
        if finished:
            try:
                if req.kind == "compress":
                    self._resolve_compress(req)
                else:
                    self._resolve_decompress(req)
            except Exception as e:
                self._fail(req, e)

    def _resolve_compress(self, req: _Request) -> None:
        stats = req.stats
        flat: dict[str, Any] = {}
        for key in req.order:
            if key in req.raw:
                flat[key] = req.raw[key]
                continue
            c = req.results[key]
            flat[key] = c
            stats["compressed"] += c.nbytes()
            stats["compressed_leaves"] += 1
        stats["ratio"] = stats["raw"] / max(stats["compressed"], 1)
        stats["coalesced"] = req.coalesced
        self._resolve(req, (flat, stats))

    def _resolve_decompress(self, req: _Request) -> None:
        flat = {
            key: req.results[key] if key in req.results else val
            for key, val in req.comp.items()
        }
        leaves_with_path, treedef = jax.tree_util.tree_flatten_with_path(req.like)
        out = [
            jnp.asarray(flat[api._path_key(p, req.sep)])
            for p, _leaf in leaves_with_path
        ]
        self._resolve(req, jax.tree_util.tree_unflatten(treedef, out))

    def _resolve_from_submission(self, req: _Request, sub: Submission) -> None:
        exc = sub.exception()
        if exc is not None:
            self._fail(req, exc)
        else:
            self._resolve(req, sub.result())

    def _resolve(self, req: _Request, value: Any) -> None:
        req.future.set_result(value)
        with self._mlock:
            self._m["completed"] += 1
        self._request_done()

    def _fail(self, req: _Request, exc: BaseException,
              counted: str = "failed") -> None:
        with req.lock:
            if req.failed or req.future.done():
                return
            req.failed = True
        req.future.set_exception(exc)
        if counted == "failed":
            with self._mlock:
                self._m["failed"] += 1
        self._request_done()

    def _request_done(self) -> None:
        with self._cond:
            self._inflight -= 1
            self._cond.notify_all()

    # --------------------------------------------------------------- metrics

    @staticmethod
    def _wait_hist(samples: list[float], pm: dict) -> dict[str, float]:
        n = len(samples)
        arr = np.asarray(samples) if n else None
        return {
            "admitted": pm["admitted"],
            "dispatched": pm["dispatched"],
            "forced": pm["forced"],
            "wait_mean": pm["wait_s_total"] / max(pm["dispatched"], 1),
            "wait_max": pm["wait_s_max"],
            "wait_p50": float(np.percentile(arr, 50)) if n else 0.0,
            "wait_p99": float(np.percentile(arr, 99)) if n else 0.0,
            "samples": n,
        }

    def stats(self) -> ServiceStats:
        with self._cond:
            depths = {p: len(q) for p, q in self._queues.items()}
            depth = sum(depths.values())
            inflight = self._inflight
        lanes = self.engine.executor.lane_stats()
        prio_lanes = self.engine.executor.priority_stats()
        kv_stats = self.kv.stats()
        with self._mlock:
            m = dict(self._m)
            tenants = {t: dict(v) for t, v in self._tenants.items()}
            priorities = {
                p: {"depth": depths[p],
                    **self._wait_hist(list(self._wait_samples[p]),
                                      self._prio_m[p])}
                for p in PRIORITIES
            }
            connections = {
                **self._conn_totals,
                "open": len(self._conns),
                "per_connection": {c: dict(v) for c, v in self._conns.items()},
            }
        parked = kv_stats.get("tenant_bytes", {})
        for tenant, nbytes in parked.items():
            tenants.setdefault(tenant, {"requests": 0, "raw_bytes": 0})
        for tenant in tenants:
            tenants[tenant]["parked_bytes"] = parked.get(tenant, 0)
        return ServiceStats(
            queue_depth=depth,
            max_queue=self.max_queue,
            inflight_requests=inflight,
            admitted=m["admitted"],
            completed=m["completed"],
            failed=m["failed"],
            rejected=m["rejected"],
            shed=m["shed"],
            dispatch_cycles=m["dispatch_cycles"],
            wait_s_mean=m["wait_s_total"] / max(m["wait_count"], 1),
            wait_s_max=m["wait_s_max"],
            stacked_buckets=m["stacked_buckets"],
            stacked_leaves=m["stacked_leaves"],
            coalesced_buckets=m["coalesced_buckets"],
            coalesced_requests=m["coalesced_requests"],
            fallback_leaves=m["fallback_leaves"],
            batch_fill_ratio=(
                m["stacked_leaves"] / max(m["stacked_buckets"], 1)
            ),
            requests_per_bucket=(
                m["bucket_requests_sum"] / max(m["stacked_buckets"], 1)
            ),
            decode_stacked_buckets=m["decode_stacked_buckets"],
            decode_stacked_leaves=m["decode_stacked_leaves"],
            decode_fallback_leaves=m["decode_fallback_leaves"],
            stream_requests=m["stream_requests"],
            stream_serial_degrades=m["stream_serial_degrades"],
            quicklook_requests=m["quicklook_requests"],
            quicklook_bytes=m["quicklook_bytes"],
            stream_decode_requests=m["stream_decode_requests"],
            chunk_decodes=m["chunk_decodes"],
            chunk_coalesce_hits=m["chunk_coalesce_hits"],
            per_tenant=tenants,
            priorities=priorities,
            executor_lanes=lanes,
            executor_priorities=prio_lanes,
            connections=connections,
            kv=kv_stats,
        )

    # ------------------------------------------------------------- lifecycle

    def close(self, timeout: float | None = None) -> None:
        """Drain queued + in-flight requests, then stop the dispatcher.

        Idempotent.  New submissions during/after close raise
        ``RuntimeError``; already-admitted requests complete normally.
        """
        with self._cond:
            self._closing = True
            self._cond.notify_all()
        self._thread.join(timeout)
        deadline = None if timeout is None else time.monotonic() + timeout
        with self._cond:
            while self._inflight > 0:
                remaining = None
                if deadline is not None:
                    remaining = deadline - time.monotonic()
                    if remaining <= 0:
                        break
                self._cond.wait(remaining)
        self._stream_pool.shutdown(wait=True)

    def __enter__(self) -> "ReductionService":
        return self

    def __exit__(self, *exc) -> None:
        self.close()
