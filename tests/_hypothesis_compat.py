"""Offline stand-in for ``hypothesis`` (wired by ``conftest.py``).

When the real package is unavailable, this module registers itself in
``sys.modules`` under the name ``hypothesis`` so the property-test modules
still collect and run.  ``@given`` then executes each test on a small fixed
set of deterministically drawn examples (always including the strategy's
boundary values), which keeps the property tests meaningful as smoke tests
without the shrinking/database machinery.

Only the strategy surface this repo uses is implemented: ``integers``,
``floats``, ``booleans``, ``sampled_from``, ``lists``.  Set
``HPDR_SHIM_EXAMPLES`` to change the per-test example count (default 5).
"""

from __future__ import annotations

import os
import random
import sys
import types

_DEFAULT_EXAMPLES = int(os.environ.get("HPDR_SHIM_EXAMPLES", "5"))


class _Strategy:
    """Base strategy: ``boundary()`` examples first, then random draws."""

    def boundary(self):
        return []

    def draw(self, rng: random.Random):
        raise NotImplementedError


class _Integers(_Strategy):
    def __init__(self, min_value, max_value):
        self.lo, self.hi = int(min_value), int(max_value)

    def boundary(self):
        return [self.lo, self.hi] if self.lo != self.hi else [self.lo]

    def draw(self, rng):
        return rng.randint(self.lo, self.hi)


class _Floats(_Strategy):
    def __init__(self, min_value=0.0, max_value=1.0, **_kw):
        self.lo, self.hi = float(min_value), float(max_value)

    def boundary(self):
        return [self.lo, self.hi]

    def draw(self, rng):
        return rng.uniform(self.lo, self.hi)


class _Booleans(_Strategy):
    def boundary(self):
        return [False, True]

    def draw(self, rng):
        return rng.random() < 0.5


class _SampledFrom(_Strategy):
    def __init__(self, elements):
        self.elements = list(elements)

    def boundary(self):
        return [self.elements[0], self.elements[-1]]

    def draw(self, rng):
        return rng.choice(self.elements)


class _Lists(_Strategy):
    def __init__(self, elements, min_size=0, max_size=10, **_kw):
        self.elements = elements
        self.min_size = int(min_size)
        self.max_size = int(max_size) if max_size is not None else self.min_size + 10

    def boundary(self):
        # smallest list of boundary elements; a mid-size random one comes
        # from draw()
        elem = self.elements.boundary() or [self.elements.draw(random.Random(0))]
        size = max(self.min_size, 1)
        return [[elem[i % len(elem)] for i in range(size)]]

    def draw(self, rng):
        size = rng.randint(self.min_size, self.max_size)
        return [self.elements.draw(rng) for _ in range(size)]


def _examples(strategies, n):
    """Deterministic example tuples: one all-lo, one all-hi, rest random."""
    out = []
    bounds = [s.boundary() for s in strategies]
    if all(bounds):
        out.append(tuple(b[0] for b in bounds))
        hi = tuple(b[-1] for b in bounds)
        if hi != out[0]:
            out.append(hi)
    rng = random.Random(0x5EED)
    while len(out) < n:
        out.append(tuple(s.draw(rng) for s in strategies))
    return out[:n]


def given(*strategies, **kw_strategies):
    if kw_strategies:
        raise NotImplementedError("shim supports positional strategies only")

    def deco(f):
        def wrapper():
            n = getattr(wrapper, "_shim_max_examples", _DEFAULT_EXAMPLES)
            n = min(n, _DEFAULT_EXAMPLES)
            for args in _examples(strategies, n):
                f(*args)

        # NB: no functools.wraps — a __wrapped__ attribute would make pytest
        # re-discover the original signature and demand fixtures for the
        # drawn arguments.
        wrapper.__name__ = f.__name__
        wrapper.__qualname__ = getattr(f, "__qualname__", f.__name__)
        wrapper.__module__ = f.__module__
        wrapper.__doc__ = f.__doc__
        wrapper.hypothesis = types.SimpleNamespace(inner_test=f)
        return wrapper

    return deco


def settings(max_examples: int = _DEFAULT_EXAMPLES, deadline=None, **_kw):
    def deco(f):
        f._shim_max_examples = max_examples
        return f

    return deco


def _install() -> None:
    """Register this module as ``hypothesis`` (+ ``hypothesis.strategies``)."""
    mod = types.ModuleType("hypothesis")
    mod.given = given
    mod.settings = settings
    mod.HealthCheck = types.SimpleNamespace(too_slow=None, data_too_large=None)
    strategies = types.ModuleType("hypothesis.strategies")
    strategies.integers = _Integers
    strategies.floats = _Floats
    strategies.booleans = _Booleans
    strategies.sampled_from = _SampledFrom
    strategies.lists = _Lists
    mod.strategies = strategies
    mod.__is_hpdr_shim__ = True
    sys.modules["hypothesis"] = mod
    sys.modules["hypothesis.strategies"] = strategies
