"""Shared fixtures. NB: no XLA_FLAGS here — tests see the real device count
(the 512-device override belongs exclusively to launch/dryrun.py)."""

import numpy as np
import pytest

try:
    import hypothesis  # noqa: F401
except ImportError:  # offline image: run @given tests on fixed examples
    import _hypothesis_compat

    _hypothesis_compat._install()


@pytest.fixture
def rng():
    return np.random.default_rng(0)


def smooth_field_3d(n: int = 48, noise: float = 0.0, seed: int = 0) -> np.ndarray:
    rng = np.random.default_rng(seed)
    g = np.linspace(0, 4 * np.pi, n)
    x, y, z = np.meshgrid(g, g, g, indexing="ij")
    f = np.sin(x) * np.cos(y) * np.sin(z)
    if noise:
        f = f + noise * rng.normal(size=f.shape)
    return f.astype(np.float32)
