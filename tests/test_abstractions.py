"""HPDR parallel abstractions + machine models + adapter registry."""

import numpy as np
import jax
import jax.numpy as jnp
import pytest

from repro.core import abstractions as ab
from repro.core import adapters
from repro.core.machine import block_view, unblock_view
import repro.kernels  # registers adapter implementations  # noqa: F401


def test_locality_blockwise(rng):
    data = jnp.asarray(rng.normal(size=(12, 8)), jnp.float32)
    out = ab.locality(data, lambda b: b * 2.0, (4, 4))
    np.testing.assert_allclose(np.asarray(out), np.asarray(data) * 2.0)


def test_locality_pads_odd_shapes(rng):
    data = jnp.asarray(rng.normal(size=(10, 7)), jnp.float32)
    out = ab.locality(data, lambda b: b + 1.0, (4, 4))
    assert out.shape == data.shape
    np.testing.assert_allclose(np.asarray(out), np.asarray(data) + 1.0)


def test_block_view_roundtrip(rng):
    data = jnp.asarray(rng.normal(size=(8, 12, 4)), jnp.float32)
    blocks, counts = block_view(data, (4, 4, 4))
    assert blocks.shape == (2 * 3 * 1, 4, 4, 4)
    back = unblock_view(blocks, counts, (4, 4, 4))
    np.testing.assert_array_equal(np.asarray(back), np.asarray(data))


def test_iterative_prefix_sum(rng):
    data = jnp.asarray(rng.normal(size=(16, 5)), jnp.float32)

    def step(carry, x):
        carry = carry + x
        return carry, carry

    _, out = ab.iterative(data, step, jnp.zeros(5), axis=0)
    np.testing.assert_allclose(
        np.asarray(out), np.cumsum(np.asarray(data), axis=0), rtol=1e-6
    )


def test_map_and_process(rng):
    data = jnp.asarray(rng.normal(size=(64,)), jnp.float32)
    ids = jnp.asarray(rng.integers(0, 3, 64), jnp.int32)
    out = ab.map_and_process(data, ids, [lambda x: x, lambda x: 2 * x, lambda x: -x])
    expect = np.asarray(data).copy()
    ids_np = np.asarray(ids)
    expect[ids_np == 1] *= 2
    expect[ids_np == 2] *= -1
    np.testing.assert_allclose(np.asarray(out), expect, rtol=1e-6)


def test_global_pipeline_stages(rng):
    data = jnp.asarray(rng.normal(size=(100,)), jnp.float32)
    pipe = ab.global_pipeline(lambda x: x - jnp.mean(x), lambda x: x / (jnp.std(x) + 1e-9))
    out = np.asarray(pipe(data))
    assert abs(out.mean()) < 1e-5 and abs(out.std() - 1) < 1e-4


def test_adapter_registry_dispatch():
    assert adapters.resolve(None) in adapters.ADAPTERS
    assert adapters.resolve("auto") in adapters.ADAPTERS
    with pytest.raises(ValueError):
        adapters.resolve("cuda")
    # registered kernel ops fall back to xla when pallas impl missing
    fn = adapters.dispatch("histogram", "xla")
    assert callable(fn)
    with pytest.raises(KeyError):
        adapters.dispatch("nonexistent_op", "xla")
