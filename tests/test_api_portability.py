"""Unified API: serialization roundtrip + cross-adapter portability.

The paper's portability contract: a bitstream produced under one device
adapter decodes under any other.
"""

import numpy as np
import jax.numpy as jnp
import pytest

from repro.core import api
from repro.core.context import GLOBAL_CMM
from repro.kernels.zfp_block import ops as zfp_ops
from conftest import smooth_field_3d


@pytest.mark.parametrize(
    "method,kw",
    [
        ("mgard", {"error_bound": 1e-2}),
        ("zfp", {"rate": 12}),
        ("huffman-bytes", {}),
    ],
)
def test_bytes_roundtrip(method, kw):
    f = smooth_field_3d(32)
    c = api.compress(jnp.asarray(f), method, **kw)
    c2 = api.Compressed.from_bytes(c.to_bytes())
    assert c2.method == c.method
    out = np.asarray(api.decompress(c2))
    if method == "huffman-bytes":
        np.testing.assert_array_equal(out, f)
    else:
        vr = f.max() - f.min()
        assert np.abs(out - f).max() <= 2e-2 * vr


def test_huffman_int_roundtrip(rng):
    keys = np.minimum(np.abs(rng.normal(0, 10, 20000)).astype(np.int32), 255)
    c = api.compress(jnp.asarray(keys), "huffman")
    out = np.asarray(api.decompress(api.Compressed.from_bytes(c.to_bytes())))
    np.testing.assert_array_equal(out, keys)


def test_cross_adapter_bitstream_portability(rng):
    """Compress with the Pallas kernel, decompress with the XLA oracle (and
    vice versa) — the paper's cross-architecture data portability claim."""
    blocks = rng.normal(size=(64, 64)).astype(np.float32)
    for enc_a, dec_a in [("pallas_interpret", "xla"), ("xla", "pallas_interpret")]:
        p, e = zfp_ops.compress_blocks(jnp.asarray(blocks), 16, 3, adapter=enc_a)
        out = np.asarray(zfp_ops.decompress_blocks(p, e, 16, 3, adapter=dec_a))
        ref = np.asarray(
            zfp_ops.decompress_blocks(
                *zfp_ops.compress_blocks(jnp.asarray(blocks), 16, 3, adapter=dec_a),
                16, 3, adapter=dec_a,
            )
        )
        np.testing.assert_array_equal(out, ref)


def test_cmm_caches_contexts():
    before = GLOBAL_CMM.hit_count + GLOBAL_CMM.miss_count
    f = smooth_field_3d(16)
    api.compress(jnp.asarray(f), "zfp", rate=8)
    api.compress(jnp.asarray(f), "zfp", rate=8)  # same characteristics → hit
    assert GLOBAL_CMM.hit_count + GLOBAL_CMM.miss_count >= before + 2
    assert GLOBAL_CMM.hit_count >= 1


def test_unknown_method_raises():
    with pytest.raises(ValueError):
        api.compress(jnp.zeros(4), "lz77")
