"""Bitstream pack/unpack: unit + hypothesis property tests."""

import numpy as np
import jax.numpy as jnp
from hypothesis import given, settings, strategies as st

from repro.core import bitstream as bs


def _roundtrip(codes, lengths):
    total = int(lengths.sum())
    w = max(1, bs.words_needed(total))
    words = bs.pack_bits(jnp.asarray(codes), jnp.asarray(lengths), total, w)
    offsets = np.concatenate([[0], np.cumsum(lengths)[:-1]]).astype(np.int32)
    out = np.asarray(bs.unpack_bits(words, jnp.asarray(offsets), jnp.asarray(lengths)))
    return out


def test_roundtrip_basic(rng):
    lengths = rng.integers(1, 33, 500).astype(np.int32)
    codes = np.array(
        [rng.integers(0, 2 ** min(int(l), 31)) for l in lengths], dtype=np.uint32
    )
    assert (_roundtrip(codes, lengths) == codes).all()


def test_zero_length_codes(rng):
    lengths = np.array([4, 0, 7, 0, 32], np.int32)
    codes = np.array([0xF, 0xFFFF, 0x55, 1, 0xDEADBEEF], np.uint32)
    out = _roundtrip(codes, lengths)
    masked = codes.copy()
    masked[lengths == 0] = 0
    masked[4] = 0xDEADBEEF  # full 32-bit survives
    assert (out == masked).all()


@settings(max_examples=50, deadline=None)
@given(st.lists(st.integers(1, 32), min_size=1, max_size=200), st.integers(0, 2**31))
def test_roundtrip_property(length_list, seed):
    rng = np.random.default_rng(seed)
    lengths = np.array(length_list, np.int32)
    codes = np.array(
        [rng.integers(0, 2 ** min(int(l), 31)) for l in lengths], np.uint32
    )
    assert (_roundtrip(codes, lengths) == codes).all()


def test_bits_words_inverse(rng):
    w = rng.integers(0, 2**32, (13, 7), dtype=np.uint32)
    out = np.asarray(bs.bits_to_words(bs.words_to_bits(jnp.asarray(w))))
    assert (out == w).all()


def test_exclusive_cumsum():
    x = jnp.asarray([3, 1, 4, 1, 5])
    out = np.asarray(bs.exclusive_cumsum(x))
    assert (out == np.array([0, 3, 4, 8, 9])).all()
