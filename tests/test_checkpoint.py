"""HPDR-compressed checkpoints: exact mode, lossy bounds, elastic resharding."""

import numpy as np
import jax
import jax.numpy as jnp
import pytest

from repro.checkpoint import CheckpointManager, CheckpointPolicy


def _tree(rng):
    return {
        "w": rng.normal(size=(64, 128)).astype(np.float32),
        "b": rng.normal(size=(128,)).astype(np.float32),
        "emb": {"table": rng.normal(size=(1000, 32)).astype(np.float32)},
        "step": np.int32(7),
    }


def test_exact_roundtrip(tmp_path, rng):
    mgr = CheckpointManager(tmp_path, CheckpointPolicy(exact=True))
    tree = _tree(rng)
    mgr.save(1, tree)
    flat, manifest = mgr.restore(1)
    assert manifest["step"] == 1
    out, _ = mgr.restore(1, target=tree)
    for a, b in zip(jax.tree.leaves(out), jax.tree.leaves(tree)):
        np.testing.assert_array_equal(np.asarray(a), np.asarray(b))


def test_lossy_zfp_bounded(tmp_path, rng):
    mgr = CheckpointManager(tmp_path, CheckpointPolicy(float_method="zfp",
                                                       zfp_rate=28,
                                                       lossless_small=1))
    tree = {"w": rng.normal(size=(256, 256)).astype(np.float32)}
    mgr.save(2, tree)
    out, manifest = mgr.restore(2, target=tree)
    err = np.abs(np.asarray(out["w"]) - tree["w"]).max()
    scale = np.abs(tree["w"]).max()
    assert err <= 1e-4 * scale
    assert manifest["ratio"] > 1.05  # 28-bit rate beats raw f32


def test_async_save(tmp_path, rng):
    mgr = CheckpointManager(tmp_path, CheckpointPolicy(exact=True))
    tree = _tree(rng)
    mgr.save_async(3, tree)
    mgr.wait()
    assert mgr.latest_step() == 3
    out, _ = mgr.restore(3, target=tree)
    np.testing.assert_array_equal(np.asarray(out["w"]), tree["w"])


def test_uncommitted_checkpoints_ignored(tmp_path, rng):
    mgr = CheckpointManager(tmp_path, CheckpointPolicy(exact=True))
    mgr.save(5, _tree(rng))
    # fake a torn checkpoint at step 9
    torn = tmp_path / "step_00000009"
    torn.mkdir()
    (torn / "manifest.json").write_text("{}")
    assert mgr.latest_step() == 5


def test_elastic_reshard_restore(tmp_path, rng):
    """Save unsharded, restore onto a different mesh layout."""
    n = len(jax.devices())
    if n < 1:
        pytest.skip("no devices")
    from jax.sharding import NamedSharding, PartitionSpec as P

    from repro.launch.mesh import make_mesh

    mesh = make_mesh((1,), ("model",))
    mgr = CheckpointManager(tmp_path, CheckpointPolicy(exact=True))
    tree = {"w": rng.normal(size=(64, 64)).astype(np.float32)}
    mgr.save(1, tree)
    sh = {"w": NamedSharding(mesh, P("model", None))}
    out, _ = mgr.restore(1, target=tree, shardings=sh)
    assert out["w"].sharding == sh["w"]
    np.testing.assert_array_equal(np.asarray(out["w"]), tree["w"])
