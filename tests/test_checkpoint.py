"""HPDR-compressed checkpoints: exact mode, lossy bounds, elastic resharding."""

import numpy as np
import jax
import jax.numpy as jnp
import pytest

from repro.checkpoint import CheckpointManager, CheckpointPolicy


def _tree(rng):
    return {
        "w": rng.normal(size=(64, 128)).astype(np.float32),
        "b": rng.normal(size=(128,)).astype(np.float32),
        "emb": {"table": rng.normal(size=(1000, 32)).astype(np.float32)},
        "step": np.int32(7),
    }


def test_exact_roundtrip(tmp_path, rng):
    mgr = CheckpointManager(tmp_path, CheckpointPolicy(exact=True))
    tree = _tree(rng)
    mgr.save(1, tree)
    flat, manifest = mgr.restore(1)
    assert manifest["step"] == 1
    out, _ = mgr.restore(1, target=tree)
    for a, b in zip(jax.tree.leaves(out), jax.tree.leaves(tree)):
        np.testing.assert_array_equal(np.asarray(a), np.asarray(b))


def test_lossy_zfp_bounded(tmp_path, rng):
    mgr = CheckpointManager(tmp_path, CheckpointPolicy(float_method="zfp",
                                                       zfp_rate=28,
                                                       lossless_small=1))
    tree = {"w": rng.normal(size=(256, 256)).astype(np.float32)}
    mgr.save(2, tree)
    out, manifest = mgr.restore(2, target=tree)
    err = np.abs(np.asarray(out["w"]) - tree["w"]).max()
    scale = np.abs(tree["w"]).max()
    assert err <= 1e-4 * scale
    assert manifest["ratio"] > 1.05  # 28-bit rate beats raw f32


def test_async_save(tmp_path, rng):
    mgr = CheckpointManager(tmp_path, CheckpointPolicy(exact=True))
    tree = _tree(rng)
    mgr.save_async(3, tree)
    mgr.wait()
    assert mgr.latest_step() == 3
    out, _ = mgr.restore(3, target=tree)
    np.testing.assert_array_equal(np.asarray(out["w"]), tree["w"])


def test_uncommitted_checkpoints_ignored(tmp_path, rng):
    mgr = CheckpointManager(tmp_path, CheckpointPolicy(exact=True))
    mgr.save(5, _tree(rng))
    # fake a torn checkpoint at step 9
    torn = tmp_path / "step_00000009"
    torn.mkdir()
    (torn / "manifest.json").write_text("{}")
    assert mgr.latest_step() == 5


def test_elastic_reshard_restore(tmp_path, rng):
    """Save unsharded, restore onto a different mesh layout."""
    n = len(jax.devices())
    if n < 1:
        pytest.skip("no devices")
    from jax.sharding import NamedSharding, PartitionSpec as P

    from repro.launch.mesh import make_mesh

    mesh = make_mesh((1,), ("model",))
    mgr = CheckpointManager(tmp_path, CheckpointPolicy(exact=True))
    tree = {"w": rng.normal(size=(64, 64)).astype(np.float32)}
    mgr.save(1, tree)
    sh = {"w": NamedSharding(mesh, P("model", None))}
    out, _ = mgr.restore(1, target=tree, shardings=sh)
    assert out["w"].sharding == sh["w"]
    np.testing.assert_array_equal(np.asarray(out["w"]), tree["w"])


# ---------------------------------------------------------------------------
# aggregated parallel-I/O layout (PR 5)
# ---------------------------------------------------------------------------


def test_save_writes_one_aggregated_segment_file(tmp_path, rng):
    """All leaves coalesce into one aligned segment file; the manifest maps
    keys to segments and records the writer's I/O stats."""
    from repro.runtime.io import AggregatedReader

    mgr = CheckpointManager(tmp_path, CheckpointPolicy(exact=True))
    tree = _tree(rng)
    manifest = mgr.save(1, tree)
    step_dir = tmp_path / "step_00000001"
    hpdr_files = [p.name for p in step_dir.glob("*.hpdr")]
    assert hpdr_files == ["leaves.hpdr"]          # ONE file, not one per leaf
    assert manifest["io"]["segments"] == len(manifest["leaves"])
    # coalescing: far fewer pwrites than segments (everything fits one buffer)
    assert manifest["io"]["writes"] < manifest["io"]["segments"]
    with AggregatedReader(step_dir / "leaves.hpdr") as r:
        for key, info in manifest["leaves"].items():
            assert info["segment"] in r.segments
            assert len(r.read(info["segment"])) == info["bytes"]


def test_partial_restore_preads_only_selected_leaves(tmp_path, rng):
    """restore(leaves=...) touches exactly the selected byte ranges."""
    mgr = CheckpointManager(tmp_path, CheckpointPolicy(exact=True))
    tree = _tree(rng)
    mgr.save(2, tree)
    flat, _ = mgr.restore(2, leaves={"w", "step"})
    assert set(flat) == {"w", "step"}
    np.testing.assert_array_equal(flat["w"], tree["w"])
    np.testing.assert_array_equal(flat["step"], tree["step"])


def test_restore_reads_pre_aggregation_layout(tmp_path, rng):
    """Checkpoints written before the aggregated writer (per-leaf files,
    no "aggregate" manifest key) still restore."""
    import json

    from repro.core import api as _api

    step_dir = tmp_path / "step_00000004"
    step_dir.mkdir(parents=True)
    arr = rng.normal(size=(8, 8)).astype(np.float32)
    blob = _api.compress_leaf(arr, "huffman-bytes").to_bytes()
    (step_dir / "w.hpdr").write_bytes(blob)
    manifest = {"step": 4, "extra": {}, "leaves":
                {"w": {"file": "w.hpdr", "bytes": len(blob), "raw": arr.nbytes}}}
    (step_dir / "manifest.json").write_text(json.dumps(manifest))
    (step_dir / "COMMITTED").write_text("ok")
    mgr = CheckpointManager(tmp_path)
    flat, _ = mgr.restore(4)
    np.testing.assert_array_equal(flat["w"], arr)


def test_queued_async_saves_chain_without_blocking(tmp_path, rng):
    """Back-to-back save_async calls return immediately; the second save
    chains on the first (io-lane order) and both commit."""
    import time as _t

    mgr = CheckpointManager(tmp_path, CheckpointPolicy(exact=True))
    tree = _tree(rng)
    t0 = _t.perf_counter()
    first = mgr.save_async(10, tree)
    second = mgr.save_async(11, tree)   # must not block on the first
    submit_s = _t.perf_counter() - t0
    manifest = mgr.wait()
    assert manifest["step"] == 11
    assert first.result()["step"] == 10
    assert submit_s < manifest["save_s"] + first.result()["save_s"]
    assert mgr.latest_step() == 11
    for s in (10, 11):
        out, _ = mgr.restore(s, target=tree)
        np.testing.assert_array_equal(np.asarray(out["w"]), tree["w"])
