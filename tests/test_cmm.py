"""CMM context cache: hit/miss accounting, LRU eviction, thread safety."""

import threading

from repro.core.context import ContextCache, ReductionContext, context_key


def _ctx(key):
    return ReductionContext(key=key, plan=lambda x: x)


def test_hit_miss():
    c = ContextCache(capacity=4)
    k = context_key("zfp", (64, 64), "float32", rate=16)
    c.get_or_create(k, lambda: _ctx(k))
    c.get_or_create(k, lambda: _ctx(k))
    assert c.hit_count == 1 and c.miss_count == 1


def test_lru_eviction():
    c = ContextCache(capacity=2)
    keys = [context_key("m", (i,), "f32") for i in range(3)]
    for k in keys:
        c.get_or_create(k, lambda k=k: _ctx(k))
    assert len(c) == 2
    assert keys[0] not in c and keys[2] in c
    assert c.evict_count == 1


def test_lru_recency():
    c = ContextCache(capacity=2)
    k0, k1, k2 = [context_key("m", (i,), "f32") for i in range(3)]
    c.get_or_create(k0, lambda: _ctx(k0))
    c.get_or_create(k1, lambda: _ctx(k1))
    c.get_or_create(k0, lambda: _ctx(k0))  # refresh k0
    c.get_or_create(k2, lambda: _ctx(k2))  # evicts k1
    assert k0 in c and k2 in c and k1 not in c


def test_thread_safety():
    c = ContextCache(capacity=64)
    k = context_key("z", (128,), "f32")
    errs = []

    def worker():
        try:
            for _ in range(200):
                c.get_or_create(k, lambda: _ctx(k))
        except Exception as e:  # noqa: BLE001
            errs.append(e)

    threads = [threading.Thread(target=worker) for _ in range(8)]
    for t in threads:
        t.start()
    for t in threads:
        t.join()
    assert not errs
    assert c.hit_count + c.miss_count == 8 * 200


def _sized_ctx(key, nbytes):
    import numpy as np

    return ReductionContext(
        key=key, plan=None, buffers={"buf": np.zeros(nbytes, np.uint8)}
    )


def test_byte_capacity_eviction_with_spill_hook():
    spilled = []
    c = ContextCache(capacity=64, capacity_bytes=2_500,
                     on_evict=spilled.append)
    keys = [context_key("kv", (i,), "u8") for i in range(4)]
    for k in keys:
        c.get_or_create(k, lambda k=k: _sized_ctx(k, 1_000))
    # 4 KB tracked > 2.5 KB budget -> two LRU entries evicted through the hook
    assert c.nbytes() <= 2_500
    assert [ctx.key for ctx in spilled] == keys[:2]
    assert keys[3] in c and keys[0] not in c
    assert c.evict_count == 2


def test_byte_capacity_never_evicts_newest():
    c = ContextCache(capacity=64, capacity_bytes=100)
    k = context_key("kv", (0,), "u8")
    c.get_or_create(k, lambda: _sized_ctx(k, 10_000))
    assert k in c  # an over-budget single context stays resident while in use


def test_explicit_evict_and_discard():
    spilled = []
    c = ContextCache(capacity=8, on_evict=spilled.append)
    k0, k1 = [context_key("kv", (i,), "u8") for i in range(2)]
    c.get_or_create(k0, lambda: _sized_ctx(k0, 10))
    c.get_or_create(k1, lambda: _sized_ctx(k1, 10))
    assert c.evict(k0).key == k0 and len(spilled) == 1
    assert c.discard(k1).key == k1 and len(spilled) == 1  # no hook
    assert c.evict(k0) is None


def test_nbytes_counts_callable_nbytes():
    class Obj:
        def nbytes(self):
            return 123

    ctx = ReductionContext(key="x", plan=None, buffers={"o": Obj()})
    assert ctx.nbytes() == 123
