"""Codec registry, plan reuse, and v1/v2 container round-trips."""

import numpy as np
import jax.numpy as jnp
import pytest

from repro.core import api
from repro.core.codecs import available_methods, get_codec
from repro.core.codecs.base import ReductionPlan, ReductionSpec
from repro.core.context import GLOBAL_CMM
from conftest import smooth_field_3d

ALL_METHODS = [
    ("mgard", {"error_bound": 1e-2}),
    ("zfp", {"rate": 12}),
    ("huffman", {}),
    ("huffman-bytes", {}),
]


def _data_for(method, rng):
    if method == "huffman":
        return np.minimum(np.abs(rng.normal(0, 10, 8192)).astype(np.int32), 255)
    return smooth_field_3d(24)


# ---------------------------------------------------------------------------
# registry
# ---------------------------------------------------------------------------


def test_registry_has_all_methods():
    assert set(api.METHODS) <= set(available_methods())
    for m in api.METHODS:
        codec = get_codec(m)
        assert codec.name == m


def test_registry_unknown_method():
    with pytest.raises(ValueError, match="unknown method"):
        get_codec("lz77")
    with pytest.raises(ValueError):
        api.compress(jnp.zeros(4), "lz77")


# ---------------------------------------------------------------------------
# container round-trips (v1 + v2) for every registered method
# ---------------------------------------------------------------------------


@pytest.mark.parametrize("method,kw", ALL_METHODS)
@pytest.mark.parametrize("version", [1, 2])
def test_container_roundtrip_all_methods(method, kw, version, rng):
    data = _data_for(method, rng)
    c = api.compress(jnp.asarray(data), method, **kw)
    c2 = api.Compressed.from_bytes(c.to_bytes(version=version))
    assert c2.method == method
    assert set(c2.arrays) == set(c.arrays)
    out = np.asarray(api.decompress(c2))
    ref = np.asarray(api.decompress(c))
    np.testing.assert_array_equal(out, ref)
    if method in ("huffman", "huffman-bytes"):
        np.testing.assert_array_equal(out, data)
    else:
        vr = data.max() - data.min()
        assert np.abs(out - data).max() <= 2e-2 * vr


def test_container_rejects_unknown_version():
    c = api.compress(jnp.zeros((8, 8), jnp.float32), "zfp", rate=8)
    raw = bytearray(c.to_bytes())
    raw[4:8] = np.uint32(7).tobytes()
    with pytest.raises(ValueError, match="version 7"):
        api.Compressed.from_bytes(bytes(raw))


def test_container_rejects_truncation():
    c = api.compress(jnp.zeros((8, 8), jnp.float32), "zfp", rate=8)
    for version in (1, 2):
        raw = c.to_bytes(version=version)
        with pytest.raises(ValueError, match="truncated"):
            api.Compressed.from_bytes(raw[:10])
        with pytest.raises(ValueError, match="truncated"):
            api.Compressed.from_bytes(raw[: len(raw) - 5])


def test_container_rejects_bad_magic_and_corrupt_payload():
    c = api.compress(jnp.ones((16,), jnp.float32), "zfp", rate=8)
    raw = bytearray(c.to_bytes())
    with pytest.raises(ValueError, match="not an HPDR stream"):
        api.Compressed.from_bytes(b"XXXX" + bytes(raw[4:]))
    raw[-1] ^= 0xFF  # flip a payload bit → checksum must catch it
    with pytest.raises(ValueError, match="corrupt HPDR payload"):
        api.Compressed.from_bytes(bytes(raw))


# ---------------------------------------------------------------------------
# plan reuse through the CMM
# ---------------------------------------------------------------------------


def test_plan_reuse_same_spec_is_cache_hit():
    """Two compress() calls with one ReductionSpec share one cached plan."""
    f = smooth_field_3d(16)
    spec = api.make_spec(f, "zfp", rate=9)
    GLOBAL_CMM.clear()
    h0, m0 = GLOBAL_CMM.hit_count, GLOBAL_CMM.miss_count

    api.encode(spec, jnp.asarray(f))
    api.encode(spec, jnp.asarray(f))

    assert GLOBAL_CMM.miss_count == m0 + 1  # plan built exactly once
    assert GLOBAL_CMM.hit_count >= h0 + 1   # second call is a hit
    ctx = GLOBAL_CMM.get_or_create(spec.key(), lambda: None)
    plan = ctx.plan
    assert isinstance(plan, ReductionPlan)
    assert plan.spec == spec
    assert callable(plan.executables["encode"])  # the jitted executable


def test_compress_wrapper_builds_identical_specs():
    """Equivalent keyword calls map to one spec → one CMM entry."""
    f = smooth_field_3d(16)
    GLOBAL_CMM.clear()
    h0, m0 = GLOBAL_CMM.hit_count, GLOBAL_CMM.miss_count
    api.compress(jnp.asarray(f), "zfp", rate=10)
    api.compress(jnp.asarray(f), "zfp", rate=10, error_bound=0.5)  # irrelevant kw
    assert GLOBAL_CMM.hit_count >= h0 + 1
    assert GLOBAL_CMM.miss_count == m0 + 1


def test_defaulted_and_explicit_specs_share_one_key():
    """Omitted params are filled with codec defaults → one canonical key."""
    f = smooth_field_3d(16)
    assert api.make_spec(f, "zfp") == api.make_spec(f, "zfp", rate=16)
    assert api.make_spec(f, "mgard") == api.make_spec(
        f, "mgard", error_bound=1e-2, relative=True, dict_size=4096
    )


def test_cmm_accounts_workspace_bytes():
    """Plan workspace buffers are visible to CMM byte accounting."""
    f = smooth_field_3d(16)
    GLOBAL_CMM.clear()
    api.compress(jnp.asarray(f), "mgard", error_bound=1e-2)
    assert GLOBAL_CMM.stats()["bytes"] > 0


def test_mgard_plan_workspace_persists():
    """The level map is a persistent workspace buffer, not rebuilt per call."""
    f = smooth_field_3d(16)
    spec = api.make_spec(f, "mgard", error_bound=1e-2, relative=True,
                         dict_size=1024)
    p1 = api.get_plan(spec)
    api.encode(spec, jnp.asarray(f))
    p2 = api.get_plan(spec)
    assert p1 is p2
    assert p1.workspace["lmap"] is p2.workspace["lmap"]
    assert p1.nbytes() > 0


def test_decode_spec_shares_plans_across_error_bounds():
    """MGARD reconstruction plans depend only on geometry + dict size."""
    f = smooth_field_3d(16)
    c1 = api.compress(jnp.asarray(f), "mgard", error_bound=1e-2)
    c2 = api.compress(jnp.asarray(f), "mgard", error_bound=1e-3)
    codec = get_codec("mgard")
    assert codec.decode_spec(c1) == codec.decode_spec(c2)


# ---------------------------------------------------------------------------
# pytree + streaming entry points
# ---------------------------------------------------------------------------


def test_compress_pytree_roundtrip(rng):
    tree = {
        "w": rng.normal(size=(64, 128)).astype(np.float32),
        "small": rng.normal(size=(8,)).astype(np.float32),
        "ids": np.arange(10, dtype=np.int32),
        "nested": {"emb": rng.normal(size=(128, 64)).astype(np.float32)},
    }
    comp, stats = api.compress_pytree(tree)
    assert stats["ratio"] > 1.0
    assert stats["compressed_leaves"] == 2  # the two big float tensors
    out = api.decompress_pytree(comp, tree)
    import jax

    for a, b in zip(jax.tree.leaves(out), jax.tree.leaves(tree)):
        a, b = np.asarray(a), np.asarray(b)
        assert a.shape == b.shape and a.dtype == b.dtype
        if b.dtype.kind != "f" or b.size < 4096:
            np.testing.assert_array_equal(a, b)


def test_compressor_stream_roundtrip_and_bytes():
    data = smooth_field_3d(32)
    stream = api.CompressorStream("zfp", mode="fixed", c_fixed_elems=8 * 32 * 32,
                                  rate=16)
    res = stream.compress(data)
    assert len(res.chunks) > 1
    out = stream.decompress(res)
    assert out.shape == data.shape
    assert np.abs(out - data).max() < 2e-3

    blob = api.CompressorStream.to_bytes(res)
    res2 = api.CompressorStream.from_bytes(blob)
    np.testing.assert_array_equal(stream.decompress(res2), out)
    with pytest.raises(ValueError):
        api.CompressorStream.from_bytes(blob[: len(blob) // 2])


def test_compressor_stream_chunks_hit_plan_cache():
    data = smooth_field_3d(32)
    stream = api.CompressorStream("zfp", mode="fixed", c_fixed_elems=8 * 32 * 32,
                                  rate=7)
    GLOBAL_CMM.clear()
    h0, m0 = GLOBAL_CMM.hit_count, GLOBAL_CMM.miss_count
    res = stream.compress(data)
    # equal-shaped chunks share one spec → misses ≪ chunks
    hits, misses = GLOBAL_CMM.hit_count - h0, GLOBAL_CMM.miss_count - m0
    assert len(res.chunks) > 2
    assert misses < len(res.chunks)
    assert hits >= len(res.chunks) - misses
